"""Assigned architecture configs (``--arch <id>``).

Each module exports CONFIG (the exact published configuration) and
SMOKE (a reduced same-family config for CPU smoke tests).
"""
from __future__ import annotations

import importlib

ARCHS = [
    "deepseek_coder_33b", "qwen3_14b", "glm4_9b", "gemma2_27b",
    "llama4_scout_17b_a16e", "grok1_314b", "rwkv6_7b", "llava_next_34b",
    "zamba2_1p2b", "whisper_small",
]

_ALIASES = {a.replace("_", "-"): a for a in ARCHS}
_ALIASES.update({
    "deepseek-coder-33b": "deepseek_coder_33b",
    "qwen3-14b": "qwen3_14b",
    "glm4-9b": "glm4_9b",
    "gemma2-27b": "gemma2_27b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "grok-1-314b": "grok1_314b",
    "rwkv6-7b": "rwkv6_7b",
    "llava-next-34b": "llava_next_34b",
    "zamba2-1.2b": "zamba2_1p2b",
    "whisper-small": "whisper_small",
})


def get_config(arch: str, smoke: bool = False):
    mod_name = _ALIASES.get(arch, arch)
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.SMOKE if smoke else mod.CONFIG


def all_configs(smoke: bool = False):
    return {a: get_config(a, smoke) for a in ARCHS}
