"""whisper-small [audio] — enc-dec, conv frontend stubbed
[arXiv:2212.04356; unverified]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small", family="audio",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
    d_ff=3072, vocab=51865, encoder_layers=12, n_frames=1500,
)

SMOKE = CONFIG.scaled(n_layers=2, d_model=64, n_heads=2, n_kv_heads=2,
                      d_ff=128, vocab=256, encoder_layers=2, n_frames=32)
