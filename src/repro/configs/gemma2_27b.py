"""gemma2-27b [dense] — local+global alternating, logit softcap
[arXiv:2408.00118; hf].  head_dim=128 explicit (32·128 ≠ d_model)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b", family="dense",
    n_layers=46, d_model=4608, n_heads=32, n_kv_heads=16,
    d_ff=36864, vocab=256000, head_dim=128,
    layer_pattern="local_global", sliding_window=4096,
    attn_softcap=50.0, final_softcap=30.0,
)

SMOKE = CONFIG.scaled(n_layers=4, d_model=128, n_heads=4, n_kv_heads=2,
                      d_ff=256, vocab=512, head_dim=32, sliding_window=16)
