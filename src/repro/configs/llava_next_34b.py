"""llava-next-34b [vlm] — anyres tiling; backbone only, patch embeddings
stubbed [hf:llava-hf/llava-v1.6; unverified]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b", family="vlm",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=20480, vocab=64000, n_patches=576,
)

SMOKE = CONFIG.scaled(n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
                      d_ff=256, vocab=512, n_patches=16)
