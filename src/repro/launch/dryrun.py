import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

Proves the distribution config is coherent without hardware: pjit each
step function onto the production mesh with ShapeDtypeStruct inputs,
``.lower().compile()``, and record memory_analysis / cost_analysis /
collective-bytes (parsed from HLO) for the roofline tables
(benchmarks/roofline.py, rendered by benchmarks/report.py).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-14b \
        --shape train_4k --mesh single            # one cell
    PYTHONPATH=src python -m repro.launch.dryrun --all --out dryrun.jsonl
"""
import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_config
from repro.launch import hlo_analysis
from repro.dist.sharding import (batch_sharding, decode_state_shardings,
                                 param_shardings, replicated,
                                 set_activation_mesh)
from repro.launch.mesh import make_production_mesh
from repro.models import api
from repro.serve.serve_step import make_decode_step, make_prefill_step
from repro.train.optimizer import AdamWConfig, opt_state_specs
from repro.train.train_step import TrainConfig, make_train_step

def _tree_bytes(specs) -> int:
    return sum(s.size * s.dtype.itemsize for s in jax.tree.leaves(specs))


# grad-accumulation microbatches per arch (train_4k): keeps per-device
# activation memory inside v5e HBM; chosen from the memory_analysis sweep
MICROBATCHES = {
    "deepseek-coder-33b": 8, "llava-next-34b": 8, "grok-1-314b": 4,
    "gemma2-27b": 4, "qwen3-14b": 2, "glm4-9b": 2,
    "llama4-scout-17b-a16e": 4, "rwkv6-7b": 2, "zamba2-1.2b": 1,
    "whisper-small": 1,
}


def build_cell(cfg, shape):
    """→ (fn, example_args (ShapeDtypeStructs), in_shardings fn, donate)."""
    pspecs = api.param_specs(cfg)
    if shape.kind == "train":
        ocfg = AdamWConfig(
            moment_dtype="bfloat16" if cfg.name == "grok-1-314b" else "float32")
        tcfg = TrainConfig(optimizer=ocfg,
                           microbatches=MICROBATCHES.get(cfg.name, 1))
        step = make_train_step(cfg, tcfg)
        ospecs = opt_state_specs(pspecs, ocfg)
        bspecs = api.input_specs(cfg, shape)

        def shardings(mesh):
            # ZeRO-1: moments additionally sharded over the DP axes
            return (param_shardings(cfg, pspecs, mesh),
                    {"m": param_shardings(cfg, ospecs["m"], mesh, zero=True),
                     "v": param_shardings(cfg, ospecs["v"], mesh, zero=True),
                     "step": replicated(mesh)},
                    batch_sharding(mesh, bspecs))

        return step, (pspecs, ospecs, bspecs), shardings, (0, 1)
    if shape.kind == "prefill":
        fn = make_prefill_step(cfg)
        bspecs = api.input_specs(cfg, shape)

        def shardings(mesh):
            return (param_shardings(cfg, pspecs, mesh),
                    batch_sharding(mesh, bspecs))

        return fn, (pspecs, bspecs), shardings, ()
    # decode
    fn = make_decode_step(cfg)
    bspecs = api.input_specs(cfg, shape)
    sspecs = api.decode_state_specs(cfg, shape.global_batch, shape.seq_len)
    pos = jax.ShapeDtypeStruct((), jnp.int32)

    def shardings(mesh):
        return (param_shardings(cfg, pspecs, mesh),
                batch_sharding(mesh, bspecs),
                decode_state_shardings(cfg, sspecs, mesh),
                replicated(mesh))

    return fn, (pspecs, bspecs, sspecs, pos), shardings, (2,)


def run_cell(arch: str, shape_name: str, multi_pod: bool) -> dict:
    from repro.models.api import SHAPES, shape_supported
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    rec = {"arch": cfg.name, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16"}
    if not shape_supported(cfg, shape):
        rec["status"] = "skipped"
        rec["reason"] = ("long-context decode requires sub-quadratic "
                         "attention (DESIGN.md §5)")
        return rec
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    set_activation_mesh(mesh)
    fn, args, shardings, donate = build_cell(cfg, shape)
    with mesh:
        jitted = jax.jit(fn, in_shardings=shardings(mesh),
                         donate_argnums=donate)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        t1 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t1
        # collectives appear only in the SPMD-partitioned module; the
        # trip-count-aware analyzer corrects for scan bodies (hlo_analysis)
        analysis = hlo_analysis.analyze(compiled.as_text())
        coll = analysis["collective_bytes"]
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
    n_dev = mesh.devices.size
    rec.update({
        "status": "ok",
        "n_devices": int(n_dev),
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "param_bytes": _tree_bytes(args[0]),
        "dot_flops": analysis["dot_flops"],
        "hbm_traffic_bytes": analysis["hbm_traffic_bytes"],
        "unfused_traffic_bytes": analysis["unfused_traffic_bytes"],
        "collectives": coll,
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes",
                                            None),
        },
        "cost": {k: cost.get(k) for k in
                 ("flops", "bytes accessed", "transcendentals", "utilization")
                 if isinstance(cost, dict) and k in cost},
    })
    if not isinstance(cost, dict):
        try:
            rec["cost"] = {"flops": cost[0].get("flops"),
                           "bytes accessed": cost[0].get("bytes accessed")}
        except Exception:
            rec["cost"] = {}
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None, help="append JSONL here")
    args = ap.parse_args()

    from repro.models.api import SHAPES
    cells = []
    archs = ARCHS if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    done = set()
    if args.out and os.path.exists(args.out):
        for line in open(args.out):
            r = json.loads(line)
            if r.get("status") in ("ok", "skipped"):
                done.add((r["arch"], r["shape"], r["mesh"]))
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                cells.append((arch, shape, mp))
    for arch, shape, mp in cells:
        cfgname = get_config(arch).name
        key = (cfgname, shape, "2x16x16" if mp else "16x16")
        if key in done:
            print(f"[skip-cached] {key}", flush=True)
            continue
        print(f"[cell] {key} ...", flush=True)
        try:
            rec = run_cell(arch, shape, mp)
        except Exception as e:  # record failures — they are bugs to fix
            rec = {"arch": cfgname, "shape": shape,
                   "mesh": "2x16x16" if mp else "16x16",
                   "status": "error", "error": repr(e)[:2000]}
        print(json.dumps(rec)[:600], flush=True)
        if args.out:
            with open(args.out, "a") as f:
                f.write(json.dumps(rec) + "\n")
        if rec["status"] == "ok":
            m = rec["memory"]
            print(f"    mem/dev: args={m['argument_bytes']}, "
                  f"temp={m['temp_bytes']}; flops={rec['cost'].get('flops')}; "
                  f"coll={rec['collectives']['total']}", flush=True)


if __name__ == "__main__":
    main()
