"""Serving launcher: prefill + decode loop with paged KV bookkeeping.

    PYTHONPATH=src python -m repro.launch.serve --arch zamba2-1.2b --smoke \
        --requests 8 --steps 32

On a real mesh the same decode step is jitted with the production
shardings (launch/dryrun.py proves every arch × decode shape lowers); on
this container it runs the smoke config on one CPU device.
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="zamba2-1.2b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.models import api
    from repro.serve.kvcache import PagedKVCache
    from repro.serve.serve_step import make_decode_step

    cfg = get_config(args.arch, smoke=args.smoke)
    print(f"[serve] {cfg.name} (reduced={args.smoke})")
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    decode = jax.jit(make_decode_step(cfg))
    rng = np.random.default_rng(0)
    queue = [rng.integers(1, cfg.vocab, int(rng.integers(4, 12)))
             .astype(np.int32) for _ in range(args.requests)]

    pool = PagedKVCache(n_pages=1024)
    state = api.init_decode_state(cfg, params, args.batch, args.max_len)
    slots = [None] * args.batch
    next_req, pos, out_tokens, completed = 0, 0, 0, 0
    t0 = time.time()
    for step in range(args.steps):
        for b in range(args.batch):
            if slots[b] is None and next_req < len(queue):
                slots[b] = {"id": next_req, "prompt": list(queue[next_req]),
                            "fed": 0, "out": []}
                pool.add_sequence(next_req)
                next_req += 1
        feed = np.zeros((args.batch, 1), np.int32)
        for b, s in enumerate(slots):
            if s is None:
                continue
            feed[b, 0] = (s["prompt"][s["fed"]] if s["fed"] < len(s["prompt"])
                          else (s["out"][-1] if s["out"] else 1))
        logits, state = decode(params, {"tokens": jnp.asarray(feed)}, state,
                               pos)
        nxt = np.asarray(jnp.argmax(logits, -1))
        pos += 1
        for b, s in enumerate(slots):
            if s is None:
                continue
            pool.append_tokens(s["id"], 1)
            if s["fed"] < len(s["prompt"]):
                s["fed"] += 1
            else:
                s["out"].append(int(nxt[b]))
                out_tokens += 1
                if len(s["out"]) >= 8:
                    completed += 1
                    pool.release(s["id"])
                    slots[b] = None
    dt = time.time() - t0
    print(f"[done] {args.steps} steps, {out_tokens} tokens, "
          f"{completed} requests complete, {out_tokens / dt:.1f} tok/s")
    print("[page table]", pool.tune_table("hbm").design.describe()
          if pool.tables else "(empty)")


if __name__ == "__main__":
    main()
