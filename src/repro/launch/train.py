"""Training launcher: mesh setup, sharded train loop, fault tolerance.

On a real cluster every host runs this same file (jax.distributed
initializes from the pod environment); on this container it drives the
single CPU device end-to-end with the identical code path:

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-14b --smoke \
        --steps 20 --batch 4 --seq 128

Production features wired in: ZeRO-1 optimizer sharding, activation
sharding constraints, grad accumulation, deterministic replayable data
(ShardedTokenStore), periodic AirIndex-manifest checkpoints, and the
TrainingSupervisor restart loop (heartbeats + elastic re-mesh).
"""
from __future__ import annotations

import argparse
import os
import time

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-14b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--workdir", default="/tmp/repro-train")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--data", default=None, help="token store dir")
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.data.store import ShardedTokenStore, write_token_store
    from repro.models import api
    from repro.train.checkpoint import restore_checkpoint, save_checkpoint
    from repro.train.fault_tolerance import FTConfig, TrainingSupervisor
    from repro.train.train_step import TrainConfig, make_train_step
    from repro.train.optimizer import adamw_init

    cfg = get_config(args.arch, smoke=args.smoke)
    os.makedirs(args.workdir, exist_ok=True)
    print(f"[train] {cfg.name} smoke={args.smoke} devices={jax.devices()}")

    # data: build a synthetic store if none given (deterministic, replayable)
    data_dir = args.data or os.path.join(args.workdir, "data")
    if not os.path.exists(os.path.join(data_dir, "offsets.npy")):
        rng = np.random.default_rng(0)
        samples = [rng.integers(0, cfg.vocab, rng.integers(64, 512))
                   .astype(np.int32) for _ in range(2048)]
        write_token_store(data_dir, samples)
    store = ShardedTokenStore(data_dir, profile="azure_ssd")
    print(f"[data] sample index: {store.tune.design.describe()}")

    tcfg = TrainConfig(microbatches=args.microbatches)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params, tcfg.optimizer)
    step_fn = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0, 1))

    def save(state, step):
        save_checkpoint(args.workdir, state["params"], step=step,
                        profile="azure_ssd")

    def restore(step):
        # build the restore template from specs — the live params
        # were donated to step_fn and their buffers are gone
        like = jax.tree.map(lambda s: np.zeros(s.shape, s.dtype),
                            api.param_specs(cfg))
        tree, stats = restore_checkpoint(args.workdir, like, step=step)
        print(f"[restore] step={step} bytes_read={stats['bytes_read']}")
        # fresh moments: the pre-failure opt state was donated to step_fn
        restored = jax.tree.map(jnp.asarray, tree)
        return {"params": restored, "opt": adamw_init(restored, tcfg.optimizer)}

    sup = TrainingSupervisor(args.workdir, [f"host{i}" for i in range(4)],
                             FTConfig(checkpoint_every=args.ckpt_every),
                             save, restore)
    it = store.batch_iterator(args.batch, args.seq, seed=0)
    losses = []

    def one_step(state, step):
        batch = next(it)
        p, o, m = step_fn(state["params"], state["opt"],
                          jax.tree.map(jnp.asarray, batch))
        losses.append(float(m["loss"]))
        if step % 5 == 0:
            print(f"[step {step}] loss={losses[-1]:.4f} "
                  f"gnorm={float(m['grad_norm']):.3f}")
        return {"params": p, "opt": o}

    t0 = time.time()
    state = {"params": params, "opt": opt}
    state, steps, log = sup.run(state, one_step, n_steps=args.steps)
    dt = time.time() - t0
    tok_s = args.steps * args.batch * args.seq / dt
    print(f"[done] {steps} steps in {dt:.1f}s ({tok_s:.0f} tok/s); "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    assert losses[-1] < losses[0], "loss must decrease"
    store.close()


if __name__ == "__main__":
    main()
