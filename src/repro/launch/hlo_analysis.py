"""Trip-count-aware analysis of partitioned HLO text.

``compiled.cost_analysis()`` visits every computation ONCE — a collective
or matmul inside a scanned layer body is counted once even though it
executes n_layers times.  For scan-over-layers models that understates
FLOPs/bytes by ~L×, so the roofline is derived here instead:

  1. parse the module into computations and instructions;
  2. recover while-loop trip counts from the loop-condition's comparison
     constant, and propagate multipliers along the call graph
     (while bodies, fusions, calls, conditionals*);
  3. accumulate, weighted by multiplier:
       · dot FLOPs          2 · |result| · Π(contracting dims)
       · HBM traffic        Σ (operand + result bytes) of top-level
                            fusions / dots / copies / DUS / collectives —
                            each top-level op reads operands from and
                            writes results to HBM on real hardware;
       · collective bytes   operand bytes of all-gather / all-reduce /
                            reduce-scatter / all-to-all / collective-permute.

  *conditional branches are counted once (an upper bound of one branch).

Raw cost_analysis numbers are also recorded for cross-checking.
"""
from __future__ import annotations

import re

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(
    r"(f64|f32|bf16|f16|s64|u64|s32|u32|s16|u16|s8|u8|pred|f8e4m3fn|f8e5m2|"
    r"c64|c128)\[([\d,]*)\]")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->\s*.+\{\s*$")
_TRIP_RE = re.compile(r'known_trip_count[^}]*"n":"(\d+)"')
_INST_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_CALL_ATTR_RE = re.compile(r"(?:calls|to_apply|body|condition)=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _shape_list_bytes(text: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(text):
        n = 1
        for d in m.group(2).split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[m.group(1)]
    return total


def _result_dims(text: str):
    """First shape in text → (dtype, dims list)."""
    m = _SHAPE_RE.search(text)
    if not m:
        return None, []
    dims = [int(d) for d in m.group(2).split(",") if d]
    return m.group(1), dims


class Instruction:
    __slots__ = ("name", "body", "opcode", "result_bytes", "operands")

    def __init__(self, name, body):
        self.name = name
        self.body = body
        # opcode = first word after the result type(s)
        m = re.search(r"\)?\s([a-z][\w\-]*)\(", body)
        self.opcode = m.group(1) if m else ""
        # result type: prefix of body up to opcode
        head = body[:m.start()] if m else body
        self.result_bytes = _shape_list_bytes(head)
        # operand names inside the first paren group after opcode
        self.operands = []
        if m:
            inner = body[m.end():]
            depth, end = 1, len(inner)
            for i, ch in enumerate(inner):
                depth += (ch == "(") - (ch == ")")
                if depth == 0:
                    end = i
                    break
            group = inner[:end]
            if "%" in group:
                # modern printers emit typed operands:
                #   dot(f32[128,128]{1,0} %lhs.4, f32[128,128]{1,0} %rhs.8)
                # — the references are exactly the %-prefixed tokens
                self.operands = re.findall(r"%([\w.\-]+)", group)
            else:
                # untyped operand lists: every bare token is a reference
                self.operands = [t for t in re.findall(r"([\w.\-]+)", group)
                                 if not re.fullmatch(r"[\d.\-]+", t)
                                 and t not in _DTYPE_BYTES]


def parse_computations(hlo: str) -> dict:
    comps = {}
    cur, cur_name = None, None
    for line in hlo.splitlines():
        mc = _COMP_RE.match(line.strip()) if "{" in line else None
        if mc and ("->" in line):
            cur_name = mc.group(1)
            cur = []
            comps[cur_name] = cur
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        mi = _INST_RE.match(line)
        if mi:
            cur.append(Instruction(mi.group(1), mi.group(2)))
    return comps


def _trip_count(cond_insts) -> int:
    consts = []
    for inst in cond_insts:
        consts += [int(c) for c in _CONST_RE.findall(inst.body)]
    return max(consts) if consts else 1


def compute_multipliers(comps: dict) -> dict:
    """Multiplier per computation = product of enclosing while trip counts.

    Trip counts come from XLA's ``known_trip_count`` backend config on the
    while op (exact), falling back to the largest constant in the loop
    condition.  Multipliers propagate along the call graph (fusion calls,
    to_apply, while body/condition, conditional branches).
    """
    called = set()
    calls = {name: [] for name in comps}   # name -> [(callee, factor)]
    for name, insts in comps.items():
        for inst in insts:
            callees = [c for c in _CALL_ATTR_RE.findall(inst.body)]
            for group in _BRANCHES_RE.findall(inst.body):
                callees += [c.strip().lstrip("%") for c in group.split(",")]
            if not callees:
                continue
            factor = 1
            if inst.opcode == "while":
                mt = _TRIP_RE.search(inst.body)
                if mt:
                    factor = int(mt.group(1))
                else:
                    mcond = re.search(r"condition=%?([\w.\-]+)", inst.body)
                    if mcond and mcond.group(1) in comps:
                        factor = _trip_count(comps[mcond.group(1)])
            for c in callees:
                if c in comps:
                    calls[name].append((c, factor))
                    called.add(c)
    roots = [n for n in comps if n not in called]
    mult = {n: 0 for n in comps}
    stack = [(r, 1) for r in roots]
    guard = 0
    while stack and guard < 1_000_000:
        guard += 1
        name, m = stack.pop()
        if m <= mult[name]:
            continue
        mult[name] = m
        for callee, factor in calls[name]:
            stack.append((callee, m * factor))
    return mult


_DOT_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def top_dots(hlo: str, k: int = 15) -> list:
    """The k biggest matmuls by trip-corrected FLOPs — the §Perf profile."""
    comps = parse_computations(hlo)
    mult = compute_multipliers(comps)
    shapes = {}
    for insts in comps.values():
        for inst in insts:
            head = inst.body.split(inst.opcode + "(")[0] if inst.opcode \
                else inst.body
            dt, dims = _result_dims(head)
            shapes[inst.name] = (dt, dims)
    out = []
    for cname, insts in comps.items():
        m = mult.get(cname, 1) or 1
        for inst in insts:
            if inst.opcode != "dot":
                continue
            _, dims = shapes.get(inst.name, (None, []))
            cm = _DOT_CONTRACT_RE.search(inst.body)
            csize = 1
            lhs_dims = []
            if cm and inst.operands:
                lhs_dims = shapes.get(inst.operands[0], (None, []))[1]
                for ci in cm.group(1).split(","):
                    if ci and int(ci) < len(lhs_dims):
                        csize *= lhs_dims[int(ci)]
            n = 1
            for d in dims:
                n *= d
            meta = re.search(r'op_name="([^"]*)"', inst.body)
            out.append({
                "flops": 2.0 * n * csize * m,
                "result": dims, "contract": csize, "mult": m,
                "comp": cname,
                "op_name": meta.group(1)[-90:] if meta else inst.name,
            })
    out.sort(key=lambda d: -d["flops"])
    return out[:k]


def analyze(hlo: str, sizes_hint: dict | None = None) -> dict:
    comps = parse_computations(hlo)
    mult = compute_multipliers(comps)
    # global name → result bytes / dims (names are unique per module)
    shapes = {}
    for insts in comps.values():
        for inst in insts:
            head = inst.body.split(inst.opcode + "(")[0] if inst.opcode else inst.body
            dt, dims = _result_dims(head)
            shapes[inst.name] = (dt, dims, inst.result_bytes)

    flops = 0.0
    dot_traffic = 0.0       # matmul operands/results — real HBM crossings
    dus_traffic = 0.0       # dynamic-update-slice writes (KV-cache updates)
    unfused_traffic = 0.0   # everything at top level (CPU-HLO upper bound)
    coll = {k: 0.0 for k in COLLECTIVES}
    coll_count = 0
    # ops whose operands/results cross HBM when they appear at top level
    # (inside fused computations the intermediates stay in registers/VMEM)
    top_level = ("fusion", "dot", "copy", "dynamic-update-slice",
                 "convolution", "scatter", "gather",
                 "sort", "concatenate", "dynamic-slice", "pad",
                 "reduce", "transpose", "convert", "add", "multiply",
                 "select", "tanh", "exp", "broadcast") + COLLECTIVES

    for cname, insts in comps.items():
        m = mult.get(cname, 1) or 1
        fused_ctx = cname.startswith(("fused", "wrapped"))
        for inst in insts:
            op = inst.opcode
            opb = sum(shapes.get(o, (None, [], 0))[2] for o in inst.operands)
            if op == "dot":
                _, dims, _ = shapes.get(inst.name, (None, [], 0))
                cm = _DOT_CONTRACT_RE.search(inst.body)
                csize = 1
                if cm and inst.operands:
                    lhs = shapes.get(inst.operands[0], (None, [], 0))[1]
                    for ci in cm.group(1).split(","):
                        if ci and int(ci) < len(lhs):
                            csize *= lhs[int(ci)]
                n = 1
                for d in dims:
                    n *= d
                flops += 2.0 * n * csize * m
                dot_traffic += (opb + inst.result_bytes) * m
            if op in ("dynamic-update-slice", "scatter"):
                # in-place update: traffic = the update slice (read) + the
                # written region — NOT the whole aliased target buffer
                upd = (shapes.get(inst.operands[1], (None, [], 0))[2]
                       if len(inst.operands) > 1 else inst.result_bytes)
                dus_traffic += 2.0 * upd * m
            base = op.split("-start")[0]
            if base in COLLECTIVES:
                coll[base] += opb * m
                coll_count += 1
            if not fused_ctx and op in top_level:
                unfused_traffic += (opb + inst.result_bytes) * m

    return {
        "dot_flops": flops,
        # memory roofline term: matmul + cache-update traffic.  Elementwise
        # chains fuse on TPU (unfused CPU-HLO counting overstates traffic
        # 10–50×); kept separately as an upper bound.
        "hbm_traffic_bytes": dot_traffic + dus_traffic,
        "unfused_traffic_bytes": unfused_traffic,
        "dus_traffic_bytes": dus_traffic,
        "collective_bytes": {**{k: coll[k] for k in COLLECTIVES},
                             "total": sum(coll.values()),
                             "count": coll_count},
        "n_computations": len(comps),
    }
