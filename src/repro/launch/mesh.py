"""Production mesh definitions.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — only the dry-run/launcher
call it after setting the host-platform device count.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Elastic variant: the fault-tolerance path re-forms smaller meshes."""
    return jax.make_mesh(tuple(shape), tuple(axes))
