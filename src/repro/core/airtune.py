"""AirTune — guided graph search with bounded visits (paper §5, Alg. 2).

Vertices are key-position collections (the origin is the data layer); an
edge applies a layer builder ``F ∈ 𝓕`` and moves to the layer's outline.
The value function solved here is exactly Alg. 2's recursion:

    V(D) = min( T(s_D),                                  # stop: D is root
                min_{Θ_next} E_X[T(Δ(x; Θ_next))] + V(outline(Θ_next)) )

with two paper mechanisms bounding the visit count:

  * **stopping criterion** (Alg. 2 lines 1–2): if reading all of ``D``
    already beats an *ideal* extra layer (1-byte root + 1-byte precise
    read), stop — no real layer can help;
  * **top-k selection** (Eq. 9): recurse only into the k candidates with
    the smallest ``τ̂(D_next; T) + E_X[T(Δ(x; Θ_next))]``.

Exactness of the expectation: step widths are constant per piece and band
widths constant per node, and piece/node boundaries are drawn from the
collection's keys, so evaluating widths at outline keys with aggregated
weights equals evaluating at the original query keys (see latency.py).

Candidate expansion runs through the fused sweep engine
(:class:`repro.core.sweep.SweepEngine`): per vertex, every family's
λ-column builds in one multi-λ call, all candidates score in one batched
``E[T(Δ)]`` evaluation, and expansions are memoized by collection
fingerprint.  ``sweep=False`` keeps the original per-builder loop as a
bit-identical reference/escape hatch (tests certify equality).

Three :class:`SearchStrategy` implementations share this machinery and are
registered in :data:`repro.core.registry.SEARCH_STRATEGIES` (the public
facade ``repro.api`` resolves strategy *names* through that registry):

  * :func:`airtune`     — the paper's guided depth-first search (Alg. 2);
  * :func:`brute_force` — exhaustive reference (no pruning, no τ̂);
  * :func:`beam_search` — breadth-first with a width-``k`` frontier; same
    stopping criterion and Eq. 9 score, but total layer builds bounded by
    ``max_layers · k · |𝓕|`` (predictable tuning cost on huge 𝓕).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Protocol

import numpy as np

from .builders import LayerBuilder, make_builders
from .complexity import tau_hat
from .keyset import KeyPositions
from .latency import IndexDesign, expected_latency, ideal_latency_with_index
from .nodes import Layer, outline
from .registry import register_strategy
from .storage import StorageProfile, normalize_objective, objective_profile
from .sweep import SCORE_SAMPLE, LayerCache, SweepEngine


@dataclasses.dataclass
class TuneStats:
    vertices_visited: int = 0
    layers_built: int = 0        # candidate layers actually constructed
    layers_reused: int = 0       # builds avoided: λ-dedup + vertex memo hits
    layers_seeded: int = 0       # warm-start: previous-design layers injected
    candidates_pruned: int = 0   # discarded without recursion: non-shrinking
    #                              outlines + beyond-top-k (guided searches)
    candidates_scored: int = 0   # E[T(Δ)] evaluations performed (est + exact)
    sweeps: int = 0              # fused children-of-vertex expansions
    sweep_seconds: float = 0.0   # wall-clock inside those expansions
    wall_seconds: float = 0.0


@dataclasses.dataclass(frozen=True)
class TuneResult:
    design: IndexDesign
    cost: float               # the objective's value on design: Eq. (6) for
    #                           "mean", E[T] + w·Q̂_p[T] for quantile tuning
    stats: TuneStats
    strategy: str = "airtune"          # which SearchStrategy produced this
    builder_names: tuple = ()          # provenance: F.name per layer, bottom-up
    objective: object = "mean"         # "mean" | {"p": q, "weight": w}

    def describe(self) -> str:
        return (f"[{self.strategy}] {self.design.describe()}  "
                f"cost={self.cost * 1e6:.1f}us  "
                f"(visited={self.stats.vertices_visited}, "
                f"built={self.stats.layers_built}, "
                f"reused={self.stats.layers_reused}, "
                f"pruned={self.stats.candidates_pruned}, "
                f"{self.stats.wall_seconds:.2f}s)")


class SearchStrategy(Protocol):
    """Protocol every registered search strategy implements.

    ``builders=None`` means the default Eq. (8) grid; ``k`` is the
    strategy's width/pruning knob (ignored by exhaustive strategies) and
    ``max_layers`` bounds the index depth.  Implementations must return a
    :class:`TuneResult` whose ``cost`` agrees with the Eq. (6) evaluator
    on the returned design.  The built-in strategies additionally accept
    ``sweep`` (False = legacy per-builder loop), ``score_backend``
    (``"numpy"`` default | ``"jnp"`` | ``"pallas"`` ranking fast paths),
    ``layer_cache`` (a shared :class:`repro.core.sweep.LayerCache` for
    cross-tune build reuse), ``seed_layers`` (warm-start: a previous
    design as ``(builder_name, layer)`` pairs, injected into the cache —
    and, for ``beam``, the initial frontier) and ``objective``
    (None/"mean" | ``{"p": q, "weight": w}`` tail-latency objective);
    third-party strategies need not (the facade refuses to route a
    quantile objective to a strategy that does not accept the kwarg).
    """

    def __call__(self, D: KeyPositions, profile: StorageProfile,
                 builders: list[LayerBuilder] | None = None, *,
                 k: int = 5, max_layers: int = 12) -> TuneResult: ...


def _mean_layer_read_cost(layer: Layer, D: KeyPositions,
                          profile: StorageProfile,
                          sample: bool = False) -> float:
    """E_{x∼X}[T(Δ(x; Θ))] over D's weighted keys.

    ``sample=True``: strided subsample for ranking-only estimates — exact
    evaluation of all |𝓕| candidates cost O(|𝓕|·n·log) per vertex and
    dominated tuning time (see the batched scorers in latency.py/sweep.py
    and the per-PR trend in BENCH_tune.json).
    """
    if sample and D.n > 2 * SCORE_SAMPLE:
        stride = D.n // SCORE_SAMPLE
        keys = D.keys[::stride]
        weights = D.weights[::stride]
    else:
        keys, weights = D.keys, D.weights
    wq = layer.widths_at(keys)
    return float(np.average(profile(wq), weights=weights))


def _require_sweep_for_seed(seed_layers, sweep: bool) -> None:
    if seed_layers and not sweep:
        raise ValueError("warm-start seeding (seed_layers) requires the "
                         "sweep engine; call with sweep=True")


def _objective_field(objective):
    """Normalized provenance value recorded on TuneResult."""
    norm = normalize_objective(objective)
    return "mean" if norm is None else {"p": norm[0], "weight": norm[1]}


@register_strategy("airtune")
def airtune(D: KeyPositions, profile: StorageProfile,
            builders: list[LayerBuilder] | None = None, *,
            k: int = 5, max_layers: int = 12, sweep: bool = True,
            score_backend: str = "numpy",
            layer_cache: LayerCache | None = None,
            seed_layers=None, objective=None) -> TuneResult:
    """Find Θ* ≈ argmin_Θ L_SM(X; Θ, T) (Table 3) via Alg. 2.

    ``seed_layers`` (warm start: a previous design as bottom-up
    ``(builder_name, layer)`` pairs) pre-populates the layer cache along
    the old design's path — pure memoization, so the returned design is
    bit-identical to a cold search with strictly fewer builds (the
    warm-vs-cold identity test certifies this).

    ``objective`` (None/"mean" default, or ``{"p": q, "weight": w}``)
    selects the cost the search minimizes: the mean objective runs on
    ``profile`` itself (bit-identical to the pre-objective search); a
    quantile objective swaps in the
    :class:`~repro.core.storage.ObjectiveProfile` cost curve so the
    unchanged Alg. 2 recursion ranks designs by ``E[T] + w·Q̂_p[T]``.
    """
    if builders is None:
        builders = make_builders()
    _require_sweep_for_seed(seed_layers, sweep)
    profile = objective_profile(profile, objective)
    stats = TuneStats()
    t0 = time.perf_counter()
    if sweep:
        engine = SweepEngine(builders, profile, stats,
                             score_backend=score_backend,
                             layer_cache=layer_cache)
        if seed_layers:
            engine.seed(D, seed_layers)
        layers, names, cost = _airtune_rec_sweep(D, profile, engine, k,
                                                 max_layers, stats)
    else:
        layers, names, cost = _airtune_rec(D, profile, builders, k,
                                           max_layers, stats)
    stats.wall_seconds = time.perf_counter() - t0
    design = IndexDesign(layers=tuple(layers), data=D)
    # the recursion's incremental cost must agree with the Eq. (6) evaluator
    return TuneResult(design=design, cost=cost, stats=stats,
                      strategy="airtune", builder_names=tuple(names),
                      objective=_objective_field(objective))


def _airtune_rec_sweep(D: KeyPositions, profile: StorageProfile,
                       engine: SweepEngine, k: int, depth_left: int,
                       stats: TuneStats) -> tuple[list, list, float]:
    stats.vertices_visited += 1
    no_index_cost = float(profile(D.size_bytes))   # L_SM(D; (), T)

    # stopping criterion: even an ideal layer cannot beat reading D outright
    if no_index_cost < ideal_latency_with_index(profile) or depth_left == 0 \
            or D.n <= 1:
        return [], [], no_index_cost

    # one fused sweep builds + scores every outgoing edge (§5.2/§5.3);
    # ranking uses sampled estimates, the k selected candidates are
    # re-scored exactly, so the returned cost is still exactly Eq. (6)
    candidates = engine.children(D)
    ranked = sorted(candidates, key=lambda c: c.score)  # stable: ties keep
    #                                                     builder order
    stats.candidates_pruned += max(len(ranked) - k, 0)
    top = ranked[:k]
    exact = engine.exact_read_costs(D, top) if top else []
    best_layers, best_names, best_cost = [], [], no_index_cost
    for cand, read_cost in zip(top, exact):
        upper_layers, upper_names, upper_cost = _airtune_rec_sweep(
            cand.outline, profile, engine, k, depth_left - 1, stats)
        total = read_cost + upper_cost       # V(D) recursion (Alg. 2 line 11)
        if total < best_cost:
            best_cost = total
            best_layers = [cand.layer] + upper_layers
            best_names = [cand.name] + upper_names
    return best_layers, best_names, best_cost


def _airtune_rec(D: KeyPositions, profile: StorageProfile,
                 builders: list[LayerBuilder], k: int, depth_left: int,
                 stats: TuneStats) -> tuple[list, list, float]:
    """Legacy per-builder loop (``sweep=False``) — the sweep engine's
    bit-identical reference; kept as the escape hatch and the baseline
    the tuning benchmark measures reductions against."""
    stats.vertices_visited += 1
    no_index_cost = float(profile(D.size_bytes))   # L_SM(D; (), T)

    if no_index_cost < ideal_latency_with_index(profile) or depth_left == 0 \
            or D.n <= 1:
        return [], [], no_index_cost

    candidates = []
    for F in builders:
        layer = F(D)
        stats.layers_built += 1
        D_next = outline(layer, D)
        # safeguard: only strictly shrinking layers guarantee termination
        if D_next.size_bytes >= D.size_bytes:
            stats.candidates_pruned += 1
            continue
        est_cost = _mean_layer_read_cost(layer, D, profile, sample=True)
        stats.candidates_scored += 1
        score = tau_hat(D_next, profile) + est_cost         # Eq. (9)
        candidates.append((score, F.name, layer, D_next))

    # select top-k by index-complexity-guided score (§5.3)
    candidates.sort(key=lambda c: c[0])
    stats.candidates_pruned += max(len(candidates) - k, 0)
    best_layers, best_names, best_cost = [], [], no_index_cost
    for score, fname, layer, D_next in candidates[:k]:
        read_cost = _mean_layer_read_cost(layer, D, profile)   # exact
        stats.candidates_scored += 1
        upper_layers, upper_names, upper_cost = _airtune_rec(
            D_next, profile, builders, k, depth_left - 1, stats)
        total = read_cost + upper_cost       # V(D) recursion (Alg. 2 line 11)
        if total < best_cost:
            best_cost = total
            best_layers = [layer] + upper_layers
            best_names = [fname] + upper_names
    return best_layers, best_names, best_cost


@register_strategy("brute_force")
def brute_force(D: KeyPositions, profile: StorageProfile,
                builders: list[LayerBuilder] | None = None, *,
                k: int = 0, max_layers: int = 4, sweep: bool = True,
                score_backend: str = "numpy",
                layer_cache: LayerCache | None = None,
                seed_layers=None, objective=None) -> TuneResult:
    """Exhaustive reference search (no top-k pruning, no τ̂ guidance).

    Exponential in |𝓕|; only usable on small inputs.  Tests use it to
    certify AirTune's pruning never loses the optimum on tractable cases.
    ``k`` is accepted for :class:`SearchStrategy` compatibility and
    ignored — brute force never prunes by score; its
    ``candidates_pruned`` counts only edges discarded by the
    strictly-shrinking termination safeguard.  The sweep engine's vertex
    memoization pays off most here: exhaustive recursion re-reaches
    identical collections constantly.
    """
    if builders is None:
        builders = make_builders()
    _require_sweep_for_seed(seed_layers, sweep)
    profile = objective_profile(profile, objective)
    stats = TuneStats()
    t0 = time.perf_counter()
    # rank_scores=False: brute force never ranks by Eq. (9), so the sweep
    # skips the sampled Ê[T(Δ)]/τ̂ pass entirely
    engine = SweepEngine(builders, profile, stats, score_backend=score_backend,
                         rank_scores=False,
                         layer_cache=layer_cache) if sweep else None
    if seed_layers:
        engine.seed(D, seed_layers)    # warm start: pure memoization

    def rec_sweep(Dc: KeyPositions, depth_left: int) -> tuple[list, list, float]:
        stats.vertices_visited += 1
        best_layers, best_names = [], []
        best_cost = float(profile(Dc.size_bytes))
        if depth_left == 0 or Dc.n <= 1:
            return best_layers, best_names, best_cost
        cands = engine.children(Dc)
        exact = engine.exact_read_costs(Dc, cands) if cands else []
        for cand, read_cost in zip(cands, exact):
            upper_layers, upper_names, upper_cost = rec_sweep(
                cand.outline, depth_left - 1)
            total = read_cost + upper_cost
            if total < best_cost:
                best_cost = total
                best_layers = [cand.layer] + upper_layers
                best_names = [cand.name] + upper_names
        return best_layers, best_names, best_cost

    def rec(Dc: KeyPositions, depth_left: int) -> tuple[list, list, float]:
        stats.vertices_visited += 1
        best_layers, best_names = [], []
        best_cost = float(profile(Dc.size_bytes))
        if depth_left == 0 or Dc.n <= 1:
            return best_layers, best_names, best_cost
        for F in builders:
            layer = F(Dc)
            stats.layers_built += 1
            D_next = outline(layer, Dc)
            if D_next.size_bytes >= Dc.size_bytes:
                stats.candidates_pruned += 1
                continue
            upper_layers, upper_names, upper_cost = rec(D_next, depth_left - 1)
            total = _mean_layer_read_cost(layer, Dc, profile) + upper_cost
            stats.candidates_scored += 1
            if total < best_cost:
                best_cost = total
                best_layers = [layer] + upper_layers
                best_names = [F.name] + upper_names
        return best_layers, best_names, best_cost

    layers, names, cost = (rec_sweep if sweep else rec)(D, max_layers)
    stats.wall_seconds = time.perf_counter() - t0
    return TuneResult(design=IndexDesign(layers=tuple(layers), data=D),
                      cost=cost, stats=stats, strategy="brute_force",
                      builder_names=tuple(names),
                      objective=_objective_field(objective))


@register_strategy("beam")
def beam_search(D: KeyPositions, profile: StorageProfile,
                builders: list[LayerBuilder] | None = None, *,
                k: int = 5, max_layers: int = 12, sweep: bool = True,
                score_backend: str = "numpy",
                layer_cache: LayerCache | None = None,
                seed_layers=None, objective=None) -> TuneResult:
    """Beam search over layer stacks: Alg. 2's graph, breadth-first.

    A frontier of at most ``k`` partial designs (bottom-up layer stacks)
    advances one layer per round; every frontier state expands through all
    of 𝓕 and the ``k`` best children *overall* — scored by accumulated
    exact read cost plus the Eq. 9 score ``τ̂(D_next) + Ê[T(Δ)]`` — survive.
    Shares :func:`airtune`'s stopping criterion, so frontier states whose
    collection is already cheaper to read outright than an ideal extra
    layer stop expanding.  Unlike the depth-first top-k recursion (which
    re-branches inside every selected child), total work is bounded by
    ``max_layers · k · |𝓕|`` layer builds — a predictable budget when the
    registered family set is large.

    With ``k`` at least the number of shrinking children per round the
    beam degenerates to exhaustive breadth-first search and matches
    :func:`brute_force` exactly.
    """
    if builders is None:
        builders = make_builders()
    _require_sweep_for_seed(seed_layers, sweep)
    profile = objective_profile(profile, objective)
    stats = TuneStats()
    t0 = time.perf_counter()
    engine = SweepEngine(builders, profile, stats,
                         score_backend=score_backend,
                         layer_cache=layer_cache) if sweep else None
    stats.vertices_visited += 1
    best_cost = float(profile(D.size_bytes))     # stop at the data layer
    best_layers: list = []
    best_names: list = []
    ideal = ideal_latency_with_index(profile)
    # frontier state: (exact cost of layers so far, collection, layers, names)
    frontier = [(0.0, D, [], [])]
    if seed_layers:
        # warm start: besides memoizing the old builds (engine.seed), the
        # previous design's partial stacks enter the beam as initial
        # vertices — the frontier starts where the last search ended, and
        # the seed's complete Eq. (6) cost bounds `best` from the first
        # round (the search can only match or improve on the old design)
        acc = 0.0
        cur_layers: list = []
        cur_names: list = []
        for name, layer, Dc, out in engine.seed(D, seed_layers)[:max_layers]:
            acc += _mean_layer_read_cost(layer, Dc, profile)   # exact
            stats.candidates_scored += 1
            cur_layers = cur_layers + [layer]
            cur_names = cur_names + [name]
            stats.vertices_visited += 1
            complete = acc + float(profile(out.size_bytes))    # Eq. (6)
            if complete < best_cost:
                best_cost = complete
                best_layers, best_names = cur_layers, cur_names
            frontier.append((acc, out, cur_layers, cur_names))
    for _ in range(max_layers):
        children = []
        for cost_so_far, Dc, layers, names in frontier:
            # stopping criterion, per state (Alg. 2 lines 1–2); the depth
            # bound re-checked per state because warm-start-injected seed
            # stacks enter the frontier at arbitrary depth
            if float(profile(Dc.size_bytes)) < ideal or Dc.n <= 1 \
                    or len(layers) >= max_layers:
                continue
            if sweep:
                for cand in engine.children(Dc):
                    score = cost_so_far + cand.est_cost + cand.tau  # Eq. (9)
                    children.append((score, cost_so_far, Dc, cand.layer,
                                     cand.name, cand.outline, layers, names,
                                     cand))
                continue
            for F in builders:
                layer = F(Dc)
                stats.layers_built += 1
                D_next = outline(layer, Dc)
                if D_next.size_bytes >= Dc.size_bytes:
                    stats.candidates_pruned += 1
                    continue
                est = _mean_layer_read_cost(layer, Dc, profile, sample=True)
                stats.candidates_scored += 1
                score = cost_so_far + est + tau_hat(D_next, profile)  # Eq. (9)
                children.append((score, cost_so_far, Dc, layer, F.name,
                                 D_next, layers, names, None))
        if not children:
            break
        children.sort(key=lambda c: c[0])
        stats.candidates_pruned += max(len(children) - k, 0)
        frontier = []
        for (score, cost_so_far, Dc, layer, fname, D_next,
             layers, names, cand) in children[:k]:
            if cand is not None:
                read_cost = engine.exact_read_costs(Dc, [cand])[0]
            else:
                read_cost = _mean_layer_read_cost(layer, Dc, profile)  # exact
                stats.candidates_scored += 1
            new_cost = cost_so_far + read_cost
            new_layers = layers + [layer]
            new_names = names + [fname]
            stats.vertices_visited += 1
            complete = new_cost + float(profile(D_next.size_bytes))  # Eq. (6)
            if complete < best_cost:
                best_cost = complete
                best_layers, best_names = new_layers, new_names
            frontier.append((new_cost, D_next, new_layers, new_names))
    stats.wall_seconds = time.perf_counter() - t0
    design = IndexDesign(layers=tuple(best_layers), data=D)
    assert abs(expected_latency(design, profile) - best_cost) \
        <= 1e-9 * max(best_cost, 1e-30)
    return TuneResult(design=design, cost=best_cost, stats=stats,
                      strategy="beam", builder_names=tuple(best_names),
                      objective=_objective_field(objective))
