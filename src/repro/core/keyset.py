"""Key-position collections (the paper's ``D``).

A key-position collection ``D = {(x_i, y_i)}`` maps sorted 64-bit keys to
byte ranges ``y_i = [y^-_i, y^+_i)`` on storage (paper §4.1).  Every index
layer is built on top of such a collection, and building a layer produces a
new, smaller collection (its *outline*, Alg. 2 line 5).

We additionally carry per-pair *weights*: the number of original query keys
covered by the pair.  The paper's objective (Eq. 6) is an expectation over
the query-key distribution ``X`` (uniform over the original keys); when a
layer is outlined into coarser pairs, exact evaluation of that expectation
requires knowing how many original keys each coarse pair covers.
"""
from __future__ import annotations

import dataclasses
import hashlib

import numpy as np

KEY_DTYPE = np.uint64
POS_DTYPE = np.int64  # byte offsets; int64 simplifies arithmetic, 2^63 B is plenty


@dataclasses.dataclass(frozen=True)
class KeyPositions:
    """Sorted keys with their byte ranges ``[lo, hi)`` and query weights."""

    keys: np.ndarray     # (n,) uint64, strictly increasing
    lo: np.ndarray       # (n,) int64, y^-
    hi: np.ndarray       # (n,) int64, y^+ ; contiguous data has hi[i] == lo[i+1]
    weights: np.ndarray  # (n,) float64, #original keys represented by each pair

    def __post_init__(self):
        n = len(self.keys)
        assert self.lo.shape == (n,) and self.hi.shape == (n,)
        assert self.weights.shape == (n,)
        object.__setattr__(self, "_f64_cache", {})

    def _f64(self, name: str) -> np.ndarray:
        """Cached float64 view — builders convert these arrays dozens of
        times per tune; caching removed ~20% of tuning time (§Perf)."""
        c = self._f64_cache
        if name not in c:
            c[name] = getattr(self, name).astype(np.float64)
        return c[name]

    @property
    def keys_f(self):
        return self._f64("keys")

    @property
    def lo_f(self):
        return self._f64("lo")

    @property
    def hi_f(self):
        return self._f64("hi")

    @property
    def mid_f(self):
        c = self._f64_cache
        if "mid" not in c:
            c["mid"] = 0.5 * (self.lo_f + self.hi_f)
        return c["mid"]

    @property
    def fingerprint(self) -> bytes:
        """Content digest of (keys, lo, hi, weights) — the sweep engine's
        memo key (repro.core.sweep): collections reached via different
        search paths but holding identical pairs hash alike, so their
        candidate expansions are built once and reused."""
        c = self._f64_cache
        if "fingerprint" not in c:
            h = hashlib.blake2b(digest_size=16)
            h.update(np.int64(self.n).tobytes())
            for a in (self.keys, self.lo, self.hi, self.weights):
                h.update(np.ascontiguousarray(a).tobytes())
            c["fingerprint"] = h.digest()
        return c["fingerprint"]

    @property
    def n(self) -> int:
        return len(self.keys)

    @property
    def size_bytes(self) -> int:
        """Total extent ``s_D = y^+_n - y^-_1`` (paper §A.3)."""
        if self.n == 0:
            return 0
        return int(self.hi[-1] - self.lo[0])

    @property
    def total_weight(self) -> float:
        return float(self.weights.sum())

    @staticmethod
    def from_offsets(keys: np.ndarray, offsets: np.ndarray) -> "KeyPositions":
        """Build from record offsets: record i occupies [offsets[i], offsets[i+1])."""
        keys = np.asarray(keys, dtype=KEY_DTYPE)
        offsets = np.asarray(offsets, dtype=POS_DTYPE)
        assert len(offsets) == len(keys) + 1
        return KeyPositions(
            keys=keys,
            lo=offsets[:-1].copy(),
            hi=offsets[1:].copy(),
            weights=np.ones(len(keys), dtype=np.float64),
        )

    @staticmethod
    def fixed_record(keys: np.ndarray, record_bytes: int, base: int = 0) -> "KeyPositions":
        """Fixed-size records laid out consecutively from ``base``."""
        keys = np.asarray(keys, dtype=KEY_DTYPE)
        offs = base + record_bytes * np.arange(len(keys) + 1, dtype=POS_DTYPE)
        return KeyPositions.from_offsets(keys, offs)

    def validate(self) -> None:
        """Invariants used throughout: sorted unique keys, sane ranges."""
        if self.n == 0:
            return
        assert np.all(np.diff(self.keys.astype(np.uint64)) > 0), "keys must be strictly increasing"
        assert np.all(self.hi > self.lo), "empty position ranges"
        assert np.all(self.lo[1:] >= self.lo[:-1]), "positions must be non-decreasing"
        assert np.all(self.weights > 0)

    def slice(self, start: int, stop: int) -> "KeyPositions":
        return KeyPositions(
            keys=self.keys[start:stop], lo=self.lo[start:stop],
            hi=self.hi[start:stop], weights=self.weights[start:stop],
        )
