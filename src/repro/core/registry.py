"""Pluggable registries: builder families ``F`` and search strategies.

The paper frames AirTune as a search over an *open-ended* family of layer
builders — "almost any existing index or a novel combination of them"
(§1; the extended abstract arXiv:2208.03823 makes the open-endedness
explicit).  These registries make that family a runtime-extensible set:

  * :data:`BUILDER_FAMILIES` maps a family name (``"gstep"``, ``"gband"``,
    ``"eband"``, …) to a build function ``f(D, lam, p) -> Layer``.
    :class:`repro.core.builders.LayerBuilder` resolves its ``kind`` through
    this registry on every call, so a family registered by third-party code
    participates in the Alg. 2 search without editing ``core/``.
  * :data:`SEARCH_STRATEGIES` maps a strategy name (``"airtune"``,
    ``"brute_force"``, ``"beam"``, …) to a callable implementing the
    :class:`repro.core.airtune.SearchStrategy` protocol.

Third-party code registers through the public facade::

    from repro.api import register_builder

    @register_builder("myfamily")
    def build_my_layer(D, lam, p):
        return ...  # a StepLayer or BandLayer

The built-in entries are registered when :mod:`repro.core.builders` and
:mod:`repro.core.airtune` are imported (both happen on ``import
repro.core``); the paper's baseline families (``"btree"``, ``"rmi_leaf"``,
``"pgm"``) register on :mod:`repro.core.baselines` import (also part of
``import repro.core``), so they compete inside Alg. 2 like any other
family.
"""
from __future__ import annotations


class Registry:
    """Name → object mapping with decorator registration and clear errors."""

    def __init__(self, kind: str):
        self.kind = kind
        self._entries: dict[str, object] = {}

    def register(self, name: str, obj=None):
        """``register(name, obj)`` or ``@register(name)`` decorator form."""
        if obj is None:
            def deco(fn):
                self.register(name, fn)
                return fn
            return deco
        if name in self._entries and self._entries[name] is not obj:
            raise ValueError(
                f"{self.kind} {name!r} is already registered; "
                f"unregister it first to replace it")
        self._entries[name] = obj
        return obj

    def unregister(self, name: str) -> None:
        self._entries.pop(name, None)

    def get(self, name: str):
        try:
            return self._entries[name]
        except KeyError:
            raise KeyError(
                f"unknown {self.kind} {name!r}; registered: "
                f"{', '.join(sorted(self._entries)) or '(none)'}") from None

    def names(self) -> tuple:
        return tuple(sorted(self._entries))

    def __contains__(self, name) -> bool:
        return name in self._entries

    def __iter__(self):
        return iter(sorted(self._entries))


#: family name -> build function ``f(D: KeyPositions, lam: float, p: int) -> Layer``
BUILDER_FAMILIES = Registry("builder family")

#: family name -> fused multi-λ build ``f(D, lams, p) -> list[Layer]``.
#: Optional fast path for the sweep engine (repro.core.sweep): one call
#: builds the family's whole Eq. (8) λ-column for a vertex, sharing
#: per-collection precomputation and deduplicating λ values that produce
#: identical partitions.  Families registered only in BUILDER_FAMILIES
#: still work — the sweep engine falls back to per-λ single builds.
MULTI_LAM_FAMILIES = Registry("multi-λ builder family")

#: strategy name -> ``SearchStrategy`` callable (see repro.core.airtune)
SEARCH_STRATEGIES = Registry("search strategy")


def register_builder(name: str, fn=None):
    """Register a layer-builder family ``f(D, lam, p) -> Layer``.

    Optional attribute: ``fn.canonical_lam(D, lam) -> hashable`` maps λ to
    the family's internal parameter (e.g. ``rmi_leaf``'s clamped model
    count).  The sweep engine keys its ``LayerCache`` on the canonical
    value, so grid λs that resolve to the same structure build once and
    count as ``TuneStats.layers_reused``.
    """
    return BUILDER_FAMILIES.register(name, fn)


def register_multi_lam_builder(name: str, fn=None):
    """Register a family's fused multi-λ entry ``f(D, lams, p) -> list[Layer]``.

    The returned list must align with ``lams`` and each element must be
    bit-identical (same arrays) to the single-λ build at that λ; entries
    for λ values yielding the same partition may share one layer object —
    the sweep engine counts those as ``layers_reused``.
    """
    return MULTI_LAM_FAMILIES.register(name, fn)


def register_strategy(name: str, fn=None):
    """Register a search strategy (``SearchStrategy`` protocol)."""
    return SEARCH_STRATEGIES.register(name, fn)
