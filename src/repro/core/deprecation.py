"""Deprecation shims: warn external callers, hard-fail internal use.

The ``repro.api`` facade replaced the scattered tune → serialize → serve
call forms; the old entry points remain as thin shims that delegate to the
facade bit-identically.  Shims are for *callers* migrating at their own
pace — code inside ``repro`` itself must use the facade (or the engine
layer directly), so an internal call through a shim is a bug and raises
immediately instead of warning.  CI additionally escalates any
``DeprecationWarning`` attributed to a ``repro.*`` module to an error
(see ``[tool.pytest.ini_options] filterwarnings``).
"""
from __future__ import annotations

import sys
import warnings


def warn_deprecated(message: str, *, stacklevel: int = 3) -> None:
    """Emit a ``DeprecationWarning`` attributed to the shim's caller.

    ``stacklevel=3`` assumes the call chain ``caller -> shim ->
    warn_deprecated``; pass a larger value for deeper shims.
    """
    caller = sys._getframe(stacklevel - 1).f_globals.get("__name__", "")
    if caller == "repro" or caller.startswith("repro."):
        raise AssertionError(
            f"deprecated API used from within repro ({caller}): {message}")
    warnings.warn(message, DeprecationWarning, stacklevel=stacklevel)
