"""Deprecation shims: warn external callers, hard-fail internal use.

The ``repro.api`` facade replaced the scattered tune → serialize → serve
call forms; the old entry points remain as thin shims that delegate to the
facade bit-identically.  Shims are for *callers* migrating at their own
pace — code inside ``repro`` itself must use the facade (or the engine
layer directly), so an internal call through a shim is a bug and raises
immediately instead of warning.  CI additionally escalates any
``DeprecationWarning`` attributed to a ``repro.*`` module to an error
(see ``[tool.pytest.ini_options] filterwarnings``).
"""
from __future__ import annotations

import sys
import warnings

_WARNED: set = set()    # messages already emitted once (see ``once=True``)


def warn_deprecated(message: str, *, stacklevel: int = 3,
                    once: bool = False) -> None:
    """Emit a ``DeprecationWarning`` attributed to the shim's caller.

    ``stacklevel=3`` assumes the call chain ``caller -> shim ->
    warn_deprecated``; pass a larger value for deeper shims.

    ``once=True`` emits each distinct message at most once per process
    (kwarg-shim surfaces like ``IndexService``'s legacy constructor would
    otherwise warn on every open in a serving loop).  The internal-use
    hard error is NOT deduplicated — repro-internal shim use always
    raises, warned before or not.
    """
    caller = sys._getframe(stacklevel - 1).f_globals.get("__name__", "")
    if caller == "repro" or caller.startswith("repro."):
        raise AssertionError(
            f"deprecated API used from within repro ({caller}): {message}")
    if once:
        if message in _WARNED:
            return
        _WARNED.add(message)
    warnings.warn(message, DeprecationWarning, stacklevel=stacklevel)
