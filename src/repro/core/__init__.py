"""AirIndex core — the paper's contribution as a composable library.

This is the *engine* layer.  The recommended public entry point is the
``repro.api`` facade (``Index`` / ``TuneSpec``), which drives everything
below through one object and records tuning provenance on disk.

Engine surface:
  KeyPositions                      — key→position collections (``D``)
  StorageProfile / PROFILES         — ``T(Δ)`` models (§3.2)
  StepLayer / BandLayer / outline   — unified index model layers (§4)
  LayerBuilder / make_builders      — registered families on the Eq.(8) grid
  BUILDER_FAMILIES / SEARCH_STRATEGIES — pluggable registries (repro.api
                                      re-exports the register decorators)
  IndexDesign / expected_latency    — ``L_SM`` (Eq. 5/6)
  step_index_complexity / tau_hat   — τ̂ (Eq. 12)
  airtune / brute_force / beam_search — SearchStrategy implementations (Alg. 2)
  SweepEngine / batched_mean_read_costs — fused λ-grid candidate sweep
                                      (multi-λ builds, batched scoring,
                                      vertex memoization; see sweep.py)
  lookup_batch / verify_lookup      — batched Alg. 1
  descend_*_layer / coalesce_ranges — shared per-layer descent + read planner
  write_index / SerializedIndex     — on-disk format (optionally paged) +
                                      partial-read lookup
  CachedProfile                     — T(Δ) through a block cache (serving)
  baselines                         — B-TREE / RMI / PGM as registered
                                      families (BASELINE_FAMILIES) competing
                                      inside Alg. 2, + Data Calculator

The batched serving engine on top of this surface lives in
``repro.serve.index_service``.  ``load_index`` and ``lookup.lookup_file``
remain as deprecation shims onto the facade.
"""
from .airtune import (SearchStrategy, TuneResult, TuneStats, airtune,
                      beam_search, brute_force)
from .builders import (DEFAULT_FAMILIES, LayerBuilder, build_eband,
                       build_eband_multi, build_gband, build_gband_multi,
                       build_gstep, build_gstep_multi, build_partitioned,
                       fit_bands_for_groups, greedy_partition,
                       gstep_from_starts, make_builders, merge_layers)
from .registry import (BUILDER_FAMILIES, MULTI_LAM_FAMILIES,
                       SEARCH_STRATEGIES, Registry, register_builder,
                       register_multi_lam_builder, register_strategy)
from .sweep import SCORE_SAMPLE, Candidate, SweepEngine
from .complexity import (S_STEP, step_index_complexity,
                         step_index_complexity_layers, tau_hat)
from .keyset import KeyPositions
from .latency import (IndexDesign, batched_mean_read_costs, expected_latency,
                      ideal_latency_with_index, latency_breakdown,
                      mean_excess_per_lookup, mean_read_volume,
                      objective_latency, quantile_latency)
from .descent import (coalesce_ranges, covering_index, descend_band_layer,
                      descend_step_layer)
from .lookup import LookupResult, last_mile_search, lookup_batch, verify_lookup
from .nodes import (BAND_NODE_BYTES, STEP_PIECE_BYTES, BandLayer, StepLayer,
                    mean_width, outline)
from .serialize import (IndexFileMeta, SerializedIndex, load_index,
                        materialize_design, page_span, record_aligned_range,
                        write_index)
from .storage import (AffineProfile, AffineUniformProfile, CachedProfile,
                      DistributionalProfile, MeasuredProfile, ObjectiveProfile,
                      PROFILES, StorageProfile, affine_coefficients,
                      normalize_objective, objective_profile,
                      profile_from_dict, profile_local_storage,
                      profile_to_dict)
from . import baselines  # noqa: F401  (registers btree / rmi_leaf / pgm)
from .baselines import (BASELINE_FAMILIES, PGM_EPS_GRID, build_fixed_btree,
                        build_pgm, build_rmi, build_rmi_leaf, data_calculator,
                        homogeneous_airtune, pgm_builders, tune_pgm, tune_rmi)

__all__ = [k for k in dir() if not k.startswith("_")]
