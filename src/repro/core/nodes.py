"""Index layers made of step / band nodes (paper §4.1, Fig. 6).

A *node* maps a key to a position range that must contain the true range
(validity, Eq. 1): ``ŷ(x) = [ŷ⁻(x), ŷ⁺(x)) ⊇ y(x)``.

  * **step** node: p-piece constant function, pieces ``(a_i → [b_i, b_{i+1}))``;
    serialized size ``16·p`` bytes (8 B key + 8 B position per piece).
  * **band** node: thick line through two key-position points with width δ:
    ``ŷ(x) = [m·x + c − δ, m·x + c + δ)``; serialized size 40 bytes.

An *index layer* is a piecewise function of nodes; node ``j`` covers keys
``[z_j, z_{j+1})``.  Layers are stored struct-of-arrays so that lookup and
cost evaluation are vectorized array programs (TPU-friendly — DESIGN.md §2).

Numerical validity: band parameters are fitted and evaluated with the
*same* float64 expression in node-local coordinates (``x − x₁``), so the
validity guarantee established at build time holds bit-for-bit at lookup.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .descent import descend_band_layer, descend_step_layer
from .keyset import KeyPositions, POS_DTYPE

STEP_PIECE_BYTES = 16   # 8 B partition key + 8 B partition position
BAND_NODE_BYTES = 40    # x1, y1, x2, y2, delta  (5 × 8 B)
LAYER_KINDS = ("step", "band")




@dataclasses.dataclass(frozen=True)
class StepLayer:
    """All step nodes of one layer, pieces flattened in key order.

    Piece ``i`` predicts ``[piece_pos[i], piece_pos[i+1])`` for keys in
    ``[piece_keys[i], piece_keys[i+1])``.  Node ``j`` owns pieces
    ``[node_piece_off[j], node_piece_off[j+1])``.
    """

    piece_keys: np.ndarray      # (P,) uint64
    piece_pos: np.ndarray       # (P+1,) int64
    node_piece_off: np.ndarray  # (N+1,) int64 CSR offsets into pieces

    kind = "step"

    @property
    def n_nodes(self) -> int:
        return len(self.node_piece_off) - 1

    @property
    def n_pieces(self) -> int:
        return len(self.piece_keys)

    def node_sizes(self) -> np.ndarray:
        return STEP_PIECE_BYTES * np.diff(self.node_piece_off)

    @property
    def size_bytes(self) -> int:
        """s(Θ_l): serialized layer size (paper: 16p bytes per step node)."""
        return int(STEP_PIECE_BYTES * self.n_pieces)

    def node_keys(self) -> np.ndarray:
        """z_j — the first partition key of each node."""
        return self.piece_keys[self.node_piece_off[:-1]]

    def predict(self, queries: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """ŷ(x) for a batch of keys → (lo, hi) arrays."""
        return descend_step_layer(self.piece_keys, self.piece_pos[:-1],
                                  self.piece_pos[1:], queries)

    def widths_at(self, queries: np.ndarray) -> np.ndarray:
        """Δ(x; Θ_l) = |ŷ(x)| per query (paper §4.3)."""
        lo, hi = self.predict(queries)
        return (hi - lo).astype(np.float64)

    def piece_widths(self) -> np.ndarray:
        return np.diff(self.piece_pos).astype(np.float64)

    def validate_against(self, D: KeyPositions) -> None:
        lo, hi = self.predict(D.keys)
        assert np.all(lo <= D.lo) and np.all(hi >= D.hi), "step layer violates Eq. (1)"


@dataclasses.dataclass(frozen=True)
class BandLayer:
    """All band nodes of one layer.

    Node ``j`` covers keys ``[node_keys[j], node_keys[j+1])`` and predicts
    ``mid(x) ± delta`` with ``mid(x) = y1 + m·(x − x1)`` evaluated in
    float64 node-local coordinates.
    """

    node_keys: np.ndarray  # (N,) uint64 == x1 of each node (the key tag)
    x1: np.ndarray         # (N,) uint64
    y1: np.ndarray         # (N,) int64
    m: np.ndarray          # (N,) float64 slope (bytes per key unit)
    delta: np.ndarray      # (N,) float64 half-width
    clamp_lo: int = 0      # predictions clamped into [clamp_lo, clamp_hi]
    clamp_hi: int = np.iinfo(np.int64).max

    kind = "band"

    @property
    def n_nodes(self) -> int:
        return len(self.node_keys)

    def node_sizes(self) -> np.ndarray:
        return np.full(self.n_nodes, BAND_NODE_BYTES, dtype=POS_DTYPE)

    @property
    def size_bytes(self) -> int:
        return int(BAND_NODE_BYTES * self.n_nodes)

    def predict(self, queries: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        lo, hi = descend_band_layer(self.node_keys, self.x1, self.y1, self.m,
                                    self.delta, queries)
        lo = np.clip(lo, self.clamp_lo, self.clamp_hi).astype(POS_DTYPE)
        hi = np.clip(hi, self.clamp_lo, self.clamp_hi).astype(POS_DTYPE)
        return lo, np.maximum(hi, lo + 1)

    def widths_at(self, queries: np.ndarray) -> np.ndarray:
        lo, hi = self.predict(queries)
        return (hi - lo).astype(np.float64)

    def validate_against(self, D: KeyPositions) -> None:
        lo, hi = self.predict(D.keys)
        assert np.all(lo <= D.lo) and np.all(hi >= D.hi), "band layer violates Eq. (1)"


Layer = StepLayer | BandLayer


def outline(layer: Layer, D: KeyPositions, base: int = 0) -> KeyPositions:
    """Turn a built layer into the key-position collection seen by the next
    layer up (Alg. 2 line 5): keys = node boundary keys z_j, positions =
    byte ranges of serialized node records, weights = covered query mass.
    """
    sizes = layer.node_sizes()
    offs = np.empty(len(sizes) + 1, dtype=POS_DTYPE)
    offs[0] = base
    np.cumsum(sizes, out=offs[1:])
    offs[1:] += base
    if isinstance(layer, StepLayer):
        zkeys = layer.node_keys()
    else:
        zkeys = layer.node_keys
    # weight of node j = total weight of D-pairs it covers; computed from
    # boundary positions in O(nodes·log n) via a weight-prefix-sum instead
    # of an O(n) bincount — builders run dozens of times per tune (§Perf)
    cw = np.concatenate([[0.0], np.cumsum(D.weights)])
    bounds = np.searchsorted(D.keys, zkeys, side="left")
    ends = np.append(bounds[1:], D.n)
    w = cw[ends] - cw[bounds]
    w = np.maximum(w, 1e-9)   # guard: empty nodes keep a token weight
    return KeyPositions(keys=zkeys.astype(np.uint64), lo=offs[:-1], hi=offs[1:],
                        weights=w)


def mean_width(layer: Layer, D: KeyPositions) -> float:
    """E_{x∼X}[Δ(x; Θ_l)] with X uniform over original keys (weights)."""
    wq = layer.widths_at(D.keys)
    return float(np.average(wq, weights=D.weights))
