"""Per-layer descent primitives shared by every lookup path (Alg. 1 line 3–5).

One traversal step = find the covering piece/node for each query key, then
evaluate its prediction.  The same two vectorized functions back

  * the in-memory batched traversal (:func:`repro.core.lookup.lookup_batch`
    via :class:`~repro.core.nodes.StepLayer` / ``BandLayer.predict``),
  * the partial-read file traversal (:mod:`repro.core.serialize`), and
  * the serving engine (:mod:`repro.serve.index_service`),

so modeled, on-disk, and served predictions agree bit-for-bit (the band
midpoint is evaluated with the identical float64 expression everywhere —
the validity guarantee of Eq. 1 established at build time must survive
every path).

Also here: :func:`coalesce_ranges`, the batched-read planner — overlapping
or near-adjacent byte ranges requested by one query batch are merged into
maximal runs before any ``pread`` is issued.
"""
from __future__ import annotations

import numpy as np


def covering_index(sorted_keys: np.ndarray, queries: np.ndarray) -> np.ndarray:
    """Rightmost i with ``sorted_keys[i] <= q`` per query, clipped to range."""
    idx = np.searchsorted(sorted_keys, queries, side="right") - 1
    return np.clip(idx, 0, len(sorted_keys) - 1)


def descend_step_layer(piece_keys: np.ndarray, pos_lo: np.ndarray,
                       pos_hi: np.ndarray,
                       queries: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """One step-layer descent: piece ``i`` covering each query predicts
    ``[pos_lo[i], pos_hi[i])``.  All arrays vectorized over queries."""
    i = covering_index(piece_keys, queries)
    return pos_lo[i], pos_hi[i]


def descend_band_layer(node_keys: np.ndarray, x1: np.ndarray, y1: np.ndarray,
                       m: np.ndarray, delta: np.ndarray,
                       queries: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """One band-layer descent → unclamped integer ``[⌊mid−δ⌋, ⌈mid+δ⌉)``.

    ``mid`` is evaluated in node-local float64 coordinates (``q − x1``) —
    the exact expression used at fit time; callers apply their own clamps
    (layer clamp bounds in memory, data extent at the end of a file walk).
    """
    j = covering_index(node_keys, queries)
    dx = (queries - x1[j]).astype(np.float64)
    mid = y1[j].astype(np.float64) + np.asarray(m)[j] * dx
    d = np.asarray(delta)[j]
    return np.floor(mid - d), np.ceil(mid + d)


def descend_layers(layers, queries: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Walk ``queries`` through a resident layer prefix, top-down — the
    multi-layer composition of the two single-layer steps above, returned
    per layer (Alg. 1 lines 3–5 over an in-memory prefix).

    ``layers`` is a top-down sequence of parsed layer dicts (the
    :class:`repro.serve.IndexService` resident representation)::

        {"kind": "step", "keys", "pos_lo", "pos_hi"}
        {"kind": "band", "x1", "y1", "m", "delta"}

    Returns ``(lo, hi)`` float64 arrays of shape ``(L, Q)``: row ``l`` is
    layer ``l``'s prediction for every query.  Each layer covers the full
    key domain, so rows are functions of the query key alone — which is
    what lets :mod:`repro.kernels.fused_descent` evaluate the whole prefix
    in one fused dispatch.  Row ``L-1`` (the bottom-most resident layer)
    is the window the on-disk walk continues from; this float64 path is
    the bit-exactness reference for every fused backend.
    """
    Q = len(queries)
    lo = np.empty((len(layers), Q), dtype=np.float64)
    hi = np.empty((len(layers), Q), dtype=np.float64)
    for li, lay in enumerate(layers):
        if lay["kind"] == "step":
            l_, h_ = descend_step_layer(lay["keys"], lay["pos_lo"],
                                        lay["pos_hi"], queries)
        else:
            l_, h_ = descend_band_layer(lay["x1"], lay["x1"], lay["y1"],
                                        lay["m"], lay["delta"], queries)
        lo[li], hi[li] = l_, h_
    return lo, hi


def coalesce_ranges(starts, ends, gap: int = 0) -> tuple[np.ndarray, np.ndarray]:
    """Merge byte ranges ``[starts[i], ends[i])`` that overlap or sit within
    ``gap`` bytes of each other into maximal runs.

    Returns ``(run_starts, run_ends)`` sorted ascending.  ``gap > 0`` trades
    a few wasted bytes for fewer storage round-trips — profitable whenever
    ``T(gap) − T(0) < ℓ`` on the target tier (one extra seek costs ℓ).
    """
    s = np.asarray(starts, dtype=np.int64)
    e = np.asarray(ends, dtype=np.int64)
    if len(s) == 0:
        return s, e
    order = np.argsort(s, kind="stable")
    s, e = s[order], e[order]
    reach = np.maximum.accumulate(e)              # furthest byte seen so far
    new_run = np.empty(len(s), dtype=bool)
    new_run[0] = True
    new_run[1:] = s[1:] > reach[:-1] + gap
    first = np.flatnonzero(new_run)
    run_starts = s[first]
    run_ends = np.maximum.reduceat(e, first)
    return run_starts, run_ends
