"""On-disk index format + real partial-read lookup (paper §5.6).

Layout (single index file, layers bottom-up):

    [magic u64][json_len u64][json meta][layer_1 bytes] … [layer_L bytes]

Per-layer bytes are the concatenated node records whose byte offsets are
exactly the outline positions used during tuning, so modeled read sizes
equal real read sizes:

  * step layer — stream of 16 B pieces ``(key u64, pos i64)``; a node of
    ``p`` pieces is ``16·p`` consecutive bytes (paper §4.1);
  * band layer — 40 B records ``(x1 u64, y1 f64, m f64, δ f64, rsv u64)``.

Readers fetch *ranges* (``pread``), never whole layers (except the root,
per Alg. 1), align to record boundaries, and for step layers extend by one
record to obtain the next piece's position (fence-pointer style).

**Paged layout** (``write_index(..., page_bytes=N)``): every layer offset
is aligned up to a multiple of ``page_bytes`` (gaps are file holes), so
the file is a sequence of fixed-size pages and each page belongs to
exactly one layer.  Pages are the caching unit of the serving engine's
tiered block cache (:mod:`repro.serve.index_service`); the per-layer page
table is recoverable from the meta via :func:`page_span`.  ``page_bytes=0``
(the default) keeps the original densely-packed format — readers accept
both.

Layer descent math is shared with the in-memory path via
:mod:`repro.core.descent`, so file lookups and ``lookup_batch`` agree
bit-for-bit.
"""
from __future__ import annotations

import dataclasses
import json
import os
import zlib

import numpy as np

from .descent import descend_band_layer, descend_step_layer
from .keyset import KeyPositions
from .latency import IndexDesign
from .nodes import BandLayer, StepLayer

MAGIC = 0x41495249  # "AIRI"
_STEP_DT = np.dtype([("key", "<u8"), ("pos", "<i8")])
_BAND_DT = np.dtype([("x1", "<u8"), ("y1", "<f8"), ("m", "<f8"),
                     ("delta", "<f8"), ("rsv", "<u8")])


@dataclasses.dataclass
class LayerMeta:
    kind: str
    offset: int      # byte offset of the layer within the file
    size: int        # serialized size (== Θ_l's s(Θ_l))
    end_pos: int     # position after the layer's last prediction target
    # per-page CRC32 table (paged layouts written with checksums=True):
    # entry k covers the layer's k-th page, computed over the page's bytes
    # zero-padded to page_bytes (alignment gaps are file holes, so a
    # padded CRC equals the CRC of what a reader actually sees — including
    # the file's final, physically-short page).  None on densely-packed
    # layouts and on files written before checksums existed; readers skip
    # verification for those.
    page_crcs: list | None = None


@dataclasses.dataclass
class IndexFileMeta:
    layers: list          # bottom-up LayerMeta
    data_size: int        # extent of the data layer (for clamping)
    data_record: int      # fixed record size of the data layer (0 = varlen)
    page_bytes: int = 0   # fixed page size (0 = densely packed, unpaged)
    tune: dict | None = None   # provenance: how the index was tuned — the
    #   ``repro.api`` facade records {"spec": TuneSpec.to_dict(), "strategy",
    #   "cost", "builder_names", "profile"} so a reopened index remembers
    #   its TuneSpec and can be re-tuned when the storage profile changes

    def to_json(self) -> str:
        d = {
            "layers": [dataclasses.asdict(l) for l in self.layers],
            "data_size": self.data_size, "data_record": self.data_record,
            "page_bytes": self.page_bytes,
        }
        if self.tune is not None:
            d["tune"] = self.tune
        return json.dumps(d)

    @staticmethod
    def from_json(s: str) -> "IndexFileMeta":
        d = json.loads(s)
        return IndexFileMeta(
            layers=[LayerMeta(**l) for l in d["layers"]],
            data_size=d["data_size"], data_record=d["data_record"],
            page_bytes=d.get("page_bytes", 0), tune=d.get("tune"))


RECORD_BYTES = {"step": 16, "band": 40}


def page_span(offset: int, size: int, page_bytes: int) -> tuple[int, int]:
    """File-global page ids [first, last) covering bytes [offset, offset+size)."""
    return offset // page_bytes, -(-(offset + size) // page_bytes)


def record_aligned_range(kind: str, lo, hi, layer_size: int):
    """Byte range of a layer to fetch for predicted positions ``[lo, hi)``.

    Vectorized over queries.  Aligns down/up to record boundaries; step
    layers extend by one record so the *next* piece's position (the range
    end, fence-pointer style) is always present.  Degenerate ``hi <= lo``
    predictions still fetch one record.
    """
    rsz = RECORD_BYTES[kind]
    a = (np.maximum(lo, 0) // rsz) * rsz
    b = -(-np.asarray(hi) // rsz) * rsz + (rsz if kind == "step" else 0)
    b = np.minimum(np.maximum(b, a + rsz), layer_size)
    a = np.minimum(a, b - rsz)
    return a.astype(np.int64), b.astype(np.int64)


def page_crc(chunk: bytes, page_bytes: int) -> int:
    """CRC32 of one page as stored on disk, zero-padded to ``page_bytes``.

    Layers are page-aligned in the paged layout, so every page holds bytes
    of exactly one layer; a layer's last page is padded by the alignment
    hole (zeros) — or physically truncated at EOF, which pads to the same
    bytes.  Padding before hashing makes the CRC independent of which of
    those two forms a reader receives."""
    if len(chunk) < page_bytes:
        chunk = chunk + b"\0" * (page_bytes - len(chunk))
    return zlib.crc32(chunk) & 0xFFFFFFFF


def layer_page_crcs(blob: bytes, page_bytes: int) -> list:
    """The per-page CRC32 table of one page-aligned layer blob."""
    return [page_crc(blob[k:k + page_bytes], page_bytes)
            for k in range(0, max(len(blob), 1), page_bytes)]


def _layer_bytes(layer) -> bytes:
    if isinstance(layer, StepLayer):
        rec = np.empty(layer.n_pieces, dtype=_STEP_DT)
        rec["key"] = layer.piece_keys
        rec["pos"] = layer.piece_pos[:-1]
        return rec.tobytes()
    rec = np.empty(layer.n_nodes, dtype=_BAND_DT)
    rec["x1"] = layer.x1
    rec["y1"] = layer.y1.astype(np.float64)
    rec["m"] = layer.m
    rec["delta"] = layer.delta
    rec["rsv"] = 0
    return rec.tobytes()


def write_index(path: str, design: IndexDesign, data_record: int = 0,
                page_bytes: int = 0, tune: dict | None = None,
                checksums: bool = True) -> IndexFileMeta:
    """Serialize a design.  ``page_bytes > 0`` aligns every layer to page
    boundaries (paged layout — the serving engine's cache unit); 0 keeps
    the densely-packed layout.  ``tune`` is an optional JSON-serializable
    provenance dict recorded into the meta (see :class:`IndexFileMeta`).
    Paged layouts also record a per-page CRC32 table into each layer's
    meta (``checksums=False`` writes the pre-checksum format — what every
    file written before the table existed looks like; readers verify only
    when the table is present)."""
    metas = []
    blobs = []
    for layer in design.layers:
        b = _layer_bytes(layer)
        assert len(b) == layer.size_bytes, "serialized size must match s(Θ_l)"
        end_pos = int(layer.piece_pos[-1]) if isinstance(layer, StepLayer) \
            else int(layer.clamp_hi)
        crcs = layer_page_crcs(b, page_bytes) \
            if page_bytes > 0 and checksums else None
        metas.append(LayerMeta(kind=layer.kind, offset=0, size=len(b),
                               end_pos=end_pos, page_crcs=crcs))
        blobs.append(b)
    meta = IndexFileMeta(layers=metas, data_size=design.data.size_bytes,
                         data_record=data_record, page_bytes=page_bytes,
                         tune=tune)

    def _align(off: int) -> int:
        return off if page_bytes == 0 else -(-off // page_bytes) * page_bytes

    def _place(base: int) -> None:
        off = base
        for m, b in zip(metas, blobs):
            m.offset = _align(off)
            off = m.offset + len(b)

    hdr = meta.to_json().encode()
    base = 16 + len(hdr)
    _place(base)
    hdr = meta.to_json().encode()  # re-encode with final offsets
    # json length changes offsets only if digit counts change; fix-point it
    while 16 + len(hdr) != base:
        base = 16 + len(hdr)
        _place(base)
        hdr = meta.to_json().encode()
    with open(path, "wb") as f:
        f.write(np.asarray([MAGIC, len(hdr)], dtype="<u8").tobytes())
        f.write(hdr)
        for m, b in zip(metas, blobs):
            f.seek(m.offset)      # alignment gaps become file holes (zeros)
            f.write(b)
    return meta


def parse_meta(pread) -> IndexFileMeta:
    """Read + decode the header through any ``pread(nbytes, offset)``
    callable — the seam that lets the serving engine's fault-tolerant
    backend (retries, fault injection) own the meta read too.  Raises
    ``ValueError`` on a bad magic or an undecodable header, so a torn
    read is retryable rather than an assert."""
    head = pread(16, 0)
    if len(head) != 16:
        raise ValueError(f"bad index file: short header ({len(head)} B)")
    magic, hlen = np.frombuffer(head, dtype="<u8")
    if magic != MAGIC:
        raise ValueError(f"bad index file: magic {int(magic):#x}")
    return IndexFileMeta.from_json(pread(int(hlen), 16).decode())


def read_meta(fd: int) -> IndexFileMeta:
    """Header from an already-open raw fd — compat seam for callers that
    own their descriptor (tests, tooling); path-based callers should use
    :func:`read_meta_path`, which reads through the StorageBackend."""
    # airlint: allow[pread-seam] -- raw-fd compat seam; the caller owns the
    # descriptor and path-based internal callers use read_meta_path instead
    return parse_meta(lambda n, off: os.pread(fd, n, off))


def open_file_backend(path: str):
    """A :class:`repro.serve.FileBackend` for ``path`` (lazy import:
    serve sits above core in the layer order)."""
    from repro.serve.backend import FileBackend
    return FileBackend(path)


def read_meta_path(path: str) -> IndexFileMeta:
    """Header of the index file at ``path``, read through the
    StorageBackend seam (so CRC/fault-injection wrappers apply)."""
    be = open_file_backend(path)
    try:
        return parse_meta(be.pread)
    finally:
        be.close()


def load_index(path: str, data: KeyPositions) -> IndexDesign:
    """Deprecated shim: use ``repro.api.Index.open(path, data=data).design``.

    Delegates to the facade (which calls :func:`materialize_design`, the
    same implementation this function used to own), so results are
    bit-identical to the old behavior.
    """
    from .deprecation import warn_deprecated
    warn_deprecated(
        "repro.core.load_index(path, data) is deprecated; use "
        "repro.api.Index.open(path, data=data).design")
    from repro.api import Index
    return Index.open(path, data=data).design


def materialize_design(path: str, data: KeyPositions) -> IndexDesign:
    """Full deserialization (round-trips, re-tuning); real lookups use ranges."""
    be = open_file_backend(path)
    try:
        meta = parse_meta(be.pread)
        layers = []
        for lm in meta.layers:
            raw = be.pread(lm.size, lm.offset)
            if lm.kind == "step":
                rec = np.frombuffer(raw, dtype=_STEP_DT)
                pos = np.append(rec["pos"].astype(np.int64), lm.end_pos)
                # node grouping is not persisted; treat each piece as a node
                off = np.arange(len(rec) + 1, dtype=np.int64)
                layers.append(StepLayer(piece_keys=rec["key"].copy(),
                                        piece_pos=pos,
                                        node_piece_off=off))
            else:
                rec = np.frombuffer(raw, dtype=_BAND_DT)
                layers.append(BandLayer(
                    node_keys=rec["x1"].copy(), x1=rec["x1"].copy(),
                    y1=rec["y1"].astype(np.int64), m=rec["m"].copy(),
                    delta=rec["delta"].copy(),
                    clamp_lo=0, clamp_hi=lm.end_pos))
        return IndexDesign(layers=tuple(layers), data=data)
    finally:
        be.close()


# ---------------------------------------------------------------------------
# real partial-read lookup (Alg. 1 against the file)
# ---------------------------------------------------------------------------
def predict_from_records(kind: str, raw: bytes, queries: np.ndarray,
                         end_pos: int) -> tuple[np.ndarray, np.ndarray]:
    """Parse fetched records and run one layer of descent for a query batch
    (Alg. 1 l. 3–5) — the same :mod:`repro.core.descent` step as the
    in-memory path.  ``end_pos`` caps the last fetched step record's range
    (its fence pointer is the next record, absent at the layer end)."""
    q = np.asarray(queries, dtype=np.uint64)
    if kind == "step":
        rec = np.frombuffer(raw, dtype=_STEP_DT)
        pos = rec["pos"].astype(np.int64)
        pos_hi = np.append(pos[1:], np.int64(end_pos))
        return descend_step_layer(rec["key"], pos, pos_hi, q)
    rec = np.frombuffer(raw, dtype=_BAND_DT)
    return descend_band_layer(rec["x1"], rec["x1"], rec["y1"], rec["m"],
                              rec["delta"], q)


def record_keys(kind: str, raw: bytes) -> np.ndarray:
    """Sorted partition keys of fetched records (covering-search domain)."""
    return np.frombuffer(raw, dtype=_STEP_DT if kind == "step" else _BAND_DT)[
        "key" if kind == "step" else "x1"]


def gallop_step(kind: str, a: int, b: int) -> int:
    """Extension step for a missed window ``[a, b)`` — the window's own
    width, but never less than one record of the layer's dtype: a
    zero-width window (``b == a`` after clamping) would otherwise retry
    with the same bounds forever.  Shared by :class:`SerializedIndex` and
    the serving engine so their gallop walks stay in lockstep."""
    return max(b - a, RECORD_BYTES[kind])


def window_misses(kind: str, raw: bytes, a: int, b: int, layer_size: int,
                  queries: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Per-query check that a fetched window ``[a, b)`` contains the true
    covering record.

    A *band* upper layer predicts a range for the exact query key, but its
    containment guarantee (Eq. 1) is established at the outline's boundary
    keys — for keys strictly between boundaries the window can land next to
    the covering record.  (Step upper layers are piecewise-constant, so
    their windows never miss.)  Misses are detectable without extra I/O:

      * left miss  — every fetched key > q and bytes exist before the
        window: the covering record is earlier;
      * right miss — every guard fails the other way (last fetched key ≤ q)
        and bytes exist after: the covering record (or its fence pointer)
        may be later.

    Callers extend the window in the indicated direction and re-check
    (galloping — doubles per round, terminates at the layer bounds).
    """
    keys = record_keys(kind, raw)
    q = np.asarray(queries, dtype=np.uint64)
    left = (keys[0] > q) & (a > 0)
    right = (keys[-1] <= q) & (b < layer_size)
    return left, right


class SerializedIndex:
    """Handle for Alg.-1 lookups against an index file with partial reads.

    Reads flow through a :class:`repro.serve.StorageBackend` (default
    :class:`~repro.serve.FileBackend`); pass ``backend_factory`` to wrap
    the file in a fault-injecting or instrumented backend.
    """

    def __init__(self, path: str, backend_factory=None):
        factory = backend_factory or open_file_backend
        self._backend = factory(path)
        self.meta = parse_meta(self._backend.pread)
        self.bytes_read = 0
        self.reads = 0
        root = self.meta.layers[-1] if self.meta.layers else None
        self._root_raw = (self._backend.pread(root.size, root.offset)
                          if root else b"")
        if root:
            self.bytes_read += root.size
            self.reads += 1

    def close(self):
        self._backend.close()

    def lookup(self, query: int) -> tuple[int, int]:
        """→ predicted [lo, hi) byte range in the data layer."""
        metas = self.meta.layers
        if not metas:
            return 0, self.meta.data_size
        q1 = np.asarray([query], dtype=np.uint64)
        lo, hi = predict_from_records(metas[-1].kind, self._root_raw, q1,
                                      metas[-1].end_pos)
        for lm in reversed(metas[:-1]):
            a, b = record_aligned_range(lm.kind, lo, hi, lm.size)
            a, b = int(a[0]), int(b[0])
            while True:
                raw = self._backend.pread(b - a, lm.offset + a)
                self.bytes_read += b - a
                self.reads += 1
                left, right = window_misses(lm.kind, raw, a, b, lm.size, q1)
                if not (left[0] or right[0]):
                    break
                w = gallop_step(lm.kind, a, b)  # toward the covering record
                if left[0]:
                    a = max(a - w, 0)
                else:
                    b = min(b + w, lm.size)
            lo, hi = predict_from_records(lm.kind, raw, q1, lm.end_pos)
        lo = max(int(lo[0]), 0)
        hi = min(max(int(hi[0]), lo + 1), self.meta.data_size)
        return lo, hi


def lookup_serialized(path: str, meta_unused, queries: np.ndarray):
    idx = SerializedIndex(path)
    try:
        return np.array([idx.lookup(int(q)) for q in np.asarray(queries)],
                        dtype=np.int64)
    finally:
        idx.close()
