"""On-disk index format + real partial-read lookup (paper §5.6).

Layout (single index file, layers bottom-up):

    [magic u64][json_len u64][json meta][layer_1 bytes] … [layer_L bytes]

Per-layer bytes are the concatenated node records whose byte offsets are
exactly the outline positions used during tuning, so modeled read sizes
equal real read sizes:

  * step layer — stream of 16 B pieces ``(key u64, pos i64)``; a node of
    ``p`` pieces is ``16·p`` consecutive bytes (paper §4.1);
  * band layer — 40 B records ``(x1 u64, y1 f64, m f64, δ f64, rsv u64)``.

Readers fetch *ranges* (``pread``), never whole layers (except the root,
per Alg. 1), align to record boundaries, and for step layers extend by one
record to obtain the next piece's position (fence-pointer style).
"""
from __future__ import annotations

import dataclasses
import json
import os

import numpy as np

from .keyset import KeyPositions
from .latency import IndexDesign
from .nodes import BandLayer, StepLayer

MAGIC = 0x41495249  # "AIRI"
_STEP_DT = np.dtype([("key", "<u8"), ("pos", "<i8")])
_BAND_DT = np.dtype([("x1", "<u8"), ("y1", "<f8"), ("m", "<f8"),
                     ("delta", "<f8"), ("rsv", "<u8")])


@dataclasses.dataclass
class LayerMeta:
    kind: str
    offset: int      # byte offset of the layer within the file
    size: int        # serialized size (== Θ_l's s(Θ_l))
    end_pos: int     # position after the layer's last prediction target


@dataclasses.dataclass
class IndexFileMeta:
    layers: list          # bottom-up LayerMeta
    data_size: int        # extent of the data layer (for clamping)
    data_record: int      # fixed record size of the data layer (0 = varlen)

    def to_json(self) -> str:
        return json.dumps({
            "layers": [dataclasses.asdict(l) for l in self.layers],
            "data_size": self.data_size, "data_record": self.data_record,
        })

    @staticmethod
    def from_json(s: str) -> "IndexFileMeta":
        d = json.loads(s)
        return IndexFileMeta(
            layers=[LayerMeta(**l) for l in d["layers"]],
            data_size=d["data_size"], data_record=d["data_record"])


def _layer_bytes(layer) -> bytes:
    if isinstance(layer, StepLayer):
        rec = np.empty(layer.n_pieces, dtype=_STEP_DT)
        rec["key"] = layer.piece_keys
        rec["pos"] = layer.piece_pos[:-1]
        return rec.tobytes()
    rec = np.empty(layer.n_nodes, dtype=_BAND_DT)
    rec["x1"] = layer.x1
    rec["y1"] = layer.y1.astype(np.float64)
    rec["m"] = layer.m
    rec["delta"] = layer.delta
    rec["rsv"] = 0
    return rec.tobytes()


def write_index(path: str, design: IndexDesign, data_record: int = 0) -> IndexFileMeta:
    metas = []
    blobs = []
    for layer in design.layers:
        b = _layer_bytes(layer)
        assert len(b) == layer.size_bytes, "serialized size must match s(Θ_l)"
        end_pos = int(layer.piece_pos[-1]) if isinstance(layer, StepLayer) \
            else int(layer.clamp_hi)
        metas.append(LayerMeta(kind=layer.kind, offset=0, size=len(b),
                               end_pos=end_pos))
        blobs.append(b)
    meta = IndexFileMeta(layers=metas, data_size=design.data.size_bytes,
                         data_record=data_record)
    hdr = meta.to_json().encode()
    base = 16 + len(hdr)
    off = base
    for m, b in zip(metas, blobs):
        m.offset = off
        off += len(b)
    hdr = meta.to_json().encode()  # re-encode with final offsets
    # json length changes offsets only if digit counts change; fix-point it
    while 16 + len(hdr) != base:
        base = 16 + len(hdr)
        off = base
        for m, b in zip(metas, blobs):
            m.offset = off
            off += len(b)
        hdr = meta.to_json().encode()
    with open(path, "wb") as f:
        f.write(np.asarray([MAGIC, len(hdr)], dtype="<u8").tobytes())
        f.write(hdr)
        for b in blobs:
            f.write(b)
    return meta


def read_meta(fd: int) -> IndexFileMeta:
    head = os.pread(fd, 16, 0)
    magic, hlen = np.frombuffer(head, dtype="<u8")
    assert magic == MAGIC, "bad index file"
    return IndexFileMeta.from_json(os.pread(fd, int(hlen), 16).decode())


def load_index(path: str, data: KeyPositions) -> IndexDesign:
    """Full deserialization (tests/round-trip); real lookups use ranges."""
    fd = os.open(path, os.O_RDONLY)
    try:
        meta = read_meta(fd)
        layers = []
        for lm in meta.layers:
            raw = os.pread(fd, lm.size, lm.offset)
            if lm.kind == "step":
                rec = np.frombuffer(raw, dtype=_STEP_DT)
                pos = np.append(rec["pos"].astype(np.int64), lm.end_pos)
                # node grouping is not persisted; treat each piece as a node
                off = np.arange(len(rec) + 1, dtype=np.int64)
                layers.append(StepLayer(piece_keys=rec["key"].copy(),
                                        piece_pos=pos,
                                        node_piece_off=off))
            else:
                rec = np.frombuffer(raw, dtype=_BAND_DT)
                layers.append(BandLayer(
                    node_keys=rec["x1"].copy(), x1=rec["x1"].copy(),
                    y1=rec["y1"].astype(np.int64), m=rec["m"].copy(),
                    delta=rec["delta"].copy(),
                    clamp_lo=0, clamp_hi=lm.end_pos))
        return IndexDesign(layers=tuple(layers), data=data)
    finally:
        os.close(fd)


# ---------------------------------------------------------------------------
# real partial-read lookup (Alg. 1 against the file)
# ---------------------------------------------------------------------------
def _predict_from_bytes(kind: str, raw: bytes, base_off: int, lo: int,
                        query: int, end_pos: int) -> tuple[int, int]:
    """Parse fetched records, find the covering one, predict (Alg.1 l.3–5)."""
    if kind == "step":
        rec = np.frombuffer(raw, dtype=_STEP_DT)
        i = int(np.searchsorted(rec["key"], np.uint64(query), side="right")) - 1
        i = max(i, 0)
        nxt = int(rec["pos"][i + 1]) if i + 1 < len(rec) else end_pos
        return int(rec["pos"][i]), nxt
    rec = np.frombuffer(raw, dtype=_BAND_DT)
    i = int(np.searchsorted(rec["x1"], np.uint64(query), side="right")) - 1
    i = max(i, 0)
    mid = float(rec["y1"][i]) + float(rec["m"][i]) * float(
        np.float64(np.uint64(query) - rec["x1"][i]))
    d = float(rec["delta"][i])
    return int(np.floor(mid - d)), int(np.ceil(mid + d))


class SerializedIndex:
    """Handle for Alg.-1 lookups against an index file with partial reads."""

    def __init__(self, path: str):
        self.fd = os.open(path, os.O_RDONLY)
        self.meta = read_meta(self.fd)
        self.bytes_read = 0
        self.reads = 0
        root = self.meta.layers[-1] if self.meta.layers else None
        self._root_raw = os.pread(self.fd, root.size, root.offset) if root else b""
        if root:
            self.bytes_read += root.size
            self.reads += 1

    def close(self):
        os.close(self.fd)

    def lookup(self, query: int) -> tuple[int, int]:
        """→ predicted [lo, hi) byte range in the data layer."""
        metas = self.meta.layers
        if not metas:
            return 0, self.meta.data_size
        lo, hi = _predict_from_bytes(
            metas[-1].kind, self._root_raw, 0, 0, query, metas[-1].end_pos)
        for lm in reversed(metas[:-1]):
            rsz = 16 if lm.kind == "step" else 40
            a = (max(lo, 0) // rsz) * rsz
            b = min(-(-hi // rsz) * rsz + (rsz if lm.kind == "step" else 0),
                    lm.size)
            raw = os.pread(self.fd, b - a, lm.offset + a)
            self.bytes_read += b - a
            self.reads += 1
            lo, hi = _predict_from_bytes(lm.kind, raw, lm.offset, a, query,
                                         lm.end_pos)
        lo = max(lo, 0)
        hi = min(max(hi, lo + 1), self.meta.data_size)
        return lo, hi


def lookup_serialized(path: str, meta_unused, queries: np.ndarray):
    idx = SerializedIndex(path)
    try:
        return np.array([idx.lookup(int(q)) for q in np.asarray(queries)],
                        dtype=np.int64)
    finally:
        idx.close()
