"""Fused λ-grid candidate sweep engine — the Alg. 2 inner loop, batched.

Every search strategy's per-vertex work used to be a Python loop over the
candidate set 𝓕: build a layer, outline it, sample its read cost, score
it — O(|𝓕|) separate ``widths_at`` + ``profile`` passes per vertex, which
dominated tuning time.  :class:`SweepEngine` replaces that loop with one
fused "score all children of D" operation:

  1. **multi-λ building** — the Eq. (8) grid applies the *same* family to
     the *same* collection across ~13 λ values, so each family's whole
     λ-column builds in one call (``MULTI_LAM_FAMILIES``): the float64
     views convert once per collection and λ values that resolve to
     identical partitions share one layer object.  Families registered
     only in ``BUILDER_FAMILIES`` (third-party single-λ builders) fall
     back to per-λ builds transparently.
  2. **batched scoring** — all surviving candidates' sampled widths stack
     into one (C, SCORE_SAMPLE) matrix and ``E[T(Δ)]`` evaluates for every
     candidate in a single vectorized call
     (:func:`repro.core.latency.batched_mean_read_costs`); the shrink
     guard is one vectorized size comparison.  An opt-in jnp/Pallas
     scoring backend (``score_backend="jnp"|"pallas"``, see
     :mod:`repro.kernels.candidate_score`) accelerates the *ranking*
     estimates for affine-representable tiers; exact Eq. (6) costs always
     use the numpy float64 path so returned designs/costs stay exact.
  3. **memoization** — whole expansions are cached per collection
     fingerprint (``_VertexSweep``), and the profile-independent
     layer/outline pairs live in a :class:`LayerCache` keyed by
     (fingerprint, builder) that can be SHARED across strategy
     invocations: ``brute_force``/``beam`` stop rebuilding layers for
     collections they already expanded, and tuning the same dataset for
     several storage tiers (or certifying several strategies against
     each other, as benchmarks/tune_bench.py does) reuses every build
     (``TuneStats.layers_reused`` / ``sweeps`` count the effect).

Bit-identity contract: with the default numpy backend, every candidate's
layer arrays, outline, est/exact read cost, and τ̂ equal the legacy
per-builder loop's values bit-for-bit (tests/test_sweep.py certifies all
three strategies end-to-end).

Tail-latency objectives ride through unchanged: the strategies wrap the
tier in an :class:`~repro.core.storage.ObjectiveProfile` (the additive
``E[T] + w·Q̂_p[T]`` cost curve), and because the engine's score memos are
keyed by the profile object (``pin_profile``), the same LayerCache can
serve mean- and quantile-objective tunes concurrently — layer *builds*
are profile-independent and shared, scores are kept apart per objective.
The batched scoring call evaluates the objective row at the same cost as
the mean row (one vectorized ``C(Δ)`` pass over the width matrix).
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from .complexity import tau_hat
from .keyset import KeyPositions
from .latency import batched_mean_read_costs
from .nodes import Layer, outline
from .registry import BUILDER_FAMILIES, MULTI_LAM_FAMILIES
from .storage import StorageProfile

SCORE_SAMPLE = 65536   # pairs used for candidate *ranking* (§5.3); the
                       # selected candidates' costs are always exact

SCORE_BACKENDS = ("numpy", "jnp", "pallas")


@dataclasses.dataclass
class Candidate:
    """One outgoing edge of a search vertex: apply builder → next layer."""

    order: int             # position in the caller's builder list (tie-break)
    name: str              # F.name — TuneResult.builder_names provenance
    layer: Layer
    outline: KeyPositions  # the vertex this edge leads to (Alg. 2 line 5)
    est_cost: float        # sampled Ê[T(Δ)] — ranking only
    tau: float             # τ̂(outline; T), Eq. (12)
    entry: object = None   # backing _LayerEntry (score memo host)

    @property
    def score(self) -> float:
        """Eq. (9) selection score (same addition order as the legacy loop)."""
        return self.tau + self.est_cost


@dataclasses.dataclass
class _VertexSweep:
    cands: list            # shrinking Candidates, in builder-list order
    n_nonshrink: int       # edges discarded by the termination safeguard


@dataclasses.dataclass
class _LayerEntry:
    layer: object                   # the built Layer
    outline: object = None          # its outline, filled on first need
    # (profile key, "exact"|"est") -> E[T(Δ)].  When the vertex is small
    # enough that the §5.3 ranking subsample IS the full key set (n ≤
    # 2·SCORE_SAMPLE), the estimate equals the exact Eq. (6) expectation
    # bit-for-bit and both share the "exact" slot — so a brute-force
    # certification pass warms every guided strategy's ranking for free.
    scores: dict = dataclasses.field(default_factory=dict)


#: default entry cap for facade-retained caches (repro.api.Index): a
#: long-running observe→retune loop keeps one cache alive across every
#: retune generation, so it must be bounded — 64k entries comfortably
#: hold several full tunes while capping worst-case residency
DEFAULT_CACHE_ENTRIES = 65536


class LayerCache:
    """Profile-independent build memo: (collection fingerprint, builder)
    → layer (+ outline, lazily).

    λ-grid and vertex sweeps inside ONE tune always go through a cache
    (engines make a private one by default); passing an explicit cache to
    several strategy invocations extends the reuse across them — tuning
    one dataset for several storage tiers, certifying several strategies
    against each other (benchmarks/tune_bench.py), or warm-starting a
    re-tune after a profile change all rebuild zero layers for
    already-expanded collections.  The layer/outline pairs are
    T(Δ)-independent; the est/exact/τ̂ memos travel WITH the cached
    entries but are keyed per profile (``_LayerEntry.scores``), so
    sharing a cache across tiers can never alias costs between profiles
    — while re-tuning the same tier skips rescoring entirely.

    ``max_entries`` bounds the memo (insertion-order eviction via
    :meth:`trim`, called by the sweep engine after each expansion):
    evicting an entry only costs a rebuild on the next miss, so
    long-running retune loops stay memory-bounded.  ``None`` (default)
    keeps the historical unbounded behavior for single-tune engines.
    """

    def __init__(self, max_entries: int | None = None):
        from collections import OrderedDict
        self._entries: OrderedDict = OrderedDict()
        self.max_entries = max_entries
        self._pinned_profiles: list = []   # see pin_profile

    def __len__(self) -> int:
        return len(self._entries)

    def clear(self) -> None:
        self._entries.clear()
        self._pinned_profiles.clear()

    def trim(self) -> None:
        """Evict oldest-inserted entries beyond ``max_entries``."""
        if self.max_entries is not None:
            while len(self._entries) > self.max_entries:
                self._entries.popitem(last=False)

    def pin_profile(self, profile) -> tuple:
        """Score-memo key for an *unhashable* profile.  Pinning a strong
        reference for the cache's lifetime keeps ``id(profile)`` unique —
        otherwise a garbage-collected profile's address could be reused
        and silently alias another profile's memoized costs."""
        self._pinned_profiles.append(profile)
        return ("unhashable-profile", id(profile))


def seed_layer_cache(cache: LayerCache, D: KeyPositions, seed_layers,
                     builders: list) -> list:
    """Warm-start seeding: inject a previous design's layers into a
    :class:`LayerCache` keyed exactly as the builders that would rebuild
    them, so the next search gets cache hits along the old design's path
    instead of rebuilding it (ROADMAP: incremental re-tune on drift).

    ``seed_layers`` is the previous design bottom-up as ``(builder_name,
    layer)`` pairs — ``TuneResult.builder_names`` zipped with
    ``design.layers``, or the recovered equivalents of a disk-opened index
    (see ``repro.api.index``).  Layers whose recorded name matches no
    builder in ``builders`` stop the chain (the collections above them
    would no longer line up with search vertices).

    The caller guarantees each seed layer is bit-identical to what its
    named builder would build on its collection (builders are
    deterministic, so in-memory results always qualify; disk recovery
    must canonicalize first) — a violated guarantee would poison the
    memo with a layer the search believes it built.

    Returns the seeded chain as ``(name, layer, collection, outline)``
    tuples (used by the beam strategy to inject initial vertices).
    """
    by_name = {b.name: b for b in builders}
    chain = []
    cur = D
    for name, layer in seed_layers:
        b = by_name.get(name)
        if b is None or b.kind not in BUILDER_FAMILIES:
            break
        canon = getattr(BUILDER_FAMILIES.get(b.kind), "canonical_lam", None)
        lam = canon(cur, b.lam) if canon else b.lam
        key = (cur.fingerprint, b.kind, lam, b.p)
        out = None
        entry = cache._entries.get(key)
        if entry is None:
            out = outline(layer, cur)
            cache._entries[key] = _LayerEntry(layer, outline=out)
        else:                       # already cached (e.g. a shared cache
            if entry.outline is None:   # from the original tune)
                entry.outline = outline(entry.layer, cur)
            out = entry.outline
            layer = entry.layer
        chain.append((name, layer, cur, out))
        cur = out
    cache.trim()
    return chain


class SweepEngine:
    """Per-tune candidate factory shared by all search strategies.

    One engine instance lives for one strategy invocation (fixed builder
    list + storage profile), so its vertex cache never crosses profiles.
    """

    def __init__(self, builders: list, profile: StorageProfile,
                 stats, *, score_backend: str = "numpy",
                 rank_scores: bool = True,
                 layer_cache: LayerCache | None = None):
        if score_backend not in SCORE_BACKENDS:
            raise ValueError(f"score_backend must be one of {SCORE_BACKENDS},"
                             f" got {score_backend!r}")
        self.builders = list(builders)
        self.profile = profile
        self.stats = stats
        self.score_backend = score_backend
        # exhaustive strategies never rank by Eq. (9): skip Ê[T(Δ)] + τ̂
        self.rank_scores = rank_scores
        self.layer_cache = layer_cache if layer_cache is not None \
            else LayerCache()
        try:                       # score-memo key: equal profiles share
            hash(profile)
            self._pk = profile
        except TypeError:
            self._pk = self.layer_cache.pin_profile(profile)
        self._vertices: dict[bytes, _VertexSweep] = {}
        # family columns: (kind, p) -> ordered builder indices; preserves
        # the caller's builder order inside each column
        cols: dict[tuple, list[int]] = {}
        for i, b in enumerate(self.builders):
            cols.setdefault((b.kind, b.p), []).append(i)
        self._columns = list(cols.items())

    # -- warm-start seeding --------------------------------------------------
    def seed(self, D: KeyPositions, seed_layers) -> list:
        """Inject a previous design into this engine's layer cache (see
        :func:`seed_layer_cache`); counts the injected layers in
        ``TuneStats.layers_seeded``."""
        chain = seed_layer_cache(self.layer_cache, D, seed_layers,
                                 self.builders)
        self.stats.layers_seeded += len(chain)
        return chain

    # -- candidate expansion -------------------------------------------------
    def children(self, D: KeyPositions) -> list[Candidate]:
        """All shrinking candidates of vertex ``D``, scored, in builder
        order.  Memoized on the collection's content fingerprint."""
        fp = D.fingerprint
        hit = self._vertices.get(fp)
        if hit is not None:
            # a legacy revisit would have rebuilt + re-pruned everything
            self.stats.layers_reused += len(self.builders)
            self.stats.candidates_pruned += hit.n_nonshrink
            return hit.cands
        t0 = time.perf_counter()
        vs = self._expand(D)
        self._vertices[fp] = vs
        self.stats.sweeps += 1
        self.stats.sweep_seconds += time.perf_counter() - t0
        return vs.cands

    def _expand(self, D: KeyPositions) -> _VertexSweep:
        stats = self.stats
        fp = D.fingerprint
        lc = self.layer_cache._entries
        entries: list = [None] * len(self.builders)
        for (kind, p), idxs in self._columns:
            # a registered family may canonicalize λ (e.g. rmi_leaf maps
            # λ → its clamped model count): builders whose λ values
            # canonicalize alike share one cache entry and one build
            canon = getattr(BUILDER_FAMILIES.get(kind), "canonical_lam",
                            None) if kind in BUILDER_FAMILIES else None

            def _key(i):
                lam = self.builders[i].lam
                return (fp, kind, canon(D, lam) if canon else lam, p)

            missing = []
            for i in idxs:
                e = lc.get(_key(i))
                if e is not None:       # built by an earlier tune/vertex
                    entries[i] = e
                    stats.layers_reused += 1
                else:
                    missing.append(i)
            if not missing:
                continue
            if kind in MULTI_LAM_FAMILIES:
                built = MULTI_LAM_FAMILIES.get(kind)(
                    D, [self.builders[i].lam for i in missing], p)
            else:                       # single-λ-only family: legacy builds
                built, by_ck = [], {}
                for i in missing:
                    ck = _key(i)
                    layer = by_ck.get(ck)
                    if layer is None:   # canonical-λ duplicates build once
                        layer = by_ck[ck] = self.builders[i](D)
                    built.append(layer)
            made: dict[int, _LayerEntry] = {}
            for i, layer in zip(missing, built):
                e = made.get(id(layer))
                if e is None:           # λ values sharing a partition share
                    e = made[id(layer)] = _LayerEntry(layer)   # one entry
                    stats.layers_built += 1
                else:
                    stats.layers_reused += 1
                lc[_key(i)] = e
                entries[i] = e
        self.layer_cache.trim()     # bounded caches evict oldest entries
        #                             (local `entries` refs keep this
        #                             expansion's layers alive regardless)

        # shrink guard for every candidate in one vectorized comparison
        # (outline extent == layer.size_bytes: outlines span the serialized
        # layer, so the guard needs no outline construction for losers)
        sizes = np.fromiter((e.layer.size_bytes for e in entries),
                            dtype=np.int64, count=len(entries))
        shrinking = sizes < D.size_bytes
        n_nonshrink = int(np.count_nonzero(~shrinking))
        stats.candidates_pruned += n_nonshrink

        # outline once per unique surviving layer (cached cross-engine)
        survivors = [i for i in range(len(entries)) if shrinking[i]]
        uniq: list[_LayerEntry] = []
        seen: set[int] = set()
        for i in survivors:
            if id(entries[i]) not in seen:
                seen.add(id(entries[i]))
                uniq.append(entries[i])
        for e in uniq:
            if e.outline is None:
                e.outline = outline(e.layer, D)

        # Eq. (9) ranking terms, memoized per (entry, profile).  When the
        # §5.3 subsample is the full key set and the backend is numpy, the
        # estimate IS the exact Eq. (6) expectation — share its slot, so a
        # prior exact pass (e.g. a brute-force certification run on the
        # same cache) makes ranking free, and vice versa.
        pk = self._pk
        tau_by: dict[int, float] = {}
        est_by: dict[int, float] = {}
        if self.rank_scores:
            full = D.n <= 2 * SCORE_SAMPLE
            est_slot = (pk, "exact") if full and self.score_backend == "numpy" \
                else (pk, "est", self.score_backend)
            for e in uniq:
                t = e.scores.get((pk, "tau"))
                if t is None:
                    t = tau_hat(e.outline, self.profile)
                    e.scores[(pk, "tau")] = t
                tau_by[id(e)] = t
            to_score = [e for e in uniq if est_slot not in e.scores]
            if to_score:
                # batched sampled Ê[T(Δ)]: ONE (U, S) matrix for all layers
                keys, weights = _score_sample(D)
                W = np.stack([e.layer.widths_at(keys) for e in to_score])
                est = self._batched_est(W, weights)
                stats.candidates_scored += len(to_score)
                for e, v in zip(to_score, est):
                    e.scores[est_slot] = float(v)
            for e in uniq:
                est_by[id(e)] = e.scores[est_slot]
        else:                       # exhaustive strategies never rank
            for e in uniq:
                tau_by[id(e)] = est_by[id(e)] = float("nan")

        cands = [Candidate(order=i, name=self.builders[i].name,
                           layer=entries[i].layer,
                           outline=entries[i].outline,
                           est_cost=est_by[id(entries[i])],
                           tau=tau_by[id(entries[i])],
                           entry=entries[i])
                 for i in survivors]
        return _VertexSweep(cands=cands, n_nonshrink=n_nonshrink)

    def _batched_est(self, W: np.ndarray, weights: np.ndarray) -> np.ndarray:
        if self.score_backend != "numpy":
            # jnp/Pallas fast path is ranking-only and affine-only; import
            # lazily so the default path never pulls in jax
            from repro.kernels.candidate_score import candidate_scores
            return candidate_scores(W, weights, self.profile,
                                    backend=self.score_backend)
        return batched_mean_read_costs(W, weights, self.profile)

    # -- exact (Eq. 6) read costs -------------------------------------------
    def exact_read_costs(self, D: KeyPositions,
                         cands: list[Candidate]) -> list[float]:
        """Exact ``E_x[T(Δ)]`` over ALL of D's weighted keys, for the
        selected candidates — batched into one matrix, memoized per
        (entry, profile).  Always numpy float64: returned designs/costs
        must stay exactly Eq. (6) regardless of the ranking backend."""
        pk = self._pk
        missing, seen = [], set()
        for c in cands:
            eid = id(c.entry)
            if (pk, "exact") not in c.entry.scores and eid not in seen:
                missing.append(c)
                seen.add(eid)
        if missing:
            W = np.stack([c.layer.widths_at(D.keys) for c in missing])
            costs = batched_mean_read_costs(W, D.weights, self.profile)
            for c, v in zip(missing, costs):
                c.entry.scores[(pk, "exact")] = float(v)
            self.stats.candidates_scored += len(missing)
        return [c.entry.scores[(pk, "exact")] for c in cands]


def _score_sample(D: KeyPositions) -> tuple[np.ndarray, np.ndarray]:
    """The strided ranking subsample — same rule as the legacy
    ``_mean_layer_read_cost(..., sample=True)`` path."""
    if D.n > 2 * SCORE_SAMPLE:
        stride = D.n // SCORE_SAMPLE
        return D.keys[::stride], D.weights[::stride]
    return D.keys, D.weights
