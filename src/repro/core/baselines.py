"""Baseline index structures (paper §7.1, Appendix B), re-implemented inside
the AirIndex framework exactly like the paper's own controlled "B-TREE"
baseline: the *structure* is fixed by the baseline's rules, the storage
model scores it, and only AirIndex gets data-and-I/O-aware tuning.

  * :func:`build_fixed_btree`   — B-TREE: GStep(p=255, λ=4096) stacked
    (≡ 4 KB pages, 255 fanout) until a single-node root.
  * :func:`tune_rmi`            — RMI/CDFShop-style: two layers, linear
    root partitioning the key space equally over n linear leaf models;
    n swept on a grid (CDFShop recommends a Pareto set; we take the best
    under the storage model — a *stronger* baseline than the paper's).
  * :func:`tune_pgm`            — PGM-style: bounded-error greedy PLA
    stacked bottom-up with the same ε per layer; ε swept per the paper's
    grid {16 … 1024} records.
  * :func:`data_calculator`     — exhaustive grid over homogeneous step
    designs (restricted branching functions, cost-model driven).
  * :func:`homogeneous_airtune` — AirTune restricted to one node type
    (the §2.2 Step-only / PWL-only comparison).
"""
from __future__ import annotations

import numpy as np

from .airtune import TuneResult, TuneStats, airtune
from .builders import (LayerBuilder, _fit_bands_for_groups, build_gband,
                       build_gstep, make_builders)
from .keyset import KeyPositions, POS_DTYPE
from .latency import IndexDesign, expected_latency
from .nodes import BandLayer, StepLayer, outline
from .storage import StorageProfile


def _stack_until_root(D: KeyPositions, build_one, max_layers: int = 16):
    """Repeatedly build a layer on the previous outline until single-node."""
    layers = []
    cur = D
    for _ in range(max_layers):
        layer = build_one(cur)
        nxt = outline(layer, cur)
        if nxt.size_bytes >= cur.size_bytes:
            break  # no longer shrinking: stop below this layer
        layers.append(layer)
        cur = nxt
        if len(layer.node_sizes()) <= 1:
            break
    return IndexDesign(layers=tuple(layers), data=D)


# ---------------------------------------------------------------------------
# B-TREE (paper Appendix B): fixed GStep(255, 4096) stack
# ---------------------------------------------------------------------------
def build_fixed_btree(D: KeyPositions, p: int = 255, lam: float = 4096.0) -> IndexDesign:
    return _stack_until_root(D, lambda c: build_gstep(c, p=p, lam=lam))


# ---------------------------------------------------------------------------
# RMI (Appendix B): linear root → n linear leaf models, on-storage
# ---------------------------------------------------------------------------
def build_rmi(D: KeyPositions, n_models: int) -> IndexDesign:
    """Two-layer RMI with an equal-key-range linear root (CDF root model)."""
    n_models = min(n_models, D.n)
    k0 = int(D.keys[0])
    span = max(int(D.keys[-1]) - k0, 1)
    n_models = min(n_models, span + 1)
    # model-slot boundaries first; routing = searchsorted over them, so the
    # build-time grouping and lookup-time routing agree by construction
    bounds = (k0 + np.arange(n_models, dtype=np.float64)
              * (span + 1) / n_models).astype(np.uint64)
    gid = np.searchsorted(bounds, D.keys, side="right") - 1
    gid = np.clip(gid, 0, n_models - 1)
    starts = np.flatnonzero(np.diff(gid, prepend=-1))
    leaf = _fit_bands_for_groups(D, starts)
    present = gid[starts]

    # materialize one 40 B record per model slot; empty slots get a
    # whole-data fallback band (never queried for existing keys)
    node_keys = bounds
    x1 = node_keys.copy()
    y1 = np.full(n_models, (D.lo[0] + D.hi[-1]) // 2, dtype=POS_DTYPE)
    m = np.zeros(n_models, dtype=np.float64)
    delta = np.full(n_models, (D.hi[-1] - D.lo[0]) / 2 + 2.0, dtype=np.float64)
    y1[present] = leaf.y1
    m[present] = leaf.m
    delta[present] = leaf.delta
    x1[present] = leaf.x1
    bottom = BandLayer(node_keys=node_keys, x1=x1, y1=y1, m=m, delta=delta,
                       clamp_lo=int(D.lo[0]), clamp_hi=int(D.hi[-1]))

    # root: single band mapping key → 40-byte model slot (exact ±1 slot)
    slot_bytes = 40.0
    root = BandLayer(
        node_keys=np.array([0], dtype=np.uint64),
        x1=np.array([k0], dtype=np.uint64),
        y1=np.array([int(slot_bytes // 2)], dtype=POS_DTYPE),
        m=np.array([slot_bytes * n_models / (span + 1)], dtype=np.float64),
        delta=np.array([slot_bytes + 1.0], dtype=np.float64),
        clamp_lo=0, clamp_hi=int(slot_bytes) * n_models)
    return IndexDesign(layers=(bottom, root), data=D)


def tune_rmi(D: KeyPositions, profile: StorageProfile,
             grid=(2**8, 2**10, 2**12, 2**14, 2**16, 2**18, 2**20)) -> TuneResult:
    best, best_cost = None, np.inf
    for n_models in grid:
        if n_models > D.n:
            break
        design = build_rmi(D, n_models)
        cost = expected_latency(design, profile)
        if cost < best_cost:
            best, best_cost = design, cost
    return TuneResult(design=best, cost=best_cost, stats=TuneStats(),
                      strategy="rmi")


# ---------------------------------------------------------------------------
# PGM-INDEX (Appendix B): bounded-ε greedy PLA per layer, bottom-up
# ---------------------------------------------------------------------------
def build_pgm(D: KeyPositions, eps_records: int, record_bytes: int = 16) -> IndexDesign:
    lam = 2.0 * eps_records * record_bytes
    return _stack_until_root(D, lambda c: build_gband(c, lam=lam))


def tune_pgm(D: KeyPositions, profile: StorageProfile,
             grid=(16, 32, 64, 128, 256, 512, 1024)) -> TuneResult:
    best, best_cost = None, np.inf
    for eps in grid:
        design = build_pgm(D, eps)
        cost = expected_latency(design, profile)
        if cost < best_cost:
            best, best_cost = design, cost
    return TuneResult(design=best, cost=best_cost, stats=TuneStats(),
                      strategy="pgm")


# ---------------------------------------------------------------------------
# DATA CALCULATOR (Appendix B): exhaustive homogeneous-step grid
# ---------------------------------------------------------------------------
def data_calculator(D: KeyPositions, profile: StorageProfile,
                    lam_grid=None, p_grid=(16, 64, 255, 1024),
                    max_layers: int = 4) -> TuneResult:
    """Cost-model-driven exhaustive search, restricted to step branching and
    one (p, λ) shared across layers — the paper's characterization of Data
    Calculator's auto-completion (grid-search-like, restricted functions)."""
    if lam_grid is None:
        lam_grid = [2.0**s for s in range(10, 22, 2)]
    stats = TuneStats()
    best, best_cost = IndexDesign(layers=(), data=D), expected_latency(
        IndexDesign(layers=(), data=D), profile)
    for p in p_grid:
        for lam in lam_grid:
            design = _stack_until_root(
                D, lambda c: build_gstep(c, p=p, lam=lam), max_layers)
            stats.layers_built += design.n_layers
            for L in range(1, design.n_layers + 1):
                sub = IndexDesign(layers=design.layers[:L], data=D)
                stats.vertices_visited += 1
                cost = expected_latency(sub, profile)
                if cost < best_cost:
                    best, best_cost = sub, cost
    return TuneResult(design=best, cost=best_cost, stats=stats,
                      strategy="datacalc")


# ---------------------------------------------------------------------------
# Homogeneous AirTune (§2.2 Step-only vs PWL-only vs heterogeneous)
# ---------------------------------------------------------------------------
def homogeneous_airtune(D: KeyPositions, profile: StorageProfile, kind: str,
                        **kw) -> TuneResult:
    kinds = {"step": ("gstep",), "band": ("gband", "eband")}[kind]
    builders = make_builders(kinds=kinds)
    return airtune(D, profile, builders, **kw)
