"""Baseline index structures (paper §7.1, Appendix B) as *registered
builder families* competing inside the Alg. 2 search.

The paper's headline claim (§7, Fig. 12) is that AirIndex's search space
*contains* the baselines, so data-and-I/O-aware tuning can only win.
Earlier revisions built these structures outside the framework and only
compared costs; now each baseline is a family in
:data:`repro.core.registry.BUILDER_FAMILIES`, so ``make_builders`` /
``TuneSpec.families`` resolve them by name and every search strategy
(airtune / beam / brute_force) can mix them freely with ``gstep`` /
``gband`` / ``eband`` — the dominance claim becomes a property of the
search itself (asserted by ``benchmarks/baseline_bench.py``).

Registered families (λ is the Eq. 8 grid parameter; ``p`` is ignored —
each family's discipline fixes the node shape):

  * ``btree``    — B-TREE page discipline: one node = one λ-byte page,
    fanout fills the page (λ/16 − 1 entries); λ = 4096 reproduces the
    paper's GStep(255, 4096) B-TREE exactly.
  * ``rmi_leaf`` — RMI/CDFShop equal-key-range linear leaf models; λ is
    the target bytes of data per model, so the Eq. 8 grid sweeps the
    model count ``n`` (CDFShop's knob).
  * ``pgm``      — PGM / FITing-tree ε-bounded greedy PLA; λ is the
    error bound ε in bytes (band width 2δ ≤ 2ε).  The paper's ε grid
    {16 … 1024} *records* is :data:`PGM_EPS_GRID` × record size —
    :func:`pgm_builders` instantiates exactly that candidate set.

``btree`` and ``pgm`` also register fused multi-λ entries so they ride
the sweep engine's λ-column fast path; ``rmi_leaf`` instead exposes
``canonical_lam`` (λ → its clamped model count) so the engine's per-λ
fallback builds once per distinct ``n`` and the ``LayerCache`` dedups
the rest (counted in ``TuneStats.layers_reused``).

The original free functions remain as thin wrappers over the registered
families, with the paper's fixed shapes:

  * :func:`build_fixed_btree`   — B-TREE: the ``btree`` family at one
    page size, stacked until a single-node root.
  * :func:`tune_rmi`            — RMI/CDFShop-style: two layers, linear
    root partitioning the key space equally over n linear leaf models;
    n swept on a grid (CDFShop recommends a Pareto set; we take the best
    under the storage model — a *stronger* baseline than the paper's).
  * :func:`tune_pgm`            — PGM-style: the ``pgm`` family stacked
    bottom-up with the same ε per layer; ε swept per the paper's grid.
  * :func:`data_calculator`     — exhaustive grid over homogeneous step
    designs (restricted branching functions, cost-model driven).
  * :func:`homogeneous_airtune` — AirTune restricted to one node type
    (the §2.2 Step-only / PWL-only comparison).
"""
from __future__ import annotations

import numpy as np

from .airtune import TuneResult, TuneStats, airtune
from .builders import (LayerBuilder, build_gband, build_gband_multi,
                       build_gstep, check_disjoint, fit_bands_for_groups,
                       greedy_partition, gstep_from_starts, make_builders)
from .keyset import KeyPositions, POS_DTYPE
from .latency import IndexDesign, expected_latency
from .nodes import STEP_PIECE_BYTES, BandLayer, outline
from .registry import (BUILDER_FAMILIES, register_builder,
                       register_multi_lam_builder)
from .storage import StorageProfile

#: the baseline families this module registers, in paper order
BASELINE_FAMILIES = ("btree", "rmi_leaf", "pgm")

BTREE_PAGE_BYTES = 4096.0         # Appendix B: 4 KB pages, 255 fanout
PGM_RECORD_BYTES = 16             # the paper's fixed record size
PGM_EPS_GRID = (16, 32, 64, 128, 256, 512, 1024)   # ε in records (§7.1)


def _stack_until_root(D: KeyPositions, build_one, max_layers: int = 16):
    """Repeatedly build a layer on the previous outline until single-node."""
    layers = []
    cur = D
    for _ in range(max_layers):
        layer = build_one(cur)
        nxt = outline(layer, cur)
        if nxt.size_bytes >= cur.size_bytes:
            break  # no longer shrinking: stop below this layer
        layers.append(layer)
        cur = nxt
        if len(layer.node_sizes()) <= 1:
            break
    return IndexDesign(layers=tuple(layers), data=D)


# ---------------------------------------------------------------------------
# B-TREE family: page discipline — node = one λ-byte page, fanout fills it
# ---------------------------------------------------------------------------
def btree_fanout(page_bytes: float) -> int:
    """Entries of a B-tree node that fills one page: page/16 B − 1 (one
    slot reserved for the fence pointer — 4 KB pages give the paper's
    255 fanout)."""
    return max(int(float(page_bytes)) // STEP_PIECE_BYTES - 1, 1)


@register_builder("btree")
def build_btree_layer(D: KeyPositions, lam: float, p: int):
    """B-TREE node discipline (Appendix B): a greedy step layer whose
    page size is λ and whose fanout fills the page.  ``p`` is ignored —
    the page alone fixes the node shape (that IS the discipline)."""
    return build_gstep(D, p=btree_fanout(lam), lam=float(lam))


@register_multi_lam_builder("btree")
def build_btree_multi(D: KeyPositions, lams, p: int) -> list:
    """Fused λ-column for ``btree``: the greedy boundaries AND the
    per-page fanout both follow λ, so dedup keys on (boundaries, fanout).
    Each element is bit-identical to :func:`build_btree_layer` at that λ."""
    check_disjoint(D)
    lo_f, hi_f = D.lo_f, D.hi_f       # one float64 conversion for all λ
    layers, by_key = [], {}
    for lam in lams:
        fanout = btree_fanout(lam)
        starts = greedy_partition(lo_f, hi_f, float(lam))
        key = (starts.tobytes(), fanout)
        layer = by_key.get(key)
        if layer is None:
            layer = by_key[key] = gstep_from_starts(D, starts, fanout)
        layers.append(layer)
    return layers


def build_fixed_btree(D: KeyPositions, p: int | None = None,
                      lam: float = BTREE_PAGE_BYTES) -> IndexDesign:
    """B-TREE (Appendix B): the registered ``btree`` family stacked until
    a single-node root.  ``p=None`` (default) follows the page discipline
    (fanout = λ/16 − 1, i.e. GStep(255, 4096) at the default page); an
    explicit ``p`` keeps the legacy decoupled (p, λ) node shape."""
    if p is None:
        return _stack_until_root(
            D, lambda c: BUILDER_FAMILIES.get("btree")(c, lam, 0))
    return _stack_until_root(D, lambda c: build_gstep(c, p=p, lam=lam))


# ---------------------------------------------------------------------------
# RMI family: equal-key-range linear leaf models (CDF root routing)
# ---------------------------------------------------------------------------
def rmi_slot_starts(D: KeyPositions, n_models: int):
    """Equal-key-range slot assignment of the linear CDF root.

    Returns ``(n, bounds, gid, starts)``: the clamped model count, the
    model-slot boundary keys, each pair's slot id, and the start indices
    of the present (non-empty) slots.  Build-time grouping and
    lookup-time routing both use ``searchsorted`` over ``bounds``, so
    they agree by construction.
    """
    n_models = max(min(int(n_models), D.n), 1)
    k0 = int(D.keys[0])
    span = max(int(D.keys[-1]) - k0, 1)
    n_models = min(n_models, span + 1)
    bounds = (k0 + np.arange(n_models, dtype=np.float64)
              * (span + 1) / n_models).astype(np.uint64)
    gid = np.searchsorted(bounds, D.keys, side="right") - 1
    gid = np.clip(gid, 0, n_models - 1)
    starts = np.flatnonzero(np.diff(gid, prepend=-1))
    return n_models, bounds, gid, starts


def rmi_models_for_lam(D: KeyPositions, lam: float) -> int:
    """λ → model count: each leaf model covers ~λ bytes of the collection
    (the Eq. 8 granularity semantics), clamped exactly like
    :func:`rmi_slot_starts` so equal results mean equal structures."""
    n = max(int(D.size_bytes // max(float(lam), 1.0)), 1)
    n = max(min(n, D.n), 1)
    if D.n:
        span = max(int(D.keys[-1]) - int(D.keys[0]), 1)
        n = min(n, span + 1)
    return n


def build_rmi_leaf(D: KeyPositions, n_models: int) -> BandLayer:
    """One equal-key-range linear-leaf layer: the RMI bottom level fitted
    over the present slots (one band per non-empty slot)."""
    _, _, _, starts = rmi_slot_starts(D, n_models)
    return fit_bands_for_groups(D, starts)


@register_builder("rmi_leaf")
def _rmi_leaf_family(D: KeyPositions, lam: float, p: int):
    return build_rmi_leaf(D, rmi_models_for_lam(D, lam))


# many λ values clamp to the same model count: the sweep engine's per-λ
# fallback consults canonical_lam so those builders share one LayerCache
# entry (the reuse shows up in TuneStats.layers_reused)
_rmi_leaf_family.canonical_lam = rmi_models_for_lam


def build_rmi(D: KeyPositions, n_models: int) -> IndexDesign:
    """Two-layer RMI with an equal-key-range linear root (CDF root model),
    materialized for on-storage serving: the bottom level stores one 40 B
    record per model *slot* (empty slots get a whole-data fallback band,
    never queried for existing keys) so the root can address slot j at
    byte 40·j exactly."""
    n_models, bounds, gid, starts = rmi_slot_starts(D, n_models)
    leaf = fit_bands_for_groups(D, starts)        # == build_rmi_leaf
    present = gid[starts]

    k0 = int(D.keys[0])
    span = max(int(D.keys[-1]) - k0, 1)
    node_keys = bounds
    x1 = node_keys.copy()
    y1 = np.full(n_models, (D.lo[0] + D.hi[-1]) // 2, dtype=POS_DTYPE)
    m = np.zeros(n_models, dtype=np.float64)
    delta = np.full(n_models, (D.hi[-1] - D.lo[0]) / 2 + 2.0, dtype=np.float64)
    y1[present] = leaf.y1
    m[present] = leaf.m
    delta[present] = leaf.delta
    x1[present] = leaf.x1
    bottom = BandLayer(node_keys=node_keys, x1=x1, y1=y1, m=m, delta=delta,
                       clamp_lo=int(D.lo[0]), clamp_hi=int(D.hi[-1]))

    # root: single band mapping key → 40-byte model slot (exact ±1 slot)
    slot_bytes = 40.0
    root = BandLayer(
        node_keys=np.array([0], dtype=np.uint64),
        x1=np.array([k0], dtype=np.uint64),
        y1=np.array([int(slot_bytes // 2)], dtype=POS_DTYPE),
        m=np.array([slot_bytes * n_models / (span + 1)], dtype=np.float64),
        delta=np.array([slot_bytes + 1.0], dtype=np.float64),
        clamp_lo=0, clamp_hi=int(slot_bytes) * n_models)
    return IndexDesign(layers=(bottom, root), data=D)


def tune_rmi(D: KeyPositions, profile: StorageProfile,
             grid=(2**8, 2**10, 2**12, 2**14, 2**16, 2**18, 2**20)) -> TuneResult:
    best, best_cost = None, np.inf
    for n_models in grid:
        if n_models > D.n:
            break
        design = build_rmi(D, n_models)
        cost = expected_latency(design, profile)
        if cost < best_cost:
            best, best_cost = design, cost
    return TuneResult(design=best, cost=best_cost, stats=TuneStats(),
                      strategy="rmi")


# ---------------------------------------------------------------------------
# PGM family: ε-bounded greedy PLA (FITing-tree / PGM segment discipline)
# ---------------------------------------------------------------------------
@register_builder("pgm")
def build_pgm_layer(D: KeyPositions, lam: float, p: int):
    """ε-bounded greedy PLA: λ is the error bound ε in BYTES — every
    emitted segment keeps its band half-width δ ≤ ε (+fit safety), i.e.
    |ŷ(x) − y(x)| ≤ ε for all indexed keys.  ``p`` is ignored."""
    return build_gband(D, lam=2.0 * float(lam))


@register_multi_lam_builder("pgm")
def build_pgm_multi(D: KeyPositions, lams, p: int) -> list:
    return build_gband_multi(D, [2.0 * float(lam) for lam in lams], p)


def pgm_builders(record_bytes: int = PGM_RECORD_BYTES,
                 grid=PGM_EPS_GRID) -> list[LayerBuilder]:
    """The paper's PGM candidate set: ε ∈ {16 … 1024} records."""
    return [LayerBuilder(kind="pgm", lam=float(eps * record_bytes))
            for eps in grid]


def build_pgm(D: KeyPositions, eps_records: int,
              record_bytes: int = PGM_RECORD_BYTES) -> IndexDesign:
    """PGM (Appendix B): the registered ``pgm`` family stacked bottom-up
    with the same ε per layer."""
    eps_bytes = float(eps_records * record_bytes)
    return _stack_until_root(
        D, lambda c: BUILDER_FAMILIES.get("pgm")(c, eps_bytes, 0))


def tune_pgm(D: KeyPositions, profile: StorageProfile,
             grid=PGM_EPS_GRID) -> TuneResult:
    best, best_cost = None, np.inf
    for eps in grid:
        design = build_pgm(D, eps)
        cost = expected_latency(design, profile)
        if cost < best_cost:
            best, best_cost = design, cost
    return TuneResult(design=best, cost=best_cost, stats=TuneStats(),
                      strategy="pgm")


# ---------------------------------------------------------------------------
# DATA CALCULATOR (Appendix B): exhaustive homogeneous-step grid
# ---------------------------------------------------------------------------
def data_calculator(D: KeyPositions, profile: StorageProfile,
                    lam_grid=None, p_grid=(16, 64, 255, 1024),
                    max_layers: int = 4) -> TuneResult:
    """Cost-model-driven exhaustive search, restricted to step branching and
    one (p, λ) shared across layers — the paper's characterization of Data
    Calculator's auto-completion (grid-search-like, restricted functions)."""
    if lam_grid is None:
        lam_grid = [2.0**s for s in range(10, 22, 2)]
    stats = TuneStats()
    best, best_cost = IndexDesign(layers=(), data=D), expected_latency(
        IndexDesign(layers=(), data=D), profile)
    gstep = BUILDER_FAMILIES.get("gstep")
    for p in p_grid:
        for lam in lam_grid:
            design = _stack_until_root(
                D, lambda c: gstep(c, lam, p), max_layers)
            stats.layers_built += design.n_layers
            for L in range(1, design.n_layers + 1):
                sub = IndexDesign(layers=design.layers[:L], data=D)
                stats.vertices_visited += 1
                cost = expected_latency(sub, profile)
                if cost < best_cost:
                    best, best_cost = sub, cost
    return TuneResult(design=best, cost=best_cost, stats=stats,
                      strategy="datacalc")


# ---------------------------------------------------------------------------
# Homogeneous AirTune (§2.2 Step-only vs PWL-only vs heterogeneous)
# ---------------------------------------------------------------------------
def homogeneous_airtune(D: KeyPositions, profile: StorageProfile, kind: str,
                        **kw) -> TuneResult:
    kinds = {"step": ("gstep",), "band": ("gband", "eband")}[kind]
    builders = make_builders(kinds=kinds)
    return airtune(D, profile, builders, **kw)
