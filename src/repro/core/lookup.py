"""Query process (paper §4.2, Alg. 1) — batched, array-oriented.

The paper traverses one key at a time (read → search → reconstruct node →
predict).  The TPU-native adaptation (DESIGN.md §2) processes a *batch* of
query keys per traversal step: each layer descent is a vectorized
piece/node search plus a prediction, which is exactly what the Pallas
kernel in ``repro.kernels.index_lookup`` implements on-device.  This module
provides:

  * :func:`descend_step_layer` / :func:`descend_band_layer` — one layer of
    descent (re-exported from :mod:`repro.core.descent`); the single
    implementation shared by every path below and by the serving engine
    (:mod:`repro.serve.index_service`);
  * :func:`lookup_batch` — in-memory traversal returning predicted data
    ranges + the modeled per-query latency (Eq. 5 terms), used by tests,
    benchmarks, and the storage-model evaluation;
  * :func:`lookup_file` — deprecation shim onto the facade
    (``repro.api.Index.open(path).lookup``); the real partial-read walk
    lives in :mod:`repro.core.serialize`.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .descent import (coalesce_ranges, descend_band_layer,  # noqa: F401
                      descend_step_layer)
from .latency import IndexDesign
from .storage import StorageProfile


@dataclasses.dataclass(frozen=True)
class LookupResult:
    lo: np.ndarray            # (q,) predicted data-layer range start
    hi: np.ndarray            # (q,) predicted data-layer range end
    modeled_seconds: np.ndarray  # (q,) Σ T(Δ) + T(s_root) per query (Eq. 5)
    bytes_read: np.ndarray    # (q,) total bytes fetched per query


def lookup_batch(design: IndexDesign, queries: np.ndarray,
                 profile: StorageProfile | None = None) -> LookupResult:
    """Traverse the index top-down for a batch of keys (Alg. 1).

    Returns the final data-layer byte range per query; the caller fetches
    those ranges and runs the last-mile search (binary search over records).
    """
    q = np.asarray(queries, dtype=np.uint64)
    n_q = len(q)
    seconds = np.zeros(n_q, dtype=np.float64)
    nbytes = np.zeros(n_q, dtype=np.float64)
    if design.n_layers == 0:
        lo = np.full(n_q, design.data.lo[0], dtype=np.int64)
        hi = np.full(n_q, design.data.hi[-1], dtype=np.int64)
        width = float(design.data.size_bytes)
        if profile is not None:
            seconds += float(profile(width))
        return LookupResult(lo, hi, seconds, nbytes + width)

    # root layer: read in full
    root = design.layers[-1]
    root_size = float(root.size_bytes)
    nbytes += root_size
    if profile is not None:
        seconds += float(profile(root_size))

    lo = hi = None
    for layer in reversed(design.layers):
        lo, hi = layer.predict(q)
        width = (hi - lo).astype(np.float64)
        nbytes += width
        if profile is not None:
            seconds += np.asarray(profile(width), dtype=np.float64)
    return LookupResult(lo, hi, seconds, nbytes)


def verify_lookup(design: IndexDesign, queries: np.ndarray) -> bool:
    """Check validity end-to-end: the predicted final range must contain the
    true record range of every queried key (Eq. 1 composed across layers)."""
    D = design.data
    idx = np.searchsorted(D.keys, np.asarray(queries, dtype=np.uint64))
    idx = np.clip(idx, 0, D.n - 1)
    res = lookup_batch(design, queries)
    ok = (res.lo <= D.lo[idx]) & (res.hi >= D.hi[idx])
    return bool(np.all(ok))


def last_mile_search(keys_in_range: np.ndarray, query: int) -> int:
    """Binary search within a fetched data range (Alg. 1 line 3)."""
    i = int(np.searchsorted(keys_in_range, np.uint64(query), side="right")) - 1
    return max(i, 0)


def lookup_file(path: str, design_meta, queries: np.ndarray):
    """Deprecated shim: use ``repro.api.Index.open(path).lookup(queries)``.

    The facade path runs the identical :class:`repro.core.serialize.
    SerializedIndex` walk, so results are bit-identical.  ``design_meta``
    was always unused and is ignored.
    """
    from .deprecation import warn_deprecated
    warn_deprecated(
        "repro.core.lookup.lookup_file(path, meta, queries) is deprecated; "
        "use repro.api.Index.open(path).lookup(queries)")
    from repro.api import Index
    with Index.open(path) as idx:
        return idx.lookup(queries)
