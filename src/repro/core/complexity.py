"""Step index complexity ``τ̂(D; T)`` (paper §5.3, §A.3, Eq. 12).

The optimal remaining lookup cost of indexing a collection of extent
``s_D`` with *ideal balanced step layers* only:

    τ̂(D; T) = min_{L ∈ 0..O(log s_D)} (L+1) · T( (s_D · s_step^L)^(1/(L+1)) )

where ``s_step`` is the size of a 1-piece step node (16 B for 8-byte keys
and positions).  It upper-bounds the true index complexity ``τ(D; T)`` and
depends only on the integer ``s_D`` — hence arithmetically computable and
cheap — making it the "remaining work" heuristic for AirTune's top-k
candidate selection (Eq. 9).
"""
from __future__ import annotations

import numpy as np

from .keyset import KeyPositions
from .storage import StorageProfile

S_STEP = 16.0  # bytes of an ideal 1-piece step node (8 B key + 8 B position)


def step_index_complexity(size_bytes: float, profile: StorageProfile,
                          max_layers: int | None = None) -> float:
    """Eq. (12) — vectorized over candidate layer counts L."""
    s = max(float(size_bytes), 1.0)
    if max_layers is None:
        # L beyond log_{?}(s_D) cannot help; log2 is a safe upper bound
        max_layers = int(np.ceil(np.log2(max(s, 2.0)))) + 1
    L = np.arange(0, max_layers + 1, dtype=np.float64)
    # (s_D * s_step^L)^(1/(L+1)) computed in log space for stability
    log_read = (np.log(s) + L * np.log(S_STEP)) / (L + 1.0)
    reads = np.exp(log_read)
    costs = (L + 1.0) * np.asarray(profile(reads), dtype=np.float64)
    return float(costs.min())


def step_index_complexity_layers(size_bytes: float, profile: StorageProfile) -> int:
    """The arg-min L of Eq. (12) — the depth an ideal step index would use."""
    s = max(float(size_bytes), 1.0)
    max_layers = int(np.ceil(np.log2(max(s, 2.0)))) + 1
    L = np.arange(0, max_layers + 1, dtype=np.float64)
    log_read = (np.log(s) + L * np.log(S_STEP)) / (L + 1.0)
    costs = (L + 1.0) * np.asarray(profile(np.exp(log_read)), dtype=np.float64)
    return int(np.argmin(costs))


def tau_hat(D: KeyPositions, profile: StorageProfile) -> float:
    """τ̂(D; T) for a key-position collection (uses only its extent s_D)."""
    return step_index_complexity(D.size_bytes, profile)
