"""End-to-end lookup latency under a storage model (paper §4.3).

``L_SM(x; Θ, T) = T(s(Θ_L)) + Σ_{l=1..L} T(Δ(x; Θ_l))``        (Eq. 5)
``L_SM(X; Θ, T) = E_{x∼X}[ · ]``                                 (Eq. 6)

A *design* here is the bottom-up list of built layers ``[Θ_1, …, Θ_L]``
(layer 1 sits directly on the data layer).  The data-layer read
``T(Δ(x; Θ_1))`` uses layer 1's prediction width; the root layer is read in
full, ``T(s(Θ_L))``; an empty design reads the whole collection, ``T(s_D)``.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .keyset import KeyPositions
from .nodes import mean_width, outline
from .storage import StorageProfile, normalize_objective, objective_profile


@dataclasses.dataclass(frozen=True)
class IndexDesign:
    """Built hierarchical index: layers bottom-up + the collection indexed."""

    layers: tuple          # (Θ_1, …, Θ_L); () = no index
    data: KeyPositions     # the data layer's key-position collection

    @property
    def n_layers(self) -> int:
        return len(self.layers)

    def outlines(self) -> list[KeyPositions]:
        """[D_0=data, D_1=outline(Θ_1), …, D_L]."""
        outs = [self.data]
        for layer in self.layers:
            outs.append(outline(layer, outs[-1]))
        return outs

    def describe(self) -> str:
        outs = self.outlines()
        parts = []
        for i, layer in enumerate(self.layers):
            parts.append(
                f"L{i + 1}:{layer.kind}[nodes={len(layer.node_sizes())}"
                f" size={layer.size_bytes}B"
                f" EΔ={mean_width(layer, outs[i]):.0f}B]")
        return " <- ".join(parts) if parts else "(no index)"


def expected_latency(design: IndexDesign, profile: StorageProfile) -> float:
    """Eq. (6) with X uniform over the data layer's (weighted) keys.

    Every layer's prediction width is evaluated at the *original* query
    keys; each original key's lookup path touches exactly one node per
    layer, so ``E_x[T(Δ(x; Θ_l))]`` is a weighted mean over data keys.
    """
    data = design.data
    if design.n_layers == 0:
        return float(profile(data.size_bytes))
    outs = design.outlines()
    total = float(profile(outs[-1].size_bytes))          # root read: T(s(Θ_L))
    for layer in design.layers:                           # Σ_l E[T(Δ(x; Θ_l))]
        wq = layer.widths_at(data.keys)
        total += float(np.average(profile(wq), weights=data.weights))
    return total


def batched_mean_read_costs(widths, weights, profile: StorageProfile) -> np.ndarray:
    """Batched ``E_x[T(Δ)]`` for C candidates at once → (C,) float64.

    ``widths`` is a (C, S) matrix of per-query prediction widths (one row
    per candidate layer, all evaluated at the SAME S query keys);
    ``weights`` the (S,) query weights.  Row c is bit-identical to the
    scalar path ``float(np.average(profile(widths[c]), weights=weights))``:
    the profile applies elementwise and numpy's pairwise reduction over a
    contiguous last axis matches the 1-D reduction exactly (asserted by
    tests/test_sweep.py).  Profiles that are not elementwise-vectorized
    over 2-D input fall back to a per-row loop with the same semantics.
    """
    W = np.asarray(widths, dtype=np.float64)
    if W.ndim == 1:
        W = W[None, :]
    T = np.asarray(profile(W), dtype=np.float64)
    if T.shape != W.shape:          # profile not 2-D-vectorized: row loop
        return np.asarray(
            [float(np.average(np.asarray(profile(w), dtype=np.float64),
                              weights=weights)) for w in W])
    return np.average(T, axis=1, weights=np.asarray(weights,
                                                    dtype=np.float64))


def latency_breakdown(design: IndexDesign, profile: StorageProfile) -> dict:
    """Per-read costs: root + every layer's expected partial read (Eq. 5)."""
    data = design.data
    if design.n_layers == 0:
        t = float(profile(data.size_bytes))
        return {"root": t, "layers": [], "total": t}
    outs = design.outlines()
    root = float(profile(outs[-1].size_bytes))
    per_layer = []
    for layer in design.layers:
        wq = layer.widths_at(data.keys)
        per_layer.append(float(np.average(profile(wq), weights=data.weights)))
    # reads happen top-down: root, then partial reads of layers L−1 … 1, data
    return {"root": root, "layers": per_layer[::-1], "total": root + sum(per_layer)}


def mean_read_volume(design: IndexDesign) -> float:
    """Total expected bytes fetched per query: s(Θ_L) + Σ E[Δ_l] (Fig. 13b)."""
    data = design.data
    if design.n_layers == 0:
        return float(data.size_bytes)
    outs = design.outlines()
    vol = float(outs[-1].size_bytes)
    for layer in design.layers:
        wq = layer.widths_at(data.keys)
        vol += float(np.average(wq, weights=data.weights))
    return vol


def ideal_latency_with_index(profile: StorageProfile) -> float:
    """Cost if an *ideal* extra layer existed: 1-byte root + 1-byte precise
    read of the current level (paper §5.1 stopping criterion)."""
    return float(profile(1.0) + profile(1.0))


def mean_excess_per_lookup(design: IndexDesign, profile: StorageProfile) -> float:
    """Summed per-read upper-tail mass ``Σ E[(Tᵢ − μᵢ)₊]`` over a lookup.

    Mirrors :func:`expected_latency`'s read structure (root in full, one
    partial read per layer, or the whole collection with no index) with
    ``profile.mean_excess`` in place of the mean curve.  Zero for
    deterministic profiles.
    """
    data = design.data
    if design.n_layers == 0:
        return float(profile.mean_excess(data.size_bytes))
    outs = design.outlines()
    total = float(profile.mean_excess(outs[-1].size_bytes))
    for layer in design.layers:
        wq = layer.widths_at(data.keys)
        total += float(np.average(profile.mean_excess(wq),
                                  weights=data.weights))
    return total


def quantile_latency(design: IndexDesign, profile: StorageProfile,
                     p: float) -> float:
    """Estimated per-lookup ``p``-quantile ``Q̂_p[T]`` under ``profile``.

    Independent-pread approximation, documented in
    :class:`~repro.core.storage.ObjectiveProfile`: Markov's inequality on
    the summed positive excess bounds the quantile of a sum of pread
    times by ``Σ μᵢ + (Σ E[(Tᵢ − μᵢ)₊]) / (1 − p)`` — the single-big-jump
    estimate for the stall-dominated tails observed reservoirs exhibit.
    For deterministic profiles this collapses to the mean (Eq. 6).
    """
    if not 0.0 < float(p) < 1.0:
        raise ValueError(f"quantile p must be in (0, 1), got {p}")
    return (expected_latency(design, profile)
            + mean_excess_per_lookup(design, profile) / (1.0 - float(p)))


def objective_latency(design: IndexDesign, profile: StorageProfile,
                      objective) -> float:
    """The tuning objective's value for a built design.

    ``"mean"`` (or None) is Eq. 6 exactly; a ``{"p": q, "weight": w}``
    objective is ``E[T] + w·Q̂_p[T]`` with the quantile from
    :func:`quantile_latency`.  Equal to
    ``expected_latency(design, objective_profile(profile, objective))`` —
    the identity the strategies rely on to rank by the objective through
    the unchanged mean-latency search.
    """
    norm = normalize_objective(objective)
    if norm is None:
        return expected_latency(design, profile)
    return expected_latency(design, objective_profile(profile, objective))
