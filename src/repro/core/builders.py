"""Layer builders ``F(D) → Θ`` (paper §5.2, §A.1).

Three families, exactly as the paper deploys:

  * ``GStep(p, λ)``  — greedy step packing: start a new constant piece when
    ``y⁺_i − b_k > λ``; pack ``p`` pieces per node (≅ sparse B-tree bulk
    load with fanout ``p`` and page size ``λ``).
  * ``GBand(λ)``     — greedily extend a linear band while its width stays
    ``≤ λ`` (band through the group's first/last key-position points).
  * ``EBand(λ)``     — group pairs into equal-size position ranges and fit
    one band per group.

The candidate set ``F`` samples the granularity λ on an exponential grid
``λ_low·(1+ε)^j`` (Eq. 8).

Array-program adaptation (DESIGN.md §2): the paper's Rust builders are
single-pass loops.  Here GStep/EBand are *fully vectorized*: the greedy
grouping recurrence is solved exactly with a jump table + frontier-doubling
orbit extraction (O(n log G) numpy work, no per-group Python iteration).
GBand keeps the paper's greedy semantics with a galloping feasibility
search per emitted node (inner ops vectorized).  All builders assume
non-overlapping, sorted position ranges — true for data layers and all
outlines.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .keyset import KeyPositions, POS_DTYPE
from .nodes import BandLayer, Layer, StepLayer
from .registry import (BUILDER_FAMILIES, register_builder,
                       register_multi_lam_builder)

_DELTA_SAFETY = 1.0  # absorbs float64 rounding so Eq.(1) holds bit-exactly


# ---------------------------------------------------------------------------
# exact greedy partitioning, vectorized
# ---------------------------------------------------------------------------
def greedy_partition(lo: np.ndarray, hi: np.ndarray, lam: float,
                     switch: int = 8192) -> np.ndarray:
    """Greedy grouping of sorted ranges: group starting at ``s`` absorbs
    items while ``hi[i] − lo[s] ≤ λ``.  Returns group start indices
    (including 0), i.e. the exact greedy boundaries of paper §A.1 (1).

    Exact vectorization: ``jump[s] = first i with hi[i] > lo[s] + λ`` is a
    monotone map; the greedy boundaries are the orbit of 0 under ``jump``.
    We extract the orbit with frontier doubling — repeatedly appending
    ``jump^{2^k}`` applied to the known prefix — in O(log G) vectorized
    rounds instead of G sequential steps.

    ``switch`` is the scalar-walk → frontier-doubling crossover (in group
    count); it only affects speed, never the boundaries — tests shrink it
    to exercise the ``walk[:-1] + orbit`` seam on small inputs.
    """
    n = len(lo)
    if n == 0:
        return np.zeros(1, dtype=np.int64)
    lam = np.float64(lam)

    # fast path: walk boundary-to-boundary with per-point binary search.
    # O(G log n) — beats the O(n log n) jump-table when groups are few.
    # hi is converted to float64 once: searchsorted with a float probe
    # would otherwise re-convert the whole array per call.
    hi_f = hi if hi.dtype == np.float64 else hi.astype(np.float64)
    lo_f = lo if lo.dtype == np.float64 else lo.astype(np.float64)
    walk = [0]
    s = 0
    while len(walk) <= switch:
        nxt = int(np.searchsorted(hi_f, lo_f[s] + lam, side="right"))
        nxt = min(max(nxt, s + 1), n)
        if nxt >= n:
            return np.asarray(walk, dtype=np.int64)
        walk.append(nxt)
        s = nxt

    # many groups: build the full jump table and extract the remaining
    # orbit with frontier doubling (O(log G) vectorized rounds).  The
    # doubling invariant — after round k the orbit holds the first 2^k
    # elements and the table equals jump^(2^k) — requires seeding from a
    # single point: the boundary where the scalar walk stopped.
    targets = lo_f + lam
    jump = np.searchsorted(hi_f, targets, side="right").astype(np.int64)
    idx = np.arange(n, dtype=np.int64)
    jump = np.maximum(jump, idx + 1)          # ≥ one item per group
    jump = np.minimum(jump, n)
    jump = np.append(jump, n)                 # absorbing state
    orbit = np.asarray([s], dtype=np.int64)
    while orbit[-1] < n:
        nxt = jump[orbit]
        orbit = np.concatenate([orbit, nxt])
        if orbit[-1] >= n and np.all(nxt >= n):
            break
        jump = jump[jump]                     # square the jump map
    orbit = orbit[orbit < n]
    # saturated duplicates are dropped by unique; walk[:-1] precedes s
    return np.concatenate([np.asarray(walk[:-1], dtype=np.int64),
                           np.unique(orbit)])


def check_disjoint(D: KeyPositions) -> None:
    """Builder precondition: non-overlapping sorted position ranges.
    Public for out-of-module builder families (e.g. baselines.py)."""
    if D.n > 1:
        assert np.all(D.hi[:-1] <= D.lo[1:]), (
            "builders require non-overlapping position ranges")


_check_disjoint = check_disjoint


# ---------------------------------------------------------------------------
# GStep
# ---------------------------------------------------------------------------
def gstep_from_starts(D: KeyPositions, starts: np.ndarray, p: int) -> StepLayer:
    """Construct a step layer from precomputed greedy piece boundaries —
    the shared backend of :func:`build_gstep` and the multi-λ adapters
    (including the ``btree`` page-discipline family in baselines.py)."""
    piece_keys = D.keys[starts]
    piece_pos = np.empty(len(starts) + 1, dtype=POS_DTYPE)
    piece_pos[:-1] = D.lo[starts]
    piece_pos[-1] = D.hi[-1]
    P = len(starts)
    node_off = np.arange(0, P, p, dtype=np.int64)
    node_off = np.append(node_off, P)
    return StepLayer(piece_keys=piece_keys, piece_pos=piece_pos,
                     node_piece_off=node_off)


def build_gstep(D: KeyPositions, p: int, lam: float) -> StepLayer:
    """Greedy step builder (paper §A.1 (1)) — exact, fully vectorized."""
    check_disjoint(D)
    starts = greedy_partition(D.lo_f, D.hi_f, lam)      # piece start indices
    return gstep_from_starts(D, starts, p)


# ---------------------------------------------------------------------------
# band fitting helpers
# ---------------------------------------------------------------------------
def fit_bands_for_groups(D: KeyPositions, starts: np.ndarray) -> BandLayer:
    """Fit one band per group (line through first/last midpoints, width =
    max residual + safety).  Vectorized with segment reductions."""
    ends = np.append(starts[1:], D.n)
    first, last = starts, ends - 1
    mid = D.mid_f
    x1 = D.keys[first]
    y1 = mid[first]
    dx = D.keys_f[last] - D.keys_f[first]
    dy = mid[last] - mid[first]
    m = np.where(dx > 0, dy / np.maximum(dx, 1.0), 0.0)
    # broadcast group params to items, residuals, then segment max
    gid = np.repeat(np.arange(len(starts)), ends - starts)
    line = y1[gid] + m[gid] * (D.keys_f - x1[gid].astype(np.float64))
    resid = np.maximum(line - D.lo_f, D.hi_f - line)
    delta = np.maximum.reduceat(resid, starts) + _DELTA_SAFETY
    return BandLayer(
        node_keys=D.keys[first].astype(np.uint64),
        x1=D.keys[first].astype(np.uint64),
        y1=np.rint(y1).astype(POS_DTYPE),
        m=m,
        delta=delta + 1.0,  # covers the rint() on y1
        clamp_lo=int(D.lo[0]),
        clamp_hi=int(D.hi[-1]),
    )


# band-fitting is part of the public builder toolkit (used by the RMI
# baseline family in baselines.py); the underscore name survives as an
# alias for older call sites
_fit_bands_for_groups = fit_bands_for_groups


def _eband_starts(D: KeyPositions, lam: float) -> np.ndarray:
    lam = max(float(lam), 1.0)
    cell = ((D.lo_f - float(D.lo[0])) // lam).astype(np.int64)
    return np.flatnonzero(np.diff(cell, prepend=cell[0] - 1))


def build_eband(D: KeyPositions, lam: float) -> BandLayer:
    """Equal-position-range band builder (paper §A.1 (3)) — vectorized.

    Groups by the position grid ``⌊(y⁻ − y⁻_0)/λ⌋`` ("equal-size position
    ranges"); worst-case group extent ≤ λ + max record size.
    """
    check_disjoint(D)
    return fit_bands_for_groups(D, _eband_starts(D, lam))


def _gband_starts(D: KeyPositions, lam: float) -> np.ndarray:
    n = D.n
    keys_f = D.keys_f
    lo_f = D.lo_f
    hi_f = D.hi_f
    mid = D.mid_f
    half = 0.5 * float(lam)

    def feasible(s: int, e: int) -> bool:
        """Band through midpoints of s and e−1 has width 2δ ≤ λ?"""
        if e - s <= 1:
            return True
        dx = keys_f[e - 1] - keys_f[s]
        m = (mid[e - 1] - mid[s]) / dx if dx > 0 else 0.0
        line = mid[s] + m * (keys_f[s:e] - keys_f[s])
        resid = np.maximum(line - lo_f[s:e], hi_f[s:e] - line)
        return float(resid.max()) + _DELTA_SAFETY <= half

    starts = [0]
    s = 0
    guess = 64
    while True:
        # gallop to bracket the maximal feasible end
        step = max(guess, 2)
        e_ok = s + 1
        e = min(s + step, n)
        while e > e_ok and feasible(s, e):
            e_ok = e
            if e == n:
                break
            step *= 4
            e = min(s + step, n)
        # binary search in (e_ok, e)
        bad = e if e > e_ok else e_ok
        while bad - e_ok > 1:
            probe = (e_ok + bad) // 2
            if feasible(s, probe):
                e_ok = probe
            else:
                bad = probe
        guess = e_ok - s
        if e_ok >= n:
            break
        starts.append(e_ok)
        s = e_ok
    return np.asarray(starts, dtype=np.int64)


def build_gband(D: KeyPositions, lam: float) -> BandLayer:
    """Greedy band builder (paper §A.1 (2)): extend each group while the
    band width ``2δ`` stays ≤ λ.  Galloping + binary search per node with
    vectorized feasibility, seeded by the previous group's size.
    """
    check_disjoint(D)
    return fit_bands_for_groups(D, _gband_starts(D, lam))


# ---------------------------------------------------------------------------
# builder objects + the Eq.(8) grid
# ---------------------------------------------------------------------------
# The built-in families, registered so the Alg. 2 search resolves them (and
# any third-party family registered via repro.api) through one mechanism.
@register_builder("gstep")
def _gstep_family(D: KeyPositions, lam: float, p: int) -> Layer:
    return build_gstep(D, int(p), lam)


@register_builder("gband")
def _gband_family(D: KeyPositions, lam: float, p: int) -> Layer:
    return build_gband(D, lam)


@register_builder("eband")
def _eband_family(D: KeyPositions, lam: float, p: int) -> Layer:
    return build_eband(D, lam)


# ---------------------------------------------------------------------------
# fused multi-λ entry points (the sweep engine's fast path, §Eq. 8)
# ---------------------------------------------------------------------------
# One call builds a family's whole λ-column for a vertex.  Shared work:
# the float64 views (lo_f/hi_f/keys_f/mid_f) convert once per collection
# (cached on D), and λ values resolving to the SAME partition — common on
# small outline collections where the grid saturates — share one layer
# object, so band fitting / step construction run once per unique
# boundary set.  NOTE greedy boundaries are *not* nested across λ (a
# coarse boundary need not survive at a finer λ), so every λ's boundaries
# are still computed exactly; only construction downstream of identical
# boundaries is deduplicated.  Each element is bit-identical to the
# single-λ build at that λ.
def _dedup_by_starts(D: KeyPositions, lams, starts_fn, construct):
    layers, by_starts = [], {}
    for lam in lams:
        starts = starts_fn(D, lam)
        key = starts.tobytes()
        layer = by_starts.get(key)
        if layer is None:
            layer = construct(starts)
            by_starts[key] = layer
        layers.append(layer)
    return layers


@register_multi_lam_builder("gstep")
def build_gstep_multi(D: KeyPositions, lams, p: int) -> list:
    check_disjoint(D)
    lo_f, hi_f = D.lo_f, D.hi_f       # one float64 conversion for all λ
    return _dedup_by_starts(
        D, lams, lambda d, lam: greedy_partition(lo_f, hi_f, lam),
        lambda starts: gstep_from_starts(D, starts, int(p)))


@register_multi_lam_builder("gband")
def build_gband_multi(D: KeyPositions, lams, p: int) -> list:
    check_disjoint(D)
    return _dedup_by_starts(D, lams, _gband_starts,
                            lambda starts: fit_bands_for_groups(D, starts))


@register_multi_lam_builder("eband")
def build_eband_multi(D: KeyPositions, lams, p: int) -> list:
    check_disjoint(D)
    return _dedup_by_starts(D, lams, _eband_starts,
                            lambda starts: fit_bands_for_groups(D, starts))


DEFAULT_FAMILIES = ("gstep", "gband", "eband")   # the paper's deployed set


@dataclasses.dataclass(frozen=True)
class LayerBuilder:
    """A node builder F ∈ 𝓕 mapping a key-position collection to a layer.

    ``kind`` names a family in :data:`repro.core.registry.BUILDER_FAMILIES`;
    resolution happens per call, so families registered after construction
    (e.g. from test or plugin code) are picked up live.
    """

    kind: str          # a registered family name ('gstep' | 'gband' | …)
    lam: float
    p: int = 16        # pieces per node (gstep only)

    @property
    def name(self) -> str:
        if self.kind == "gstep":
            return f"GStep({self.p},{int(self.lam)})"
        if self.kind in ("gband", "eband"):
            return f"{'GBand' if self.kind == 'gband' else 'EBand'}({int(self.lam)})"
        return f"{self.kind}({int(self.lam)})"

    def __call__(self, D: KeyPositions) -> Layer:
        return BUILDER_FAMILIES.get(self.kind)(D, self.lam, self.p)


def make_builders(lam_low: float = 2**8, lam_high: float = 2**20,
                  base: float = 2.0, p: int = 16,
                  kinds=DEFAULT_FAMILIES) -> list[LayerBuilder]:
    """Granularity exponentiation (Eq. 8): λ_low, λ_low·(1+ε), …, λ_high.

    ``kinds`` are family names resolved through the builder registry;
    unknown names raise ``KeyError`` listing what is registered.
    """
    if not base > 1.0:       # a real raise: base <= 1 never terminates
        raise ValueError(f"grid base must be > 1, got {base}")
    if kinds is None:
        kinds = DEFAULT_FAMILIES
    for k in kinds:
        BUILDER_FAMILIES.get(k)        # fail fast on unknown families
    lams = []
    lam = float(lam_low)
    while lam <= lam_high * (1 + 1e-9):
        lams.append(lam)
        lam *= base
    return [LayerBuilder(kind=k, lam=l, p=p) for k in kinds for l in lams]


# ---------------------------------------------------------------------------
# data-partitioned building (paper §5.4 "From Data Partitioning")
# ---------------------------------------------------------------------------
def merge_layers(parts: list[Layer]) -> Layer:
    """Merge per-partition layers into one (piecewise functions concatenate)."""
    assert parts
    if isinstance(parts[0], StepLayer):
        piece_keys = np.concatenate([q.piece_keys for q in parts])
        piece_pos = np.concatenate(
            [q.piece_pos[:-1] for q in parts] + [parts[-1].piece_pos[-1:]])
        offs = [parts[0].node_piece_off]
        acc = parts[0].n_pieces
        for q in parts[1:]:
            offs.append(q.node_piece_off[1:] + acc)
            acc += q.n_pieces
        return StepLayer(piece_keys=piece_keys, piece_pos=piece_pos,
                         node_piece_off=np.concatenate(offs))
    return BandLayer(
        node_keys=np.concatenate([q.node_keys for q in parts]),
        x1=np.concatenate([q.x1 for q in parts]),
        y1=np.concatenate([q.y1 for q in parts]),
        m=np.concatenate([q.m for q in parts]),
        delta=np.concatenate([q.delta for q in parts]),
        clamp_lo=min(q.clamp_lo for q in parts),
        clamp_hi=max(q.clamp_hi for q in parts),
    )


def build_partitioned(builder: LayerBuilder, D: KeyPositions,
                      partition_pairs: int = 1_000_000) -> Layer:
    """Build per 1M-pair partition and merge (paper's default partitioning).

    On a real cluster each partition builds on a different host/shard over
    the ``data`` mesh axis; here partitions run sequentially.
    """
    if D.n <= partition_pairs:
        return builder(D)
    parts = []
    for s in range(0, D.n, partition_pairs):
        parts.append(builder(D.slice(s, min(s + partition_pairs, D.n))))
    return merge_layers(parts)
