"""Storage performance profiles ``T(Δ)`` (paper §3.2).

``T(Δ)`` is the expected time to read ``Δ`` consecutive bytes from a storage
tier.  The paper implements the affine profile ``T_aff(Δ) = ℓ + Δ/B`` and
notes that the optimization works with *any* monotonically increasing
``T``.  We provide:

  * :class:`AffineProfile`        — ``ℓ + Δ/B`` (paper default),
  * :class:`AffineUniformProfile` — expectation under uniformly varying
    latency/bandwidth (paper §3.2 closed form),
  * :class:`MeasuredProfile`      — monotone piecewise-linear interpolation
    of real measurements, plus a helper that actually measures the local
    filesystem of this machine,
  * named profiles for the tiers a multi-pod TPU training stack talks to
    (object store / NFS / SSD / host DRAM / HBM / VMEM / ICI / DCN).

Hardware adaptation (DESIGN.md §2): the paper profiles NFS/SSD/HDD; on a
TPU system the same abstraction spans ~6 orders of magnitude down to HBM
and VMEM, and AirIndex tunes index structure per tier unchanged.
"""
from __future__ import annotations

import dataclasses
import os
import time

import numpy as np


class StorageProfile:
    """Monotone non-decreasing expected read time ``T(Δ)`` in seconds."""

    name: str = "abstract"

    def read_time(self, delta):
        """Vectorized ``T(Δ)``; ``delta`` in bytes (scalar or ndarray)."""
        raise NotImplementedError

    def __call__(self, delta):
        return self.read_time(delta)


@dataclasses.dataclass(frozen=True)
class AffineProfile(StorageProfile):
    """``T(Δ) = ℓ + Δ / B`` with latency ``ℓ`` [s] and bandwidth ``B`` [B/s]."""

    latency: float
    bandwidth: float
    name: str = "affine"

    def read_time(self, delta):
        return self.latency + np.asarray(delta, dtype=np.float64) / self.bandwidth


@dataclasses.dataclass(frozen=True)
class AffineUniformProfile(StorageProfile):
    """Affine profile with uniformly varying ``ℓ ∈ [ℓ0, ℓ1]``, ``B ∈ [B0, B1]``.

    Paper §3.2: ``T(Δ) = (ℓ0+ℓ1)/2 + Δ (ln B1 − ln B0)/(B1 − B0)``.
    """

    latency_lo: float
    latency_hi: float
    bandwidth_lo: float
    bandwidth_hi: float
    name: str = "affine-uniform"

    def coefficients(self) -> tuple[float, float]:
        """The closed-form ``(ℓ, 1/B)`` this profile is affine with —
        single source of truth for read_time and affine_coefficients."""
        ell = 0.5 * (self.latency_lo + self.latency_hi)
        if self.bandwidth_hi == self.bandwidth_lo:
            inv_bw = 1.0 / self.bandwidth_lo
        else:
            inv_bw = (np.log(self.bandwidth_hi) - np.log(self.bandwidth_lo)) / (
                self.bandwidth_hi - self.bandwidth_lo)
        return float(ell), float(inv_bw)

    def read_time(self, delta):
        ell, inv_bw = self.coefficients()
        return ell + np.asarray(delta, dtype=np.float64) * inv_bw


@dataclasses.dataclass(frozen=True)
class MeasuredProfile(StorageProfile):
    """Monotone piecewise-linear ``T(Δ)`` through measured (Δ, seconds) points."""

    deltas: tuple          # increasing byte sizes
    seconds: tuple         # measured expected read times
    name: str = "measured"

    def read_time(self, delta):
        d = np.asarray(delta, dtype=np.float64)
        xs = np.asarray(self.deltas, dtype=np.float64)
        ys = np.maximum.accumulate(np.asarray(self.seconds, dtype=np.float64))
        # extrapolate the last segment's slope beyond the measured range
        out = np.interp(d, xs, ys)
        slope = (ys[-1] - ys[-2]) / max(xs[-1] - xs[-2], 1.0) if len(xs) > 1 else 0.0
        out = np.where(d > xs[-1], ys[-1] + (d - xs[-1]) * slope, out)
        return out

    def fit_affine(self) -> AffineProfile:
        """Least-squares affine fit — useful to report ℓ and B of a tier."""
        xs = np.asarray(self.deltas, dtype=np.float64)
        ys = np.asarray(self.seconds, dtype=np.float64)
        A = np.stack([np.ones_like(xs), xs], axis=1)
        (ell, inv_bw), *_ = np.linalg.lstsq(A, ys, rcond=None)
        ell = max(float(ell), 1e-12)
        bw = 1.0 / max(float(inv_bw), 1e-18)
        return AffineProfile(latency=ell, bandwidth=bw, name=f"{self.name}-affine")


#: CachedProfile's default cache tier (host-DRAM constants; also the
#: basis of PROFILES["host_dram"] below)
_DEFAULT_CACHE = AffineProfile(150e-9, 50e9, name="host_dram")


@dataclasses.dataclass(frozen=True)
class CachedProfile(StorageProfile):
    """``T(Δ)`` seen *through* a block cache in front of a backing tier.

    A fraction ``hit_rate`` of reads is served by the cache tier (DRAM by
    default), the rest by the backing tier:

        ``T(Δ) = h · T_cache(Δ) + (1 − h) · T_backing(Δ)``

    Monotone whenever both component profiles are, so AirTune can tune an
    index *for* a cached deployment unchanged — with a hot cache the
    effective tier is fat-and-fast and the optimum shifts toward fewer,
    larger layers (paper Fig. 1 intuition).  The serving engine's observed
    hit rate (``IndexService.cached_profile``) closes the loop: serve →
    measure → re-tune.
    """

    backing: StorageProfile
    cache: StorageProfile | None = None   # default: host-DRAM constants
    hit_rate: float = 0.0
    name: str = "cached"

    def read_time(self, delta):
        h = min(max(float(self.hit_rate), 0.0), 1.0)
        cache = self.cache or _DEFAULT_CACHE
        return (h * np.asarray(cache(delta), dtype=np.float64)
                + (1.0 - h) * np.asarray(self.backing(delta), dtype=np.float64))


def profile_local_storage(path: str, *, sizes=None, repeats: int = 5,
                          file_bytes: int = 1 << 26, rng=None) -> MeasuredProfile:
    """Measure ``T(Δ)`` of the filesystem hosting ``path`` (paper §3.2).

    Writes a scratch file once, then times ``pread``s of each size at random
    offsets.  Page-cache effects make this a *warm* profile on this
    container; it is still monotone and exercises the real syscall path.
    """
    if sizes is None:
        sizes = [1 << s for s in range(8, 23, 2)]  # 256 B .. 4 MiB
    rng = rng or np.random.default_rng(0)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    if not os.path.exists(path) or os.path.getsize(path) < file_bytes:
        with open(path, "wb") as f:
            f.write(os.urandom(min(file_bytes, 1 << 26)))
    fd = os.open(path, os.O_RDONLY)
    try:
        actual = os.path.getsize(path)
        meas = []
        for sz in sizes:
            ts = []
            for _ in range(repeats):
                off = int(rng.integers(0, max(actual - sz, 1)))
                t0 = time.perf_counter()
                os.pread(fd, sz, off)
                ts.append(time.perf_counter() - t0)
            meas.append(float(np.median(ts)))
        return MeasuredProfile(deltas=tuple(sizes), seconds=tuple(meas), name="local-fs")
    finally:
        os.close(fd)


def affine_coefficients(profile: StorageProfile) -> tuple[float, float] | None:
    """``(ℓ, 1/B)`` if ``T(Δ) = ℓ + Δ·(1/B)`` holds exactly, else None.

    The device-side batched candidate scorers
    (:mod:`repro.kernels.candidate_score`) evaluate only affine-
    representable tiers in closed form; any other profile takes the numpy
    path.  ``AffineUniformProfile`` and ``CachedProfile`` over affine
    components are affine in Δ and are folded here.
    """
    if isinstance(profile, AffineProfile):
        return float(profile.latency), 1.0 / float(profile.bandwidth)
    if isinstance(profile, AffineUniformProfile):
        return profile.coefficients()
    if isinstance(profile, CachedProfile):
        cache = profile.cache or _DEFAULT_CACHE
        back = affine_coefficients(profile.backing)
        front = affine_coefficients(cache)
        if back is None or front is None:
            return None
        h = min(max(float(profile.hit_rate), 0.0), 1.0)
        return (h * front[0] + (1.0 - h) * back[0],
                h * front[1] + (1.0 - h) * back[1])
    return None


# ---------------------------------------------------------------------------
# JSON round-trip for profiles (facade provenance: an index file records
# the T(Δ) it was tuned for, so Index.open can restore measured/custom
# tiers — not just named constants).  Unknown profile types degrade to
# None rather than failing the save/open.
# ---------------------------------------------------------------------------
def profile_to_dict(profile: StorageProfile | None) -> dict | None:
    if isinstance(profile, AffineProfile):
        return {"kind": "affine", "latency": profile.latency,
                "bandwidth": profile.bandwidth, "name": profile.name}
    if isinstance(profile, AffineUniformProfile):
        return {"kind": "affine_uniform",
                "latency_lo": profile.latency_lo,
                "latency_hi": profile.latency_hi,
                "bandwidth_lo": profile.bandwidth_lo,
                "bandwidth_hi": profile.bandwidth_hi, "name": profile.name}
    if isinstance(profile, MeasuredProfile):
        return {"kind": "measured", "deltas": list(profile.deltas),
                "seconds": list(profile.seconds), "name": profile.name}
    if isinstance(profile, CachedProfile):
        backing = profile_to_dict(profile.backing)
        if backing is None:
            return None
        return {"kind": "cached", "backing": backing,
                "cache": profile_to_dict(profile.cache),
                "hit_rate": profile.hit_rate, "name": profile.name}
    return None


def profile_from_dict(d: dict | None) -> StorageProfile | None:
    if not isinstance(d, dict):
        return None
    try:
        kind = d["kind"]
        if kind == "affine":
            return AffineProfile(d["latency"], d["bandwidth"],
                                 name=d.get("name", "affine"))
        if kind == "affine_uniform":
            return AffineUniformProfile(
                d["latency_lo"], d["latency_hi"],
                d["bandwidth_lo"], d["bandwidth_hi"],
                name=d.get("name", "affine-uniform"))
        if kind == "measured":
            return MeasuredProfile(tuple(d["deltas"]), tuple(d["seconds"]),
                                   name=d.get("name", "measured"))
        if kind == "cached":
            backing = profile_from_dict(d["backing"])
            if backing is None:
                return None
            return CachedProfile(backing=backing,
                                 cache=profile_from_dict(d.get("cache")),
                                 hit_rate=d.get("hit_rate", 0.0),
                                 name=d.get("name", "cached"))
    except (KeyError, TypeError, ValueError):
        return None
    return None


# ---------------------------------------------------------------------------
# Named profiles.
#   Paper §2.1 example tiers + paper §7.1 Azure tiers + TPU-system tiers
#   (the hardware adaptation: same T(Δ) abstraction, constants per tier).
# ---------------------------------------------------------------------------
PROFILES = {
    # paper §2.1 worked example
    "ssd_ex":    AffineProfile(100e-6, 1e9,    name="ssd_ex"),     # 100 µs, 1 GB/s
    "cloud_ex":  AffineProfile(100e-3, 100e6,  name="cloud_ex"),   # 100 ms, 100 MB/s
    # paper §7 experimental tiers (Fig. 3 / Fig. 14 constants)
    "azure_ssd": AffineProfile(250e-6, 175e6,  name="azure_ssd"),  # 250 µs, 175 MB/s
    "azure_nfs": AffineProfile(50e-3,  12e6,   name="azure_nfs"),  # 50 ms, 12 MB/s
    "azure_hdd": AffineProfile(2e-3,   60e6,   name="azure_hdd"),  # 500 IOPS, 60 MB/s
    # TPU-system tiers (targets of the adaptation; v5e-class constants)
    "object_store": AffineProfile(80e-3, 250e6, name="object_store"),
    "host_dram":    _DEFAULT_CACHE,
    "hbm":          AffineProfile(1e-6,  819e9, name="hbm"),       # v5e HBM
    "vmem":         AffineProfile(30e-9, 10e12, name="vmem"),
    "ici":          AffineProfile(1e-6,  50e9,  name="ici"),       # per-link
    "dcn":          AffineProfile(20e-6, 12.5e9, name="dcn"),
}
