"""Storage performance profiles ``T(Δ)`` (paper §3.2).

``T(Δ)`` is the expected time to read ``Δ`` consecutive bytes from a storage
tier.  The paper implements the affine profile ``T_aff(Δ) = ℓ + Δ/B`` and
notes that the optimization works with *any* monotonically increasing
``T``.  We provide:

  * :class:`AffineProfile`        — ``ℓ + Δ/B`` (paper default),
  * :class:`AffineUniformProfile` — expectation under uniformly varying
    latency/bandwidth (paper §3.2 closed form),
  * :class:`MeasuredProfile`      — monotone piecewise-linear interpolation
    of real measurements, plus a helper that actually measures the local
    filesystem of this machine,
  * :class:`DistributionalProfile` — per-Δ latency *distributions* (mean,
    mean-excess, empirical quantiles) fitted from the ServeStats pread
    reservoir, the raw material of tail-latency tuning,
  * :class:`ObjectiveProfile`     — a synthetic per-read cost curve that
    folds the ``E[T] + w·Q_p[T]`` objective into an additive ``C(Δ)`` so
    every mean-latency search ranks designs by the tail objective
    unchanged (see the class docstring for the bound),
  * named profiles for the tiers a multi-pod TPU training stack talks to
    (object store / NFS / SSD / host DRAM / HBM / VMEM / ICI / DCN).

Hardware adaptation (DESIGN.md §2): the paper profiles NFS/SSD/HDD; on a
TPU system the same abstraction spans ~6 orders of magnitude down to HBM
and VMEM, and AirIndex tunes index structure per tier unchanged.
"""
from __future__ import annotations

import dataclasses
import os
import time
import warnings

import numpy as np


class StorageProfile:
    """Monotone non-decreasing expected read time ``T(Δ)`` in seconds."""

    name: str = "abstract"

    def read_time(self, delta):
        """Vectorized ``T(Δ)``; ``delta`` in bytes (scalar or ndarray)."""
        raise NotImplementedError

    def mean_excess(self, delta):
        """Per-read upper-tail mass ``E[(T(Δ) − E[T(Δ)])₊]`` in seconds.

        Zero for deterministic profiles (affine/measured constants model
        the *expected* time only); :class:`DistributionalProfile`
        overrides this with the fitted empirical excess.  This is the
        quantity the quantile objective propagates through a layer stack
        (see :class:`ObjectiveProfile`).
        """
        return np.asarray(delta, dtype=np.float64) * 0.0

    def __call__(self, delta):
        return self.read_time(delta)


@dataclasses.dataclass(frozen=True)
class AffineProfile(StorageProfile):
    """``T(Δ) = ℓ + Δ / B`` with latency ``ℓ`` [s] and bandwidth ``B`` [B/s]."""

    latency: float
    bandwidth: float
    name: str = "affine"

    def read_time(self, delta):
        return self.latency + np.asarray(delta, dtype=np.float64) / self.bandwidth


@dataclasses.dataclass(frozen=True)
class AffineUniformProfile(StorageProfile):
    """Affine profile with uniformly varying ``ℓ ∈ [ℓ0, ℓ1]``, ``B ∈ [B0, B1]``.

    Paper §3.2: ``T(Δ) = (ℓ0+ℓ1)/2 + Δ (ln B1 − ln B0)/(B1 − B0)``.
    """

    latency_lo: float
    latency_hi: float
    bandwidth_lo: float
    bandwidth_hi: float
    name: str = "affine-uniform"

    def coefficients(self) -> tuple[float, float]:
        """The closed-form ``(ℓ, 1/B)`` this profile is affine with —
        single source of truth for read_time and affine_coefficients."""
        ell = 0.5 * (self.latency_lo + self.latency_hi)
        if self.bandwidth_hi == self.bandwidth_lo:
            inv_bw = 1.0 / self.bandwidth_lo
        else:
            inv_bw = (np.log(self.bandwidth_hi) - np.log(self.bandwidth_lo)) / (
                self.bandwidth_hi - self.bandwidth_lo)
        return float(ell), float(inv_bw)

    def read_time(self, delta):
        ell, inv_bw = self.coefficients()
        return ell + np.asarray(delta, dtype=np.float64) * inv_bw


@dataclasses.dataclass(frozen=True)
class MeasuredProfile(StorageProfile):
    """Monotone piecewise-linear ``T(Δ)`` through measured (Δ, seconds) points."""

    deltas: tuple          # increasing byte sizes
    seconds: tuple         # measured expected read times
    name: str = "measured"

    def read_time(self, delta):
        d = np.asarray(delta, dtype=np.float64)
        xs = np.asarray(self.deltas, dtype=np.float64)
        ys = np.maximum.accumulate(np.asarray(self.seconds, dtype=np.float64))
        # extrapolate the last segment's slope beyond the measured range
        out = np.interp(d, xs, ys)
        slope = (ys[-1] - ys[-2]) / max(xs[-1] - xs[-2], 1.0) if len(xs) > 1 else 0.0
        out = np.where(d > xs[-1], ys[-1] + (d - xs[-1]) * slope, out)
        return out

    def fit_affine(self) -> AffineProfile:
        """Least-squares affine fit — useful to report ℓ and B of a tier.

        Degenerate measurements — fewer than 2 distinct Δ values (the
        normal equations are singular; lstsq's minimum-norm solution
        splits the constant arbitrarily between ℓ and the slope) or
        all-equal seconds (slope 0, or slightly negative from fp noise)
        — used to yield negative/NaN predicted latencies that poison
        batched candidate scoring.  Both shapes now degrade to a
        *constant* profile at the mean measured seconds, with a warning;
        a genuinely negative fitted slope is clamped the same way.
        """
        xs = np.asarray(self.deltas, dtype=np.float64)
        ys = np.asarray(self.seconds, dtype=np.float64)
        constant = AffineProfile(latency=max(float(np.mean(ys)), 1e-12),
                                 bandwidth=1e30,  # finite so JSON round-trips
                                 name=f"{self.name}-affine")
        if len(np.unique(xs)) < 2 or np.allclose(ys, ys[0]):
            warnings.warn(
                f"fit_affine({self.name}): degenerate measurements "
                "(<2 distinct sizes or constant seconds); using a "
                "constant profile", RuntimeWarning, stacklevel=2)
            return constant
        A = np.stack([np.ones_like(xs), xs], axis=1)
        (ell, inv_bw), *_ = np.linalg.lstsq(A, ys, rcond=None)
        ell, inv_bw = float(ell), float(inv_bw)
        if not (np.isfinite(ell) and np.isfinite(inv_bw)) or inv_bw <= 0.0:
            warnings.warn(
                f"fit_affine({self.name}): non-finite or non-positive "
                f"slope ({inv_bw!r}); using a constant profile",
                RuntimeWarning, stacklevel=2)
            return constant
        ell = max(ell, 1e-12)
        bw = 1.0 / inv_bw
        return AffineProfile(latency=ell, bandwidth=bw, name=f"{self.name}-affine")


@dataclasses.dataclass(frozen=True)
class DistributionalProfile(StorageProfile):
    """Per-Δ latency *distributions* fitted from observed preads.

    Beyond the monotone mean curve of :class:`MeasuredProfile`, each
    measured size carries the empirical upper-tail mass
    ``me(Δ) = E[(T − E[T])₊]`` and a grid of empirical quantiles.  The
    mean and mean-excess curves are what the quantile tuning objective
    consumes (:class:`ObjectiveProfile`); the quantile grid is for
    reporting (``quantile_time``).

    Both curves are made monotone in Δ by a running max — conservative
    when a larger read happens to be better-behaved than a smaller one,
    but required by the search's monotone-``T`` assumption.  Beyond the
    measured range the mean extrapolates the last segment's slope
    (bandwidth keeps costing) while the excess holds flat (a stall does
    not grow with the read size it interrupted).
    """

    deltas: tuple          # increasing byte sizes
    means: tuple           # per-Δ mean seconds
    excess: tuple          # per-Δ E[(T − mean)₊] seconds
    qs: tuple = ()         # quantile grid in (0, 1], increasing
    qvalues: tuple = ()    # per-Δ tuple of quantile seconds, len == len(qs)
    name: str = "distributional"

    def _curve(self, delta, raw, *, extrapolate_slope):
        d = np.asarray(delta, dtype=np.float64)
        xs = np.asarray(self.deltas, dtype=np.float64)
        ys = np.maximum.accumulate(np.asarray(raw, dtype=np.float64))
        out = np.interp(d, xs, ys)
        if extrapolate_slope and len(xs) > 1:
            slope = (ys[-1] - ys[-2]) / max(xs[-1] - xs[-2], 1.0)
            out = np.where(d > xs[-1], ys[-1] + (d - xs[-1]) * slope, out)
        return out

    def read_time(self, delta):
        return self._curve(delta, self.means, extrapolate_slope=True)

    def mean_excess(self, delta):
        return np.maximum(
            self._curve(delta, self.excess, extrapolate_slope=False), 0.0)

    def quantile_time(self, delta, p):
        """Empirical per-read ``p``-quantile of ``T(Δ)`` (reporting only —
        the tuning objective propagates ``mean_excess``, not this)."""
        if not self.qs:
            return self.read_time(delta)
        qs = np.asarray(self.qs, dtype=np.float64)
        rows = np.asarray(self.qvalues, dtype=np.float64)  # (n_deltas, n_qs)
        p = min(max(float(p), float(qs[0])), float(qs[-1]))
        per_delta = np.array([np.interp(p, qs, row) for row in rows])
        return self._curve(delta, per_delta, extrapolate_slope=True)

    @classmethod
    def fit(cls, samples, *, min_samples: int = 32, min_sizes: int = 2,
            qs=(0.5, 0.9, 0.95, 0.99),
            name: str = "distributional") -> "DistributionalProfile | None":
        """Fit from ``(Δ, seconds)`` pairs; ``None`` when too scarce.

        Requires ``min_samples`` total observations over at least
        ``min_sizes`` distinct sizes — the same contract as the measured
        mean fit, so a scarce reservoir degrades to "no observed
        profile" rather than a one-point distribution.
        """
        pairs = [(float(d), float(s)) for d, s in samples]
        if len(pairs) < min_samples:
            return None
        arr = np.asarray(pairs, dtype=np.float64)
        uniq = np.unique(arr[:, 0])
        if len(uniq) < min_sizes:
            return None
        means, excess, qvals = [], [], []
        for d in uniq:
            ts = arr[arr[:, 0] == d, 1]
            mu = float(ts.mean())
            means.append(mu)
            excess.append(float(np.maximum(ts - mu, 0.0).mean()))
            qvals.append(tuple(float(np.quantile(ts, q)) for q in qs))
        return cls(deltas=tuple(float(d) for d in uniq), means=tuple(means),
                   excess=tuple(excess), qs=tuple(float(q) for q in qs),
                   qvalues=tuple(qvals), name=name)


@dataclasses.dataclass(frozen=True)
class ObjectiveProfile(StorageProfile):
    """Per-read cost curve of the tail objective ``E[T] + w·Q_p[T]``.

    A lookup's latency is a sum of pread times, ``T = Σ Tᵢ``.  Writing
    ``μᵢ = E[Tᵢ]``, Markov's inequality on the summed positive excess
    gives, for any dependence structure,

        ``Q_p[T] ≤ Σ μᵢ + (Σ E[(Tᵢ − μᵢ)₊]) / (1 − p)``

    and under the documented *independent-pread approximation* this is
    the single-big-jump estimate of the tail (tight for the
    subexponential stall-dominated distributions the fault layer
    produces: a bad lookup is one stalled pread, and stall probability
    accumulates linearly across the stack).  The objective therefore
    decomposes into an additive per-read cost

        ``C(Δ) = (1 + w)·μ(Δ) + (w / (1 − p))·me(Δ)``

    which is exactly this profile's ``read_time``.  Every mean-latency
    search (Eq. 6's additive recursion, the fused sweep's batched
    scoring, ``tau_hat``'s ranking) ranks designs by the tail objective
    simply by receiving this profile instead of the base one.  With a
    deterministic base (``me ≡ 0``) the curve is ``(1 + w)·μ`` — same
    argmin as the mean objective, cost scaled by exactly ``1 + w``.
    """

    base: StorageProfile
    p: float
    weight: float
    name: str = "objective"

    def read_time(self, delta):
        mu = np.asarray(self.base.read_time(delta), dtype=np.float64)
        me = np.asarray(self.base.mean_excess(delta), dtype=np.float64)
        return (1.0 + self.weight) * mu + (self.weight / (1.0 - self.p)) * me

    def mean_excess(self, delta):
        # the synthetic curve is itself a deterministic cost model
        return np.asarray(delta, dtype=np.float64) * 0.0


def normalize_objective(objective) -> tuple[float, float] | None:
    """``None`` for the mean objective, else a validated ``(p, weight)``.

    Accepts ``None`` / ``"mean"`` / ``{"p": q, "weight": w}`` (weight
    defaults to 1.0; ``weight == 0`` *is* the mean objective).  Raises
    ``ValueError`` on anything else — objectives are user-facing spec
    fields and silent fallback would tune for the wrong thing.
    """
    if objective is None or objective == "mean":
        return None
    if isinstance(objective, dict):
        extra = set(objective) - {"p", "weight"}
        if extra:
            raise ValueError(f"objective: unknown keys {sorted(extra)}")
        try:
            p = float(objective["p"])
            w = float(objective.get("weight", 1.0))
        except (KeyError, TypeError, ValueError) as e:
            raise ValueError(f"objective: need numeric 'p' (got {objective!r})") from e
        if not 0.0 < p < 1.0:
            raise ValueError(f"objective: p must be in (0, 1), got {p}")
        if not w >= 0.0:
            raise ValueError(f"objective: weight must be >= 0, got {w}")
        return None if w == 0.0 else (p, w)
    raise ValueError(f"objective must be 'mean' or a {{p, weight}} dict, "
                     f"got {objective!r}")


def objective_profile(profile: StorageProfile, objective) -> StorageProfile:
    """Wrap ``profile`` for the requested objective.

    The mean objective returns ``profile`` itself (same object — the
    guarantee behind ``objective="mean"`` being bit-identical to the
    pre-objective search); a quantile objective returns the
    :class:`ObjectiveProfile` cost curve over it.
    """
    norm = normalize_objective(objective)
    if norm is None:
        return profile
    p, w = norm
    return ObjectiveProfile(base=profile, p=p, weight=w,
                            name=f"{profile.name}|p{p:g}w{w:g}")


#: CachedProfile's default cache tier (host-DRAM constants; also the
#: basis of PROFILES["host_dram"] below)
_DEFAULT_CACHE = AffineProfile(150e-9, 50e9, name="host_dram")


@dataclasses.dataclass(frozen=True)
class CachedProfile(StorageProfile):
    """``T(Δ)`` seen *through* a block cache in front of a backing tier.

    A fraction ``hit_rate`` of reads is served by the cache tier (DRAM by
    default), the rest by the backing tier:

        ``T(Δ) = h · T_cache(Δ) + (1 − h) · T_backing(Δ)``

    Monotone whenever both component profiles are, so AirTune can tune an
    index *for* a cached deployment unchanged — with a hot cache the
    effective tier is fat-and-fast and the optimum shifts toward fewer,
    larger layers (paper Fig. 1 intuition).  The serving engine's observed
    hit rate (``IndexService.cached_profile``) closes the loop: serve →
    measure → re-tune.
    """

    backing: StorageProfile
    cache: StorageProfile | None = None   # default: host-DRAM constants
    hit_rate: float = 0.0
    name: str = "cached"

    def read_time(self, delta):
        h = min(max(float(self.hit_rate), 0.0), 1.0)
        cache = self.cache or _DEFAULT_CACHE
        return (h * np.asarray(cache(delta), dtype=np.float64)
                + (1.0 - h) * np.asarray(self.backing(delta), dtype=np.float64))

    def mean_excess(self, delta):
        # hit-rate blend of the component tails, mirroring read_time
        h = min(max(float(self.hit_rate), 0.0), 1.0)
        cache = self.cache or _DEFAULT_CACHE
        return (h * np.asarray(cache.mean_excess(delta), dtype=np.float64)
                + (1.0 - h) * np.asarray(self.backing.mean_excess(delta),
                                         dtype=np.float64))


def profile_local_storage(path: str, *, sizes=None, repeats: int = 5,
                          file_bytes: int = 1 << 26, rng=None) -> MeasuredProfile:
    """Measure ``T(Δ)`` of the filesystem hosting ``path`` (paper §3.2).

    Writes a scratch file once, then times ``pread``s of each size at random
    offsets.  Page-cache effects make this a *warm* profile on this
    container; it is still monotone and exercises the real syscall path.
    """
    if sizes is None:
        sizes = [1 << s for s in range(8, 23, 2)]  # 256 B .. 4 MiB
    rng = rng or np.random.default_rng(0)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    if not os.path.exists(path) or os.path.getsize(path) < file_bytes:
        with open(path, "wb") as f:
            f.write(os.urandom(min(file_bytes, 1 << 26)))
    # airlint: allow[pread-seam] -- §3.2 probe: measures the raw syscall
    # path on purpose; wrapping it in a backend would time the wrapper
    fd = os.open(path, os.O_RDONLY)
    try:
        actual = os.path.getsize(path)
        meas = []
        for sz in sizes:
            ts = []
            for _ in range(repeats):
                off = int(rng.integers(0, max(actual - sz, 1)))
                t0 = time.perf_counter()
                # airlint: allow[pread-seam] -- the probe's measured read:
                # timing the bare syscall IS the point (§3.2 profiling)
                os.pread(fd, sz, off)
                ts.append(time.perf_counter() - t0)
            meas.append(float(np.median(ts)))
        return MeasuredProfile(deltas=tuple(sizes), seconds=tuple(meas), name="local-fs")
    finally:
        os.close(fd)


def affine_coefficients(profile: StorageProfile) -> tuple[float, float] | None:
    """``(ℓ, 1/B)`` if ``T(Δ) = ℓ + Δ·(1/B)`` holds exactly, else None.

    The device-side batched candidate scorers
    (:mod:`repro.kernels.candidate_score`) evaluate only affine-
    representable tiers in closed form; any other profile takes the numpy
    path.  ``AffineUniformProfile`` and ``CachedProfile`` over affine
    components are affine in Δ and are folded here.
    """
    if isinstance(profile, AffineProfile):
        return float(profile.latency), 1.0 / float(profile.bandwidth)
    if isinstance(profile, AffineUniformProfile):
        return profile.coefficients()
    if isinstance(profile, CachedProfile):
        cache = profile.cache or _DEFAULT_CACHE
        back = affine_coefficients(profile.backing)
        front = affine_coefficients(cache)
        if back is None or front is None:
            return None
        h = min(max(float(profile.hit_rate), 0.0), 1.0)
        return (h * front[0] + (1.0 - h) * back[0],
                h * front[1] + (1.0 - h) * back[1])
    if isinstance(profile, ObjectiveProfile):
        # affine-representable bases are deterministic (mean_excess ≡ 0),
        # so the objective curve is the base scaled by (1 + w)
        base = affine_coefficients(profile.base)
        if base is None:
            return None
        scale = 1.0 + float(profile.weight)
        return scale * base[0], scale * base[1]
    return None


# ---------------------------------------------------------------------------
# JSON round-trip for profiles (facade provenance: an index file records
# the T(Δ) it was tuned for, so Index.open can restore measured/custom
# tiers — not just named constants).  Unknown profile types degrade to
# None rather than failing the save/open.
# ---------------------------------------------------------------------------
def profile_to_dict(profile: StorageProfile | None) -> dict | None:
    if isinstance(profile, AffineProfile):
        return {"kind": "affine", "latency": profile.latency,
                "bandwidth": profile.bandwidth, "name": profile.name}
    if isinstance(profile, AffineUniformProfile):
        return {"kind": "affine_uniform",
                "latency_lo": profile.latency_lo,
                "latency_hi": profile.latency_hi,
                "bandwidth_lo": profile.bandwidth_lo,
                "bandwidth_hi": profile.bandwidth_hi, "name": profile.name}
    if isinstance(profile, MeasuredProfile):
        return {"kind": "measured", "deltas": list(profile.deltas),
                "seconds": list(profile.seconds), "name": profile.name}
    if isinstance(profile, DistributionalProfile):
        return {"kind": "distributional", "deltas": list(profile.deltas),
                "means": list(profile.means), "excess": list(profile.excess),
                "qs": list(profile.qs),
                "qvalues": [list(row) for row in profile.qvalues],
                "name": profile.name}
    if isinstance(profile, ObjectiveProfile):
        base = profile_to_dict(profile.base)
        if base is None:
            return None
        return {"kind": "objective", "base": base, "p": profile.p,
                "weight": profile.weight, "name": profile.name}
    if isinstance(profile, CachedProfile):
        backing = profile_to_dict(profile.backing)
        if backing is None:
            return None
        return {"kind": "cached", "backing": backing,
                "cache": profile_to_dict(profile.cache),
                "hit_rate": profile.hit_rate, "name": profile.name}
    return None


def profile_from_dict(d: dict | None) -> StorageProfile | None:
    if not isinstance(d, dict):
        return None
    try:
        kind = d["kind"]
        if kind == "affine":
            return AffineProfile(d["latency"], d["bandwidth"],
                                 name=d.get("name", "affine"))
        if kind == "affine_uniform":
            return AffineUniformProfile(
                d["latency_lo"], d["latency_hi"],
                d["bandwidth_lo"], d["bandwidth_hi"],
                name=d.get("name", "affine-uniform"))
        if kind == "measured":
            return MeasuredProfile(tuple(d["deltas"]), tuple(d["seconds"]),
                                   name=d.get("name", "measured"))
        if kind == "distributional":
            return DistributionalProfile(
                deltas=tuple(d["deltas"]), means=tuple(d["means"]),
                excess=tuple(d["excess"]), qs=tuple(d.get("qs", ())),
                qvalues=tuple(tuple(row) for row in d.get("qvalues", ())),
                name=d.get("name", "distributional"))
        if kind == "objective":
            base = profile_from_dict(d["base"])
            if base is None:
                return None
            return ObjectiveProfile(base=base, p=float(d["p"]),
                                    weight=float(d["weight"]),
                                    name=d.get("name", "objective"))
        if kind == "cached":
            backing = profile_from_dict(d["backing"])
            if backing is None:
                return None
            return CachedProfile(backing=backing,
                                 cache=profile_from_dict(d.get("cache")),
                                 hit_rate=d.get("hit_rate", 0.0),
                                 name=d.get("name", "cached"))
    except (KeyError, TypeError, ValueError):
        return None
    return None


# ---------------------------------------------------------------------------
# Named profiles.
#   Paper §2.1 example tiers + paper §7.1 Azure tiers + TPU-system tiers
#   (the hardware adaptation: same T(Δ) abstraction, constants per tier).
# ---------------------------------------------------------------------------
PROFILES = {
    # paper §2.1 worked example
    "ssd_ex":    AffineProfile(100e-6, 1e9,    name="ssd_ex"),     # 100 µs, 1 GB/s
    "cloud_ex":  AffineProfile(100e-3, 100e6,  name="cloud_ex"),   # 100 ms, 100 MB/s
    # paper §7 experimental tiers (Fig. 3 / Fig. 14 constants)
    "azure_ssd": AffineProfile(250e-6, 175e6,  name="azure_ssd"),  # 250 µs, 175 MB/s
    "azure_nfs": AffineProfile(50e-3,  12e6,   name="azure_nfs"),  # 50 ms, 12 MB/s
    "azure_hdd": AffineProfile(2e-3,   60e6,   name="azure_hdd"),  # 500 IOPS, 60 MB/s
    # TPU-system tiers (targets of the adaptation; v5e-class constants)
    "object_store": AffineProfile(80e-3, 250e6, name="object_store"),
    "host_dram":    _DEFAULT_CACHE,
    "hbm":          AffineProfile(1e-6,  819e9, name="hbm"),       # v5e HBM
    "vmem":         AffineProfile(30e-9, 10e12, name="vmem"),
    "ici":          AffineProfile(1e-6,  50e9,  name="ici"),       # per-link
    "dcn":          AffineProfile(20e-6, 12.5e9, name="dcn"),
}
