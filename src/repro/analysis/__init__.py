"""airlint — AST-based invariant checks for the serving engine's contracts.

Nine PRs of growth accumulated load-bearing invariants that existed only
as prose and runtime assertions: every serving-path pread must flow
through the :class:`repro.serve.StorageBackend` seam so retries / CRC /
fault injection apply, ``ServeStats``/cache mutations must happen under
the engine lock while preads run outside it, typed
:class:`repro.serve.StorageError`\\ s must never be silently absorbed,
and frozen specs must JSON-round-trip every declared field.  This package
turns those tribal contracts into machine-checked ones: a pure-stdlib
``ast`` rule framework (:mod:`repro.analysis.core`), one module per rule
(:mod:`repro.analysis.rules`), and a CLI (``python -m repro.analysis``)
that CI runs as a fatal step.

Rules (error codes are stable — tests and suppressions key on them):

====================  =======  ==================================================
rule                  code     contract
====================  =======  ==================================================
pread-seam            AIR001   ``os.pread`` / ``os.open(..., O_RDONLY)`` only in
                               ``serve/backend.py``; everything else goes through
                               a ``StorageBackend`` or carries a justified allow
lock-discipline       AIR002   stats/cache mutations under ``self._mu``; preads
                               never under it (lock-using modules only)
typed-error-flow      AIR003   no broad ``except`` in ``serve/``/``fleet/`` that
                               can absorb a ``StorageError`` without re-raising,
                               a preceding typed handler, or an allow
spec-roundtrip        AIR004   every declared field of the frozen spec
                               dataclasses appears in ``to_dict`` and is restored
                               by ``from_json`` / ``from_dict``
shim-discipline       AIR005   no internal reference to deprecated entry points
                               or legacy ``IndexService`` keyword arguments
kernel-fallback-shape AIR006   every ``kernels/*/`` package ships ``ops`` +
                               ``ref``; a ``backend=``-dispatching ``ops`` names
                               the full pallas → jnp → numpy chain and imports
                               jax lazily
allow-hygiene         AIR000   an ``# airlint: allow[rule]`` without a
                               ``-- reason`` justification is itself a finding
====================  =======  ==================================================

Suppression: ``# airlint: allow[<rule>] -- <reason>`` on the offending
line, or alone on a comment line above it (the justification may continue
over following comment lines).  The reason is mandatory — an allow is an
argued exception, not an off switch.
"""
from .core import Finding, Rule, ProjectRule, collect_allows, run_checks
from .rules import ALL_RULES, rules_by_name

__all__ = ["Finding", "Rule", "ProjectRule", "ALL_RULES", "rules_by_name",
           "collect_allows", "run_checks"]
