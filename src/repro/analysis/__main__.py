"""airlint CLI — ``python -m repro.analysis [paths...]``.

Human output is one ``path:line:col: CODE [rule] message`` line per
finding (sorted, grep-friendly); ``--json FILE`` additionally writes a
machine-readable report with a stable schema (``version`` bumps on any
breaking change) that CI uploads as an artifact.

Exit codes: 0 clean, 1 findings, 2 usage / unknown rule.
"""
from __future__ import annotations

import argparse
import json
import sys

from .core import run_checks
from .rules import ALL_RULES, rules_by_name

#: bump on any breaking change to the --json report shape
JSON_SCHEMA_VERSION = 1


def build_report(paths, rules, findings, files_scanned) -> dict:
    return {
        "version": JSON_SCHEMA_VERSION,
        "paths": list(paths),
        "rules": [{"name": r.name, "code": r.code,
                   "description": r.description} for r in rules],
        "files_scanned": files_scanned,
        "findings": [f.to_dict() for f in findings],
    }


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="airlint: AST-based invariant checks for the repro "
                    "serving engine (pread seam, lock discipline, typed "
                    "errors, spec round trips, shims, kernel shape).")
    parser.add_argument("paths", nargs="*", default=None,
                        help="files/directories to scan (default: src)")
    parser.add_argument("--rules", default=None, metavar="NAME[,NAME...]",
                        help="comma-separated rule subset (default: all)")
    parser.add_argument("--json", default=None, metavar="FILE",
                        dest="json_path",
                        help="also write a machine-readable report here")
    parser.add_argument("--list-rules", action="store_true",
                        help="print the rule table and exit")
    args = parser.parse_args(argv)

    if args.list_rules:
        for r in ALL_RULES:
            print(f"{r.code}  {r.name:<22} {r.description}")
        return 0

    try:
        names = ([n.strip() for n in args.rules.split(",") if n.strip()]
                 if args.rules else None)
        rules = rules_by_name(names)
    except KeyError as e:
        print(f"error: {e.args[0]}", file=sys.stderr)
        return 2

    paths = args.paths or ["src"]
    findings, files_scanned = run_checks(paths, rules)

    if args.json_path:
        report = build_report(paths, rules, findings, files_scanned)
        with open(args.json_path, "w", encoding="utf-8") as fh:
            json.dump(report, fh, indent=2, sort_keys=True)
            fh.write("\n")

    for f in findings:
        print(f.format())
    noun = "finding" if len(findings) == 1 else "findings"
    print(f"airlint: {len(findings)} {noun} in {files_scanned} files "
          f"({len(rules)} rules)", file=sys.stderr)
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
