"""airlint framework: findings, rule base classes, allow parsing, runner.

Pure stdlib (``ast`` + ``re``) on purpose — the linter must run in any
environment the repo does, including a bare CI container before heavier
dependencies import.  Rules come in two shapes:

* :class:`Rule` — per-file AST checks; the runner hands each one the
  parsed tree and source lines of every ``.py`` file under the scanned
  paths.
* :class:`ProjectRule` — whole-tree checks that run once (import-based
  spec introspection, kernel package shape).

Findings carry ``(rule, code, path, line, col, message)`` and are
suppressible with ``# airlint: allow[<rule>] -- <reason>`` on the finding
line or alone on the line directly above.  An allow without a reason is
itself a finding (``AIR000``): a suppression is an argued exception, and
the argument is the point.
"""
from __future__ import annotations

import ast
import dataclasses
import os
import re

#: the suppression comment grammar.  The reason (after ``--``) is
#: mandatory for the allow to take effect; matching is per rule name.
ALLOW_RE = re.compile(
    r"#\s*airlint:\s*allow\[(?P<rule>[a-z0-9_-]+)\]"
    r"(?:\s*--\s*(?P<reason>.*\S))?\s*$")

ALLOW_HYGIENE_RULE = "allow-hygiene"
ALLOW_HYGIENE_CODE = "AIR000"


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at a source location."""

    rule: str
    code: str
    path: str
    line: int
    col: int
    message: str

    def format(self) -> str:
        return (f"{self.path}:{self.line}:{self.col}: "
                f"{self.code} [{self.rule}] {self.message}")

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


class Rule:
    """Base for per-file AST rules.  Subclasses set ``name`` / ``code`` /
    ``description`` and implement :meth:`check_file`."""

    name: str = ""
    code: str = ""
    description: str = ""

    def check_file(self, path: str, tree: ast.AST, lines: list[str]):
        """→ iterable of :class:`Finding` for one parsed source file.
        ``path`` is the runner-relative path reported in findings."""
        raise NotImplementedError

    def finding(self, path: str, node_or_line, message: str,
                col: int | None = None) -> Finding:
        """Build a finding anchored at an AST node (or a 1-based line)."""
        if isinstance(node_or_line, int):
            line, c = node_or_line, col or 1
        else:
            line = getattr(node_or_line, "lineno", 1)
            c = (col if col is not None
                 else getattr(node_or_line, "col_offset", 0) + 1)
        return Finding(rule=self.name, code=self.code, path=path,
                       line=line, col=c, message=message)


class ProjectRule(Rule):
    """Base for whole-tree rules that run once per invocation."""

    def check_project(self, files: list[str]):
        """→ iterable of :class:`Finding`; ``files`` are all collected
        ``.py`` paths (runner-relative)."""
        raise NotImplementedError

    def check_file(self, path, tree, lines):   # pragma: no cover - unused
        return ()


@dataclasses.dataclass(frozen=True)
class Allow:
    """One parsed suppression comment."""

    rule: str
    line: int          # the line the allow suppresses findings on
    comment_line: int  # where the comment physically sits
    reason: str | None


def collect_allows(lines: list[str]) -> list[Allow]:
    """Parse every ``# airlint: allow[...]`` comment in a source file.

    A comment sharing a line with code suppresses findings on that line;
    a comment alone on its line suppresses findings on the next
    non-comment line (so a justification may continue across further
    comment lines between the allow and the code it covers).
    """
    allows = []
    for i, raw in enumerate(lines, start=1):
        m = ALLOW_RE.search(raw)
        if not m:
            continue
        code_before = raw[:m.start()].strip()
        if code_before:
            target = i
        else:
            target = i + 1
            while target <= len(lines) and (
                    not lines[target - 1].strip()
                    or lines[target - 1].lstrip().startswith("#")):
                target += 1
        allows.append(Allow(rule=m.group("rule"), line=target,
                            comment_line=i, reason=m.group("reason")))
    return allows


def apply_allows(findings: list[Finding],
                 allows_by_path: dict[str, list[Allow]]) -> list[Finding]:
    """Drop findings covered by a justified allow; emit an ``AIR000``
    finding for every allow that lacks a reason (those never suppress)."""
    out = []
    for f in findings:
        allows = allows_by_path.get(f.path, ())
        if any(a.rule == f.rule and a.line == f.line and a.reason
               for a in allows):
            continue
        out.append(f)
    for path, allows in allows_by_path.items():
        for a in allows:
            if not a.reason:
                out.append(Finding(
                    rule=ALLOW_HYGIENE_RULE, code=ALLOW_HYGIENE_CODE,
                    path=path, line=a.comment_line, col=1,
                    message=f"allow[{a.rule}] without a justification — "
                            f"write '# airlint: allow[{a.rule}] -- <reason>'"))
    return out


def collect_py_files(paths: list[str]) -> list[str]:
    """All ``.py`` files under the given files/directories, sorted,
    ``__pycache__`` pruned.  Paths are returned as given (relative stays
    relative) so findings print runner-relative locations."""
    files = []
    for p in paths:
        if os.path.isfile(p):
            if p.endswith(".py"):
                files.append(p)
            continue
        for root, dirnames, names in os.walk(p):
            dirnames[:] = [d for d in dirnames if d != "__pycache__"]
            files.extend(os.path.join(root, n)
                         for n in names if n.endswith(".py"))
    return sorted(set(files))


def run_checks(paths: list[str], rules: list[Rule]) -> tuple[list, int]:
    """Run ``rules`` over every ``.py`` file under ``paths``.

    → ``(findings, files_scanned)``; findings are allow-filtered and
    sorted ``(path, line, code)``.  A file that fails to parse yields a
    finding (code ``AIR999``) rather than an exception — a syntax error
    must fail the gate, not crash it.
    """
    files = collect_py_files(paths)
    findings: list[Finding] = []
    allows_by_path: dict[str, list[Allow]] = {}
    file_rules = [r for r in rules if not isinstance(r, ProjectRule)]
    project_rules = [r for r in rules if isinstance(r, ProjectRule)]
    for path in files:
        try:
            with open(path, encoding="utf-8") as fh:
                src = fh.read()
            tree = ast.parse(src, filename=path)
        except (SyntaxError, ValueError, OSError) as e:
            findings.append(Finding(
                rule="parse", code="AIR999", path=path,
                line=getattr(e, "lineno", 1) or 1, col=1,
                message=f"could not parse: {e}"))
            continue
        lines = src.splitlines()
        allows_by_path[path] = collect_allows(lines)
        for rule in file_rules:
            findings.extend(rule.check_file(path, tree, lines))
    for rule in project_rules:
        findings.extend(rule.check_project(files))
    findings = apply_allows(findings, allows_by_path)
    findings.sort(key=lambda f: (f.path, f.line, f.code, f.col))
    return findings, len(files)


# -- shared AST helpers ------------------------------------------------------
def dotted_name(node: ast.AST) -> str | None:
    """``a.b.c`` for a Name/Attribute chain, else None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def norm_path(path: str) -> str:
    """Forward-slash form for suffix matching regardless of platform."""
    return path.replace(os.sep, "/")
