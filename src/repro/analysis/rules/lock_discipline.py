"""lock-discipline (AIR002): stats/cache under the lock, preads outside it.

PR 6's pipelined engine shares one lock (``self._mu``) between the
serving thread and the prefetch worker: every ``ServeStats`` mutation and
every block-cache access happens under it, while the preads themselves
(and their retry sleeps) run *outside* it so stage-1 I/O really overlaps
stage-2 compute.  Both directions rot silently — an unlocked ``st.stats.x
+= 1`` is a data race that only shows up as drifting counters under load,
and a pread under the lock serializes the pipeline without failing any
test.  This rule checks both, in any module that uses the ``with
self._mu:`` idiom:

* mutations of ``<x>.stats.<field>`` (assign / augmented assign), calls
  to ``<x>.stats.record_*``, and block-cache accessor calls
  (``<x>.cache.get/put/peek/pop``) must sit under a ``with <x>._mu:``
  block;
* ``.pread`` / ``.pread_full`` calls must NOT sit under one.

Open-time mutations of a not-yet-published epoch are the legitimate
exception — those sites carry a justified allow.
"""
from __future__ import annotations

import ast

from ..core import Rule

#: cache methods that mutate or probe the shared TieredBlockCache
_CACHE_METHODS = {"get", "put", "peek", "pop"}


def _is_mu(node: ast.AST) -> bool:
    return isinstance(node, ast.Attribute) and node.attr == "_mu"


def _stats_member(node: ast.AST):
    """``<x>.stats.<field>`` → field name, else None."""
    if isinstance(node, ast.Attribute) \
            and isinstance(node.value, ast.Attribute) \
            and node.value.attr == "stats":
        return node.attr
    return None


class LockDisciplineRule(Rule):
    name = "lock-discipline"
    code = "AIR002"
    description = ("in modules using the self._mu idiom: ServeStats/cache "
                   "mutations only under the lock; backend preads never "
                   "under it")

    def check_file(self, path, tree, lines):
        # gate: only modules that actually use the lock idiom are in scope
        if not any("._mu" in ln for ln in lines):
            return ()
        findings: list = []
        self._walk_body(path, tree.body, locked=False, findings=findings)
        return findings

    # -- recursive statement walk with lock state ---------------------------
    def _walk_body(self, path, stmts, locked: bool, findings: list):
        for stmt in stmts:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                # nested scope runs later; lock state does not carry over
                self._walk_body(path, stmt.body, locked=False,
                                findings=findings)
                continue
            if isinstance(stmt, ast.With):
                inner = locked or any(_is_mu(item.context_expr)
                                      for item in stmt.items)
                for item in stmt.items:
                    self._check_expr(path, item.context_expr, locked,
                                     findings)
                self._walk_body(path, stmt.body, inner, findings)
                continue
            self._check_stmt(path, stmt, locked, findings)
            # child blocks (if/for/try/class bodies, except handlers) keep
            # the lock state; bare expressions are scanned for calls
            for _name, value in ast.iter_fields(stmt):
                if isinstance(value, ast.AST) \
                        and not isinstance(value, ast.stmt):
                    self._check_expr(path, value, locked, findings)
                elif isinstance(value, list):
                    block = [v for v in value if isinstance(v, ast.stmt)]
                    if block:
                        self._walk_body(path, block, locked, findings)
                    for v in value:
                        if isinstance(v, ast.excepthandler):
                            if v.type is not None:
                                self._check_expr(path, v.type, locked,
                                                 findings)
                            self._walk_body(path, v.body, locked, findings)
                        elif isinstance(v, ast.AST) \
                                and not isinstance(v, ast.stmt):
                            self._check_expr(path, v, locked, findings)

    def _check_stmt(self, path, stmt, locked: bool, findings: list):
        targets = []
        if isinstance(stmt, ast.Assign):
            targets = stmt.targets
        elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
            targets = [stmt.target]
        for t in targets:
            field = _stats_member(t)
            if field is not None and not locked:
                findings.append(self.finding(
                    path, t,
                    f"ServeStats mutation '.stats.{field}' outside "
                    f"'with self._mu:' — racing the prefetch worker"))

    def _check_expr(self, path, expr, locked: bool, findings: list):
        for node in ast.walk(expr):
            if not isinstance(node, ast.Call) \
                    or not isinstance(node.func, ast.Attribute):
                continue
            meth = node.func.attr
            owner = node.func.value
            if not locked:
                if _stats_member(node.func) and meth.startswith("record_"):
                    findings.append(self.finding(
                        path, node,
                        f"ServeStats mutation '.stats.{meth}(...)' outside "
                        f"'with self._mu:' — racing the prefetch worker"))
                elif isinstance(owner, ast.Attribute) \
                        and owner.attr == "cache" \
                        and meth in _CACHE_METHODS:
                    findings.append(self.finding(
                        path, node,
                        f"block-cache access '.cache.{meth}(...)' outside "
                        f"'with self._mu:' — the tiered LRU is not "
                        f"thread-safe"))
            else:
                if meth in ("pread", "pread_full"):
                    findings.append(self.finding(
                        path, node,
                        f"'.{meth}(...)' under 'with self._mu:' — I/O must "
                        f"run outside the lock so the pipeline overlaps"))
