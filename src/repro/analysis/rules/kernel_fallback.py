"""kernel-fallback-shape (AIR006): every kernel package ships the chain.

The kernels grew a uniform shape over PRs 1–7: each
``repro/kernels/<name>/`` package exposes its public entry points from
``ops`` (re-exported by ``__init__``), keeps a pure-NumPy oracle in
``ref``, and — when it dispatches on a ``backend=`` argument — names the
full ``pallas → jnp → numpy`` fallback chain and imports jax *lazily*
(inside functions), so a CPU-only environment can still import and run
the numpy path.  A new kernel package that skips ``ref`` loses its
oracle tests; an eager module-level ``import jax`` in a dispatching
``ops`` breaks CPU-only import of the whole package.

Per scanned ``repro/kernels/<name>/`` package this rule checks:

* ``ops.py`` and ``ref.py`` exist,
* ``__init__.py`` imports from ``.ops``,
* if any function in ``ops.py`` takes a ``backend`` parameter: the
  module contains all three backend literals (``"pallas"``, ``"jnp"``,
  ``"numpy"``) and has no module-top-level ``import jax``.
"""
from __future__ import annotations

import ast
import os
import re

from ..core import Finding, ProjectRule, norm_path

_PKG_RE = re.compile(r"(?P<root>.*/repro/kernels)/(?P<pkg>[^/]+)/")

_BACKENDS = ("pallas", "jnp", "numpy")


def _parse(path):
    try:
        with open(path, encoding="utf-8") as fh:
            src = fh.read()
        return ast.parse(src, filename=path)
    except (SyntaxError, ValueError, OSError):
        return None  # AIR999 covers parse failures


def _has_backend_param(tree) -> bool:
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            a = node.args
            names = [p.arg for p in
                     a.posonlyargs + a.args + a.kwonlyargs]
            if "backend" in names:
                return True
    return False


def _string_literals(tree) -> set:
    return {n.value for n in ast.walk(tree)
            if isinstance(n, ast.Constant) and isinstance(n.value, str)}


def _toplevel_jax_import(tree):
    """Module-level ``import jax`` / ``from jax... import`` node, if any.
    Imports inside functions (the lazy idiom) don't count."""
    for stmt in tree.body:
        if isinstance(stmt, ast.Import):
            if any(a.name == "jax" or a.name.startswith("jax.")
                   for a in stmt.names):
                return stmt
        elif isinstance(stmt, ast.ImportFrom):
            mod = stmt.module or ""
            if stmt.level == 0 and (mod == "jax"
                                    or mod.startswith("jax.")):
                return stmt
    return None


def _imports_from_ops(tree) -> bool:
    for node in ast.walk(tree):
        if isinstance(node, ast.ImportFrom):
            mod = node.module or ""
            if mod == "ops" or mod.endswith(".ops") \
                    or (node.level >= 1 and mod == "ops"):
                return True
            if any(a.name == "ops" for a in node.names):
                return True
    return False


class KernelFallbackShapeRule(ProjectRule):
    name = "kernel-fallback-shape"
    code = "AIR006"
    description = ("every repro/kernels/* package ships ops.py + ref.py, "
                   "re-exports from .ops, and a backend=-dispatching ops "
                   "names the pallas/jnp/numpy chain with lazy jax imports")

    def check_project(self, files):
        pkgs: dict[str, dict[str, str]] = {}
        for p in files:
            m = _PKG_RE.search(norm_path(p))
            if not m:
                continue
            pkgs.setdefault(m.group("pkg"), {})[
                os.path.basename(p)] = p
        for pkg in sorted(pkgs):
            members = pkgs[pkg]
            init = members.get("__init__.py")
            anchor = init or next(iter(sorted(members.values())))
            for required in ("ops.py", "ref.py"):
                if required not in members:
                    yield Finding(
                        rule=self.name, code=self.code, path=anchor,
                        line=1, col=1,
                        message=f"kernel package '{pkg}' is missing "
                                f"{required} — every kernel ships a "
                                f"dispatching ops module and a NumPy "
                                f"reference oracle")
            if init is not None:
                tree = _parse(init)
                if tree is not None and not _imports_from_ops(tree):
                    yield Finding(
                        rule=self.name, code=self.code, path=init,
                        line=1, col=1,
                        message=f"kernel package '{pkg}' __init__.py does "
                                f"not re-export from .ops")
            ops = members.get("ops.py")
            if ops is None:
                continue
            tree = _parse(ops)
            if tree is None:
                continue
            if not _has_backend_param(tree):
                continue  # fixed-backend kernels (attention) are exempt
            literals = _string_literals(tree)
            missing = [b for b in _BACKENDS if b not in literals]
            if missing:
                yield Finding(
                    rule=self.name, code=self.code, path=ops, line=1,
                    col=1,
                    message=f"kernel package '{pkg}' ops.py dispatches on "
                            f"backend= but never names "
                            f"{', '.join(repr(b) for b in missing)} — the "
                            f"pallas → jnp → numpy chain must be complete")
            jax_imp = _toplevel_jax_import(tree)
            if jax_imp is not None:
                yield Finding(
                    rule=self.name, code=self.code, path=ops,
                    line=jax_imp.lineno, col=jax_imp.col_offset + 1,
                    message=f"kernel package '{pkg}' ops.py imports jax at "
                            f"module top level — backend-dispatching ops "
                            f"must import jax lazily so the numpy path "
                            f"works without jax")
