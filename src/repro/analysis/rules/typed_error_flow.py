"""typed-error-flow (AIR003): broad excepts must not absorb StorageErrors.

The fault-tolerance contract (PR 8/9) threads typed errors —
``StorageError`` → ``ReadError`` / ``CorruptPageError`` /
``DeadlineExceededError``, plus the fleet's ``ShardUnavailableError`` —
from the pread seam up to availability reports, where operators key on
the concrete class name.  A ``except Exception:`` in ``serve/`` or
``fleet/`` sitting on that path flattens the whole ladder into silence:
the shard shows "degraded(?)" instead of "CorruptPageError", and the
chaos-gate assertions stop meaning anything.

A broad handler (bare ``except:``, ``except Exception``, ``except
BaseException``) in those packages passes only if it provably cannot
absorb a typed storage error:

* its body re-raises (any ``raise`` statement), or
* a *preceding* except clause in the same ``try`` already catches one of
  the typed storage errors (so they never reach the broad one), or
* it carries a justified ``# airlint: allow[typed-error-flow] -- <reason>``
  (e.g. a ``__del__`` / best-effort-shutdown path).
"""
from __future__ import annotations

import ast

from ..core import Rule, norm_path

#: path fragments that put a module on the typed-error path
SCOPED_DIRS = ("/serve/", "/fleet/")

#: the typed ladder; a preceding handler for any of these shields the
#: broad handler from absorbing storage errors
TYPED_ERRORS = {"StorageError", "ReadError", "CorruptPageError",
                "DeadlineExceededError", "ShardUnavailableError"}

_BROAD = {"Exception", "BaseException"}


def _exc_names(type_node: ast.AST | None):
    """Exception class names named by an ``except`` clause (handles
    tuples and dotted references)."""
    if type_node is None:
        return set()
    nodes = (type_node.elts if isinstance(type_node, ast.Tuple)
             else [type_node])
    names = set()
    for n in nodes:
        if isinstance(n, ast.Name):
            names.add(n.id)
        elif isinstance(n, ast.Attribute):
            names.add(n.attr)
    return names


def _reraises(handler: ast.ExceptHandler) -> bool:
    for node in ast.walk(handler):
        if isinstance(node, ast.Raise):
            return True
    return False


class TypedErrorFlowRule(Rule):
    name = "typed-error-flow"
    code = "AIR003"
    description = ("no bare/broad except in serve/ or fleet/ that can "
                   "absorb a typed StorageError without re-raising or a "
                   "preceding typed handler")

    def check_file(self, path, tree, lines):
        p = norm_path(path)
        if not any(d in p for d in SCOPED_DIRS):
            return
        for node in ast.walk(tree):
            if not isinstance(node, ast.Try):
                continue
            shielded = False  # a preceding typed handler catches the ladder
            for handler in node.handlers:
                names = _exc_names(handler.type)
                if handler.type is None or names & _BROAD:
                    if shielded or _reraises(handler):
                        continue
                    what = ("bare 'except:'" if handler.type is None
                            else f"'except {'/'.join(sorted(names & _BROAD))}'")
                    yield self.finding(
                        path, handler,
                        f"{what} can absorb a typed StorageError — re-raise, "
                        f"add a preceding 'except StorageError' handler, or "
                        f"justify with # airlint: allow[typed-error-flow] "
                        f"-- <reason>")
                elif names & TYPED_ERRORS:
                    shielded = True
