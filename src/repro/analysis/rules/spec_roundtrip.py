"""spec-roundtrip (AIR004): every spec field survives the JSON round trip.

The frozen spec dataclasses (``TuneSpec`` / ``ServeSpec`` /
``RetryPolicy`` / ``FleetSpec`` / ``ShardMap``) are the repo's wire
format: benchmarks persist them, the retune daemon diffs them, the fleet
rebuilds per-shard ``ServeSpec``\\ s from JSON.  ``FleetSpec.to_dict`` is
hand-written, so adding a field and forgetting the dict literal silently
drops it — the spec saves, loads, and quietly reverts that knob to its
default.  Grep cannot catch this; importing and introspecting can.

This is a :class:`ProjectRule`: it runs once, imports the spec modules,
and for every registered class checks that

* each declared dataclass field appears in ``to_dict()``'s keys,
* ``to_json()`` produces valid JSON,
* ``from_json(to_json(x)) == x`` for a default instance, and
* perturbing each scalar field (via ``dataclasses.replace``) still
  round-trips — i.e. the field is actually *restored*, not defaulted.

Findings anchor at the class definition line in the spec module.
"""
from __future__ import annotations

import dataclasses
import inspect
import json
import os

from ..core import Finding, ProjectRule, norm_path

#: (module, class, builder) — builder returns a valid default instance
SPEC_TARGETS = [
    ("repro.api.spec", "TuneSpec", lambda cls: cls()),
    ("repro.api.spec", "RetryPolicy", lambda cls: cls()),
    ("repro.api.spec", "ServeSpec", lambda cls: cls()),
    ("repro.fleet.spec", "ShardMap", lambda cls: cls(bounds=(16, 32))),
    ("repro.fleet.spec", "FleetSpec", lambda cls: cls()),
]

#: module suffixes that gate the rule: only run when the scanned paths
#: actually include the spec sources (scanning tests/ alone skips it)
_GATE_SUFFIXES = ("repro/api/spec.py", "repro/fleet/spec.py")


def _perturb(value):
    """A different-but-plausible value for a scalar field, else None."""
    if isinstance(value, bool):
        return not value
    if isinstance(value, int):
        return value + 1
    if isinstance(value, float):
        return value + 0.5
    if isinstance(value, str):
        return value + "_x"
    return None


def roundtrip_problems(cls, build) -> list[str]:
    """Check one spec class; → list of human-readable problems.

    Exposed standalone so tests can point it at deliberately broken
    dataclasses without going through the import machinery.
    """
    problems: list[str] = []
    try:
        x = build(cls)
    except Exception as e:
        return [f"could not construct a default instance: {e!r}"]
    field_names = [f.name for f in dataclasses.fields(cls)]

    try:
        d = cls.to_dict(x) if hasattr(cls, "to_dict") else None
    except Exception as e:
        return [f"to_dict() raised: {e!r}"]
    if d is None:
        return ["spec class has no to_dict()"]
    missing = [n for n in field_names if n not in d]
    for n in missing:
        problems.append(f"field '{n}' missing from to_dict() — it will "
                        f"silently revert to its default on reload")

    if not hasattr(cls, "from_json") or not hasattr(cls, "to_json"):
        problems.append("spec class lacks to_json()/from_json()")
        return problems
    try:
        blob = x.to_json()
        json.loads(blob)
    except Exception as e:
        problems.append(f"to_json() did not produce valid JSON: {e!r}")
        return problems
    try:
        y = cls.from_json(blob)
    except Exception as e:
        problems.append(f"from_json(to_json(x)) raised: {e!r}")
        return problems
    if y != x:
        problems.append("from_json(to_json(x)) != x for a default instance")

    # perturb each scalar field and make sure the new value survives
    for f in dataclasses.fields(cls):
        if f.name in missing:
            continue  # already reported above
        current = getattr(x, f.name)
        new = _perturb(current)
        if new is None:
            continue
        try:
            z = dataclasses.replace(x, **{f.name: new})
        except Exception:
            continue  # validation rejects the perturbed value — fine
        try:
            z2 = cls.from_json(z.to_json())
        except Exception as e:
            problems.append(f"round trip with perturbed field '{f.name}' "
                            f"raised: {e!r}")
            continue
        if getattr(z2, f.name) != new:
            problems.append(f"field '{f.name}' not restored by "
                            f"from_json(to_json(x)) — got "
                            f"{getattr(z2, f.name)!r}, expected {new!r}")
    return problems


class SpecRoundtripRule(ProjectRule):
    name = "spec-roundtrip"
    code = "AIR004"
    description = ("every declared field of the frozen spec dataclasses "
                   "appears in to_dict() and is restored by "
                   "from_json(to_json(x))")

    def check_project(self, files):
        if not any(norm_path(p).endswith(s)
                   for p in files for s in _GATE_SUFFIXES):
            return
        import importlib
        for mod_name, cls_name, build in SPEC_TARGETS:
            try:
                mod = importlib.import_module(mod_name)
                cls = getattr(mod, cls_name)
            except Exception as e:
                yield Finding(rule=self.name, code=self.code,
                              path=mod_name.replace(".", "/") + ".py",
                              line=1, col=1,
                              message=f"could not import {mod_name}."
                                      f"{cls_name}: {e!r}")
                continue
            path, line = _anchor(cls, files)
            for problem in roundtrip_problems(cls, build):
                yield Finding(rule=self.name, code=self.code, path=path,
                              line=line, col=1,
                              message=f"{cls_name}: {problem}")


def _anchor(cls, files) -> tuple[str, int]:
    """(scanned-relative path, class def line) for findings/allows."""
    try:
        src_file = inspect.getsourcefile(cls)
        src, line = inspect.getsourcelines(cls)
    except (OSError, TypeError):
        return cls.__module__.replace(".", "/") + ".py", 1
    # skip decorator lines so the anchor is the ``class X:`` statement
    for i, ln in enumerate(src):
        if ln.lstrip().startswith("class "):
            line += i
            break
    src_norm = norm_path(os.path.abspath(src_file))
    for p in files:
        if src_norm.endswith(norm_path(p).lstrip("./")):
            return p, line
    return os.path.relpath(src_file), line
