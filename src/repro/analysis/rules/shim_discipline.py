"""shim-discipline (AIR005): internal code never uses its own shims.

``repro.core.deprecation`` draws a hard line: deprecated entry points
warn external callers and *assert* when called from inside ``repro``.
That assertion only fires at runtime, on the path somebody happens to
exercise — this rule catches the regression at lint time instead.  Flags:

* calls to the deprecated entry points (``load_index``,
  ``lookup_file``) anywhere in scanned code,
* ``from ... import`` of those names outside ``__init__.py`` re-export
  modules (mirrors the ruff F401 ``__init__.py`` carve-out),
* ``IndexService(...)`` / ``Index.open(...)``-style constructions
  passing a legacy keyword that ``ServeSpec`` replaced
  (``cache_bytes=``, ``use_device=``, ...) — internal code must build a
  ``ServeSpec`` and pass ``spec=``.

Definition sites are untouched (the shims must keep existing for
external callers); only *references* are findings.
"""
from __future__ import annotations

import ast
import os

from ..core import Rule

#: deprecated entry point → its replacement (used in messages)
DEPRECATED_ENTRY_POINTS = {
    "load_index": "repro.api.Index.open(path, data=data).design",
    "lookup_file": "repro.api.Index.open(path).lookup(queries)",
}

#: IndexService kwargs folded into ServeSpec; internal callers must pass
#: spec=ServeSpec(...) instead (mirrors _fold_legacy_kwargs)
LEGACY_KWARGS = ("cache_bytes", "cache_profile", "page_bytes",
                 "resident_layers", "use_device", "interpret",
                 "coalesce_gap", "persist_stats")

#: callables whose keyword lists the legacy-kwarg check applies to
_SERVICE_NAMES = {"IndexService"}


class ShimDisciplineRule(Rule):
    name = "shim-discipline"
    code = "AIR005"
    description = ("no internal calls/imports of deprecated entry points "
                   "(load_index, lookup_file) and no legacy IndexService "
                   "kwargs outside __init__.py re-exports")

    def check_file(self, path, tree, lines):
        is_init = os.path.basename(path) == "__init__.py"
        deprecated = set(DEPRECATED_ENTRY_POINTS)
        for node in ast.walk(tree):
            if isinstance(node, ast.ImportFrom) and not is_init:
                for alias in node.names:
                    if alias.name in deprecated:
                        yield self.finding(
                            path, node,
                            f"import of deprecated entry point "
                            f"'{alias.name}' — use "
                            f"{DEPRECATED_ENTRY_POINTS[alias.name]}")
            elif isinstance(node, ast.Call):
                name = (node.func.id if isinstance(node.func, ast.Name)
                        else node.func.attr
                        if isinstance(node.func, ast.Attribute) else None)
                if name in deprecated:
                    yield self.finding(
                        path, node,
                        f"call to deprecated entry point '{name}' — use "
                        f"{DEPRECATED_ENTRY_POINTS[name]} (the shim hard-"
                        f"asserts when called from inside repro)")
                elif name in _SERVICE_NAMES:
                    legacy = [kw.arg for kw in node.keywords
                              if kw.arg in LEGACY_KWARGS]
                    if legacy:
                        yield self.finding(
                            path, node,
                            f"IndexService(...) with legacy kwarg(s) "
                            f"{', '.join(sorted(legacy))} — internal code "
                            f"builds a ServeSpec and passes spec=")
