"""airlint rule registry — one module per enforced contract."""
from .kernel_fallback import KernelFallbackShapeRule
from .lock_discipline import LockDisciplineRule
from .pread_seam import PreadSeamRule
from .shim_discipline import ShimDisciplineRule
from .spec_roundtrip import SpecRoundtripRule
from .typed_error_flow import TypedErrorFlowRule

#: every shipped rule, instantiated (rules are stateless between runs)
ALL_RULES = [
    PreadSeamRule(),
    LockDisciplineRule(),
    TypedErrorFlowRule(),
    SpecRoundtripRule(),
    ShimDisciplineRule(),
    KernelFallbackShapeRule(),
]


def rules_by_name(names=None) -> list:
    """Resolve a rule-name subset (None = all).  KeyError lists what
    exists — same contract as the builder/strategy registries."""
    if names is None:
        return list(ALL_RULES)
    by_name = {r.name: r for r in ALL_RULES}
    out = []
    for n in names:
        if n not in by_name:
            raise KeyError(f"unknown rule {n!r}; "
                           f"available: {', '.join(sorted(by_name))}")
        out.append(by_name[n])
    return out


__all__ = ["ALL_RULES", "rules_by_name", "PreadSeamRule",
           "LockDisciplineRule", "TypedErrorFlowRule", "SpecRoundtripRule",
           "ShimDisciplineRule", "KernelFallbackShapeRule"]
