"""pread-seam (AIR001): all serving-path reads flow through StorageBackend.

PR 8 built the fault seam — retries, exponential backoff, CRC32
verification, deterministic fault injection — into
:class:`repro.serve.StorageBackend`, and every byte the serving stack
reads is supposed to flow through it.  A raw ``os.pread`` (or an
``os.open(..., os.O_RDONLY)`` that exists to feed one) silently opts out
of all of that: no retry budget, no checksum, invisible to the chaos
gate.  This rule flags every such call outside ``serve/backend.py`` (the
one module allowed to touch the syscall).  Offline-only call sites that
*measure* the raw syscall on purpose (the §3.2 probe loop) carry a
justified ``# airlint: allow[pread-seam] -- <reason>``.
"""
from __future__ import annotations

import ast

from ..core import Rule, dotted_name, norm_path

#: the one module allowed to call os.pread / os.open-for-read directly
SEAM_MODULE = "repro/serve/backend.py"


def _mentions_o_rdonly(node: ast.AST) -> bool:
    for sub in ast.walk(node):
        if isinstance(sub, ast.Attribute) and sub.attr == "O_RDONLY":
            return True
        if isinstance(sub, ast.Name) and sub.id == "O_RDONLY":
            return True
    return False


class PreadSeamRule(Rule):
    name = "pread-seam"
    code = "AIR001"
    description = ("os.pread / os.open(..., O_RDONLY) only inside "
                   "serve/backend.py; all other call sites must use a "
                   "StorageBackend or carry a justified allow")

    def check_file(self, path, tree, lines):
        if norm_path(path).endswith(SEAM_MODULE):
            return
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call):
                continue
            fn = dotted_name(node.func)
            if fn == "os.pread":
                yield self.finding(
                    path, node,
                    "raw os.pread bypasses the StorageBackend seam "
                    "(retries / CRC / fault injection); read through "
                    "repro.serve.FileBackend or justify with "
                    "# airlint: allow[pread-seam] -- <reason>")
            elif fn == "os.open" and any(_mentions_o_rdonly(a)
                                         for a in node.args):
                yield self.finding(
                    path, node,
                    "os.open(..., O_RDONLY) opens a read path outside the "
                    "StorageBackend seam; use repro.serve.FileBackend (or "
                    "justify with # airlint: allow[pread-seam] -- <reason>)")
