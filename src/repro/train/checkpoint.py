"""Checkpointing with an AirIndex manifest (DESIGN.md §3).

A checkpoint is one packed blob of raw leaf bytes plus an AirTune-built
index over ``slice_id → byte range`` tuned for the checkpoint storage
tier.  Restore-after-failure reads the manifest root (one small read) and
then exactly the byte ranges of the slices a host needs — on a 1000-node
cluster each host restores only its own shards, O(Σ T(Δ_slice)) instead of
O(T(whole checkpoint)).

Leaves are split into fixed-grain slices (default 4 MiB) so partial
restore granularity is independent of tensor size.  Every slice carries a
crc32 for integrity; a corrupted slice fails loudly at restore.
"""
from __future__ import annotations

import json
import os
import zlib

import jax
import numpy as np

from repro.core import (KeyPositions, SerializedIndex, airtune, write_index)
from repro.core.storage import PROFILES, StorageProfile

SLICE_BYTES = 4 << 20


def _leaf_paths(tree) -> list:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        out.append(("/".join(str(getattr(p, "key", p)) for p in path), leaf))
    return out


def save_checkpoint(path: str, tree, *, profile: StorageProfile | str =
                    "object_store", step: int = 0) -> dict:
    """Write blob + AirIndex manifest; returns the meta dict."""
    os.makedirs(path, exist_ok=True)
    if isinstance(profile, str):
        profile = PROFILES[profile]
    blob_path = os.path.join(path, f"ckpt-{step}.blob")
    slices = []       # (key, offset, size, crc, leaf_idx, slice_idx)
    leaves = _leaf_paths(tree)
    off = 0
    with open(blob_path, "wb") as f:
        for li, (name, leaf) in enumerate(leaves):
            raw = np.asarray(leaf).tobytes()
            for si in range(0, max(len(raw), 1), SLICE_BYTES):
                chunk = raw[si:si + SLICE_BYTES]
                f.write(chunk)
                slices.append({"leaf": li, "name": name, "off": off,
                               "size": len(chunk),
                               "crc": zlib.crc32(chunk)})
                off += len(chunk)
    # AirIndex over slice_id → byte range
    keys = np.arange(len(slices), dtype=np.uint64)
    offs = np.asarray([s["off"] for s in slices] + [off], dtype=np.int64)
    D = KeyPositions.from_offsets(keys, offs)
    tune = airtune(D, profile, k=3)
    write_index(os.path.join(path, f"ckpt-{step}.air"), tune.design)
    meta = {
        "step": step,
        "blob_bytes": off,
        "slices": slices,
        "leaves": [{"name": n, "shape": list(np.asarray(l).shape),
                    "dtype": str(np.asarray(l).dtype)} for n, l in leaves],
        "index_cost_us": tune.cost * 1e6,
        "index_design": tune.design.describe(),
    }
    with open(os.path.join(path, f"ckpt-{step}.json"), "w") as f:
        json.dump(meta, f)
    return meta


def restore_checkpoint(path: str, tree_like, *, step: int = 0,
                       leaf_filter=None) -> tuple:
    """Restore (a subset of) leaves via manifest-indexed partial reads.

    ``leaf_filter(name) → bool`` selects which leaves this host needs
    (None = all).  Returns (tree, stats) where stats records bytes read —
    the partial-restore win is visible there.
    """
    with open(os.path.join(path, f"ckpt-{step}.json")) as f:
        meta = json.load(f)
    idx = SerializedIndex(os.path.join(path, f"ckpt-{step}.air"))
    # airlint: allow[pread-seam] -- offline restore path: single-process,
    # CRC-checked per slice below; no serving retry/chaos semantics apply
    blob_fd = os.open(os.path.join(path, f"ckpt-{step}.blob"), os.O_RDONLY)
    stats = {"bytes_read": idx.bytes_read, "reads": idx.reads,
             "slices_read": 0}
    try:
        leaves_meta = meta["leaves"]
        by_leaf: dict[int, list] = {}
        for sid, s in enumerate(meta["slices"]):
            by_leaf.setdefault(s["leaf"], []).append((sid, s))
        flat, tree_def = jax.tree_util.tree_flatten_with_path(tree_like)
        out = []
        for li, (path_k, leaf) in enumerate(flat):
            name = "/".join(str(getattr(p, "key", p)) for p in path_k)
            lm = leaves_meta[li]
            assert lm["name"] == name, (lm["name"], name)
            if leaf_filter is not None and not leaf_filter(name):
                out.append(None)
                continue
            raw = b""
            for sid, s in by_leaf[li]:
                lo, hi = idx.lookup(sid)          # Alg. 1 on the manifest
                lo = max(min(lo, s["off"]), 0)
                hi = max(hi, s["off"] + s["size"])
                # airlint: allow[pread-seam] -- offline restore read; slice
                # integrity is the crc32 assert two lines down
                window = os.pread(blob_fd, hi - lo, lo)
                chunk = window[s["off"] - lo: s["off"] - lo + s["size"]]
                assert zlib.crc32(chunk) == s["crc"], f"corrupt slice {sid}"
                stats["bytes_read"] += hi - lo
                stats["reads"] += 1
                stats["slices_read"] += 1
                raw += chunk
            arr = np.frombuffer(raw, dtype=lm["dtype"]).reshape(lm["shape"])
            out.append(arr)
        stats["bytes_read"] += idx.bytes_read
        return jax.tree_util.tree_unflatten(tree_def, out), stats
    finally:
        idx.close()
        os.close(blob_fd)
