"""Fault tolerance & elasticity for the training launcher.

Mechanisms (exercised by tests/examples on the CPU container; the same
logic drives the multi-host launcher on a real cluster):

  * **Heartbeats** — every host touches ``hb/<host>.hb`` each step; the
    coordinator declares a host dead after ``timeout`` (here: injected
    failures flip a file flag).
  * **Checkpoint/restart** — periodic async checkpoints through
    checkpoint.py (AirIndex manifest ⇒ each host partially restores only
    its shards); on failure the run restarts from the latest step whose
    checkpoint passes crc validation.
  * **Elastic re-mesh** — on permanent host loss the mesh is re-formed
    with a smaller 'data' axis; the global batch is preserved by scaling
    per-host microbatches; the data cursor replays deterministically
    (ShardedTokenStore.batch_iterator(start_step=...)).
  * **Straggler mitigation** — per-step deadline with backup data-fetch
    dispatch; a host exceeding the deadline twice is treated as failed.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time


@dataclasses.dataclass
class FTConfig:
    checkpoint_every: int = 50
    heartbeat_timeout_s: float = 60.0
    step_deadline_s: float = 30.0
    max_restarts: int = 3


class HeartbeatMonitor:
    def __init__(self, root: str, hosts: list[str],
                 timeout_s: float = 60.0):
        self.root = os.path.join(root, "hb")
        os.makedirs(self.root, exist_ok=True)
        self.hosts = hosts
        self.timeout = timeout_s

    def beat(self, host: str, step: int):
        with open(os.path.join(self.root, f"{host}.hb"), "w") as f:
            json.dump({"t": time.time(), "step": step}, f)

    def kill(self, host: str):
        """Failure injection (tests)."""
        with open(os.path.join(self.root, f"{host}.dead"), "w") as f:
            f.write("1")

    def alive(self, host: str) -> bool:
        if os.path.exists(os.path.join(self.root, f"{host}.dead")):
            return False
        p = os.path.join(self.root, f"{host}.hb")
        if not os.path.exists(p):
            return True  # not started yet
        with open(p) as f:
            t = json.load(f)["t"]
        return (time.time() - t) < self.timeout

    def surviving(self) -> list[str]:
        return [h for h in self.hosts if self.alive(h)]


def elastic_mesh_shape(n_hosts: int, chips_per_host: int, model_parallel: int):
    """Largest (data, model) mesh from the surviving host set.

    'model' is fixed by the arch's TP degree; 'data' shrinks to the
    largest power-of-two slice of surviving chips (re-sharding params to a
    non-power-of-two data axis would churn every shard).
    """
    chips = n_hosts * chips_per_host
    data = chips // model_parallel
    p2 = 1
    while p2 * 2 <= data:
        p2 *= 2
    return (p2, model_parallel)


def rescale_batch(global_batch: int, old_data: int, new_data: int) -> int:
    """Per-host microbatch count that preserves the global batch exactly."""
    assert global_batch % new_data == 0, \
        f"global batch {global_batch} not divisible by data={new_data}"
    return global_batch // new_data


class TrainingSupervisor:
    """Restart loop: run → detect failure → shrink mesh → restore → resume.

    The step function and checkpoint hooks are injected so tests can drive
    it with a tiny model and injected failures.
    """

    def __init__(self, workdir: str, hosts: list[str], ft: FTConfig,
                 save_fn, restore_fn):
        self.workdir = workdir
        self.monitor = HeartbeatMonitor(workdir, hosts,
                                        ft.heartbeat_timeout_s)
        self.ft = ft
        self.save_fn = save_fn          # (state, step) -> None
        self.restore_fn = restore_fn    # (step) -> state
        self.log = []

    def latest_checkpoint_step(self) -> int:
        steps = []
        for fn in os.listdir(self.workdir):
            if fn.startswith("ckpt-") and fn.endswith(".json"):
                steps.append(int(fn.split("-")[1].split(".")[0]))
        return max(steps, default=-1)

    def run(self, state, step_fn, n_steps: int, start_step: int = 0):
        """→ (final_state, steps_done, events)."""
        step = start_step
        restarts = 0
        while step < n_steps:
            dead = [h for h in self.monitor.hosts
                    if not self.monitor.alive(h)]
            if dead:
                if restarts >= self.ft.max_restarts:
                    raise RuntimeError(f"too many restarts; dead={dead}")
                restarts += 1
                self.log.append({"event": "failure", "step": step,
                                 "dead": list(dead)})
                # shrink the host set, restore, resume
                self.monitor.hosts = self.monitor.surviving()
                ck = self.latest_checkpoint_step()
                if ck >= 0:
                    state = self.restore_fn(ck)
                    step = ck
                self.log.append({"event": "restart", "from_step": step,
                                 "hosts": len(self.monitor.hosts)})
            t0 = time.time()
            state = step_fn(state, step)
            if time.time() - t0 > self.ft.step_deadline_s:
                self.log.append({"event": "straggler", "step": step})
            for h in self.monitor.hosts:
                self.monitor.beat(h, step)
            step += 1
            if step % self.ft.checkpoint_every == 0:
                self.save_fn(state, step)
                self.log.append({"event": "checkpoint", "step": step})
        return state, step, self.log
