"""Training step: CE loss (+z-loss, +MoE aux), grad accumulation, remat.

The step is a pure function suitable for jax.jit with in/out shardings;
gradient accumulation scans over microbatches (sequential, activations
freed between microbatches) and the optimizer update runs once per step.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import api
from .optimizer import AdamWConfig, adamw_update


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    microbatches: int = 1          # grad-accumulation steps
    z_loss: float = 1e-4
    aux_loss_weight: float = 1e-2
    optimizer: AdamWConfig = AdamWConfig()


LOSS_CHUNK = 512  # sequence positions unembedded at a time


def _ce_chunk(cfg, unemb, hidden_c, labels_c):
    """CE + z-loss sums for one sequence chunk; never keeps full logits."""
    logits = hidden_c @ unemb
    if cfg.final_softcap is not None:
        logits = cfg.final_softcap * jnp.tanh(
            logits.astype(jnp.float32) / cfg.final_softcap)
    logits = logits.astype(jnp.float32)
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                          logits.ndim - 1)
    # vocab is padded to a shardable multiple (ModelConfig.padded_vocab);
    # padded columns are excluded from the partition function
    logits = jnp.where(vocab_iota < cfg.vocab, logits, -1e30)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    # label log-prob via masked sum (not take_along_axis): keeps the vocab
    # dim shardable — SPMD reduces a partial sum instead of gathering logits
    ll = jnp.sum(jnp.where(vocab_iota == labels_c[..., None], logits, 0.0),
                 axis=-1)
    return jnp.sum(logz - ll), jnp.sum(jnp.square(logz))


def loss_fn(cfg, params, batch, tcfg: TrainConfig):
    """Chunked-softmax CE: the (B,S,V) logits tensor is never materialized —
    hidden states are unembedded LOSS_CHUNK positions at a time inside a
    rematerialized scan (memory ≈ B·chunk·V_shard instead of B·S·V_shard)."""
    hidden, aux = api.forward_hidden(cfg, params, batch)
    labels = batch["labels"]
    B, S, d = hidden.shape
    chunk = min(LOSS_CHUNK, S)
    if S % chunk:
        chunk = S          # odd lengths: single chunk (tests/smoke only)
    n_tok = B * S
    nc = S // chunk
    hc = hidden.reshape(B, nc, chunk, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, nc, chunk).transpose(1, 0, 2)
    unemb = params["unembed"]

    def step(carry, xs):
        ce_s, z_s = carry
        h, l = xs
        dce, dz = jax.checkpoint(
            lambda hh, ll_: _ce_chunk(cfg, unemb, hh, ll_))(h, l)
        return (ce_s + dce, z_s + dz), None

    (ce_sum, z_sum), _ = jax.lax.scan(step, (0.0, 0.0), (hc, lc))
    ce = ce_sum / n_tok
    z = z_sum / n_tok
    total = ce + tcfg.z_loss * z + tcfg.aux_loss_weight * aux
    return total, {"ce": ce, "aux": aux, "z": z}


def _split_microbatches(batch, n):
    def split(x):
        B = x.shape[0]
        assert B % n == 0, f"batch {B} not divisible by microbatches {n}"
        return x.reshape(n, B // n, *x.shape[1:])
    return jax.tree.map(split, batch)


def make_train_step(cfg, tcfg: TrainConfig):
    """Returns step(params, opt_state, batch) → (params, opt_state, metrics)."""

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, batch, tcfg), has_aux=True)(params)
        return loss, metrics, grads

    def step(params, opt_state, batch):
        n = tcfg.microbatches
        if n == 1:
            loss, metrics, grads = grads_of(params, batch)
        else:
            micro = _split_microbatches(batch, n)

            def accum(carry, mb):
                g_acc, l_acc = carry
                loss, _, grads = grads_of(params, mb)
                return (jax.tree.map(jnp.add, g_acc, grads), l_acc + loss), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), _ = jax.lax.scan(accum, (g0, 0.0), micro)
            grads = jax.tree.map(lambda g: g / n, grads)
            loss = loss / n
            metrics = {}
        params, opt_state, opt_metrics = adamw_update(
            params, grads, opt_state, tcfg.optimizer)
        return params, opt_state, {"loss": loss, **opt_metrics}

    return step
