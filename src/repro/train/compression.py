"""Error-feedback int8 gradient compression for the cross-pod (DCN) axis.

Cross-pod data parallelism reduces gradients over the slowest link (DCN,
~12.5 GB/s vs 50 GB/s/link ICI).  Quantizing the pod-level all-reduce to
int8 cuts that traffic 4× (bf16→int8 halves, f32→int8 quarters); the
quantization error is carried in an *error-feedback* buffer so the scheme
is unbiased over time (SGD with error feedback converges at the same rate;
Karimireddy et al. 2019).

``compressed_psum`` runs inside shard_map over the 'pod' axis:
    q, new_err = quantize(g + err)
    g̃ = dequantize(psum(q)) / n_pods
The per-tensor scale is the max-abs (psum'd so all pods agree).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x, scale):
    q = jnp.clip(jnp.round(x / scale * 127.0), -127, 127).astype(jnp.int8)
    return q


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * (scale / 127.0)


def compress_decompress(x):
    """Single-tensor quantize→dequantize (for error modeling/tests)."""
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12)
    return dequantize_int8(quantize_int8(x, scale), scale)


def compressed_psum(grads, err, axis_name: str):
    """Int8 all-reduce with error feedback; call inside shard_map.

    grads/err: pytrees of f32 arrays (same structure).
    Returns (reduced_grads_mean, new_err).
    """
    n = jax.lax.psum(1, axis_name)

    def one(g, e):
        g = g.astype(jnp.float32) + e
        # shared scale: max over pods so quantization grids agree
        scale = jax.lax.pmax(jnp.maximum(jnp.max(jnp.abs(g)), 1e-12),
                             axis_name)
        q = quantize_int8(g, scale)
        deq_local = dequantize_int8(q, scale)
        new_e = g - deq_local                      # local residual
        summed = jax.lax.psum(q.astype(jnp.int32), axis_name)
        mean = summed.astype(jnp.float32) * (scale / 127.0) / n
        return mean, new_e

    flat_g, tree = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree_util.tree_leaves(err)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    means = jax.tree_util.tree_unflatten(tree, [o[0] for o in out])
    errs = jax.tree_util.tree_unflatten(tree, [o[1] for o in out])
    return means, errs


def init_error_state(grads_like):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)
