"""AdamW — pure-pytree, ZeRO-friendly.

Moments live in a configurable dtype (fp32 default; bf16 for grok-1 so the
fully-sharded state fits 16 GB/chip — DESIGN.md §5) and are sharded exactly
like their parameters, which under FSDP means the optimizer state is fully
sharded across the whole mesh (ZeRO-3-equivalent memory).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"
    warmup_steps: int = 100
    total_steps: int = 10_000


def opt_state_specs(param_specs, ocfg: AdamWConfig):
    dt = jnp.dtype(ocfg.moment_dtype)
    mom = jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, dt), param_specs)
    return {"m": mom, "v": mom,
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


def adamw_init(params, ocfg: AdamWConfig):
    dt = jnp.dtype(ocfg.moment_dtype)
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, dt), params)
    return {"m": zeros, "v": jax.tree.map(jnp.copy, zeros),
            "step": jnp.zeros((), jnp.int32)}


def _schedule(step, ocfg: AdamWConfig):
    warm = jnp.minimum(step / max(ocfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - ocfg.warmup_steps)
                    / max(ocfg.total_steps - ocfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return ocfg.lr * warm * (0.1 + 0.9 * cos)


def global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_update(params, grads, state, ocfg: AdamWConfig):
    """→ (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, ocfg.grad_clip / (gnorm + 1e-9))
    lr = _schedule(step, ocfg)
    b1, b2 = ocfg.b1, ocfg.b2
    mdt = jnp.dtype(ocfg.moment_dtype)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g)
        mhat = m32 / bc1
        vhat = v32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + ocfg.eps) + \
            ocfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * delta
        return newp.astype(p.dtype), m32.astype(mdt), v32.astype(mdt)

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    return new_params, {"m": new_m, "v": new_v, "step": step}, \
        {"grad_norm": gnorm, "lr": lr}
