from .optimizer import AdamWConfig, adamw_init, adamw_update, opt_state_specs
from .train_step import TrainConfig, loss_fn, make_train_step
