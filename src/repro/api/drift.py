"""Drift detection — when the serving reality leaves the tuned-for model.

AirIndex's core claim is that the optimal design is a *function of the
I/O profile* (Eq. 6): the recorded ``tune.cost`` is the expected
per-lookup latency under the profile the index was tuned for.  When the
*observed* per-lookup cost (``ServeStats.query_modeled_seconds``, plus
the measured per-pread latencies and the block-cache hit rate) walks away
from that recording, the design is stale and a retune — ideally a
warm-started one (``Index.retune(..., warm_start=True)``) — pays for
itself.  This module turns that comparison into a small, trendable value
object::

    svc = idx.serve(profile=deployed_tier, persist_stats=True)
    svc.lookup(batch); ...
    report = detect_drift(svc)
    if report.action == "retune":
        idx2 = idx.retune(report.observed_profile, warm_start=True)

``detect_drift_from_file`` runs the same comparison offline from the
persisted ``<path>.stats.json`` snapshots — no live service needed.
"""
from __future__ import annotations

import dataclasses
import math

from repro.core.storage import (CachedProfile, PROFILES, StorageProfile,
                                profile_from_dict)
from repro.serve.index_service import (MIN_FIT_SAMPLES, ServeStats,
                                       observed_profile_from_stats,
                                       untainted_read_samples)

#: observed/recorded per-lookup cost ratio beyond which we call drift
DRIFT_RATIO = 1.25
#: queries needed before the verdict is fully confident
MIN_QUERIES = 512


@dataclasses.dataclass(frozen=True)
class DriftReport:
    """Observed vs recorded per-lookup cost, with a recommended action.

    Three per-lookup numbers are compared (all E[T] seconds):

      * ``recorded_seconds``  — ``tune.cost`` from the index meta: what
        the design was tuned to deliver on its tuned-for tier;
      * ``predicted_seconds`` — the deployment profile's prediction for
        the same design on the observed traffic
        (``ServeStats.walk_query_seconds``: full-price Alg. 1 walk, no
        cache/residency) — so ``ratio = predicted/recorded`` isolates
        *storage-tier* drift and is robust to cache warm-up state;
      * ``observed_seconds``  — what lookups actually cost through the
        engine (residency + block cache), so ``cache_gain =
        observed/predicted ≤ 1`` quantifies the headroom a retune for
        the observed :class:`CachedProfile` can exploit.

    ``confidence`` grows with the number of observed queries
    (``min(1, queries/min_queries)``); ``action`` is ``"retune"``
    (drifted, enough evidence), ``"observe"`` (not enough queries), or
    ``"none"``.  ``observed_profile`` is the effective ``T(Δ)`` to hand
    to ``Index.retune(..., warm_start=True)``.
    """

    observed_seconds: float          # engine per-lookup E[T] (cache-aware)
    predicted_seconds: float         # full-price walk on the deployed tier
    recorded_seconds: float | None   # tune.cost from the index meta
    ratio: float                     # predicted / recorded (inf if unknown)
    cache_gain: float                # observed / predicted (≤ 1 typically)
    confidence: float                # 0..1
    queries: int
    hit_rate: float
    drifted: bool
    action: str                      # "none" | "observe" | "retune"
    observed_profile: CachedProfile | None = None
    threshold: float = DRIFT_RATIO
    # online per-lookup latency quantiles (per-query seconds, estimated
    # from the uniform lookup reservoir); None before any lookups.  These
    # are what a p99 SLO actually experiences — the raw material for
    # deciding to retune with a quantile objective.
    observed_p50_seconds: float | None = None
    observed_p99_seconds: float | None = None

    def describe(self) -> str:
        rec = (f"{self.recorded_seconds * 1e6:.1f}us"
               if self.recorded_seconds is not None else "n/a")
        return (f"DriftReport(observed={self.observed_seconds * 1e6:.1f}us, "
                f"predicted={self.predicted_seconds * 1e6:.1f}us, "
                f"recorded={rec}, ratio={self.ratio:.2f}, "
                f"cache_gain={self.cache_gain:.2f}, "
                f"confidence={self.confidence:.2f}, "
                f"hit_rate={self.hit_rate:.3f}, action={self.action})")

    def to_dict(self) -> dict:
        """JSON-safe trend record (benchmarks persist these per PR)."""
        fin = lambda v: v if v is not None and math.isfinite(v) else None  # noqa: E731
        return {
            "observed_us": fin(self.observed_seconds * 1e6),
            "predicted_us": fin(self.predicted_seconds * 1e6),
            "recorded_us": (fin(self.recorded_seconds * 1e6)
                            if self.recorded_seconds is not None else None),
            "ratio": fin(self.ratio),
            "cache_gain": fin(self.cache_gain),
            "confidence": self.confidence,
            "queries": self.queries,
            "hit_rate": self.hit_rate,
            "drifted": self.drifted,
            "action": self.action,
            "threshold": self.threshold,
            "observed_p50_us": (fin(self.observed_p50_seconds * 1e6)
                                if self.observed_p50_seconds is not None
                                else None),
            "observed_p99_us": (fin(self.observed_p99_seconds * 1e6)
                                if self.observed_p99_seconds is not None
                                else None),
        }


def drift_from_stats(stats: ServeStats, recorded_cost: float | None, *,
                     backing: StorageProfile | None = None,
                     cache: StorageProfile | None = None,
                     threshold: float = DRIFT_RATIO,
                     min_queries: int = MIN_QUERIES,
                     measured: bool = True,
                     distributional: bool = False) -> DriftReport:
    """Pure comparison of a :class:`ServeStats` against a recorded cost —
    shared by the live (:func:`detect_drift`) and offline
    (:func:`detect_drift_from_file`) entry points.

    Drift is symmetric: a tier that got *faster* (ratio < 1/threshold)
    is as stale as one that degraded — the optimum moves either way
    (paper Fig. 1: profile moves, design moves).
    """
    observed = stats.query_modeled_seconds
    predicted = stats.walk_query_seconds
    queries = int(stats.queries)
    confidence = min(1.0, queries / float(max(min_queries, 1)))
    # a fault-dominated window: the reservoir is full enough to fit a
    # profile, but (nearly) everything in it is tainted — retried,
    # repaired, or deadline-hit reads.  Nothing trustworthy can be
    # fitted (measured/distributional fits return None), and a drift
    # verdict from such a window would model a flaky tier as a slow
    # one, so the report degrades to a confidence-0 "observe".
    if len(stats.read_samples) >= MIN_FIT_SAMPLES \
            and len(untainted_read_samples(stats)) < MIN_FIT_SAMPLES:
        confidence = 0.0
    if recorded_cost is not None and recorded_cost > 0 \
            and math.isfinite(predicted):
        ratio = predicted / recorded_cost
    else:
        ratio = float("inf")
    cache_gain = (observed / predicted
                  if math.isfinite(observed) and predicted > 0
                  else float("inf"))
    drifted = math.isfinite(ratio) and not (1.0 / threshold <= ratio
                                            <= threshold)
    if not math.isfinite(ratio) or confidence < 1.0:
        action = "observe"
    elif drifted:
        action = "retune"
    else:
        action = "none"
    profile = None
    if backing is not None:
        profile = observed_profile_from_stats(stats, backing, cache,
                                              measured=measured,
                                              distributional=distributional)
    p50 = stats.lookup_quantile(0.5)
    p99 = stats.lookup_quantile(0.99)
    return DriftReport(observed_seconds=float(observed),
                       predicted_seconds=float(predicted),
                       recorded_seconds=(float(recorded_cost)
                                         if recorded_cost is not None
                                         else None),
                       ratio=float(ratio), cache_gain=float(cache_gain),
                       confidence=float(confidence),
                       queries=queries, hit_rate=float(stats.hit_rate),
                       drifted=bool(drifted), action=action,
                       observed_profile=profile, threshold=float(threshold),
                       observed_p50_seconds=p50, observed_p99_seconds=p99)


def detect_drift(service, *, threshold: float = DRIFT_RATIO,
                 min_queries: int = MIN_QUERIES,
                 measured: bool = True,
                 distributional: bool = False) -> DriftReport:
    """Compare a live :class:`repro.serve.IndexService`'s observed E[T]
    against the ``tune.cost`` recorded in its file meta.
    ``distributional=True`` makes the report's ``observed_profile`` carry
    the per-Δ distribution fit — the input a quantile-objective retune
    needs."""
    recorded = (service.tune_meta or {}).get("cost")
    return drift_from_stats(service.stats, recorded,
                            backing=service.profile,
                            cache=service.cache_profile,
                            threshold=threshold, min_queries=min_queries,
                            measured=measured, distributional=distributional)


def detect_drift_from_file(index_path: str, *,
                           backing: StorageProfile | str | None = None,
                           cache: StorageProfile | None = None,
                           threshold: float = DRIFT_RATIO,
                           min_queries: int = MIN_QUERIES,
                           measured: bool = True,
                           distributional: bool = False) -> DriftReport | None:
    """Offline observe→retune: read the persisted ``<path>.stats.json``
    snapshot and the index meta's recorded cost/profile, no service
    required.  ``backing`` defaults to the profile the snapshot was
    *served* under (recorded per snapshot by ``save_stats_snapshot``) —
    the observed_profile must describe the deployment tier, not the
    tuned-for tier the report may be flagging as stale — falling back to
    the meta's tuned-for profile for snapshots without a profile name.
    Returns None when no snapshot has been persisted yet.

    Robust to damage: a corrupt or truncated stats file never raises —
    unreadable snapshots are skipped newest-first (``load_stats_history``
    warns), and a stats file that exists but yields nothing usable
    produces a low-confidence ``action="observe"`` report (empty stats →
    confidence 0) rather than an exception, so a fleet startup reading N
    of these degrades per shard instead of failing."""
    import os
    import warnings

    from repro.core.serialize import read_meta_path
    from repro.serve.index_service import load_stats_history, stats_path

    history = load_stats_history(index_path)
    if not history and not os.path.exists(stats_path(index_path)):
        return None
    stats = used_snap = None
    for snap in reversed(history):
        try:
            stats = ServeStats.from_snapshot(snap["stats"])
            used_snap = snap
            break
        except (KeyError, TypeError, ValueError, IndexError):
            warnings.warn(
                f"stats file {stats_path(index_path)!r}: skipping a "
                f"snapshot that does not decode as ServeStats",
                RuntimeWarning, stacklevel=2)
    if stats is None:
        # file present but nothing loadable: report "keep observing" at
        # zero confidence instead of raising
        warnings.warn(
            f"stats file {stats_path(index_path)!r} holds no usable "
            f"snapshot; returning a low-confidence observe report",
            RuntimeWarning, stacklevel=2)
        stats = ServeStats()
    meta = read_meta_path(index_path)
    tune = meta.tune or {}
    if cache is None:
        # IndexService's default cache tier, so the offline profile
        # compares field-equal to the live service's observed_profile()
        cache = PROFILES["host_dram"]
    if isinstance(backing, str):
        backing = PROFILES[backing]
    if backing is None and used_snap is not None:
        served = used_snap.get("profile")
        if served in PROFILES:
            backing = PROFILES[served]
    if backing is None:
        backing = profile_from_dict(tune.get("profile_params"))
        if backing is None and tune.get("profile") in PROFILES:
            backing = PROFILES[tune["profile"]]
    return drift_from_stats(stats, tune.get("cost"), backing=backing,
                            cache=cache, threshold=threshold,
                            min_queries=min_queries, measured=measured,
                            distributional=distributional)
