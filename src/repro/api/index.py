"""The :class:`Index` facade — one object from tune → disk → serve.

Old lifecycle (scattered):   ``airtune(D, prof)`` → ``write_index(path,
design)`` → ``SerializedIndex(path)`` / ``IndexService(path, ...)`` with
nothing carrying the design, its stats, and its serialized form together.

New lifecycle (one handle)::

    idx = Index.tune(D, "azure_ssd", TuneSpec(strategy="beam", k=4,
                                              page_bytes=4096))
    idx.build()                   # run the search (implicit on first use)
    idx.save("index.air")         # paged layout + TuneSpec provenance
    ranges = idx.lookup(keys)     # in-memory batched Alg. 1

    idx2 = Index.open("index.air")        # remembers its TuneSpec
    svc = idx2.serve()                    # IndexService, spec defaults
    idx3 = idx2.retune(new_profile, data=D)   # same spec, new tier

All lookup paths return bit-identical ``(q, 2)`` data-layer byte ranges
(shared per-layer descent, see :mod:`repro.core.descent`).
"""
from __future__ import annotations

import dataclasses
import inspect

import numpy as np

from repro.core.keyset import KeyPositions
from repro.core.latency import IndexDesign, expected_latency
from repro.core.lookup import lookup_batch
from repro.core.nodes import BandLayer, StepLayer, outline
from repro.core.registry import SEARCH_STRATEGIES
from repro.core.airtune import TuneResult, TuneStats
from repro.core.serialize import (SerializedIndex, materialize_design,
                                  read_meta_path, write_index)
from repro.core.storage import (PROFILES, StorageProfile,
                                normalize_objective, profile_from_dict,
                                profile_to_dict)
from repro.core.sweep import DEFAULT_CACHE_ENTRIES, LayerCache

from .spec import ServeSpec, TuneSpec

#: valid Index.serve() keyword overrides (besides ``profile``)
_SERVE_FIELDS = frozenset(f.name for f in dataclasses.fields(ServeSpec))
_MISSING = object()


def resolve_profile(profile) -> tuple[StorageProfile | None, str | None]:
    """Accept a profile name, a StorageProfile, or None → (profile, name)."""
    if profile is None:
        return None, None
    if isinstance(profile, str):
        try:
            return PROFILES[profile], profile
        except KeyError:
            raise KeyError(
                f"unknown storage profile {profile!r}; named profiles: "
                f"{', '.join(sorted(PROFILES))}") from None
    if isinstance(profile, StorageProfile):
        return profile, getattr(profile, "name", None)
    raise TypeError(f"profile must be a name, StorageProfile, or None; "
                    f"got {type(profile).__name__}")


# ---------------------------------------------------------------------------
# warm-start seed recovery (ROADMAP: incremental re-tune on drift)
# ---------------------------------------------------------------------------
# Step layers lose their node grouping on disk (serialize.materialize_design
# treats each piece as a node) and band layers lose clamp_lo; seeding the
# search's LayerCache with such a layer would poison the memo — the cached
# outline would differ from what the named builder builds.  These helpers
# restore the exact build, per family discipline, before seeding.
_STEP_GROUPING = {
    "gstep": lambda b: int(b.p),
}
_BAND_KINDS = frozenset({"gband", "eband", "pgm", "rmi_leaf"})


def _btree_grouping(b) -> int:
    from repro.core.baselines import btree_fanout   # lazy: api sits above
    return btree_fanout(b.lam)


_STEP_GROUPING["btree"] = _btree_grouping


def _canonical_seed_layer(layer, builder, cur: KeyPositions):
    """The layer exactly as ``builder`` would (re)build it on ``cur``, or
    None when fidelity cannot be guaranteed (unknown family discipline)."""
    if isinstance(layer, StepLayer):
        grouping = _STEP_GROUPING.get(builder.kind)
        if grouping is None:
            return None
        p = max(grouping(builder), 1)
        P = layer.n_pieces
        off = np.append(np.arange(0, P, p, dtype=np.int64), np.int64(P))
        return StepLayer(piece_keys=layer.piece_keys,
                         piece_pos=layer.piece_pos, node_piece_off=off)
    if isinstance(layer, BandLayer) and builder.kind in _BAND_KINDS:
        # fit_bands_for_groups anchors clamp_lo at the collection's first
        # position; the file format only records clamp_hi (end_pos)
        return dataclasses.replace(layer, clamp_lo=int(cur.lo[0]))
    return None


def recover_seed_layers(builder_names, layers, builders,
                        data: KeyPositions) -> list:
    """Reconstruct warm-start ``(name, layer)`` seed pairs from a
    disk-materialized design + its recorded builder provenance.  Stops at
    the first layer whose recorded builder is absent from ``builders`` or
    whose family discipline we cannot restore bit-exactly (the collections
    above it would no longer line up with search vertices)."""
    by_name = {b.name: b for b in builders}
    out: list = []
    cur = data
    for name, layer in zip(builder_names, layers):
        b = by_name.get(name)
        if b is None:
            break
        fixed = _canonical_seed_layer(layer, b, cur)
        if fixed is None:
            break
        out.append((name, fixed))
        cur = outline(fixed, cur)
    return out


def _strategy_accepts(strategy, name: str) -> bool:
    """Third-party strategies (SearchStrategy protocol) need not accept the
    built-ins' extended kwargs — pass them only when the signature does."""
    try:
        params = inspect.signature(strategy).parameters
    except (TypeError, ValueError):
        return False
    return name in params or any(
        p.kind is inspect.Parameter.VAR_KEYWORD for p in params.values())


class Index:
    """Facade over the full index lifecycle; construct via
    :meth:`tune`, :meth:`from_design`, or :meth:`open`."""

    def __init__(self, *, data=None, profile=None, profile_name=None,
                 spec=None, serve_spec=None, result=None, path=None,
                 file_meta=None):
        self._data: KeyPositions | None = data
        self._profile: StorageProfile | None = profile
        self._profile_name: str | None = profile_name
        self._spec: TuneSpec | None = spec
        self._serve_spec: ServeSpec | None = serve_spec
        self._result: TuneResult | None = result
        self._path: str | None = path
        self._file_meta = file_meta
        # opened from disk (vs declared via tune/from_design): the file IS
        # the design — never silently re-search on attribute access
        self._from_disk = file_meta is not None and result is None
        self._disk_design: IndexDesign | None = None
        self._handle: SerializedIndex | None = None
        # warm-start state: a LayerCache retained across build/retune and
        # the previous design's (builder_name, layer) seed pairs
        self._layer_cache: LayerCache | None = None
        self._seed_layers: list | None = None

    # -- constructors -------------------------------------------------------
    @classmethod
    def tune(cls, data: KeyPositions, profile, spec: TuneSpec | None = None,
             **overrides) -> "Index":
        """Declare a tuning problem: Θ* = argmin L_SM(X; Θ, T) under
        ``spec``.  The search runs on :meth:`build` (implicitly triggered
        by ``design`` / ``save`` / ``lookup``).  ``overrides`` are
        TuneSpec field replacements, e.g. ``strategy="beam"``."""
        spec = spec if spec is not None else TuneSpec()
        if overrides:
            spec = spec.replace(**overrides)
        prof, pname = resolve_profile(profile)
        if prof is None:
            raise ValueError("Index.tune requires a storage profile")
        return cls(data=data, profile=prof, profile_name=pname, spec=spec)

    @classmethod
    def from_design(cls, design: IndexDesign, spec: TuneSpec | None = None,
                    profile=None) -> "Index":
        """Wrap an explicitly-built design (manual stacks, demo designs)
        in the facade lifecycle.  ``cost`` is evaluated via Eq. (6) when a
        profile is given, else NaN."""
        prof, pname = resolve_profile(profile)
        cost = expected_latency(design, prof) if prof is not None \
            else float("nan")
        result = TuneResult(design=design, cost=cost, stats=TuneStats(),
                            strategy="manual", builder_names=())
        return cls(data=design.data, profile=prof, profile_name=pname,
                   spec=spec, result=result)

    @classmethod
    def open(cls, path: str, data: KeyPositions | None = None) -> "Index":
        """Open a serialized index.  The recorded :class:`TuneSpec` and
        :class:`ServeSpec` (if the file was written by :meth:`save`) are
        restored; pass ``data`` to enable full materialization
        (``.design``) and :meth:`retune`."""
        meta = read_meta_path(path)
        spec = sspec = prof = pname = None
        if meta.tune:
            if meta.tune.get("spec") is not None:
                try:
                    spec = TuneSpec.from_dict(meta.tune["spec"])
                except (TypeError, ValueError):
                    spec = None   # forward/hand-edited provenance must not
                    #               make a readable file unopenable; the raw
                    #               dict stays available via file_meta.tune
            if meta.tune.get("serve") is not None:
                try:
                    sspec = ServeSpec.from_dict(meta.tune["serve"])
                except (TypeError, ValueError):
                    sspec = None
            pname = meta.tune.get("profile")
            # full parameters first (measured/custom tiers), name fallback
            prof = profile_from_dict(meta.tune.get("profile_params"))
            if prof is None and pname in PROFILES:
                prof = PROFILES[pname]
        return cls(path=path, file_meta=meta, data=data, spec=spec,
                   serve_spec=sspec, profile=prof, profile_name=pname)

    # -- lifecycle ----------------------------------------------------------
    def build(self) -> "Index":
        """Run the configured search strategy (idempotent).  For an Index
        opened from disk this is a no-op — the file already holds the
        design; use :meth:`retune` to search again."""
        if self._from_disk:
            return self
        if self._result is None:
            if self._data is None:
                raise ValueError("no data to build from")
            if self._profile is None:
                raise ValueError("no storage profile to tune for")
            if self._spec is None:
                self._spec = TuneSpec()
            spec = self._spec.validate()
            strategy = SEARCH_STRATEGIES.get(spec.strategy)
            kwargs = {}
            if _strategy_accepts(strategy, "layer_cache"):
                # retained so a later warm retune reuses every build;
                # bounded: a long-lived observe→retune loop shares ONE
                # cache across generations (oldest entries evict)
                if self._layer_cache is None:
                    self._layer_cache = LayerCache(
                        max_entries=DEFAULT_CACHE_ENTRIES)
                kwargs["layer_cache"] = self._layer_cache
            if self._seed_layers and _strategy_accepts(strategy,
                                                       "seed_layers"):
                kwargs["seed_layers"] = self._seed_layers
            if _strategy_accepts(strategy, "objective"):
                kwargs["objective"] = spec.objective
            elif normalize_objective(spec.objective) is not None:
                # a quantile objective silently tuned for the mean would
                # be the worst failure mode: loud refusal instead
                raise ValueError(
                    f"strategy {spec.strategy!r} does not accept the "
                    f"'objective' kwarg; quantile objectives require an "
                    f"objective-aware strategy (built-ins: airtune, "
                    f"brute_force, beam)")
            self._result = strategy(self._data, self._profile,
                                    spec.builders(), k=spec.k,
                                    max_layers=spec.max_layers, **kwargs)
        return self

    def save(self, path: str, *, data_record: int = 0,
             page_bytes: int | None = None,
             serve_spec: ServeSpec | None = None) -> "Index":
        """Serialize (building first if needed) with TuneSpec provenance.

        ``page_bytes`` defaults to the spec's; the recorded meta lets
        :meth:`open` restore the spec and :class:`repro.serve.IndexService`
        pick up the spec's cache configuration.  ``serve_spec`` (or one
        already attached to this Index) is recorded alongside — a reopened
        index then serves with that configuration by default."""
        self.build()
        if self._result is None:       # disk-opened: nothing new to write
            raise ValueError(
                "save() needs an in-memory design: this Index was opened "
                "from disk; the file already exists (use retune() to search "
                "again, then save the result)")
        if page_bytes is None:
            pb = self._spec.page_bytes if self._spec is not None else 0
        else:
            pb = page_bytes
        # provenance must describe the file as written: a page_bytes
        # override is recorded into the spec, not silently dropped
        spec = self._spec.replace(page_bytes=pb) \
            if self._spec is not None else None
        if serve_spec is not None:
            self._serve_spec = serve_spec.validate()
        cost = float(self._result.cost)
        tune_meta = {
            "spec": spec.to_dict() if spec is not None else None,
            "serve": (self._serve_spec.to_dict()
                      if self._serve_spec is not None else None),
            "strategy": self._result.strategy,
            # NaN is not valid strict JSON — null out unknown costs
            "cost": cost if np.isfinite(cost) else None,
            "builder_names": list(self._result.builder_names),
            # the objective `cost` was minimized under ("mean" | {p, weight});
            # also present inside spec.objective for spec-carrying indexes
            "objective": self._result.objective,
            "profile": self._profile_name,
            "profile_params": profile_to_dict(self._profile),
        }
        self._file_meta = write_index(path, self.design,
                                      data_record=data_record,
                                      page_bytes=pb, tune=tune_meta)
        self._path = path
        return self

    def serve(self, spec: ServeSpec | None = None, backend_factory=None,
              **overrides):
        """Open a batched :class:`repro.serve.IndexService` on the saved
        file.  Defaults flow from the facade: the tuned-for profile applies
        unless ``profile=`` overrides it, and the :class:`ServeSpec`
        recorded at save time (else field defaults) configures the engine.
        Keyword overrides are ServeSpec field replacements — e.g.
        ``idx.serve(backend="pallas", pipeline_depth=2)``.
        ``backend_factory`` (``path -> StorageBackend``) passes through to
        the engine — the chaos-testing seam."""
        if self._path is None:
            raise ValueError(
                "serve() needs an on-disk index: call save(path) first "
                "(or open an existing file with Index.open)")
        from repro.serve.index_service import IndexService
        profile = overrides.pop("profile", _MISSING)
        if profile is _MISSING:
            # the tuned-for tier; an untuned handle gets the engine default
            profile = self._profile if self._profile is not None \
                else "azure_ssd"
        if "use_device" in overrides:
            from repro.core.deprecation import warn_deprecated
            warn_deprecated(
                "repro.serve.Index.serve(use_device=...) is deprecated; "
                "pass backend='pallas' (a ServeSpec field) instead",
                stacklevel=3, once=True)
            overrides["backend"] = ("pallas" if overrides.pop("use_device")
                                    else "numpy")
        base = spec if spec is not None else self._serve_spec
        if overrides:
            unknown = set(overrides) - _SERVE_FIELDS
            if unknown:
                raise TypeError(
                    f"serve() got unexpected keyword(s) {sorted(unknown)}; "
                    f"valid ServeSpec fields: {sorted(_SERVE_FIELDS)}")
            if overrides.get("cache_bytes", _MISSING) is None:
                overrides.pop("cache_bytes")   # None keeps engine defaults
            base = (base if base is not None
                    else ServeSpec()).replace(**overrides)
        return IndexService(self._path, profile=profile, spec=base,
                            backend_factory=backend_factory)

    def observe(self, service=None, **kwargs):
        """Drift check against live serving: compare a service's observed
        behavior (hit rate, measured pread latency) with the cost recorded
        at tune time → :class:`repro.api.DriftReport`.  With no
        ``service``, falls back to :meth:`observe_offline` on this Index's
        file.  Keyword args pass through to ``detect_drift`` (e.g.
        ``threshold=``)."""
        from .drift import detect_drift
        if service is None:
            return self.observe_offline(**kwargs)
        return detect_drift(service, **kwargs)

    def observe_offline(self, path: str | None = None, **kwargs):
        """Drift check from the persisted stats snapshot next to the index
        file (``persist_stats=True`` serving writes it on close) — the
        offline half of the observe→retune loop.  None when no snapshot
        exists yet.  Keyword args pass through to
        ``detect_drift_from_file``."""
        path = path if path is not None else self._path
        if path is None:
            raise ValueError(
                "observe_offline() needs an on-disk index: call save(path) "
                "first (or open an existing file with Index.open)")
        from .drift import detect_drift_from_file
        return detect_drift_from_file(path, **kwargs)

    def retune(self, profile=None, data: KeyPositions | None = None,
               warm_start: bool = False, **spec_overrides) -> "Index":
        """Re-tune with the recorded spec — e.g. when the storage profile
        changed (new tier, or an observed ``CachedProfile`` from a
        :class:`DriftReport`).  Returns a fresh unsaved :class:`Index`;
        the original is untouched.

        ``warm_start=True`` seeds the new search with the previous design:
        its layers (taken from the in-memory result, or recovered from the
        file meta outlines for a disk-opened Index) pre-populate the
        search's layer cache, and this Index's retained
        :class:`~repro.core.sweep.LayerCache` is shared with the new
        search — a drift-triggered retune rebuilds only what the profile
        change actually moves.  Pure memoization for ``airtune`` /
        ``brute_force`` (bit-identical result, strictly less work); the
        ``beam`` strategy additionally starts its frontier from the
        previous design's partial stacks."""
        data = data if data is not None else self._data
        if data is None and self._result is not None:
            data = self._result.design.data
        if data is None:
            raise ValueError(
                "retune needs the data layer: pass data= (an Index opened "
                "from disk does not store it)")
        prof = profile if profile is not None else self._profile
        if prof is None:
            raise ValueError("retune needs a storage profile")
        spec = self._spec if self._spec is not None else TuneSpec()
        if spec_overrides:
            spec = spec.replace(**spec_overrides)
        new = Index.tune(data, prof, spec)
        if warm_start:
            if self._layer_cache is None:
                self._layer_cache = LayerCache(
                    max_entries=DEFAULT_CACHE_ENTRIES)
            new._layer_cache = self._layer_cache   # shared build memo
            new._seed_layers = self._warm_seed_layers(data, spec)
        return new

    def _warm_seed_layers(self, data: KeyPositions, spec: TuneSpec) -> list:
        """The previous design as ``(builder_name, layer)`` seed pairs —
        exact from the in-memory result, canonicalized from disk."""
        if self._result is not None:
            names = self._result.builder_names
            layers = self._result.design.layers
            if len(names) == len(layers):
                return list(zip(names, layers))
            return []
        if self._from_disk and self._path is not None:
            names = tuple((self._file_meta.tune or {})
                          .get("builder_names") or ())
            if not names:
                return []
            layers = materialize_design(self._path, data).layers
            return recover_seed_layers(names, layers, spec.builders(), data)
        return []

    def close(self) -> None:
        if self._handle is not None:
            self._handle.close()
            self._handle = None

    def __enter__(self) -> "Index":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __del__(self):
        # disk lookups cache a SerializedIndex fd; don't leak it when the
        # caller skips the context-manager form
        try:
            self.close()
        except Exception:
            pass

    # -- queries ------------------------------------------------------------
    def lookup(self, keys) -> np.ndarray:
        """Batched Alg. 1 → ``(q, 2)`` int64 data-layer byte ranges.

        In-memory designs use :func:`repro.core.lookup_batch`; disk-opened
        indexes use the partial-read :class:`SerializedIndex` walk.  Both
        share the same per-layer descent and agree bit-for-bit."""
        q = np.atleast_1d(np.asarray(keys, dtype=np.uint64))
        if not self._from_disk:
            res = lookup_batch(self.design, q)
            return np.stack([np.asarray(res.lo, dtype=np.int64),
                             np.asarray(res.hi, dtype=np.int64)], axis=1)
        if self._handle is None:
            self._handle = SerializedIndex(self._path)
        return np.array([self._handle.lookup(int(x)) for x in q],
                        dtype=np.int64).reshape(len(q), 2)

    # -- introspection ------------------------------------------------------
    @property
    def design(self) -> IndexDesign:
        """The built :class:`IndexDesign` (searches / materializes lazily)."""
        if self._from_disk:
            if self._data is None:
                raise ValueError(
                    "cannot materialize the design without the data layer; "
                    "pass data= to Index.open")
            if self._disk_design is None:
                self._disk_design = materialize_design(self._path, self._data)
            return self._disk_design
        return self.build()._result.design

    @property
    def result(self) -> TuneResult:
        if self._from_disk:
            raise ValueError(
                "no in-memory tune result: this Index was opened from disk "
                "(see file_meta.tune for the recorded strategy/cost, or "
                "retune() to search again)")
        return self.build()._result

    @property
    def cost(self) -> float:
        """L_SM of the design; for a disk-opened Index, the recorded cost
        from the file meta (NaN when the file has no provenance)."""
        if self._from_disk:
            c = (self._file_meta.tune or {}).get("cost")
            return float(c) if c is not None else float("nan")
        return self.result.cost

    @property
    def stats(self) -> TuneStats:
        return self.result.stats

    @property
    def spec(self) -> TuneSpec | None:
        """The originating TuneSpec (None for files without provenance)."""
        return self._spec

    @property
    def serve_spec(self) -> ServeSpec | None:
        """The recorded ServeSpec (None: engine defaults serve)."""
        return self._serve_spec

    @property
    def profile(self) -> StorageProfile | None:
        return self._profile

    @property
    def path(self) -> str | None:
        return self._path

    @property
    def file_meta(self):
        return self._file_meta

    def describe(self) -> str:
        if self._from_disk:
            t = self._file_meta.tune or {}
            cost = t.get("cost")
            fams = ",".join((t.get("spec") or {}).get("families") or ())
            names = "<-".join(t.get("builder_names") or ())
            return (f"Index(open: {self._path}, "
                    f"strategy={t.get('strategy') or 'unknown'}, "
                    f"recorded_cost="
                    f"{f'{cost * 1e6:.1f}us' if cost is not None else 'n/a'}, "
                    f"spec={'recorded' if self._spec is not None else 'none'}, "
                    f"families=[{fams}], builders=[{names}])")
        if self._result is not None:
            loc = f" @ {self._path}" if self._path else ""
            return self._result.describe() + loc
        # never launch the search just to format a status string
        return f"Index(unbuilt, spec={self._spec!r})"
