"""``repro.api`` — the single public entry point for AirIndex.

One object carries the whole lifecycle::

    from repro.api import Index, TuneSpec

    spec = TuneSpec(strategy="beam", k=4, page_bytes=4096,
                    cache_bytes=(256 << 10, 2 << 20))
    idx = Index.tune(D, "azure_ssd", spec).build()
    idx.save("index.air")                  # records the spec on disk
    svc = Index.open("index.air").serve()  # spec defaults drive the engine

Extensibility (the paper's open-ended builder family, arXiv:2208.03823)::

    from repro.api import register_builder, register_strategy

    @register_builder("myfamily")          # participates in Alg. 2
    def build_my_layer(D, lam, p): ...

    @register_strategy("mysearch")         # SearchStrategy protocol
    def my_search(D, profile, builders=None, *, k=5, max_layers=12): ...

The engine layer stays importable (``repro.core``, ``repro.serve``) —
this package is a facade, not a wall.
"""
from repro.core.airtune import SearchStrategy, TuneResult, TuneStats
from repro.core.baselines import BASELINE_FAMILIES
from repro.core.registry import (BUILDER_FAMILIES, SEARCH_STRATEGIES,
                                 Registry, register_builder,
                                 register_strategy)
from repro.core.storage import PROFILES, StorageProfile

from .drift import (DriftReport, detect_drift, detect_drift_from_file,
                    drift_from_stats)
from .index import Index, resolve_profile
from .spec import RetryPolicy, ServeSpec, TuneSpec

# fleet sits above the facade (its modules import repro.api.index/spec
# directly), so this re-export must come after the locals above
from repro.fleet import Fleet, FleetService, FleetSpec, ShardMap  # noqa: E402

__all__ = [
    "Index", "TuneSpec", "ServeSpec", "RetryPolicy",
    "Fleet", "FleetSpec", "FleetService", "ShardMap",
    "SearchStrategy", "TuneResult", "TuneStats",
    "DriftReport", "detect_drift", "detect_drift_from_file",
    "drift_from_stats",
    "BASELINE_FAMILIES", "BUILDER_FAMILIES", "SEARCH_STRATEGIES", "Registry",
    "register_builder", "register_strategy",
    "PROFILES", "StorageProfile", "resolve_profile",
]
