"""Declarative tuning and serving specifications — the "how", as data.

A :class:`TuneSpec` names everything Alg. 2 needs beyond the data and the
storage profile: which builder families compete (registry names), the
λ-grid they are instantiated on (Eq. 8), the search strategy and its
knobs, and the serving-side layout/cache configuration.  A
:class:`ServeSpec` is its serving-side twin: everything the batched engine
(:class:`repro.serve.IndexService`) needs beyond (file, deployment tier) —
cache tiers, residency, descent backend, and the two-stage pipeline knobs.
Both are frozen value objects that round-trip through JSON losslessly, so
the facade can record them into the on-disk index meta — a reopened index
remembers how it was tuned AND how it is meant to be served.
"""
from __future__ import annotations

import dataclasses
import json

from repro.core.builders import DEFAULT_FAMILIES, LayerBuilder, make_builders
from repro.core.registry import BUILDER_FAMILIES, SEARCH_STRATEGIES
from repro.core.storage import PROFILES, normalize_objective

#: resident-prefix descent backends, in fallback order (fused_descent ops)
SERVE_BACKENDS = ("pallas", "jnp", "numpy")


@dataclasses.dataclass(frozen=True)
class TuneSpec:
    """Everything needed to (re)produce a tuned index from (data, profile).

    Fields
    ------
    families:    builder-family names resolved through the registry; any
                 family registered via ``repro.api.register_builder``
                 participates in the search.  Besides the paper's deployed
                 set (``gstep``/``gband``/``eband``), the baseline
                 families ``btree``/``rmi_leaf``/``pgm``
                 (:data:`repro.core.baselines.BASELINE_FAMILIES`) are
                 registered and can be mixed in freely — e.g.
                 ``families=("btree", "pgm", "gstep")``.
    lam_low/lam_high/lam_base: the Eq. (8) granularity grid
                 ``λ_low · lam_base^j ≤ λ_high``.
    p:           pieces per step node (gstep-family parameter).
    k:           search width (top-k selection / beam width).
    max_layers:  index depth bound.
    strategy:    search-strategy name resolved through the registry
                 (``airtune`` | ``brute_force`` | ``beam`` | registered).
    page_bytes:  on-disk layout page size used by ``Index.save`` (0 =
                 densely packed; >0 = paged, the serving cache unit).
    cache_bytes: default tiered-cache capacities (hottest first) that
                 ``Index.serve()`` / ``IndexService`` use when the caller
                 does not override them; () = engine default.
    objective:   what the search minimizes — ``"mean"`` (Eq. 6 expected
                 lookup latency; the default, bit-identical to the
                 pre-objective search) or ``{"p": q, "weight": w}`` for
                 the tail objective ``E[T] + w·Q̂_p[T]`` (see
                 :class:`repro.core.storage.ObjectiveProfile` for the
                 quantile propagation).  Recorded in the on-disk meta;
                 metas written before this field simply omit it and
                 parse as ``"mean"``.
    """

    families: tuple = DEFAULT_FAMILIES
    lam_low: float = 2.0**8
    lam_high: float = 2.0**20
    lam_base: float = 2.0
    p: int = 16
    k: int = 5
    max_layers: int = 12
    strategy: str = "airtune"
    page_bytes: int = 0
    cache_bytes: tuple = ()
    objective: object = "mean"

    def __post_init__(self):
        object.__setattr__(self, "families", tuple(self.families))
        object.__setattr__(self, "cache_bytes",
                           tuple(int(c) for c in self.cache_bytes))

    # -- validation ---------------------------------------------------------
    def validate(self) -> "TuneSpec":
        """Resolve all registry names (KeyError lists what is registered)
        and sanity-check the numeric knobs.  Returns self for chaining."""
        for fam in self.families:
            BUILDER_FAMILIES.get(fam)
        SEARCH_STRATEGIES.get(self.strategy)
        # real raises, not asserts: user input must stay checked under -O
        if not self.families:
            raise ValueError("at least one builder family required")
        if not (self.lam_base > 1.0 and 0 < self.lam_low <= self.lam_high):
            raise ValueError(
                f"bad λ grid: need lam_base > 1 and 0 < lam_low <= lam_high, "
                f"got base={self.lam_base} low={self.lam_low} "
                f"high={self.lam_high}")
        if self.p < 1 or self.k < 1 or self.max_layers < 0:
            raise ValueError(f"bad knobs: p={self.p} k={self.k} "
                             f"max_layers={self.max_layers}")
        if self.page_bytes < 0 or any(c < 0 for c in self.cache_bytes):
            raise ValueError(f"negative sizes: page_bytes={self.page_bytes} "
                             f"cache_bytes={self.cache_bytes}")
        normalize_objective(self.objective)   # ValueError on bad objectives
        return self

    # -- materialization ----------------------------------------------------
    def builders(self) -> list[LayerBuilder]:
        """Instantiate the candidate set 𝓕 on the Eq. (8) grid."""
        return make_builders(lam_low=self.lam_low, lam_high=self.lam_high,
                             base=self.lam_base, p=self.p, kinds=self.families)

    def replace(self, **changes) -> "TuneSpec":
        return dataclasses.replace(self, **changes)

    # -- JSON round-trip ----------------------------------------------------
    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["families"] = list(self.families)
        d["cache_bytes"] = list(self.cache_bytes)
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "TuneSpec":
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(
                f"unknown TuneSpec fields {sorted(unknown)}; "
                f"allowed: {sorted(known)}")
        return cls(**d)

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), sort_keys=True)

    @classmethod
    def from_json(cls, s: str) -> "TuneSpec":
        return cls.from_dict(json.loads(s))


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """How the serving engine survives a flaky storage tier — frozen,
    JSON-round-trippable, nested in :class:`ServeSpec`.

    Every pread gets up to ``max_attempts`` tries; a failed attempt
    (``OSError``, short read, or a read slower than ``pread_deadline_s``)
    sleeps ``backoff_s · backoff_mult^attempt`` (capped at
    ``max_backoff_s``) before the next.  A coalesced multi-page run that
    exhausts its budget is split and retried at page granularity before
    the engine gives up with a typed :class:`repro.serve.ReadError`.
    ``batch_deadline_s`` bounds one whole ``lookup`` call; past it the
    engine raises :class:`repro.serve.DeadlineExceededError` instead of
    issuing more I/O.  Deadlines default to None (unbounded).  Reads that
    needed retries are tagged in ``ServeStats`` so
    ``observed_profile()``'s measured tier fit never ingests them.
    """

    max_attempts: int = 3
    backoff_s: float = 0.001
    backoff_mult: float = 2.0
    max_backoff_s: float = 0.1
    pread_deadline_s: float | None = None
    batch_deadline_s: float | None = None

    def validate(self) -> "RetryPolicy":
        if self.max_attempts < 1:
            raise ValueError(f"max_attempts must be >= 1, "
                             f"got {self.max_attempts}")
        if self.backoff_s < 0 or self.max_backoff_s < 0 \
                or self.backoff_mult < 1.0:
            raise ValueError(
                f"bad backoff: backoff_s={self.backoff_s} "
                f"backoff_mult={self.backoff_mult} "
                f"max_backoff_s={self.max_backoff_s}")
        for name in ("pread_deadline_s", "batch_deadline_s"):
            v = getattr(self, name)
            if v is not None and v <= 0:
                raise ValueError(f"{name} must be positive or None, got {v}")
        return self

    def backoff(self, attempt: int) -> float:
        """Sleep before retry number ``attempt`` (0-based failed attempt)."""
        return min(self.backoff_s * self.backoff_mult ** attempt,
                   self.max_backoff_s)

    def replace(self, **changes) -> "RetryPolicy":
        return dataclasses.replace(self, **changes)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "RetryPolicy":
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(
                f"unknown RetryPolicy fields {sorted(unknown)}; "
                f"allowed: {sorted(known)}")
        return cls(**d)

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), sort_keys=True)

    @classmethod
    def from_json(cls, s: str) -> "RetryPolicy":
        return cls.from_dict(json.loads(s))


@dataclasses.dataclass(frozen=True)
class ServeSpec:
    """Everything the serving engine needs beyond (file, deployment tier).

    Consolidates the constructor surface :class:`repro.serve.IndexService`
    accreted over five PRs into one JSON-round-trippable value object,
    symmetric with :class:`TuneSpec`: recorded into the on-disk meta by
    ``Index.save(serve_spec=...)``, restored on ``Index.open``, accepted by
    ``Index.serve(spec=...)``.  The deployment *tier* stays a separate
    argument — the same spec serves the same file on any tier.

    Fields
    ------
    cache_bytes:     tiered block-cache capacities, hottest first;
                     ``()`` falls back to the TuneSpec-recorded capacities
                     in the file meta, else a single 1 MiB tier.
    cache_profile:   ``PROFILES`` name the cache's hit cost is modeled on
                     (None: hits are free in ``modeled_seconds``).
    page_bytes:      cache unit; 0 = the file's paged layout, else 4096.
    resident_layers: top layers pinned in memory at open (the engine reads
                     at least the root, per Alg. 1).
    backend:         resident-prefix descent backend — ``"numpy"`` is the
                     bit-exact float64 walk; ``"pallas"`` / ``"jnp"`` run
                     the fused f32 kernel (step layers exact, band layers
                     δ-slack widened) with the Pallas → jnp → numpy
                     fallback chain.
    interpret:       run Pallas in interpret mode (CPU containers).
    coalesce_gap:    merge missing-page runs separated by ≤ this many
                     bytes (profitable when ``T(gap) − T(0) < ℓ``).
    persist_stats:   write a ServeStats snapshot next to the index on
                     ``close()`` (the observe→retune loop's raw material).
    pipeline_depth:  batches prefetched ahead by ``lookup_batches``'s
                     background stage (0 = unpipelined serving).
    prefetch_layers: disk layers the prefetch stage walks ahead per
                     future batch (first-window preads only, no gallop).
    retry:           :class:`RetryPolicy` for every pread the engine
                     issues — attempts, exponential backoff, per-pread
                     and per-batch deadlines, degraded-split retries.
                     A JSON dict coerces on construction, so recorded
                     metas round-trip.
    verify_checksums: verify the per-page CRC32 table recorded in the
                     paged layout on every cache fill (corrupt pages are
                     refetched once, then raise
                     :class:`repro.serve.CorruptPageError`).  Files
                     without checksums, or a cache page size different
                     from the file's layout, serve verify-skipped.
    """

    cache_bytes: tuple = ()
    cache_profile: str | None = "host_dram"
    page_bytes: int = 0
    resident_layers: int = 1
    backend: str = "numpy"
    interpret: bool = True
    coalesce_gap: int = 0
    persist_stats: bool = False
    pipeline_depth: int = 0
    prefetch_layers: int = 1
    retry: RetryPolicy = RetryPolicy()
    verify_checksums: bool = True

    def __post_init__(self):
        object.__setattr__(self, "cache_bytes",
                           tuple(int(c) for c in self.cache_bytes))
        if isinstance(self.retry, dict):   # JSON round-trip / replace(dict)
            object.__setattr__(self, "retry",
                               RetryPolicy.from_dict(self.retry))

    # -- validation ---------------------------------------------------------
    def validate(self) -> "ServeSpec":
        """Sanity-check knobs and resolve the cache-profile name.  Returns
        self for chaining; real raises (user input stays checked under -O).
        """
        if self.backend not in SERVE_BACKENDS:
            raise ValueError(f"unknown backend {self.backend!r}; "
                             f"one of {SERVE_BACKENDS}")
        if self.cache_profile is not None \
                and self.cache_profile not in PROFILES:
            raise ValueError(
                f"unknown cache_profile {self.cache_profile!r}; named "
                f"profiles: {', '.join(sorted(PROFILES))}")
        if self.page_bytes < 0 or any(c < 0 for c in self.cache_bytes):
            raise ValueError(f"negative sizes: page_bytes={self.page_bytes} "
                             f"cache_bytes={self.cache_bytes}")
        if self.resident_layers < 0 or self.pipeline_depth < 0 \
                or self.coalesce_gap < 0 or self.prefetch_layers < 1:
            raise ValueError(
                f"bad knobs: resident_layers={self.resident_layers} "
                f"pipeline_depth={self.pipeline_depth} "
                f"coalesce_gap={self.coalesce_gap} "
                f"prefetch_layers={self.prefetch_layers}")
        if not isinstance(self.retry, RetryPolicy):
            raise ValueError(f"retry must be a RetryPolicy (or its dict "
                             f"form), got {type(self.retry).__name__}")
        self.retry.validate()
        return self

    def replace(self, **changes) -> "ServeSpec":
        return dataclasses.replace(self, **changes)

    # -- JSON round-trip ----------------------------------------------------
    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["cache_bytes"] = list(self.cache_bytes)
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "ServeSpec":
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(
                f"unknown ServeSpec fields {sorted(unknown)}; "
                f"allowed: {sorted(known)}")
        return cls(**d)

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), sort_keys=True)

    @classmethod
    def from_json(cls, s: str) -> "ServeSpec":
        return cls.from_dict(json.loads(s))
