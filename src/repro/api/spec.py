"""Declarative tuning specification — the "how" of an index, as data.

A :class:`TuneSpec` names everything Alg. 2 needs beyond the data and the
storage profile: which builder families compete (registry names), the
λ-grid they are instantiated on (Eq. 8), the search strategy and its
knobs, and the serving-side layout/cache configuration.  It is a frozen
value object that round-trips through JSON losslessly, so the facade can
record it into the on-disk index meta — a reopened index remembers how it
was tuned and can be re-tuned when the storage profile changes.
"""
from __future__ import annotations

import dataclasses
import json

from repro.core.builders import DEFAULT_FAMILIES, LayerBuilder, make_builders
from repro.core.registry import BUILDER_FAMILIES, SEARCH_STRATEGIES


@dataclasses.dataclass(frozen=True)
class TuneSpec:
    """Everything needed to (re)produce a tuned index from (data, profile).

    Fields
    ------
    families:    builder-family names resolved through the registry; any
                 family registered via ``repro.api.register_builder``
                 participates in the search.  Besides the paper's deployed
                 set (``gstep``/``gband``/``eband``), the baseline
                 families ``btree``/``rmi_leaf``/``pgm``
                 (:data:`repro.core.baselines.BASELINE_FAMILIES`) are
                 registered and can be mixed in freely — e.g.
                 ``families=("btree", "pgm", "gstep")``.
    lam_low/lam_high/lam_base: the Eq. (8) granularity grid
                 ``λ_low · lam_base^j ≤ λ_high``.
    p:           pieces per step node (gstep-family parameter).
    k:           search width (top-k selection / beam width).
    max_layers:  index depth bound.
    strategy:    search-strategy name resolved through the registry
                 (``airtune`` | ``brute_force`` | ``beam`` | registered).
    page_bytes:  on-disk layout page size used by ``Index.save`` (0 =
                 densely packed; >0 = paged, the serving cache unit).
    cache_bytes: default tiered-cache capacities (hottest first) that
                 ``Index.serve()`` / ``IndexService`` use when the caller
                 does not override them; () = engine default.
    """

    families: tuple = DEFAULT_FAMILIES
    lam_low: float = 2.0**8
    lam_high: float = 2.0**20
    lam_base: float = 2.0
    p: int = 16
    k: int = 5
    max_layers: int = 12
    strategy: str = "airtune"
    page_bytes: int = 0
    cache_bytes: tuple = ()

    def __post_init__(self):
        object.__setattr__(self, "families", tuple(self.families))
        object.__setattr__(self, "cache_bytes",
                           tuple(int(c) for c in self.cache_bytes))

    # -- validation ---------------------------------------------------------
    def validate(self) -> "TuneSpec":
        """Resolve all registry names (KeyError lists what is registered)
        and sanity-check the numeric knobs.  Returns self for chaining."""
        for fam in self.families:
            BUILDER_FAMILIES.get(fam)
        SEARCH_STRATEGIES.get(self.strategy)
        # real raises, not asserts: user input must stay checked under -O
        if not self.families:
            raise ValueError("at least one builder family required")
        if not (self.lam_base > 1.0 and 0 < self.lam_low <= self.lam_high):
            raise ValueError(
                f"bad λ grid: need lam_base > 1 and 0 < lam_low <= lam_high, "
                f"got base={self.lam_base} low={self.lam_low} "
                f"high={self.lam_high}")
        if self.p < 1 or self.k < 1 or self.max_layers < 0:
            raise ValueError(f"bad knobs: p={self.p} k={self.k} "
                             f"max_layers={self.max_layers}")
        if self.page_bytes < 0 or any(c < 0 for c in self.cache_bytes):
            raise ValueError(f"negative sizes: page_bytes={self.page_bytes} "
                             f"cache_bytes={self.cache_bytes}")
        return self

    # -- materialization ----------------------------------------------------
    def builders(self) -> list[LayerBuilder]:
        """Instantiate the candidate set 𝓕 on the Eq. (8) grid."""
        return make_builders(lam_low=self.lam_low, lam_high=self.lam_high,
                             base=self.lam_base, p=self.p, kinds=self.families)

    def replace(self, **changes) -> "TuneSpec":
        return dataclasses.replace(self, **changes)

    # -- JSON round-trip ----------------------------------------------------
    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["families"] = list(self.families)
        d["cache_bytes"] = list(self.cache_bytes)
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "TuneSpec":
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(
                f"unknown TuneSpec fields {sorted(unknown)}; "
                f"allowed: {sorted(known)}")
        return cls(**d)

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), sort_keys=True)

    @classmethod
    def from_json(cls, s: str) -> "TuneSpec":
        return cls.from_dict(json.loads(s))
