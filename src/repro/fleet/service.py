"""Scatter-gather serving across a fleet of per-shard index services.

A :class:`FleetService` is to a fleet what
:class:`repro.serve.IndexService` is to one file: batched lookups in,
``(q, 2)`` byte ranges out.  Each batch is routed by the fleet's
:class:`~repro.fleet.ShardMap` (one vectorized searchsorted), the
per-shard sub-batches run through each shard's own engine — block cache,
coalesced preads, fused resident descent, and (via
:meth:`lookup_batches`) the two-stage prefetch pipeline, all per shard —
and the results gather back in input order.  Shard files store positions
rebased to 0 (see :mod:`repro.fleet.fleet`); the gather side adds each
shard's base back, so callers see one global byte space.

The scatter-gather is *bit-identical* to looking each key up in its
shard's service directly: routing only decides which engine serves a key,
never how.

Failure isolation: a shard whose engine exhausts its retry budget (any
:class:`repro.serve.StorageError`) is marked *unhealthy* and taken out of
rotation instead of failing every later fleet call.  By default a lookup
touching an unhealthy (or just-failing) shard raises
:class:`ShardUnavailableError`; with ``partial_results=True`` the healthy
shards' results return alongside an explicit per-key availability mask —
the caller chooses fail-stop or degraded serving, the fleet never
silently drops keys.
"""
from __future__ import annotations

import numpy as np

from repro.serve.backend import StorageError
from repro.serve.index_service import IndexService

from .spec import ShardMap


class ShardUnavailableError(StorageError):
    """A lookup needed a shard that is unhealthy (its engine spent a retry
    budget earlier, or its backend just failed).  Carries ``shard`` and
    the underlying ``cause`` string; pass ``partial_results=True`` to get
    the healthy shards' results plus an availability mask instead."""

    def __init__(self, msg: str, *, shard=None, cause=None):
        super().__init__(msg)
        self.shard = shard
        self.cause = cause


class FleetService:
    """Serve batched lookups across per-shard :class:`IndexService`\\ s.

    Parameters
    ----------
    shard_map: the fleet's key-range partition (routes queries).
    paths:     per-shard index-file paths, in shard order.
    bases:     per-shard global byte offsets (added to results — shard
               files are written rebased to 0).
    profile:   deployment tier, shared by every shard (``modeled_seconds``
               accounting; same semantics as IndexService).
    specs:     per-shard :class:`repro.api.ServeSpec` list — usually the
               fleet spec's serve template with each shard's
               ``cache_bytes`` overridden by the budget allocator.
    plan:      the :class:`repro.fleet.CachePlan` that produced those
               cache sizes (introspection only; may be None).
    backend_factories:
               per-shard ``path -> StorageBackend`` list (or one factory
               for every shard) forwarded to each shard's engine — the
               chaos harness injects per-shard fault schedules here.
    """

    def __init__(self, shard_map: ShardMap, paths, bases, *,
                 profile="azure_ssd", specs=None, plan=None,
                 backend_factories=None):
        paths = list(paths)
        bases = [int(b) for b in bases]
        if len(paths) != shard_map.n_shards or len(bases) != len(paths):
            raise ValueError(
                f"shard count mismatch: map has {shard_map.n_shards}, "
                f"got {len(paths)} paths / {len(bases)} bases")
        if specs is None:
            specs = [None] * len(paths)
        if len(specs) != len(paths):
            raise ValueError(f"{len(specs)} specs for {len(paths)} shards")
        if backend_factories is None or callable(backend_factories):
            backend_factories = [backend_factories] * len(paths)
        if len(backend_factories) != len(paths):
            raise ValueError(f"{len(backend_factories)} backend factories "
                             f"for {len(paths)} shards")
        self.shard_map = shard_map
        self.paths = paths
        self.bases = bases
        self.plan = plan
        self.healthy: list[bool] = [True] * len(paths)
        self.errors: list[str | None] = [None] * len(paths)
        self.services: list[IndexService] = []
        try:
            for path, spec, bf in zip(paths, specs, backend_factories):
                self.services.append(
                    IndexService(path, profile=profile, spec=spec,
                                 backend_factory=bf))
        except Exception:
            self.close()
            raise

    def _mark_unhealthy(self, sid: int, exc: BaseException) -> None:
        """Take a shard out of rotation after its engine gave up (typed
        storage failure past the retry budget).  Its service object stays
        open — stats remain inspectable and an operator can swap in a
        repaired file and call :meth:`mark_healthy`."""
        self.healthy[sid] = False
        self.errors[sid] = f"{type(exc).__name__}: {exc}"

    def mark_healthy(self, sid: int) -> None:
        """Put a shard back in rotation (after repair / :meth:`swap`)."""
        self.healthy[sid] = True
        self.errors[sid] = None

    @property
    def n_shards(self) -> int:
        return len(self.services)

    # -- lookups ------------------------------------------------------------
    def lookup(self, queries, *, partial_results: bool = False):
        """Batched Alg. 1 across the fleet → (q, 2) int64 global byte
        ranges, in input order.  Identical to routing each key and calling
        its shard's service alone — scatter-gather changes scheduling,
        not results.

        A key routed to an unhealthy shard (or one that fails past its
        retry budget during this call) raises
        :class:`ShardUnavailableError` by default.  With
        ``partial_results=True`` the return is ``(out, available)``: rows
        of keys the fleet could not serve are ``(-1, -1)`` and their
        ``available`` mask entries False — healthy shards' results are
        exactly what the default path would have returned."""
        q = np.atleast_1d(np.asarray(queries, dtype=np.uint64))
        out = np.empty((len(q), 2), dtype=np.int64)
        avail = np.ones(len(q), dtype=bool)
        for sid, pos in self.shard_map.sub_batches(q):
            res = self._serve_shard(
                sid, pos, partial_results,
                lambda svc: svc.lookup(q[pos]) + self.bases[sid])
            if res is None:
                out[pos] = -1
                avail[pos] = False
            else:
                out[pos] = res
        if partial_results:
            return out, avail
        return out

    def _serve_shard(self, sid: int, pos, partial: bool, fn):
        """Run ``fn`` against shard ``sid``'s service under the fleet's
        failure-isolation contract: an unhealthy shard is skipped, a
        typed storage failure marks it unhealthy — then either None comes
        back (``partial``: the caller masks those keys) or the
        :class:`ShardUnavailableError` propagates."""
        if not self.healthy[sid]:
            if partial:
                return None
            raise ShardUnavailableError(
                f"shard {sid} ({self.paths[sid]!r}) is unhealthy: "
                f"{self.errors[sid]}", shard=sid, cause=self.errors[sid])
        try:
            return fn(self.services[sid])
        except StorageError as e:
            self._mark_unhealthy(sid, e)
            if partial:
                return None
            raise ShardUnavailableError(
                f"shard {sid} ({self.paths[sid]!r}) failed past its retry "
                f"budget: {e}", shard=sid, cause=str(e)) from e

    def lookup_batches(self, batches, *, partial_results: bool = False):
        """Serve a sequence of batches, keeping each shard's two-stage
        prefetch pipeline fed: every shard receives its sub-batches of
        *all* batches in one ``lookup_batches`` call (so its stage-1
        worker prefetches across batch boundaries), then results gather
        per input batch in input order.

        Failure isolation matches :meth:`lookup`; with
        ``partial_results=True`` the return is ``(outs, avails)`` — one
        availability mask per input batch, and a shard that fails mid-way
        masks *all* its keys in every batch (its pipeline results cannot
        be trusted to a batch boundary)."""
        batches = [np.atleast_1d(np.asarray(b, dtype=np.uint64))
                   for b in batches]
        outs = [np.empty((len(b), 2), dtype=np.int64) for b in batches]
        avails = [np.ones(len(b), dtype=bool) for b in batches]
        per_shard: dict[int, list] = {}
        for bi, b in enumerate(batches):
            for sid, pos in self.shard_map.sub_batches(b):
                per_shard.setdefault(sid, []).append((bi, pos))
        for sid in sorted(per_shard):
            subs = per_shard[sid]
            res = self._serve_shard(
                sid, None, partial_results,
                lambda svc: svc.lookup_batches(
                    [batches[bi][pos] for bi, pos in subs]))
            for (bi, pos), r in zip(subs, res if res is not None
                                    else [None] * len(subs)):
                if r is None:
                    outs[bi][pos] = -1
                    avails[bi][pos] = False
                else:
                    outs[bi][pos] = r + self.bases[sid]
        if partial_results:
            return outs, avails
        return outs

    # -- observation ---------------------------------------------------------
    def stats_summary(self) -> dict:
        """Fleet-wide aggregates plus per-shard snapshots.  The fleet's
        per-query observed cost is the traffic-weighted mean of the
        shards' (Eq. 6-comparable, open-amortized) per-query costs.

        Never raises on a sick shard: an unhealthy or already-closed
        service still gets a row (``healthy``/``error`` say why it is
        thin) — a fleet dashboard must render *because* something is
        wrong, not fail when it is."""
        per_shard = []
        tq = modeled = walk = 0.0
        preads = bytes_fetched = hits = fetched = 0
        n_unhealthy = 0
        for sid, svc in enumerate(self.services):
            row = {"shard": sid, "healthy": self.healthy[sid],
                   "error": self.errors[sid]}
            if not self.healthy[sid]:
                n_unhealthy += 1
            try:
                st = svc.stats
                row.update({
                    "queries": st.queries,
                    "hit_rate": st.hit_rate, "preads": st.preads,
                    "bytes_fetched": st.bytes_fetched,
                    "io_retries": st.io_retries,
                    "io_timeouts": st.io_timeouts,
                    "degraded_runs": st.degraded_runs,
                    "corrupt_pages": st.corrupt_pages,
                    "query_modeled_us": (st.query_modeled_seconds * 1e6
                                         if st.queries else None),
                    "cache_bytes": list(
                        svc.cache.cap_pages[i] * svc.page_bytes
                        for i in range(svc.cache.n_tiers)),
                })
            except StorageError as e:
                # typed failure while reading shard state: take the shard
                # out of rotation and surface the concrete class name —
                # operators key availability reports on it
                if self.healthy[sid]:
                    n_unhealthy += 1
                self._mark_unhealthy(sid, e)
                row["healthy"] = False
                row["error"] = self.errors[sid]
                per_shard.append(row)
                continue
            except Exception as e:   # closed / half-open shard: thin row
                row["error"] = row["error"] or f"{type(e).__name__}: {e}"
                per_shard.append(row)
                continue
            per_shard.append(row)
            tq += st.queries
            modeled += (st.modeled_seconds - st.open_modeled_seconds
                        + st.data_modeled_seconds)
            walk += st.walk_modeled_seconds
            preads += st.preads
            bytes_fetched += st.bytes_fetched
            hits += st.pages_hit
            fetched += st.pages_fetched
        touched = hits + fetched
        return {
            "queries": int(tq),
            "preads": preads,
            "bytes_fetched": bytes_fetched,
            "hit_rate": (hits / touched) if touched else 0.0,
            "query_modeled_us": (modeled / tq * 1e6) if tq else None,
            "walk_query_us": (walk / tq * 1e6) if tq else None,
            "plan": self.plan.to_dict() if self.plan is not None else None,
            "healthy_shards": len(self.services) - n_unhealthy,
            "unhealthy_shards": n_unhealthy,
            "shards": per_shard,
        }

    # -- lifecycle -----------------------------------------------------------
    def close(self) -> None:
        """Close every shard service (each persists its own ServeStats
        snapshot next to its file when its spec says so)."""
        for svc in self.services:
            try:
                svc.close()
            # airlint: allow[typed-error-flow] -- best-effort shutdown: one
            # shard's close failure must not strand the remaining shards
            except Exception:
                pass

    def __enter__(self) -> "FleetService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
