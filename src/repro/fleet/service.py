"""Scatter-gather serving across a fleet of per-shard index services.

A :class:`FleetService` is to a fleet what
:class:`repro.serve.IndexService` is to one file: batched lookups in,
``(q, 2)`` byte ranges out.  Each batch is routed by the fleet's
:class:`~repro.fleet.ShardMap` (one vectorized searchsorted), the
per-shard sub-batches run through each shard's own engine — block cache,
coalesced preads, fused resident descent, and (via
:meth:`lookup_batches`) the two-stage prefetch pipeline, all per shard —
and the results gather back in input order.  Shard files store positions
rebased to 0 (see :mod:`repro.fleet.fleet`); the gather side adds each
shard's base back, so callers see one global byte space.

The scatter-gather is *bit-identical* to looking each key up in its
shard's service directly: routing only decides which engine serves a key,
never how.
"""
from __future__ import annotations

import numpy as np

from repro.serve.index_service import IndexService

from .spec import ShardMap


class FleetService:
    """Serve batched lookups across per-shard :class:`IndexService`\\ s.

    Parameters
    ----------
    shard_map: the fleet's key-range partition (routes queries).
    paths:     per-shard index-file paths, in shard order.
    bases:     per-shard global byte offsets (added to results — shard
               files are written rebased to 0).
    profile:   deployment tier, shared by every shard (``modeled_seconds``
               accounting; same semantics as IndexService).
    specs:     per-shard :class:`repro.api.ServeSpec` list — usually the
               fleet spec's serve template with each shard's
               ``cache_bytes`` overridden by the budget allocator.
    plan:      the :class:`repro.fleet.CachePlan` that produced those
               cache sizes (introspection only; may be None).
    """

    def __init__(self, shard_map: ShardMap, paths, bases, *,
                 profile="azure_ssd", specs=None, plan=None):
        paths = list(paths)
        bases = [int(b) for b in bases]
        if len(paths) != shard_map.n_shards or len(bases) != len(paths):
            raise ValueError(
                f"shard count mismatch: map has {shard_map.n_shards}, "
                f"got {len(paths)} paths / {len(bases)} bases")
        if specs is None:
            specs = [None] * len(paths)
        if len(specs) != len(paths):
            raise ValueError(f"{len(specs)} specs for {len(paths)} shards")
        self.shard_map = shard_map
        self.paths = paths
        self.bases = bases
        self.plan = plan
        self.services: list[IndexService] = []
        try:
            for path, spec in zip(paths, specs):
                self.services.append(
                    IndexService(path, profile=profile, spec=spec))
        except Exception:
            self.close()
            raise

    @property
    def n_shards(self) -> int:
        return len(self.services)

    # -- lookups ------------------------------------------------------------
    def lookup(self, queries) -> np.ndarray:
        """Batched Alg. 1 across the fleet → (q, 2) int64 global byte
        ranges, in input order.  Identical to routing each key and calling
        its shard's service alone — scatter-gather changes scheduling,
        not results."""
        q = np.atleast_1d(np.asarray(queries, dtype=np.uint64))
        out = np.empty((len(q), 2), dtype=np.int64)
        for sid, pos in self.shard_map.sub_batches(q):
            out[pos] = self.services[sid].lookup(q[pos]) + self.bases[sid]
        return out

    def lookup_batches(self, batches) -> list:
        """Serve a sequence of batches, keeping each shard's two-stage
        prefetch pipeline fed: every shard receives its sub-batches of
        *all* batches in one ``lookup_batches`` call (so its stage-1
        worker prefetches across batch boundaries), then results gather
        per input batch in input order."""
        batches = [np.atleast_1d(np.asarray(b, dtype=np.uint64))
                   for b in batches]
        outs = [np.empty((len(b), 2), dtype=np.int64) for b in batches]
        per_shard: dict[int, list] = {}
        for bi, b in enumerate(batches):
            for sid, pos in self.shard_map.sub_batches(b):
                per_shard.setdefault(sid, []).append((bi, pos))
        for sid in sorted(per_shard):
            subs = per_shard[sid]
            res = self.services[sid].lookup_batches(
                [batches[bi][pos] for bi, pos in subs])
            for (bi, pos), r in zip(subs, res):
                outs[bi][pos] = r + self.bases[sid]
        return outs

    # -- observation ---------------------------------------------------------
    def stats_summary(self) -> dict:
        """Fleet-wide aggregates plus per-shard snapshots.  The fleet's
        per-query observed cost is the traffic-weighted mean of the
        shards' (Eq. 6-comparable, open-amortized) per-query costs."""
        per_shard = []
        tq = modeled = walk = 0.0
        preads = bytes_fetched = hits = fetched = 0
        for sid, svc in enumerate(self.services):
            st = svc.stats
            per_shard.append({
                "shard": sid, "queries": st.queries,
                "hit_rate": st.hit_rate, "preads": st.preads,
                "bytes_fetched": st.bytes_fetched,
                "query_modeled_us": (st.query_modeled_seconds * 1e6
                                     if st.queries else None),
                "cache_bytes": list(svc.cache.cap_pages[i] * svc.page_bytes
                                    for i in range(svc.cache.n_tiers)),
            })
            tq += st.queries
            modeled += (st.modeled_seconds - st.open_modeled_seconds
                        + st.data_modeled_seconds)
            walk += st.walk_modeled_seconds
            preads += st.preads
            bytes_fetched += st.bytes_fetched
            hits += st.pages_hit
            fetched += st.pages_fetched
        touched = hits + fetched
        return {
            "queries": int(tq),
            "preads": preads,
            "bytes_fetched": bytes_fetched,
            "hit_rate": (hits / touched) if touched else 0.0,
            "query_modeled_us": (modeled / tq * 1e6) if tq else None,
            "walk_query_us": (walk / tq * 1e6) if tq else None,
            "plan": self.plan.to_dict() if self.plan is not None else None,
            "shards": per_shard,
        }

    # -- lifecycle -----------------------------------------------------------
    def close(self) -> None:
        """Close every shard service (each persists its own ServeStats
        snapshot next to its file when its spec says so)."""
        for svc in self.services:
            try:
                svc.close()
            except Exception:
                pass        # best effort: one shard must not strand the rest

    def __enter__(self) -> "FleetService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
