"""The :class:`Fleet` facade — N per-shard indexes behind one handle,
mirroring :class:`repro.api.Index`'s tune → disk → serve lifecycle::

    fleet = Fleet.tune(D, "azure_ssd", FleetSpec(n_shards=4,
                                                 cache_budget_bytes=2 << 20))
    fleet.build()                  # per-shard Alg. 2, one shared LayerCache
    fleet.save("fleet_dir/")       # shard_0000.air ... + fleet.json manifest
    svc = Fleet.open("fleet_dir/").serve()   # budgeted FleetService
    ranges = fleet.lookup(keys)    # global byte ranges, any shard

Each shard gets its OWN search (the per-partition specialization of
arXiv 2208.03823): its local key distribution, its own observed
:class:`~repro.core.CachedProfile` on retune.  One
:class:`~repro.core.sweep.LayerCache` is shared across all shard searches
— candidate layers built for one shard's collection are memo hits for
any other shard that reaches an identical collection, and for every
later retune.

Shard files are written *rebased*: each shard's key-position slice is
shifted so its first byte is position 0, and the shift (``base``) is
recorded in the manifest.  This keeps every per-shard file
self-consistent (the engine clamps results to ``[0, data_size]``);
``Fleet.lookup`` / :class:`FleetService` add the base back, so callers
always see the original global byte space.
"""
from __future__ import annotations

import json
import os

import numpy as np

from repro.api.index import Index, resolve_profile
from repro.api.spec import ServeSpec
from repro.core.keyset import KeyPositions
from repro.core.storage import profile_from_dict, profile_to_dict
from repro.core.sweep import DEFAULT_CACHE_ENTRIES, LayerCache
from repro.serve.index_service import (load_serve_stats,
                                       observed_profile_from_stats)

from .budget import (CachePlan, allocate_cache_budget, demand_from_design,
                     demand_from_meta, split_cache_tiers)
from .spec import FleetSpec, ShardMap
from .service import FleetService

MANIFEST_NAME = "fleet.json"
SHARD_TEMPLATE = "shard_{:04d}.air"

_MISSING = object()


def _rebase(part: KeyPositions) -> tuple[KeyPositions, int]:
    """Shift a key-position slice so its first byte is position 0; the
    returned base is what lookups must add back."""
    if part.n == 0:
        return part, 0
    base = int(part.lo[0])
    if base == 0:
        return part, 0
    return KeyPositions(keys=part.keys, lo=part.lo - base,
                        hi=part.hi - base, weights=part.weights), base


def _partition(data: KeyPositions, shard_map: ShardMap):
    """→ (rebased per-shard collections, per-shard bases)."""
    parts, bases = [], []
    for a, z in shard_map.slice_bounds(data.keys):
        if z <= a:
            raise ValueError(
                "empty shard: the shard map does not match this data "
                "(every shard needs at least one key)")
        part, base = _rebase(data.slice(a, z))
        parts.append(part)
        bases.append(base)
    return parts, bases


class Fleet:
    """Facade over the sharded-fleet lifecycle; construct via
    :meth:`tune` or :meth:`open`."""

    def __init__(self, *, spec: FleetSpec, shard_map: ShardMap, shards,
                 bases, profile=None, profile_name=None, directory=None):
        self._spec = spec
        self._shard_map = shard_map
        self._shards: list[Index] = list(shards)
        self._bases = [int(b) for b in bases]
        self._profile = profile
        self._profile_name = profile_name
        self._directory = directory
        # ONE build memo across every shard search and later retune
        self._layer_cache = LayerCache(max_entries=DEFAULT_CACHE_ENTRIES)

    # -- constructors -------------------------------------------------------
    @classmethod
    def tune(cls, data: KeyPositions, profile,
             spec: FleetSpec | None = None, **overrides) -> "Fleet":
        """Declare N per-shard tuning problems: partition ``data`` by key
        range (:meth:`ShardMap.even_keys`), rebase each slice, and set up
        one :class:`repro.api.Index` per shard under ``spec.tune``.
        ``overrides`` are FleetSpec field replacements."""
        spec = spec if spec is not None else FleetSpec()
        if overrides:
            spec = spec.replace(**overrides)
        spec.validate()
        prof, pname = resolve_profile(profile)
        if prof is None:
            raise ValueError("Fleet.tune requires a storage profile")
        shard_map = ShardMap.even_keys(data.keys, spec.n_shards)
        parts, bases = _partition(data, shard_map)
        shards = [Index.tune(part, prof, spec.tune) for part in parts]
        return cls(spec=spec, shard_map=shard_map, shards=shards,
                   bases=bases, profile=prof, profile_name=pname)

    @classmethod
    def open(cls, directory: str,
             data: KeyPositions | None = None) -> "Fleet":
        """Open a saved fleet from its manifest.  Pass ``data`` (the full
        global collection) to enable :meth:`retune` — it is re-partitioned
        with the *persisted* shard map and must reproduce the recorded
        per-shard bases."""
        with open(os.path.join(directory, MANIFEST_NAME)) as f:
            m = json.load(f)
        spec = FleetSpec.from_dict(m["spec"])
        shard_map = ShardMap.from_dict(m["shard_map"])
        prof = profile_from_dict(m.get("profile_params"))
        pname = m.get("profile")
        if prof is None and pname is not None:
            prof, pname = resolve_profile(pname)
        parts = [None] * shard_map.n_shards
        if data is not None:
            parts, bases = _partition(data, shard_map)
            recorded = [int(s["base"]) for s in m["shards"]]
            if bases != recorded:
                raise ValueError(
                    f"data does not match the saved fleet: re-partitioned "
                    f"bases {bases} != recorded {recorded}")
        shards, bases = [], []
        for s, part in zip(m["shards"], parts):
            shards.append(Index.open(os.path.join(directory, s["path"]),
                                     data=part))
            bases.append(int(s["base"]))
        return cls(spec=spec, shard_map=shard_map, shards=shards,
                   bases=bases, profile=prof, profile_name=pname,
                   directory=directory)

    # -- lifecycle ----------------------------------------------------------
    def build(self) -> "Fleet":
        """Run every shard's search (idempotent), sharing one LayerCache
        so identical candidate builds across shards/retunes happen once."""
        for idx in self._shards:
            idx._layer_cache = self._layer_cache
            idx.build()
        return self

    def save(self, directory: str) -> "Fleet":
        """Serialize every shard (building first if needed) plus the fleet
        manifest.  Layout::

            directory/
              fleet.json            # spec, shard map, profile, shard table
              shard_0000.air        # per-shard paged index files
              shard_0000.air.stats.json   # per-shard ServeStats (serving)
              ...
        """
        self.build()
        os.makedirs(directory, exist_ok=True)
        table = []
        for i, (idx, base) in enumerate(zip(self._shards, self._bases)):
            name = SHARD_TEMPLATE.format(i)
            idx.save(os.path.join(directory, name),
                     serve_spec=self._spec.serve)
            table.append({"path": name, "base": base,
                          "n_keys": int(idx.design.data.n),
                          "cost": float(idx.cost)})
        manifest = {
            "version": 1,
            "spec": self._spec.to_dict(),
            "shard_map": self._shard_map.to_dict(),
            "profile": self._profile_name,
            "profile_params": profile_to_dict(self._profile),
            "shards": table,
        }
        tmp = os.path.join(directory, MANIFEST_NAME + ".tmp")
        with open(tmp, "w") as f:
            json.dump(manifest, f, indent=1)
        os.replace(tmp, os.path.join(directory, MANIFEST_NAME))
        self._directory = directory
        return self

    # -- queries ------------------------------------------------------------
    def lookup(self, keys) -> np.ndarray:
        """Batched Alg. 1 across shards → (q, 2) int64 *global* byte
        ranges (each shard's base added back), in input order."""
        q = np.atleast_1d(np.asarray(keys, dtype=np.uint64))
        out = np.empty((len(q), 2), dtype=np.int64)
        for sid, pos in self._shard_map.sub_batches(q):
            out[pos] = self._shards[sid].lookup(q[pos]) + self._bases[sid]
        return out

    # -- serving ------------------------------------------------------------
    def serve(self, spec: ServeSpec | None = None,
              total_cache_bytes: int | None = None,
              backend_factories=None, **overrides) -> FleetService:
        """Open a :class:`FleetService` over the saved shard files.

        The serve template is the fleet spec's ``serve`` (or ``spec=``),
        with ServeSpec keyword ``overrides`` applied to every shard.  When
        a global budget is set (``total_cache_bytes=`` here, else the
        spec's ``cache_budget_bytes``), each shard's ``cache_bytes`` is
        replaced by its share under the marginal-gain allocation
        (:func:`repro.fleet.allocate_cache_budget`), traffic-weighted by
        persisted per-shard ServeStats when present — hot shards earn
        more cache."""
        if self._directory is None:
            raise ValueError(
                "serve() needs a saved fleet: call save(directory) first "
                "(or open an existing one with Fleet.open)")
        profile = overrides.pop("profile", _MISSING)
        if profile is _MISSING:
            profile = self._profile if self._profile is not None \
                else "azure_ssd"
        template = spec if spec is not None else self._spec.serve
        if overrides:
            template = template.replace(**overrides)
        template.validate()
        budget = self._spec.cache_budget_bytes \
            if total_cache_bytes is None else int(total_cache_bytes)
        plan = None
        specs = [template] * len(self._shards)
        if budget > 0:
            plan = self.allocate_cache(budget, profile=profile)
            specs = [
                template.replace(cache_bytes=split_cache_tiers(
                    plan.for_shard(i), template.cache_bytes,
                    quantum=self._spec.quantum))
                for i in range(len(self._shards))]
        paths = [idx.path for idx in self._shards]
        return FleetService(self._shard_map, paths, self._bases,
                            profile=profile, specs=specs, plan=plan,
                            backend_factories=backend_factories)

    def allocate_cache(self, total_bytes: int, profile=None) -> CachePlan:
        """The marginal-gain cache plan for a given budget: per-shard
        demands (Eq. 6 saving × observed traffic ÷ working set) fed to
        greedy water-filling.  Traffic weights come from each shard's
        persisted ``<shard>.stats.json`` (uniform when absent)."""
        prof, _ = resolve_profile(profile if profile is not None
                                  else self._profile)
        if prof is None:
            raise ValueError("allocate_cache needs a storage profile")
        cache_prof, _ = resolve_profile(self._spec.serve.cache_profile)
        res = self._spec.serve.resident_layers
        demands = []
        for i, idx in enumerate(self._shards):
            traffic = 1.0
            if idx.path is not None:
                stats = load_serve_stats(idx.path)
                if stats is not None and stats.queries > 0:
                    traffic = float(stats.queries)
            meta = idx.file_meta
            if idx._result is not None:
                from repro.serve.index_service import cacheable_working_set
                ws = cacheable_working_set(meta, res) \
                    if meta is not None else None
                demands.append(demand_from_design(
                    i, idx.design, prof, cache=cache_prof,
                    resident_layers=res, traffic=traffic, working_set=ws))
            elif meta is not None:
                demands.append(demand_from_meta(
                    i, meta, prof, cache=cache_prof,
                    resident_layers=res, traffic=traffic))
            else:
                raise ValueError(f"shard {i} has neither a built design "
                                 f"nor a file meta to derive demand from")
        return allocate_cache_budget(demands, total_bytes,
                                     quantum=self._spec.quantum)

    # -- observe → retune ----------------------------------------------------
    def retune(self, profile=None, data: KeyPositions | None = None,
               warm_start: bool = True, measured: bool = False,
               **tune_overrides) -> "Fleet":
        """Re-run every shard's search against its OWN observed serving
        conditions: each shard's persisted ServeStats yields its observed
        :class:`CachedProfile` (hit rate over the backing tier; shards
        without stats retune for the plain backing tier), and each search
        is warm-started from that shard's previous design through the
        shared fleet LayerCache.  Returns a fresh unsaved Fleet; the
        original is untouched."""
        backing, bname = resolve_profile(profile if profile is not None
                                         else self._profile)
        if backing is None:
            raise ValueError("retune needs a storage profile")
        cache_prof, _ = resolve_profile(self._spec.serve.cache_profile)
        parts = [None] * len(self._shards)
        if data is not None:
            parts, bases = _partition(data, self._shard_map)
            if bases != self._bases:
                raise ValueError(
                    f"data does not match this fleet: re-partitioned "
                    f"bases {bases} != recorded {self._bases}")
        spec = self._spec
        if tune_overrides:
            spec = spec.replace(tune=spec.tune.replace(**tune_overrides))
        new_shards = []
        for i, idx in enumerate(self._shards):
            shard_prof = backing
            if idx.path is not None:
                stats = load_serve_stats(idx.path)
                if stats is not None and stats.queries > 0:
                    shard_prof = observed_profile_from_stats(
                        stats, backing, cache_prof, measured=measured)
            idx._layer_cache = self._layer_cache   # fleet-wide build memo
            new = idx.retune(shard_prof, data=parts[i],
                             warm_start=warm_start,
                             **(tune_overrides or {}))
            new_shards.append(new)
        out = Fleet(spec=spec, shard_map=self._shard_map,
                    shards=new_shards, bases=self._bases, profile=backing,
                    profile_name=bname)
        out._layer_cache = self._layer_cache
        return out

    def retune_budgeted(self, profile=None, data: KeyPositions | None = None,
                        total_cache_bytes: int | None = None,
                        warm_start: bool = True):
        """Joint per-shard design × global cache budget retune — one round
        of coordinate descent over the coupled problem (each shard's
        optimal design depends on its hit rate; its hit rate depends on
        its cache share; its *deserved* share depends on its design):

        1. **tentative**: retune every shard for the fully-warmed cache
           tier (``CachedProfile`` at hit rate 1 — the steady-state
           cached path), yielding each shard's fine candidate design and
           its cacheable working set;
        2. **allocate**: water-fill the global budget over the tentative
           designs' Eq. 6 curves (:func:`allocate_cache_budget`), traffic-
           weighted by persisted per-shard ServeStats — hot shards earn
           their working sets first;
        3. **final**: retune each shard for its *planned* hit rate
           ``h_i = alloc_i / ws_i`` — shards whose working set fits keep
           the fine steady-state design; shards priced out of the budget
           fall back toward the raw-tier design (coarse, no cache
           dependence), which is exactly right for an uncached shard.

        Returns ``(fleet, plan)``: a fresh unsaved Fleet (with
        ``cache_budget_bytes`` recorded so save→serve re-allocates
        consistently) and the step-2 :class:`CachePlan`."""
        from repro.core.storage import CachedProfile

        backing, bname = resolve_profile(profile if profile is not None
                                         else self._profile)
        if backing is None:
            raise ValueError("retune_budgeted needs a storage profile")
        cache_prof, _ = resolve_profile(self._spec.serve.cache_profile)
        budget = self._spec.cache_budget_bytes \
            if total_cache_bytes is None else int(total_cache_bytes)
        if budget <= 0:
            raise ValueError("retune_budgeted needs a positive cache "
                             "budget (total_cache_bytes= or the spec's "
                             "cache_budget_bytes)")
        parts = [None] * len(self._shards)
        if data is not None:
            parts, bases = _partition(data, self._shard_map)
            if bases != self._bases:
                raise ValueError(
                    f"data does not match this fleet: re-partitioned "
                    f"bases {bases} != recorded {self._bases}")
        res = self._spec.serve.resident_layers
        warmed = CachedProfile(backing=backing, cache=cache_prof,
                               hit_rate=1.0)
        # 1. tentative steady-state designs (shared LayerCache: their
        #    builds seed both the final searches and later retunes)
        tentative, demands = [], []
        for i, idx in enumerate(self._shards):
            idx._layer_cache = self._layer_cache
            t = idx.retune(warmed, data=parts[i], warm_start=warm_start)
            t._layer_cache = self._layer_cache
            t.build()
            tentative.append(t)
            traffic = 1.0
            if idx.path is not None:
                stats = load_serve_stats(idx.path)
                if stats is not None and stats.queries > 0:
                    traffic = float(stats.queries)
            demands.append(demand_from_design(
                i, t.result.design, backing, cache=cache_prof,
                resident_layers=res, traffic=traffic))
        # 2. marginal-gain water-filling over the tentative curves
        plan = allocate_cache_budget(demands, budget,
                                     quantum=self._spec.quantum)
        # 3. final per-shard retune at the planned hit rate
        new_shards = []
        for i, (t, d) in enumerate(zip(tentative, demands)):
            h = min(1.0, plan.for_shard(i) / d.working_set) \
                if d.working_set > 0 else 0.0
            if h >= 1.0:
                new_shards.append(t)       # the steady-state design IS it
                continue
            prof_i = backing if h <= 0.0 else CachedProfile(
                backing=backing, cache=cache_prof, hit_rate=h)
            self._shards[i]._layer_cache = self._layer_cache
            new = self._shards[i].retune(prof_i, data=parts[i],
                                         warm_start=warm_start)
            new._layer_cache = self._layer_cache
            new_shards.append(new)
        spec = self._spec.replace(cache_budget_bytes=budget)
        out = Fleet(spec=spec, shard_map=self._shard_map,
                    shards=new_shards, bases=self._bases, profile=backing,
                    profile_name=bname)
        out._layer_cache = self._layer_cache
        return out, plan

    def close(self) -> None:
        for idx in self._shards:
            idx.close()

    def __enter__(self) -> "Fleet":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- introspection ------------------------------------------------------
    @property
    def spec(self) -> FleetSpec:
        return self._spec

    @property
    def shard_map(self) -> ShardMap:
        return self._shard_map

    @property
    def shards(self) -> list:
        """The per-shard :class:`repro.api.Index` handles, in shard order."""
        return list(self._shards)

    @property
    def bases(self) -> list:
        return list(self._bases)

    @property
    def n_shards(self) -> int:
        return len(self._shards)

    @property
    def directory(self) -> str | None:
        return self._directory

    @property
    def costs(self) -> list:
        """Per-shard Eq. 6 costs (recorded costs for disk-opened shards)."""
        return [idx.cost for idx in self._shards]

    def describe(self) -> str:
        loc = f" @ {self._directory}" if self._directory else ""
        costs = ", ".join(
            f"{c * 1e6:.1f}us" if np.isfinite(c) else "?" for c in self.costs)
        return (f"Fleet(n_shards={self.n_shards}, "
                f"profile={self._profile_name or 'custom'}, "
                f"budget={self._spec.cache_budget_bytes}B, "
                f"costs=[{costs}]{loc})")
