"""Fleet-level declarative configuration: how a keyed collection is
sharded, tuned, served, and cache-budgeted across N index files.

A :class:`ShardMap` is the key-range partition itself — ``n − 1`` split
keys dividing the uint64 key space into contiguous ranges, one per shard.
A :class:`FleetSpec` carries everything else: the per-shard
:class:`~repro.api.spec.TuneSpec` (each shard runs its OWN Alg. 2 search —
the per-partition specialization of arXiv 2208.03823), the per-shard
:class:`~repro.api.spec.ServeSpec`, and the *global* cache-byte budget
that :mod:`repro.fleet.budget` allocates across shards by marginal
E[T(Δ)] gain.  Both are frozen value objects that round-trip through JSON
losslessly, so ``Fleet.save`` can persist them into the fleet manifest
(``fleet.json``) next to the shard metas and ``Fleet.open`` restores them.
"""
from __future__ import annotations

import dataclasses
import json

import numpy as np

from repro.api.spec import ServeSpec, TuneSpec
from repro.core.keyset import KEY_DTYPE


@dataclasses.dataclass(frozen=True)
class ShardMap:
    """Key-range partition of the uint64 key space into contiguous shards.

    ``bounds`` holds ``n_shards − 1`` strictly increasing split keys;
    shard ``i`` owns ``[bounds[i−1], bounds[i])`` with open outer ends
    (shard 0 owns everything below ``bounds[0]``, the last shard
    everything from ``bounds[-1]`` up).  Routing is one vectorized
    ``searchsorted`` — O(q log n) with no per-key Python.
    """

    bounds: tuple    # (n_shards − 1,) strictly increasing uint64 split keys

    def __post_init__(self):
        b = tuple(int(x) for x in self.bounds)
        if any(y <= x for x, y in zip(b, b[1:])):
            raise ValueError(f"shard bounds must strictly increase: {b}")
        object.__setattr__(self, "bounds", b)

    @property
    def n_shards(self) -> int:
        return len(self.bounds) + 1

    @classmethod
    def even_keys(cls, keys: np.ndarray, n_shards: int) -> "ShardMap":
        """Split sorted unique keys into ``n_shards`` near-equal-count
        ranges; split key ``i`` is the first key of shard ``i``."""
        keys = np.asarray(keys, dtype=KEY_DTYPE)
        n = len(keys)
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        if n < n_shards:
            raise ValueError(f"cannot split {n} keys into {n_shards} shards")
        cuts = [(i * n) // n_shards for i in range(1, n_shards)]
        return cls(bounds=tuple(int(keys[c]) for c in cuts))

    def route(self, keys) -> np.ndarray:
        """→ (q,) int64 shard id per key."""
        q = np.atleast_1d(np.asarray(keys, dtype=KEY_DTYPE))
        b = np.asarray(self.bounds, dtype=KEY_DTYPE)
        return np.searchsorted(b, q, side="right").astype(np.int64)

    def sub_batches(self, keys) -> list:
        """Scatter one query batch → ``[(shard_id, positions), ...]`` for
        every shard that received at least one key, in shard order.
        ``positions`` indexes into the input batch (the gather side puts
        per-shard results back in input order)."""
        q = np.atleast_1d(np.asarray(keys, dtype=KEY_DTYPE))
        sid = self.route(q)
        out = []
        for s in np.unique(sid):
            out.append((int(s), np.flatnonzero(sid == s)))
        return out

    def slice_bounds(self, keys: np.ndarray) -> list:
        """Per-shard ``(start, stop)`` index ranges into a sorted key
        array — the partition a :class:`~repro.core.KeyPositions` is
        sliced by when (re)building per-shard collections."""
        keys = np.asarray(keys, dtype=KEY_DTYPE)
        b = np.asarray(self.bounds, dtype=KEY_DTYPE)
        cuts = [0] + list(np.searchsorted(keys, b, side="left")) + [len(keys)]
        return [(int(a), int(z)) for a, z in zip(cuts, cuts[1:])]

    # -- JSON round-trip ----------------------------------------------------
    def to_dict(self) -> dict:
        return {"bounds": list(self.bounds)}

    @classmethod
    def from_dict(cls, d: dict) -> "ShardMap":
        unknown = set(d) - {"bounds"}
        if unknown:
            raise ValueError(f"unknown ShardMap fields {sorted(unknown)}; "
                             f"allowed: ['bounds']")
        return cls(bounds=tuple(d["bounds"]))

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), sort_keys=True)

    @classmethod
    def from_json(cls, s: str) -> "ShardMap":
        return cls.from_dict(json.loads(s))


@dataclasses.dataclass(frozen=True)
class FleetSpec:
    """Everything needed to (re)produce a tuned fleet from (data, profile).

    Fields
    ------
    n_shards:           key-range shards (each its own on-disk index file
                        with its own Alg. 2 search).
    tune:               per-shard :class:`TuneSpec` — families, λ-grid,
                        strategy, and the tuning ``objective`` ("mean" or
                        a ``{"p": q, "weight": w}`` tail objective, which
                        every shard search and ``Fleet.retune`` /
                        ``retune_budgeted`` honor); every shard searches
                        the same space but against its OWN keys and
                        profile.
    serve:              per-shard :class:`ServeSpec` template; the global
                        budget allocator overrides each shard's
                        ``cache_bytes`` (preserving the template's tier
                        proportions when it names several tiers).
    cache_budget_bytes: global cache-byte budget shared by all shards;
                        0 disables budgeting (every shard serves with the
                        ``serve`` template's own cache configuration).
    budget_quantum:     allocation granularity in bytes; 0 = the tune
                        spec's ``page_bytes`` (else 4096) — the cache's
                        page unit, so allocations are always whole pages.
    """

    n_shards: int = 4
    tune: TuneSpec = TuneSpec()
    serve: ServeSpec = ServeSpec()
    cache_budget_bytes: int = 0
    budget_quantum: int = 0

    # -- validation ---------------------------------------------------------
    def validate(self) -> "FleetSpec":
        if self.n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {self.n_shards}")
        if self.cache_budget_bytes < 0 or self.budget_quantum < 0:
            raise ValueError(
                f"negative sizes: cache_budget_bytes="
                f"{self.cache_budget_bytes} "
                f"budget_quantum={self.budget_quantum}")
        self.tune.validate()
        self.serve.validate()
        return self

    @property
    def quantum(self) -> int:
        """Effective allocation granularity (never 0)."""
        return int(self.budget_quantum or self.tune.page_bytes or 4096)

    def replace(self, **changes) -> "FleetSpec":
        return dataclasses.replace(self, **changes)

    # -- JSON round-trip ----------------------------------------------------
    def to_dict(self) -> dict:
        return {
            "n_shards": self.n_shards,
            "tune": self.tune.to_dict(),
            "serve": self.serve.to_dict(),
            "cache_budget_bytes": self.cache_budget_bytes,
            "budget_quantum": self.budget_quantum,
        }

    @classmethod
    def from_dict(cls, d: dict) -> "FleetSpec":
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(
                f"unknown FleetSpec fields {sorted(unknown)}; "
                f"allowed: {sorted(known)}")
        kw = dict(d)
        if "tune" in kw and isinstance(kw["tune"], dict):
            kw["tune"] = TuneSpec.from_dict(kw["tune"])
        if "serve" in kw and isinstance(kw["serve"], dict):
            kw["serve"] = ServeSpec.from_dict(kw["serve"])
        return cls(**kw)

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), sort_keys=True)

    @classmethod
    def from_json(cls, s: str) -> "FleetSpec":
        return cls.from_dict(json.loads(s))
