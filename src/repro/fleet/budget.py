"""Global cache-byte budgeting across a fleet of shards (§6 applied
fleet-wide): allocate one memory budget over N per-shard block caches by
marginal E[T(Δ)] gain.

Each shard's Eq. 6 cost as a function of its cache bytes ``c`` is — under
the engine's LRU with a stable working set ``w`` and the linear hit model
``h(c) = min(1, c/w)`` — piecewise linear and concave::

    cost_i(c) = base_i + saving_i · (1 − h(c))
              = base_i + saving_i · max(0, 1 − c/w_i)

so the *marginal* gain of one more byte given to shard ``i`` is the
constant ``traffic_i · saving_i / w_i`` until the working set fits, then
zero.  Greedy water-filling over such curves is exactly optimal: sort
shards by marginal-gain density and saturate working sets in that order.
``saving_i`` is the per-query Eq. 6 spend a full cache removes (the
backing-tier cost of every non-resident layer read, minus the cache
tier's hit cost), ``w_i`` the shard's cacheable working set (serialized
bytes of its non-resident layers), and ``traffic_i`` the shard's observed
query share — recomputed from persisted per-shard ServeStats so hot
shards earn more cache (see :meth:`repro.fleet.Fleet.serve`).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.storage import StorageProfile
from repro.serve.index_service import cacheable_working_set

DEFAULT_QUANTUM = 4096


@dataclasses.dataclass(frozen=True)
class ShardDemand:
    """One shard's cost-vs-cache-bytes curve, reduced to its three
    sufficient statistics (the curve is linear until saturation)."""

    shard: int
    traffic: float      # observed query share (any nonnegative scale)
    working_set: int    # cacheable bytes: serialized non-resident layers
    saving: float       # per-query E[T] seconds a full cache removes

    @property
    def density(self) -> float:
        """Marginal gain of one cached byte: traffic · saving / w
        (seconds removed per byte, before saturation)."""
        if self.working_set <= 0 or self.saving <= 0 or self.traffic <= 0:
            return 0.0
        return self.traffic * self.saving / float(self.working_set)

    def gain(self, alloc_bytes: int) -> float:
        """Traffic-weighted seconds removed by an ``alloc_bytes`` cache
        (the linear hit model's prediction, saturating at w)."""
        if self.working_set <= 0:
            return 0.0
        h = min(1.0, alloc_bytes / float(self.working_set))
        return self.traffic * self.saving * h

    def to_dict(self) -> dict:
        return {"shard": self.shard, "traffic": self.traffic,
                "working_set": self.working_set, "saving": self.saving,
                "density": self.density}


def _resident_split(layers, resident_layers: int):
    """Non-resident slice of a bottom-up layer tuple, mirroring the
    engine's pinning rule (top ``n_res`` layers resident, root always)."""
    L = len(layers)
    n_res = min(max(int(resident_layers), 1), L) if L else 0
    return layers[:L - n_res]


def demand_from_design(shard: int, design, backing: StorageProfile, *,
                       cache: StorageProfile | None = None,
                       resident_layers: int = 1, traffic: float = 1.0,
                       working_set: int | None = None) -> ShardDemand:
    """Exact Eq. 6 saving for an in-memory design: the weighted-mean
    backing cost of every non-resident layer's prediction windows, minus
    the cache tier's hit cost for the same windows — what the block cache
    removes per query once the working set is resident.  ``working_set``
    defaults to the layers' serialized sizes (pass the file meta's exact
    figure when the fleet is already on disk)."""
    cacheable = _resident_split(design.layers, resident_layers)
    if working_set is None:
        working_set = int(sum(l.size_bytes for l in cacheable))
    saving = 0.0
    D = design.data
    for layer in cacheable:
        wq = layer.widths_at(D.keys)
        full = float(np.average(backing(wq), weights=D.weights))
        hit = float(np.average(cache(wq), weights=D.weights)) \
            if cache is not None else 0.0
        saving += max(full - hit, 0.0)
    return ShardDemand(shard=int(shard), traffic=float(traffic),
                       working_set=int(working_set), saving=saving)


def demand_from_meta(shard: int, meta, backing: StorageProfile, *,
                     cache: StorageProfile | None = None,
                     resident_layers: int = 1,
                     traffic: float = 1.0) -> ShardDemand:
    """Demand for a disk-opened shard whose design cannot be materialized
    (no data layer): the working set is exact (layer sizes from the file
    meta); the per-layer window cost is approximated by one page-sized
    read per non-resident layer — the right order for tuned designs,
    whose windows land near the layout page."""
    cacheable = _resident_split(meta.layers, resident_layers)
    working_set = cacheable_working_set(meta, resident_layers)
    win = float(meta.page_bytes or DEFAULT_QUANTUM)
    per_read = float(backing(win)) - (float(cache(win))
                                      if cache is not None else 0.0)
    saving = max(per_read, 0.0) * len(cacheable)
    return ShardDemand(shard=int(shard), traffic=float(traffic),
                       working_set=int(working_set), saving=saving)


@dataclasses.dataclass(frozen=True)
class CachePlan:
    """The allocator's output: per-shard cache bytes plus the evidence
    (demands, predicted gains) — serve_bench persists these per PR."""

    total_bytes: int
    quantum: int
    shares: tuple         # ((shard, bytes), ...) in shard order
    demands: tuple        # the ShardDemand inputs, in shard order

    def for_shard(self, shard: int) -> int:
        for s, b in self.shares:
            if s == shard:
                return b
        return 0

    @property
    def allocated_bytes(self) -> int:
        return int(sum(b for _, b in self.shares))

    @property
    def unallocated_bytes(self) -> int:
        return self.total_bytes - self.allocated_bytes

    @property
    def predicted_gain(self) -> float:
        """Traffic-weighted seconds removed per unit traffic-time — the
        water-filling objective value at this allocation."""
        by_shard = {d.shard: d for d in self.demands}
        return float(sum(by_shard[s].gain(b) for s, b in self.shares
                         if s in by_shard))

    def to_dict(self) -> dict:
        return {
            "total_bytes": self.total_bytes,
            "quantum": self.quantum,
            "shares": {str(s): b for s, b in self.shares},
            "unallocated_bytes": self.unallocated_bytes,
            "predicted_gain": self.predicted_gain,
            "demands": [d.to_dict() for d in self.demands],
        }


def allocate_cache_budget(demands, total_bytes: int, *,
                          quantum: int = DEFAULT_QUANTUM) -> CachePlan:
    """Greedy water-filling: saturate working sets in marginal-gain-density
    order until the budget runs out.  Optimal for the piecewise-linear
    concave per-shard curves (each shard's marginal gain is constant until
    its working set fits, then zero), so no fractional refinement is
    needed — allocations are rounded to whole ``quantum`` units (the cache
    page size) and never exceed a shard's working set plus one quantum.

    Budget left over once every working set fits stays unallocated (the
    linear model prices extra bytes at zero marginal gain); callers can
    fold it back as slack if they prefer."""
    demands = sorted(demands, key=lambda d: d.shard)
    if len({d.shard for d in demands}) != len(demands):
        raise ValueError("duplicate shard ids in demands")
    total = max(int(total_bytes), 0)
    q = max(int(quantum), 1)
    alloc = {d.shard: 0 for d in demands}
    remaining = total
    # density desc; ties broken toward hotter, then lower-id shards so the
    # plan is deterministic for identical demands
    order = sorted(demands, key=lambda d: (-d.density, -d.traffic, d.shard))
    for d in order:
        if remaining < q or d.density <= 0:
            continue
        want = -(-d.working_set // q) * q        # round w up to whole pages
        give = min(want, (remaining // q) * q)
        alloc[d.shard] = give
        remaining -= give
    return CachePlan(total_bytes=total, quantum=q,
                     shares=tuple((d.shard, alloc[d.shard])
                                  for d in demands),
                     demands=tuple(demands))


def split_cache_tiers(alloc_bytes: int, template, *,
                      quantum: int = DEFAULT_QUANTUM) -> tuple:
    """Split one shard's allocation across the ServeSpec template's cache
    tiers, preserving the template's proportions (rounded to whole
    quanta, remainder to the hottest tier).  An empty template — engine
    default — becomes a single tier of the full allocation."""
    alloc = max(int(alloc_bytes), 0)
    tiers = tuple(int(t) for t in (template or ()))
    if not tiers or sum(tiers) <= 0:
        return (alloc,)
    q = max(int(quantum), 1)
    total = float(sum(tiers))
    out = [(int(alloc * t / total) // q) * q for t in tiers]
    out[0] += alloc - sum(out)
    return tuple(out)
