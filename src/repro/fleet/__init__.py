"""``repro.fleet`` — sharded multi-tenant serving (ROADMAP: past one
``IndexService`` per process).

A fleet is N key-range shards, each its own on-disk index file with its
own Alg. 2 search, served through scatter-gather with one *global*
cache-byte budget allocated across shards by marginal E[T(Δ)] gain::

    from repro.fleet import Fleet, FleetSpec

    fleet = Fleet.tune(D, "azure_ssd",
                       FleetSpec(n_shards=4, cache_budget_bytes=2 << 20))
    fleet.save("fleet_dir/")
    with Fleet.open("fleet_dir/").serve() as svc:
        ranges = svc.lookup(keys)          # global byte ranges

See :mod:`repro.fleet.fleet` (facade), :mod:`repro.fleet.spec`
(ShardMap/FleetSpec), :mod:`repro.fleet.service` (scatter-gather), and
:mod:`repro.fleet.budget` (water-filling allocator).
"""
from .budget import (CachePlan, ShardDemand, allocate_cache_budget,
                     demand_from_design, demand_from_meta, split_cache_tiers)
from .fleet import Fleet
from .service import FleetService, ShardUnavailableError
from .spec import FleetSpec, ShardMap

__all__ = [
    "Fleet", "FleetSpec", "FleetService", "ShardMap",
    "ShardUnavailableError",
    "CachePlan", "ShardDemand", "allocate_cache_budget",
    "demand_from_design", "demand_from_meta", "split_cache_tiers",
]
