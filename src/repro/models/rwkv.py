"""RWKV6 "Finch" — attention-free LM with data-dependent decay (arXiv:2404.05892).

Time-mix: data-dependent token-shift lerp (ddlerp LoRAs) producing r,k,v,g
and the per-channel decay w_t = exp(−exp(w0 + LoRA_w(x̃))); the WKV
recurrence runs through the shared chunked linear scan (exclusive form with
bonus u).  Channel-mix: token-shifted squared-ReLU FFN.

O(1)-state decode: each layer carries (x_prev_att, x_prev_ffn, WKV state).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from repro.dist.sharding import constrain_residual
from .layers import rms_norm
from .linear_scan import chunked_linear_scan, linear_scan_decode

LORA_R = 64


def param_specs(cfg: ModelConfig) -> dict:
    d, ff, L = cfg.d_model, cfg.d_ff, cfg.n_layers
    H, N = cfg.n_wkv_heads, cfg.wkv_head_dim
    dt = cfg.jdtype
    S = lambda *shape: jax.ShapeDtypeStruct((L, *shape), dt)
    blocks = {
        "ln1": S(d), "ln2": S(d),
        # ddlerp: base mus + one LoRA pair per stream (r,k,v,w,g)
        "mu_base": S(5, d),
        "lora_a": S(5, d, LORA_R), "lora_b": S(5, LORA_R, d),
        "wr": S(d, d), "wk": S(d, d), "wv": S(d, d), "wg": S(d, d),
        "wo": S(d, d),
        "w0": S(d),                               # decay bias
        "wdecay_a": S(d, LORA_R), "wdecay_b": S(LORA_R, d),
        "bonus_u": S(H, N),
        "gn_scale": S(H, N),                      # per-head group norm
        # channel mix
        "mu_ck": S(d), "mu_cr": S(d),
        "ck": S(d, ff), "cv": S(ff, d), "cr": S(d, d),
    }
    return {
        "embed": jax.ShapeDtypeStruct((cfg.padded_vocab, d), dt),
        "unembed": jax.ShapeDtypeStruct((d, cfg.padded_vocab), dt),
        "final_norm": jax.ShapeDtypeStruct((d,), dt),
        "blocks": blocks,
    }


def init_params(cfg: ModelConfig, rng) -> dict:
    specs = param_specs(cfg)
    flat, tree = jax.tree_util.tree_flatten_with_path(specs)
    keys = jax.random.split(rng, len(flat))
    out = []
    for key, (path, s) in zip(keys, flat):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if name in ("ln1", "ln2", "final_norm", "w0", "gn_scale"):
            v = jnp.zeros(s.shape, s.dtype)
        elif name.startswith("mu"):
            v = jnp.full(s.shape, 0.5, s.dtype)
        elif name == "bonus_u":
            v = jnp.full(s.shape, 0.1, s.dtype)
        else:
            fan_in = s.shape[-2] if len(s.shape) >= 2 else s.shape[-1]
            v = (jax.random.normal(key, s.shape, jnp.float32)
                 / jnp.sqrt(fan_in)).astype(s.dtype)
        out.append(v)
    return jax.tree_util.tree_unflatten(tree, out)


def _token_shift(x, x_prev_first):
    """Shift sequence right by one; position 0 sees x_prev_first (B,d)."""
    return jnp.concatenate([x_prev_first[:, None, :], x[:, :-1, :]], axis=1)


def _ddlerp(x, xs, mu_base, lora_a, lora_b):
    """Data-dependent lerp for the 5 streams → (5, B, T, d)."""
    delta = (xs - x).astype(jnp.float32)
    # shared inner mix then per-stream LoRA (Finch §3)
    inner = x.astype(jnp.float32) + delta * mu_base[0][None, None]
    mixes = []
    for i in range(5):
        lor = jnp.tanh(inner @ lora_a[i].astype(jnp.float32)) @ \
            lora_b[i].astype(jnp.float32)
        mu = mu_base[i][None, None].astype(jnp.float32) + lor
        mixes.append(x.astype(jnp.float32) + delta * mu)
    return mixes  # [r, k, v, w, g]


def _time_mix(cfg, p, x, x_prev, wkv_state, *, chunked=True):
    """x (B,T,d).  Returns (out, new_x_prev (B,d), new_state)."""
    B, T, d = x.shape
    H, N = cfg.n_wkv_heads, cfg.wkv_head_dim
    xs = _token_shift(x, x_prev)
    xr, xk, xv, xw, xg = _ddlerp(x, xs, p["mu_base"], p["lora_a"], p["lora_b"])
    f32 = jnp.float32
    r = (xr @ p["wr"].astype(f32)).reshape(B, T, H, N).transpose(0, 2, 1, 3)
    k = (xk @ p["wk"].astype(f32)).reshape(B, T, H, N).transpose(0, 2, 1, 3)
    v = (xv @ p["wv"].astype(f32)).reshape(B, T, H, N).transpose(0, 2, 1, 3)
    g = jax.nn.silu(xg @ p["wg"].astype(f32))
    dec = p["w0"].astype(f32)[None, None] + \
        jnp.tanh(xw @ p["wdecay_a"].astype(f32)) @ p["wdecay_b"].astype(f32)
    logw = -jnp.exp(-3.0 + dec)     # w = exp(−exp(·)) ∈ (0,1); mild at init
    logw = logw.reshape(B, T, H, N).transpose(0, 2, 1, 3)
    u = p["bonus_u"].astype(f32)
    if chunked:
        y, new_state = chunked_linear_scan(r, k, v, logw, wkv_state,
                                           inclusive=False, bonus=u)
    else:
        y, new_state = linear_scan_decode(
            r[:, :, 0], k[:, :, 0], v[:, :, 0], logw[:, :, 0], wkv_state,
            inclusive=False, bonus=u)
        y = y[:, :, None, :]
    # per-head group norm, then gate
    y = y.transpose(0, 2, 1, 3)                       # (B,T,H,N)
    mean = y.mean(-1, keepdims=True)
    var = y.var(-1, keepdims=True)
    y = (y - mean) * jax.lax.rsqrt(var + 1e-5) * \
        (1.0 + p["gn_scale"].astype(f32))[None, None]
    y = y.reshape(B, T, d) * g
    out = (y @ p["wo"].astype(f32)).astype(x.dtype)
    return out, x[:, -1, :], new_state


def _channel_mix(p, x, x_prev):
    B, T, d = x.shape
    xs = _token_shift(x, x_prev)
    f32 = jnp.float32
    xk = x.astype(f32) + (xs - x).astype(f32) * p["mu_ck"].astype(f32)
    xr = x.astype(f32) + (xs - x).astype(f32) * p["mu_cr"].astype(f32)
    h = jnp.square(jax.nn.relu(xk @ p["ck"].astype(f32)))
    out = jax.nn.sigmoid(xr @ p["cr"].astype(f32)) * (h @ p["cv"].astype(f32))
    return out.astype(x.dtype), x[:, -1, :]


def _block(cfg, p, x, state, *, chunked=True):
    att_out, xp_att, wkv = _time_mix(cfg, p, rms_norm(x, p["ln1"]),
                                     state["x_att"], state["wkv"],
                                     chunked=chunked)
    x = x + att_out
    ffn_out, xp_ffn = _channel_mix(p, rms_norm(x, p["ln2"]), state["x_ffn"])
    x = x + ffn_out
    return x, {"x_att": xp_att, "x_ffn": xp_ffn, "wkv": wkv}


def state_specs(cfg: ModelConfig, batch: int):
    H, N, d, L = cfg.n_wkv_heads, cfg.wkv_head_dim, cfg.d_model, cfg.n_layers
    return {
        "x_att": jax.ShapeDtypeStruct((L, batch, d), cfg.jdtype),
        "x_ffn": jax.ShapeDtypeStruct((L, batch, d), cfg.jdtype),
        "wkv": jax.ShapeDtypeStruct((L, batch, H, N, N), jnp.float32),
    }


def init_state(cfg: ModelConfig, batch: int):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        state_specs(cfg, batch))


def _run(cfg, params, tokens, state, *, chunked):
    x = constrain_residual(params["embed"][tokens])

    def body(x, xs):
        pblk, st = xs
        x = constrain_residual(x)
        x, new_st = _block(cfg, pblk, x, st, chunked=chunked)
        return x, new_st

    body = jax.checkpoint(body) if (cfg.remat and chunked) else body
    x, new_state = jax.lax.scan(body, x, (params["blocks"], state))
    return rms_norm(x, params["final_norm"]), new_state


def forward_hidden(cfg: ModelConfig, params, batch):
    B = batch["tokens"].shape[0]
    hidden, _ = _run(cfg, params, batch["tokens"], init_state(cfg, B),
                     chunked=True)
    return hidden, 0.0


def forward_train(cfg: ModelConfig, params, batch):
    hidden, aux = forward_hidden(cfg, params, batch)
    return hidden @ params["unembed"], aux


def forward_decode(cfg: ModelConfig, params, batch, state, pos):
    """One token; state carries per-layer (x_att, x_ffn, wkv).  pos unused
    (RWKV has no positional encoding) but kept for API symmetry."""
    hidden, new_state = _run(cfg, params, batch["tokens"], state,
                             chunked=False)
    return hidden @ params["unembed"], new_state
