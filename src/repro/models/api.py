"""Uniform model API: dispatch by config.family.

    param_specs(cfg)                  → abstract params (dry-run/sharding)
    init_params(cfg, rng)             → concrete params (smoke/examples)
    forward_train(cfg, params, batch) → (logits, aux_loss)
    forward_decode(cfg, params, batch, cache, pos) → (logits, new_cache)
    decode_state_specs(cfg, batch, max_len) → abstract cache/state
    input_specs(cfg, shape)           → abstract batch for a named shape

The four assigned input shapes (train_4k / prefill_32k / decode_32k /
long_500k) are materialized by :func:`input_specs` as ShapeDtypeStructs —
weak-type-correct, shardable, no allocation.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from . import rwkv, ssm, transformer, whisper
from .config import ModelConfig

_TRANSFORMER_FAMILIES = ("dense", "moe", "vlm")


def _mod(cfg: ModelConfig):
    if cfg.family in _TRANSFORMER_FAMILIES:
        return transformer
    if cfg.family == "ssm":
        return rwkv
    if cfg.family == "hybrid":
        return ssm
    if cfg.family == "audio":
        return whisper
    raise ValueError(cfg.family)


def param_specs(cfg):
    return _mod(cfg).param_specs(cfg)


def init_params(cfg, rng):
    return _mod(cfg).init_params(cfg, rng)


def forward_train(cfg, params, batch):
    return _mod(cfg).forward_train(cfg, params, batch)


def forward_hidden(cfg, params, batch):
    """Final-normed hidden states before the unembedding — the loss and
    prefill paths unembed chunk-wise / last-token-only to avoid ever
    materializing (B, S, vocab) logits."""
    return _mod(cfg).forward_hidden(cfg, params, batch)


def apply_unembed(cfg, params, hidden):
    logits = hidden @ params["unembed"]
    if getattr(cfg, "final_softcap", None):
        logits = cfg.final_softcap * jnp.tanh(
            logits.astype(jnp.float32) / cfg.final_softcap)
    if cfg.padded_vocab != cfg.vocab:      # mask padded columns for sampling
        iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                        logits.ndim - 1)
        logits = jnp.where(iota < cfg.vocab, logits, -1e30)
    return logits


def forward_decode(cfg, params, batch, cache, pos):
    return _mod(cfg).forward_decode(cfg, params, batch, cache, pos)


def decode_state_specs(cfg: ModelConfig, batch: int, max_len: int):
    if cfg.family in _TRANSFORMER_FAMILIES:
        return transformer.cache_specs(cfg, batch, max_len)
    if cfg.family == "ssm":
        return rwkv.state_specs(cfg, batch)
    if cfg.family == "hybrid":
        return ssm.state_specs(cfg, batch, max_len)
    if cfg.family == "audio":
        return whisper.cache_specs(cfg, batch, max_len)
    raise ValueError(cfg.family)


def init_decode_state(cfg: ModelConfig, params, batch: int, max_len: int,
                      frames=None):
    if cfg.family == "audio":
        if frames is None:
            frames = jnp.zeros((batch, cfg.n_frames, cfg.d_model), cfg.jdtype)
        return whisper.init_cache(cfg, params, frames, batch, max_len)
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        decode_state_specs(cfg, batch, max_len))


# ---------------------------------------------------------------------------
# assigned input shapes
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str      # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}

# long-context decode requires O(1)/sub-quadratic state (DESIGN.md §5)
LONG_CONTEXT_FAMILIES = ("ssm", "hybrid")


def shape_supported(cfg: ModelConfig, shape: InputShape) -> bool:
    if shape.name == "long_500k":
        return cfg.family in LONG_CONTEXT_FAMILIES
    return True


def input_specs(cfg: ModelConfig, shape: InputShape) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of a shape cell."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    tok = lambda s: jax.ShapeDtypeStruct((B, s), i32)
    if shape.kind == "train":
        batch = {"tokens": tok(S), "labels": tok(S)}
    elif shape.kind == "prefill":
        batch = {"tokens": tok(S)}
    else:  # decode: one new token; cache of length S is a separate input
        batch = {"tokens": tok(1)}
    if cfg.family == "vlm" and shape.kind != "decode":
        batch["patch_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.n_patches, cfg.d_model), cfg.jdtype)
        batch["patch_positions"] = jax.ShapeDtypeStruct(
            (B, cfg.n_patches), i32)
    if cfg.family == "audio" and shape.kind != "decode":
        batch["frames"] = jax.ShapeDtypeStruct(
            (B, cfg.n_frames, cfg.d_model), cfg.jdtype)
    return batch
