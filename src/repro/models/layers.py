"""Shared building blocks: norms, RoPE, blocked attention, MLP, MoE.

Attention here is the *jnp* implementation (flash-style blocked online
softmax via lax.scan) used for CPU smoke tests and the 512-device dry-run
lowering; on TPU the Pallas kernels in repro.kernels are drop-in (same
math, validated against the same refs).  Blocked form is mandatory even in
jnp: a 32k×32k logit matrix would never fit.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def rms_norm(x, scale, eps=1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def rope(x, positions, theta=10000.0):
    """x (..., S, H, D); positions (..., S) int32."""
    d = x.shape[-1]
    half = d // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def blocked_attention(q, k, v, *, causal=True, window=None, softcap=None,
                      q_offset=None, kv_length=None, block_k=1024,
                      scale=None):
    """Flash-style attention in jnp (online softmax over kv blocks).

    q (B,Hq,Sq,D); k/v (B,Hkv,Skv,D) → (B,Hq,Sq,D) in q.dtype.
    Same semantics as kernels.flash_attention.ref (q positions end-aligned
    unless q_offset given; kv_length masks a padded cache).
    """
    B, Hq, Sq, D = q.shape
    _, Hkv, Skv, _ = k.shape
    group = Hq // Hkv
    scale = scale if scale is not None else 1.0 / (D ** 0.5)
    q_offset = Skv - Sq if q_offset is None else q_offset
    qf = q.astype(jnp.float32) * scale
    nb = -(-Skv // block_k)
    pad = nb * block_k - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    kb = k.reshape(B, Hkv, nb, block_k, D).transpose(2, 0, 1, 3, 4)
    vb = v.reshape(B, Hkv, nb, block_k, D).transpose(2, 0, 1, 3, 4)
    q_pos = q_offset + jnp.arange(Sq)
    valid_len = jnp.full((B,), Skv, jnp.int32) if kv_length is None else kv_length

    def step(carry, blk):
        m, l, acc, ib = carry
        kblk, vblk = blk                                  # (B,Hkv,bk,D)
        kg = jnp.repeat(kblk, group, axis=1).astype(jnp.float32)
        vg = jnp.repeat(vblk, group, axis=1).astype(jnp.float32)
        s = jnp.einsum("bhqd,bhkd->bhqk", qf, kg)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        k_pos = ib * block_k + jnp.arange(block_k)
        mask = jnp.ones((Sq, block_k), bool)
        if causal:
            mask &= k_pos[None, :] <= q_pos[:, None]
        if window is not None:
            mask &= k_pos[None, :] > q_pos[:, None] - window
        mask = mask[None, None] & (
            k_pos[None, None, None, :] < valid_len[:, None, None, None])
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l = l * alpha + p.sum(axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum("bhqk,bhkd->bhqd", p, vg)
        return (m_new, l, acc, ib + 1), None

    m0 = jnp.full((B, Hq, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hq, Sq), jnp.float32)
    a0 = jnp.zeros((B, Hq, Sq, D), jnp.float32)
    # remat each kv-block step: backward recomputes the (Sq × block_k)
    # score tile instead of saving it — the flash-attention memory bound
    (m, l, acc, _), _ = jax.lax.scan(jax.checkpoint(step), (m0, l0, a0, 0),
                                     (kb, vb))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.astype(q.dtype)


def swiglu(x, w_gate, w_up, w_down):
    h = jax.nn.silu(x @ w_gate) * (x @ w_up)
    return h @ w_down


# ---------------------------------------------------------------------------
# MoE: top-k routing with capacity dropping via expert-sorted permutation
# ---------------------------------------------------------------------------
MOE_GROUPS = 64  # routing groups; ≥ DP degree so each shard sorts locally


def _moe_group_dispatch(x, gate_vals, experts, we_gate, we_up, we_down,
                        top_k, capacity_factor):
    """One routing group: x (t, d); experts (t, k) → (t, d)."""
    t, d = x.shape
    E = we_gate.shape[0]
    C = max(int(t * top_k * capacity_factor / E), 4)
    flat_e = experts.reshape(-1)                            # (t·k,)
    order = jnp.argsort(flat_e)                             # stable
    sorted_e = flat_e[order]
    # rank within expert group = position − group start
    group_start = jnp.searchsorted(sorted_e, jnp.arange(E))
    pos_in_e = jnp.arange(t * top_k) - group_start[sorted_e]
    keep = pos_in_e < C
    slot = jnp.where(keep, sorted_e * C + pos_in_e, E * C)  # overflow slot
    tok = order // top_k
    buf = jnp.zeros((E * C + 1, d), x.dtype).at[slot].set(x[tok])
    buf = buf[:-1].reshape(E, C, d)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, we_gate)) * \
        jnp.einsum("ecd,edf->ecf", buf, we_up)
    y = jnp.einsum("ecf,efd->ecd", h, we_down).reshape(E * C, d)
    contrib = jnp.where(keep[:, None], y[jnp.minimum(slot, E * C - 1)], 0)
    g = gate_vals.reshape(-1)[order][:, None].astype(x.dtype)
    return jnp.zeros_like(x).at[tok].add(contrib * g)


def moe_ffn(x, router_w, we_gate, we_up, we_down, *, top_k, capacity_factor):
    """x (T, d) → (T, d).  Experts computed on a capacity-padded,
    expert-contiguous buffer (megablocks-lite): argsort token→expert
    assignments, gather into (E, C, d), batched expert matmuls, scatter
    back with gate weighting.  Tokens beyond capacity are dropped.

    Dispatch runs per *routing group* (vmap over MOE_GROUPS slices): the
    argsort/scatter stay local to each group, so with the group axis
    sharded over DP the SPMD partitioner never materializes a global
    sort — a global argsort replicated the full token buffer on every
    device (695 GB/dev on grok prefill_32k in the dry-run memory
    analysis; see benchmarks/roofline.py and ROADMAP.md).
    """
    T, d = x.shape
    logits = (x.astype(jnp.float32) @ router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, experts = jax.lax.top_k(probs, top_k)        # (T, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # groups of ≥256 tokens so per-group capacity stays meaningful; tiny
    # token counts (decode) fall back to one global (but tiny) sort
    G = max(min(MOE_GROUPS, T // 256), 1)
    while T % G:
        G -= 1
    disp = functools.partial(_moe_group_dispatch, top_k=top_k,
                             capacity_factor=capacity_factor,
                             we_gate=we_gate, we_up=we_up, we_down=we_down)
    out = jax.vmap(disp)(x.reshape(G, T // G, d),
                         gate_vals.reshape(G, T // G, top_k),
                         experts.reshape(G, T // G, top_k))
    return out.reshape(T, d)


def aux_load_balance_loss(x, router_w, top_k):
    """Switch-style load-balancing auxiliary loss (fraction·prob per expert)."""
    logits = x.astype(jnp.float32) @ router_w.astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    E = probs.shape[-1]
    _, experts = jax.lax.top_k(probs, top_k)
    onehot = jax.nn.one_hot(experts, E).sum(axis=-2)  # (T, E)
    frac = onehot.mean(axis=0) / top_k
    imp = probs.mean(axis=0)
    return E * jnp.sum(frac * imp)
