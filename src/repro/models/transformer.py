"""Unified decoder-only transformer: dense, MoE, gemma2-style, VLM backbone.

Pure-pytree implementation.  Per-layer parameters are stacked on a leading
axis and the layer stack is a ``lax.scan`` (compile time stays flat in
depth — essential for 62-layer × 512-device dry-runs), with
``jax.checkpoint`` around the block body when cfg.remat.

Gemma2's alternating local/global pattern scans over *pairs* of layers so
the sliding-window mask stays static inside the traced block.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from .config import ModelConfig
from repro.dist.sharding import constrain_residual
from .layers import (aux_load_balance_loss, blocked_attention, moe_ffn,
                     rms_norm, rope, swiglu)


# ---------------------------------------------------------------------------
# parameters
# ---------------------------------------------------------------------------
def _block_specs(cfg: ModelConfig, L: int) -> dict:
    d, ff, hd = cfg.d_model, cfg.d_ff, cfg.hd
    Hq, Hkv = cfg.n_heads, cfg.n_kv_heads
    dt = cfg.jdtype
    S = lambda *shape: jax.ShapeDtypeStruct((L, *shape), dt)
    spec = {
        "ln1": S(d), "ln2": S(d),
        "wq": S(d, Hq * hd), "wk": S(d, Hkv * hd), "wv": S(d, Hkv * hd),
        "wo": S(Hq * hd, d),
    }
    if cfg.qk_norm:
        spec["qnorm"] = S(hd)
        spec["knorm"] = S(hd)
    if cfg.n_experts:
        E = cfg.n_experts
        spec.update({
            "router": S(d, E),
            "we_gate": S(E, d, ff), "we_up": S(E, d, ff), "we_down": S(E, ff, d),
        })
        if cfg.shared_expert:
            spec.update({"ws_gate": S(d, ff), "ws_up": S(d, ff),
                         "ws_down": S(ff, d)})
    else:
        spec.update({"w_gate": S(d, ff), "w_up": S(d, ff), "w_down": S(ff, d)})
    return spec


def param_specs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    dt = cfg.jdtype
    spec = {
        "embed": jax.ShapeDtypeStruct((cfg.padded_vocab, d), dt),
        "unembed": jax.ShapeDtypeStruct((d, cfg.padded_vocab), dt),
        "final_norm": jax.ShapeDtypeStruct((d,), dt),
        "blocks": _block_specs(cfg, cfg.n_layers),
    }
    return spec


def init_params(cfg: ModelConfig, rng) -> dict:
    specs = param_specs(cfg)
    flat, tree = jax.tree_util.tree_flatten(specs)
    keys = jax.random.split(rng, len(flat))

    def init_one(key, s):
        fan_in = s.shape[-2] if len(s.shape) >= 2 else s.shape[-1]
        scale = 0.02 if len(s.shape) < 2 else (1.0 / jnp.sqrt(fan_in))
        return (jax.random.normal(key, s.shape, jnp.float32) * scale).astype(s.dtype)

    leaves = [init_one(k, s) for k, s in zip(keys, flat)]
    params = jax.tree_util.tree_unflatten(tree, leaves)
    # norms start at zero offset (rms_norm uses 1+scale)
    params["final_norm"] = jnp.zeros_like(params["final_norm"])
    params["blocks"]["ln1"] = jnp.zeros_like(params["blocks"]["ln1"])
    params["blocks"]["ln2"] = jnp.zeros_like(params["blocks"]["ln2"])
    return params


# ---------------------------------------------------------------------------
# block body
# ---------------------------------------------------------------------------
def _attention(cfg: ModelConfig, p, x, positions, *, window, cache=None,
               pos=None):
    """x (B,S,d) → (B,S,d); optional cache {k,v} (B,Hkv,Smax,hd) + pos."""
    B, S, d = x.shape
    hd, Hq, Hkv = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    q = (x @ p["wq"]).reshape(B, S, Hq, hd)
    k = (x @ p["wk"]).reshape(B, S, Hkv, hd)
    v = (x @ p["wv"]).reshape(B, S, Hkv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["qnorm"])
        k = rms_norm(k, p["knorm"])
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    q = q.transpose(0, 2, 1, 3)
    k = k.transpose(0, 2, 1, 3)
    v = v.transpose(0, 2, 1, 3)
    if cache is None:
        out = blocked_attention(q, k, v, causal=True, window=window,
                                softcap=cfg.attn_softcap)
        new_cache = None
    else:
        ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                          (0, 0, pos, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                          (0, 0, pos, 0))
        kv_len = jnp.full((B,), pos + S, jnp.int32)
        out = decode_attention_jnp(q, ck, cv, kv_len, window=window,
                                   softcap=cfg.attn_softcap)
        new_cache = {"k": ck, "v": cv}
    out = out.transpose(0, 2, 1, 3).reshape(B, S, Hq * hd)
    return out.astype(x.dtype) @ p["wo"], new_cache


def decode_attention_jnp(q, ck, cv, kv_length, *, window=None, softcap=None):
    """One-token attention over a padded cache (baseline serve path).

    q (B,Hq,Sq,hd); ck/cv (B,Hkv,Smax,hd).  The cache stays in its storage
    dtype: QK/PV einsums take bf16 inputs with f32 accumulation
    (preferred_element_type) and GQA folds the group into the einsum
    instead of jnp.repeat — upcasting + repeating the cache materialized
    ~4x the cache bytes per layer in dry-run memory analysis (see
    benchmarks/roofline.py).  Logits live at
    (B,Hq,Sq,Smax) f32 — fine for decode.
    """
    B, Hq, Sq, hd = q.shape
    Hkv, Smax = ck.shape[1], ck.shape[2]
    group = Hq // Hkv
    scale = 1.0 / (hd ** 0.5)
    qg = (q.astype(jnp.float32) * scale).astype(ck.dtype)
    qg = qg.reshape(B, Hkv, group * Sq, hd)
    s = jnp.einsum("bkgd,bksd->bkgs", qg, ck,
                   preferred_element_type=jnp.float32)
    s = s.reshape(B, Hq, Sq, Smax)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    k_pos = jnp.arange(Smax)
    mask = k_pos[None, None, None, :] < kv_length[:, None, None, None]
    if window is not None:
        mask &= k_pos[None, None, None, :] > (kv_length[:, None, None, None]
                                              - 1 - window)
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    pv = jnp.einsum("bkgs,bksd->bkgd",
                    p.reshape(B, Hkv, group * Sq, Smax).astype(cv.dtype), cv,
                    preferred_element_type=jnp.float32)
    return pv.reshape(B, Hq, Sq, hd)


def _ffn(cfg: ModelConfig, p, x):
    """Dense or MoE FFN on (B,S,d); returns (out, aux_loss)."""
    B, S, d = x.shape
    if not cfg.n_experts:
        return swiglu(x, p["w_gate"], p["w_up"], p["w_down"]), 0.0
    flat = x.reshape(B * S, d)
    y = moe_ffn(flat, p["router"], p["we_gate"], p["we_up"], p["we_down"],
                top_k=cfg.top_k, capacity_factor=cfg.capacity_factor)
    aux = aux_load_balance_loss(flat, p["router"], cfg.top_k)
    if cfg.shared_expert:
        y = y + swiglu(flat, p["ws_gate"], p["ws_up"], p["ws_down"])
    return y.reshape(B, S, d), aux


def _block(cfg: ModelConfig, p, x, positions, *, window, cache=None, pos=None):
    attn_out, new_cache = _attention(cfg, p, rms_norm(x, p["ln1"]), positions,
                                     window=window, cache=cache, pos=pos)
    x = x + attn_out
    ffn_out, aux = _ffn(cfg, p, rms_norm(x, p["ln2"]))
    return x + ffn_out, aux, new_cache


def _window_for(cfg: ModelConfig, sub: int):
    """Static per-sublayer window: gemma2 alternates local (even) / global."""
    if cfg.layer_pattern == "local_global":
        return cfg.sliding_window if sub == 0 else None
    return cfg.sliding_window


# ---------------------------------------------------------------------------
# forward passes
# ---------------------------------------------------------------------------
def _embed(cfg: ModelConfig, params, batch):
    tokens = batch["tokens"]
    x = constrain_residual(params["embed"][tokens])
    if cfg.family == "vlm" and "patch_embeds" in batch:
        # scatter the stub vision-frontend embeddings over image-slot tokens
        pe = batch["patch_embeds"].astype(x.dtype)      # (B, P, d)
        pp = batch["patch_positions"]                   # (B, P) int32
        x = jax.vmap(lambda xi, pi, ei: xi.at[pi].set(ei))(x, pp, pe)
    return x


def _stack_pattern(cfg: ModelConfig):
    """(#scan steps, sublayers per step)."""
    if cfg.layer_pattern == "local_global":
        assert cfg.n_layers % 2 == 0
        return cfg.n_layers // 2, 2
    return cfg.n_layers, 1


def forward_hidden(cfg: ModelConfig, params, batch):
    """→ (final-normed hidden (B,S,d), aux_loss scalar) — pre-unembed."""
    x = _embed(cfg, params, batch)
    B, S, d = x.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    steps, subs = _stack_pattern(cfg)

    def scan_body(carry, pblk):
        x, aux = carry
        x = constrain_residual(x)
        for sub in range(subs):
            psub = jax.tree.map(lambda a: a[sub], pblk) if subs > 1 else pblk
            x, a, _ = _block(cfg, psub, x, positions,
                             window=_window_for(cfg, sub))
            aux = aux + a
        return (x, aux), None

    body = jax.checkpoint(scan_body) if cfg.remat else scan_body
    blocks = params["blocks"]
    if subs > 1:
        blocks = jax.tree.map(
            lambda a: a.reshape(steps, subs, *a.shape[1:]), blocks)
    (x, aux), _ = jax.lax.scan(body, (x, 0.0), blocks)
    return rms_norm(x, params["final_norm"]), aux / cfg.n_layers


def unembed(cfg: ModelConfig, params, hidden):
    logits = hidden @ params["unembed"]
    if cfg.final_softcap is not None:
        logits = cfg.final_softcap * jnp.tanh(
            logits.astype(jnp.float32) / cfg.final_softcap)
    return logits


def forward_train(cfg: ModelConfig, params, batch):
    """→ (logits (B,S,V), aux_loss scalar)."""
    hidden, aux = forward_hidden(cfg, params, batch)
    return unembed(cfg, params, hidden), aux


def cache_specs(cfg: ModelConfig, batch: int, max_len: int):
    hd, Hkv = cfg.hd, cfg.n_kv_heads
    kv = jax.ShapeDtypeStruct((cfg.n_layers, batch, Hkv, max_len, hd),
                              cfg.jdtype)
    return {"k": kv, "v": kv}


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        cache_specs(cfg, batch, max_len))


def forward_decode(cfg: ModelConfig, params, batch, cache, pos):
    """One decode step.  batch.tokens (B,1); cache {k,v} (L,B,Hkv,Smax,hd);
    pos: scalar int32 current length.  → (logits (B,1,V), new cache)."""
    x = _embed(cfg, params, batch)
    B, S, d = x.shape
    positions = jnp.broadcast_to(pos + jnp.arange(S), (B, S))
    steps, subs = _stack_pattern(cfg)

    def scan_body(x, xs):
        pblk, ck, cv = xs
        x = constrain_residual(x)
        new_k, new_v = [], []
        for sub in range(subs):
            psub = jax.tree.map(lambda a: a[sub], pblk) if subs > 1 else pblk
            cks = ck[sub] if subs > 1 else ck
            cvs = cv[sub] if subs > 1 else cv
            x, _, nc = _block(cfg, psub, x, positions,
                              window=_window_for(cfg, sub),
                              cache={"k": cks, "v": cvs}, pos=pos)
            new_k.append(nc["k"])
            new_v.append(nc["v"])
        nk = jnp.stack(new_k) if subs > 1 else new_k[0]
        nv = jnp.stack(new_v) if subs > 1 else new_v[0]
        return x, (nk, nv)

    blocks = params["blocks"]
    ck, cv = cache["k"], cache["v"]
    if subs > 1:
        blocks = jax.tree.map(
            lambda a: a.reshape(steps, subs, *a.shape[1:]), blocks)
        ck = ck.reshape(steps, subs, *ck.shape[1:])
        cv = cv.reshape(steps, subs, *cv.shape[1:])
    x, (nk, nv) = jax.lax.scan(scan_body, x, (blocks, ck, cv))
    if subs > 1:
        nk = nk.reshape(cfg.n_layers, *nk.shape[2:])
        nv = nv.reshape(cfg.n_layers, *nv.shape[2:])
    x = rms_norm(x, params["final_norm"])
    logits = x @ params["unembed"]
    if cfg.final_softcap is not None:
        logits = cfg.final_softcap * jnp.tanh(
            logits.astype(jnp.float32) / cfg.final_softcap)
    return logits, {"k": nk, "v": nv}
