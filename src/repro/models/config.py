"""Model configuration — one dataclass covering the 10 assigned families."""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int | None = None  # defaults to d_model // n_heads

    # attention options
    qk_norm: bool = False
    attn_softcap: float | None = None      # gemma2 attention logit softcap
    final_softcap: float | None = None     # gemma2 final logit softcap
    sliding_window: int | None = None      # local layers' window
    layer_pattern: str = "global"          # "local_global" alternates
    rope_theta: float = 10000.0

    # MoE
    n_experts: int = 0
    top_k: int = 0
    shared_expert: bool = False            # llama4-style shared expert
    capacity_factor: float = 1.25

    # SSM / linear attention
    ssm_state: int = 0                     # mamba2 state size
    wkv_head_dim: int = 64                 # rwkv6 head dim
    attn_every: int = 0                    # zamba2: shared attn cadence
    conv_width: int = 4                    # mamba conv window

    # encoder-decoder (whisper)
    encoder_layers: int = 0
    n_frames: int = 0                      # stub frontend output length

    # VLM (llava)
    n_patches: int = 0                     # stub patch embeddings per image

    dtype: str = "bfloat16"
    # training
    remat: bool = True

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a multiple of 128 so the unembedding (and
        the CE loss) shard over the 16-way model axis; padded logit columns
        are masked to −inf in the loss and at sampling time."""
        return -(-self.vocab // 128) * 128

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    @property
    def n_wkv_heads(self) -> int:
        return self.d_model // self.wkv_head_dim

    def scaled(self, **overrides) -> "ModelConfig":
        """Reduced-config variant (smoke tests)."""
        return dataclasses.replace(self, **overrides)

    # ---- parameter counting (roofline MODEL_FLOPS = 6·N·D) ----
    def param_count(self, active_only: bool = False) -> int:
        d, ff, L = self.d_model, self.d_ff, self.n_layers
        hd, Hq, Hkv = self.hd, self.n_heads, self.n_kv_heads
        attn = d * hd * (Hq + 2 * Hkv) + Hq * hd * d
        dense_mlp = 3 * d * ff
        n = 0
        if self.family in ("dense", "vlm"):
            n = L * (attn + dense_mlp)
        elif self.family == "moe":
            e = (self.top_k if active_only else self.n_experts)
            mlp = 3 * d * ff * e + (3 * d * ff if self.shared_expert else 0)
            n = L * (attn + mlp + d * self.n_experts)
        elif self.family == "ssm":       # rwkv6
            H = self.n_wkv_heads
            # time-mix: wr,wk,wv,wg,wo (5·d²) + ddlerp/decay LoRAs;
            # channel-mix: ck (d·ff) + cv (ff·d) + cr (d²)
            wkv = 5 * d * d + 11 * 64 * d + H * self.wkv_head_dim
            cmix = 2 * d * ff + d * d
            n = L * (wkv + cmix)
        elif self.family == "hybrid":    # zamba2: mamba blocks have no MLP
            d_in = 2 * d
            H = d_in // 64
            mamba = d * (2 * d_in + 2 * self.ssm_state + H) + d_in * d
            n = L * mamba + (attn + dense_mlp)  # + one shared block
        elif self.family == "audio":
            n = (self.encoder_layers + L) * (attn + dense_mlp) + \
                L * attn  # cross attention
        n += 2 * d * self.vocab + d
        return int(n)
