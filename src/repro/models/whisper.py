"""Whisper-style encoder-decoder backbone (arXiv:2212.04356).

Backbone only: the conv audio frontend is a STUB — ``input_specs`` feeds
precomputed frame embeddings (B, n_frames, d) straight into the encoder
(bidirectional attention); the decoder is a causal LM with cross-attention
into the encoder output.  Decode carries the self-attention cache plus the
precomputed encoder K/V.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from repro.dist.sharding import constrain_residual
from .layers import blocked_attention, rms_norm, swiglu
from .transformer import decode_attention_jnp


def _enc_block_specs(cfg, L):
    d, ff, hd = cfg.d_model, cfg.d_ff, cfg.hd
    H = cfg.n_heads
    dt = cfg.jdtype
    S = lambda *s: jax.ShapeDtypeStruct((L, *s), dt)
    return {"ln1": S(d), "ln2": S(d),
            "wq": S(d, H * hd), "wk": S(d, H * hd), "wv": S(d, H * hd),
            "wo": S(H * hd, d),
            "w_gate": S(d, ff), "w_up": S(d, ff), "w_down": S(ff, d)}


def _dec_block_specs(cfg, L):
    spec = _enc_block_specs(cfg, L)
    d, hd = cfg.d_model, cfg.hd
    H = cfg.n_heads
    dt = cfg.jdtype
    S = lambda *s: jax.ShapeDtypeStruct((L, *s), dt)
    spec.update({"ln_x": S(d),
                 "xq": S(d, H * hd), "xk": S(d, H * hd), "xv": S(d, H * hd),
                 "xo": S(H * hd, d)})
    return spec


def param_specs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    dt = cfg.jdtype
    return {
        "embed": jax.ShapeDtypeStruct((cfg.padded_vocab, d), dt),
        "unembed": jax.ShapeDtypeStruct((d, cfg.padded_vocab), dt),
        "pos_dec": jax.ShapeDtypeStruct((4096, d), dt),
        "pos_enc": jax.ShapeDtypeStruct((max(cfg.n_frames, 1), d), dt),
        "final_norm": jax.ShapeDtypeStruct((d,), dt),
        "enc_blocks": _enc_block_specs(cfg, cfg.encoder_layers),
        "dec_blocks": _dec_block_specs(cfg, cfg.n_layers),
        "enc_final_norm": jax.ShapeDtypeStruct((d,), dt),
    }


def init_params(cfg: ModelConfig, rng) -> dict:
    specs = param_specs(cfg)
    flat, tree = jax.tree_util.tree_flatten_with_path(specs)
    keys = jax.random.split(rng, len(flat))
    out = []
    for key, (path, s) in zip(keys, flat):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if name.startswith(("ln", "final", "enc_final")):
            v = jnp.zeros(s.shape, s.dtype)
        elif name.startswith("pos"):
            v = (0.02 * jax.random.normal(key, s.shape, jnp.float32)).astype(s.dtype)
        else:
            fan_in = s.shape[-2] if len(s.shape) >= 2 else s.shape[-1]
            v = (jax.random.normal(key, s.shape, jnp.float32)
                 / jnp.sqrt(fan_in)).astype(s.dtype)
        out.append(v)
    return jax.tree_util.tree_unflatten(tree, out)


def _self_attn(cfg, p, x, *, causal, cache=None, pos=None):
    B, S, d = x.shape
    H, hd = cfg.n_heads, cfg.hd
    q = (x @ p["wq"]).reshape(B, S, H, hd).transpose(0, 2, 1, 3)
    k = (x @ p["wk"]).reshape(B, S, H, hd).transpose(0, 2, 1, 3)
    v = (x @ p["wv"]).reshape(B, S, H, hd).transpose(0, 2, 1, 3)
    if cache is None:
        out = blocked_attention(q, k, v, causal=causal)
        nc = None
    else:
        ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                          (0, 0, pos, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                          (0, 0, pos, 0))
        out = decode_attention_jnp(q, ck, cv, jnp.full((B,), pos + S, jnp.int32))
        nc = {"k": ck, "v": cv}
    return out.transpose(0, 2, 1, 3).reshape(B, S, H * hd).astype(x.dtype) \
        @ p["wo"], nc


def _cross_attn(cfg, p, x, enc_k, enc_v):
    """enc_k/enc_v (B,H,F,hd) precomputed from encoder output."""
    B, S, d = x.shape
    H, hd = cfg.n_heads, cfg.hd
    q = (x @ p["xq"]).reshape(B, S, H, hd).transpose(0, 2, 1, 3)
    out = blocked_attention(q, enc_k, enc_v, causal=False)
    return out.transpose(0, 2, 1, 3).reshape(B, S, H * hd).astype(x.dtype) \
        @ p["xo"]


def encode(cfg: ModelConfig, params, frames):
    """frames (B, F, d) — stub frontend output."""
    x = frames.astype(cfg.jdtype) + params["pos_enc"][None, :frames.shape[1]]

    def body(x, pblk):
        x = constrain_residual(x)
        a, _ = _self_attn(cfg, pblk, rms_norm(x, pblk["ln1"]), causal=False)
        x = x + a
        x = x + swiglu(rms_norm(x, pblk["ln2"]), pblk["w_gate"], pblk["w_up"],
                       pblk["w_down"])
        return x, None

    body = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return rms_norm(x, params["enc_final_norm"])


def _enc_kv(cfg, params, enc_out):
    """Precompute cross-attention K/V per decoder layer (stacked on L)."""
    B, F, d = enc_out.shape
    H, hd = cfg.n_heads, cfg.hd

    def one(pblk):
        k = (enc_out @ pblk["xk"]).reshape(B, F, H, hd).transpose(0, 2, 1, 3)
        v = (enc_out @ pblk["xv"]).reshape(B, F, H, hd).transpose(0, 2, 1, 3)
        return k, v

    return jax.vmap(one)(params["dec_blocks"])   # (L,B,H,F,hd) ×2


def _decoder(cfg, params, tokens, enc_kv, pos, cache=None):
    x = constrain_residual(params["embed"][tokens])
    B, S, d = x.shape
    x = x + params["pos_dec"][(0 if pos is None else pos) + jnp.arange(S)][None]
    ek, ev = enc_kv

    def body(x, xs):
        x = constrain_residual(x)
        if cache is None:
            pblk, eki, evi = xs
            c = None
        else:
            pblk, eki, evi, ck, cv = xs
            c = {"k": ck, "v": cv}
        a, nc = _self_attn(cfg, pblk, rms_norm(x, pblk["ln1"]), causal=True,
                           cache=c, pos=pos)
        x = x + a
        x = x + _cross_attn(cfg, pblk, rms_norm(x, pblk["ln_x"]), eki, evi)
        x = x + swiglu(rms_norm(x, pblk["ln2"]), pblk["w_gate"], pblk["w_up"],
                       pblk["w_down"])
        return x, (nc["k"], nc["v"]) if nc is not None else None

    if cache is None:
        wrapped = jax.checkpoint(body) if cfg.remat else body
        x, _ = jax.lax.scan(wrapped, x, (params["dec_blocks"], ek, ev))
        new_cache = None
    else:
        x, (nk, nv) = jax.lax.scan(
            body, x, (params["dec_blocks"], ek, ev, cache["k"], cache["v"]))
        new_cache = {"k": nk, "v": nv}
    return rms_norm(x, params["final_norm"]), new_cache


def forward_hidden(cfg: ModelConfig, params, batch):
    """batch: frames (B,F,d) + tokens (B,S) → (hidden, aux)."""
    enc = encode(cfg, params, batch["frames"])
    hidden, _ = _decoder(cfg, params, batch["tokens"],
                         _enc_kv(cfg, params, enc), pos=None)
    return hidden, 0.0


def forward_train(cfg: ModelConfig, params, batch):
    hidden, aux = forward_hidden(cfg, params, batch)
    return hidden @ params["unembed"], aux


def cache_specs(cfg: ModelConfig, batch: int, max_len: int):
    H, hd, L = cfg.n_heads, cfg.hd, cfg.n_layers
    kv = jax.ShapeDtypeStruct((L, batch, H, max_len, hd), cfg.jdtype)
    ekv = jax.ShapeDtypeStruct((L, batch, H, cfg.n_frames, hd), cfg.jdtype)
    return {"k": kv, "v": kv, "enc_k": ekv, "enc_v": ekv}


def init_cache(cfg: ModelConfig, params, frames, batch: int, max_len: int):
    enc = encode(cfg, params, frames)
    ek, ev = _enc_kv(cfg, params, enc)
    kv = jnp.zeros((cfg.n_layers, batch, cfg.n_heads, max_len, cfg.hd),
                   cfg.jdtype)
    return {"k": kv, "v": kv.copy(), "enc_k": ek, "enc_v": ev}


def forward_decode(cfg: ModelConfig, params, batch, cache, pos):
    hidden, nc = _decoder(cfg, params, batch["tokens"],
                          (cache["enc_k"], cache["enc_v"]), pos,
                          cache={"k": cache["k"], "v": cache["v"]})
    logits = hidden @ params["unembed"]
    return logits, {**nc, "enc_k": cache["enc_k"], "enc_v": cache["enc_v"]}
