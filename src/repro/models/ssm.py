"""Mamba2 blocks + the Zamba2 hybrid (arXiv:2411.15242).

Mamba2 block: in-proj → (z, x, B, C, dt); causal conv over (x,B,C);
SSD recurrence S_t = exp(a·dt_t)·S_{t−1} + dt_t·B_tᵀx_t, y_t = C_t·S_t —
run through the shared chunked linear scan (inclusive, scalar decay per
head); gated RMS-norm output.

Zamba2: a stack of Mamba2 blocks with ONE weight-shared attention+MLP
block applied every ``attn_every`` layers (each application has its own KV
cache).  The layer stack is segmented: scan(6 mamba blocks) → shared
block → scan(...) — segment count is static so the HLO stays small.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from repro.dist.sharding import constrain_residual
from .layers import blocked_attention, rms_norm, rope, swiglu
from .linear_scan import chunked_linear_scan, linear_scan_decode
from .transformer import decode_attention_jnp

EXPAND = 2


def _dims(cfg: ModelConfig):
    d_in = EXPAND * cfg.d_model
    H = d_in // 64                     # mamba2 head dim 64
    N = cfg.ssm_state
    return d_in, H, N


def mamba_block_specs(cfg: ModelConfig, L: int) -> dict:
    d = cfg.d_model
    d_in, H, N = _dims(cfg)
    dt = cfg.jdtype
    S = lambda *shape: jax.ShapeDtypeStruct((L, *shape), dt)
    conv_ch = d_in + 2 * N
    return {
        "ln": S(d),
        "w_in": S(d, 2 * d_in + 2 * N + H),    # z, x, B, C, dt
        "conv_w": S(cfg.conv_width, conv_ch),
        "conv_b": S(conv_ch),
        "A_log": S(H), "dt_bias": S(H), "D": S(H),
        "gn_scale": S(d_in),
        "w_out": S(d_in, d),
    }


def _split_proj(cfg, proj):
    d_in, H, N = _dims(cfg)
    z, xc, Bm, Cm, dt = jnp.split(
        proj, [d_in, 2 * d_in, 2 * d_in + N, 2 * d_in + 2 * N], axis=-1)
    return z, xc, Bm, Cm, dt


def _causal_conv(x, w, b, conv_state=None):
    """x (B,T,C); depthwise causal conv width K.  conv_state (B,K−1,C) for
    decode (returns updated state)."""
    K = w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    else:
        pad = conv_state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i][None, None] for i in range(K))
    new_state = xp[:, -(K - 1):] if K > 1 else None
    return jax.nn.silu(out + b[None, None]), new_state


def mamba_forward(cfg, p, x, ssm_state, conv_state, *, chunked=True):
    """x (B,T,d) → (out, new_ssm_state, new_conv_state)."""
    B, T, d = x.shape
    d_in, H, N = _dims(cfg)
    f32 = jnp.float32
    proj = rms_norm(x, p["ln"]).astype(f32) @ p["w_in"].astype(f32)
    z, xc, Bm, Cm, dt = _split_proj(cfg, proj)
    conv_in = jnp.concatenate([xc, Bm, Cm], axis=-1)
    conv_out, new_conv = _causal_conv(conv_in, p["conv_w"].astype(f32),
                                      p["conv_b"].astype(f32), conv_state)
    xc, Bm, Cm = jnp.split(conv_out, [d_in, d_in + N], axis=-1)
    dt = jax.nn.softplus(dt + p["dt_bias"].astype(f32)[None, None])  # (B,T,H)
    a = -jnp.exp(p["A_log"].astype(f32))                             # (H,)
    logw = (a[None, None] * dt)[..., None]                    # (B,T,H,1)
    v = xc.reshape(B, T, H, 64) * dt[..., None]               # (B,T,H,64)
    q = jnp.broadcast_to(Cm[:, :, None, :], (B, T, H, N))
    k = jnp.broadcast_to(Bm[:, :, None, :], (B, T, H, N))
    tr = lambda t: t.transpose(0, 2, 1, 3)
    if chunked:
        y, new_ssm = chunked_linear_scan(tr(q), tr(k), tr(v),
                                         tr(logw), ssm_state, inclusive=True)
    else:
        y, new_ssm = linear_scan_decode(q[:, 0], k[:, 0], v[:, 0],
                                        logw[:, 0], ssm_state, inclusive=True)
        y = y[:, :, None, :]
    y = y.transpose(0, 2, 1, 3).reshape(B, T, d_in)           # (B,T,d_in)
    y = y + xc * (p["D"].astype(f32))[None, None].repeat(64, -1)[..., :d_in]
    # gated RMS norm (mamba2)
    y = y * jax.nn.silu(z)
    var = jnp.mean(jnp.square(y), axis=-1, keepdims=True)
    y = y * jax.lax.rsqrt(var + 1e-6) * (1 + p["gn_scale"].astype(f32))
    out = (y @ p["w_out"].astype(f32)).astype(x.dtype)
    return x + out, new_ssm, new_conv


# ---------------------------------------------------------------------------
# Zamba2 hybrid
# ---------------------------------------------------------------------------
def shared_block_specs(cfg: ModelConfig) -> dict:
    d, ff, hd = cfg.d_model, cfg.d_ff, cfg.hd
    Hq, Hkv = cfg.n_heads, cfg.n_kv_heads
    dt = cfg.jdtype
    S = lambda *shape: jax.ShapeDtypeStruct(shape, dt)
    return {
        "ln1": S(d), "ln2": S(d),
        "wq": S(d, Hq * hd), "wk": S(d, Hkv * hd), "wv": S(d, Hkv * hd),
        "wo": S(Hq * hd, d),
        "w_gate": S(d, ff), "w_up": S(d, ff), "w_down": S(ff, d),
    }


def param_specs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    dt = cfg.jdtype
    return {
        "embed": jax.ShapeDtypeStruct((cfg.padded_vocab, d), dt),
        "unembed": jax.ShapeDtypeStruct((d, cfg.padded_vocab), dt),
        "final_norm": jax.ShapeDtypeStruct((d,), dt),
        "blocks": mamba_block_specs(cfg, cfg.n_layers),
        "shared": shared_block_specs(cfg),
    }


def init_params(cfg: ModelConfig, rng) -> dict:
    specs = param_specs(cfg)
    flat, tree = jax.tree_util.tree_flatten_with_path(specs)
    keys = jax.random.split(rng, len(flat))
    out = []
    for key, (path, s) in zip(keys, flat):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if name in ("ln", "ln1", "ln2", "final_norm", "gn_scale", "conv_b"):
            v = jnp.zeros(s.shape, s.dtype)
        elif name == "A_log":
            v = jnp.log(jnp.linspace(0.5, 4.0, s.shape[-1])) * jnp.ones(
                s.shape, jnp.float32)
            v = v.astype(s.dtype)
        elif name == "dt_bias":
            v = jnp.full(s.shape, -2.0, s.dtype)
        elif name == "D":
            v = jnp.ones(s.shape, s.dtype)
        else:
            fan_in = s.shape[-2] if len(s.shape) >= 2 else s.shape[-1]
            v = (jax.random.normal(key, s.shape, jnp.float32)
                 / jnp.sqrt(fan_in)).astype(s.dtype)
        out.append(v)
    return jax.tree_util.tree_unflatten(tree, out)


def _shared_attn_block(cfg, p, x, positions, cache=None, pos=None):
    B, S, d = x.shape
    hd, Hq, Hkv = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    h = rms_norm(x, p["ln1"])
    q = rope((h @ p["wq"]).reshape(B, S, Hq, hd), positions, cfg.rope_theta)
    k = rope((h @ p["wk"]).reshape(B, S, Hkv, hd), positions, cfg.rope_theta)
    v = (h @ p["wv"]).reshape(B, S, Hkv, hd)
    q, k, v = (t.transpose(0, 2, 1, 3) for t in (q, k, v))
    if cache is None:
        attn = blocked_attention(q, k, v, causal=True)
        new_cache = None
    else:
        ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                          (0, 0, pos, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                          (0, 0, pos, 0))
        attn = decode_attention_jnp(q, ck, cv,
                                    jnp.full((B,), pos + S, jnp.int32))
        new_cache = {"k": ck, "v": cv}
    attn = attn.transpose(0, 2, 1, 3).reshape(B, S, Hq * hd).astype(x.dtype)
    x = x + attn @ p["wo"]
    x = x + swiglu(rms_norm(x, p["ln2"]), p["w_gate"], p["w_up"], p["w_down"])
    return x, new_cache


def _segments(cfg: ModelConfig):
    """Static segmentation: shared block after every attn_every mamba blocks."""
    k = cfg.attn_every or cfg.n_layers + 1
    bounds = list(range(0, cfg.n_layers, k))[1:]
    segs, prev = [], 0
    for b in bounds:
        segs.append((prev, b))
        prev = b
    segs.append((prev, cfg.n_layers))
    return segs  # [(start, end)]; shared block between segments


def n_shared_applications(cfg: ModelConfig) -> int:
    return len(_segments(cfg)) - 1


def state_specs(cfg: ModelConfig, batch: int, max_len: int):
    d_in, H, N = _dims(cfg)
    L = cfg.n_layers
    napp = n_shared_applications(cfg)
    kv = jax.ShapeDtypeStruct(
        (napp, batch, cfg.n_kv_heads, max_len, cfg.hd), cfg.jdtype)
    return {
        "ssm": jax.ShapeDtypeStruct((L, batch, H, N, 64), jnp.float32),
        "conv": jax.ShapeDtypeStruct(
            (L, batch, cfg.conv_width - 1, d_in + 2 * N), cfg.jdtype),
        "k": kv, "v": kv,
    }


def init_state(cfg: ModelConfig, batch: int, max_len: int):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        state_specs(cfg, batch, max_len))


def _run(cfg, params, tokens, state, pos, *, chunked):
    x = constrain_residual(params["embed"][tokens])
    B, S, _ = x.shape
    positions = jnp.broadcast_to(
        (0 if pos is None else pos) + jnp.arange(S), (B, S))
    segs = _segments(cfg)
    new_ssm, new_conv, new_k, new_v = [], [], [], []

    def seg_scan(x, blocks, ssm, conv):
        def body(x, xs):
            pblk, s_ssm, s_conv = xs
            x = constrain_residual(x)
            x, ns, nc = mamba_forward(cfg, pblk, x, s_ssm, s_conv,
                                      chunked=chunked)
            return x, (ns, nc)
        body = jax.checkpoint(body) if (cfg.remat and chunked) else body
        return jax.lax.scan(body, x, (blocks, ssm, conv))

    for i, (a, b) in enumerate(segs):
        take = lambda t: jax.tree.map(lambda u: u[a:b], t)
        x, (ns, nc) = seg_scan(x, take(params["blocks"]),
                               state["ssm"][a:b], state["conv"][a:b])
        new_ssm.append(ns)
        new_conv.append(nc)
        if i < len(segs) - 1:
            cache = None if chunked else {"k": state["k"][i], "v": state["v"][i]}
            x, nc2 = _shared_attn_block(cfg, params["shared"], x, positions,
                                        cache=cache, pos=pos)
            if nc2 is not None:
                new_k.append(nc2["k"])
                new_v.append(nc2["v"])
    x = rms_norm(x, params["final_norm"])
    new_state = {
        "ssm": jnp.concatenate(new_ssm), "conv": jnp.concatenate(new_conv),
        "k": jnp.stack(new_k) if new_k else state["k"],
        "v": jnp.stack(new_v) if new_v else state["v"],
    }
    return x, new_state


def forward_hidden(cfg: ModelConfig, params, batch):
    B, S = batch["tokens"].shape
    st = init_state(cfg, B, 1)
    hidden, _ = _run(cfg, params, batch["tokens"], st, None, chunked=True)
    return hidden, 0.0


def forward_train(cfg: ModelConfig, params, batch):
    hidden, aux = forward_hidden(cfg, params, batch)
    return hidden @ params["unembed"], aux


def forward_decode(cfg: ModelConfig, params, batch, state, pos):
    hidden, new_state = _run(cfg, params, batch["tokens"], state, pos,
                             chunked=False)
    return hidden @ params["unembed"], new_state
