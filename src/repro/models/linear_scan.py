"""Chunked linear-attention scan — shared by RWKV6 (WKV) and Mamba2 (SSD).

Recurrence (per head; S is a (N, P) state matrix, decay on the N axis):

    S_t = diag(a_t) S_{t−1} + k_tᵀ v_t          a_t = exp(logw_t)
    o_t = q_t · S_{t−1 or t}  (+ RWKV bonus (q_t ⊙ u)·k_t v_t)

TPU adaptation: instead of a length-T sequential scan, tokens are processed
in chunks of C: intra-chunk contributions become a (C×C) masked matmul
(MXU-friendly) with per-channel decay factors exp(W_t − W_s) factorized as
(q ⊙ e^{W}) @ (k ⊙ e^{−W})ᵀ; inter-chunk state flows through a lax.scan of
T/C steps.  This is the standard chunked formulation (SSD / FLA) — exactly
the structure a Pallas kernel would tile.

Numerics: the factorization is computed in float32 on *chunk-local*
cumulative decays, so exponents are bounded by C·max|logw| per chunk.
Callers keep decays in a realistic band (|logw| ≲ 1); chunk=32 default.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("inclusive", "chunk"))
def chunked_linear_scan(q, k, v, logw, state0, *, inclusive: bool,
                        bonus=None, chunk: int = 32):
    """q,k (B,H,T,N); v (B,H,T,P); logw (B,H,T,N) or (B,H,T,1);
    state0 (B,H,N,P); bonus (H,N) or None (RWKV's u).
    Returns (out (B,H,T,P) f32, stateT (B,H,N,P) f32).

    inclusive=True  → o_t = q_t·S_t      (Mamba2/SSD)
    inclusive=False → o_t = q_t·S_{t−1} + (q_t⊙u)·k_t v_t   (RWKV6)
    """
    B, H, T, N = q.shape
    P = v.shape[-1]
    T0 = T
    pad = (-T) % chunk
    if pad:
        # zero k/v add nothing to the state and logw=0 means decay 1, so
        # tail padding is exact for both outputs and the final state
        zpad = lambda x: jnp.pad(x, ((0, 0), (0, 0), (0, pad), (0, 0)))
        q, k, v, logw = zpad(q), zpad(k), zpad(v), zpad(logw)
        T = T + pad
    nc = T // chunk
    f32 = jnp.float32

    def to_chunks(x):
        return x.astype(f32).reshape(B, H, nc, chunk, -1).transpose(2, 0, 1, 3, 4)

    qc, kc, vc, wc = map(to_chunks, (q, k, v, jnp.broadcast_to(
        logw, (B, H, T, logw.shape[-1]))))

    tri = jnp.tril(jnp.ones((chunk, chunk), bool), 0 if inclusive else -1)

    def step(S, blk):
        qb, kb, vb, wb = blk          # (B,H,C,N|P)
        W = jnp.cumsum(wb, axis=2)    # inclusive cumulative log-decay
        Wq = W if inclusive else W - wb          # exclusive for RWKV
        q_t = qb * jnp.exp(Wq)
        k_t = kb * jnp.exp(-W)
        A = jnp.einsum("bhtn,bhsn->bhts", q_t, k_t)
        A = jnp.where(tri[None, None], A, 0.0)
        if bonus is not None:
            diag = jnp.einsum("bhtn,bhtn->bht", qb * bonus[None, :, None, :], kb)
            A = A + diag[..., None] * jnp.eye(chunk, dtype=f32)[None, None]
        intra = jnp.einsum("bhts,bhsp->bhtp", A, vb)
        inter = jnp.einsum("bhtn,bhnp->bhtp", q_t, S)
        out = intra + inter
        Wlast = W[:, :, -1:, :]                 # (B,H,1,N)
        kd = kb * jnp.exp(Wlast - W)
        S_new = jnp.exp(Wlast[:, :, 0, :, None]) * S + jnp.einsum(
            "bhsn,bhsp->bhnp", kd, vb)
        return S_new, out

    stateT, outs = jax.lax.scan(step, state0.astype(f32), (qc, kc, vc, wc))
    out = outs.transpose(1, 2, 0, 3, 4).reshape(B, H, T, P)
    return out[:, :, :T0], stateT


def linear_scan_decode(q, k, v, logw, state, *, inclusive: bool, bonus=None):
    """Single-token recurrence (serving): all inputs (B,H,N|P); state (B,H,N,P)."""
    f32 = jnp.float32
    q, k, v = q.astype(f32), k.astype(f32), v.astype(f32)
    a = jnp.exp(logw.astype(f32))                         # (B,H,N) or (B,H,1)
    kv = jnp.einsum("bhn,bhp->bhnp", k, v)
    if inclusive:
        S_new = a[..., None] * state + kv
        out = jnp.einsum("bhn,bhnp->bhp", q, S_new)
    else:
        out = jnp.einsum("bhn,bhnp->bhp", q, state) + jnp.einsum(
            "bhn,bhnp->bhp", q * bonus[None], kv)
        S_new = a[..., None] * state + kv
    return out, S_new


def sequential_scan_ref(q, k, v, logw, state0, *, inclusive: bool, bonus=None):
    """O(T) sequential oracle for tests."""
    B, H, T, N = q.shape

    def step(S, t):
        o, S_new = linear_scan_decode(q[:, :, t], k[:, :, t], v[:, :, t],
                                      jnp.broadcast_to(logw[:, :, t],
                                                       (B, H, logw.shape[-1])),
                                      S, inclusive=inclusive, bonus=bonus)
        return S_new, o

    S, outs = jax.lax.scan(step, state0.astype(jnp.float32), jnp.arange(T))
    return outs.transpose(1, 2, 0, 3), S
