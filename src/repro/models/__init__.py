"""Architecture zoo: pure-pytree JAX model definitions.

All models expose the same API (see api.py):
    param_specs(cfg)   → pytree of ShapeDtypeStruct (dry-run, sharding)
    init_params(cfg, rng) → concrete pytree (smoke tests, examples)
    forward(cfg, params, batch) → logits
    train_step / prefill / decode in repro.train / repro.serve
"""
from .config import ModelConfig
