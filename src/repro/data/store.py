"""ShardedTokenStore — the data-pipeline AirIndex integration (DESIGN.md §3).

Training corpora are packed variable-length token records inside shard
files on slow storage.  Random-access sample fetch needs
``sample_id → byte range``; that mapping is a key-position collection, so
the store tunes a hierarchical index for it with AirTune against the
*profiled* storage tier and serves lookups with real partial reads
(Alg. 1 over the serialized index + one data pread).

This makes data loading O(T(root) + Σ T(Δ_l) + T(record)) per random
sample instead of O(T(shard)) — the paper's end-to-end objective applied
to the training input pipeline.  Deterministic index-based sampling also
gives exact replay after restarts (fault_tolerance.py).
"""
from __future__ import annotations

import json
import os

import numpy as np

from repro.core import (KeyPositions, SerializedIndex, airtune,
                        profile_local_storage, write_index)
from repro.core.storage import PROFILES, StorageProfile


def write_token_store(path: str, samples: list[np.ndarray]) -> dict:
    """Pack variable-length int32 token records; returns manifest dict."""
    os.makedirs(path, exist_ok=True)
    data_path = os.path.join(path, "shard0.tokens")
    offs = [0]
    with open(data_path, "wb") as f:
        for s in samples:
            b = np.asarray(s, dtype=np.int32).tobytes()
            f.write(b)
            offs.append(offs[-1] + len(b))
    manifest = {"n": len(samples), "offsets_tail": offs[-1]}
    np.save(os.path.join(path, "offsets.npy"), np.asarray(offs, np.int64))
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    return manifest


class ShardedTokenStore:
    """Random-access token store with an AirTune-built sample index."""

    def __init__(self, path: str, profile: StorageProfile | str = "measure",
                 k: int = 3, backend_factory=None):
        self.path = path
        offs = np.load(os.path.join(path, "offsets.npy"))
        self.n = len(offs) - 1
        keys = np.arange(self.n, dtype=np.uint64)
        self.D = KeyPositions.from_offsets(keys, offs)
        if profile == "measure":
            profile = profile_local_storage(
                os.path.join(path, ".profile_scratch"))
        elif isinstance(profile, str):
            profile = PROFILES[profile]
        self.profile = profile
        self.tune = airtune(self.D, profile, k=k)
        idx_path = os.path.join(path, "sample.air")
        write_index(idx_path, self.tune.design)
        self.index = SerializedIndex(idx_path,
                                     backend_factory=backend_factory)
        from repro.core.serialize import open_file_backend
        factory = backend_factory or open_file_backend
        self._data_backend = factory(os.path.join(path, "shard0.tokens"))
        self.offs = offs

    def close(self):
        self.index.close()
        self._data_backend.close()

    def get(self, sample_id: int) -> np.ndarray:
        """Fetch one sample via index lookup + partial data read (Alg. 1)."""
        lo, hi = self.index.lookup(int(sample_id))
        raw = self._data_backend.pread(hi - lo, lo)
        # last-mile: exact record range from the fetched window
        rec_lo = int(self.offs[sample_id]) - lo
        rec_hi = int(self.offs[sample_id + 1]) - lo
        assert 0 <= rec_lo <= rec_hi <= len(raw), "index returned bad range"
        return np.frombuffer(raw[rec_lo:rec_hi], dtype=np.int32)

    def batch_iterator(self, batch: int, seq_len: int, seed: int = 0,
                       start_step: int = 0):
        """Deterministic packed batches; replayable from any step."""
        rng = np.random.default_rng(seed)
        perm = rng.permutation(self.n)
        cursor = 0
        step = 0
        buf = []
        while True:
            while sum(len(b) for b in buf) < batch * (seq_len + 1):
                buf.append(self.get(int(perm[cursor % self.n])))
                cursor += 1
            flat = np.concatenate(buf)
            need = batch * (seq_len + 1)
            tokens = flat[:need].reshape(batch, seq_len + 1)
            buf = [flat[need:]]
            if step >= start_step:
                yield {"tokens": tokens[:, :-1].astype(np.int32),
                       "labels": tokens[:, 1:].astype(np.int32)}
            step += 1
