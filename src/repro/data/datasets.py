"""Synthetic key distributions mirroring the paper's datasets (§7.1).

books/fb/osm/wiki come from SOSD [42]; we generate distributions with the
same qualitative structure at container scale (the paper's are 200–800M
keys; the generators accept any n).  gmm follows the paper exactly: a
100-cluster Gaussian mixture.  wiki includes duplicate keys (the paper's
"unusual dataset"), deduplicated into first-offset semantics by the caller.
"""
from __future__ import annotations

import numpy as np


def _dedup_sorted(keys: np.ndarray) -> np.ndarray:
    return np.unique(keys)


def sosd_like(name: str, n: int, seed: int = 0) -> np.ndarray:
    """→ sorted unique uint64 keys."""
    rng = np.random.default_rng(seed + hash(name) % 2**16)
    if name == "books":
        # heavy-tailed popularity counts accumulated (Amazon book sales)
        gaps = rng.zipf(1.31, int(n * 1.05)).astype(np.uint64)
        keys = np.cumsum(gaps)[:n]
    elif name == "fb":
        # Facebook user ids: dense near-linear ranges with rare big jumps
        base = rng.integers(1, 12, int(n * 1.05), dtype=np.uint64)
        jump = (rng.random(int(n * 1.05)) < 2e-5) * rng.integers(
            2**33, 2**35, int(n * 1.05), dtype=np.uint64)
        keys = np.cumsum(base + jump)[:n]
    elif name == "osm":
        # OSM cell ids: highly clustered, multi-scale (hardest in the paper)
        n_cl = max(int(np.sqrt(n)) // 4, 8)
        centers = np.sort(rng.integers(2**40, 2**62, n_cl, dtype=np.uint64))
        sizes = rng.zipf(1.4, n_cl).astype(np.float64)
        sizes = np.maximum(sizes / sizes.sum() * n, 1).astype(np.int64)
        parts = [c + rng.integers(0, max(int(s) * 64, 64), int(s),
                                  dtype=np.uint64)
                 for c, s in zip(centers, sizes)]
        keys = np.concatenate(parts)[:n]
    elif name == "wiki":
        # edit timestamps: near-uniform with many duplicates
        keys = np.sort(rng.integers(1, n * 8, int(n * 1.3),
                                    dtype=np.uint64))[:n]
    elif name == "gmm":
        # paper §7.1: Gaussian mixture, 100 clusters
        centers = rng.uniform(2**32, 2**52, 100)
        scales = rng.uniform(2**24, 2**30, 100)
        parts = [np.abs(rng.normal(c, s, n // 100 + 1)) for c, s in
                 zip(centers, scales)]
        keys = np.concatenate(parts)[:n].astype(np.uint64) + 1
    elif name == "uden64":
        keys = rng.integers(1, 2**63, int(n * 1.05), dtype=np.uint64)[:n]
    else:
        raise ValueError(name)
    return _dedup_sorted(np.sort(keys))


DATASETS = ("books", "fb", "osm", "wiki", "gmm")
