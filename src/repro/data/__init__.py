from .datasets import sosd_like, DATASETS
from .store import ShardedTokenStore, write_token_store
