"""Sharding rules shared by the models, the serving path, and the dry-run
launcher.

One convention everywhere (mesh axes ``("data", "model")`` per pod, plus a
leading ``"pod"`` axis multi-pod):

  * parameters    — tensor-parallel over ``"model"``: the largest trailing
    dim divisible by the axis size is sharded; ZeRO-1 optimizer moments are
    additionally sharded over the data-parallel axes;
  * batches       — leading (batch) dim over the data-parallel axes;
  * decode state  — KV caches / recurrent states are ``(L, B, …)``; the
    batch dim (axis 1) is sharded over the data-parallel axes;
  * activations   — the residual stream is constrained to batch-sharded via
    :func:`constrain_residual`, a no-op until the launcher installs a mesh
    with :func:`set_activation_mesh` (models stay importable and testable
    on a single device).

All helpers degrade to fully-replicated specs when a dim does not divide
the axis size, so the same rules lower on a 1×1 test mesh and the 16×16
production mesh.
"""
from __future__ import annotations

import math

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_ACTIVATION_MESH: Mesh | None = None


def set_activation_mesh(mesh: Mesh | None) -> None:
    """Install the mesh used by :func:`constrain_residual` (None to clear)."""
    global _ACTIVATION_MESH
    _ACTIVATION_MESH = mesh


def _data_axes(mesh: Mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _axes_size(mesh: Mesh, axes: tuple) -> int:
    return math.prod(mesh.shape[a] for a in axes) if axes else 1


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def constrain_residual(x):
    """Constrain a residual-stream activation (B, …) to batch sharding.

    Identity when no mesh is installed, the mesh is trivial, or the batch
    dim does not divide the data-parallel extent (e.g. unit-batch decode).
    """
    mesh = _ACTIVATION_MESH
    if mesh is None or mesh.size == 1 or x.ndim < 1:
        return x
    daxes = _data_axes(mesh)
    dp = _axes_size(mesh, daxes)
    if dp <= 1 or x.shape[0] % dp != 0:
        return x
    spec = [None] * x.ndim
    spec[0] = daxes if len(daxes) > 1 else daxes[0]
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))


def _shard_one_dim(shape, axis_n, *, reverse=True, taken=()):
    """Pick the dim to shard over an axis of size ``axis_n`` (or None)."""
    if axis_n <= 1:
        return None
    dims = range(len(shape) - 1, -1, -1) if reverse else range(len(shape))
    for i in dims:
        if i not in taken and shape[i] % axis_n == 0 and shape[i] >= axis_n:
            return i
    return None


def param_shardings(cfg, specs, mesh: Mesh, *, zero: bool = False):
    """NamedSharding tree for a param (or moment) spec tree.

    Tensor-parallel over ``"model"`` on the largest-index divisible dim
    (skipping the leading layer-stack dim of scanned block params); with
    ``zero=True`` (ZeRO-1 moments) an additional dim is sharded over the
    data-parallel axes.
    """
    model_n = mesh.shape.get("model", 1)
    daxes = _data_axes(mesh)
    dp = _axes_size(mesh, daxes)

    def one(s):
        spec = [None] * len(s.shape)
        # never shard the scanned layer-stack dim (dim 0 of >=2D block
        # params equals n_layers); trailing dims are the matmul dims
        mi = _shard_one_dim(s.shape, model_n,
                            taken=(0,) if len(s.shape) > 2 else ())
        if mi is not None:
            spec[mi] = "model"
        if zero and dp > 1:
            zi = _shard_one_dim(s.shape, dp, reverse=False,
                                taken=() if mi is None else (mi,))
            if zi is not None:
                spec[zi] = daxes if len(daxes) > 1 else daxes[0]
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(one, specs)


def batch_sharding(mesh: Mesh, specs):
    """Shard the leading (batch) dim of every input leaf over data axes."""
    daxes = _data_axes(mesh)
    dp = _axes_size(mesh, daxes)

    def one(s):
        spec = [None] * len(s.shape)
        if s.shape and dp > 1 and s.shape[0] % dp == 0:
            spec[0] = daxes if len(daxes) > 1 else daxes[0]
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(one, specs)


def decode_state_shardings(cfg, specs, mesh: Mesh):
    """Decode-state leaves are (L, B, …): shard batch (axis 1) over data
    axes and, when divisible, the head dim (axis 2) over ``"model"``."""
    model_n = mesh.shape.get("model", 1)
    daxes = _data_axes(mesh)
    dp = _axes_size(mesh, daxes)

    def one(s):
        spec = [None] * len(s.shape)
        if len(s.shape) > 1 and dp > 1 and s.shape[1] % dp == 0:
            spec[1] = daxes if len(daxes) > 1 else daxes[0]
        if len(s.shape) > 2 and model_n > 1 and s.shape[2] % model_n == 0:
            spec[2] = "model"
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(one, specs)
