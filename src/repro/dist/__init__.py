from .sharding import (batch_sharding, constrain_residual,
                       decode_state_shardings, param_shardings, replicated,
                       set_activation_mesh)

__all__ = ["batch_sharding", "constrain_residual", "decode_state_shardings",
           "param_shardings", "replicated", "set_activation_mesh"]
