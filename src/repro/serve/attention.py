"""Flash-decode over a sequence-sharded KV cache (the optimized serve path).

When kv_heads < model-axis size (deepseek/qwen/llama4/grok: 8 kv heads on
a 16-way axis), the baseline shards the cache's *sequence* dim and lets
SPMD insert logit gathers.  This module does it manually with shard_map:
each device computes the partial-softmax triple (o, m, l) over its local
sequence shard — kernels.decode_attention on TPU, its oracle here — and
the shards combine with the numerically-exact max-correction:

    M = pmax(m);  L = psum(l·e^{m−M});  O = psum(o·l·e^{m−M}) / L

Communication per step: 2·(B·Hq) scalars + (B·Hq·hd) — independent of
sequence length, vs the baseline's (B·Hq·S_local) logit gather.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.kernels.decode_attention import ops as da_ops


def flash_decode_sharded(mesh: Mesh, axis: str = "model"):
    """Returns decode_attn(q, ck, cv, kv_length) with seq-sharded ck/cv.

    q (B,Hq,hd) replicated over ``axis``; ck/cv (B,Hkv,S,hd) sharded on S;
    kv_length (B,) global lengths.  Output (B,Hq,hd) replicated.
    """
    n_shards = mesh.shape[axis]

    def local(q, ck, cv, kv_length):
        idx = jax.lax.axis_index(axis)
        S_local = ck.shape[2]
        start = idx * S_local
        # tokens of this shard that are within the global valid length
        local_len = jnp.clip(kv_length - start, 0, S_local)
        o, m, l = da_ops.decode_attention(q, ck, cv, local_len, use_ref=True)
        # all-empty shards contribute exp(-inf)=0 via the m correction
        M = jax.lax.pmax(m, axis)
        w = l * jnp.exp(m - M)
        L = jax.lax.psum(w, axis)
        O = jax.lax.psum(o * w[..., None], axis) / jnp.maximum(
            L, 1e-30)[..., None]
        return O

    in_specs = (P(), P(None, None, axis, None), P(None, None, axis, None),
                P())
    if hasattr(jax, "shard_map"):            # jax >= 0.6
        return jax.shard_map(local, mesh=mesh, in_specs=in_specs,
                             out_specs=P(), check_vma=False)
    from jax.experimental.shard_map import shard_map
    return shard_map(local, mesh=mesh, in_specs=in_specs, out_specs=P(),
                     check_rep=False)
