"""Storage backends for the serving engine — the fault seam.

Every byte :class:`repro.serve.IndexService` serves comes through a
:class:`StorageBackend`: ``pread(nbytes, offset) -> bytes`` plus a size
probe and a close.  :class:`FileBackend` is the real thing (an ``os.pread``
that loops until the requested window is filled — a bare ``pread`` may
legally return fewer bytes near EOF or on signal interruption, and a
truncated buffer handed to the page cache would poison every later hit).
:class:`FaultInjectingBackend` wraps any backend with a *deterministic,
seeded* fault schedule — transient or persistent ``EIO``, short (torn)
reads, page corruption, latency stalls, and a flaky-then-healthy startup
window — so chaos tests and the ``serve_bench --chaos`` gate can assert
that results under faults are bit-identical to the fault-free run.

The typed error ladder the engine raises once its
:class:`repro.api.RetryPolicy` budget is spent:

``StorageError``
    base class — the fleet marks a shard unhealthy on any of these;
``ReadError``
    a pread (or its finer-granularity degraded retries) kept failing;
``CorruptPageError``
    a page failed its CRC32 check twice (fetch + one refetch);
``DeadlineExceededError``
    the per-pread or per-batch deadline expired.
"""
from __future__ import annotations

import errno
import os
import threading
import time

import numpy as np


# ---------------------------------------------------------------------------
# typed failures (the serving stack's error surface)
# ---------------------------------------------------------------------------
class StorageError(Exception):
    """Base for serving-path storage failures (after retries/repairs).

    Catch this to degrade gracefully — :class:`repro.fleet.FleetService`
    does, marking the failing shard unhealthy instead of taking the whole
    fleet down."""


class ReadError(StorageError):
    """A pread failed past the retry budget (EIO, short read, ...)."""

    def __init__(self, msg: str, *, path=None, offset=None, nbytes=None,
                 attempts=None):
        super().__init__(msg)
        self.path = path
        self.offset = offset
        self.nbytes = nbytes
        self.attempts = attempts


class CorruptPageError(StorageError):
    """A page failed CRC32 verification twice (fetch + one refetch) —
    surfaced instead of silently serving wrong lookups."""

    def __init__(self, msg: str, *, path=None, page_id=None):
        super().__init__(msg)
        self.path = path
        self.page_id = page_id


class DeadlineExceededError(StorageError):
    """A per-pread or per-batch RetryPolicy deadline expired."""


# ---------------------------------------------------------------------------
# the real backend
# ---------------------------------------------------------------------------
def pread_full(fd: int, nbytes: int, offset: int) -> bytes:
    """``os.pread`` that loops until ``nbytes`` arrive or EOF.

    ``pread`` may return fewer bytes than requested (EOF, signal
    interruption); callers of this helper always get the full window or
    the true end of file — never a transiently-torn buffer."""
    buf = os.pread(fd, nbytes, offset)
    if len(buf) == nbytes or not buf:
        return buf
    parts = [buf]
    got = len(buf)
    while got < nbytes:
        chunk = os.pread(fd, nbytes - got, offset + got)
        if not chunk:          # true EOF: a legitimately short window
            break
        parts.append(chunk)
        got += len(chunk)
    return b"".join(parts)


class StorageBackend:
    """Minimal read-only storage surface the serving engine needs."""

    path: str | None = None

    def pread(self, nbytes: int, offset: int) -> bytes:
        raise NotImplementedError

    def size(self) -> int:
        raise NotImplementedError

    def close(self) -> None:
        pass


class FileBackend(StorageBackend):
    """A local file served through short-read-safe ``os.pread``."""

    def __init__(self, path: str):
        self.path = path
        self.fd: int | None = os.open(path, os.O_RDONLY)

    def pread(self, nbytes: int, offset: int) -> bytes:
        return pread_full(self.fd, int(nbytes), int(offset))

    def size(self) -> int:
        return os.fstat(self.fd).st_size

    def close(self) -> None:
        if self.fd is not None:
            os.close(self.fd)
            self.fd = None


# ---------------------------------------------------------------------------
# deterministic fault injection (chaos harness)
# ---------------------------------------------------------------------------
class FaultInjectingBackend(StorageBackend):
    """Wrap a backend with a seeded, deterministic fault schedule.

    Whether a given read window faults is a pure function of
    ``(seed, offset, nbytes)`` plus that window's *attempt index* (how many
    times it has been read so far), so a schedule replays identically
    regardless of thread interleaving: retries of the same window advance
    its attempt counter and a ``*_attempts``-bounded fault heals exactly
    when the schedule says it does.

    Parameters (all faults combinable; rates in [0, 1]):

    eio_rate / eio_attempts:
        selected windows raise ``OSError(EIO)`` for their first
        ``eio_attempts`` reads, then heal; ``eio_attempts=None`` makes the
        failure *persistent* (the retry budget must eventually give up).
    short_rate / short_attempts:
        selected windows return a torn buffer (roughly half the bytes).
    corrupt_rate / corrupt_attempts:
        selected windows return bit-flipped bytes (first byte of each page
        XOR 0xFF) — what page checksums exist to catch.
    stall_rate / stall_seconds / stall_attempts:
        selected windows sleep before returning good data — the
        per-pread-deadline regime.
    fail_first:
        the first ``fail_first`` calls (any window) raise EIO — a
        flaky-then-healthy startup schedule.
    only_over_bytes:
        faults apply only to reads strictly larger than this — e.g. set it
        to one page to fault coalesced multi-page runs while letting the
        engine's degraded page-granularity retries through.
    only_from_offset:
        faults apply only to reads at or past this file offset — e.g. set
        it past the header to fault layer pages while the meta decodes
        cleanly (how a persistent-corruption schedule reaches the page
        CRC check instead of dying in the meta parse).
    """

    def __init__(self, inner: StorageBackend, *, seed: int = 0,
                 eio_rate: float = 0.0, eio_attempts: int | None = 1,
                 short_rate: float = 0.0, short_attempts: int = 1,
                 corrupt_rate: float = 0.0, corrupt_attempts: int = 1,
                 stall_rate: float = 0.0, stall_seconds: float = 0.002,
                 stall_attempts: int = 1,
                 fail_first: int = 0, only_over_bytes: int = 0,
                 only_from_offset: int = 0, page_bytes: int = 4096):
        self.inner = inner
        self.path = inner.path
        self.seed = int(seed)
        self.eio_rate = float(eio_rate)
        self.eio_attempts = eio_attempts
        self.short_rate = float(short_rate)
        self.short_attempts = int(short_attempts)
        self.corrupt_rate = float(corrupt_rate)
        self.corrupt_attempts = int(corrupt_attempts)
        self.stall_rate = float(stall_rate)
        self.stall_seconds = float(stall_seconds)
        self.stall_attempts = int(stall_attempts)
        self.fail_first = int(fail_first)
        self.only_over_bytes = int(only_over_bytes)
        self.only_from_offset = int(only_from_offset)
        self.page_bytes = int(page_bytes)
        self.calls = 0
        self.fault_log: list[tuple] = []   # (kind, offset, nbytes, attempt)
        self._attempts: dict[tuple, int] = {}
        self._mu = threading.Lock()

    def _draws(self, offset: int, nbytes: int) -> np.ndarray:
        """Four uniform draws, a pure function of (seed, offset, nbytes)."""
        rng = np.random.default_rng(
            [self.seed, int(offset) & 0x7FFFFFFF, int(nbytes) & 0x7FFFFFFF])
        return rng.random(4)

    def _log(self, kind: str, offset: int, nbytes: int, attempt: int):
        self.fault_log.append((kind, int(offset), int(nbytes), attempt))

    def pread(self, nbytes: int, offset: int) -> bytes:
        with self._mu:
            call = self.calls
            self.calls += 1
            key = (int(offset), int(nbytes))
            attempt = self._attempts.get(key, 0)
            self._attempts[key] = attempt + 1
            if call < self.fail_first:
                self._log("fail_first", offset, nbytes, attempt)
        if call < self.fail_first:
            raise OSError(errno.EIO, f"injected flaky-start EIO "
                                     f"(call {call} < {self.fail_first})")
        if nbytes <= self.only_over_bytes or offset < self.only_from_offset:
            return self.inner.pread(nbytes, offset)
        u_eio, u_short, u_corrupt, u_stall = self._draws(offset, nbytes)
        if u_stall < self.stall_rate and attempt < self.stall_attempts:
            with self._mu:
                self._log("stall", offset, nbytes, attempt)
            time.sleep(self.stall_seconds)
        if u_eio < self.eio_rate and (self.eio_attempts is None
                                      or attempt < self.eio_attempts):
            with self._mu:
                self._log("eio", offset, nbytes, attempt)
            raise OSError(errno.EIO, f"injected EIO at offset {offset} "
                                     f"(attempt {attempt})")
        data = self.inner.pread(nbytes, offset)
        if u_short < self.short_rate and attempt < self.short_attempts \
                and len(data) > 1:
            with self._mu:
                self._log("short", offset, nbytes, attempt)
            return data[:len(data) // 2]
        if u_corrupt < self.corrupt_rate and attempt < self.corrupt_attempts \
                and data:
            with self._mu:
                self._log("corrupt", offset, nbytes, attempt)
            # flip the first byte of every page in the window: each torn
            # page fails its CRC, not just the window's first
            buf = bytearray(data)
            for k in range(0, len(buf), self.page_bytes):
                buf[k] ^= 0xFF
            return bytes(buf)
        return data

    def size(self) -> int:
        return self.inner.size()

    def close(self) -> None:
        self.inner.close()
