from .backend import (CorruptPageError, DeadlineExceededError,
                      FaultInjectingBackend, FileBackend, ReadError,
                      StorageBackend, StorageError, pread_full)
from .index_service import (IndexService, ServeStats, TieredBlockCache,
                            cacheable_working_set, load_serve_stats,
                            load_stats_history, observed_profile_from_stats,
                            save_stats_snapshot, stats_path)
from .serve_step import make_prefill_step, make_decode_step

__all__ = ["IndexService", "ServeStats", "TieredBlockCache",
           "cacheable_working_set", "load_serve_stats", "load_stats_history",
           "observed_profile_from_stats", "save_stats_snapshot", "stats_path",
           "make_prefill_step", "make_decode_step",
           "StorageBackend", "FileBackend", "FaultInjectingBackend",
           "StorageError", "ReadError", "CorruptPageError",
           "DeadlineExceededError", "pread_full"]
