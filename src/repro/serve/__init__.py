from .index_service import IndexService, ServeStats, TieredBlockCache
from .serve_step import make_prefill_step, make_decode_step

__all__ = ["IndexService", "ServeStats", "TieredBlockCache",
           "make_prefill_step", "make_decode_step"]
