"""Paged KV cache with an AirTune-tuned page table (DESIGN.md §3).

Serving keeps KV pages in a device pool; per-sequence *page tables* map
``logical block → physical page``.  A page table is itself a small
hierarchical index queried on every decode step — the same step-function
machinery as the paper's B-tree layers.  Its shape (single flat table vs
2-level vs deeper) is chosen by AirTune against the tier it lives in
(HBM profile for on-device tables; host-DRAM profile when tables are
offloaded), mirroring Fig. 1: fat-fast tiers ⇒ shallow, thin ⇒ deeper.

The batched lookup path runs on the Pallas index_lookup kernel (int32
keys = (seq_id << 20) | logical_block).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import KeyPositions, PROFILES, airtune, make_builders

PAGE = 16  # tokens per KV page


@dataclasses.dataclass
class PagedKVCache:
    """Host-side page-pool bookkeeping (device arrays live in serve_step)."""

    n_pages: int
    page_tokens: int = PAGE

    def __post_init__(self):
        self.free = list(range(self.n_pages))[::-1]
        self.tables: dict[int, list[int]] = {}   # seq -> physical pages
        self.lengths: dict[int, int] = {}

    def add_sequence(self, seq_id: int):
        self.tables[seq_id] = []
        self.lengths[seq_id] = 0

    def append_tokens(self, seq_id: int, n: int):
        need = -(-(self.lengths[seq_id] + n) // self.page_tokens) \
            - len(self.tables[seq_id])
        for _ in range(need):
            if not self.free:
                raise MemoryError("KV pool exhausted")
            self.tables[seq_id].append(self.free.pop())
        self.lengths[seq_id] += n

    def release(self, seq_id: int):
        self.free.extend(self.tables.pop(seq_id))
        self.lengths.pop(seq_id)

    # ---- AirIndex over the page mapping ----
    def key_positions(self) -> KeyPositions:
        """(seq<<20|block) → physical page byte ranges (page-record space)."""
        keys, pages = [], []
        for seq, tbl in sorted(self.tables.items()):
            for blk, phys in enumerate(tbl):
                keys.append((seq << 20) | blk)
                pages.append(phys)
        keys = np.asarray(keys, dtype=np.uint64)
        pages = np.asarray(pages, dtype=np.int64)
        order = np.argsort(keys)
        keys, pages = keys[order], pages[order]
        # record = one 8-byte page pointer in the table tier
        lo = pages * 8
        return KeyPositions(keys=keys, lo=lo, hi=lo + 8,
                            weights=np.ones(len(keys)))

    def tune_table(self, tier: str = "hbm", k: int = 3):
        """AirTune the page-table structure for a storage tier."""
        D = self.key_positions()
        builders = make_builders(lam_low=2**5, lam_high=2**14, base=2.0, p=8)
        return airtune(D, PROFILES[tier], builders, k=k)

    def modeled_lookup_cost(self, tier: str = "hbm") -> dict:
        """Compare tuned vs flat-table lookup under the tier profile."""
        res = self.tune_table(tier)
        D = res.design.data
        flat_cost = float(PROFILES[tier](D.size_bytes))   # read whole table
        return {"tuned_us": res.cost * 1e6, "flat_us": flat_cost * 1e6,
                "design": res.design.describe()}
