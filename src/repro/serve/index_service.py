"""Batched index-serving engine with a tiered block cache (ROADMAP: the
production-serving path).

``lookup_serialized`` walks the file once per query; under heavy traffic
that wastes exactly the structure AirIndex tunes for — hot upper-layer
pages are re-fetched from storage again and again, and per-query ``pread``s
of overlapping ranges each pay the tier's latency ℓ.  :class:`IndexService`
serves *batches* against one serialized index through three mechanisms:

  1. **page cache** — the file is read in fixed-size pages (the paged
     layout of :mod:`repro.core.serialize`); pages pass through a tiered
     LRU (:class:`TieredBlockCache`, e.g. a small L1 over a larger L2), so
     a skewed or repeated workload stops touching storage at all;
  2. **read coalescing** — all pages a batch misses are merged into maximal
     runs (:func:`repro.core.descent.coalesce_ranges`) before any
     ``pread`` is issued: one seek per run, not per query;
  3. **resident layers** — the top ``spec.resident_layers`` index layers
     are pinned in memory at open (the root is always read in full, per
     Alg. 1) and descended in ONE fused dispatch per batch
     (:mod:`repro.kernels.fused_descent`): the numpy backend is the
     bit-exact float64 walk; ``backend="pallas"``/``"jnp"`` run the fused
     f32 kernel with the Pallas → jnp → numpy fallback chain;
  4. **two-stage pipeline** — :meth:`IndexService.lookup_batches` with
     ``spec.pipeline_depth > 0`` overlaps the fused descent + disk walk of
     batch *i* (stage 2, this thread) with the coalesced first-window
     preads of batches *i+1..i+depth* (stage 1, a single background
     worker).  The prefetch stage only warms the block cache — windows are
     identical to unpipelined serving — and its preads are tagged
     ``overlapped`` in the stats so per-pread latency fits stay honest.

Configuration arrives as a :class:`repro.api.ServeSpec` (``spec=``); the
pre-spec keyword surface survives as warn-once deprecation shims.
Per-layer descent is the same :mod:`repro.core.descent` step used by
``lookup_batch`` and ``SerializedIndex``, so all three paths agree
bit-for-bit.  Observed hit rates feed back into tuning via
:meth:`IndexService.cached_profile` (→ :class:`repro.core.CachedProfile`);
:meth:`ServeStats.roofline` attributes served time to compute vs I/O so
the serve bench can trend which side of the roofline the engine sits on.
"""
from __future__ import annotations

import dataclasses
import json
import os
import threading
import time
import warnings
from collections import OrderedDict

import numpy as np

from repro.core.descent import coalesce_ranges, descend_layers
from repro.core.serialize import (_BAND_DT, _STEP_DT, gallop_step, page_crc,
                                  page_span, parse_meta,
                                  predict_from_records, record_aligned_range,
                                  window_misses)
from repro.core.storage import (CachedProfile, DistributionalProfile,
                                MeasuredProfile, PROFILES, StorageProfile)
from repro.serve.backend import (CorruptPageError, DeadlineExceededError,
                                 FileBackend, ReadError, StorageBackend)

DEFAULT_PAGE_BYTES = 4096

STATS_SUFFIX = ".stats.json"   # ServeStats snapshots live next to the index
STATS_WINDOW = 16              # rotating window: snapshots kept per file
READ_SAMPLE_CAP = 512          # measured (Δ, seconds) pread samples retained
LOOKUP_SAMPLE_CAP = 512        # per-lookup (n, wall) samples retained
MIN_FIT_SAMPLES = 8            # reservoir samples needed before any
#                                observed-profile fit (measured or
#                                distributional) says anything


def demo_serving_design(D):
    """Canonical 3-layer stack (step <- band <- step root) used by the
    serving benchmark, example, and tests: two disk layers below a
    resident root, so the block cache actually has something to do.
    (AirTune picks 1-layer designs at container scale — optimal for
    latency, useless for exercising a cache.)"""
    from repro.core import IndexDesign
    from repro.core.builders import build_gband, build_gstep
    from repro.core.nodes import outline
    l1 = build_gstep(D, 8, 2**10)
    o1 = outline(l1, D)
    l2 = build_gband(o1, 2**9)
    l3 = build_gstep(outline(l2, o1), 8, 2**7)
    return IndexDesign(layers=(l1, l2, l3), data=D)


# ---------------------------------------------------------------------------
# tiered LRU block cache
# ---------------------------------------------------------------------------
class TieredBlockCache:
    """LRU page cache with N capacity tiers (tier 0 = hottest).

    ``get`` probes tiers in order and promotes hits to tier 0; inserts
    cascade evictions downward (tier i's LRU page demotes to tier i+1, the
    last tier evicts to nothing) — i.e. an exclusive multi-level cache, the
    software mirror of a DRAM-over-SSD-over-object-store hierarchy.
    """

    def __init__(self, capacities_bytes, page_bytes: int):
        caps = tuple(int(c) for c in capacities_bytes)
        assert caps and all(c >= 0 for c in caps), caps
        self.page_bytes = int(page_bytes)
        self.cap_pages = [c // self.page_bytes for c in caps]
        self.tiers = [OrderedDict() for _ in caps]
        self.hits = [0] * len(caps)
        self.misses = 0

    @property
    def n_tiers(self) -> int:
        return len(self.tiers)

    def __contains__(self, page_id) -> bool:
        return any(page_id in t for t in self.tiers)

    def get(self, page_id):
        """→ page bytes (promoting to tier 0) or None on a full miss."""
        for ti, tier in enumerate(self.tiers):
            if page_id in tier:
                data = tier.pop(page_id)
                self.hits[ti] += 1
                self._insert(page_id, data)
                return data
        self.misses += 1
        return None

    def peek(self, page_id):
        """→ page bytes without promotion or hit/miss accounting — the
        prefetch stage reads through this so overlapped work never skews
        the hit-rate the tuner feeds on."""
        for tier in self.tiers:
            if page_id in tier:
                return tier[page_id]
        return None

    def put(self, page_id, data) -> None:
        for tier in self.tiers:
            tier.pop(page_id, None)
        self._insert(page_id, data)

    def _insert(self, page_id, data) -> None:
        ti = 0
        while ti < len(self.tiers):
            tier = self.tiers[ti]
            tier[page_id] = data
            tier.move_to_end(page_id)
            if len(tier) <= self.cap_pages[ti]:
                return
            page_id, data = tier.popitem(last=False)   # demote the LRU page
            ti += 1

    def stats(self) -> dict:
        return {"hits_per_tier": list(self.hits), "hits": sum(self.hits),
                "misses": self.misses,
                "pages_resident": [len(t) for t in self.tiers]}


# ---------------------------------------------------------------------------
# serving statistics
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class ServeStats:
    queries: int = 0
    batches: int = 0
    preads: int = 0             # coalesced reads actually issued
    ranges_requested: int = 0   # per-query per-layer ranges before merging
    pages_fetched: int = 0
    pages_hit: int = 0
    bytes_fetched: int = 0      # from storage, excluding open-time reads
    bytes_from_cache: int = 0
    open_bytes: int = 0         # root + resident layers read at open
    retries: int = 0            # window extensions (band inter-key misses)
    # -- fault-tolerance counters (RetryPolicy + checksums + hot swap) ----
    io_retries: int = 0         # failed pread attempts that were retried
    io_timeouts: int = 0        # preads past the per-pread deadline
    degraded_runs: int = 0      # coalesced runs split to page granularity
    #                             after exhausting their retry budget
    corrupt_pages: int = 0      # CRC32 failures detected on cache fill
    #                             (each is refetched once before raising)
    swaps: int = 0              # live index hot-swaps performed (counted on
    #                             the service's NEW epoch stats)
    device_batches: int = 0     # batches whose resident descent ran fused
    #                             on a device backend (pallas or jnp)
    pipelined_batches: int = 0  # batches served through lookup_batches'
    #                             two-stage pipeline
    overlapped_preads: int = 0  # preads issued by the prefetch stage while
    #                             stage 2 was descending another batch
    modeled_seconds: float = 0.0   # Σ T(Δ) under the configured profile
    open_modeled_seconds: float = 0.0  # the open-time share of the above
    data_modeled_seconds: float = 0.0  # Σ T(hi−lo) of returned data ranges
    # roofline attribution (see .roofline()): measured wall inside the
    # fused resident descent (stage-2 compute) vs Σ T(run) of every pread
    # actually issued under the deployment profile (serving I/O; open-time
    # resident loads excluded) — plus the prefetch stage's own wall
    pread_modeled_seconds: float = 0.0
    descent_seconds: float = 0.0
    prefetch_seconds: float = 0.0
    overlapped_pread_seconds: float = 0.0  # measured wall of tagged preads
    # what the *uncached* Alg. 1 walk (lookup_serialized) would pay for the
    # same traffic under the configured profile: per query, full price for
    # every layer window (resident ones included) plus the data read —
    # the deployment tier's Eq. 6 value realized on observed queries
    walk_modeled_seconds: float = 0.0
    pread_seconds: float = 0.0  # measured wall-clock inside os.pread
    # uniform reservoir (Vitter's Algorithm R, seeded — deterministic
    # under a fixed ``sample_seed``) of measured (Δ bytes, seconds,
    # overlapped, tainted) pread samples — the raw material of
    # observed_profile(); capped at READ_SAMPLE_CAP.  Every pread ever
    # seen has equal probability of being retained, so quantile fits
    # are not biased toward the most recent burst (the old cap-eviction
    # kept a recency window).  ``overlapped`` tags preads issued by the
    # prefetch stage: they ran concurrently with compute and other I/O,
    # so their wall time measures queueing as much as the tier.
    # ``tainted`` tags reads that needed retries, blew a deadline, or
    # repaired a corrupt page: their wall time measures the *fault*, not
    # the tier, and no profile fit may ever ingest them
    # (:func:`untainted_read_samples` is the single eligibility filter).
    read_samples: list = dataclasses.field(default_factory=list)
    reads_seen: int = 0         # total preads offered to the reservoir
    # uniform reservoir of per-lookup (n_queries, wall seconds) pairs —
    # the online p50/p99 estimates (``lookup_quantile``) that feed
    # detect_drift's observed_p50/p99 fields
    lookup_samples: list = dataclasses.field(default_factory=list)
    lookups_seen: int = 0       # total lookup batches offered
    sample_seed: int = 0        # reservoir determinism knob

    @property
    def hit_rate(self) -> float:
        touched = self.pages_hit + self.pages_fetched
        return self.pages_hit / touched if touched else 0.0

    @property
    def bytes_saved(self) -> int:
        return self.bytes_from_cache

    @property
    def query_modeled_seconds(self) -> float:
        """Observed per-query E[T]: what a lookup costs through *this*
        engine (residency + block cache + coalescing) including the final
        data-range read, under the configured profile.  Open-time reads
        are amortized out — per-lookup cost is what Eq. 6 models."""
        if self.queries == 0:
            return float("nan")
        return (self.modeled_seconds - self.open_modeled_seconds
                + self.data_modeled_seconds) / self.queries

    @property
    def walk_query_seconds(self) -> float:
        """Per-query cost of the full-price (cacheless) Alg. 1 walk on the
        observed traffic — the configured profile's *prediction* for this
        design, independent of cache warm-up state.  Compared against the
        recorded ``tune.cost`` this isolates storage-tier drift; compared
        against :attr:`query_modeled_seconds` it shows the cache's gain
        (see :mod:`repro.api.drift`)."""
        if self.queries == 0:
            return float("nan")
        return self.walk_modeled_seconds / self.queries

    def _reservoir_put(self, reservoir: list, cap: int, seen: int,
                       sample: tuple, salt: int) -> None:
        """Algorithm R step: with the reservoir full, the ``seen``-th item
        replaces a uniformly random slot with probability cap/seen.  The
        replacement draw is a pure function of (sample_seed, salt, seen),
        so a fixed seed replays the identical reservoir."""
        if len(reservoir) < cap:
            reservoir.append(sample)
            return
        rng = np.random.default_rng((int(self.sample_seed) & 0x7FFFFFFF,
                                     int(salt), int(seen)))
        j = int(rng.integers(0, seen))
        if j < cap:
            reservoir[j] = sample

    def record_read(self, nbytes: int, seconds: float,
                    overlapped: bool = False, tainted: bool = False) -> None:
        self.pread_seconds += seconds
        self.reads_seen += 1
        self._reservoir_put(self.read_samples, READ_SAMPLE_CAP,
                            self.reads_seen,
                            (int(nbytes), float(seconds), bool(overlapped),
                             bool(tainted)), salt=0)

    def record_lookup(self, n_queries: int, wall_seconds: float) -> None:
        """Feed one lookup batch's wall time into the per-lookup latency
        reservoir (uniform over all batches ever served)."""
        self.lookups_seen += 1
        self._reservoir_put(self.lookup_samples, LOOKUP_SAMPLE_CAP,
                            self.lookups_seen,
                            (int(n_queries), float(wall_seconds)), salt=1)

    def lookup_quantile(self, p: float) -> float | None:
        """Online per-query wall-latency ``p``-quantile estimate.

        Each reservoir entry contributes its per-query average weighted
        by its batch size (a 64-query batch is 64 query experiences).
        None before any lookups are recorded.  Weighted empirical
        quantile with midpoint positions, linear interpolation.
        """
        if not self.lookup_samples:
            return None
        if not 0.0 < float(p) < 1.0:
            raise ValueError(f"quantile p must be in (0, 1), got {p}")
        vals = np.asarray([s / max(int(n), 1)
                           for n, s in self.lookup_samples], dtype=np.float64)
        w = np.asarray([max(int(n), 1) for n, _ in self.lookup_samples],
                       dtype=np.float64)
        order = np.argsort(vals, kind="stable")
        vals, w = vals[order], w[order]
        pos = (np.cumsum(w) - 0.5 * w) / w.sum()
        return float(np.interp(float(p), pos, vals))

    def roofline(self) -> dict:
        """Compute-vs-I/O attribution of served traffic: measured wall
        inside the fused resident descent (stage-2 compute) vs the modeled
        cost ``Σ T(run)`` of every pread actually issued under the
        deployment tier (overlapped or not; open-time loads excluded).
        ``bound`` names the roofline side — ``"pread"`` is the goal state,
        the regime the paper's storage-aware tuning optimizes for.  The
        serve bench trends this dict per PR (``BENCH_serve.json``)."""
        compute = float(self.descent_seconds)
        io = float(self.pread_modeled_seconds)
        total = compute + io
        return {
            "compute_seconds": compute,
            "io_seconds": io,
            "io_fraction": (io / total) if total > 0 else None,
            "bound": (("pread" if io >= compute else "descent")
                      if total > 0 else None),
        }

    def snapshot(self) -> dict:
        d = dataclasses.asdict(self)
        d["read_samples"] = [[int(r[0]), float(r[1]), bool(r[2]), bool(r[3])]
                             for r in self.read_samples]
        d["lookup_samples"] = [[int(r[0]), float(r[1])]
                               for r in self.lookup_samples]
        d["hit_rate"] = self.hit_rate
        d["roofline"] = self.roofline()
        # derived, human-readable tail estimates (ignored on load)
        d["lookup_p50_seconds"] = self.lookup_quantile(0.5)
        d["lookup_p99_seconds"] = self.lookup_quantile(0.99)
        # NaN (no queries yet) is not valid strict JSON — null it out
        for key in ("query_modeled_seconds", "walk_query_seconds"):
            v = getattr(self, key)
            d[key] = v if np.isfinite(v) else None
        return d

    @classmethod
    def from_snapshot(cls, d: dict) -> "ServeStats":
        """Inverse of :meth:`snapshot` (derived keys are recomputed, so
        ``from_snapshot(s.snapshot())`` round-trips exactly).  Pre-pipeline
        snapshots carried 2-element read samples (→ non-overlapped) and
        pre-reliability ones 3-element samples (→ non-tainted)."""
        if not isinstance(d, dict):
            raise TypeError(f"snapshot must be an object, "
                            f"got {type(d).__name__}")
        fields = {f.name: f for f in dataclasses.fields(cls)}
        # coerce scalars through the field's declared type so a corrupt
        # value (e.g. "queries": "oops") raises here — load_serve_stats
        # turns that into a warn-and-skip, never a poisoned ServeStats
        kw = {}
        for k, v in d.items():
            f = fields.get(k)
            if f is None or k in ("read_samples", "lookup_samples"):
                continue
            kw[k] = int(v) if isinstance(f.default, int) else float(v)
        kw["read_samples"] = [
            (int(r[0]), float(r[1]),
             bool(r[2]) if len(r) > 2 else False,
             bool(r[3]) if len(r) > 3 else False)
            for r in d.get("read_samples", [])]
        kw["lookup_samples"] = [(int(r[0]), float(r[1]))
                                for r in d.get("lookup_samples", [])]
        st = cls(**kw)
        # legacy snapshots (pre-reservoir) carry no seen counters: make
        # the reservoir state self-consistent so Algorithm R keeps
        # working (seen must be >= the retained count)
        st.reads_seen = max(st.reads_seen, len(st.read_samples))
        st.lookups_seen = max(st.lookups_seen, len(st.lookup_samples))
        return st


# ---------------------------------------------------------------------------
# ServeStats persistence (ROADMAP: serve-path autoscaling / observe→retune)
# ---------------------------------------------------------------------------
def stats_path(index_path: str) -> str:
    """Where an index file's ServeStats snapshots live (next to the meta)."""
    return index_path + STATS_SUFFIX


def save_stats_snapshot(index_path: str, stats: ServeStats, *,
                        profile_name: str | None = None,
                        window: int = STATS_WINDOW) -> str:
    """Append one snapshot to ``<index_path>.stats.json``, keeping only the
    last ``window`` snapshots (rotating).  Returns the stats-file path."""
    path = stats_path(index_path)
    history = load_stats_history(index_path)
    history.append({"profile": profile_name, "stats": stats.snapshot()})
    history = history[-max(int(window), 1):]
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump({"version": 1, "snapshots": history}, f)
    os.replace(tmp, path)      # atomic: a reader never sees a torn file
    return path


def load_stats_history(index_path: str) -> list:
    """All persisted snapshots (oldest first); [] when none/unreadable.

    Never raises: a fleet startup reads N of these, and one corrupt or
    truncated file must not take the whole fleet down.  A file that exists
    but cannot be decoded (torn write, hand edit, wrong schema) warns and
    loads as empty; individual malformed snapshot entries are skipped with
    a warning rather than poisoning the readable ones."""
    path = stats_path(index_path)
    try:
        with open(path) as f:
            d = json.load(f)
    except OSError:
        return []          # no snapshot yet: the normal cold-start case
    except ValueError:
        warnings.warn(f"corrupt stats file {path!r}: not valid JSON; "
                      f"treating as empty", RuntimeWarning, stacklevel=2)
        return []
    if not isinstance(d, dict):
        warnings.warn(f"corrupt stats file {path!r}: expected an object, "
                      f"got {type(d).__name__}; treating as empty",
                      RuntimeWarning, stacklevel=2)
        return []
    snaps = d.get("snapshots") or []
    if not isinstance(snaps, list):
        warnings.warn(f"corrupt stats file {path!r}: 'snapshots' is not a "
                      f"list; treating as empty", RuntimeWarning,
                      stacklevel=2)
        return []
    good = [s for s in snaps if isinstance(s, dict)]
    if len(good) != len(snaps):
        warnings.warn(f"stats file {path!r}: skipped "
                      f"{len(snaps) - len(good)} malformed snapshot(s)",
                      RuntimeWarning, stacklevel=2)
    return good


def load_serve_stats(index_path: str) -> ServeStats | None:
    """The latest *loadable* persisted :class:`ServeStats` for an index
    file — snapshots that fail to decode are skipped (newest first, with
    a warning) rather than raised, so one torn snapshot degrades to the
    previous one instead of failing fleet startup."""
    for snap in reversed(load_stats_history(index_path)):
        try:
            return ServeStats.from_snapshot(snap["stats"])
        except (KeyError, TypeError, ValueError, IndexError):
            warnings.warn(
                f"stats file {stats_path(index_path)!r}: skipping a "
                f"snapshot that does not decode as ServeStats",
                RuntimeWarning, stacklevel=2)
    return None


def cacheable_working_set(meta, resident_layers: int = 1) -> int:
    """Bytes the block cache can usefully hold for an index file: the
    serialized sizes of every *non-resident* layer (the engine pins the
    top ``resident_layers`` in memory at open; the data layer is read by
    the caller, not through the cache).  The fleet's budget allocator
    water-fills against exactly this figure per shard."""
    L = len(meta.layers)
    n_res = min(max(int(resident_layers), 1), L) if L else 0
    return int(sum(lm.size for lm in meta.layers[:L - n_res]))


def untainted_read_samples(stats: ServeStats) -> list:
    """Reservoir samples eligible for *any* profile fitting.

    The single source of truth for the tainted filter: samples tagged
    ``tainted`` (retried, stalled past a deadline, or part of a
    corrupt-page repair) measure the *fault*, not the tier, and no
    fitting path — measured mean or distributional — may ever ingest
    them.  A flaky disk must not read as a slow one."""
    return [r for r in stats.read_samples if not (len(r) > 3 and r[3])]


def _fit_eligible_samples(stats: ServeStats, min_samples: int) -> list:
    """Shared eligibility ladder for observed-profile fits.

    Samples tagged ``overlapped`` (issued by the pipeline's prefetch
    stage while compute and other I/O were in flight) measure queueing,
    not the tier — fitting them would *under-price* the tier exactly
    when pipelining hides latency best.  They are excluded whenever
    enough blocking samples remain; a fully-pipelined window falls back
    to all *untainted* samples — the ``overlapped`` filter is the only
    one ever relaxed; the tainted filter
    (:func:`untainted_read_samples`) is unconditional, so a scarce
    mostly-tainted window yields too few samples and the fit returns
    None rather than modeling the faults."""
    clean = untainted_read_samples(stats)
    blocking = [r for r in clean if not (len(r) > 2 and r[2])]
    return blocking if len(blocking) >= min_samples else clean


def measured_backing_profile(
        stats: ServeStats,
        min_samples: int = MIN_FIT_SAMPLES) -> MeasuredProfile | None:
    """Monotone ``T(Δ)`` through the *measured* pread samples — per-size
    median wall-clock, the §3.2 measurement applied to live serving.
    None when the window holds too few eligible samples (tainted ones
    never are — see :func:`_fit_eligible_samples`) or too few distinct
    sizes to say anything about the latency/bandwidth split."""
    samples = _fit_eligible_samples(stats, min_samples)
    if len(samples) < min_samples:
        return None
    sizes = np.asarray([r[0] for r in samples], dtype=np.float64)
    secs = np.asarray([r[1] for r in samples], dtype=np.float64)
    uniq = np.unique(sizes)
    if len(uniq) < 2:
        return None
    med = [float(np.median(secs[sizes == u])) for u in uniq]
    return MeasuredProfile(deltas=tuple(float(u) for u in uniq),
                           seconds=tuple(med), name="observed-preads")


def distributional_backing_profile(
        stats: ServeStats, min_samples: int = MIN_FIT_SAMPLES,
        qs=(0.5, 0.9, 0.95, 0.99)) -> DistributionalProfile | None:
    """Per-Δ latency *distributions* from the pread reservoir — the raw
    material of tail-latency tuning (mean + mean-excess + empirical
    quantiles per size; see
    :class:`repro.core.storage.DistributionalProfile`).  Same sample
    eligibility as :func:`measured_backing_profile`: tainted reads never
    fit, the overlapped filter relaxes only when blocking samples are
    scarce.  None when too few eligible samples or distinct sizes."""
    samples = _fit_eligible_samples(stats, min_samples)
    return DistributionalProfile.fit(
        [(r[0], r[1]) for r in samples], min_samples=min_samples, qs=qs,
        name="observed-pread-dist")


def observed_profile_from_stats(stats: ServeStats, backing: StorageProfile,
                                cache: StorageProfile | None = None, *,
                                measured: bool = True,
                                min_samples: int = MIN_FIT_SAMPLES,
                                distributional: bool = False) -> CachedProfile:
    """Fold observed serving behavior into an effective ``T(Δ)``.

    The hit rate always comes from the stats; the backing tier is replaced
    by the *measured* per-pread profile when ``measured=True`` and the
    sample window supports it, else the modeled ``backing`` is kept (so
    with ``measured=False`` this is exactly the deployment-configured
    :meth:`IndexService.cached_profile`).  ``distributional=True``
    prefers the distributional fit (mean + tail mass, the input a
    quantile-objective retune needs), degrading to the measured mean
    fit, then the modeled backing.  Pure function of the snapshot —
    a reloaded snapshot yields the identical profile."""
    eff = backing
    if measured:
        m = (distributional_backing_profile(stats, min_samples=min_samples)
             if distributional else None)
        if m is None:
            m = measured_backing_profile(stats, min_samples=min_samples)
        if m is not None:
            eff = m
    # default name kept so a measured=False observed profile compares equal
    # to IndexService.cached_profile() (frozen-dataclass field equality)
    return CachedProfile(backing=eff, cache=cache, hit_rate=stats.hit_rate)


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------
#: pre-ServeSpec constructor keywords, kept as warn-once deprecation shims
_LEGACY_KWARGS = ("cache_bytes", "cache_profile", "page_bytes",
                  "resident_layers", "use_device", "interpret",
                  "coalesce_gap", "persist_stats")


def _fold_legacy_kwargs(spec, legacy: dict):
    """Fold pre-spec constructor keywords into a ServeSpec, warning once
    per keyword (hard error for ``repro.*`` callers — the repo itself must
    stay on the spec surface).  ``spec`` may be None."""
    from repro.api.spec import ServeSpec   # lazy: api sits above serve
    from repro.core.deprecation import warn_deprecated
    changes = {}
    for name, val in legacy.items():
        if name not in _LEGACY_KWARGS:
            raise TypeError(
                f"IndexService got an unexpected keyword {name!r}")
        warn_deprecated(
            f"repro.serve.IndexService({name}=...) is deprecated; pass "
            f"spec=repro.api.ServeSpec(...) instead",
            stacklevel=4, once=True)
        if name == "use_device":
            changes["backend"] = "pallas" if val else "numpy"
        elif name == "cache_bytes":
            if val is not None:      # None kept the engine default — still does
                changes["cache_bytes"] = tuple(val)
        elif name == "page_bytes":
            changes["page_bytes"] = int(val or 0)
        elif name == "cache_profile":
            if val is None or isinstance(val, str):
                changes["cache_profile"] = val
            else:                    # profile object: map back to its name
                pname = getattr(val, "name", None)
                if pname not in PROFILES:
                    raise TypeError(
                        "cache_profile objects are no longer accepted; "
                        "pass a PROFILES name (or None) via ServeSpec")
                changes["cache_profile"] = pname
        else:
            changes[name] = val
    if not changes:
        return spec
    return (spec or ServeSpec()).replace(**changes)


class _ServeState:
    """One serving *epoch*: everything :meth:`IndexService.swap` replaces
    atomically — the storage backend, decoded meta, resident prefix,
    block cache, page-CRC table, and that epoch's :class:`ServeStats`.
    Lookups pin the state for their whole batch (``pins`` refcount under
    the service lock), so a swap never closes a backend mid-descent and
    no batch ever mixes bytes from two index files."""

    __slots__ = ("path", "storage", "file_size", "meta", "tune_meta",
                 "page_bytes", "cache", "page_crcs", "resident",
                 "prefix_lis", "prefix", "packed", "device_active",
                 "stats", "pins", "retired")

    def __init__(self, path: str, storage: StorageBackend):
        self.path = path
        self.storage = storage
        self.stats = ServeStats()
        self.pins = 0
        self.retired = False


class IndexService:
    """Serve batched lookups against a serialized index file.

    Parameters
    ----------
    path:     index file written by :func:`repro.core.write_index`
              (usually via ``repro.api.Index.save``).
    profile:  storage tier of the file (name in ``PROFILES`` or a
              :class:`StorageProfile`); drives ``modeled_seconds``.  Kept
              outside the spec on purpose — the same spec serves the same
              file on any tier.
    spec:     a :class:`repro.api.ServeSpec` with everything else: cache
              tiers, residency, descent backend, pipeline knobs, the
              :class:`repro.api.RetryPolicy`, checksum verification.
              ``None`` uses the spec recorded in the file meta by
              ``Index.save(serve_spec=...)`` when present, else defaults.
              See the ServeSpec docstring for the field reference.
    backend_factory:
              ``path -> StorageBackend`` used to open the file (and every
              file later :meth:`swap`-ped in).  Defaults to
              :class:`repro.serve.FileBackend`; chaos tests pass a
              :class:`repro.serve.FaultInjectingBackend` wrapper here.

    Every byte is read through the backend with ``spec.retry`` semantics:
    failed or short preads back off and retry, a failing coalesced run
    degrades to page-granularity retries, per-page CRC32 checksums (when
    the file carries them) are verified before a page may enter the
    cache, and the typed errors of :mod:`repro.serve.backend` surface
    once the budget is spent.  All epoch-specific objects live in a
    :class:`_ServeState`; ``meta``/``cache``/``stats``/... are properties
    onto the current epoch so :meth:`swap` can replace them atomically
    under live traffic.

    The pre-spec keyword surface (``cache_bytes=``, ``use_device=``, ...)
    survives as warn-once deprecation shims that fold into the spec;
    internal (``repro.*``) callers hard-error instead.
    """

    def __init__(self, path: str, *, profile="azure_ssd", spec=None,
                 backend_factory=None, **legacy):
        self._state = None          # __del__ must be safe mid-__init__
        self._final_state = None
        self._executor = None
        self._prefetch_exc = None
        if legacy:
            spec = _fold_legacy_kwargs(spec, legacy)
        self.path = path
        self._backend_factory = backend_factory or FileBackend
        self.profile = PROFILES[profile] if isinstance(profile, str) else profile
        # one lock covers cache + stats + the epoch pointer: the prefetch
        # worker shares them with the serving thread; preads themselves
        # (and their retry sleeps) run outside it
        self._mu = threading.Lock()
        st, spec = self._open_state(path, spec)
        self._apply_spec(spec)
        self._state = st

    def _apply_spec(self, spec) -> None:
        """Service-level views of a resolved (validated) ServeSpec —
        everything that is deployment policy rather than epoch state."""
        self.spec = spec
        self.retry = spec.retry
        self.verify_checksums = bool(spec.verify_checksums)
        self.cache_profile = (PROFILES[spec.cache_profile]
                              if spec.cache_profile else None)
        self.coalesce_gap = int(spec.coalesce_gap)
        self.interpret = spec.interpret
        self.persist_stats = bool(spec.persist_stats)
        self.backend = spec.backend

    def _open_state(self, path: str, spec):
        """Open ``path`` into a fresh :class:`_ServeState` (meta read,
        spec resolution, CRC table, resident prefix, cold cache) without
        touching the currently-serving epoch.  Returns
        ``(state, resolved_spec)``; the backend is closed on any failure."""
        from repro.api.spec import RetryPolicy, ServeSpec
        storage = self._backend_factory(path)
        try:
            st = _ServeState(path, storage)
            st.file_size = int(storage.size())
            policy = spec.retry if spec is not None else RetryPolicy()
            st.meta = self._read_meta(st, policy)
            st.tune_meta = st.meta.tune  # facade provenance (may be None)
            if spec is None:
                spec = self._spec_from_meta(st.tune_meta)
            if spec is None:
                spec = ServeSpec()
            spec = spec.validate()
            policy = spec.retry
            # precedence: spec field > file's paged layout > default
            st.page_bytes = int(spec.page_bytes or st.meta.page_bytes
                                or DEFAULT_PAGE_BYTES)
            cache_bytes = spec.cache_bytes
            if not cache_bytes:   # TuneSpec-recorded capacities, then default
                tspec = (st.tune_meta or {}).get("spec") or {}
                cache_bytes = tuple(tspec.get("cache_bytes") or ()) or (1 << 20,)
            st.cache = TieredBlockCache(cache_bytes, st.page_bytes)
            # CRC table: file page id -> expected CRC32.  Only meaningful
            # when the engine pages exactly as the writer did — a spec
            # page_bytes override re-tiles the file and the per-page CRCs
            # no longer line up, so verification is skipped (same as an
            # old file without checksums).
            st.page_crcs = None
            if spec.verify_checksums and st.page_bytes \
                    and st.page_bytes == st.meta.page_bytes:
                table = {}
                for lm in st.meta.layers:
                    if lm.page_crcs:
                        base = int(lm.offset) // st.page_bytes
                        for k, c in enumerate(lm.page_crcs):
                            table[base + k] = int(c)
                st.page_crcs = table or None

            L = len(st.meta.layers)
            n_res = min(max(int(spec.resident_layers), 1), L) if L else 0
            st.resident = {}
            for li in range(L - n_res, L):
                lm = st.meta.layers[li]
                raw = self._load_resident(st, lm, policy)
                st.resident[li] = self._parse_layer(lm, raw)
                with self._mu:
                    st.stats.open_bytes += lm.size
                    if self.profile is not None:
                        t = float(self.profile(lm.size))
                        st.stats.modeled_seconds += t
                        st.stats.open_modeled_seconds += t
            # the resident prefix, top-down (root first) — the fused
            # kernel's layer order; row L−1 of its output feeds the disk
            # walk
            st.prefix_lis = list(range(L - 1, L - n_res - 1, -1))
            st.prefix = [st.resident[li] for li in st.prefix_lis]
            st.packed = None
            st.device_active = False
            if spec.backend != "numpy" and st.prefix:
                from repro.kernels import fused_descent as fd
                st.packed = fd.pack_prefix(st.prefix)
                if st.packed is not None:
                    try:
                        import jax  # noqa: F401  (gated: CPU-only containers)
                    # airlint: allow[typed-error-flow] -- import gate: the
                    # body is 'import jax', which cannot raise a StorageError
                    except Exception:
                        st.packed = None
                st.device_active = st.packed is not None
        except BaseException:
            storage.close()
            raise
        return st, spec

    def _read_meta(self, st, policy):
        """Decode the file header through the backend, retrying torn or
        failing header reads under ``policy`` (a short/corrupt header
        parses as ``ValueError`` — retryable, unlike the old assert)."""
        attempt = 0
        while True:
            try:
                return parse_meta(st.storage.pread)
            except (OSError, ValueError, KeyError, TypeError) as e:
                attempt += 1
                if attempt >= policy.max_attempts:
                    raise ReadError(
                        f"could not read index meta from {st.path!r} after "
                        f"{attempt} attempt(s): {e}",
                        path=st.path, offset=0, attempts=attempt) from e
                with self._mu:
                    st.stats.io_retries += 1
                time.sleep(policy.backoff(attempt - 1))

    def _load_resident(self, st, lm, policy) -> bytes:
        """One resident layer's bytes, short-read-safe and CRC-verified
        when the file carries checksums — resident bytes never pass the
        cache-fill check, so the open path must verify on its own.  A
        corrupt layer is refetched once, then raises
        :class:`CorruptPageError`."""
        raw, dt, tainted = self._pread_retry(st, lm.size, lm.offset,
                                             policy=policy)
        P = st.page_bytes
        crcs = st.page_crcs and getattr(lm, "page_crcs", None)
        if crcs:
            base = int(lm.offset) // P
            bad = [k for k in range(len(crcs))
                   if page_crc(raw[k * P:(k + 1) * P], P)
                   != st.page_crcs.get(base + k)]
            if bad:
                with self._mu:
                    st.stats.corrupt_pages += len(bad)
                    st.stats.record_read(len(raw), dt, tainted=True)
                raw, dt, _ = self._pread_retry(st, lm.size, lm.offset,
                                               policy=policy)
                tainted = True
                still = [k for k in bad
                         if page_crc(raw[k * P:(k + 1) * P], P)
                         != st.page_crcs.get(base + k)]
                if still:
                    raise CorruptPageError(
                        f"resident layer page {base + still[0]} of "
                        f"{st.path!r} failed CRC32 verification twice",
                        path=st.path, page_id=base + still[0])
        with self._mu:
            st.stats.record_read(len(raw), dt, tainted=tainted)
        return raw

    def _spec_from_meta(self, tune_meta):
        """The ServeSpec recorded by ``Index.save(serve_spec=...)``, or
        None (missing / forward-version meta serves on defaults)."""
        d = (tune_meta or {}).get("serve")
        if d is None:
            return None
        from repro.api.spec import ServeSpec
        try:
            return ServeSpec.from_dict(d)
        except (TypeError, ValueError):
            return None

    # -- epoch plumbing ------------------------------------------------------
    @property
    def _st(self):
        """Current epoch for attribute reads; after close, the final one
        (stats stay inspectable on a closed service)."""
        st = self._state
        return st if st is not None else self._final_state

    @property
    def meta(self):
        return self._st.meta

    @property
    def tune_meta(self):
        return self._st.tune_meta

    @property
    def stats(self) -> ServeStats:
        return self._st.stats

    @property
    def cache(self) -> TieredBlockCache:
        return self._st.cache

    @property
    def page_bytes(self) -> int:
        return self._st.page_bytes

    @property
    def device_active(self) -> bool:
        return self._st.device_active

    @property
    def _prefix(self) -> list:
        return self._st.prefix

    @property
    def storage(self) -> StorageBackend | None:
        st = self._state
        return st.storage if st is not None else None

    @property
    def fd(self):
        """The current epoch's file descriptor when the backend has one
        (:class:`FileBackend` does); None on other backends or after
        close.  Kept for the pre-backend-seam surface."""
        st = self._state
        return getattr(st.storage, "fd", None) if st is not None else None

    def _pin(self) -> _ServeState:
        """Claim the current epoch for one batch.  Must be paired with
        :meth:`_unpin` (the last unpin of a retired epoch closes its
        backend)."""
        with self._mu:
            st = self._state
            if st is None:
                raise RuntimeError("IndexService is closed")
            st.pins += 1
            return st

    def _unpin(self, st: _ServeState) -> None:
        with self._mu:
            st.pins -= 1
            dead = st.retired and st.pins == 0
        if dead:
            st.storage.close()

    def swap(self, path: str, *, spec=None) -> None:
        """Hot-swap serving to ``path`` (e.g. a freshly retuned index)
        under live traffic.  The new file is fully opened — meta, CRC
        table, resident prefix, cold cache, fresh :class:`ServeStats` —
        *before* the switch, and the switch itself is one pointer move
        under the service lock: batches already in flight pinned the old
        epoch at entry and finish on its backend + cache; batches
        arriving after ``swap`` returns serve entirely from the new one.
        No result ever mixes bytes of the two files.  The old epoch's
        stats are persisted first (``persist_stats=True``) and its
        backend closes when the last in-flight batch unpins it.  With
        ``spec=None`` the service keeps its current (deployment) spec;
        fresh-epoch stats keep observed_profile() honest for the new
        design, carrying only the ``swaps`` counter forward.  This is the
        closing move of the ROADMAP's observe → drift → retune loop —
        see ``examples/retune_daemon.py``."""
        if self._state is None:
            raise RuntimeError("swap() on a closed IndexService")
        st_new, resolved = self._open_state(
            path, spec if spec is not None else self.spec)
        with self._mu:
            old = self._state
            if old is None:            # closed while the new epoch opened
                st_new.storage.close()
                raise RuntimeError("swap() on a closed IndexService")
            st_new.stats.swaps = old.stats.swaps + 1
            self._state = st_new
            self.path = path
            old.retired = True
            dead = old.pins == 0
        if spec is not None:
            self._apply_spec(resolved)
        if self.persist_stats:
            try:
                save_stats_snapshot(old.path, old.stats,
                                    profile_name=getattr(self.profile,
                                                         "name", None))
            except OSError:
                pass
        if dead:
            old.storage.close()

    # -- lifecycle ----------------------------------------------------------
    def close(self) -> None:
        """Idempotent; drains the prefetch worker, then (with
        ``persist_stats=True``) writes the final ServeStats snapshot to
        ``<path>.stats.json`` before releasing the backend."""
        ex = getattr(self, "_executor", None)
        if ex is not None:
            ex.shutdown(wait=True)   # no prefetch pread may outlive the fd
            self._executor = None
        mu = getattr(self, "_mu", None)
        if mu is None or getattr(self, "_state", None) is None:
            return
        with mu:
            st, self._state = self._state, None
            if st is None:
                return
            self._final_state = st
            st.retired = True
            dead = st.pins == 0
        if getattr(self, "persist_stats", False):
            try:
                save_stats_snapshot(st.path, st.stats,
                                    profile_name=getattr(self.profile,
                                                         "name", None))
            except OSError:
                pass          # a read-only deployment must still close
        if dead:              # stragglers (if any) close on last unpin
            st.storage.close()

    def __enter__(self) -> "IndexService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __del__(self):
        # mirror repro.api.Index.__del__: don't leak the fd when the caller
        # skips close()/the context manager
        try:
            self.close()
        # airlint: allow[typed-error-flow] -- best-effort finalizer; raising
        # from __del__ would crash interpreter shutdown, not surface errors
        except Exception:
            pass

    # -- layer materialization ---------------------------------------------
    @staticmethod
    def _parse_layer(lm, raw: bytes) -> dict:
        if lm.kind == "step":
            rec = np.frombuffer(raw, dtype=_STEP_DT)
            pos = rec["pos"].astype(np.int64)
            return {"kind": "step", "keys": rec["key"].copy(), "pos_lo": pos,
                    "pos_hi": np.append(pos[1:], np.int64(lm.end_pos))}
        rec = np.frombuffer(raw, dtype=_BAND_DT)
        return {"kind": "band", "x1": rec["x1"].copy(),
                "y1": rec["y1"].astype(np.float64), "m": rec["m"].copy(),
                "delta": rec["delta"].copy()}

    # -- fault-tolerant reads ------------------------------------------------
    def _pread_retry(self, st: _ServeState, nbytes: int, offset: int, *,
                     deadline: float | None = None, policy=None):
        """One logical read through the backend under the RetryPolicy →
        ``(data, seconds, tainted)``.

        A failed or short attempt (pread may legally return fewer bytes
        than requested only at true EOF — anything else is a torn read)
        backs off exponentially and retries up to ``max_attempts``, then
        raises :class:`ReadError`.  ``deadline`` is an absolute
        ``perf_counter`` horizon (the per-batch budget): once past it no
        further attempt is issued and :class:`DeadlineExceededError`
        surfaces.  An attempt that outlives ``pread_deadline_s`` counts
        as a timeout; if its data is good it is still served — late bytes
        beat no bytes — but the sample comes back ``tainted`` so the
        measured tier fit never prices the stall."""
        policy = policy or self.retry
        nbytes, offset = int(nbytes), int(offset)
        want = max(min(nbytes, st.file_size - offset), 0)
        attempt = 0
        tainted = False
        while True:
            if deadline is not None and time.perf_counter() >= deadline:
                with self._mu:
                    st.stats.io_timeouts += 1
                raise DeadlineExceededError(
                    f"batch deadline expired before pread({nbytes} B @ "
                    f"{offset}) on {st.path!r}")
            err = None
            t0 = time.perf_counter()
            try:
                data = st.storage.pread(nbytes, offset)
            except OSError as e:
                data, err = b"", e
            dt = time.perf_counter() - t0
            pdl = policy.pread_deadline_s
            if pdl is not None and dt > pdl:
                # stalled attempt: count it; good-but-late data still
                # serves (the caller records the sample as tainted)
                tainted = True
                with self._mu:
                    st.stats.io_timeouts += 1
            if err is None and len(data) >= want:
                return data, dt, tainted
            attempt += 1
            if attempt >= policy.max_attempts:
                if err is not None:
                    raise ReadError(
                        f"pread({nbytes} B @ {offset}) on {st.path!r} "
                        f"failed after {attempt} attempt(s): {err}",
                        path=st.path, offset=offset, nbytes=nbytes,
                        attempts=attempt) from err
                raise ReadError(
                    f"pread({nbytes} B @ {offset}) on {st.path!r} kept "
                    f"coming back short ({len(data)}/{want} B) after "
                    f"{attempt} attempt(s)", path=st.path, offset=offset,
                    nbytes=nbytes, attempts=attempt)
            tainted = True
            with self._mu:
                st.stats.io_retries += 1
            time.sleep(policy.backoff(attempt - 1))

    def _refetch_page(self, st: _ServeState, pid: int, *,
                      deadline: float | None = None) -> bytes:
        """A page failed its CRC on cache fill: drop it and refetch once
        (the retrying pread underneath gets its own attempt budget); a
        second mismatch is a typed :class:`CorruptPageError` — never a
        silently wrong lookup."""
        P = st.page_bytes
        with self._mu:
            st.stats.corrupt_pages += 1
        raw, dt, _ = self._pread_retry(st, P, pid * P, deadline=deadline)
        with self._mu:
            st.stats.record_read(len(raw), dt, tainted=True)
        if page_crc(raw, P) != st.page_crcs.get(pid):
            raise CorruptPageError(
                f"page {pid} of {st.path!r} failed CRC32 verification "
                f"twice", path=st.path, page_id=pid)
        return raw

    # -- descent ------------------------------------------------------------
    def _descend_prefix(self, st: _ServeState, q: np.ndarray):
        """Fused walk through the whole resident prefix → float64 (L, Q)
        lo/hi rows plus the backend that served.  Device-eligible batches
        go through the Pallas → jnp → numpy chain; everything else is the
        bit-exact float64 walk (= the old per-layer path exactly)."""
        from repro.kernels import fused_descent as fd
        if st.device_active:
            return fd.fused_descent_with_backend(
                st.prefix, q, backend=self.backend,
                interpret=self.interpret, packed=st.packed)
        lo, hi = descend_layers(st.prefix, q)
        return lo, hi, "numpy"

    def _ensure_pages(self, st: _ServeState, page_ids: list,
                      deadline: float | None = None) -> dict:
        """All requested pages → bytes, via cache then coalesced preads."""
        P = st.page_bytes
        pages, missing = {}, []
        with self._mu:
            for pid in page_ids:
                data = st.cache.get(pid)
                if data is None:
                    missing.append(pid)
                else:
                    pages[pid] = data
                    st.stats.pages_hit += 1
                    st.stats.bytes_from_cache += len(data)
            if self.cache_profile is not None and pages:
                st.stats.modeled_seconds += len(pages) * float(
                    self.cache_profile(P))
        if missing:
            pages.update(self._fetch_missing(st, missing, deadline=deadline))
        return pages

    def _fetch_missing(self, st: _ServeState, missing: list, *,
                       overlapped: bool = False,
                       deadline: float | None = None) -> dict:
        """Coalesce missing page ids into runs and pread them into the
        cache.  A run that exhausts its retry budget degrades: it is
        split and refetched page-by-page (each page with a fresh budget)
        before the typed error surfaces — one bad sector must not take
        down every page that merely coalesced next to it.  Deadline
        expiry is not degradable (splitting only takes longer) and
        re-raises immediately."""
        P = st.page_bytes
        pages = {}
        ms = np.asarray(missing, dtype=np.int64) * P
        run_s, run_e = coalesce_ranges(ms, ms + P, gap=self.coalesce_gap)
        for rs, re_ in zip(run_s, run_e):
            rs, re_ = int(rs), int(re_)
            try:
                got = self._fetch_run(st, rs, re_, overlapped=overlapped,
                                      deadline=deadline)
            except ReadError:
                with self._mu:
                    st.stats.degraded_runs += 1
                got = {}
                for po in range(rs, re_, P):
                    got.update(self._fetch_run(
                        st, po, min(po + P, re_), overlapped=overlapped,
                        deadline=deadline, tainted=True))
            pages.update(got)
        return pages

    def _fetch_run(self, st: _ServeState, rs: int, re_: int, *,
                   overlapped: bool = False,
                   deadline: float | None = None,
                   tainted: bool = False) -> dict:
        """One coalesced run → pages, through the retrying pread and (when
        the file carries checksums) per-page CRC32 verification before
        anything may enter the cache.  The pread runs outside the lock
        (so prefetch I/O really overlaps stage-2 compute); cache/stats
        mutation re-acquires it."""
        P = st.page_bytes
        raw, dt, tnt = self._pread_retry(st, re_ - rs, rs, deadline=deadline)
        tnt = tnt or tainted
        chunks = []
        for k in range(-(-len(raw) // P)):
            pid = rs // P + k
            chunk = raw[k * P:(k + 1) * P]
            if st.page_crcs is not None:
                crc = st.page_crcs.get(pid)
                if crc is not None and page_crc(chunk, P) != crc:
                    chunk = self._refetch_page(st, pid, deadline=deadline)
                    tnt = True
            chunks.append((pid, chunk))
        pages = {}
        with self._mu:
            st.stats.record_read(len(raw), dt, overlapped=overlapped,
                                 tainted=tnt)
            st.stats.preads += 1
            if overlapped:
                st.stats.overlapped_preads += 1
                st.stats.overlapped_pread_seconds += dt
            st.stats.bytes_fetched += len(raw)
            if self.profile is not None:
                t = float(self.profile(re_ - rs))
                st.stats.modeled_seconds += t
                st.stats.pread_modeled_seconds += t
            for pid, chunk in chunks:
                pages[pid] = chunk
                st.cache.put(pid, chunk)
                st.stats.pages_fetched += 1
        return pages

    def _descend_disk(self, st, lm, lo, hi, q: np.ndarray,
                      deadline: float | None = None):
        P = st.page_bytes
        a, b = record_aligned_range(lm.kind, lo, hi, lm.size)
        a, b = a.copy(), b.copy()       # per-query windows, grown on misses
        with self._mu:
            st.stats.ranges_requested += len(q)
            if self.profile is not None:  # full-price walk: one window/query
                st.stats.walk_modeled_seconds += float(
                    np.sum(self.profile((b - a).astype(np.float64))))
        out_lo = np.empty(len(q), dtype=np.float64)
        out_hi = np.empty(len(q), dtype=np.float64)
        pending = np.arange(len(q))
        while len(pending):
            ab, inv = np.unique(np.stack([a[pending], b[pending]], axis=1),
                                axis=0, return_inverse=True)
            inv = inv.reshape(-1)   # numpy 2.1 briefly returned (n, 1) here
            fa, fb = lm.offset + ab[:, 0], lm.offset + ab[:, 1]
            pa, pb = page_span(fa, fb - fa, P)      # elementwise over ranges
            need: set = set()
            for x, y in zip(pa.tolist(), pb.tolist()):
                need.update(range(x, y))
            pages = self._ensure_pages(st, sorted(need), deadline)
            still = []
            for ui in range(len(ab)):
                base = int(pa[ui]) * P
                buf = b"".join(pages[p]
                               for p in range(int(pa[ui]), int(pb[ui])))
                raw = buf[int(fa[ui]) - base:int(fb[ui]) - base]
                sub = pending[inv == ui]
                left, right = window_misses(lm.kind, raw, int(ab[ui, 0]),
                                            int(ab[ui, 1]), lm.size, q[sub])
                ok = sub[~(left | right)]
                if len(ok):
                    l_, h_ = predict_from_records(lm.kind, raw, q[ok],
                                                  lm.end_pos)
                    out_lo[ok] = l_
                    out_hi[ok] = h_
                # gallop the missed windows toward the covering record
                # (same rule as SerializedIndex.lookup — parity preserved);
                # gallop_step never returns 0, so a degenerate zero-width
                # window still extends by ≥ one record instead of retrying
                # the same bounds forever
                w = gallop_step(lm.kind, int(ab[ui, 0]), int(ab[ui, 1]))
                lmiss, rmiss = sub[left], sub[right & ~left]
                a[lmiss] = max(int(ab[ui, 0]) - w, 0)
                b[rmiss] = min(int(ab[ui, 1]) + w, lm.size)
                still.extend([lmiss, rmiss])
                with self._mu:
                    st.stats.retries += len(lmiss) + len(rmiss)
                    if self.profile is not None \
                            and (len(lmiss) or len(rmiss)):
                        # the scalar walk re-reads each extended window
                        ext = np.concatenate([lmiss, rmiss])
                        st.stats.walk_modeled_seconds += float(np.sum(
                            self.profile(
                                (b[ext] - a[ext]).astype(np.float64))))
            pending = (np.concatenate(still) if still
                       else np.empty(0, dtype=np.int64))
        return out_lo, out_hi

    # -- public API ---------------------------------------------------------
    def lookup(self, queries) -> np.ndarray:
        """Batched Alg. 1 → (q, 2) int64 array of data-layer byte ranges.

        The resident prefix is descended in ONE fused dispatch (all layers,
        all queries); remaining layers walk the file through the block
        cache.  On the numpy backend the results are bit-identical to
        ``lookup_serialized`` on the same file — fusion, the cache and
        coalescing only change *how* windows are computed and bytes
        obtained.  Device backends widen resident *band* layers by the
        f32-rounding slack (ranges stay valid but may be strictly wider).

        A batch pins its serving epoch at entry, so a concurrent
        :meth:`swap` never changes the file mid-descent; with
        ``spec.retry.batch_deadline_s`` set, every pread the batch
        triggers shares one absolute deadline.
        """
        st = self._pin()
        t0 = time.perf_counter()
        try:
            out = self._lookup_pinned(st, queries)
        finally:
            self._unpin(st)
        wall = time.perf_counter() - t0
        with self._mu:
            st.stats.record_lookup(len(out), wall)
        return out

    def _lookup_pinned(self, st: _ServeState, queries) -> np.ndarray:
        q = np.atleast_1d(np.asarray(queries, dtype=np.uint64))
        bdl = self.retry.batch_deadline_s
        deadline = (time.perf_counter() + bdl) if bdl is not None else None
        with self._mu:
            st.stats.queries += len(q)
            st.stats.batches += 1
        metas = st.meta.layers
        if len(q) == 0:
            return np.empty((0, 2), dtype=np.int64)
        if not metas:
            out = np.empty((len(q), 2), dtype=np.int64)
            out[:, 0] = 0
            out[:, 1] = st.meta.data_size
            if self.profile is not None:   # (no index): scan the data layer
                t = len(q) * float(self.profile(st.meta.data_size))
                with self._mu:
                    st.stats.data_modeled_seconds += t
                    st.stats.walk_modeled_seconds += t
            return out
        lo = hi = None
        n_res = len(st.prefix)
        if n_res:
            t0 = time.perf_counter()
            plo, phi, used = self._descend_prefix(st, q)
            dt = time.perf_counter() - t0
            walk = 0.0
            if self.profile is not None:
                for r, li in enumerate(st.prefix_lis):
                    lm = metas[li]
                    if r == 0:
                        # Alg. 1 reads the ROOT outright per query;
                        # residency only amortizes it — the full-price
                        # walk counter must not
                        walk += len(q) * float(self.profile(lm.size))
                    else:
                        # non-root resident layers would be *window*
                        # reads in the scalar walk — charge the
                        # record-aligned window, not the layer size
                        # (first-window cost; the rare gallop retries an
                        # on-disk walk would pay are not modeled here)
                        wa, wb = record_aligned_range(
                            lm.kind, plo[r - 1], phi[r - 1], lm.size)
                        walk += float(np.sum(
                            self.profile((wb - wa).astype(np.float64))))
            with self._mu:
                st.stats.descent_seconds += dt
                st.stats.walk_modeled_seconds += walk
                if used != "numpy":
                    st.stats.device_batches += 1
            lo, hi = plo[-1], phi[-1]
        for li in range(len(metas) - n_res - 1, -1, -1):
            lo, hi = self._descend_disk(st, metas[li], lo, hi, q, deadline)
        lo = np.maximum(np.asarray(lo, dtype=np.int64), 0)
        hi = np.minimum(np.maximum(np.asarray(hi, dtype=np.int64), lo + 1),
                        st.meta.data_size)
        if self.profile is not None:
            # the caller's final data-range read, modeled on the same tier:
            # part of Eq. 6's E[T], charged to observed AND walk cost
            t = float(np.sum(self.profile((hi - lo).astype(np.float64))))
            with self._mu:
                st.stats.data_modeled_seconds += t
                st.stats.walk_modeled_seconds += t
        return np.stack([lo, hi], axis=1)

    def lookup_batches(self, batches) -> list:
        """Serve a sequence of query batches through the two-stage
        pipeline: while this thread descends + walks batch *i* (stage 2),
        a single background worker pre-issues the coalesced first-window
        preads of batches *i+1..i+depth* (stage 1), so storage latency
        hides behind compute.  Returns one ``lookup``-shaped array per
        batch — identical to calling :meth:`lookup` sequentially
        (``spec.pipeline_depth == 0`` does exactly that).

        A failure inside the prefetch worker (its pread retry budget
        spent, a corrupt page, a died thread) is captured and re-raised
        *here*, on the next batch boundary — never swallowed into a
        silently degraded or hung pipeline."""
        batches = [np.atleast_1d(np.asarray(b, dtype=np.uint64))
                   for b in batches]
        depth = int(self.spec.pipeline_depth)
        if depth <= 0 or len(batches) <= 1:
            return [self.lookup(b) for b in batches]
        if self._executor is None:
            from concurrent.futures import ThreadPoolExecutor
            self._executor = ThreadPoolExecutor(
                max_workers=1, thread_name_prefix="airindex-prefetch")
        pending: dict[int, object] = {}
        out = []
        for i in range(len(batches)):
            for j in range(i + 1, min(i + depth, len(batches) - 1) + 1):
                if j not in pending:
                    pending[j] = self._executor.submit(
                        self._prefetch_task, batches[j])
            out.append(self.lookup(batches[i]))
            with self._mu:
                self.stats.pipelined_batches += 1
            fut = pending.pop(i + 1, None)
            if fut is not None:
                # batch i+1 must be fully staged before stage 2 touches it:
                # the cache probe is the only coupling, but waiting keeps
                # the hit accounting deterministic
                fut.result()
            self._raise_prefetch_exc()
        for fut in pending.values():
            fut.result()
        self._raise_prefetch_exc()
        return out

    def _raise_prefetch_exc(self) -> None:
        """Surface the first exception the prefetch worker captured (the
        stage-1 error-propagation contract of :meth:`lookup_batches`)."""
        with self._mu:
            exc, self._prefetch_exc = self._prefetch_exc, None
        if exc is not None:
            raise exc

    def _prefetch_task(self, q: np.ndarray) -> int:
        """The unit the worker thread actually runs: pin an epoch, stage
        the batch, and *capture* any failure for the serving thread to
        re-raise at the next batch boundary — an exception escaping into
        the executor would otherwise vanish into the Future until someone
        happens to ``.result()`` it."""
        try:
            st = self._pin()
        except RuntimeError:
            return 0                 # service closed under the pipeline
        try:
            return self._prefetch_batch(st, q)
        # airlint: allow[typed-error-flow] -- not absorbed: captured in
        # _prefetch_exc and re-raised typed at the next batch boundary
        except BaseException as e:   # noqa: BLE001 — re-raised on boundary
            with self._mu:
                if self._prefetch_exc is None:
                    self._prefetch_exc = e
            return 0
        finally:
            self._unpin(st)

    def _prefetch_batch(self, st: _ServeState, q: np.ndarray) -> int:
        """Stage 1 of the pipeline: descend the resident prefix for a
        *future* batch and pread its missing first-window pages into the
        cache (tagged ``overlapped``).  Walks up to
        ``spec.prefetch_layers`` disk layers deep, advancing through
        already-cached records only — no gallop, no stats that belong to
        serving (the later :meth:`lookup` charges those).  Returns the
        number of pages staged."""
        t_start = time.perf_counter()
        metas = st.meta.layers
        n_res = len(st.prefix)
        n_disk = len(metas) - n_res
        staged = 0
        if n_disk <= 0 or len(q) == 0:
            return 0
        if n_res:
            plo, phi, _ = self._descend_prefix(st, q)
            lo, hi = plo[-1], phi[-1]
        else:
            lo = hi = None
        depth = min(max(int(self.spec.prefetch_layers), 1), n_disk)
        P = st.page_bytes
        for d in range(depth):
            lm = metas[n_disk - 1 - d]
            a, b = record_aligned_range(lm.kind, lo, hi, lm.size)
            ab = np.unique(np.stack([a, b], axis=1), axis=0)
            fa, fb = lm.offset + ab[:, 0], lm.offset + ab[:, 1]
            pa, pb = page_span(fa, fb - fa, P)
            need: set = set()
            for x, y in zip(pa.tolist(), pb.tolist()):
                need.update(range(x, y))
            with self._mu:
                missing = [pid for pid in sorted(need)
                           if pid not in st.cache]
            if missing:
                staged += len(self._fetch_missing(st, missing,
                                                  overlapped=True))
            if d + 1 < depth:
                lo, hi, q = self._advance_windows(st, lm, a, b, q)
                if len(q) == 0:
                    break
        with self._mu:
            st.stats.prefetch_seconds += time.perf_counter() - t_start
        return staged

    def _advance_windows(self, st: _ServeState, lm, a, b, q: np.ndarray):
        """Predict the next layer's windows from *cached* pages only
        (``peek``: no promotion, no hit/miss skew).  Queries whose window
        pages were evicted, or whose covering record lies outside the
        first window, simply drop out of the prefetch — stage 2 serves
        them at full fidelity."""
        P = st.page_bytes
        ab, inv = np.unique(np.stack([a, b], axis=1), axis=0,
                            return_inverse=True)
        inv = inv.reshape(-1)
        fa, fb = lm.offset + ab[:, 0], lm.offset + ab[:, 1]
        pa, pb = page_span(fa, fb - fa, P)
        idx = np.arange(len(q))
        los, his, qs = [], [], []
        for ui in range(len(ab)):
            with self._mu:
                chunks = [st.cache.peek(p)
                          for p in range(int(pa[ui]), int(pb[ui]))]
            if any(c is None for c in chunks):
                continue            # evicted under pressure: stop here
            base = int(pa[ui]) * P
            raw = b"".join(chunks)[int(fa[ui]) - base:int(fb[ui]) - base]
            sub = idx[inv == ui]
            left, right = window_misses(lm.kind, raw, int(ab[ui, 0]),
                                        int(ab[ui, 1]), lm.size, q[sub])
            ok = sub[~(left | right)]
            if len(ok) == 0:
                continue
            l_, h_ = predict_from_records(lm.kind, raw, q[ok], lm.end_pos)
            los.append(l_)
            his.append(h_)
            qs.append(q[ok])
        if not qs:
            e = np.empty(0, dtype=np.float64)
            return e, e, np.empty(0, dtype=np.uint64)
        return (np.concatenate(los), np.concatenate(his),
                np.concatenate(qs))

    @property
    def tune_spec(self):
        """The TuneSpec recorded by ``repro.api.Index.save`` (or None)."""
        spec = (self.tune_meta or {}).get("spec")
        if spec is None:
            return None
        from repro.api.spec import TuneSpec   # lazy: api sits above serve
        try:
            return TuneSpec.from_dict(spec)
        except (TypeError, ValueError):
            return None   # forward-version provenance: serve anyway

    def cached_profile(self, backing: StorageProfile | None = None) -> CachedProfile:
        """Effective ``T(Δ)`` at the observed hit rate — hand this back to
        ``airtune`` to re-tune the index *for* this cache deployment."""
        backing = backing or self.profile
        if backing is None:
            raise ValueError("no backing profile: the service was opened "
                             "with profile=None — pass one explicitly")
        return CachedProfile(backing=backing, cache=self.cache_profile,
                             hit_rate=self.stats.hit_rate)

    def observed_profile(self, backing: StorageProfile | None = None, *,
                         measured: bool = True,
                         min_samples: int = MIN_FIT_SAMPLES,
                         distributional: bool = False) -> CachedProfile:
        """Effective ``T(Δ)`` from *observed* serving behavior: the block
        cache's hit rate plus (``measured=True``) the measured per-pread
        latency in place of the modeled backing tier.  This is the profile
        a drift-triggered ``Index.retune`` should tune for (see
        :mod:`repro.api.drift`).  With ``measured=False`` it equals
        :meth:`cached_profile` exactly; ``distributional=True`` prefers
        the per-Δ distribution fit (what a quantile-objective retune
        needs)."""
        backing = backing or self.profile
        if backing is None:
            raise ValueError("no backing profile: the service was opened "
                             "with profile=None — pass one explicitly")
        return observed_profile_from_stats(self.stats, backing,
                                           self.cache_profile,
                                           measured=measured,
                                           min_samples=min_samples,
                                           distributional=distributional)

    def save_stats(self, *, window: int = STATS_WINDOW) -> str:
        """Persist the current :class:`ServeStats` snapshot next to the
        index meta (``<path>.stats.json``, rotating window) — the serve
        side of the observe→retune loop.  Returns the stats-file path."""
        prof = getattr(self.profile, "name", None)
        return save_stats_snapshot(self.path, self.stats,
                                   profile_name=prof, window=window)
