"""Batched index-serving engine with a tiered block cache (ROADMAP: the
production-serving path).

``lookup_serialized`` walks the file once per query; under heavy traffic
that wastes exactly the structure AirIndex tunes for — hot upper-layer
pages are re-fetched from storage again and again, and per-query ``pread``s
of overlapping ranges each pay the tier's latency ℓ.  :class:`IndexService`
serves *batches* against one serialized index through three mechanisms:

  1. **page cache** — the file is read in fixed-size pages (the paged
     layout of :mod:`repro.core.serialize`); pages pass through a tiered
     LRU (:class:`TieredBlockCache`, e.g. a small L1 over a larger L2), so
     a skewed or repeated workload stops touching storage at all;
  2. **read coalescing** — all pages a batch misses are merged into maximal
     runs (:func:`repro.core.descent.coalesce_ranges`) before any
     ``pread`` is issued: one seek per run, not per query;
  3. **resident layers** — the top ``resident_layers`` index layers are
     pinned in memory at open (the root is always read in full, per
     Alg. 1) and descended fully vectorized; with ``use_device=True`` the
     descent of resident layers routes through the Pallas
     ``index_lookup`` kernels when keys/positions fit int32, with the
     numpy :mod:`repro.core.descent` path as fallback.

Per-layer descent is the same :mod:`repro.core.descent` step used by
``lookup_batch`` and ``SerializedIndex``, so all three paths agree
bit-for-bit.  Observed hit rates feed back into tuning via
:meth:`IndexService.cached_profile` (→ :class:`repro.core.CachedProfile`).
"""
from __future__ import annotations

import dataclasses
import os
from collections import OrderedDict

import numpy as np

from repro.core.descent import coalesce_ranges
from repro.core.serialize import (_BAND_DT, _STEP_DT, page_span,
                                  predict_from_records, read_meta,
                                  record_aligned_range, window_misses)
from repro.core.storage import CachedProfile, PROFILES, StorageProfile

DEFAULT_PAGE_BYTES = 4096


def demo_serving_design(D):
    """Canonical 3-layer stack (step <- band <- step root) used by the
    serving benchmark, example, and tests: two disk layers below a
    resident root, so the block cache actually has something to do.
    (AirTune picks 1-layer designs at container scale — optimal for
    latency, useless for exercising a cache.)"""
    from repro.core import IndexDesign
    from repro.core.builders import build_gband, build_gstep
    from repro.core.nodes import outline
    l1 = build_gstep(D, 8, 2**10)
    o1 = outline(l1, D)
    l2 = build_gband(o1, 2**9)
    l3 = build_gstep(outline(l2, o1), 8, 2**7)
    return IndexDesign(layers=(l1, l2, l3), data=D)


# ---------------------------------------------------------------------------
# tiered LRU block cache
# ---------------------------------------------------------------------------
class TieredBlockCache:
    """LRU page cache with N capacity tiers (tier 0 = hottest).

    ``get`` probes tiers in order and promotes hits to tier 0; inserts
    cascade evictions downward (tier i's LRU page demotes to tier i+1, the
    last tier evicts to nothing) — i.e. an exclusive multi-level cache, the
    software mirror of a DRAM-over-SSD-over-object-store hierarchy.
    """

    def __init__(self, capacities_bytes, page_bytes: int):
        caps = tuple(int(c) for c in capacities_bytes)
        assert caps and all(c >= 0 for c in caps), caps
        self.page_bytes = int(page_bytes)
        self.cap_pages = [c // self.page_bytes for c in caps]
        self.tiers = [OrderedDict() for _ in caps]
        self.hits = [0] * len(caps)
        self.misses = 0

    @property
    def n_tiers(self) -> int:
        return len(self.tiers)

    def __contains__(self, page_id) -> bool:
        return any(page_id in t for t in self.tiers)

    def get(self, page_id):
        """→ page bytes (promoting to tier 0) or None on a full miss."""
        for ti, tier in enumerate(self.tiers):
            if page_id in tier:
                data = tier.pop(page_id)
                self.hits[ti] += 1
                self._insert(page_id, data)
                return data
        self.misses += 1
        return None

    def put(self, page_id, data) -> None:
        for tier in self.tiers:
            tier.pop(page_id, None)
        self._insert(page_id, data)

    def _insert(self, page_id, data) -> None:
        ti = 0
        while ti < len(self.tiers):
            tier = self.tiers[ti]
            tier[page_id] = data
            tier.move_to_end(page_id)
            if len(tier) <= self.cap_pages[ti]:
                return
            page_id, data = tier.popitem(last=False)   # demote the LRU page
            ti += 1

    def stats(self) -> dict:
        return {"hits_per_tier": list(self.hits), "hits": sum(self.hits),
                "misses": self.misses,
                "pages_resident": [len(t) for t in self.tiers]}


# ---------------------------------------------------------------------------
# serving statistics
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class ServeStats:
    queries: int = 0
    batches: int = 0
    preads: int = 0             # coalesced reads actually issued
    ranges_requested: int = 0   # per-query per-layer ranges before merging
    pages_fetched: int = 0
    pages_hit: int = 0
    bytes_fetched: int = 0      # from storage, excluding open-time reads
    bytes_from_cache: int = 0
    open_bytes: int = 0         # root + resident layers read at open
    retries: int = 0            # window extensions (band inter-key misses)
    device_batches: int = 0
    modeled_seconds: float = 0.0   # Σ T(Δ) under the configured profile

    @property
    def hit_rate(self) -> float:
        touched = self.pages_hit + self.pages_fetched
        return self.pages_hit / touched if touched else 0.0

    @property
    def bytes_saved(self) -> int:
        return self.bytes_from_cache

    def snapshot(self) -> dict:
        d = dataclasses.asdict(self)
        d["hit_rate"] = self.hit_rate
        return d


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------
class IndexService:
    """Serve batched lookups against a serialized index file.

    Parameters
    ----------
    path:            index file written by :func:`repro.core.write_index`
                     (usually via ``repro.api.Index.save``).
    profile:         storage tier of the file (name in ``PROFILES`` or a
                     :class:`StorageProfile`); drives ``modeled_seconds``.
    cache_bytes:     per-tier capacities of the block cache, hottest first.
                     ``None`` (default) uses the ``cache_bytes`` of the
                     TuneSpec recorded in the file meta when present, else
                     a single 1 MiB tier.
    cache_profile:   tier the cache lives in (modeled hit cost; host DRAM).
    page_bytes:      cache unit; defaults to the file's paged layout, or
                     ``DEFAULT_PAGE_BYTES`` for densely-packed files.
    resident_layers: top layers pinned in memory at open (≥ 1: the root is
                     always read in full, per Alg. 1).
    use_device:      descend resident layers on the Pallas index-lookup
                     kernels when keys/positions fit int32.
    coalesce_gap:    merge missing-page runs separated by ≤ this many bytes
                     (profitable when ``T(gap) − T(0) < ℓ``).
    """

    def __init__(self, path: str, *, profile="azure_ssd",
                 cache_bytes=None, cache_profile="host_dram",
                 page_bytes: int | None = None, resident_layers: int = 1,
                 use_device: bool = False, interpret: bool = True,
                 coalesce_gap: int = 0):
        self.fd = os.open(path, os.O_RDONLY)
        self.meta = read_meta(self.fd)
        self.tune_meta = self.meta.tune   # facade provenance (may be None)
        self.profile = PROFILES[profile] if isinstance(profile, str) else profile
        self.cache_profile = (PROFILES[cache_profile]
                              if isinstance(cache_profile, str) else cache_profile)
        self.page_bytes = int(self.meta.page_bytes or page_bytes
                              or DEFAULT_PAGE_BYTES)
        if cache_bytes is None:     # spec-recorded cache config, then default
            spec = (self.tune_meta or {}).get("spec") or {}
            cache_bytes = tuple(spec.get("cache_bytes") or ()) or (1 << 20,)
        self.cache = TieredBlockCache(cache_bytes, self.page_bytes)
        self.coalesce_gap = int(coalesce_gap)
        self.interpret = interpret
        self.stats = ServeStats()

        L = len(self.meta.layers)
        n_res = min(max(int(resident_layers), 1), L) if L else 0
        self._resident: dict[int, dict] = {}
        for li in range(L - n_res, L):
            lm = self.meta.layers[li]
            raw = os.pread(self.fd, lm.size, lm.offset)
            self._resident[li] = self._parse_layer(lm, raw)
            self.stats.open_bytes += lm.size
            if self.profile is not None:
                self.stats.modeled_seconds += float(self.profile(lm.size))
        self._device: dict[int, dict] = {}
        self.device_active = False
        if use_device:
            self._device = self._to_device(self._resident)
            self.device_active = bool(self._device)

    # -- lifecycle ----------------------------------------------------------
    def close(self) -> None:
        if self.fd is not None:
            os.close(self.fd)
            self.fd = None

    def __enter__(self) -> "IndexService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- layer materialization ---------------------------------------------
    @staticmethod
    def _parse_layer(lm, raw: bytes) -> dict:
        if lm.kind == "step":
            rec = np.frombuffer(raw, dtype=_STEP_DT)
            pos = rec["pos"].astype(np.int64)
            return {"kind": "step", "keys": rec["key"].copy(), "pos_lo": pos,
                    "pos_hi": np.append(pos[1:], np.int64(lm.end_pos))}
        rec = np.frombuffer(raw, dtype=_BAND_DT)
        return {"kind": "band", "x1": rec["x1"].copy(),
                "y1": rec["y1"].astype(np.float64), "m": rec["m"].copy(),
                "delta": rec["delta"].copy()}

    def _to_device(self, resident: dict) -> dict:
        """Kernel-ready int32/f32 arrays for resident layers; {} when jax is
        unavailable or any layer overflows int32 (numpy path then serves)."""
        try:
            import jax.numpy as jnp  # noqa: F401  (gated: CPU-only containers)
        except Exception:
            return {}
        dev = {}
        for li, lay in resident.items():
            if lay["kind"] == "step":
                if (int(lay["keys"].max(initial=0)) >= 2**31
                        or int(lay["pos_hi"].max(initial=0)) >= 2**31):
                    return {}
                dev[li] = {
                    "kind": "step",
                    "piece_keys": jnp.asarray(lay["keys"], jnp.int32),
                    "piece_pos": jnp.asarray(
                        np.append(lay["pos_lo"], lay["pos_hi"][-1]), jnp.int32),
                }
            else:
                if int(lay["x1"].max(initial=0)) >= 2**31:
                    return {}
                # widen δ by the worst-case f32 rounding (same slack as
                # kernels.index_lookup.ops.device_arrays_from_design)
                slack = (8.0 + np.abs(lay["y1"]) * 4e-6
                         + np.abs(lay["m"]) * lay["x1"].astype(np.float64) * 4e-6)
                dev[li] = {
                    "kind": "band",
                    "node_keys": jnp.asarray(lay["x1"], jnp.int32),
                    "x1": jnp.asarray(lay["x1"], jnp.float32),
                    "y1": jnp.asarray(lay["y1"], jnp.float32),
                    "m": jnp.asarray(lay["m"], jnp.float32),
                    "delta": jnp.asarray(lay["delta"] + slack, jnp.float32),
                }
        return dev

    # -- descent ------------------------------------------------------------
    def _descend_resident(self, li: int, q: np.ndarray):
        if self.device_active and li in self._device \
                and int(q.max(initial=0)) < 2**31:
            from repro.kernels.index_lookup import ops
            import jax.numpy as jnp
            lay = self._device[li]
            qd = jnp.asarray(q, jnp.int32)
            if lay["kind"] == "step":
                lo, hi = ops.lookup_step_layer(qd, lay["piece_keys"],
                                               lay["piece_pos"],
                                               interpret=self.interpret)
            else:
                lo, hi = ops.lookup_band_layer(qd, lay["node_keys"],
                                               lay["x1"], lay["y1"], lay["m"],
                                               lay["delta"],
                                               interpret=self.interpret)
            self.stats.device_batches += 1
            return np.asarray(lo, np.int64), np.asarray(hi, np.int64)
        lay = self._resident[li]
        if lay["kind"] == "step":
            from repro.core.descent import descend_step_layer
            return descend_step_layer(lay["keys"], lay["pos_lo"],
                                      lay["pos_hi"], q)
        from repro.core.descent import descend_band_layer
        return descend_band_layer(lay["x1"], lay["x1"], lay["y1"], lay["m"],
                                  lay["delta"], q)

    def _ensure_pages(self, page_ids: list) -> dict:
        """All requested pages → bytes, via cache then coalesced preads."""
        P = self.page_bytes
        pages, missing = {}, []
        for pid in page_ids:
            data = self.cache.get(pid)
            if data is None:
                missing.append(pid)
            else:
                pages[pid] = data
                self.stats.pages_hit += 1
                self.stats.bytes_from_cache += len(data)
        if self.cache_profile is not None and pages:
            self.stats.modeled_seconds += len(pages) * float(
                self.cache_profile(P))
        if not missing:
            return pages
        ms = np.asarray(missing, dtype=np.int64) * P
        run_s, run_e = coalesce_ranges(ms, ms + P, gap=self.coalesce_gap)
        for rs, re_ in zip(run_s, run_e):
            raw = os.pread(self.fd, int(re_ - rs), int(rs))
            self.stats.preads += 1
            self.stats.bytes_fetched += len(raw)
            if self.profile is not None:
                self.stats.modeled_seconds += float(self.profile(re_ - rs))
            for k in range(-(-len(raw) // P)):
                pid = int(rs) // P + k
                chunk = raw[k * P:(k + 1) * P]
                pages[pid] = chunk
                self.cache.put(pid, chunk)
                self.stats.pages_fetched += 1
        return pages

    def _descend_disk(self, lm, lo, hi, q: np.ndarray):
        P = self.page_bytes
        a, b = record_aligned_range(lm.kind, lo, hi, lm.size)
        a, b = a.copy(), b.copy()       # per-query windows, grown on misses
        self.stats.ranges_requested += len(q)
        out_lo = np.empty(len(q), dtype=np.float64)
        out_hi = np.empty(len(q), dtype=np.float64)
        pending = np.arange(len(q))
        while len(pending):
            ab, inv = np.unique(np.stack([a[pending], b[pending]], axis=1),
                                axis=0, return_inverse=True)
            inv = inv.reshape(-1)   # numpy 2.1 briefly returned (n, 1) here
            fa, fb = lm.offset + ab[:, 0], lm.offset + ab[:, 1]
            pa, pb = page_span(fa, fb - fa, P)      # elementwise over ranges
            need: set = set()
            for x, y in zip(pa.tolist(), pb.tolist()):
                need.update(range(x, y))
            pages = self._ensure_pages(sorted(need))
            still = []
            for ui in range(len(ab)):
                base = int(pa[ui]) * P
                buf = b"".join(pages[p]
                               for p in range(int(pa[ui]), int(pb[ui])))
                raw = buf[int(fa[ui]) - base:int(fb[ui]) - base]
                sub = pending[inv == ui]
                left, right = window_misses(lm.kind, raw, int(ab[ui, 0]),
                                            int(ab[ui, 1]), lm.size, q[sub])
                ok = sub[~(left | right)]
                if len(ok):
                    l_, h_ = predict_from_records(lm.kind, raw, q[ok],
                                                  lm.end_pos)
                    out_lo[ok] = l_
                    out_hi[ok] = h_
                # gallop the missed windows toward the covering record
                # (same rule as SerializedIndex.lookup — parity preserved)
                w = int(ab[ui, 1] - ab[ui, 0])
                lmiss, rmiss = sub[left], sub[right & ~left]
                a[lmiss] = max(int(ab[ui, 0]) - w, 0)
                b[rmiss] = min(int(ab[ui, 1]) + w, lm.size)
                still.extend([lmiss, rmiss])
                self.stats.retries += len(lmiss) + len(rmiss)
            pending = (np.concatenate(still) if still
                       else np.empty(0, dtype=np.int64))
        return out_lo, out_hi

    # -- public API ---------------------------------------------------------
    def lookup(self, queries) -> np.ndarray:
        """Batched Alg. 1 → (q, 2) int64 array of data-layer byte ranges.

        On the numpy path the results are bit-identical to
        ``lookup_serialized`` on the same file — the cache and coalescing
        only change *how* bytes are obtained.  The device path widens
        resident *band* layers by the f32-rounding slack (ranges stay
        valid but may be strictly wider).
        """
        q = np.atleast_1d(np.asarray(queries, dtype=np.uint64))
        self.stats.queries += len(q)
        self.stats.batches += 1
        metas = self.meta.layers
        if len(q) == 0:
            return np.empty((0, 2), dtype=np.int64)
        if not metas:
            out = np.empty((len(q), 2), dtype=np.int64)
            out[:, 0] = 0
            out[:, 1] = self.meta.data_size
            return out
        lo = hi = None
        for li in range(len(metas) - 1, -1, -1):
            if li in self._resident:
                lo, hi = self._descend_resident(li, q)
            else:
                lo, hi = self._descend_disk(metas[li], lo, hi, q)
        lo = np.maximum(np.asarray(lo, dtype=np.int64), 0)
        hi = np.minimum(np.maximum(np.asarray(hi, dtype=np.int64), lo + 1),
                        self.meta.data_size)
        return np.stack([lo, hi], axis=1)

    @property
    def tune_spec(self):
        """The TuneSpec recorded by ``repro.api.Index.save`` (or None)."""
        spec = (self.tune_meta or {}).get("spec")
        if spec is None:
            return None
        from repro.api.spec import TuneSpec   # lazy: api sits above serve
        try:
            return TuneSpec.from_dict(spec)
        except (TypeError, ValueError):
            return None   # forward-version provenance: serve anyway

    def cached_profile(self, backing: StorageProfile | None = None) -> CachedProfile:
        """Effective ``T(Δ)`` at the observed hit rate — hand this back to
        ``airtune`` to re-tune the index *for* this cache deployment."""
        backing = backing or self.profile
        if backing is None:
            raise ValueError("no backing profile: the service was opened "
                             "with profile=None — pass one explicitly")
        return CachedProfile(backing=backing, cache=self.cache_profile,
                             hit_rate=self.stats.hit_rate)
