"""Serving steps: prefill (full-sequence forward) and decode (one token
against the KV cache / recurrent state)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import api


def make_prefill_step(cfg):
    """prefill(params, batch) → last-position logits (B, V).

    Unembeds only the final position — full-sequence logits at 32k would
    be hundreds of GB and no server needs them.
    """

    def prefill(params, batch):
        hidden, _ = api.forward_hidden(cfg, params, batch)
        return api.apply_unembed(cfg, params, hidden[:, -1, :])

    return prefill


def make_decode_step(cfg):
    """decode(params, batch, state, pos) → (next_token_logits (B,V), state)."""

    def decode(params, batch, state, pos):
        logits, new_state = api.forward_decode(cfg, params, batch, state, pos)
        logits = logits[:, -1, :]
        if cfg.padded_vocab != cfg.vocab:   # mask padded vocab columns
            iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
            logits = jnp.where(iota < cfg.vocab, logits, -1e30)
        return logits, new_state

    return decode


def greedy_generate(cfg, params, prompt_tokens, n_steps, max_len, frames=None):
    """Simple greedy decoding loop (examples/tests); prompt (B, S0)."""
    B, S0 = prompt_tokens.shape
    state = api.init_decode_state(cfg, params, B, max_len, frames=frames)
    decode = make_decode_step(cfg)
    # feed prompt one token at a time (no separate prefill graph needed here)
    tok = None
    for t in range(S0):
        tok, state = decode(params, {"tokens": prompt_tokens[:, t:t + 1]},
                            state, t)
    out = [jnp.argmax(tok, -1)]
    for t in range(S0, S0 + n_steps - 1):
        tok, state = decode(params, {"tokens": out[-1][:, None]}, state, t)
        out.append(jnp.argmax(tok, -1))
    return jnp.stack(out, axis=1)
