"""Pallas TPU kernels: batched index-layer lookup (Alg. 1 on the MXU/VPU).

Hardware adaptation (DESIGN.md §2): a CPU traverses an index by
pointer-chase binary search — serial, data-dependent, hostile to TPUs.
The TPU-native formulation used here:

  rank(q)  = Σ_tiles count(keys_tile ≤ q)       (compare + row-sum, VPU)
  gather   = Σ_j onehot(i)_j · value_j          (select + row-sum; an MXU
                                                 matmul when values fit f32)

Both are dense, block-tileable array ops.  One pallas_call handles one
layer for a block of queries; the whole (padded) layer lives in VMEM —
which is the *designed* regime: AirIndex tunes upper layers to be small
(Fig. 1), and `ops.py` falls back to a two-level scheme for oversized
layers.

Blocking: queries are tiled ``(BLOCK_Q,)``; layer arrays are brought in
whole (padded to a multiple of 128 lanes).  int32 gathers use masked
integer row-sums (exact); float32 gathers use select + row-sum (exact,
one non-zero per row).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_Q = 256
LANE = 128
KEY_PAD = jnp.iinfo(jnp.int32).max  # padding key: never ≤ any query


def _rank(keys, q):
    """#{keys ≤ q} per query; keys (P,), q (Bq,) → (Bq,) int32."""
    cmp = (keys[None, :] <= q[:, None]).astype(jnp.int32)   # (Bq, P)
    return cmp.sum(axis=1)


def _gather_i32(values, idx, P):
    """Exact int32 gather via masked row-sum; values (P,), idx (Bq,)."""
    onehot = (jax.lax.broadcasted_iota(jnp.int32, (idx.shape[0], P), 1)
              == idx[:, None])
    return jnp.sum(jnp.where(onehot, values[None, :], 0), axis=1)


def _gather_f32(values, idx, P):
    onehot = (jax.lax.broadcasted_iota(jnp.int32, (idx.shape[0], P), 1)
              == idx[:, None])
    return jnp.sum(jnp.where(onehot, values[None, :], 0.0), axis=1)


# ---------------------------------------------------------------------------
# step layer
# ---------------------------------------------------------------------------
def _step_kernel(q_ref, keys_ref, pos_lo_ref, pos_hi_ref, lo_ref, hi_ref):
    q = q_ref[...]
    keys = keys_ref[...]
    P = keys.shape[0]
    i = jnp.maximum(_rank(keys, q) - 1, 0)
    lo_ref[...] = _gather_i32(pos_lo_ref[...], i, P)
    hi_ref[...] = _gather_i32(pos_hi_ref[...], i, P)


@functools.partial(jax.jit, static_argnames=("interpret",))
def step_lookup_pallas(queries, piece_keys, pos_lo, pos_hi, *, interpret=True):
    """queries (Q,) int32 — Q multiple of BLOCK_Q; layer padded to LANE."""
    Q, P = queries.shape[0], piece_keys.shape[0]
    assert Q % BLOCK_Q == 0 and P % LANE == 0
    grid = (Q // BLOCK_Q,)
    qspec = pl.BlockSpec((BLOCK_Q,), lambda i: (i,))
    lspec = pl.BlockSpec((P,), lambda i: (0,))
    return pl.pallas_call(
        _step_kernel,
        grid=grid,
        in_specs=[qspec, lspec, lspec, lspec],
        out_specs=[qspec, qspec],
        out_shape=[jax.ShapeDtypeStruct((Q,), jnp.int32)] * 2,
        interpret=interpret,
    )(queries, piece_keys, pos_lo, pos_hi)


# ---------------------------------------------------------------------------
# band layer
# ---------------------------------------------------------------------------
def _band_kernel(q_ref, keys_ref, x1_ref, y1_ref, m_ref, d_ref, lo_ref, hi_ref):
    q = q_ref[...]
    keys = keys_ref[...]
    P = keys.shape[0]
    j = jnp.maximum(_rank(keys, q) - 1, 0)
    x1 = _gather_f32(x1_ref[...], j, P)
    y1 = _gather_f32(y1_ref[...], j, P)
    m = _gather_f32(m_ref[...], j, P)
    d = _gather_f32(d_ref[...], j, P)
    mid = y1 + m * (q.astype(jnp.float32) - x1)
    lo = jnp.floor(mid - d).astype(jnp.int32)
    hi = jnp.ceil(mid + d).astype(jnp.int32)
    lo_ref[...] = lo
    hi_ref[...] = jnp.maximum(hi, lo + 1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def band_lookup_pallas(queries, node_keys, x1, y1, m, delta, *, interpret=True):
    Q, P = queries.shape[0], node_keys.shape[0]
    assert Q % BLOCK_Q == 0 and P % LANE == 0
    grid = (Q // BLOCK_Q,)
    qspec = pl.BlockSpec((BLOCK_Q,), lambda i: (i,))
    lspec = pl.BlockSpec((P,), lambda i: (0,))
    return pl.pallas_call(
        _band_kernel,
        grid=grid,
        in_specs=[qspec] + [lspec] * 5,
        out_specs=[qspec, qspec],
        out_shape=[jax.ShapeDtypeStruct((Q,), jnp.int32)] * 2,
        interpret=interpret,
    )(queries, node_keys, x1, y1, m, delta)


# ---------------------------------------------------------------------------
# segmented step lookup (level 2 of the two-level scheme for big layers):
# query i searches only its own (S,)-segment, fetched by a host-side gather
# ---------------------------------------------------------------------------
def _seg_step_kernel(q_ref, keys_ref, lo_in_ref, hi_in_ref, lo_ref, hi_ref):
    q = q_ref[...]                         # (Bq,)
    keys = keys_ref[...]                   # (Bq, S)
    S = keys.shape[1]
    cmp = (keys <= q[:, None]).astype(jnp.int32)
    i = jnp.maximum(cmp.sum(axis=1) - 1, 0)
    onehot = (jax.lax.broadcasted_iota(jnp.int32, keys.shape, 1) == i[:, None])
    lo_ref[...] = jnp.sum(jnp.where(onehot, lo_in_ref[...], 0), axis=1)
    hi_ref[...] = jnp.sum(jnp.where(onehot, hi_in_ref[...], 0), axis=1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def segmented_step_lookup_pallas(queries, seg_keys, seg_pos_lo, seg_pos_hi, *,
                                 interpret=True):
    Q, S = seg_keys.shape
    assert Q % BLOCK_Q == 0 and S % LANE == 0
    grid = (Q // BLOCK_Q,)
    qspec = pl.BlockSpec((BLOCK_Q,), lambda i: (i,))
    sspec = pl.BlockSpec((BLOCK_Q, S), lambda i: (i, 0))
    return pl.pallas_call(
        _seg_step_kernel,
        grid=grid,
        in_specs=[qspec, sspec, sspec, sspec],
        out_specs=[qspec, qspec],
        out_shape=[jax.ShapeDtypeStruct((Q,), jnp.int32)] * 2,
        interpret=interpret,
    )(queries, seg_keys, seg_pos_lo, seg_pos_hi)
