from .ops import lookup_step_layer, lookup_band_layer, traverse_index
from . import ref
