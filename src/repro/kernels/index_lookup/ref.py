"""Pure-jnp oracle for the batched index-lookup kernels.

Semantics contract (shared with kernel.py):

  * step layer: rank r(q) = #{piece keys ≤ q}; covering piece i = max(r−1, 0);
    prediction = [pos_lo[i], pos_hi[i]).
  * band layer: node j = max(#{node keys ≤ q} − 1, 0);
    mid = y1[j] + m[j]·(q − x1[j]) in float32;
    prediction = [floor(mid − δ[j]), ceil(mid + δ[j])).

Keys and step positions are int32 (TPU-native); band math is float32.
The oracle uses the same dtypes/ops so kernel vs ref comparison is exact.
"""
from __future__ import annotations

import jax.numpy as jnp


def step_lookup_ref(queries, piece_keys, pos_lo, pos_hi):
    """queries (Q,) int32; piece_keys (P,) int32 sorted; pos_* (P,) int32."""
    r = jnp.searchsorted(piece_keys, queries, side="right").astype(jnp.int32)
    i = jnp.maximum(r - 1, 0)
    return pos_lo[i], pos_hi[i]


def band_lookup_ref(queries, node_keys, x1, y1, m, delta):
    """queries (Q,) int32; node_keys (N,) int32 sorted; params (N,) float32."""
    r = jnp.searchsorted(node_keys, queries, side="right").astype(jnp.int32)
    j = jnp.maximum(r - 1, 0)
    mid = y1[j] + m[j] * (queries.astype(jnp.float32) - x1[j])
    lo = jnp.floor(mid - delta[j]).astype(jnp.int32)
    hi = jnp.ceil(mid + delta[j]).astype(jnp.int32)
    return lo, jnp.maximum(hi, lo + 1)


def segmented_step_lookup_ref(queries, seg_keys, seg_pos_lo, seg_pos_hi):
    """Row-wise variant: query i searches its own segment seg_keys[i] (S,)."""
    cmp = (seg_keys <= queries[:, None]).astype(jnp.int32)
    r = cmp.sum(axis=1)
    i = jnp.maximum(r - 1, 0)
    take = jnp.take_along_axis
    lo = take(seg_pos_lo, i[:, None], axis=1)[:, 0]
    hi = take(seg_pos_hi, i[:, None], axis=1)[:, 0]
    return lo, hi
