"""Public jit'd wrappers for the index-lookup kernels.

``lookup_step_layer`` / ``lookup_band_layer`` pad inputs to kernel tiling,
dispatch the single-call kernel when the layer fits VMEM, and otherwise use
the two-level scheme (sampled-grid search → per-query segment gather →
segmented kernel).  ``traverse_index`` chains layers top-down — the batched
Alg. 1.

Arrays are int32 keys / int32 positions (band params float32); conversion
from the numpy ``IndexDesign`` is in :func:`device_arrays_from_design`.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from . import kernel as K
from . import ref

MAX_VMEM_ENTRIES = 4096  # single-call kernels keep the whole layer in VMEM


def _pad_to(x, mult, fill):
    n = x.shape[-1]
    pad = (-n) % mult
    if pad == 0:
        return x
    return jnp.concatenate([x, jnp.full(x.shape[:-1] + (pad,), fill, x.dtype)],
                           axis=-1)


def _pad_queries(q):
    padded = _pad_to(q, K.BLOCK_Q, q[-1])
    return padded, q.shape[0]


def lookup_step_layer(queries, piece_keys, piece_pos, *, interpret=True,
                      use_ref=False):
    """Batched step-layer lookup.

    queries (Q,) int32; piece_keys (P,) int32 sorted; piece_pos (P+1,) int32.
    Returns (lo, hi) int32 arrays of shape (Q,).
    """
    pos_lo, pos_hi = piece_pos[:-1], piece_pos[1:]
    if use_ref:
        return ref.step_lookup_ref(queries, piece_keys, pos_lo, pos_hi)
    P = piece_keys.shape[0]
    q, nq = _pad_queries(queries)
    if P <= MAX_VMEM_ENTRIES:
        keys = _pad_to(piece_keys, K.LANE, K.KEY_PAD)
        lo = _pad_to(pos_lo, K.LANE, pos_lo[-1])
        hi = _pad_to(pos_hi, K.LANE, pos_hi[-1])
        out_lo, out_hi = K.step_lookup_pallas(q, keys, lo, hi,
                                              interpret=interpret)
        return out_lo[:nq], out_hi[:nq]
    # two-level: search a sampled grid, then the owning segment per query
    S = K.LANE
    n_seg = -(-P // S)
    seg_first = piece_keys[::S]                       # (n_seg,) grid keys
    g = jnp.searchsorted(seg_first, queries, side="right") - 1
    g = jnp.maximum(g, 0)
    # gather each query's segment (host-side XLA gather, then kernel search)
    base = g * S
    idx = base[:, None] + jnp.arange(S)[None, :]
    idx = jnp.minimum(idx, P - 1)
    seg_keys = piece_keys[idx]
    seg_lo = pos_lo[idx]
    seg_hi = pos_hi[idx]
    qp, nq = _pad_queries(queries)
    padq = qp.shape[0] - nq
    if padq:
        seg_keys = jnp.concatenate([seg_keys, jnp.tile(seg_keys[-1:], (padq, 1))])
        seg_lo = jnp.concatenate([seg_lo, jnp.tile(seg_lo[-1:], (padq, 1))])
        seg_hi = jnp.concatenate([seg_hi, jnp.tile(seg_hi[-1:], (padq, 1))])
    out_lo, out_hi = K.segmented_step_lookup_pallas(
        qp, seg_keys, seg_lo, seg_hi, interpret=interpret)
    return out_lo[:nq], out_hi[:nq]


def lookup_band_layer(queries, node_keys, x1, y1, m, delta, *, interpret=True,
                      use_ref=False):
    """Batched band-layer lookup → (lo, hi) int32 of shape (Q,)."""
    if use_ref:
        return ref.band_lookup_ref(queries, node_keys, x1, y1, m, delta)
    P = node_keys.shape[0]
    assert P <= MAX_VMEM_ENTRIES, "band layers are tuned small; got %d" % P
    q, nq = _pad_queries(queries)
    keys = _pad_to(node_keys, K.LANE, K.KEY_PAD)
    pads = [_pad_to(a, K.LANE, 0.0) for a in (x1, y1, m, delta)]
    out_lo, out_hi = K.band_lookup_pallas(q, keys, *pads, interpret=interpret)
    return out_lo[:nq], out_hi[:nq]


def device_arrays_from_design(design) -> list[dict]:
    """Convert a numpy IndexDesign into kernel-ready int32/f32 arrays.

    Requires keys and positions to fit int32 (serving-scale page tables and
    sample indexes do; SOSD-scale benchmarks use the numpy path).
    """
    layers = []
    for layer in design.layers:
        if layer.kind == "step":
            assert layer.piece_keys.max() < 2**31 and layer.piece_pos.max() < 2**31
            layers.append(dict(
                kind="step",
                piece_keys=jnp.asarray(layer.piece_keys, jnp.int32),
                piece_pos=jnp.asarray(layer.piece_pos, jnp.int32),
            ))
        else:
            assert layer.node_keys.max() < 2**31
            # widen δ by the worst-case f32 rounding of mid = y1 + m·(q−x1):
            # a few ULP of |y1| plus key-quantization error |m|·ULP(x1)
            slack = (8.0 + np.abs(layer.y1) * 4e-6
                     + np.abs(layer.m) * np.abs(layer.x1.astype(np.float64))
                     * 4e-6)
            layers.append(dict(
                kind="band",
                node_keys=jnp.asarray(layer.node_keys, jnp.int32),
                x1=jnp.asarray(layer.x1, jnp.float32),
                y1=jnp.asarray(layer.y1, jnp.float32),
                m=jnp.asarray(layer.m, jnp.float32),
                delta=jnp.asarray(layer.delta + slack, jnp.float32),
            ))
    return layers


def traverse_index(layers: list[dict], queries, *, interpret=True,
                   use_ref=False):
    """Batched Alg. 1 over kernel-ready layers (top-down) → final (lo, hi)."""
    lo = hi = None
    for layer in reversed(layers):
        if layer["kind"] == "step":
            lo, hi = lookup_step_layer(queries, layer["piece_keys"],
                                       layer["piece_pos"],
                                       interpret=interpret, use_ref=use_ref)
        else:
            lo, hi = lookup_band_layer(queries, layer["node_keys"],
                                       layer["x1"], layer["y1"], layer["m"],
                                       layer["delta"],
                                       interpret=interpret, use_ref=use_ref)
    return lo, hi
