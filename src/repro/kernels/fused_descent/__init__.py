from .ops import (MAX_VMEM_ENTRIES, band_f32_slack, fused_descent,
                  fused_descent_with_backend, pack_prefix)

__all__ = ["MAX_VMEM_ENTRIES", "band_f32_slack", "fused_descent",
           "fused_descent_with_backend", "pack_prefix"]
