"""Public dispatch for the fused multi-layer descent (Pallas → jnp → numpy).

``fused_descent`` is what the serving engine calls per batch: one op walks
the queries through the whole resident layer prefix and returns the (L, Q)
per-layer windows.  The numpy backend *is*
:func:`repro.core.descent.descend_layers` — bit-identical to the per-layer
path for every registered family.  The device backends compute in
int32/float32: step rows stay exact, band rows are widened by the δ slack
of :mod:`repro.kernels.index_lookup` (ranges remain valid under Eq. 1 but
may be strictly wider), mirroring the engine's previous ``use_device``
semantics.  Backend failures degrade down the chain like
``candidate_score`` — a container without jax always lands on numpy.
"""
from __future__ import annotations

import numpy as np

from . import ref

MAX_VMEM_ENTRIES = 4096  # the fused kernel keeps one layer plane in VMEM
# numpy twins of kernel.LANE / kernel.KEY_PAD so packing never imports jax
LANE = 128
KEY_PAD = np.iinfo(np.int32).max
# device backends index with int32; KEY_PAD must stay strictly greater than
# every real key AND every query, hence the -1
_I32_LIM = 2**31 - 1


def band_f32_slack(y1, m, x1) -> np.ndarray:
    """Worst-case f32 rounding of ``mid = y1 + m·(q − x1)``: a few ULP of
    |y1| plus key-quantization error |m|·ULP(x1) (same widening as
    ``index_lookup.ops.device_arrays_from_design``)."""
    return (8.0 + np.abs(np.asarray(y1, dtype=np.float64)) * 4e-6
            + np.abs(np.asarray(m, dtype=np.float64))
            * np.abs(np.asarray(x1, dtype=np.float64)) * 4e-6)


def _pad_up(n: int, mult: int) -> int:
    return n + (-n) % mult


def pack_prefix(layers) -> dict | None:
    """Pack a top-down resident prefix (parsed layer dicts, the
    :class:`repro.serve.IndexService` representation) into the fused
    kernel's (L, P) planes.

    Returns None when the prefix is empty, any layer overflows int32, or
    the common padded width exceeds the VMEM bound — callers then serve on
    the numpy path, exactly like the per-layer device gating did.
    Pure numpy: packing works without jax; only dispatch needs it.
    """
    L = len(layers)
    if L == 0:
        return None
    widths = [len(lay["keys"] if lay["kind"] == "step" else lay["x1"])
              for lay in layers]
    P = _pad_up(max(widths), LANE)
    if P > MAX_VMEM_ENTRIES:
        return None
    kinds = np.zeros(L, dtype=np.int32)
    keys = np.full((L, P), KEY_PAD, dtype=np.int32)
    pos_lo = np.zeros((L, P), dtype=np.int32)
    pos_hi = np.zeros((L, P), dtype=np.int32)
    x1 = np.zeros((L, P), dtype=np.float32)
    y1 = np.zeros((L, P), dtype=np.float32)
    m = np.zeros((L, P), dtype=np.float32)
    delta = np.zeros((L, P), dtype=np.float32)
    for l, lay in enumerate(layers):
        n = widths[l]
        if lay["kind"] == "step":
            if (int(lay["keys"].max(initial=0)) >= _I32_LIM
                    or int(lay["pos_hi"].max(initial=0)) >= _I32_LIM):
                return None
            keys[l, :n] = lay["keys"]
            pos_lo[l, :n] = lay["pos_lo"]
            pos_hi[l, :n] = lay["pos_hi"]
        else:
            if int(lay["x1"].max(initial=0)) >= _I32_LIM:
                return None
            kinds[l] = 1
            keys[l, :n] = lay["x1"]
            x1[l, :n] = lay["x1"].astype(np.float32)
            y1[l, :n] = np.asarray(lay["y1"], dtype=np.float32)
            m[l, :n] = np.asarray(lay["m"], dtype=np.float32)
            delta[l, :n] = (np.asarray(lay["delta"], dtype=np.float64)
                            + band_f32_slack(lay["y1"], lay["m"],
                                             lay["x1"])).astype(np.float32)
    return {"kinds": kinds, "keys": keys, "pos_lo": pos_lo, "pos_hi": pos_hi,
            "x1": x1, "y1": y1, "m": m, "delta": delta}


def _device_descent(planes: dict, q: np.ndarray, backend: str,
                    interpret: bool):
    """One device dispatch over packed planes → float64 (L, Q) rows."""
    import jax.numpy as jnp

    from . import kernel as K

    qi = jnp.asarray(q.astype(np.int64), jnp.int32)
    if backend == "jnp":
        lo, hi = ref.fused_descent_jnp(planes, qi)
    elif backend == "pallas":
        nq = qi.shape[0]
        pad = (-nq) % K.BLOCK_Q
        if pad:
            qi = jnp.concatenate([qi, jnp.full((pad,), qi[-1], qi.dtype)])
        jplanes = [jnp.asarray(planes[k]) for k in
                   ("kinds", "keys", "pos_lo", "pos_hi", "x1", "y1", "m",
                    "delta")]
        lo, hi = K.fused_descent_pallas(qi, *jplanes, interpret=interpret)
        lo, hi = lo[:, :nq], hi[:, :nq]
    else:
        raise ValueError(f"unknown device backend {backend!r}")
    return (np.asarray(lo, dtype=np.float64),
            np.asarray(hi, dtype=np.float64))


def fused_descent_with_backend(layers, queries, *, backend: str = "pallas",
                               interpret: bool = True, packed=None):
    """Like :func:`fused_descent` but also reports the backend that
    actually served: ``(lo, hi, backend_used)`` — the engine attributes
    ``device_batches`` from it."""
    q = np.atleast_1d(np.asarray(queries, dtype=np.uint64))
    if backend != "numpy":
        if packed is None:
            packed = pack_prefix(layers)
        if (packed is not None and len(q)
                and int(q.max(initial=0)) < _I32_LIM):
            chain = ("pallas", "jnp") if backend == "pallas" else (backend,)
            for b in chain:
                try:
                    lo, hi = _device_descent(packed, q, b, interpret)
                except Exception:   # missing jax / kernel failure: degrade
                    continue
                return lo, hi, b
    lo, hi = ref.fused_descent_ref(layers, q)
    return lo, hi, "numpy"


def fused_descent(layers, queries, *, backend: str = "pallas",
                  interpret: bool = True, packed=None):
    """Walk ``queries`` through a resident prefix in one fused dispatch →
    ``(lo, hi)`` float64 arrays of shape (L, Q), row ``l`` = layer ``l``'s
    window per query (top-down; row L−1 feeds the disk walk).

    Fallback order: requested device backend (Pallas, then jnp) → numpy.
    ``backend="numpy"`` (and every chain exhaustion) is bit-identical to
    the per-layer :func:`repro.core.descent.descend_layers` walk; device
    backends keep step rows exact and widen band rows by the f32 δ slack.
    ``packed`` lets long-lived callers reuse one :func:`pack_prefix`
    result across batches.
    """
    lo, hi, _ = fused_descent_with_backend(layers, queries, backend=backend,
                                           interpret=interpret, packed=packed)
    return lo, hi
