"""Pallas TPU kernel: fused multi-layer index descent (whole Alg. 1 prefix).

The per-layer ``index_lookup`` kernels pay one dispatch per resident layer;
this kernel walks a batch of keys through the *entire* resident prefix in a
single ``pallas_call``.  The trick is the structural fact exploited by
:func:`repro.core.descent.descend_layers`: every index layer covers the full
key domain, so layer ``l``'s prediction is a function of the query key alone
— the (L, Q) prediction rows are independent and can be evaluated by one
fused grid instead of L chained dispatches.

Grid ``(n_q_blocks, L)`` — the layer dimension is innermost, and TPU grids
are executed sequentially per core, so the Pallas pipeline double-buffers
the per-layer parameter planes (the ``flash_attention`` idiom: while layer
``l`` computes, layer ``l+1``'s (1, P) plane tiles are already streaming
into the second VMEM buffer).  The query block is cast to f32 once into a
VMEM scratch that persists across the layer iterations of one query cell.

Per-layer branching is data-driven: a per-layer function-type vector
``kinds`` (0 = step, 1 = band) selects between the two prediction forms
with a ``jnp.where`` — both are computed densely (compare-count rank +
one-hot masked row-sums, the TPU-native formulation of ``index_lookup``),
which keeps the kernel free of data-dependent control flow.

Plane layout (packed by ``ops.pack_prefix``, one row per layer, padded to a
common LANE-multiple width P):

  kinds            (L,)    int32   0 step / 1 band
  keys             (L, P)  int32   partition keys (KEY_PAD beyond the layer)
  pos_lo, pos_hi   (L, P)  int32   step piece ranges      (zeros on band rows)
  x1, y1, m, delta (L, P)  f32     band line params, δ pre-widened by the
                                   f32 slack                (zeros on step rows)

Outputs are (L, Q) int32 ``lo``/``hi``: row ``l`` is layer ``l``'s window
for every query; row ``L-1`` feeds the on-disk walk.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK_Q = 256
LANE = 128
KEY_PAD = jnp.iinfo(jnp.int32).max  # padding key: never ≤ any query


def _rank(keys, q):
    """#{keys ≤ q} per query; keys (P,), q (Bq,) → (Bq,) int32."""
    cmp = (keys[None, :] <= q[:, None]).astype(jnp.int32)   # (Bq, P)
    return cmp.sum(axis=1)


def _gather(values, idx, P):
    """Exact gather via one-hot masked row-sum; values (P,), idx (Bq,)."""
    onehot = (jax.lax.broadcasted_iota(jnp.int32, (idx.shape[0], P), 1)
              == idx[:, None])
    zero = values.dtype.type(0)
    return jnp.sum(jnp.where(onehot, values[None, :], zero), axis=1)


def _fused_kernel(kind_ref, q_ref, keys_ref, pos_lo_ref, pos_hi_ref,
                  x1_ref, y1_ref, m_ref, d_ref, lo_ref, hi_ref, qf_ref):
    l = pl.program_id(1)
    q = q_ref[...]                              # (Bq,) int32

    @pl.when(l == 0)
    def _stage_queries():                       # f32 cast once per q-cell;
        qf_ref[...] = q.astype(jnp.float32)     # reused by every band layer

    keys = keys_ref[0]                          # (P,) this layer's plane
    P = keys.shape[0]
    i = jnp.maximum(_rank(keys, q) - 1, 0)      # covering partition per query

    # step form: piece i predicts [pos_lo[i], pos_hi[i])
    slo = _gather(pos_lo_ref[0], i, P)
    shi = _gather(pos_hi_ref[0], i, P)

    # band form: node i's line, evaluated at the (pre-staged) f32 query
    x1 = _gather(x1_ref[0], i, P)
    y1 = _gather(y1_ref[0], i, P)
    m = _gather(m_ref[0], i, P)
    d = _gather(d_ref[0], i, P)
    mid = y1 + m * (qf_ref[...] - x1)
    blo = jnp.floor(mid - d).astype(jnp.int32)
    bhi = jnp.maximum(jnp.ceil(mid + d).astype(jnp.int32), blo + 1)

    is_band = kind_ref[0] == 1
    lo_ref[0] = jnp.where(is_band, blo, slo)
    hi_ref[0] = jnp.where(is_band, bhi, shi)


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_descent_pallas(queries, kinds, keys, pos_lo, pos_hi, x1, y1, m,
                         delta, *, interpret=True):
    """queries (Q,) int32, Q multiple of BLOCK_Q; planes (L, P), P multiple
    of LANE → (lo, hi) int32 of shape (L, Q)."""
    Q = queries.shape[0]
    L, P = keys.shape
    assert Q % BLOCK_Q == 0 and P % LANE == 0 and L >= 1
    grid = (Q // BLOCK_Q, L)      # layer innermost: planes double-buffer
    qspec = pl.BlockSpec((BLOCK_Q,), lambda iq, l: (iq,))
    kspec = pl.BlockSpec((1,), lambda iq, l: (l,))
    pspec = pl.BlockSpec((1, P), lambda iq, l: (l, 0))
    ospec = pl.BlockSpec((1, BLOCK_Q), lambda iq, l: (l, iq))
    return pl.pallas_call(
        _fused_kernel,
        grid=grid,
        in_specs=[kspec, qspec] + [pspec] * 7,
        out_specs=[ospec, ospec],
        out_shape=[jax.ShapeDtypeStruct((L, Q), jnp.int32)] * 2,
        scratch_shapes=[pltpu.VMEM((BLOCK_Q,), jnp.float32)],  # staged q f32
        interpret=interpret,
    )(kinds, queries, keys, pos_lo, pos_hi, x1, y1, m, delta)
