"""References for the fused descent kernel.

Two oracles with different contracts:

  * :func:`fused_descent_ref` — the float64 ground truth.  Delegates to
    :func:`repro.core.descent.descend_layers`, i.e. literally the per-layer
    path the serving engine used before fusion; every numpy-backend result
    of ``ops.fused_descent`` must be bit-identical to it.
  * :func:`fused_descent_jnp` — pure-jnp f32 oracle over the *packed*
    planes, mirroring the kernel's semantics (int32 keys, f32 band math on
    the slack-widened δ, per-layer ``hi ≥ lo+1`` on band rows).  This is
    both the kernel's test oracle and the middle link of the
    Pallas → jnp → numpy fallback chain; it may differ from the kernel by
    a few ULP of the f32 band midpoint (FMA contraction), never more.
"""
from __future__ import annotations

import numpy as np

from repro.core.descent import descend_layers


def fused_descent_ref(layers, queries: np.ndarray):
    """Float64 (L, Q) lo/hi rows — the bit-exactness reference."""
    return descend_layers(layers, np.asarray(queries, dtype=np.uint64))


def fused_descent_jnp(planes: dict, queries):
    """jnp f32 oracle over packed planes → (lo, hi) int32 of shape (L, Q).

    ``planes`` is the dict built by ``ops.pack_prefix`` (numpy or jnp
    arrays); ``queries`` int32, in-range per the packer's guards.
    """
    import jax.numpy as jnp

    q = jnp.asarray(queries, jnp.int32)
    qf = q.astype(jnp.float32)
    kinds = np.asarray(planes["kinds"])
    keys = jnp.asarray(planes["keys"])
    los, his = [], []
    for l in range(keys.shape[0]):
        # rank − 1 == searchsorted-right − 1: the covering partition
        i = jnp.clip(jnp.searchsorted(keys[l], q, side="right") - 1, 0, None)
        if kinds[l] == 1:
            x1 = jnp.asarray(planes["x1"])[l][i]
            y1 = jnp.asarray(planes["y1"])[l][i]
            m = jnp.asarray(planes["m"])[l][i]
            d = jnp.asarray(planes["delta"])[l][i]
            mid = y1 + m * (qf - x1)
            lo = jnp.floor(mid - d).astype(jnp.int32)
            hi = jnp.maximum(jnp.ceil(mid + d).astype(jnp.int32), lo + 1)
        else:
            lo = jnp.asarray(planes["pos_lo"])[l][i]
            hi = jnp.asarray(planes["pos_hi"])[l][i]
        los.append(lo)
        his.append(hi)
    return jnp.stack(los), jnp.stack(his)
