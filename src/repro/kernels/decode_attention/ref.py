"""Pure-jnp oracle for flash-decode (single-token attention over a cache).

Returns the *partial-softmax triple* ``(o, m, l)`` so the result can be
combined across sequence shards:

    o — Σ_j exp(s_j − m)·v_j / l     (locally normalized output)
    m — local running max
    l — local normalizer Σ_j exp(s_j − m)

Combination across shards i (ref for the shard_map flash-decode path):

    M = max_i m_i;  L = Σ_i l_i·exp(m_i − M);  O = Σ_i o_i·l_i·exp(m_i − M)/L
"""
from __future__ import annotations

import jax.numpy as jnp


def decode_attention_ref(q, k, v, kv_length=None, scale=None):
    """q (B,Hq,D); k/v (B,Hkv,S,D); kv_length (B,) → (o (B,Hq,D), m, l (B,Hq))."""
    B, Hq, D = q.shape
    _, Hkv, S, _ = k.shape
    group = Hq // Hkv
    scale = scale if scale is not None else 1.0 / jnp.sqrt(D)
    qf = q.astype(jnp.float32) * scale
    kf = jnp.repeat(k.astype(jnp.float32), group, axis=1)   # (B,Hq,S,D)
    vf = jnp.repeat(v.astype(jnp.float32), group, axis=1)
    s = jnp.einsum("bhd,bhsd->bhs", qf, kf)
    if kv_length is not None:
        mask = jnp.arange(S)[None, None, :] < kv_length[:, None, None]
        s = jnp.where(mask, s, -1e30)
    m = s.max(axis=-1)
    p = jnp.exp(s - m[..., None])
    l = p.sum(axis=-1)
    o = jnp.einsum("bhs,bhsd->bhd", p, vf) / jnp.maximum(l, 1e-30)[..., None]
    return o, m, l


def combine_partials_ref(os, ms, ls):
    """Combine per-shard (o, m, l) triples along a leading shard axis."""
    M = ms.max(axis=0)
    w = ls * jnp.exp(ms - M[None])
    L = w.sum(axis=0)
    O = (os * w[..., None]).sum(axis=0) / jnp.maximum(L, 1e-30)[..., None]
    return O, M, L
