from .ops import decode_attention, combine_partials
from . import ref
