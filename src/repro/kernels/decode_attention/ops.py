"""Public wrapper for flash-decode: GQA regrouping + shard combination."""
from __future__ import annotations

import jax.numpy as jnp

from . import kernel as K
from . import ref


def decode_attention(q, k, v, kv_length=None, *, scale=None, block_k=128,
                     interpret=True, use_ref=False):
    """q (B,Hq,D); k/v (B,Hkv,S,D) → partial triple (o, m, l).

    GQA is handled by folding the kv-head axis into the batch: each
    (batch, kv_head) pair becomes one kernel batch row whose Hq′ = group
    query heads attend to that single kv head.
    """
    B, Hq, D = q.shape
    _, Hkv, S, _ = k.shape
    if kv_length is None:
        kv_length = jnp.full((B,), S, jnp.int32)
    if use_ref:
        return ref.decode_attention_ref(q, k, v, kv_length, scale=scale)
    group = Hq // Hkv
    # fold kv heads into batch: q (B·Hkv, group, D); k/v (B·Hkv, 1, S, D) —
    # the kernel then always pairs one kv head with its group of q heads
    qg = q.reshape(B, Hkv, group, D).reshape(B * Hkv, group, D)
    kg = k.reshape(B * Hkv, 1, S, D)
    vg = v.reshape(B * Hkv, 1, S, D)
    lg = jnp.repeat(kv_length, Hkv)
    o, m, l = K.decode_attention_pallas(
        qg, kg, vg, lg, scale=scale, block_k=block_k, interpret=interpret)
    return (o.reshape(B, Hq, D), m.reshape(B, Hq), l.reshape(B, Hq))


def combine_partials(os, ms, ls):
    """Combine per-shard partial triples stacked on axis 0 (ref math)."""
    return ref.combine_partials_ref(os, ms, ls)
