"""Pallas TPU flash-decode: one new token vs a long KV cache.

Decode attention is memory-bound (the whole KV cache streams through once
per token), so the kernel's job is to keep that stream dense: grid
``(B, n_kv_blocks)``; per batch element all query heads are processed at
once against each (BLOCK_K, D) cache tile, with running (m, l, acc)
accumulators in VMEM scratch.

Emits the partial-softmax triple (o, m, l) — the same contract as ref.py —
so a shard_map over a sequence-sharded cache can psum-combine shards
(flash-decoding across chips; see serve/attention.py).

Per-sequence valid lengths arrive via scalar prefetch (SMEM) so tiles
beyond a sequence's length are skipped without streaming them.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(len_ref,                      # scalar prefetch (SMEM)
                   q_ref, k_ref, v_ref,          # VMEM blocks
                   o_ref, m_out_ref, l_out_ref,  # outputs
                   m_ref, l_ref, acc_ref,        # scratch
                   *, scale, block_k, n_kv_blocks):
    b = pl.program_id(0)
    ik = pl.program_id(1)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[b]
    live = ik * block_k < length

    @pl.when(live)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale        # (Hq, D)
        k = k_ref[0, 0].astype(jnp.float32)             # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (Hq, bk)
        k_pos = ik * block_k + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        s = jnp.where(k_pos < length, s, NEG_INF)
        m_prev = m_ref[...]                             # (Hq, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_ref[...] = m_new

    @pl.when(ik == n_kv_blocks - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)
        m_out_ref[0] = m_ref[...][:, 0]
        l_out_ref[0] = l_ref[...][:, 0]


@functools.partial(jax.jit,
                   static_argnames=("scale", "block_k", "interpret"))
def decode_attention_pallas(q, k, v, kv_length, *, scale=None, block_k=128,
                            interpret=True):
    """q (B,Hq,D); k/v (B,1,S,D); kv_length (B,) int32.

    ops.py folds GQA/MHA kv heads into the batch axis, so every kernel
    batch row pairs one kv head with its group of query heads (Hkv ≡ 1).
    Returns (o (B,Hq,D) f32, m (B,Hq) f32, l (B,Hq) f32).
    """
    B, Hq, D = q.shape
    _, Hkv, S, _ = k.shape
    assert S % block_k == 0
    assert Hkv == 1, "ops.py folds kv heads into batch"
    scale = scale if scale is not None else 1.0 / (D ** 0.5)
    n_kv = S // block_k
    grid = (B, n_kv)
    kernel = functools.partial(_decode_kernel, scale=scale, block_k=block_k,
                               n_kv_blocks=n_kv)
    # index maps receive (grid indices..., scalar_ref) under scalar prefetch
    kmap = (lambda b, ik, lens: (b, 0, ik, 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Hq, D), lambda b, ik, lens: (b, 0, 0)),
            pl.BlockSpec((1, 1, block_k, D), kmap),
            pl.BlockSpec((1, 1, block_k, D), kmap),
        ],
        out_specs=[
            pl.BlockSpec((1, Hq, D), lambda b, ik, lens: (b, 0, 0)),
            pl.BlockSpec((1, Hq), lambda b, ik, lens: (b, 0)),
            pl.BlockSpec((1, Hq), lambda b, ik, lens: (b, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((Hq, 1), jnp.float32),
            pltpu.VMEM((Hq, 1), jnp.float32),
            pltpu.VMEM((Hq, D), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, Hq, D), jnp.float32),
            jax.ShapeDtypeStruct((B, Hq), jnp.float32),
            jax.ShapeDtypeStruct((B, Hq), jnp.float32),
        ],
        interpret=interpret,
    )(kv_length.astype(jnp.int32), q, k, v)
