"""Pallas TPU kernels for the framework's compute hot spots.

  index_lookup     — batched hierarchical index lookup (the paper's Alg. 1
                     adapted to the MXU: compare-count ranks + one-hot
                     gathers instead of pointer-chase binary search)
  fused_descent    — the whole resident layer prefix in ONE kernel: a
                     (queries, layers) grid walks every query through all
                     pinned layers, per-layer step/band branching selected
                     by a kind vector, parameter planes double-buffered
                     through VMEM by the grid pipeline (serving hot path)
  flash_attention  — causal blockwise attention (GQA, sliding window,
                     logit softcap) for train/prefill
  decode_attention — flash-decode: one-token attention over a long KV
                     cache with partial-softmax accumulation (composes
                     with sequence-sharded KV via shard_map)

Each kernel ships kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd
public wrapper) and ref.py (pure-jnp oracle).  On this CPU container the
kernels are validated with ``interpret=True``; on TPU the same code paths
compile natively.
"""
