"""Public wrapper: pad to block multiples, dispatch kernel or oracle."""
from __future__ import annotations

import jax.numpy as jnp

from . import kernel as K
from . import ref


def flash_attention(q, k, v, *, causal=True, window=None, softcap=None,
                    scale=None, block_q=128, block_k=128, interpret=True,
                    use_ref=False):
    """Flash attention with GQA/sliding-window/softcap.

    q (B,Hq,Sq,D), k/v (B,Hkv,Skv,D).  Pads Sq/Skv up to block multiples;
    padded kv columns are masked out via an effective causal bound (padding
    appends *future* positions, which causal masking already excludes).
    """
    if use_ref:
        return ref.attention_ref(q, k, v, causal=causal, window=window,
                                 softcap=softcap, scale=scale)
    B, Hq, Sq, D = q.shape
    Skv = k.shape[2]
    pq = (-Sq) % block_q
    pk = (-Skv) % block_k
    assert causal or pk == 0, "non-causal padding needs explicit kv masking"
    if pq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
    # original query i keeps its true position Skv − Sq + i; padded queries
    # land after it and padded kv is excluded by the causal bound
    out = K.flash_attention_pallas(
        q, k, v, causal=causal, window=window, softcap=softcap, scale=scale,
        block_q=block_q, block_k=block_k, q_offset=Skv - Sq,
        interpret=interpret)
    return out[:, :, :Sq] if pq else out
