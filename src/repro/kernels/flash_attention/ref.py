"""Pure-jnp oracle for blockwise (flash) attention.

Supports the attention variants the architecture pool needs:
  * causal masking,
  * GQA (q_heads a multiple of kv_heads),
  * sliding-window (local) attention — gemma2's alternating local layers,
  * logit softcapping — gemma2,
  * explicit kv length masking (padded caches).
"""
from __future__ import annotations

import jax.numpy as jnp


def attention_ref(q, k, v, *, causal=True, window=None, softcap=None,
                  kv_length=None, scale=None):
    """q (B, Hq, Sq, D); k/v (B, Hkv, Skv, D) → (B, Hq, Sq, D) float32.

    ``window``: keys attendable iff q_pos − window < k_pos ≤ q_pos.
    ``kv_length``: (B,) valid kv prefix lengths.
    Query positions are aligned to the *end* of the kv sequence
    (q_pos = Skv − Sq + i), matching decode/prefill-with-cache semantics.
    """
    B, Hq, Sq, D = q.shape
    _, Hkv, Skv, _ = k.shape
    group = Hq // Hkv
    scale = scale if scale is not None else 1.0 / jnp.sqrt(D)
    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    kf = jnp.repeat(kf, group, axis=1)
    vf = jnp.repeat(vf, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", qf, kf)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    q_pos = Skv - Sq + jnp.arange(Sq)
    k_pos = jnp.arange(Skv)
    mask = jnp.ones((Sq, Skv), dtype=bool)
    if causal:
        mask &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        mask &= k_pos[None, :] > q_pos[:, None] - window
    mask = jnp.broadcast_to(mask[None, None], s.shape)
    if kv_length is not None:
        lmask = k_pos[None, :] < kv_length[:, None]          # (B, Skv)
        mask &= lmask[:, None, None, :]
    s = jnp.where(mask, s, -1e30)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vf)
