"""Pallas TPU flash attention (causal, GQA, sliding window, softcap).

Blockwise online-softmax attention.  Grid ``(B, Hq, n_q_blocks,
n_kv_blocks)`` — the kv-block dimension is innermost, and TPU grids are
executed sequentially per core, so VMEM scratch accumulators (running max
``m``, normalizer ``l``, output ``acc``) persist across kv iterations of
one (b, h, iq) cell.

BlockSpecs:
  q/out: (1, 1, BLOCK_Q, D)  at (b, h, iq, 0)
  k/v:   (1, 1, BLOCK_K, D)  at (b, h·Hkv//Hq, ik, 0)  ← GQA via index map

Out-of-range blocks (fully masked by causality/window) are skipped with
``pl.when`` — logits are never computed for them, though their tiles are
still streamed in by the fixed grid (a known cost of dense grids; the
§Perf log discusses the skip-map optimization for TPU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                 scale, causal, window, softcap, q_offset, block_q, block_k,
                 n_kv_blocks):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_pos = q_offset + iq * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    k_pos = ik * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    # block-level skip: any (q, k) pair in this tile attendable?
    lo_q = q_offset + iq * block_q
    hi_q = lo_q + block_q - 1
    lo_k = ik * block_k
    hi_k = lo_k + block_k - 1
    live = True
    if causal:
        live = jnp.logical_and(live, lo_k <= hi_q)
    if window is not None:
        live = jnp.logical_and(live, hi_k > lo_q - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale       # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)               # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bk)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        mask = jnp.ones_like(s, dtype=bool)
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]                               # (bq, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_ref[...] = m_new

    @pl.when(ik == n_kv_blocks - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "scale", "block_q",
                     "block_k", "q_offset", "interpret"))
def flash_attention_pallas(q, k, v, *, causal=True, window=None, softcap=None,
                           scale=None, block_q=128, block_k=128,
                           q_offset=None, interpret=True):
    """q (B,Hq,Sq,D), k/v (B,Hkv,Skv,D) → (B,Hq,Sq,D), q's dtype.

    Sq/Skv must be multiples of the block sizes (ops.py pads).  Query i sits
    at position ``q_offset + i`` (default: aligned to the end of kv —
    prefill-with-cache semantics).
    """
    B, Hq, Sq, D = q.shape
    _, Hkv, Skv, _ = k.shape
    assert Sq % block_q == 0 and Skv % block_k == 0 and Hq % Hkv == 0
    scale = scale if scale is not None else 1.0 / (D ** 0.5)
    n_q = Sq // block_q
    n_kv = Skv // block_k
    grid = (B, Hq, n_q, n_kv)
    group = Hq // Hkv
    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap,
        q_offset=(Skv - Sq) if q_offset is None else q_offset,
        block_q=block_q, block_k=block_k, n_kv_blocks=n_kv)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, iq, ik: (b, h // group, ik, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, iq, ik: (b, h // group, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max m
            pltpu.VMEM((block_q, 1), jnp.float32),   # normalizer l
            pltpu.VMEM((block_q, D), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
