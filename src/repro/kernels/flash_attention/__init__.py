from .ops import flash_attention
from . import ref
