"""Numpy reference for the batched affine candidate scorer."""
from __future__ import annotations

import numpy as np


def affine_scores_ref(widths, weights, ell: float, inv_bw: float) -> np.ndarray:
    """Weighted row means of ``ell + widths·inv_bw`` → (C,) float64.

    Float64 oracle for the device backends.  (The *search* default does
    not go through here — it applies the profile directly via
    ``repro.core.latency.batched_mean_read_costs``, which divides by B
    exactly as the scalar path does; this closed form multiplies by the
    precomputed 1/B and is for ranking only.)
    """
    t = ell + np.asarray(widths, dtype=np.float64) * inv_bw
    return np.average(t, axis=1,
                      weights=np.asarray(weights, dtype=np.float64))
