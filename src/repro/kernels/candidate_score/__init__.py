"""Batched candidate scoring for the AirTune sweep engine.

Evaluates the Eq. (9) ranking estimate ``Ê[T(Δ)]`` for a whole (C, S)
matrix of candidate widths in one shot.  Backends, in fallback order
Pallas → jnp → numpy (see :func:`ops.candidate_scores`):

  * ``pallas`` — fused affine-profile weighted row-mean kernel
    (interpret mode on CPU, native on TPU),
  * ``jnp``    — jitted XLA reduction,
  * ``numpy``  — :func:`repro.core.latency.batched_mean_read_costs`,
    the bit-exact float64 reference and the search default.

Device paths require an affine-representable tier
(:func:`repro.core.storage.affine_coefficients`); anything else falls
back to numpy.  They compute in float32 and are used for candidate
*ranking* only — exact Eq. (6) costs always take the numpy path.
"""
from .ops import affine_candidate_scores, candidate_scores
from .ref import affine_scores_ref

__all__ = ["affine_candidate_scores", "candidate_scores",
           "affine_scores_ref"]
