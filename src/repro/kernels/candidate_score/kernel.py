"""Pallas TPU kernel: fused affine-profile weighted row means.

One pallas_call scores a block of candidates: the (BLOCK_C, S) widths
tile and the shared (S,) weight vector live in VMEM; the kernel fuses the
affine profile ``T = ℓ + Δ·(1/B)`` with the weighted mean reduction
(multiply + row-sum on the VPU), so each candidate's Ê[T(Δ)] is produced
without materializing the profiled matrix in HBM.

Padding contract (enforced by ops.py): S padded to LANE with zero
weights — padded columns contribute nothing to either the numerator or
the weight total; C padded to BLOCK_C with arbitrary rows — padded rows
are dropped after the call.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_C = 8     # candidate rows per grid step (f32 sublane tile)
LANE = 128


def _score_kernel(w_ref, wt_ref, out_ref, *, ell, inv_bw):
    W = w_ref[...]                    # (BLOCK_C, S) widths
    wt = wt_ref[...]                  # (S,) weights, zero on padding
    t = ell + W * inv_bw              # fused affine profile
    out_ref[...] = (t * wt[None, :]).sum(axis=1) / wt.sum()


@functools.partial(jax.jit, static_argnames=("ell", "inv_bw", "interpret"))
def affine_scores_pallas(widths, weights, *, ell: float, inv_bw: float,
                         interpret: bool = True):
    """widths (C, S) f32 — C multiple of BLOCK_C, S multiple of LANE;
    weights (S,) f32.  Returns (C,) f32 scores."""
    C, S = widths.shape
    assert C % BLOCK_C == 0 and S % LANE == 0
    grid = (C // BLOCK_C,)
    return pl.pallas_call(
        functools.partial(_score_kernel, ell=ell, inv_bw=inv_bw),
        grid=grid,
        in_specs=[pl.BlockSpec((BLOCK_C, S), lambda i: (i, 0)),
                  pl.BlockSpec((S,), lambda i: (0,))],
        out_specs=pl.BlockSpec((BLOCK_C,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((C,), jnp.float32),
        interpret=interpret,
    )(widths, weights)
