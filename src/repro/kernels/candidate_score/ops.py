"""Public dispatch for batched candidate scoring (Pallas → jnp → numpy).

``candidate_scores`` is what the sweep engine calls: it folds the storage
profile into affine coefficients when possible and walks the backend
fallback chain; non-affine profiles and backend failures land on the
bit-exact numpy evaluator.  Device backends compute in float32 — they
rank candidates, they never produce the exact Eq. (6) costs.
"""
from __future__ import annotations

import functools

import numpy as np

from repro.core.latency import batched_mean_read_costs
from repro.core.storage import affine_coefficients

from . import ref


def _pad_to(x, mult, axis):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return np.pad(x, widths)


@functools.lru_cache(maxsize=None)
def _jitted_jnp(ell: float, inv_bw: float):
    import jax
    import jax.numpy as jnp

    @jax.jit
    def score(W, wt):
        t = ell + W * inv_bw
        return (t * wt[None, :]).sum(axis=1) / wt.sum()
    return score


def affine_candidate_scores(widths, weights, ell: float, inv_bw: float, *,
                            backend: str = "numpy",
                            interpret: bool = True) -> np.ndarray:
    """Batched ``Ê[T(Δ)]`` under an affine tier, on the chosen backend."""
    if backend == "numpy":
        return ref.affine_scores_ref(widths, weights, ell, inv_bw)
    import jax.numpy as jnp
    W = np.asarray(widths, dtype=np.float32)
    wt = np.asarray(weights, dtype=np.float32)
    if backend == "jnp":
        out = _jitted_jnp(float(ell), float(inv_bw))(jnp.asarray(W),
                                                     jnp.asarray(wt))
        return np.asarray(out, dtype=np.float64)
    if backend == "pallas":
        from .kernel import BLOCK_C, LANE, affine_scores_pallas
        C = W.shape[0]
        Wp = _pad_to(_pad_to(W, LANE, 1), BLOCK_C, 0)
        wtp = _pad_to(wt, LANE, 0)          # zero-weight padding columns
        out = affine_scores_pallas(jnp.asarray(Wp), jnp.asarray(wtp),
                                   ell=float(ell), inv_bw=float(inv_bw),
                                   interpret=interpret)
        return np.asarray(out, dtype=np.float64)[:C]
    raise ValueError(f"unknown backend {backend!r}")


def candidate_scores(widths, weights, profile, *, backend: str = "pallas",
                     interpret: bool = True) -> np.ndarray:
    """Score a (C, S) widths matrix under ``profile`` → (C,) float64.

    Fallback order: requested device backend (Pallas, then jnp) → numpy.
    Non-affine-representable profiles go straight to numpy — the device
    closed form only exists for ``T(Δ) = ℓ + Δ/B`` tiers.
    """
    if backend != "numpy":
        co = affine_coefficients(profile)
        if co is not None:
            chain = ("pallas", "jnp") if backend == "pallas" else (backend,)
            for b in chain:
                try:
                    return affine_candidate_scores(
                        widths, weights, *co, backend=b, interpret=interpret)
                except Exception:   # missing jax / kernel failure: degrade
                    continue
    return batched_mean_read_costs(widths, weights, profile)
