"""Serving an index under heavy traffic: the facade + batched-engine walkthrough.

1. wraps a 3-layer design over a gmm dataset in the :class:`repro.api.Index`
   facade and saves it *paged* (fixed-size pages = the cache unit) with its
   :class:`repro.api.TuneSpec` recorded in the file meta,
2. reopens the file and serves a skewed query stream through
   :meth:`Index.serve` — the spec's two-tier LRU cache config applies
   automatically,
3. shows what the engine saves: coalesced preads, bytes served from
   cache, warm-vs-cold modeled latency,
4. closes the loop with AirTune: the observed hit rate becomes a
   :class:`repro.core.CachedProfile` and :meth:`Index.retune` re-tunes the
   index *for* the cache (paper Fig. 1: a hotter tier wants a shallower
   index) using the spec the file remembers,
5. pipelines batches through :class:`repro.api.ServeSpec` — a worker
   thread prefetches batch *i+1*'s pages while one fused Pallas kernel
   descends batch *i*'s resident prefix — and reads the
   compute-vs-I/O roofline off ``svc.stats``,
6. closes it end to end: serving on a degraded tier persists ServeStats
   next to the file, :meth:`Index.observe` flags the drift, and a
   warm-started retune (shared ``LayerCache``) searches again for the
   observed profile at a fraction of the cold-search work.

Run:  PYTHONPATH=src python examples/serve_index.py
"""
import os
import sys
import tempfile

import numpy as np

sys.path.insert(0, "src")

from repro.api import Index, PROFILES, ServeSpec, TuneSpec
from repro.core import KeyPositions, expected_latency
from repro.serve.index_service import demo_serving_design
from repro.data.datasets import sosd_like

workdir = tempfile.mkdtemp(prefix="airindex-serve-")
path = os.path.join(workdir, "index.air")
tier = "azure_ssd"

print("== build + save (paged, spec recorded) ==")
keys = sosd_like("gmm", 200_000)
D = KeyPositions.fixed_record(keys, 16)
spec = TuneSpec(page_bytes=4096, cache_bytes=(64 << 10, 1 << 20))
idx = Index.from_design(demo_serving_design(D),   # 3 layers: 2 disk + root
                        spec=spec, profile=tier)
idx.save(path)
print(f"design: {idx.design.describe()}")
print(f"file: {os.path.getsize(path)} B in {spec.page_bytes} B pages; "
      f"layer offsets {[lm.offset for lm in idx.file_meta.layers]}")

print("== serve a skewed stream (hot keys repeat) ==")
rng = np.random.default_rng(0)
reopened = Index.open(path)              # remembers spec + profile
assert reopened.spec == spec
svc = reopened.serve()                   # cache tiers from the spec
hot = rng.choice(D.keys, 512)                      # the working set
for step in range(6):
    qs = np.concatenate([rng.choice(hot, 768), rng.choice(D.keys, 256)])
    ranges = svc.lookup(qs)
    s = svc.stats
    print(f"batch {step}: hit_rate={s.hit_rate:.3f} "
          f"preads={s.preads} bytes_fetched={s.bytes_fetched} "
          f"bytes_from_cache={s.bytes_from_cache}")

print("== what the cache buys (cold vs warm, modeled) ==")
cold = reopened.serve(cache_bytes=(1 << 20,))
base = cold.stats.modeled_seconds
cold.lookup(hot)
cold_s = cold.stats.modeled_seconds - base
warm_base = cold.stats.modeled_seconds
cold.lookup(hot)                                    # same batch, warm
warm_s = cold.stats.modeled_seconds - warm_base
print(f"cold batch: {cold_s * 1e6:.1f}us modeled   "
      f"warm batch: {warm_s * 1e6:.1f}us modeled   "
      f"({cold_s / max(warm_s, 1e-12):.0f}x)")
cold.close()

print("== pipelined batches (ServeSpec: prefetch overlaps descent) ==")
# a deliberately tiny cache so batches miss: the worker thread prefetches
# batch i+1's pages while the fused kernel descends batch i
pipe = reopened.serve(spec=ServeSpec(cache_bytes=(8 << 10,),
                                     pipeline_depth=2, prefetch_layers=2))
batches = [rng.choice(D.keys, 400) for _ in range(4)]
pipe.lookup_batches(batches)
roof = pipe.stats.roofline()
print(f"pipelined {pipe.stats.pipelined_batches} batches, "
      f"{pipe.stats.overlapped_preads} preads overlapped with descent; "
      f"roofline: {roof['bound']}-bound "
      f"(io_fraction={roof['io_fraction']:.2f})")
pipe.close()

print("== re-tune FOR the cache (CachedProfile via Index.retune) ==")
eff = svc.cached_profile()           # T(Δ) at the observed hit rate
# warm_start shares the Index's LayerCache across retunes: every layer
# built here is free for the drift retune below
retuned = idx.retune(eff, k=3, warm_start=True).build()
plain = idx.retune(PROFILES[tier], k=3, warm_start=True).build()
print(f"observed hit rate: {eff.hit_rate:.3f}")
print(f"tuned for raw {tier}:  {plain.describe()}")
print(f"tuned for cached {tier}: {retuned.describe()}")
print(f"(current 3-layer design under cached profile: "
      f"{expected_latency(idx.design, eff) * 1e6:.1f}us)")
svc.close()

print("== the observe→retune loop (drift → warm-started search) ==")
degraded = "azure_hdd"                       # the tier it ACTUALLY runs on
svc = idx.serve(profile=degraded, persist_stats=True)
for _ in range(6):
    svc.lookup(rng.choice(D.keys, 512))
report = idx.observe(svc, min_queries=1024)  # live DriftReport; after
#   close(), idx.observe_offline() reads the persisted snapshot instead
print(report.describe())
observed = svc.observed_profile(measured=False)
svc.close()                                  # snapshot → index.air.stats.json
if report.action == "retune":
    warm = idx.retune(observed, warm_start=True, k=3).build()
    print(f"warm retune for {degraded}: {warm.result.describe()}")
    print(f"  (reused {warm.stats.layers_reused} builds from the earlier "
          f"searches via the shared LayerCache, built "
          f"{warm.stats.layers_built} fresh)")
print("done.")
