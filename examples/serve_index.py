"""Serving an index under heavy traffic: the batched engine walkthrough.

1. builds a 3-layer index over a gmm dataset and serializes it *paged*
   (fixed-size pages = the cache unit),
2. opens an :class:`repro.serve.IndexService` with a two-tier LRU block
   cache and serves a skewed query stream,
3. shows what the engine saves: coalesced preads, bytes served from
   cache, warm-vs-cold modeled latency,
4. closes the loop with AirTune: the observed hit rate becomes a
   :class:`repro.core.CachedProfile` and the index is re-tuned *for* the
   cache (paper Fig. 1: a hotter tier wants a shallower index).

Run:  PYTHONPATH=src python examples/serve_index.py
"""
import os
import sys
import tempfile

import numpy as np

sys.path.insert(0, "src")

from repro.core import (KeyPositions, PROFILES, airtune, expected_latency,
                        write_index)
from repro.serve import IndexService
from repro.serve.index_service import demo_serving_design
from repro.data.datasets import sosd_like

workdir = tempfile.mkdtemp(prefix="airindex-serve-")
path = os.path.join(workdir, "index.air")

print("== build + serialize (paged) ==")
keys = sosd_like("gmm", 200_000)
D = KeyPositions.fixed_record(keys, 16)
design = demo_serving_design(D)      # 3 layers: two disk + resident root
meta = write_index(path, design, page_bytes=4096)
print(f"design: {design.describe()}")
print(f"file: {os.path.getsize(path)} B in 4096 B pages; "
      f"layer offsets {[lm.offset for lm in meta.layers]}")

print("== serve a skewed stream (hot keys repeat) ==")
rng = np.random.default_rng(0)
tier = "azure_ssd"
svc = IndexService(path, profile=tier, cache_bytes=(64 << 10, 1 << 20))
hot = rng.choice(D.keys, 512)                      # the working set
for step in range(6):
    qs = np.concatenate([rng.choice(hot, 768), rng.choice(D.keys, 256)])
    ranges = svc.lookup(qs)
    s = svc.stats
    print(f"batch {step}: hit_rate={s.hit_rate:.3f} "
          f"preads={s.preads} bytes_fetched={s.bytes_fetched} "
          f"bytes_from_cache={s.bytes_from_cache}")

print("== what the cache buys (cold vs warm, modeled) ==")
cold = IndexService(path, profile=tier, cache_bytes=(1 << 20,))
base = cold.stats.modeled_seconds
cold.lookup(hot)
cold_s = cold.stats.modeled_seconds - base
warm_base = cold.stats.modeled_seconds
cold.lookup(hot)                                    # same batch, warm
warm_s = cold.stats.modeled_seconds - warm_base
print(f"cold batch: {cold_s * 1e6:.1f}us modeled   "
      f"warm batch: {warm_s * 1e6:.1f}us modeled   "
      f"({cold_s / max(warm_s, 1e-12):.0f}x)")
cold.close()

print("== re-tune FOR the cache (CachedProfile) ==")
eff = svc.cached_profile()           # T(Δ) at the observed hit rate
retuned = airtune(D, eff, k=3)
plain = airtune(D, PROFILES[tier], k=3)
print(f"observed hit rate: {eff.hit_rate:.3f}")
print(f"tuned for raw {tier}:  {plain.design.describe()} "
      f"-> {plain.cost * 1e6:.1f}us")
print(f"tuned for cached {tier}: {retuned.design.describe()} "
      f"-> {retuned.cost * 1e6:.1f}us")
print(f"(current 3-layer design under cached profile: "
      f"{expected_latency(design, eff) * 1e6:.1f}us)")
svc.close()
print("done.")
