"""End-to-end serving driver: batched requests against a small LM.

The paper is a lookup/serving paper, so the e2e driver serves: a reduced
zamba2 (hybrid SSM+attention — O(1) decode state) handles a batch of
requests with greedy decoding, a paged KV cache whose page table is
AirTune-tuned for the HBM tier, and per-step continuous batching
(finished sequences are replaced by queued requests).

Run:  PYTHONPATH=src python examples/serve_llm.py [n_requests] [steps]
"""
import sys
import time

import numpy as np

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import api
from repro.serve.kvcache import PagedKVCache
from repro.serve.serve_step import make_decode_step

N_REQ = int(sys.argv[1]) if len(sys.argv) > 1 else 12
STEPS = int(sys.argv[2]) if len(sys.argv) > 2 else 24
BATCH = 4
MAX_LEN = 128

cfg = get_config("zamba2-1.2b", smoke=True)
print(f"== serving {cfg.name} (reduced: {cfg.n_layers}L d{cfg.d_model}) ==")
params = api.init_params(cfg, jax.random.PRNGKey(0))
decode = jax.jit(make_decode_step(cfg), static_argnums=())

# request queue: random prompts of 4-12 tokens
rng = np.random.default_rng(0)
queue = [rng.integers(1, cfg.vocab, rng.integers(4, 12)).astype(np.int32)
         for _ in range(N_REQ)]
done = []

# paged KV pool + AirTune'd page table for the HBM tier
pool = PagedKVCache(n_pages=256)

state = api.init_decode_state(cfg, params, BATCH, MAX_LEN)
slots = [None] * BATCH          # per-slot (request_id, tokens, generated)
next_req = 0
pos = 0
t0 = time.perf_counter()
tokens_out = 0

for step in range(STEPS):
    # continuous batching: fill free slots from the queue
    for b in range(BATCH):
        if slots[b] is None and next_req < len(queue):
            slots[b] = {"id": next_req, "prompt": list(queue[next_req]),
                        "fed": 0, "out": []}
            pool.add_sequence(next_req)
            next_req += 1
    # one token per slot: prompt token if any left, else last generated
    feed = np.zeros((BATCH, 1), np.int32)
    for b, s in enumerate(slots):
        if s is None:
            continue
        if s["fed"] < len(s["prompt"]):
            feed[b, 0] = s["prompt"][s["fed"]]
        else:
            feed[b, 0] = s["out"][-1] if s["out"] else 1
    logits, state = decode(params, {"tokens": jnp.asarray(feed)}, state, pos)
    nxt = np.asarray(jnp.argmax(logits, -1))
    pos += 1
    for b, s in enumerate(slots):
        if s is None:
            continue
        pool.append_tokens(s["id"], 1)
        if s["fed"] < len(s["prompt"]):
            s["fed"] += 1
        else:
            s["out"].append(int(nxt[b]))
            tokens_out += 1
            if len(s["out"]) >= 8:       # request complete
                done.append(s)
                pool.release(s["id"])
                slots[b] = None

dt = time.perf_counter() - t0
print(f"{STEPS} decode steps, {tokens_out} tokens generated, "
      f"{len(done)} requests completed, "
      f"{tokens_out / dt:.1f} tok/s (1 CPU core)")

print("== AirTune'd page tables per tier (Fig. 1 in the serving stack) ==")
pool2 = PagedKVCache(n_pages=65536)
for s in range(512):
    pool2.add_sequence(s)
    pool2.append_tokens(s, int(rng.integers(256, 2048)))
for tier in ("hbm", "host_dram"):
    stats = pool2.modeled_lookup_cost(tier)
    print(f"[{tier}] {stats['design']}")
    print(f"[{tier}] modeled lookup: tuned={stats['tuned_us']:.2f}us vs "
          f"flat-table={stats['flat_us']:.2f}us")
# fat-fast HBM ⇒ no index (read the whole table); offloaded host-DRAM
# tables ⇒ AirTune builds a real hierarchy — the paper's Fig. 1 adapted
print("OK")
