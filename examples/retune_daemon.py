"""The retune daemon: observe → drift → warm retune → hot swap, all
under live traffic that never stops.

The closing move of the autonomous serving loop.  A traffic thread
hammers pipelined batches through one :class:`repro.serve.IndexService`
— through a fault-injecting backend, so every read also rides the
:class:`repro.api.RetryPolicy` — while the daemon thread:

1. tunes generation 0 for the tier it *thinks* it deploys on
   (azure_ssd) and opens it on the tier it ACTUALLY runs on
   (azure_hdd, ``persist_stats=True``),
2. watches :func:`repro.api.detect_drift` until the observed
   per-lookup cost convicts the design (``action == "retune"``),
3. warm-retunes for the observed :class:`repro.core.CachedProfile`
   (the shared ``LayerCache`` makes the search incremental), saves the
   new generation to a fresh file,
4. calls :meth:`IndexService.swap` — one pointer move under the
   service lock.  Batches in flight finish on the old epoch's backend
   and cache; batches after the swap serve entirely from the new one.
   The traffic thread never sees an error and no batch ever mixes
   bytes of two generations (verified below against per-generation
   ground truth),
5. keeps observing: the fresh epoch's stats re-convict or acquit the
   new design, and every retired generation leaves its ServeStats
   snapshot (``<path>.stats.json``) behind — the offline observe trail
   ``detect_drift_from_file`` reads.

Run:  PYTHONPATH=src python examples/retune_daemon.py
"""
import os
import sys
import tempfile
import threading
import time

import numpy as np

sys.path.insert(0, "src")

from repro.api import Index, RetryPolicy, ServeSpec, TuneSpec, detect_drift
from repro.core import KeyPositions
from repro.core.serialize import read_meta_path
from repro.data.datasets import sosd_like
from repro.serve import FaultInjectingBackend, FileBackend
from repro.serve.index_service import demo_serving_design

workdir = tempfile.mkdtemp(prefix="airindex-daemon-")
gen_path = lambda g: os.path.join(workdir, f"index-gen{g}.air")  # noqa: E731

TUNED_FOR, DEPLOYED_ON = "azure_ssd", "azure_hdd"
RETRY = RetryPolicy(max_attempts=4, backoff_s=1e-5, max_backoff_s=1e-3)
SPEC = ServeSpec(cache_bytes=(64 << 10,), pipeline_depth=2, retry=RETRY)
MIN_QUERIES = 2048


def chaotic(path):
    """The deployment's storage is not polite: transient EIO and torn
    reads on data pages (gated past the meta region so a dense schedule
    cannot spend the whole parse budget inside the header).  Every fault
    clears within the RetryPolicy budget — recoverable by contract."""
    meta_end = min(lm.offset for lm in read_meta_path(path).layers)
    return FaultInjectingBackend(FileBackend(path), seed=7, page_bytes=1024,
                                 eio_rate=0.35, eio_attempts=2,
                                 short_rate=0.25, short_attempts=1,
                                 only_from_offset=meta_end)


print("== generation 0: costed for the tier we THINK we deploy on ==")
keys = sosd_like("gmm", 80_000)
D = KeyPositions.fixed_record(keys, 16)
# a 3-layer design (2 disk layers + resident root): plenty of real
# preads for the faults to bite and for the cache to matter
idx = Index.from_design(demo_serving_design(D),
                        spec=TuneSpec(page_bytes=1024,
                                      cache_bytes=(64 << 10,)),
                        profile=TUNED_FOR)
idx.save(gen_path(0))
print(f"gen0 ({TUNED_FOR}): {idx.design.describe()}")

rng = np.random.default_rng(3)
batches = [rng.choice(D.keys, 256) for _ in range(6)]


def ground_truth(path):
    """Per-generation expected results, read fault-free."""
    from repro.serve import IndexService
    with IndexService(path, profile=None, spec=SPEC) as clean:
        return [clean.lookup(b) for b in batches]


wants = {0: ground_truth(gen_path(0))}

print(f"== serving on {DEPLOYED_ON} (the tier it ACTUALLY runs on), "
      "faults injected ==")
svc = idx.serve(profile=DEPLOYED_ON, spec=SPEC, persist_stats=True,
                backend_factory=chaotic)

stop = threading.Event()
served, errors = [], []


def hammer():
    while not stop.is_set():
        try:
            outs = svc.lookup_batches(batches)
        except Exception as e:          # the contract says: never
            errors.append(repr(e))
            return
        served.extend(zip(range(len(batches)), outs))


traffic = threading.Thread(target=hammer, name="daemon-traffic")
traffic.start()

print("== the daemon loop: observe → drift → warm retune → swap ==")
gen = 0
# fault counters live on the per-epoch ServeStats; fold each retiring
# epoch's tally in before its swap (the snapshot persists the rest)
absorbed = {"io_retries": 0, "degraded_runs": 0, "corrupt_pages": 0}


def fold(s):
    for k in absorbed:
        absorbed[k] += getattr(s, k)


for tick in range(4):
    while svc.stats.queries < MIN_QUERIES and not errors:
        time.sleep(0.02)                # traffic accumulates evidence
    report = detect_drift(svc, min_queries=MIN_QUERIES)
    print(f"tick {tick} (gen{gen}): {report.describe()}")
    if report.action != "retune":
        if report.action == "none":
            print(f"gen{gen} acquitted on {DEPLOYED_ON}: daemon idles.")
            break
        continue                        # "observe": not enough evidence yet
    # warm retune FOR the observed deployment (tier + cache headroom);
    # the search runs beside live traffic — old generation keeps serving
    nxt = idx.retune(report.observed_profile, warm_start=True).build()
    gen += 1
    nxt.save(gen_path(gen))
    wants[gen] = ground_truth(gen_path(gen))
    print(f"  retuned gen{gen}: {nxt.result.design.describe()} "
          f"(reused {nxt.result.stats.layers_reused} layer builds, "
          f"built {nxt.result.stats.layers_built} fresh)")
    if nxt.result.design.describe() == idx.design.describe():
        print("  (same shape, re-costed: the fresh epoch's honest "
              "recorded cost is what acquits or re-convicts it)")
    fold(svc.stats)                     # the retiring epoch's fault tally
    svc.swap(gen_path(gen))             # one pointer move, traffic live
    idx = nxt
    print(f"  swapped in under live traffic (swaps={svc.stats.swaps}); "
          f"gen{gen - 1} stats persisted to its .stats.json")

stop.set()
traffic.join()
fold(svc.stats)
svc.close()

print("== the atomicity audit: every batch belongs to ONE generation ==")
by_gen = {g: 0 for g in wants}
shared = mixed = 0
for i, out in served:
    ms = [g for g, want in wants.items() if np.array_equal(out, want[i])]
    if not ms:
        mixed += 1              # bytes of two generations in one batch
    elif len(ms) == 1:
        by_gen[ms[0]] += 1
    else:
        shared += 1             # generations tuned to identical designs
print(f"batches served: {len(served)}  "
      f"per generation: { {f'gen{g}': n for g, n in by_gen.items()} }  "
      f"identical across gens: {shared}  "
      f"mixed-epoch: {mixed}  errors: {errors}")
assert mixed == 0 and not errors, "hot swap broke batch atomicity"

print("== what the retry policy absorbed along the way ==")
print(f"io_retries={absorbed['io_retries']} "
      f"degraded_runs={absorbed['degraded_runs']} "
      f"corrupt_pages={absorbed['corrupt_pages']} "
      f"(none of it visible in results)")
snaps = sorted(f for f in os.listdir(workdir) if f.endswith(".stats.json"))
print(f"observe trail for the offline daemon: {snaps}")
print("done.")
