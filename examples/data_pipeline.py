"""Data-pipeline example: AirIndex-backed random-access token store.

Builds a packed token store on the local filesystem, PROFILES the real
disk (T(Δ), §3.2), tunes the sample index with AirTune, and compares the
measured fetch path against a naive full-shard read.

Run:  PYTHONPATH=src python examples/data_pipeline.py
"""
import sys
import tempfile
import time

import numpy as np

sys.path.insert(0, "src")

from repro.data.store import ShardedTokenStore, write_token_store

root = tempfile.mkdtemp(prefix="airindex-data-")
rng = np.random.default_rng(0)
print("== writing 4000 variable-length samples ==")
samples = [rng.integers(0, 50_000, int(rng.integers(100, 2000)))
           .astype(np.int32) for _ in range(4000)]
write_token_store(root, samples)
total = sum(len(s) * 4 for s in samples)
print(f"store: {total / 1e6:.1f} MB packed tokens")

print("== profiling local disk + tuning the sample index ==")
store = ShardedTokenStore(root, profile="measure")
print(f"index: {store.tune.design.describe()}")
print(f"modeled lookup: {store.tune.cost * 1e6:.1f}us "
      f"(vs full-shard read {store.profile(total) * 1e6:.1f}us)")

print("== random-access fetches (real preads) ==")
ids = rng.integers(0, len(samples), 500)
t0 = time.perf_counter()
for i in ids:
    got = store.get(int(i))
    assert np.array_equal(got, samples[int(i)])
dt = (time.perf_counter() - t0) / len(ids)
print(f"500 verified fetches, {dt * 1e6:.0f}us each, "
      f"{store.index.bytes_read / max(store.index.reads, 1):.0f}B/index-read")

print("== deterministic replay (fault-tolerance contract) ==")
a = next(store.batch_iterator(8, 256, seed=3, start_step=5))
b = None
it = store.batch_iterator(8, 256, seed=3)
for _ in range(6):
    b = next(it)
assert np.array_equal(a["tokens"], b["tokens"])
print("replay from step 5 matches sequential iteration: OK")
store.close()
