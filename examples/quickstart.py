"""Quickstart: tune an index for YOUR storage and data (paper Alg. 2).

1. profiles the local filesystem (T(Δ), §3.2),
2. tunes an index for a gmm dataset with AirTune,
3. compares the modeled latency against B-tree / RMI / PGM / DataCalc,
4. serializes the index and serves real partial-read lookups (Alg. 1).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys
import tempfile
import time

import numpy as np

sys.path.insert(0, "src")

from repro.core import (KeyPositions, PROFILES, SerializedIndex, airtune,
                        expected_latency, profile_local_storage, write_index)
from repro.core.baselines import build_fixed_btree, tune_pgm, tune_rmi
from repro.data.datasets import sosd_like

workdir = tempfile.mkdtemp(prefix="airindex-")
print(f"== profiling local storage ({workdir}) ==")
prof = profile_local_storage(os.path.join(workdir, "scratch.bin"))
aff = prof.fit_affine()
print(f"measured T(4KB)={prof(4096) * 1e6:.1f}us  "
      f"affine fit: latency={aff.latency * 1e6:.1f}us "
      f"bandwidth={aff.bandwidth / 1e9:.2f}GB/s")

print("== dataset: gmm, 400k keys ==")
keys = sosd_like("gmm", 400_000)
D = KeyPositions.fixed_record(keys, 16)

print("== AirTune (Alg. 2) ==")
t0 = time.perf_counter()
res = airtune(D, prof, k=5)
print(f"tuned in {time.perf_counter() - t0:.2f}s -> {res.describe()}")

for name, design in [
    ("B-TREE(255,4K)", build_fixed_btree(D)),
    ("RMI (tuned)", tune_rmi(D, prof).design),
    ("PGM (tuned)", tune_pgm(D, prof).design),
]:
    c = expected_latency(design, prof)
    print(f"  vs {name:16s}: {c * 1e6:9.1f}us  "
          f"({c / res.cost:.2f}x slower than AirIndex)")

print("== serialized, real partial-read lookups ==")
idx_path = os.path.join(workdir, "index.air")
write_index(idx_path, res.design)
idx = SerializedIndex(idx_path)
rng = np.random.default_rng(0)
qs = rng.choice(keys, 1000)
t0 = time.perf_counter()
for q in qs:
    lo, hi = idx.lookup(int(q))
dt = (time.perf_counter() - t0) / len(qs)
print(f"1000 file lookups: {dt * 1e6:.1f}us each, "
      f"{idx.bytes_read / idx.reads:.0f}B/read avg, index file "
      f"{os.path.getsize(idx_path)}B")
idx.close()
print("OK")
