"""Quickstart: tune an index for YOUR storage and data (paper Alg. 2).

1. profiles the local filesystem (T(Δ), §3.2),
2. tunes an index for a gmm dataset through the ``repro.api`` facade,
3. compares the modeled latency against B-tree / RMI / PGM,
4. serializes the index (spec recorded on disk) and serves real
   partial-read lookups (Alg. 1) from the reopened file.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys
import tempfile
import time

import numpy as np

sys.path.insert(0, "src")

from repro.api import Index, TuneSpec
from repro.core import KeyPositions, expected_latency, profile_local_storage
from repro.core.baselines import build_fixed_btree, tune_pgm, tune_rmi
from repro.data.datasets import sosd_like

workdir = tempfile.mkdtemp(prefix="airindex-")
print(f"== profiling local storage ({workdir}) ==")
prof = profile_local_storage(os.path.join(workdir, "scratch.bin"))
aff = prof.fit_affine()
print(f"measured T(4KB)={prof(4096) * 1e6:.1f}us  "
      f"affine fit: latency={aff.latency * 1e6:.1f}us "
      f"bandwidth={aff.bandwidth / 1e9:.2f}GB/s")

print("== dataset: gmm, 400k keys ==")
keys = sosd_like("gmm", 400_000)
D = KeyPositions.fixed_record(keys, 16)

print("== AirTune (Alg. 2) through the facade ==")
t0 = time.perf_counter()
idx = Index.tune(D, prof, TuneSpec(k=5)).build()
print(f"tuned in {time.perf_counter() - t0:.2f}s -> {idx.describe()}")

for name, design in [
    ("B-TREE(255,4K)", build_fixed_btree(D)),
    ("RMI (tuned)", tune_rmi(D, prof).design),
    ("PGM (tuned)", tune_pgm(D, prof).design),
]:
    c = expected_latency(design, prof)
    print(f"  vs {name:16s}: {c * 1e6:9.1f}us  "
          f"({c / idx.cost:.2f}x slower than AirIndex)")

print("== serialized, real partial-read lookups ==")
idx_path = os.path.join(workdir, "index.air")
idx.save(idx_path)
rng = np.random.default_rng(0)
qs = rng.choice(keys, 1000)
with Index.open(idx_path) as reopened:       # disk walk, no data needed
    assert reopened.spec == idx.spec         # the file remembers its spec
    t0 = time.perf_counter()
    ranges = reopened.lookup(qs)
    dt = (time.perf_counter() - t0) / len(qs)
print(f"1000 file lookups: {dt * 1e6:.1f}us each, "
      f"mean range {float(np.mean(ranges[:, 1] - ranges[:, 0])):.0f}B, "
      f"index file {os.path.getsize(idx_path)}B")
print("OK")
