"""Sharded fleet serving: per-shard tuning + one global cache budget.

1. partitions a gmm dataset into 4 key-range shards and tunes each shard's
   index *independently* (:meth:`repro.fleet.Fleet.tune` — one Alg. 2
   search per shard over its own keys, sharing one ``LayerCache``),
2. saves the fleet (per-shard ``shard_NNNN.air`` files + a ``fleet.json``
   manifest) and serves a *skewed* stream through scatter-gather
   (:class:`repro.fleet.FleetService`) — results are bit-identical to
   looking every key up in its own shard,
3. persists per-shard ServeStats, so the fleet now *knows* which shards
   are hot,
4. re-tunes jointly with :meth:`Fleet.retune_budgeted`: every shard gets
   a tentative steady-state-cached design, the global cache budget is
   water-filled over the tentative designs by marginal E[T(Δ)] gain ×
   observed traffic, and each shard's final design is re-tuned for the
   hit rate its share actually buys — hot shards keep fine cached
   designs, priced-out shards fall back to coarse raw-tier designs,
5. serves again under the plan and reads the per-shard cache shares and
   hit rates off ``svc.stats_summary()``.

Run:  PYTHONPATH=src python examples/serve_fleet.py
"""
import os
import sys
import tempfile

import numpy as np

sys.path.insert(0, "src")

from repro.api import ServeSpec, TuneSpec
from repro.core import KeyPositions
from repro.data.datasets import sosd_like
from repro.fleet import Fleet, FleetSpec

workdir = tempfile.mkdtemp(prefix="airindex-fleet-")
fleet_dir = os.path.join(workdir, "fleet")
tier = "azure_ssd"
N_SHARDS = 4
WEIGHTS = (0.85, 0.09, 0.04, 0.02)        # skew: shard 0 takes 85% of traffic

print("== tune + save a 4-shard fleet (one search per shard) ==")
keys = sosd_like("gmm", 160_000)
D = KeyPositions.fixed_record(keys, 1024)
spec = FleetSpec(
    n_shards=N_SHARDS,
    tune=TuneSpec(lam_low=2**8, lam_high=2**17, k=3, max_layers=6,
                  page_bytes=4096),
    serve=ServeSpec(persist_stats=True))
fleet = Fleet.tune(D, tier, spec).build().save(fleet_dir)
print(fleet.describe())

print("== serve a skewed stream (scatter-gather, stats persisted) ==")
rng = np.random.default_rng(0)
bounds = fleet.shard_map.slice_bounds(D.keys)


def skewed_batch(n=512):
    sid = rng.choice(N_SHARDS, size=n, p=WEIGHTS)
    lo = np.array([bounds[s][0] for s in sid])
    hi = np.array([bounds[s][1] for s in sid])
    return D.keys[lo + (rng.random(n) * (hi - lo)).astype(np.int64)]


batches = [skewed_batch() for _ in range(12)]
with fleet.serve() as svc:
    flat = np.concatenate(batches)
    got = svc.lookup(flat)
    # scatter-gather identity: each key's range matches its own shard
    for sid, pos in fleet.shard_map.sub_batches(flat):
        solo = fleet.shards[sid].lookup(flat[pos]) + fleet.bases[sid]
        assert np.array_equal(got[pos], solo)
    svc.lookup_batches(batches)
    s = svc.stats_summary()
    print(f"served {s['queries']} queries, identity ok; per-shard load: "
          f"{[p['queries'] for p in s['shards']]}")

print("== joint retune: per-shard designs x global cache budget ==")
budget = 384 << 10                         # deliberately < total working set
fleet2, plan = Fleet.open(fleet_dir, data=D).retune_budgeted(
    data=D, total_cache_bytes=budget)
fleet2.build().save(fleet_dir + "2")
print(f"budget {budget >> 10} KiB water-filled by traffic x marginal gain:")
for d in plan.demands:
    share = plan.for_shard(d.shard)
    print(f"  shard {d.shard}: traffic={d.traffic:8.0f}  "
          f"working_set={d.working_set:7d} B  -> {share:7d} B "
          f"({'full' if share >= d.working_set > 0 else 'partial' if share else 'priced out'})")
print(f"designs: {[i.design.describe() for i in fleet2.shards]}")

print("== serve under the plan (hot shards earn their cache) ==")
with fleet2.serve() as svc:
    svc.lookup_batches([skewed_batch() for _ in range(12)])
    s = svc.stats_summary()
    for p in s["shards"]:
        print(f"  shard {p['shard']}: cache={sum(p['cache_bytes']):7d} B  "
              f"hit_rate={p['hit_rate']:.3f}  queries={p['queries']}")
    print(f"fleet per-query modeled cost: {s['query_modeled_us']:.1f}us "
          f"(uncached walk would pay {s['walk_query_us']:.1f}us)")
print("done.")
