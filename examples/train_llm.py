"""End-to-end training example with fault injection.

Trains a reduced qwen3 on a synthetic token store, checkpoints through the
AirIndex manifest, injects a host failure mid-run, and shows the
supervisor restarting from the latest checkpoint with an elastically
shrunk host set.  Loss must decrease end to end.

Run:  PYTHONPATH=src python examples/train_llm.py [steps]
"""
import os
import sys
import tempfile
import time

import numpy as np

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data.store import ShardedTokenStore, write_token_store
from repro.models import api
from repro.train.checkpoint import restore_checkpoint, save_checkpoint
from repro.train.fault_tolerance import (FTConfig, TrainingSupervisor,
                                         elastic_mesh_shape)
from repro.train.optimizer import adamw_init
from repro.train.train_step import TrainConfig, make_train_step

STEPS = int(sys.argv[1]) if len(sys.argv) > 1 else 40
workdir = tempfile.mkdtemp(prefix="airindex-train-")

cfg = get_config("qwen3-14b", smoke=True)
print(f"== training reduced {cfg.name}: {cfg.n_layers}L d{cfg.d_model} ==")

data_dir = os.path.join(workdir, "data")
rng = np.random.default_rng(0)
# learnable structure: repeated n-gram patterns
pats = [rng.integers(0, cfg.vocab, 16).astype(np.int32) for _ in range(8)]
samples = [np.concatenate([pats[i % 8]] * int(rng.integers(4, 16)))
           for i in range(512)]
write_token_store(data_dir, samples)
store = ShardedTokenStore(data_dir, profile="azure_ssd")
print(f"[data] index: {store.tune.design.describe()}")

tcfg = TrainConfig(microbatches=1)
params = api.init_params(cfg, jax.random.PRNGKey(0))
opt = adamw_init(params, tcfg.optimizer)
step_fn = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0, 1))
it = store.batch_iterator(4, 64, seed=0)
losses = []


def save(state, step):
    meta = save_checkpoint(workdir, state["params"], step=step,
                           profile="azure_ssd")
    print(f"[ckpt] step={step} blob={meta['blob_bytes']}B "
          f"manifest={meta['index_design']}")


def restore(step):
    # build the restore template from specs — the live params
    # were donated to step_fn and their buffers are gone
    like = jax.tree.map(lambda s: np.zeros(s.shape, s.dtype),
                        api.param_specs(cfg))
    tree, stats = restore_checkpoint(workdir, like, step=step)
    print(f"[restore] step={step} bytes={stats['bytes_read']} "
          f"reads={stats['reads']}")
    # fresh moments: the pre-failure opt state was donated to step_fn
    restored = jax.tree.map(jnp.asarray, tree)
    return {"params": restored, "opt": adamw_init(restored, tcfg.optimizer)}


sup = TrainingSupervisor(workdir, [f"host{i}" for i in range(4)],
                         FTConfig(checkpoint_every=10), save, restore)
killed = {"done": False}


def one_step(state, step):
    if step == 25 and not killed["done"]:
        print("[inject] killing host2 at step 25")
        sup.monitor.kill("host2")
        killed["done"] = True
    batch = next(it)
    p, o, m = step_fn(state["params"], state["opt"],
                      jax.tree.map(jnp.asarray, batch))
    losses.append(float(m["loss"]))
    if step % 5 == 0:
        print(f"[step {step:3d}] loss={losses[-1]:.4f}")
    return {"params": p, "opt": o}


t0 = time.time()
state, steps, log = sup.run({"params": params, "opt": opt}, one_step, STEPS)
events = [e["event"] for e in log]
new_mesh = elastic_mesh_shape(len(sup.monitor.hosts), 4, 2)
print(f"== done: {steps} steps in {time.time() - t0:.1f}s; "
      f"events={sorted(set(events))} ==")
print(f"surviving hosts={len(sup.monitor.hosts)} -> elastic mesh {new_mesh}")
print(f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")
assert "failure" in events and "restart" in events
assert losses[-1] < losses[0], "loss must decrease"
store.close()
print("OK")
