"""Tuning benchmark: cost + wall-clock + sweep-engine work per strategy.

The serving benchmark tracks how fast a tuned index *serves*; this one
tracks how fast (and how well) the tuner itself *searches*.  Every
registered strategy runs on a fixed dataset × storage-profile grid with
one shared :class:`TuneSpec`; per cell the fused sweep engine (the
default) is compared against the legacy per-builder loop
(``sweep=False``), so the JSON records both the answer quality and the
work reduction:

  * ``cost_us``        — L_SM (Eq. 6) of the returned design,
  * ``wall_s`` / ``legacy_wall_s`` — strategy wall-clock, both paths,
  * ``layers_built`` / ``layers_reused`` — construction vs cache hits,
  * ``scored``         — E[T(Δ)] evaluations actually performed,
  * ``sweeps`` / ``sweep_s_per_vertex`` — fused expansions + their cost,
  * ``work_reduction`` — legacy (built+scored) / sweep (built+scored),
  * ``sweep_matches_legacy`` — bit-identical design/cost certification.

The three strategies share one :class:`repro.core.sweep.LayerCache` per
dataset — the certification workload (brute force first, then the guided
searches, across every tier) is exactly the cross-tune reuse the cache
exists for, so the guided strategies ride the exhaustive pass's builds.

The λ-grid keeps ``brute_force`` tractable; it certifies the guided
strategies' costs on every run (``within_brute`` > 1.05 fails the run —
the CI regression guard).  A scoring micro-benchmark also records the
numpy / jnp / Pallas-interpret batched-scorer wall-clocks.

Prints the repo's ``name,us_per_call,derived`` CSV; ``--json PATH`` also
dumps ``BENCH_tune.json`` so the perf trajectory tracks tuner speed
(``benchmarks/run.py --tune-json`` wires this into the main harness).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "..", "src"))

from repro.api import TuneSpec
from repro.core import KeyPositions, PROFILES, batched_mean_read_costs
from repro.core.registry import SEARCH_STRATEGIES
from repro.core.sweep import LayerCache
from repro.data.datasets import sosd_like

N_KEYS = 50_000
RECORD = 16
DATASETS = ("gmm", "books")
TIERS = ("azure_ssd", "azure_nfs")
# brute force first: its exhaustive expansion warms the shared per-dataset
# LayerCache, so the guided certifications ride its builds
STRATEGIES = ("brute_force", "beam", "airtune")

# small Eq.(8) grid: 7 λ values × 3 families keeps brute_force tractable
SPEC = TuneSpec(lam_low=2.0**10, lam_high=2.0**16, lam_base=2.0,
                k=3, max_layers=4)


def emit(name, us, derived):
    print(f"{name},{us:.2f},{derived}")


def _run_cell(strat: str, D, profile, builders, cache: LayerCache) -> dict:
    fn = SEARCH_STRATEGIES.get(strat)
    kw = dict(k=SPEC.k, max_layers=SPEC.max_layers)
    res = fn(D, profile, builders, sweep=True, layer_cache=cache, **kw)
    leg = fn(D, profile, builders, sweep=False, **kw)
    s, ls = res.stats, leg.stats
    sweep_work = s.layers_built + s.candidates_scored
    legacy_work = ls.layers_built + ls.candidates_scored
    # a cell where the stopping criterion fires immediately does zero
    # work on BOTH paths — that is parity (1.0), not a 0x regression
    reduction = legacy_work / max(sweep_work, 1) if legacy_work else 1.0
    return {
        "strategy": strat,
        "cost_us": res.cost * 1e6,
        "wall_s": s.wall_seconds,
        "legacy_wall_s": ls.wall_seconds,
        "layers_built": s.layers_built,
        "layers_reused": s.layers_reused,
        "pruned": s.candidates_pruned,
        "scored": s.candidates_scored,
        "sweeps": s.sweeps,
        "sweep_s_per_vertex": s.sweep_seconds / max(s.sweeps, 1),
        "legacy_layers_built": ls.layers_built,
        "legacy_scored": ls.candidates_scored,
        "work_reduction": reduction,
        "sweep_matches_legacy": bool(
            res.cost == leg.cost
            and res.builder_names == leg.builder_names),
        "n_layers": res.design.n_layers,
        "builder_names": list(res.builder_names),
    }


def _bench_scoring_backends(C: int = 32, S: int = 8192) -> dict:
    """Wall-clock of one batched (C, S) candidate-scoring call per
    backend (fallback order Pallas → jnp → numpy; see
    repro.kernels.candidate_score)."""
    rng = np.random.default_rng(0)
    W = rng.uniform(16.0, 1e6, size=(C, S))
    weights = rng.uniform(0.5, 4.0, size=S)
    prof = PROFILES["azure_ssd"]
    out = {"candidates": C, "sample": S}

    def _time(fn, reps=5):
        fn()                                     # warmup / jit compile
        t0 = time.perf_counter()
        for _ in range(reps):
            fn()
        return (time.perf_counter() - t0) / reps * 1e6

    out["numpy_us"] = _time(
        lambda: batched_mean_read_costs(W, weights, prof))
    # each device backend fails independently (e.g. jnp works but the
    # Pallas interpret path raises on an older jax) — time them separately
    for key, backend, reps in (("jnp_us", "jnp", 5),
                               ("pallas_interpret_us", "pallas", 2)):
        try:
            from repro.core.storage import affine_coefficients
            from repro.kernels.candidate_score import affine_candidate_scores
            ell, inv_bw = affine_coefficients(prof)
            out[key] = _time(lambda: affine_candidate_scores(
                W, weights, ell, inv_bw, backend=backend), reps=reps)
        except Exception as exc:                 # no jax / kernel failure
            out[key] = None
            out[f"{backend}_backend_error"] = repr(exc)
    for k in ("numpy_us", "jnp_us", "pallas_interpret_us"):
        v = out.get(k)
        emit(f"tune_score_{k[:-3]}", v if v is not None else 0.0,
             f"batched ({C},{S}) candidate scoring" if v is not None
             else "backend unavailable")
    return out


def run_tune_bench(n_keys: int = N_KEYS,
                   strategies=STRATEGIES) -> dict:
    results = {"n_keys": n_keys, "spec": SPEC.to_dict(), "rows": []}
    builders = SPEC.builders()
    for ds in DATASETS:
        D = KeyPositions.fixed_record(sosd_like(ds, n_keys), RECORD)
        cache = LayerCache()        # shared across tiers AND strategies
        for tier in TIERS:
            per_strategy = {}
            for strat in strategies:
                row = _run_cell(strat, D, PROFILES[tier], builders, cache)
                row.update({"dataset": ds, "tier": tier})
                per_strategy[strat] = row
                results["rows"].append(row)
                emit(f"tune_{ds}_{tier}_{strat}", row["wall_s"] * 1e6,
                     f"cost={row['cost_us']:.1f}us built={row['layers_built']} "
                     f"reused={row['layers_reused']} scored={row['scored']} "
                     f"red={row['work_reduction']:.1f}x "
                     f"layers={row['n_layers']}")
            if "brute_force" in per_strategy:
                ref = per_strategy["brute_force"]["cost_us"]
                for strat, row in per_strategy.items():
                    row["within_brute"] = row["cost_us"] / max(ref, 1e-12)

    # per-strategy aggregates: the trend line benchmarks/run.py prints
    per = {}
    for row in results["rows"]:
        a = per.setdefault(row["strategy"], {
            "wall_s": 0.0, "legacy_wall_s": 0.0, "layers_built": 0,
            "layers_reused": 0, "scored": 0, "legacy_layers_built": 0,
            "legacy_scored": 0, "sweeps": 0})
        for k in a:
            a[k] += row[k]
    for strat, a in per.items():
        sweep_work = a["layers_built"] + a["scored"]
        legacy_work = a["legacy_layers_built"] + a["legacy_scored"]
        a["work_reduction"] = legacy_work / max(sweep_work, 1) \
            if legacy_work else 1.0
    results["per_strategy"] = per

    results["scoring_backends"] = _bench_scoring_backends()

    guided = [r for r in results["rows"] if r["strategy"] != "brute_force"
              and "within_brute" in r]
    ok_cost = all(r["within_brute"] <= 1.05 for r in guided)
    ok_ident = all(r["sweep_matches_legacy"] for r in results["rows"])
    ok_work = all(a["work_reduction"] >= 3.0 for a in per.values())
    results["acceptance_guided_within_5pct_of_brute"] = ok_cost
    results["acceptance_sweep_bit_identical"] = ok_ident
    results["acceptance_work_reduction_3x"] = ok_work
    emit("tune_acceptance", 0.0,
         f"guided_within_5pct_of_brute_on_{len(guided)}_cells={ok_cost} "
         f"sweep_bit_identical={ok_ident} work_reduction_3x={ok_work}")
    for strat, a in per.items():
        if a["wall_s"] > a["legacy_wall_s"] * 1.2:
            # GitHub annotation; plain noise locally — wall regressions
            # warn, they do not fail the run (machine variance)
            print(f"::warning ::tune_bench {strat}: sweep wall "
                  f"{a['wall_s']:.2f}s > 1.2x legacy "
                  f"{a['legacy_wall_s']:.2f}s")
    return results


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also dump results as JSON (e.g. BENCH_tune.json)")
    ap.add_argument("--n-keys", type=int, default=N_KEYS)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    results = run_tune_bench(args.n_keys)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=2)
        print(f"# wrote {args.json}", flush=True)
    # regression guard: guided search quality and sweep equivalence are
    # hard failures; wall-clock only warns (above)
    if not (results["acceptance_guided_within_5pct_of_brute"]
            and results["acceptance_sweep_bit_identical"]):
        sys.exit(1)


if __name__ == "__main__":
    main()
