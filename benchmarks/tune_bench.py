"""Tuning benchmark: cost + wall-clock per search strategy.

The serving benchmark tracks how fast a tuned index *serves*; this one
tracks how fast (and how well) the tuner itself *searches*.  Every
registered strategy runs through the ``repro.api`` facade on a fixed
dataset × storage-profile grid with one shared :class:`TuneSpec`, so the
numbers are comparable across PRs:

  * ``cost_us``       — L_SM (Eq. 6) of the returned design,
  * ``wall_s``        — strategy wall-clock (TuneStats.wall_seconds),
  * ``layers_built``  — candidate layers constructed (the search's work),
  * ``pruned``        — candidates discarded without exact evaluation.

The λ-grid is kept small enough that ``brute_force`` stays tractable and
certifies the guided strategies' costs on every run (``within_brute`` in
the JSON; >1.05 means a guided search lost the optimum).

Prints the repo's ``name,us_per_call,derived`` CSV; ``--json PATH`` also
dumps ``BENCH_tune.json`` so the perf trajectory tracks tuner speed
(``benchmarks/run.py --tune-json`` wires this into the main harness).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "..", "src"))

from repro.api import Index, TuneSpec
from repro.core import KeyPositions
from repro.data.datasets import sosd_like

N_KEYS = 50_000
RECORD = 16
DATASETS = ("gmm", "books")
TIERS = ("azure_ssd", "azure_nfs")
STRATEGIES = ("airtune", "beam", "brute_force")

# small Eq.(8) grid: 4 λ values × 3 families keeps brute_force tractable
SPEC = TuneSpec(lam_low=2.0**10, lam_high=2.0**16, lam_base=4.0,
                k=3, max_layers=4)


def emit(name, us, derived):
    print(f"{name},{us:.2f},{derived}")


def run_tune_bench(n_keys: int = N_KEYS,
                   strategies=STRATEGIES) -> dict:
    results = {"n_keys": n_keys, "spec": SPEC.to_dict(), "rows": []}
    for ds in DATASETS:
        D = KeyPositions.fixed_record(sosd_like(ds, n_keys), RECORD)
        for tier in TIERS:
            per_strategy = {}
            for strat in strategies:
                res = Index.tune(D, tier, SPEC, strategy=strat).result
                row = {
                    "dataset": ds, "tier": tier, "strategy": strat,
                    "cost_us": res.cost * 1e6,
                    "wall_s": res.stats.wall_seconds,
                    "layers_built": res.stats.layers_built,
                    "pruned": res.stats.candidates_pruned,
                    "n_layers": res.design.n_layers,
                    "builder_names": list(res.builder_names),
                }
                per_strategy[strat] = row
                results["rows"].append(row)
                emit(f"tune_{ds}_{tier}_{strat}", res.stats.wall_seconds * 1e6,
                     f"cost={res.cost * 1e6:.1f}us built={res.stats.layers_built} "
                     f"pruned={res.stats.candidates_pruned} "
                     f"layers={res.design.n_layers}")
            if "brute_force" in per_strategy:
                ref = per_strategy["brute_force"]["cost_us"]
                for strat, row in per_strategy.items():
                    row["within_brute"] = row["cost_us"] / max(ref, 1e-12)
    guided = [r for r in results["rows"] if r["strategy"] != "brute_force"
              and "within_brute" in r]
    ok = all(r["within_brute"] <= 1.05 for r in guided)
    results["acceptance_guided_within_5pct_of_brute"] = ok
    emit("tune_acceptance", 0.0,
         f"guided_within_5pct_of_brute_on_{len(guided)}_cells={ok}")
    return results


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also dump results as JSON (e.g. BENCH_tune.json)")
    ap.add_argument("--n-keys", type=int, default=N_KEYS)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    results = run_tune_bench(args.n_keys)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=2)
        print(f"# wrote {args.json}", flush=True)
    if not results["acceptance_guided_within_5pct_of_brute"]:
        sys.exit(1)


if __name__ == "__main__":
    main()
