"""Serving-engine benchmark: queries/sec vs cache size vs storage tier.

Exercises :class:`repro.serve.IndexService` against a paged index file:

  * **cold vs warm** — the same batch served twice; the warm pass must
    fetch strictly fewer bytes from storage and complete faster in modeled
    seconds (Eq. 5 under the tier profile) on every tier (the ISSUE's
    acceptance gate);
  * **cache sweep** — hit rate and modeled time for a skewed (Zipf-ish)
    query stream as the tiered cache grows;
  * **throughput** — wall-clock queries/sec of the batched engine vs the
    one-query-at-a-time ``lookup_serialized`` walk;
  * **pipeline** — ``lookup_batches`` (batch-i+1 prefetch overlapping
    batch-i fused descent) vs sequential ``lookup`` on ``azure_hdd``:
    windows must be identical (FATAL) and the roofline must show the
    engine pread-bound (``io_fraction >= 0.8``, FATAL); a wall-clock
    qps regression only warns;
  * **drift scenario** — tune on ``azure_ssd``, serve on a degraded tier:
    the persisted ServeStats must flag drift (``repro.api.drift``) and a
    warm-started retune must recover the cold-retune cost (within 1%)
    with strictly fewer layer builds — a failed recovery is FATAL, only
    wall-clock regressions degrade to warnings;
  * **baselines on the serve path** — the §7.2 btree/rmi/pgm designs
    served through the same ``IndexService`` + cache as the AirTune
    design, so ``BENCH_serve.json`` trends the dominance margin on the
    *real* partial-read path, not just the Eq. 6 model.

``--chaos`` / ``--chaos-only`` add the fault-injection gate: every
recoverable fault schedule (transient EIO, torn reads, stalls, corrupt
pages, flaky start, persistent coalesced-run failure) must serve
bit-identical results through the retry/repair machinery (FATAL);
past-the-budget failures must surface their typed errors (FATAL); hot
swap under live traffic must never mix epochs within a batch (FATAL); a
dead fleet shard must honor the fail-stop and ``partial_results``
contracts (FATAL); qps degradation under faults only warns.
``--chaos-json PATH`` dumps ``BENCH_chaos.json``.

Prints the repo's ``name,us_per_call,derived`` CSV; ``--json PATH`` also
dumps a machine-readable ``BENCH_serve.json`` so later PRs have a perf
trajectory to compare against (``benchmarks/run.py --serve-json`` wires
this into the main harness).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "..", "src"))

from repro.api import Index, RetryPolicy, ServeSpec, TuneSpec, detect_drift
from repro.core import (KeyPositions, PROFILES, airtune, expected_latency,
                        profile_to_dict, quantile_latency)
from repro.core.baselines import build_fixed_btree, tune_pgm, tune_rmi
from repro.core.serialize import lookup_serialized, write_index
from repro.core.storage import CachedProfile
from repro.fleet import Fleet, FleetSpec, ShardUnavailableError, \
    demand_from_design
from repro.serve import (FaultInjectingBackend, FileBackend, IndexService,
                         ReadError, StorageError)
from repro.serve.index_service import (ServeStats, demo_serving_design,
                                       distributional_backing_profile)
from repro.data.datasets import sosd_like

N_KEYS = 200_000
RECORD = 16
PAGE = 4096
TIERS = ("azure_nfs", "azure_ssd")
CACHE_SIZES = (32 << 10, 256 << 10, 2 << 20)

# drift scenario: tuned-for tier vs the degraded tier it is served on
DRIFT_TUNED = "azure_ssd"
DRIFT_SERVED = "azure_hdd"
DRIFT_SPEC = TuneSpec(lam_low=2**8, lam_high=2**17, lam_base=2.0, k=4,
                      max_layers=8, page_bytes=PAGE,
                      cache_bytes=(64 << 10, 512 << 10))


def emit(name, us, derived):
    print(f"{name},{us:.2f},{derived}")


build_serving_design = demo_serving_design


_HOT_ORDER = None       # fixed random rank→key map, shared by all sweeps


def _skewed_queries(keys: np.ndarray, n: int, rng) -> np.ndarray:
    """Zipf-ish rank sampling — the hot-key regime block caches live for.
    Ranks map through a fixed random permutation so the hot set is spread
    across the key space (not the physically-clustered smallest keys)."""
    global _HOT_ORDER
    if _HOT_ORDER is None or len(_HOT_ORDER) != len(keys):
        _HOT_ORDER = np.random.default_rng(123).permutation(len(keys))
    ranks = (rng.zipf(1.2, n) - 1) % len(keys)
    return keys[_HOT_ORDER[ranks]]


def bench_cold_warm(idx: Index, tier: str, queries: np.ndarray) -> dict:
    svc = idx.serve(profile=tier, cache_bytes=(256 << 10, 2 << 20))
    base = svc.stats.snapshot()
    t0 = time.perf_counter()
    svc.lookup(queries)
    cold_wall = time.perf_counter() - t0
    mid = svc.stats.snapshot()
    t0 = time.perf_counter()
    svc.lookup(queries)
    warm_wall = time.perf_counter() - t0
    end = svc.stats.snapshot()
    svc.close()
    cold = {k: mid[k] - base[k] for k in ("bytes_fetched", "modeled_seconds",
                                          "preads")}
    warm = {k: end[k] - mid[k] for k in ("bytes_fetched", "modeled_seconds",
                                         "preads")}
    return {
        "tier": tier,
        "cold": {**cold, "wall_s": cold_wall,
                 "qps": len(queries) / max(cold_wall, 1e-9)},
        "warm": {**warm, "wall_s": warm_wall,
                 "qps": len(queries) / max(warm_wall, 1e-9)},
        "hit_rate_final": end["hit_rate"],
        "warm_fewer_bytes": warm["bytes_fetched"] < cold["bytes_fetched"],
        "warm_faster_modeled":
            warm["modeled_seconds"] < cold["modeled_seconds"],
    }


def bench_cache_sweep(idx: Index, tier: str, keys: np.ndarray, *,
                      n_batches: int = 8, batch: int = 1024) -> list:
    rng = np.random.default_rng(7)
    stream = [_skewed_queries(keys, batch, rng) for _ in range(n_batches)]
    rows = []
    for cap in CACHE_SIZES:
        svc = idx.serve(profile=tier,
                        cache_bytes=(cap // 4, cap - cap // 4))
        base = svc.stats.snapshot()
        t0 = time.perf_counter()
        for qs in stream:
            svc.lookup(qs)
        wall = time.perf_counter() - t0
        end = svc.stats.snapshot()
        svc.close()
        rows.append({
            "tier": tier, "cache_bytes": cap,
            "hit_rate": end["hit_rate"],
            "bytes_fetched": end["bytes_fetched"] - base["bytes_fetched"],
            "bytes_from_cache": end["bytes_from_cache"],
            "modeled_seconds": end["modeled_seconds"] - base["modeled_seconds"],
            "qps": n_batches * batch / max(wall, 1e-9),
        })
    return rows


def bench_engine_vs_scalar(idx: Index, queries: np.ndarray) -> dict:
    path = idx.path
    svc = idx.serve(profile=None, cache_bytes=(2 << 20,))
    svc.lookup(queries[:64])                      # touch pages / warm python
    t0 = time.perf_counter()
    svc.lookup(queries)
    engine_wall = time.perf_counter() - t0
    svc.close()
    t0 = time.perf_counter()
    lookup_serialized(path, None, queries)
    scalar_wall = time.perf_counter() - t0
    return {"engine_qps": len(queries) / max(engine_wall, 1e-9),
            "scalar_qps": len(queries) / max(scalar_wall, 1e-9),
            "speedup": scalar_wall / max(engine_wall, 1e-9)}


def bench_pipeline(idx: Index, keys: np.ndarray, *, n_batches: int = 8,
                   batch: int = 512) -> dict:
    """Pipeline-on vs pipeline-off on the slow tier: ``lookup_batches``
    with batch-i+1 prefetch overlapping batch-i descent must return
    windows identical to sequential ``lookup`` (fatal gate), and the
    roofline must show the engine pread-bound on ``azure_hdd`` — the
    whole point of overlapping I/O is that I/O dominates.

    Unlike the cache sweep this cell wants *misses*: uniform queries (no
    hot set) against a cache smaller than the disk-resident layers, so
    every batch issues real preads and the modeled azure_hdd seek time
    dwarfs the fused-descent compute."""
    rng = np.random.default_rng(31)
    batches = [rng.choice(keys, batch) for _ in range(n_batches)]
    base = ServeSpec(cache_bytes=(8 << 10,))

    svc = idx.serve(profile=DRIFT_SERVED, spec=base)
    t0 = time.perf_counter()
    want = [svc.lookup(qs) for qs in batches]
    off_wall = time.perf_counter() - t0
    off_roof = svc.stats.roofline()
    svc.close()

    svc = idx.serve(profile=DRIFT_SERVED,
                    spec=base.replace(pipeline_depth=2, prefetch_layers=2))
    t0 = time.perf_counter()
    got = svc.lookup_batches(batches)
    on_wall = time.perf_counter() - t0
    on_roof = svc.stats.roofline()
    s = svc.stats
    row = {
        "tier": DRIFT_SERVED,
        "identical": bool(all(np.array_equal(w, g)
                              for w, g in zip(want, got))),
        "qps_off": n_batches * batch / max(off_wall, 1e-9),
        "qps_on": n_batches * batch / max(on_wall, 1e-9),
        "pipelined_batches": s.pipelined_batches,
        "overlapped_preads": s.overlapped_preads,
        "overlapped_pread_seconds": s.overlapped_pread_seconds,
        "roofline_off": off_roof,
        "roofline_on": on_roof,
        # acceptance: the pipelined engine is pread-bound on azure_hdd
        "pread_bound": bool(on_roof["bound"] == "pread"
                            and on_roof["io_fraction"] >= 0.8),
    }
    svc.close()
    row["speedup"] = row["qps_on"] / max(row["qps_off"], 1e-9)
    return row


def bench_drift(D: KeyPositions, workdir: str) -> dict:
    """The observe→retune loop end to end: tune on DRIFT_TUNED, serve on
    DRIFT_SERVED, detect drift from persisted ServeStats, then warm- vs
    cold-retune for the observed profile.  The warm search must land
    within 1% of the cold cost with strictly fewer builds (fatal gate);
    wall-clock only informs."""
    idx = Index.tune(D, DRIFT_TUNED, DRIFT_SPEC).build()
    path = os.path.join(workdir, "drift.air")
    idx.save(path)
    rng = np.random.default_rng(11)
    svc = idx.serve(profile=DRIFT_SERVED, persist_stats=True)
    for _ in range(8):
        svc.lookup(_skewed_queries(D.keys, 512, rng))
    report = detect_drift(svc)
    observed = svc.observed_profile(measured=False)   # modeled degraded
    #                                 tier + observed hit rate: CI-stable
    svc.close()

    t0 = time.perf_counter()
    cold = idx.retune(observed).build()
    cold_wall = time.perf_counter() - t0
    t0 = time.perf_counter()
    warm = idx.retune(observed, warm_start=True).build()
    warm_wall = time.perf_counter() - t0

    recovery = warm.cost / cold.cost if cold.cost > 0 else float("inf")
    work_ok = (warm.stats.layers_reused > cold.stats.layers_reused
               and warm.stats.layers_built < cold.stats.layers_built)
    return {
        "tuned_tier": DRIFT_TUNED, "served_tier": DRIFT_SERVED,
        "report": report.to_dict(),
        "drift_detected": bool(report.drifted and report.action == "retune"),
        "recorded_cost_us": idx.cost * 1e6,
        "cold": {"cost_us": cold.cost * 1e6, "wall_s": cold_wall,
                 "built": cold.stats.layers_built,
                 "reused": cold.stats.layers_reused},
        "warm": {"cost_us": warm.cost * 1e6, "wall_s": warm_wall,
                 "built": warm.stats.layers_built,
                 "reused": warm.stats.layers_reused,
                 "seeded": warm.stats.layers_seeded},
        "recovery_ratio": recovery,          # ≤ 1.01 required
        "work_reduction": (cold.stats.layers_built
                           / max(warm.stats.layers_built, 1)),
        "warm_recovers": bool(recovery <= 1.01 and work_ok),
        "warm_wall_faster": bool(warm_wall < cold_wall),
    }


#: serve-path tuning ladder — every *tunable* family is tuned once per
#: rung and keeps its realized-best candidate.  The raw tier alone
#: mis-prices the serve path (index-layer reads hit the block cache, the
#: final data read never does), so families also tune for the cached
#: deployment at a high and a fully-warmed hit rate; selection is by
#: *observed* per-query cost through the engine, which is fair to every
#: family because they all get the same ladder and the same stream.
SERVE_LADDER = ("raw", 0.9, 1.0)


def _ladder_profile(tier: str, rung):
    if rung == "raw":
        return PROFILES[tier]
    return CachedProfile(backing=PROFILES[tier],
                         cache=PROFILES["host_dram"], hit_rate=float(rung))


def _serve_design(design, tier, stream, workdir, tag) -> dict:
    """One candidate through the engine: same cache spec, same stream."""
    path = os.path.join(workdir, f"baseline_{tag}.air")
    Index.from_design(design, spec=TuneSpec(page_bytes=PAGE),
                      profile=tier).save(path)
    svc = None
    try:
        svc = IndexService(path, profile=tier,
                           spec=ServeSpec(cache_bytes=(64 << 10, 512 << 10)))
        t0 = time.perf_counter()
        for qs in stream:
            svc.lookup(qs)
        wall = time.perf_counter() - t0
        s = svc.stats
        return {
            "layers": len(design.layers),
            "eq6_cost_us": expected_latency(design, PROFILES[tier]) * 1e6,
            "observed_us": s.query_modeled_seconds * 1e6,
            "walk_us": s.walk_query_seconds * 1e6,
            "hit_rate": s.hit_rate,
            "preads": s.preads,
            "bytes_fetched": s.bytes_fetched,
            "qps": len(stream) * len(stream[0]) / max(wall, 1e-9),
        }
    finally:
        if svc is not None:
            svc.close()
        os.unlink(path)


def bench_baseline_serve(D: KeyPositions, tier: str, workdir: str, *,
                         n_batches: int = 8, batch: int = 512) -> dict:
    """§7.2 on the real serve path: every family's candidates served
    through the SAME engine + cache against the same skewed stream, the
    dominance margin compared between per-family *realized-best*
    candidates (per-query observed E[T]).

    Each tunable family (airtune, rmi, pgm) tunes once per
    ``SERVE_LADDER`` rung — the raw tier plus cached deployments at
    h=0.9 / h=1.0 — and is judged by its best observed cost; btree is
    fixed-shape.  This closes the raw-tier mispricing gap (a raw-tuned
    design pays coarse data reads the cached path never amortizes away)
    without hand-picking a profile for AirTune only."""
    tuners = {
        "airtune": lambda prof: Index.tune(D, prof, DRIFT_SPEC)
                                     .build().result.design,
        "rmi": lambda prof: tune_rmi(D, prof).design,
        "pgm": lambda prof: tune_pgm(D, prof).design,
    }
    rng = np.random.default_rng(23)
    stream = [_skewed_queries(D.keys, batch, rng) for _ in range(n_batches)]
    rows, ladder = {}, {}
    for name, tuner in tuners.items():
        best = None
        ladder[name] = {}
        for rung in SERVE_LADDER:
            design = tuner(_ladder_profile(tier, rung))
            r = _serve_design(design, tier, stream, workdir,
                              f"{name}_{rung}")
            r["rung"] = str(rung)
            ladder[name][str(rung)] = r["observed_us"]
            if best is None or r["observed_us"] < best["observed_us"]:
                best = r
        rows[name] = best
    r = _serve_design(build_fixed_btree(D), tier, stream, workdir, "btree")
    r["rung"] = "fixed"
    ladder["btree"] = {"fixed": r["observed_us"]}
    rows["btree"] = r
    air = rows["airtune"]["observed_us"]
    for name, row in rows.items():
        if name != "airtune":
            row["margin_vs_airtune"] = row["observed_us"] / max(air, 1e-12)
    margins = [row["margin_vs_airtune"] for n, row in rows.items()
               if n != "airtune"]
    return {"tier": tier, "designs": rows, "ladder": ladder,
            "min_margin": min(margins),
            # §7.2 on the serve path: AirTune ≤ every baseline (small
            # slack: cache/residency interactions are not in the model)
            "dominates": bool(min(margins) >= 0.999)}


# ---------------------------------------------------------------------------
# Sharded fleet vs one monolithic index under skewed hot/cold traffic
# ---------------------------------------------------------------------------
# Large records put the monolith's Eq. 6 optimum at a 2-layer design with
# a multi-MB disk-resident bottom layer — the regime where a cache byte
# budget is a real resource.  The budget is half the monolith's raw
# working set, so the monolith is capacity-constrained by construction;
# the fleet must win it back through per-shard tuning plus marginal-gain
# budgeting (Fleet.retune_budgeted), not through extra memory.
FLEET_N_KEYS = 400_000
FLEET_RECORD = 1024
FLEET_SHARDS = 4
FLEET_WEIGHTS = (0.90, 0.06, 0.03, 0.01)   # hot/cold traffic per shard
FLEET_TIER = "azure_ssd"
FLEET_BATCHES, FLEET_BATCH = 24, 512
FLEET_TUNE = TuneSpec(lam_low=2**8, lam_high=2**17, lam_base=2.0, k=4,
                      max_layers=8, page_bytes=PAGE)


def _fleet_stream(keys: np.ndarray, shard_map, rng) -> list:
    """Skewed-across, uniform-within: batch keys drawn per shard with
    FLEET_WEIGHTS, uniform inside each shard's key range."""
    sl = shard_map.slice_bounds(keys)
    batches = []
    for _ in range(FLEET_BATCHES):
        sid = rng.choice(len(FLEET_WEIGHTS), size=FLEET_BATCH,
                         p=FLEET_WEIGHTS)
        b = np.empty(FLEET_BATCH, dtype=np.uint64)
        for s in range(len(FLEET_WEIGHTS)):
            m = sid == s
            if m.any():
                b[m] = keys[rng.integers(sl[s][0], sl[s][1],
                                         size=int(m.sum()))]
        batches.append(b)
    return batches


def _fleet_identity(fleet, batches, tier: str) -> dict:
    """The acceptance gate: fleet scatter-gather must be bit-identical to
    sequential per-shard IndexService lookups (+ base), and
    ``lookup_batches`` identical to per-batch ``lookup``."""
    flat = np.concatenate(batches)
    want = np.empty((len(flat), 2), dtype=np.int64)
    for sid, pos in fleet.shard_map.sub_batches(flat):
        with IndexService(fleet.shards[sid].path, profile=tier) as ref:
            want[pos] = ref.lookup(flat[pos]) + fleet.bases[sid]
    with fleet.serve(persist_stats=False) as svc:
        got = svc.lookup(flat)
        got_b = np.concatenate(svc.lookup_batches(batches))
    return {
        "scatter_gather_identical": bool(np.array_equal(got, want)),
        "batches_identical": bool(np.array_equal(got_b, want)),
    }


def _serve_mono(idx: Index, budget: int, batches, workdir, tag) -> dict:
    path = os.path.join(workdir, f"mono_{tag}.air")
    idx.save(path)
    with IndexService(path, profile=FLEET_TIER,
                      spec=ServeSpec(cache_bytes=(budget,))) as svc:
        svc.lookup_batches(batches)
        s = svc.stats
        return {"candidate": tag, "design": idx.describe(),
                "observed_us": s.query_modeled_seconds * 1e6,
                "hit_rate": s.hit_rate, "preads": s.preads}


def _serve_fleet(fleet, budget: int, batches) -> dict:
    with fleet.serve(total_cache_bytes=budget) as svc:
        svc.lookup_batches(batches)
        return svc.stats_summary()


def run_fleet_bench(n_keys: int = FLEET_N_KEYS,
                    record: int = FLEET_RECORD) -> dict:
    """Per-shard-tuned fleet vs one monolithic index, same storage tier,
    same total cache budget, same skewed stream.

    Phase 1 serves both raw-tier-tuned; phase 2 gives the fleet
    ``Fleet.retune_budgeted`` (steady-state per-shard retune + water-
    filled budget) and gives the monolith the same intelligence as three
    candidates — raw-tuned, fully-cached-tuned, and planned-hit-rate-
    tuned — keeping its realized best.  Gates: scatter-gather identity
    (fatal) and phase-2 fleet strictly below the monolith's best (fatal).
    """
    workdir = tempfile.mkdtemp(prefix="fleet_bench_")
    keys = sosd_like("gmm", n_keys)
    D = KeyPositions.fixed_record(keys, record)
    backing = PROFILES[FLEET_TIER]
    dram = PROFILES["host_dram"]
    fspec = FleetSpec(n_shards=FLEET_SHARDS, tune=FLEET_TUNE,
                      serve=ServeSpec(persist_stats=True))

    # monolith candidates: raw + the same ladder the fleet gets
    t0 = time.perf_counter()
    mono_raw = Index.tune(D, FLEET_TIER, FLEET_TUNE).build()
    mono_tune_s = time.perf_counter() - t0
    ws_raw = demand_from_design(0, mono_raw.result.design,
                                backing, cache=dram).working_set
    mono_h1 = Index.tune(D, CachedProfile(backing=backing, cache=dram,
                                          hit_rate=1.0), FLEET_TUNE).build()
    ws_h1 = demand_from_design(0, mono_h1.result.design,
                               backing, cache=dram).working_set
    # budget = 1.25x one shard's slice of the monolith's fully-cached
    # working set: scarce against the monolith's fine design (~0.31x) and
    # against the fleet's total steady-state demand, so water-filling has
    # to choose — roughly the hot shards' working sets and nothing else
    budget = max(PAGE, (int(1.25 * ws_h1 / FLEET_SHARDS) + PAGE - 1)
                 // PAGE * PAGE)
    monos = [(mono_raw, "raw"), (mono_h1, "h1.0")]
    hp = min(1.0, budget / ws_h1) if ws_h1 > 0 else 0.0
    if 0.0 < hp < 1.0:
        monos.append((Index.tune(D, CachedProfile(backing=backing,
                                                  cache=dram, hit_rate=hp),
                                 FLEET_TUNE).build(), f"h{hp:.2f}"))

    # fleet phase 1: raw per-shard tuning
    t0 = time.perf_counter()
    fleet1 = Fleet.tune(D, FLEET_TIER, fspec).build()
    fleet_tune_s = time.perf_counter() - t0
    dir1 = os.path.join(workdir, "fleet_raw")
    fleet1.save(dir1)

    rng = np.random.default_rng(42)
    batches = _fleet_stream(keys, fleet1.shard_map, rng)

    identity = _fleet_identity(fleet1, batches, FLEET_TIER)
    phase1 = _serve_fleet(fleet1, budget, batches)   # persists shard stats

    mono_rows = [_serve_mono(idx, budget, batches, workdir, tag)
                 for idx, tag in monos]

    # fleet phase 2: observed-traffic retune + water-filled budget
    t0 = time.perf_counter()
    fleet2, plan = Fleet.open(dir1, data=D).retune_budgeted(
        data=D, total_cache_bytes=budget)
    fleet2.build()
    retune_s = time.perf_counter() - t0
    dir2 = os.path.join(workdir, "fleet_budgeted")
    fleet2.save(dir2)
    phase2 = _serve_fleet(Fleet.open(dir2), budget, batches)

    mono_best = min(mono_rows, key=lambda r: r["observed_us"])
    us_fleet = phase2["query_modeled_us"]
    return {
        "n_keys": int(D.n), "record": record, "tier": FLEET_TIER,
        "n_shards": FLEET_SHARDS, "weights": list(FLEET_WEIGHTS),
        "cache_budget_bytes": budget,
        "mono_working_set_raw": int(ws_raw),
        "identity": identity,
        "mono": mono_rows,
        "mono_best": mono_best,
        "fleet_phase1": phase1,
        "fleet_phase2": phase2,
        "plan": plan.to_dict(),
        "shard_designs": [idx.describe() for idx in fleet2.shards],
        "wall": {"mono_tune_s": mono_tune_s, "fleet_tune_s": fleet_tune_s,
                 "fleet_retune_s": retune_s},
        "fleet_vs_mono": us_fleet / max(mono_best["observed_us"], 1e-12),
        "identical": bool(identity["scatter_gather_identical"]
                          and identity["batches_identical"]),
        "fleet_beats_monolith": bool(
            us_fleet < 0.999 * mono_best["observed_us"]),
    }


def emit_fleet(results: dict) -> None:
    emit("fleet_identity", 0.0,
         f"scatter_gather={results['identity']['scatter_gather_identical']} "
         f"batches={results['identity']['batches_identical']}")
    emit("fleet_phase1_raw", results["fleet_phase1"]["query_modeled_us"],
         f"hit_rate={results['fleet_phase1']['hit_rate']:.3f} "
         f"preads={results['fleet_phase1']['preads']}")
    for r in results["mono"]:
        emit(f"fleet_mono_{r['candidate']}", r["observed_us"],
             f"hit_rate={r['hit_rate']:.3f} preads={r['preads']}")
    emit("fleet_phase2_budgeted",
         results["fleet_phase2"]["query_modeled_us"],
         f"hit_rate={results['fleet_phase2']['hit_rate']:.3f} "
         f"preads={results['fleet_phase2']['preads']} "
         f"budget={results['cache_budget_bytes']}")
    shares = (results["fleet_phase2"].get("plan") or {}).get("shares", {})
    emit("fleet_cache_plan", 0.0,
         f"shares={shares} budget={results['cache_budget_bytes']}")
    emit("fleet_vs_monolith", 0.0,
         f"ratio={results['fleet_vs_mono']:.4f} "
         f"mono_best={results['mono_best']['candidate']} "
         f"beats={results['fleet_beats_monolith']}")


# ---------------------------------------------------------------------------
# chaos gate (--chaos / --chaos-only) — BENCH_chaos.json
# ---------------------------------------------------------------------------
CHAOS_PAGE = 1024
CHAOS_RETRY = RetryPolicy(max_attempts=4, backoff_s=1e-5, max_backoff_s=1e-3)
CHAOS_SPEC = ServeSpec(cache_bytes=(64 << 10,), retry=CHAOS_RETRY)
# every recoverable schedule the engine must serve bit-identically through;
# corrupt schedules gate on multi-page reads so the engine's single-page
# repair refetch comes back clean (its window key differs, but an unbounded
# rate would re-corrupt it)
CHAOS_SCHEDULES = (
    ("eio", dict(eio_rate=0.3, eio_attempts=2)),
    ("torn_read", dict(short_rate=0.4, short_attempts=2)),
    ("stall", dict(stall_rate=0.3, stall_seconds=2e-4, stall_attempts=1)),
    ("corrupt", dict(corrupt_rate=1.0, corrupt_attempts=1,
                     only_over_bytes=CHAOS_PAGE)),
    ("flaky_start", dict(fail_first=3)),
    # coalesced runs fail persistently, single pages succeed: the engine
    # must fall back to page-granularity fetches (graceful degradation)
    ("degraded_split", dict(eio_rate=1.0, eio_attempts=None,
                            only_over_bytes=CHAOS_PAGE)),
    ("combined", dict(eio_rate=0.4, eio_attempts=1, short_rate=0.4,
                      short_attempts=1, corrupt_rate=0.8, corrupt_attempts=1,
                      stall_rate=0.3, stall_seconds=2e-4, stall_attempts=1,
                      only_over_bytes=CHAOS_PAGE)),
)


def _chaos_counters(svc: IndexService) -> dict:
    s = svc.stats
    return {"preads": s.preads, "io_retries": s.io_retries,
            "io_timeouts": s.io_timeouts, "degraded_runs": s.degraded_runs,
            "corrupt_pages": s.corrupt_pages,
            "tainted_samples": sum(1 for r in s.read_samples if r[3])}


def _chaos_design(D: KeyPositions):
    """A dense 3-layer stack (hundreds of disk pages) — the demo design is
    a handful of pages that fit the cache whole, which would let most
    fault schedules run to completion without a single pread to fault."""
    from repro.core import IndexDesign
    from repro.core.builders import build_gband, build_gstep
    from repro.core.nodes import outline
    l1 = build_gstep(D, 8, 2**6)
    o1 = outline(l1, D)
    l2 = build_gband(o1, 2**9)
    l3 = build_gstep(outline(l2, o1), 8, 2**7)
    return IndexDesign(layers=(l1, l2, l3), data=D)


def _chaos_alt_design(D: KeyPositions):
    """A structurally different stack over the same data, distinguishable
    from the demo design by its windows — what a retune would hot-swap in."""
    from repro.core import IndexDesign
    from repro.core.builders import build_gband, build_gstep
    from repro.core.nodes import outline
    l1 = build_gstep(D, 8, 2**9)
    o1 = outline(l1, D)
    l2 = build_gband(o1, 2**8)
    l3 = build_gstep(outline(l2, o1), 8, 2**6)
    return IndexDesign(layers=(l1, l2, l3), data=D)


def _chaos_schedules_row(path, queries, want, meta_end: int,
                         resident_bytes: int) -> list:
    # schedules gate past the meta region: a dense schedule over the
    # multi-window header parse can exhaust the whole open budget before
    # a single data page is served (persistent header failure is its own
    # scenario under typed_failures); open-time resident-layer loads and
    # all serving preads still run through the fault schedule
    rows = []
    for name, kw in CHAOS_SCHEDULES:
        kw = dict(kw)
        if name == "degraded_split":
            # persistent failure for *coalesced* runs only: the gate must
            # also clear the one-shot resident-layer blob load at open,
            # which has no finer granularity to degrade to
            kw["only_over_bytes"] = max(CHAOS_PAGE, resident_bytes)
        svc = IndexService(
            path, profile=None, spec=CHAOS_SPEC,
            backend_factory=lambda p: FaultInjectingBackend(
                FileBackend(p), seed=11, page_bytes=CHAOS_PAGE,
                only_from_offset=meta_end, **kw))
        try:
            t0 = time.perf_counter()
            got = svc.lookup(queries)
            wall = time.perf_counter() - t0
            rows.append({"schedule": name,
                         "identical": bool(np.array_equal(want, got)),
                         "qps": len(queries) / max(wall, 1e-9),
                         **_chaos_counters(svc)})
        finally:
            svc.close()
    return rows


def _chaos_typed_failures(path, queries, meta_end: int) -> dict:
    """Past-the-budget failures must surface as *typed* errors, never as
    silent wrong answers or a bare OSError out of the engine's guts."""
    from repro.serve import CorruptPageError
    out = {}
    # the typed error may surface at open (resident-layer load) or at the
    # first lookup — both are honest fail-stops; a silent wrong answer or
    # a bare OSError out of the engine's guts is the regression
    svc = None
    try:
        svc = IndexService(
            path, profile=None, spec=CHAOS_SPEC,
            backend_factory=lambda p: FaultInjectingBackend(
                FileBackend(p), seed=2, eio_rate=1.0, eio_attempts=None,
                only_from_offset=meta_end))
        svc.lookup(queries)
        out["persistent_eio"] = {"raised": None, "ok": False}
    except ReadError as e:
        out["persistent_eio"] = {"raised": type(e).__name__,
                                 "attempts": e.attempts,
                                 "ok": e.attempts == CHAOS_RETRY.max_attempts}
    except StorageError as e:   # wrong subtype: typed but not honest
        out["persistent_eio"] = {"raised": type(e).__name__, "ok": False}
    finally:
        if svc is not None:
            svc.close()
    svc = None
    try:
        svc = IndexService(
            path, profile=None, spec=CHAOS_SPEC,
            backend_factory=lambda p: FaultInjectingBackend(
                FileBackend(p), seed=2, corrupt_rate=1.0,
                corrupt_attempts=10**9, page_bytes=CHAOS_PAGE,
                only_from_offset=meta_end))
        svc.lookup(queries)
        out["persistent_corruption"] = {"raised": None, "ok": False}
    except CorruptPageError as e:
        out["persistent_corruption"] = {"raised": type(e).__name__,
                                        "page_id": e.page_id, "ok": True}
    except StorageError as e:
        out["persistent_corruption"] = {"raised": type(e).__name__,
                                        "ok": False}
    finally:
        if svc is not None:
            svc.close()
    return out


def _chaos_swap(path_a, path_b, keys) -> dict:
    """Hot-swap under live traffic: a hammer thread runs ``lookup_batches``
    while the main thread swaps between two designs — every batch must be
    served wholly by one epoch (old or new windows, never a row-mix)."""
    import threading
    rng = np.random.default_rng(3)
    batches = [rng.choice(keys, 256) for _ in range(6)]
    spec = CHAOS_SPEC.replace(pipeline_depth=2)
    with IndexService(path_a, profile=None, spec=spec) as svc:
        want_a = [svc.lookup(b) for b in batches]
    with IndexService(path_b, profile=None, spec=spec) as svc:
        want_b = [svc.lookup(b) for b in batches]

    results, errors, stop = [], [], threading.Event()
    svc = IndexService(path_a, profile=None, spec=spec)

    def hammer():
        try:
            while not stop.is_set():
                results.append(svc.lookup_batches(batches))
        except Exception as e:
            errors.append(f"{type(e).__name__}: {e}")

    t = threading.Thread(target=hammer)
    t0 = time.perf_counter()
    t.start()
    n_swaps = 8
    try:
        for k in range(n_swaps):
            svc.swap(path_b if k % 2 == 0 else path_a)
            time.sleep(0.005)
    finally:
        stop.set()
        t.join()
        wall = time.perf_counter() - t0
        swaps_recorded = svc.stats.swaps
        svc.close()
    mixed = 0
    for run in results:
        for i, got in enumerate(run):
            if not (np.array_equal(got, want_a[i])
                    or np.array_equal(got, want_b[i])):
                mixed += 1
    served = sum(len(run) * 256 for run in results)
    return {"swaps": n_swaps, "swaps_recorded": swaps_recorded,
            "batch_runs": len(results), "errors": errors,
            "mixed_batches": mixed,
            "qps_during_swaps": served / max(wall, 1e-9),
            "ok": bool(results) and not errors and mixed == 0}


class _ChaosDeadShard(FileBackend):
    """Healthy through open, then every pread raises — a shard whose disk
    died under a live fleet."""

    armed = False

    def pread(self, nbytes, offset):
        if _ChaosDeadShard.armed:
            import errno
            raise OSError(errno.EIO, "chaos: dead shard")
        return super().pread(nbytes, offset)


def _chaos_fleet(D: KeyPositions, workdir: str) -> dict:
    """One shard of three dies under traffic: the default contract is a
    typed fail-stop, ``partial_results=True`` must keep serving the two
    healthy shards bit-identically with an honest unavailable mask."""
    from repro.fleet.fleet import _partition
    from repro.fleet.service import FleetService
    from repro.fleet.spec import ShardMap
    shard_map = ShardMap.even_keys(D.keys, 3)
    parts, bases = _partition(D, shard_map)
    paths = []
    for i, part in enumerate(parts):
        p = os.path.join(workdir, f"chaos_shard_{i}.air")
        write_index(p, _chaos_design(part), page_bytes=CHAOS_PAGE)
        paths.append(p)
    rng = np.random.default_rng(2)
    qs = rng.choice(D.keys, 1024)
    with FleetService(shard_map, paths, bases, profile=None,
                      specs=[CHAOS_SPEC] * 3) as svc:
        want = svc.lookup(qs)
    sick = 1
    _ChaosDeadShard.armed = False

    def factory(p):
        return _ChaosDeadShard(p) if p == paths[sick] else FileBackend(p)

    row = {"n_shards": 3, "sick_shard": sick}
    with FleetService(shard_map, paths, bases, profile=None,
                      specs=[CHAOS_SPEC] * 3,
                      backend_factories=factory) as svc:
        _ChaosDeadShard.armed = True
        try:
            svc.lookup(qs)
            row["fail_stop"] = {"raised": None, "ok": False}
        except ShardUnavailableError as e:
            row["fail_stop"] = {"raised": type(e).__name__, "shard": e.shard,
                                "ok": e.shard == sick}
        out, avail = svc.lookup(qs, partial_results=True)
        sick_keys = shard_map.route(qs) == sick
        row["degraded"] = {
            "mask_honest": bool(np.array_equal(avail, ~sick_keys)),
            "healthy_identical": bool(
                np.array_equal(out[avail], want[avail])),
            "unavailable_fraction": float(sick_keys.mean()),
        }
        summary = svc.stats_summary()
        row["summary_unhealthy"] = summary["unhealthy_shards"]
        row["ok"] = bool(row["fail_stop"]["ok"]
                         and row["degraded"]["mask_honest"]
                         and row["degraded"]["healthy_identical"]
                         and summary["unhealthy_shards"] == 1)
    _ChaosDeadShard.armed = False
    return row


def run_chaos_bench(n_keys: int = 60_000, n_queries: int = 2048) -> dict:
    keys = sosd_like("gmm", n_keys)
    D = KeyPositions.fixed_record(keys, RECORD)
    workdir = tempfile.mkdtemp(prefix="chaos_bench_")
    path = os.path.join(workdir, "index.air")
    write_index(path, _chaos_design(D), page_bytes=CHAOS_PAGE)
    alt = os.path.join(workdir, "alt.air")
    write_index(alt, _chaos_alt_design(D), page_bytes=CHAOS_PAGE)
    rng = np.random.default_rng(0)
    queries = rng.choice(D.keys, n_queries)

    svc = IndexService(path, profile=None, spec=CHAOS_SPEC)
    try:
        meta_end = min(lm.offset for lm in svc.meta.layers)
        n_res = len(svc._st.prefix)
        resident_bytes = max(
            (lm.size for lm in svc.meta.layers[len(svc.meta.layers) - n_res:]),
            default=0)
        t0 = time.perf_counter()
        want = svc.lookup(queries)
        clean_wall = time.perf_counter() - t0
    finally:
        svc.close()
    clean_qps = n_queries / max(clean_wall, 1e-9)

    results = {"n_keys": int(D.n), "n_queries": int(n_queries),
               "page_bytes": CHAOS_PAGE,
               "retry": CHAOS_RETRY.to_dict(),
               "clean_qps": clean_qps,
               "schedules": _chaos_schedules_row(path, queries, want,
                                                 meta_end, resident_bytes),
               "typed_failures": _chaos_typed_failures(path, queries,
                                                       meta_end),
               "swap_under_traffic": _chaos_swap(path, alt, D.keys),
               "fleet_degradation": _chaos_fleet(D, workdir)}
    for row in results["schedules"]:
        row["qps_vs_clean"] = row["qps"] / max(clean_qps, 1e-9)
    results["acceptance_chaos"] = bool(
        all(r["identical"] for r in results["schedules"])
        and all(v["ok"] for v in results["typed_failures"].values())
        and results["swap_under_traffic"]["ok"]
        and results["fleet_degradation"]["ok"])
    return results


def emit_chaos(results: dict) -> None:
    for r in results["schedules"]:
        emit(f"chaos_{r['schedule']}", 0.0,
             f"identical={r['identical']} qps={r['qps']:.0f} "
             f"({r['qps_vs_clean']:.2f}x clean) retries={r['io_retries']} "
             f"degraded={r['degraded_runs']} crc={r['corrupt_pages']}")
    for name, v in results["typed_failures"].items():
        emit(f"chaos_{name}", 0.0, f"raised={v['raised']} ok={v['ok']}")
    sw = results["swap_under_traffic"]
    emit("chaos_swap_under_traffic", 0.0,
         f"ok={sw['ok']} swaps={sw['swaps']} runs={sw['batch_runs']} "
         f"mixed={sw['mixed_batches']} qps={sw['qps_during_swaps']:.0f}")
    fl = results["fleet_degradation"]
    emit("chaos_fleet_degradation", 0.0,
         f"ok={fl['ok']} fail_stop={fl['fail_stop']['raised']} "
         f"mask_honest={fl['degraded']['mask_honest']} "
         f"unavailable={fl['degraded']['unavailable_fraction']:.2f}")
    emit("chaos_acceptance", 0.0,
         f"identity_under_faults={results['acceptance_chaos']}")


def chaos_fatal_warnings(results: dict) -> list:
    """FATAL list for the chaos gate: identity and typed-error contracts.
    Wall-clock degradation under faults only warns (the injected stalls
    and backoffs *should* cost something)."""
    fatal = []
    bad = [r["schedule"] for r in results["schedules"]
           if not r["identical"]]
    if bad:
        fatal.append(f"chaos: results diverged under recoverable fault "
                     f"schedules {bad} — retries/repairs must be "
                     f"invisible in lookup results")
    for name, v in results["typed_failures"].items():
        if not v["ok"]:
            fatal.append(f"chaos: {name} did not surface the typed error "
                         f"(raised={v['raised']})")
    sw = results["swap_under_traffic"]
    if not sw["ok"]:
        fatal.append(f"chaos: hot swap under traffic broke epoch isolation "
                     f"(mixed={sw['mixed_batches']}, errors={sw['errors']})")
    fl = results["fleet_degradation"]
    if not fl["ok"]:
        fatal.append("chaos: fleet shard degradation contract failed "
                     f"(fail_stop={fl['fail_stop']}, "
                     f"degraded={fl['degraded']})")
    for r in results["schedules"]:
        if r["qps_vs_clean"] < 0.05:
            print(f"::warning::chaos schedule {r['schedule']} qps collapsed "
                  f"to {r['qps_vs_clean']:.3f}x of fault-free serving")
    return fatal


# ---------------------------------------------------------------------------
# tail-latency gate (--p99 / --p99-only) — BENCH_p99.json
# ---------------------------------------------------------------------------
# The end-to-end tail-tuning loop: calibrate a stall-heavy *data* tier
# through the fault backend into a DistributionalProfile (ServeStats
# pread reservoir → distributional_backing_profile), tune the SAME data
# twice — mean objective vs E[T] + w·Q_0.99[T] — and serve both
# head-to-head against the SAME bursty tier, judging on realized
# per-lookup wall clock (engine walk + the final data-range read).
#
# The simulated deployment: the index file sits on a throttled but
# *reliable* tier (every pread sleeps ℓ + Δ/B), while the records live
# on a remote tier with the same affine cost plus a heavy stall tail —
# reads strictly wider than P99_STALL_OVER stall P99_STALL_SECONDS at
# rate P99_STALL_RATE (deterministic per window, unbounded attempts, so
# the schedule holds for the whole run).  Large records put the
# objectives in real tension: narrow (stall-safe) data windows need a
# deeper/fatter index — extra ℓ per lookup — while wide windows are
# cheaper in expectation (stall *mass* rate·stall ≈ 0.3 ms < ℓ) but
# carry the tail (surcharge ≈ rate·stall·w/(1−p) ≈ 30 ms).  The mean
# objective buys the wide windows; the p99 objective refuses them.
# Both tunes see the same fitted profile; only the objective differs.
P99_OBJECTIVE = {"p": 0.99, "weight": 1.0}
P99_N_KEYS = 400_000
P99_RECORD = 1024              # bytes per record (the data tier is wide)
P99_PAGE = 4096
P99_BASE_SLEEP = 1e-3          # ℓ of the simulated tiers (s per pread)
P99_BANDWIDTH = 256e6          # B of the simulated tiers (bytes/s)
P99_STALL_OVER = 32768         # data reads strictly wider can stall
P99_STALL_RATE = 0.03          # fraction of wide windows that stall
P99_STALL_SECONDS = 10e-3      # the stall itself (heavy tail >> ℓ)
P99_SEED = 5
# calibration grid: sizes × probes lands exactly at the reservoir cap, so
# the fit sees every probe (no subsampling noise on the tail estimate)
P99_CAL_SIZES = (4096, 16384, 32768, 49152, 65536, 131072, 262144)
P99_CAL_PROBES = 73            # 7 × 73 = 511 ≤ READ_SAMPLE_CAP
P99_LOOKUPS = 1200
P99_SPEC = TuneSpec(lam_low=2**10, lam_high=2**19, lam_base=2.0, k=4,
                    max_layers=6, page_bytes=P99_PAGE)
P99_SERVE_SPEC = ServeSpec(cache_bytes=(P99_PAGE,))   # ~no cache: every
#                            lookup pays the tier, stalls stay exposed


class _ThrottledBackend(FileBackend):
    """Simulated slow tier over a local file: ℓ + Δ/B of sleep per
    pread, then real bytes — realized wall clock, not a model, is what
    the two tuning arms are judged on."""

    def pread(self, nbytes: int, offset: int) -> bytes:
        time.sleep(P99_BASE_SLEEP + nbytes / P99_BANDWIDTH)
        return super().pread(nbytes, offset)


def _p99_data_backend(data_path: str) -> FaultInjectingBackend:
    """The record tier: throttled + the heavy-tailed stall schedule."""
    return FaultInjectingBackend(
        _ThrottledBackend(data_path), seed=P99_SEED,
        stall_rate=P99_STALL_RATE, stall_seconds=P99_STALL_SECONDS,
        stall_attempts=10**9, only_over_bytes=P99_STALL_OVER,
        page_bytes=P99_PAGE)


def _p99_calibrate(data_path: str) -> tuple:
    """The §3.2 profiling pass, distribution-aware: probe the (bursty)
    record tier at a grid of read sizes through the ServeStats pread
    reservoir and fit the DistributionalProfile tuning consumes."""
    be = _p99_data_backend(data_path)
    st = ServeStats()
    rng = np.random.default_rng(17)
    try:
        size = be.size()
        for nbytes in P99_CAL_SIZES:
            pages = max((size - nbytes) // P99_PAGE, 1)
            for _ in range(P99_CAL_PROBES):
                off = int(rng.integers(0, pages)) * P99_PAGE
                t0 = time.perf_counter()
                be.pread(nbytes, off)
                st.record_read(nbytes, time.perf_counter() - t0)
    finally:
        be.close()
    prof = distributional_backing_profile(st)
    if prof is None:
        raise RuntimeError("p99 calibration failed to fit a profile")
    return prof, st


def _p99_layers_identical(a, b) -> bool:
    if len(a) != len(b):
        return False
    for la, lb in zip(a, b):
        if la.kind != lb.kind:
            return False
        fields = (("piece_keys", "piece_pos", "node_piece_off")
                  if la.kind == "step" else ("node_keys", "x1", "y1", "m",
                                             "delta"))
        if not all(np.array_equal(getattr(la, f), getattr(lb, f))
                   for f in fields):
            return False
    return True


def _p99_serve(index_path: str, data_path: str, queries: np.ndarray,
               warmup: int = 16) -> dict:
    """Serve single-query lookups end to end: the engine walks the index
    through the throttled (reliable) tier, then the returned data-layer
    byte range is read through the bursty record tier — the Eq. 6 data
    read, realized.  Realized wall per lookup (engine + data read) is
    the judged quantity; a second ServeStats fed the end-to-end walls
    exercises the online reservoir p50/p99 estimator on the same stream.
    """
    walls = []
    svc = IndexService(index_path, profile=None, spec=P99_SERVE_SPEC,
                       backend_factory=_ThrottledBackend)
    data_be = _p99_data_backend(data_path)
    e2e = ServeStats()
    try:
        for q in queries[:warmup]:          # page-walk + kernel warmup
            svc.lookup(np.array([q], dtype=np.uint64))
        for q in queries:
            t0 = time.perf_counter()
            out = svc.lookup(np.array([q], dtype=np.uint64))
            lo, hi = int(out[0, 0]), int(out[0, 1])
            data_be.pread(max(hi - lo, 1), lo)
            wall = time.perf_counter() - t0
            walls.append(wall)
            e2e.record_lookup(1, wall)
        online_p50 = e2e.lookup_quantile(0.5)
        online_p99 = e2e.lookup_quantile(0.99)
        s = svc.stats
        counters = {"index_preads": int(s.preads),
                    "data_preads": len(walls),
                    "hit_rate": float(s.hit_rate)}
    finally:
        data_be.close()
        svc.close()
    w = np.asarray(walls, dtype=np.float64)
    return {
        "lookups": len(walls),
        "mean_us": float(w.mean() * 1e6),
        "p50_us": float(np.percentile(w, 50) * 1e6),
        "p99_us": float(np.percentile(w, 99) * 1e6),
        "online_p50_us": (online_p50 * 1e6
                          if online_p50 is not None else None),
        "online_p99_us": (online_p99 * 1e6
                          if online_p99 is not None else None),
        **counters,
    }


def run_p99_bench(n_keys: int = P99_N_KEYS,
                  n_lookups: int = P99_LOOKUPS) -> dict:
    keys = sosd_like("gmm", n_keys)
    D = KeyPositions.fixed_record(keys, P99_RECORD)
    workdir = tempfile.mkdtemp(prefix="p99_bench_")

    # the record tier itself: a sparse file spanning the data extent (the
    # bytes read are zeros — only offsets/sizes matter to the simulated
    # tier), giving calibration a real window population to sample
    data_path = os.path.join(workdir, "records.dat")
    with open(data_path, "wb") as f:
        f.truncate(int(D.n) * P99_RECORD)
    t0 = time.perf_counter()
    fitted, cal_stats = _p99_calibrate(data_path)
    cal_wall = time.perf_counter() - t0

    # head-to-head tunes over the SAME fitted profile
    spec_mean = P99_SPEC
    spec_p99 = P99_SPEC.replace(objective=P99_OBJECTIVE)
    mean_idx = Index.tune(D, fitted, spec_mean).build()
    p99_idx = Index.tune(D, fitted, spec_p99).build()

    # identity gate: the facade's default ("mean") objective must be
    # bit-identical to a direct strategy call without the kwarg at all
    raw = airtune(D, fitted, spec_mean.builders(), k=spec_mean.k,
                  max_layers=spec_mean.max_layers)
    identity = bool(raw.cost == mean_idx.result.cost
                    and raw.builder_names == mean_idx.result.builder_names
                    and _p99_layers_identical(raw.design.layers,
                                              mean_idx.result.design.layers))
    designs_differ = not (
        mean_idx.result.builder_names == p99_idx.result.builder_names
        and _p99_layers_identical(mean_idx.result.design.layers,
                                  p99_idx.result.design.layers))

    p, w = P99_OBJECTIVE["p"], P99_OBJECTIVE["weight"]
    predicted = {
        arm: {
            "mean_us": expected_latency(idx.result.design, fitted) * 1e6,
            "p99_us": quantile_latency(idx.result.design, fitted, p) * 1e6,
        }
        for arm, idx in (("mean", mean_idx), ("p99", p99_idx))}

    mean_path = os.path.join(workdir, "tuned_mean.air")
    p99_path = os.path.join(workdir, "tuned_p99.air")
    mean_idx.save(mean_path)
    p99_idx.save(p99_path)

    rng = np.random.default_rng(1)
    queries = rng.choice(D.keys, n_lookups)
    realized = {"mean": _p99_serve(mean_path, data_path, queries),
                "p99": _p99_serve(p99_path, data_path, queries)}

    results = {
        "n_keys": int(D.n), "n_lookups": int(n_lookups),
        "record_bytes": P99_RECORD,
        "page_bytes": P99_PAGE, "objective": P99_OBJECTIVE,
        "tier": {"base_sleep_s": P99_BASE_SLEEP,
                 "bandwidth": P99_BANDWIDTH,
                 "stall_over_bytes": P99_STALL_OVER,
                 "stall_rate": P99_STALL_RATE,
                 "stall_seconds": P99_STALL_SECONDS},
        "calibration": {
            "probes": len(cal_stats.read_samples),
            "sizes": list(P99_CAL_SIZES),
            "wall_s": cal_wall,
            "fitted_profile": profile_to_dict(fitted),
        },
        "designs": {"mean": mean_idx.describe(), "p99": p99_idx.describe()},
        "recorded_objectives": {
            "mean": mean_idx.result.objective,
            "p99": p99_idx.result.objective},
        "predicted": predicted,
        "realized": realized,
        "identity_mean_objective": identity,
        "designs_differ": designs_differ,
        "p99_wins_realized_p99":
            bool(realized["p99"]["p99_us"] < realized["mean"]["p99_us"]),
        "mean_regression_ratio":
            realized["p99"]["mean_us"] / max(realized["mean"]["mean_us"],
                                             1e-12),
    }
    return results


def emit_p99(results: dict) -> None:
    emit("p99_identity", 0.0,
         f"mean_objective_bit_identical={results['identity_mean_objective']}")
    for arm in ("mean", "p99"):
        r = results["realized"][arm]
        pr = results["predicted"][arm]
        emit(f"p99_tuned_{arm}", r["p99_us"],
             f"mean={r['mean_us']:.0f}us p50={r['p50_us']:.0f}us "
             f"p99={r['p99_us']:.0f}us "
             f"(online_p99={r['online_p99_us'] or float('nan'):.0f}us, "
             f"predicted_p99={pr['p99_us']:.0f}us) "
             f"index_preads={r['index_preads']}")
    emit("p99_acceptance", 0.0,
         f"designs_differ={results['designs_differ']} "
         f"p99_wins={results['p99_wins_realized_p99']} "
         f"mean_ratio={results['mean_regression_ratio']:.2f}")


def p99_fatal_warnings(results: dict) -> list:
    """FATAL list for the tail-latency gate: the mean-objective identity
    and the head-to-head realized-p99 win.  A realized *mean* regression
    of the p99-tuned design only warns — trading some expectation for the
    tail is the objective working as designed, but a large regression
    deserves eyes."""
    fatal = []
    if not results["identity_mean_objective"]:
        fatal.append("p99: objective='mean' tune diverged from the "
                     "pre-objective search — the default must stay "
                     "bit-identical")
    if not results["designs_differ"]:
        fatal.append("p99: mean- and p99-tuned designs are identical — "
                     "the scenario no longer separates the objectives "
                     "(retune the bench knobs)")
    if not results["p99_wins_realized_p99"]:
        fatal.append(
            f"p99: tail-tuned design lost on realized p99 "
            f"({results['realized']['p99']['p99_us']:.0f}us vs "
            f"mean-tuned {results['realized']['mean']['p99_us']:.0f}us)")
    if results["mean_regression_ratio"] > 2.0:
        print(f"::warning::p99-tuned design's realized mean is "
              f"{results['mean_regression_ratio']:.2f}x the mean-tuned "
              f"design's (expected to trade some mean for tail, but check "
              f"the margin)")
    return fatal


def run_serve_bench(n_keys: int = N_KEYS, n_queries: int = 4096) -> dict:
    keys = sosd_like("gmm", n_keys)
    D = KeyPositions.fixed_record(keys, RECORD)
    design = build_serving_design(D)
    path = os.path.join(tempfile.mkdtemp(prefix="serve_bench_"), "index.air")
    idx = Index.from_design(design, spec=TuneSpec(page_bytes=PAGE))
    idx.save(path)
    rng = np.random.default_rng(0)
    queries = rng.choice(D.keys, n_queries)

    results = {"design": design.describe(), "page_bytes": PAGE,
               "n_keys": int(D.n), "n_queries": int(n_queries),
               "cold_warm": [], "cache_sweep": [],
               "expected_latency_us": {
                   t: expected_latency(design, PROFILES[t]) * 1e6
                   for t in TIERS}}
    for tier in TIERS:
        cw = bench_cold_warm(idx, tier, queries)
        results["cold_warm"].append(cw)
        emit(f"serve_cold_{tier}", cw["cold"]["modeled_seconds"] * 1e6,
             f"bytes={cw['cold']['bytes_fetched']} preads={cw['cold']['preads']}"
             f" qps={cw['cold']['qps']:.0f}")
        emit(f"serve_warm_{tier}", cw["warm"]["modeled_seconds"] * 1e6,
             f"bytes={cw['warm']['bytes_fetched']} preads={cw['warm']['preads']}"
             f" qps={cw['warm']['qps']:.0f}"
             f" fewer_bytes={cw['warm_fewer_bytes']}"
             f" faster_modeled={cw['warm_faster_modeled']}")
        for row in bench_cache_sweep(idx, tier, D.keys):
            results["cache_sweep"].append(row)
            emit(f"serve_sweep_{tier}_{row['cache_bytes'] >> 10}KiB",
                 row["modeled_seconds"] * 1e6,
                 f"hit_rate={row['hit_rate']:.3f} qps={row['qps']:.0f} "
                 f"bytes={row['bytes_fetched']}")
    results["engine_vs_scalar"] = bench_engine_vs_scalar(idx, queries)
    ev = results["engine_vs_scalar"]
    emit("serve_engine_vs_scalar", 0.0,
         f"engine={ev['engine_qps']:.0f}q/s scalar={ev['scalar_qps']:.0f}q/s "
         f"speedup={ev['speedup']:.1f}x")

    pipe = bench_pipeline(idx, D.keys)
    results["pipeline"] = pipe
    emit(f"serve_pipeline_{DRIFT_SERVED}",
         pipe["roofline_on"]["io_seconds"] * 1e6,
         f"identical={pipe['identical']} qps_on={pipe['qps_on']:.0f} "
         f"qps_off={pipe['qps_off']:.0f} "
         f"io_fraction={pipe['roofline_on']['io_fraction']:.3f} "
         f"bound={pipe['roofline_on']['bound']} "
         f"overlapped_preads={pipe['overlapped_preads']}")

    workdir = os.path.dirname(path)
    drift = bench_drift(D, workdir)
    results["drift"] = drift
    emit(f"serve_drift_{DRIFT_TUNED}_to_{DRIFT_SERVED}",
         drift["report"]["observed_us"] or 0.0,
         f"ratio={drift['report']['ratio']:.2f} "
         f"action={drift['report']['action']} "
         f"hit_rate={drift['report']['hit_rate']:.3f}")
    emit("serve_drift_retune", drift["warm"]["cost_us"],
         f"recovery={drift['recovery_ratio']:.4f} "
         f"warm_built={drift['warm']['built']} "
         f"cold_built={drift['cold']['built']} "
         f"reused={drift['warm']['reused']} "
         f"work_reduction={drift['work_reduction']:.1f}x")

    results["baseline_serve"] = []
    for tier in ("azure_ssd", "azure_hdd"):
        bs = bench_baseline_serve(D, tier, workdir)
        results["baseline_serve"].append(bs)
        for name, r in bs["designs"].items():
            mg = r.get("margin_vs_airtune")
            emit(f"serve_baseline_{tier}_{name}", r["observed_us"],
                 f"hit_rate={r['hit_rate']:.3f} qps={r['qps']:.0f}"
                 + (f" margin={mg:.2f}x" if mg is not None else ""))
        emit(f"serve_baseline_{tier}_dominance", 0.0,
             f"min_margin={bs['min_margin']:.3f} "
             f"dominates={bs['dominates']}")

    ok = all(cw["warm_fewer_bytes"] and cw["warm_faster_modeled"]
             for cw in results["cold_warm"])
    results["acceptance_warm_beats_cold_all_tiers"] = ok
    results["acceptance_drift_recovery"] = bool(
        drift["drift_detected"] and drift["warm_recovers"])
    results["baseline_serve_dominates_all_tiers"] = all(
        bs["dominates"] for bs in results["baseline_serve"])
    results["acceptance_pipeline"] = bool(
        pipe["identical"] and pipe["pread_bound"])
    emit("serve_acceptance", 0.0,
         f"warm_beats_cold_on_{len(results['cold_warm'])}_tiers={ok} "
         f"drift_recovery={results['acceptance_drift_recovery']} "
         f"baseline_dominance={results['baseline_serve_dominates_all_tiers']} "
         f"pipeline={results['acceptance_pipeline']}")
    os.unlink(path)
    return results


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also dump results as JSON (e.g. BENCH_serve.json)")
    ap.add_argument("--n-keys", type=int, default=N_KEYS)
    ap.add_argument("--n-queries", type=int, default=4096)
    ap.add_argument("--fleet-json", metavar="PATH", default=None,
                    help="run the sharded-fleet scenario and dump its "
                         "results (e.g. BENCH_fleet.json)")
    ap.add_argument("--fleet-only", action="store_true",
                    help="run only the sharded-fleet scenario")
    ap.add_argument("--fleet-n-keys", type=int, default=FLEET_N_KEYS)
    ap.add_argument("--chaos", action="store_true",
                    help="also run the fault-injection gate (identity "
                         "under faults is FATAL, qps degradation warns)")
    ap.add_argument("--chaos-only", action="store_true",
                    help="run only the fault-injection gate")
    ap.add_argument("--chaos-json", metavar="PATH", default=None,
                    help="dump the chaos gate results "
                         "(e.g. BENCH_chaos.json); implies --chaos")
    ap.add_argument("--p99", action="store_true",
                    help="also run the tail-latency gate (tune-for-p99 vs "
                         "tune-for-mean under bursty stalls; p99 win is "
                         "FATAL, a mean regression warns)")
    ap.add_argument("--p99-only", action="store_true",
                    help="run only the tail-latency gate")
    ap.add_argument("--p99-json", metavar="PATH", default=None,
                    help="dump the tail-latency gate results "
                         "(e.g. BENCH_p99.json); implies --p99")
    args = ap.parse_args()
    print("name,us_per_call,derived")

    p99_results = None
    if args.p99 or args.p99_only or args.p99_json:
        p99_results = run_p99_bench()
        emit_p99(p99_results)
        if args.p99_json:
            with open(args.p99_json, "w") as f:
                json.dump(p99_results, f, indent=2)
            print(f"# wrote {args.p99_json}", flush=True)
        if args.p99_only:
            fatal = p99_fatal_warnings(p99_results)
            if fatal:
                for msg in fatal:
                    print(f"::error::{msg}")
                sys.exit(1)
            return

    chaos_results = None
    if args.chaos or args.chaos_only or args.chaos_json:
        chaos_results = run_chaos_bench()
        emit_chaos(chaos_results)
        if args.chaos_json:
            with open(args.chaos_json, "w") as f:
                json.dump(chaos_results, f, indent=2)
            print(f"# wrote {args.chaos_json}", flush=True)
        if args.chaos_only:
            fatal = chaos_fatal_warnings(chaos_results)
            if fatal:
                for msg in fatal:
                    print(f"::error::{msg}")
                sys.exit(1)
            return

    fleet_results = None
    if args.fleet_json or args.fleet_only:
        fleet_results = run_fleet_bench(args.fleet_n_keys)
        emit_fleet(fleet_results)
        if args.fleet_json:
            with open(args.fleet_json, "w") as f:
                json.dump(fleet_results, f, indent=2)
            print(f"# wrote {args.fleet_json}", flush=True)
        if args.fleet_only:
            fatal = []
            if not fleet_results["identical"]:
                fatal.append("fleet scatter-gather diverged from "
                             "sequential per-shard lookups")
            if not fleet_results["fleet_beats_monolith"]:
                fatal.append(
                    f"per-shard-tuned fleet did not beat the monolith: "
                    f"fleet={fleet_results['fleet_phase2']['query_modeled_us']:.1f}us vs "
                    f"mono={fleet_results['mono_best']['observed_us']:.1f}us "
                    f"(ratio={fleet_results['fleet_vs_mono']:.4f}, "
                    f"need < 0.999)")
            if fatal:
                for msg in fatal:
                    print(f"::error::{msg}")
                sys.exit(1)
            return

    results = run_serve_bench(args.n_keys, args.n_queries)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=2)
        print(f"# wrote {args.json}", flush=True)

    # wall-clock signals only warn (noisy CI runners must not redden the
    # build); correctness/recovery regressions below are fatal
    if results["engine_vs_scalar"]["speedup"] < 1.0:
        print("::warning::serve engine slower than the scalar walk "
              f"(speedup={results['engine_vs_scalar']['speedup']:.2f}x)")
    if not results["drift"]["warm_wall_faster"]:
        print("::warning::warm retune not faster in wall-clock "
              f"(warm={results['drift']['warm']['wall_s']:.2f}s "
              f"cold={results['drift']['cold']['wall_s']:.2f}s)")
    if results["pipeline"]["qps_on"] < results["pipeline"]["qps_off"]:
        # wall-clock only: CPU-interpreted Pallas + python threads make
        # the overlap win noisy; correctness + roofline gates are below
        print("::warning::pipelined serving slower than unpipelined "
              f"(qps_on={results['pipeline']['qps_on']:.0f} "
              f"qps_off={results['pipeline']['qps_off']:.0f})")
    fatal = []
    if not results["baseline_serve_dominates_all_tiers"]:
        # fatal since the ladder closed the raw-tier mispricing gap:
        # every family tunes over the same cached-deployment ladder and
        # is judged by realized cost, so a loss here is a real regression
        fatal.append("baseline design beat AirTune on the serve path "
                     f"(min margins: "
                     f"{[bs['min_margin'] for bs in results['baseline_serve']]})")
    if not results["acceptance_warm_beats_cold_all_tiers"]:
        fatal.append("warm cache pass did not beat the cold pass")
    if not results["drift"]["drift_detected"]:
        fatal.append("degraded tier not flagged by drift detection")
    if not results["drift"]["warm_recovers"]:
        fatal.append(
            f"warm retune failed recovery: cost ratio "
            f"{results['drift']['recovery_ratio']:.4f} (need <= 1.01) or "
            f"no work reduction (warm built "
            f"{results['drift']['warm']['built']} vs cold "
            f"{results['drift']['cold']['built']})")
    if not results["pipeline"]["identical"]:
        fatal.append("pipelined lookup_batches diverged from sequential "
                     "lookup (prefetch must be invisible in results)")
    if not results["pipeline"]["pread_bound"]:
        fatal.append(
            f"pipelined engine not pread-bound on {DRIFT_SERVED}: "
            f"io_fraction="
            f"{results['pipeline']['roofline_on']['io_fraction']:.3f} "
            f"(need >= 0.8, bound="
            f"{results['pipeline']['roofline_on']['bound']})")
    if fleet_results is not None:
        if not fleet_results["identical"]:
            fatal.append("fleet scatter-gather diverged from sequential "
                         "per-shard lookups")
        if not fleet_results["fleet_beats_monolith"]:
            fatal.append(
                f"per-shard-tuned fleet did not beat the monolith "
                f"(ratio={fleet_results['fleet_vs_mono']:.4f}, need < 0.999)")
    if chaos_results is not None:
        fatal.extend(chaos_fatal_warnings(chaos_results))
    if p99_results is not None:
        fatal.extend(p99_fatal_warnings(p99_results))
    if fatal:
        for msg in fatal:
            print(f"::error::{msg}")
        sys.exit(1)


if __name__ == "__main__":
    main()
