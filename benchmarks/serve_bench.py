"""Serving-engine benchmark: queries/sec vs cache size vs storage tier.

Exercises :class:`repro.serve.IndexService` against a paged index file:

  * **cold vs warm** — the same batch served twice; the warm pass must
    fetch strictly fewer bytes from storage and complete faster in modeled
    seconds (Eq. 5 under the tier profile) on every tier (the ISSUE's
    acceptance gate);
  * **cache sweep** — hit rate and modeled time for a skewed (Zipf-ish)
    query stream as the tiered cache grows;
  * **throughput** — wall-clock queries/sec of the batched engine vs the
    one-query-at-a-time ``lookup_serialized`` walk;
  * **pipeline** — ``lookup_batches`` (batch-i+1 prefetch overlapping
    batch-i fused descent) vs sequential ``lookup`` on ``azure_hdd``:
    windows must be identical (FATAL) and the roofline must show the
    engine pread-bound (``io_fraction >= 0.8``, FATAL); a wall-clock
    qps regression only warns;
  * **drift scenario** — tune on ``azure_ssd``, serve on a degraded tier:
    the persisted ServeStats must flag drift (``repro.api.drift``) and a
    warm-started retune must recover the cold-retune cost (within 1%)
    with strictly fewer layer builds — a failed recovery is FATAL, only
    wall-clock regressions degrade to warnings;
  * **baselines on the serve path** — the §7.2 btree/rmi/pgm designs
    served through the same ``IndexService`` + cache as the AirTune
    design, so ``BENCH_serve.json`` trends the dominance margin on the
    *real* partial-read path, not just the Eq. 6 model.

Prints the repo's ``name,us_per_call,derived`` CSV; ``--json PATH`` also
dumps a machine-readable ``BENCH_serve.json`` so later PRs have a perf
trajectory to compare against (``benchmarks/run.py --serve-json`` wires
this into the main harness).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "..", "src"))

from repro.api import Index, ServeSpec, TuneSpec, detect_drift
from repro.core import KeyPositions, PROFILES, expected_latency
from repro.core.baselines import build_fixed_btree, tune_pgm, tune_rmi
from repro.core.serialize import lookup_serialized
from repro.serve.index_service import demo_serving_design
from repro.data.datasets import sosd_like

N_KEYS = 200_000
RECORD = 16
PAGE = 4096
TIERS = ("azure_nfs", "azure_ssd")
CACHE_SIZES = (32 << 10, 256 << 10, 2 << 20)

# drift scenario: tuned-for tier vs the degraded tier it is served on
DRIFT_TUNED = "azure_ssd"
DRIFT_SERVED = "azure_hdd"
DRIFT_SPEC = TuneSpec(lam_low=2**8, lam_high=2**17, lam_base=2.0, k=4,
                      max_layers=8, page_bytes=PAGE,
                      cache_bytes=(64 << 10, 512 << 10))


def emit(name, us, derived):
    print(f"{name},{us:.2f},{derived}")


build_serving_design = demo_serving_design


_HOT_ORDER = None       # fixed random rank→key map, shared by all sweeps


def _skewed_queries(keys: np.ndarray, n: int, rng) -> np.ndarray:
    """Zipf-ish rank sampling — the hot-key regime block caches live for.
    Ranks map through a fixed random permutation so the hot set is spread
    across the key space (not the physically-clustered smallest keys)."""
    global _HOT_ORDER
    if _HOT_ORDER is None or len(_HOT_ORDER) != len(keys):
        _HOT_ORDER = np.random.default_rng(123).permutation(len(keys))
    ranks = (rng.zipf(1.2, n) - 1) % len(keys)
    return keys[_HOT_ORDER[ranks]]


def bench_cold_warm(idx: Index, tier: str, queries: np.ndarray) -> dict:
    svc = idx.serve(profile=tier, cache_bytes=(256 << 10, 2 << 20))
    base = svc.stats.snapshot()
    t0 = time.perf_counter()
    svc.lookup(queries)
    cold_wall = time.perf_counter() - t0
    mid = svc.stats.snapshot()
    t0 = time.perf_counter()
    svc.lookup(queries)
    warm_wall = time.perf_counter() - t0
    end = svc.stats.snapshot()
    svc.close()
    cold = {k: mid[k] - base[k] for k in ("bytes_fetched", "modeled_seconds",
                                          "preads")}
    warm = {k: end[k] - mid[k] for k in ("bytes_fetched", "modeled_seconds",
                                         "preads")}
    return {
        "tier": tier,
        "cold": {**cold, "wall_s": cold_wall,
                 "qps": len(queries) / max(cold_wall, 1e-9)},
        "warm": {**warm, "wall_s": warm_wall,
                 "qps": len(queries) / max(warm_wall, 1e-9)},
        "hit_rate_final": end["hit_rate"],
        "warm_fewer_bytes": warm["bytes_fetched"] < cold["bytes_fetched"],
        "warm_faster_modeled":
            warm["modeled_seconds"] < cold["modeled_seconds"],
    }


def bench_cache_sweep(idx: Index, tier: str, keys: np.ndarray, *,
                      n_batches: int = 8, batch: int = 1024) -> list:
    rng = np.random.default_rng(7)
    stream = [_skewed_queries(keys, batch, rng) for _ in range(n_batches)]
    rows = []
    for cap in CACHE_SIZES:
        svc = idx.serve(profile=tier,
                        cache_bytes=(cap // 4, cap - cap // 4))
        base = svc.stats.snapshot()
        t0 = time.perf_counter()
        for qs in stream:
            svc.lookup(qs)
        wall = time.perf_counter() - t0
        end = svc.stats.snapshot()
        svc.close()
        rows.append({
            "tier": tier, "cache_bytes": cap,
            "hit_rate": end["hit_rate"],
            "bytes_fetched": end["bytes_fetched"] - base["bytes_fetched"],
            "bytes_from_cache": end["bytes_from_cache"],
            "modeled_seconds": end["modeled_seconds"] - base["modeled_seconds"],
            "qps": n_batches * batch / max(wall, 1e-9),
        })
    return rows


def bench_engine_vs_scalar(idx: Index, queries: np.ndarray) -> dict:
    path = idx.path
    svc = idx.serve(profile=None, cache_bytes=(2 << 20,))
    svc.lookup(queries[:64])                      # touch pages / warm python
    t0 = time.perf_counter()
    svc.lookup(queries)
    engine_wall = time.perf_counter() - t0
    svc.close()
    t0 = time.perf_counter()
    lookup_serialized(path, None, queries)
    scalar_wall = time.perf_counter() - t0
    return {"engine_qps": len(queries) / max(engine_wall, 1e-9),
            "scalar_qps": len(queries) / max(scalar_wall, 1e-9),
            "speedup": scalar_wall / max(engine_wall, 1e-9)}


def bench_pipeline(idx: Index, keys: np.ndarray, *, n_batches: int = 8,
                   batch: int = 512) -> dict:
    """Pipeline-on vs pipeline-off on the slow tier: ``lookup_batches``
    with batch-i+1 prefetch overlapping batch-i descent must return
    windows identical to sequential ``lookup`` (fatal gate), and the
    roofline must show the engine pread-bound on ``azure_hdd`` — the
    whole point of overlapping I/O is that I/O dominates.

    Unlike the cache sweep this cell wants *misses*: uniform queries (no
    hot set) against a cache smaller than the disk-resident layers, so
    every batch issues real preads and the modeled azure_hdd seek time
    dwarfs the fused-descent compute."""
    rng = np.random.default_rng(31)
    batches = [rng.choice(keys, batch) for _ in range(n_batches)]
    base = ServeSpec(cache_bytes=(8 << 10,))

    svc = idx.serve(profile=DRIFT_SERVED, spec=base)
    t0 = time.perf_counter()
    want = [svc.lookup(qs) for qs in batches]
    off_wall = time.perf_counter() - t0
    off_roof = svc.stats.roofline()
    svc.close()

    svc = idx.serve(profile=DRIFT_SERVED,
                    spec=base.replace(pipeline_depth=2, prefetch_layers=2))
    t0 = time.perf_counter()
    got = svc.lookup_batches(batches)
    on_wall = time.perf_counter() - t0
    on_roof = svc.stats.roofline()
    s = svc.stats
    row = {
        "tier": DRIFT_SERVED,
        "identical": bool(all(np.array_equal(w, g)
                              for w, g in zip(want, got))),
        "qps_off": n_batches * batch / max(off_wall, 1e-9),
        "qps_on": n_batches * batch / max(on_wall, 1e-9),
        "pipelined_batches": s.pipelined_batches,
        "overlapped_preads": s.overlapped_preads,
        "overlapped_pread_seconds": s.overlapped_pread_seconds,
        "roofline_off": off_roof,
        "roofline_on": on_roof,
        # acceptance: the pipelined engine is pread-bound on azure_hdd
        "pread_bound": bool(on_roof["bound"] == "pread"
                            and on_roof["io_fraction"] >= 0.8),
    }
    svc.close()
    row["speedup"] = row["qps_on"] / max(row["qps_off"], 1e-9)
    return row


def bench_drift(D: KeyPositions, workdir: str) -> dict:
    """The observe→retune loop end to end: tune on DRIFT_TUNED, serve on
    DRIFT_SERVED, detect drift from persisted ServeStats, then warm- vs
    cold-retune for the observed profile.  The warm search must land
    within 1% of the cold cost with strictly fewer builds (fatal gate);
    wall-clock only informs."""
    idx = Index.tune(D, DRIFT_TUNED, DRIFT_SPEC).build()
    path = os.path.join(workdir, "drift.air")
    idx.save(path)
    rng = np.random.default_rng(11)
    svc = idx.serve(profile=DRIFT_SERVED, persist_stats=True)
    for _ in range(8):
        svc.lookup(_skewed_queries(D.keys, 512, rng))
    report = detect_drift(svc)
    observed = svc.observed_profile(measured=False)   # modeled degraded
    #                                 tier + observed hit rate: CI-stable
    svc.close()

    t0 = time.perf_counter()
    cold = idx.retune(observed).build()
    cold_wall = time.perf_counter() - t0
    t0 = time.perf_counter()
    warm = idx.retune(observed, warm_start=True).build()
    warm_wall = time.perf_counter() - t0

    recovery = warm.cost / cold.cost if cold.cost > 0 else float("inf")
    work_ok = (warm.stats.layers_reused > cold.stats.layers_reused
               and warm.stats.layers_built < cold.stats.layers_built)
    return {
        "tuned_tier": DRIFT_TUNED, "served_tier": DRIFT_SERVED,
        "report": report.to_dict(),
        "drift_detected": bool(report.drifted and report.action == "retune"),
        "recorded_cost_us": idx.cost * 1e6,
        "cold": {"cost_us": cold.cost * 1e6, "wall_s": cold_wall,
                 "built": cold.stats.layers_built,
                 "reused": cold.stats.layers_reused},
        "warm": {"cost_us": warm.cost * 1e6, "wall_s": warm_wall,
                 "built": warm.stats.layers_built,
                 "reused": warm.stats.layers_reused,
                 "seeded": warm.stats.layers_seeded},
        "recovery_ratio": recovery,          # ≤ 1.01 required
        "work_reduction": (cold.stats.layers_built
                           / max(warm.stats.layers_built, 1)),
        "warm_recovers": bool(recovery <= 1.01 and work_ok),
        "warm_wall_faster": bool(warm_wall < cold_wall),
    }


def bench_baseline_serve(D: KeyPositions, tier: str, workdir: str, *,
                         n_batches: int = 8, batch: int = 512) -> dict:
    """§7.2 on the real serve path: the AirTune design and the fixed-shape
    baseline designs served through the SAME engine + cache against the
    same skewed stream; the dominance margin is per-query observed E[T]."""
    profile = PROFILES[tier]
    designs = {
        "airtune": Index.tune(D, tier, DRIFT_SPEC).build().result.design,
        "btree": build_fixed_btree(D),
        "rmi": tune_rmi(D, profile).design,
        "pgm": tune_pgm(D, profile).design,
    }
    rng = np.random.default_rng(23)
    stream = [_skewed_queries(D.keys, batch, rng) for _ in range(n_batches)]
    rows = {}
    for name, design in designs.items():
        path = os.path.join(workdir, f"baseline_{name}.air")
        Index.from_design(design, spec=TuneSpec(page_bytes=PAGE),
                          profile=tier).save(path)
        svc = None
        try:
            from repro.serve import IndexService
            svc = IndexService(path, profile=tier,
                               spec=ServeSpec(
                                   cache_bytes=(64 << 10, 512 << 10)))
            t0 = time.perf_counter()
            for qs in stream:
                svc.lookup(qs)
            wall = time.perf_counter() - t0
            s = svc.stats
            rows[name] = {
                "layers": len(design.layers),
                "eq6_cost_us": expected_latency(design, profile) * 1e6,
                "observed_us": s.query_modeled_seconds * 1e6,
                "walk_us": s.walk_query_seconds * 1e6,
                "hit_rate": s.hit_rate,
                "preads": s.preads,
                "bytes_fetched": s.bytes_fetched,
                "qps": n_batches * batch / max(wall, 1e-9),
            }
        finally:
            if svc is not None:
                svc.close()
            os.unlink(path)
    air = rows["airtune"]["observed_us"]
    for name, r in rows.items():
        if name != "airtune":
            r["margin_vs_airtune"] = r["observed_us"] / max(air, 1e-12)
    margins = [r["margin_vs_airtune"] for n, r in rows.items()
               if n != "airtune"]
    return {"tier": tier, "designs": rows,
            "min_margin": min(margins),
            # §7.2 on the serve path: AirTune ≤ every baseline (small
            # slack: cache/residency interactions are not in the model)
            "dominates": bool(min(margins) >= 0.999)}


def run_serve_bench(n_keys: int = N_KEYS, n_queries: int = 4096) -> dict:
    keys = sosd_like("gmm", n_keys)
    D = KeyPositions.fixed_record(keys, RECORD)
    design = build_serving_design(D)
    path = os.path.join(tempfile.mkdtemp(prefix="serve_bench_"), "index.air")
    idx = Index.from_design(design, spec=TuneSpec(page_bytes=PAGE))
    idx.save(path)
    rng = np.random.default_rng(0)
    queries = rng.choice(D.keys, n_queries)

    results = {"design": design.describe(), "page_bytes": PAGE,
               "n_keys": int(D.n), "n_queries": int(n_queries),
               "cold_warm": [], "cache_sweep": [],
               "expected_latency_us": {
                   t: expected_latency(design, PROFILES[t]) * 1e6
                   for t in TIERS}}
    for tier in TIERS:
        cw = bench_cold_warm(idx, tier, queries)
        results["cold_warm"].append(cw)
        emit(f"serve_cold_{tier}", cw["cold"]["modeled_seconds"] * 1e6,
             f"bytes={cw['cold']['bytes_fetched']} preads={cw['cold']['preads']}"
             f" qps={cw['cold']['qps']:.0f}")
        emit(f"serve_warm_{tier}", cw["warm"]["modeled_seconds"] * 1e6,
             f"bytes={cw['warm']['bytes_fetched']} preads={cw['warm']['preads']}"
             f" qps={cw['warm']['qps']:.0f}"
             f" fewer_bytes={cw['warm_fewer_bytes']}"
             f" faster_modeled={cw['warm_faster_modeled']}")
        for row in bench_cache_sweep(idx, tier, D.keys):
            results["cache_sweep"].append(row)
            emit(f"serve_sweep_{tier}_{row['cache_bytes'] >> 10}KiB",
                 row["modeled_seconds"] * 1e6,
                 f"hit_rate={row['hit_rate']:.3f} qps={row['qps']:.0f} "
                 f"bytes={row['bytes_fetched']}")
    results["engine_vs_scalar"] = bench_engine_vs_scalar(idx, queries)
    ev = results["engine_vs_scalar"]
    emit("serve_engine_vs_scalar", 0.0,
         f"engine={ev['engine_qps']:.0f}q/s scalar={ev['scalar_qps']:.0f}q/s "
         f"speedup={ev['speedup']:.1f}x")

    pipe = bench_pipeline(idx, D.keys)
    results["pipeline"] = pipe
    emit(f"serve_pipeline_{DRIFT_SERVED}",
         pipe["roofline_on"]["io_seconds"] * 1e6,
         f"identical={pipe['identical']} qps_on={pipe['qps_on']:.0f} "
         f"qps_off={pipe['qps_off']:.0f} "
         f"io_fraction={pipe['roofline_on']['io_fraction']:.3f} "
         f"bound={pipe['roofline_on']['bound']} "
         f"overlapped_preads={pipe['overlapped_preads']}")

    workdir = os.path.dirname(path)
    drift = bench_drift(D, workdir)
    results["drift"] = drift
    emit(f"serve_drift_{DRIFT_TUNED}_to_{DRIFT_SERVED}",
         drift["report"]["observed_us"] or 0.0,
         f"ratio={drift['report']['ratio']:.2f} "
         f"action={drift['report']['action']} "
         f"hit_rate={drift['report']['hit_rate']:.3f}")
    emit("serve_drift_retune", drift["warm"]["cost_us"],
         f"recovery={drift['recovery_ratio']:.4f} "
         f"warm_built={drift['warm']['built']} "
         f"cold_built={drift['cold']['built']} "
         f"reused={drift['warm']['reused']} "
         f"work_reduction={drift['work_reduction']:.1f}x")

    results["baseline_serve"] = []
    for tier in ("azure_ssd", "azure_hdd"):
        bs = bench_baseline_serve(D, tier, workdir)
        results["baseline_serve"].append(bs)
        for name, r in bs["designs"].items():
            mg = r.get("margin_vs_airtune")
            emit(f"serve_baseline_{tier}_{name}", r["observed_us"],
                 f"hit_rate={r['hit_rate']:.3f} qps={r['qps']:.0f}"
                 + (f" margin={mg:.2f}x" if mg is not None else ""))
        emit(f"serve_baseline_{tier}_dominance", 0.0,
             f"min_margin={bs['min_margin']:.3f} "
             f"dominates={bs['dominates']}")

    ok = all(cw["warm_fewer_bytes"] and cw["warm_faster_modeled"]
             for cw in results["cold_warm"])
    results["acceptance_warm_beats_cold_all_tiers"] = ok
    results["acceptance_drift_recovery"] = bool(
        drift["drift_detected"] and drift["warm_recovers"])
    results["baseline_serve_dominates_all_tiers"] = all(
        bs["dominates"] for bs in results["baseline_serve"])
    results["acceptance_pipeline"] = bool(
        pipe["identical"] and pipe["pread_bound"])
    emit("serve_acceptance", 0.0,
         f"warm_beats_cold_on_{len(results['cold_warm'])}_tiers={ok} "
         f"drift_recovery={results['acceptance_drift_recovery']} "
         f"baseline_dominance={results['baseline_serve_dominates_all_tiers']} "
         f"pipeline={results['acceptance_pipeline']}")
    os.unlink(path)
    return results


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also dump results as JSON (e.g. BENCH_serve.json)")
    ap.add_argument("--n-keys", type=int, default=N_KEYS)
    ap.add_argument("--n-queries", type=int, default=4096)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    results = run_serve_bench(args.n_keys, args.n_queries)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=2)
        print(f"# wrote {args.json}", flush=True)

    # wall-clock signals only warn (noisy CI runners must not redden the
    # build); correctness/recovery regressions below are fatal
    if results["engine_vs_scalar"]["speedup"] < 1.0:
        print("::warning::serve engine slower than the scalar walk "
              f"(speedup={results['engine_vs_scalar']['speedup']:.2f}x)")
    if not results["drift"]["warm_wall_faster"]:
        print("::warning::warm retune not faster in wall-clock "
              f"(warm={results['drift']['warm']['wall_s']:.2f}s "
              f"cold={results['drift']['cold']['wall_s']:.2f}s)")
    if results["pipeline"]["qps_on"] < results["pipeline"]["qps_off"]:
        # wall-clock only: CPU-interpreted Pallas + python threads make
        # the overlap win noisy; correctness + roofline gates are below
        print("::warning::pipelined serving slower than unpipelined "
              f"(qps_on={results['pipeline']['qps_on']:.0f} "
              f"qps_off={results['pipeline']['qps_off']:.0f})")
    if not results["baseline_serve_dominates_all_tiers"]:
        # trended, not enforced: cache/residency interactions are outside
        # the Eq. 6 model the dominance claim is proven under
        print("::warning::baseline design beat AirTune on the serve path "
              f"(min margins: "
              f"{[bs['min_margin'] for bs in results['baseline_serve']]})")

    fatal = []
    if not results["acceptance_warm_beats_cold_all_tiers"]:
        fatal.append("warm cache pass did not beat the cold pass")
    if not results["drift"]["drift_detected"]:
        fatal.append("degraded tier not flagged by drift detection")
    if not results["drift"]["warm_recovers"]:
        fatal.append(
            f"warm retune failed recovery: cost ratio "
            f"{results['drift']['recovery_ratio']:.4f} (need <= 1.01) or "
            f"no work reduction (warm built "
            f"{results['drift']['warm']['built']} vs cold "
            f"{results['drift']['cold']['built']})")
    if not results["pipeline"]["identical"]:
        fatal.append("pipelined lookup_batches diverged from sequential "
                     "lookup (prefetch must be invisible in results)")
    if not results["pipeline"]["pread_bound"]:
        fatal.append(
            f"pipelined engine not pread-bound on {DRIFT_SERVED}: "
            f"io_fraction="
            f"{results['pipeline']['roofline_on']['io_fraction']:.3f} "
            f"(need >= 0.8, bound="
            f"{results['pipeline']['roofline_on']['bound']})")
    if fatal:
        for msg in fatal:
            print(f"::error::{msg}")
        sys.exit(1)


if __name__ == "__main__":
    main()
