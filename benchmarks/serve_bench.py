"""Serving-engine benchmark: queries/sec vs cache size vs storage tier.

Exercises :class:`repro.serve.IndexService` against a paged index file:

  * **cold vs warm** — the same batch served twice; the warm pass must
    fetch strictly fewer bytes from storage and complete faster in modeled
    seconds (Eq. 5 under the tier profile) on every tier (the ISSUE's
    acceptance gate);
  * **cache sweep** — hit rate and modeled time for a skewed (Zipf-ish)
    query stream as the tiered cache grows;
  * **throughput** — wall-clock queries/sec of the batched engine vs the
    one-query-at-a-time ``lookup_serialized`` walk.

Prints the repo's ``name,us_per_call,derived`` CSV; ``--json PATH`` also
dumps a machine-readable ``BENCH_serve.json`` so later PRs have a perf
trajectory to compare against (``benchmarks/run.py --serve-json`` wires
this into the main harness).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "..", "src"))

from repro.api import Index, TuneSpec
from repro.core import KeyPositions, PROFILES, expected_latency
from repro.core.serialize import lookup_serialized
from repro.serve.index_service import demo_serving_design
from repro.data.datasets import sosd_like

N_KEYS = 200_000
RECORD = 16
PAGE = 4096
TIERS = ("azure_nfs", "azure_ssd")
CACHE_SIZES = (32 << 10, 256 << 10, 2 << 20)


def emit(name, us, derived):
    print(f"{name},{us:.2f},{derived}")


build_serving_design = demo_serving_design


_HOT_ORDER = None       # fixed random rank→key map, shared by all sweeps


def _skewed_queries(keys: np.ndarray, n: int, rng) -> np.ndarray:
    """Zipf-ish rank sampling — the hot-key regime block caches live for.
    Ranks map through a fixed random permutation so the hot set is spread
    across the key space (not the physically-clustered smallest keys)."""
    global _HOT_ORDER
    if _HOT_ORDER is None or len(_HOT_ORDER) != len(keys):
        _HOT_ORDER = np.random.default_rng(123).permutation(len(keys))
    ranks = (rng.zipf(1.2, n) - 1) % len(keys)
    return keys[_HOT_ORDER[ranks]]


def bench_cold_warm(idx: Index, tier: str, queries: np.ndarray) -> dict:
    svc = idx.serve(profile=tier, cache_bytes=(256 << 10, 2 << 20))
    base = svc.stats.snapshot()
    t0 = time.perf_counter()
    svc.lookup(queries)
    cold_wall = time.perf_counter() - t0
    mid = svc.stats.snapshot()
    t0 = time.perf_counter()
    svc.lookup(queries)
    warm_wall = time.perf_counter() - t0
    end = svc.stats.snapshot()
    svc.close()
    cold = {k: mid[k] - base[k] for k in ("bytes_fetched", "modeled_seconds",
                                          "preads")}
    warm = {k: end[k] - mid[k] for k in ("bytes_fetched", "modeled_seconds",
                                         "preads")}
    return {
        "tier": tier,
        "cold": {**cold, "wall_s": cold_wall,
                 "qps": len(queries) / max(cold_wall, 1e-9)},
        "warm": {**warm, "wall_s": warm_wall,
                 "qps": len(queries) / max(warm_wall, 1e-9)},
        "hit_rate_final": end["hit_rate"],
        "warm_fewer_bytes": warm["bytes_fetched"] < cold["bytes_fetched"],
        "warm_faster_modeled":
            warm["modeled_seconds"] < cold["modeled_seconds"],
    }


def bench_cache_sweep(idx: Index, tier: str, keys: np.ndarray, *,
                      n_batches: int = 8, batch: int = 1024) -> list:
    rng = np.random.default_rng(7)
    stream = [_skewed_queries(keys, batch, rng) for _ in range(n_batches)]
    rows = []
    for cap in CACHE_SIZES:
        svc = idx.serve(profile=tier,
                        cache_bytes=(cap // 4, cap - cap // 4))
        base = svc.stats.snapshot()
        t0 = time.perf_counter()
        for qs in stream:
            svc.lookup(qs)
        wall = time.perf_counter() - t0
        end = svc.stats.snapshot()
        svc.close()
        rows.append({
            "tier": tier, "cache_bytes": cap,
            "hit_rate": end["hit_rate"],
            "bytes_fetched": end["bytes_fetched"] - base["bytes_fetched"],
            "bytes_from_cache": end["bytes_from_cache"],
            "modeled_seconds": end["modeled_seconds"] - base["modeled_seconds"],
            "qps": n_batches * batch / max(wall, 1e-9),
        })
    return rows


def bench_engine_vs_scalar(idx: Index, queries: np.ndarray) -> dict:
    path = idx.path
    svc = idx.serve(profile=None, cache_bytes=(2 << 20,))
    svc.lookup(queries[:64])                      # touch pages / warm python
    t0 = time.perf_counter()
    svc.lookup(queries)
    engine_wall = time.perf_counter() - t0
    svc.close()
    t0 = time.perf_counter()
    lookup_serialized(path, None, queries)
    scalar_wall = time.perf_counter() - t0
    return {"engine_qps": len(queries) / max(engine_wall, 1e-9),
            "scalar_qps": len(queries) / max(scalar_wall, 1e-9),
            "speedup": scalar_wall / max(engine_wall, 1e-9)}


def run_serve_bench(n_keys: int = N_KEYS, n_queries: int = 4096) -> dict:
    keys = sosd_like("gmm", n_keys)
    D = KeyPositions.fixed_record(keys, RECORD)
    design = build_serving_design(D)
    path = os.path.join(tempfile.mkdtemp(prefix="serve_bench_"), "index.air")
    idx = Index.from_design(design, spec=TuneSpec(page_bytes=PAGE))
    idx.save(path)
    rng = np.random.default_rng(0)
    queries = rng.choice(D.keys, n_queries)

    results = {"design": design.describe(), "page_bytes": PAGE,
               "n_keys": int(D.n), "n_queries": int(n_queries),
               "cold_warm": [], "cache_sweep": [],
               "expected_latency_us": {
                   t: expected_latency(design, PROFILES[t]) * 1e6
                   for t in TIERS}}
    for tier in TIERS:
        cw = bench_cold_warm(idx, tier, queries)
        results["cold_warm"].append(cw)
        emit(f"serve_cold_{tier}", cw["cold"]["modeled_seconds"] * 1e6,
             f"bytes={cw['cold']['bytes_fetched']} preads={cw['cold']['preads']}"
             f" qps={cw['cold']['qps']:.0f}")
        emit(f"serve_warm_{tier}", cw["warm"]["modeled_seconds"] * 1e6,
             f"bytes={cw['warm']['bytes_fetched']} preads={cw['warm']['preads']}"
             f" qps={cw['warm']['qps']:.0f}"
             f" fewer_bytes={cw['warm_fewer_bytes']}"
             f" faster_modeled={cw['warm_faster_modeled']}")
        for row in bench_cache_sweep(idx, tier, D.keys):
            results["cache_sweep"].append(row)
            emit(f"serve_sweep_{tier}_{row['cache_bytes'] >> 10}KiB",
                 row["modeled_seconds"] * 1e6,
                 f"hit_rate={row['hit_rate']:.3f} qps={row['qps']:.0f} "
                 f"bytes={row['bytes_fetched']}")
    results["engine_vs_scalar"] = bench_engine_vs_scalar(idx, queries)
    ev = results["engine_vs_scalar"]
    emit("serve_engine_vs_scalar", 0.0,
         f"engine={ev['engine_qps']:.0f}q/s scalar={ev['scalar_qps']:.0f}q/s "
         f"speedup={ev['speedup']:.1f}x")
    ok = all(cw["warm_fewer_bytes"] and cw["warm_faster_modeled"]
             for cw in results["cold_warm"])
    results["acceptance_warm_beats_cold_all_tiers"] = ok
    emit("serve_acceptance", 0.0,
         f"warm_beats_cold_on_{len(results['cold_warm'])}_tiers={ok}")
    os.unlink(path)
    return results


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also dump results as JSON (e.g. BENCH_serve.json)")
    ap.add_argument("--n-keys", type=int, default=N_KEYS)
    ap.add_argument("--n-queries", type=int, default=4096)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    results = run_serve_bench(args.n_keys, args.n_queries)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=2)
        print(f"# wrote {args.json}", flush=True)
    if not results["acceptance_warm_beats_cold_all_tiers"]:
        sys.exit(1)


if __name__ == "__main__":
    main()
