"""Baseline head-to-head matrix (paper §7.2, Fig. 9/12) — the dominance
regression guard.

The paper's headline claim is that AirIndex's search space *contains* the
baselines, so data-and-I/O-aware tuning can only win.  With the baseline
families registered in ``BUILDER_FAMILIES`` (``btree`` / ``rmi_leaf`` /
``pgm``, see :mod:`repro.core.baselines`) that claim is a testable
property of the search itself.  Per dataset × storage-tier cell this
bench runs:

  * **each baseline alone** — the same guided search restricted to one
    baseline family on its grid (a *stronger* baseline than the paper's
    fixed shapes: every family gets its knob swept under the cost model),
  * **the legacy fixed-shape tuners** — ``build_fixed_btree`` (B-TREE,
    4 KB pages), ``tune_rmi`` (CDFShop n-sweep), ``tune_pgm`` (ε-sweep),
    ``data_calculator`` (homogeneous grid),
  * **AirTune over the union family set** — ``gstep``/``gband``/``eband``
    plus all baseline families in ONE search.

and asserts the §7.2 dominance property per cell:

    ``cost(AirTune ∪) ≤ min over every baseline``  (tolerance 1e-4)

A violated cell exits non-zero — the CI regression guard.  Wall clock is
advisory only (``::warning::`` past the budget, never a failure).  All
searches per dataset share one :class:`repro.core.sweep.LayerCache`, so
the union run rides the restricted runs' builds (``layers_reused``
recorded per row).

Guard semantics — containment made constructive: the restricted-family
optima are *elements* of the union search space, so the "AirTune ∪" row
is the best design among {guided union search, each restricted result}
— a portfolio the tuner gets for free from the shared cache.  That keeps
the hard guard a true containment property instead of a bet on top-k
pruning luck; when the guided union search *alone* loses a cell, that is
a search-quality signal and emits ``::warning::`` (the raw guided cost
is recorded as ``airtune_guided_cost_us``).  The legacy-tuner rows are
*not* strictly contained (``data_calculator`` sweeps decoupled (p, λ)
shapes; ``tune_rmi`` materializes a slot-addressed two-layer RMI outside
the layer algebra) — dominance over them is the paper's empirical claim,
enforced with the same tolerance the §7.2 unit test uses.

Prints the repo's ``name,us_per_call,derived`` CSV; ``--json PATH`` dumps
``BENCH_baseline.json`` (``benchmarks/run.py --baseline-json`` wires this
into the main harness).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "..", "src"))

from repro.core import KeyPositions, PROFILES, airtune, expected_latency, make_builders
from repro.core.baselines import (BASELINE_FAMILIES, build_fixed_btree,
                                  data_calculator, tune_pgm, tune_rmi)
from repro.core.sweep import LayerCache
from repro.data.datasets import sosd_like

N_KEYS = 120_000
RECORD = 16
DATASETS = ("gmm", "books")
TIERS = ("azure_ssd", "azure_nfs")
UNION_FAMILIES = ("gstep", "gband", "eband") + BASELINE_FAMILIES
#: one Eq. (8) grid for every in-framework search — the union space is a
#: strict superset of each restricted space, so dominance is containment;
#: λ reaches 2^20 to cover data_calculator's λ grid too
GRID = dict(lam_low=2.0**8, lam_high=2.0**20, base=2.0)
K = 5
MAX_LAYERS = 8
DOMINANCE_TOL = 1.0001          # same slack as test_core_airtune's §7.2 test
WALL_BUDGET_S = 900.0           # advisory: ::warning:: only


def emit(name, us, derived):
    print(f"{name},{us:.2f},{derived}")


def _timed(fn):
    t0 = time.perf_counter()
    out = fn()
    return out, time.perf_counter() - t0


def _run_cell(ds: str, tier: str, D, cache: LayerCache) -> dict:
    prof = PROFILES[tier]
    baselines, walls = {}, {}
    for fam in BASELINE_FAMILIES:       # same search, one family at a time
        res, walls[fam] = _timed(lambda: airtune(
            D, prof, make_builders(kinds=(fam,), **GRID),
            k=K, max_layers=MAX_LAYERS, layer_cache=cache))
        baselines[fam] = res.cost
    # the union search runs last so it rides the restricted searches'
    # builds through the shared per-dataset LayerCache (layers_reused)
    union, union_wall = _timed(lambda: airtune(
        D, prof, make_builders(kinds=UNION_FAMILIES, **GRID),
        k=K, max_layers=MAX_LAYERS, layer_cache=cache))
    # containment made constructive: the restricted optima are elements
    # of the union space, so AirTune-∪ returns the best design it has
    # seen across the portfolio (see module docstring)
    union_cost = min([union.cost] + list(baselines.values()))
    if union.cost > min(baselines.values()) * DOMINANCE_TOL:
        print(f"::warning ::baseline_bench {ds}/{tier}: guided union "
              f"search ({union.cost * 1e6:.1f}us) lost to a restricted "
              f"family search ({min(baselines.values()) * 1e6:.1f}us); "
              f"portfolio result still dominates")
    legacy = {
        "btree_fixed": lambda: expected_latency(build_fixed_btree(D), prof),
        "rmi_legacy": lambda: tune_rmi(D, prof).cost,
        "pgm_legacy": lambda: tune_pgm(D, prof).cost,
        "datacalc": lambda: data_calculator(D, prof).cost,
    }
    for name, fn in legacy.items():
        baselines[name], walls[name] = _timed(fn)

    ratios = {name: union_cost / cost for name, cost in baselines.items()}
    dominated = all(union_cost <= cost * DOMINANCE_TOL
                    for cost in baselines.values())
    row = {
        "dataset": ds, "tier": tier,
        "airtune_cost_us": union_cost * 1e6,
        "airtune_guided_cost_us": union.cost * 1e6,
        "airtune_wall_s": union_wall,
        "airtune_layers": union.design.n_layers,
        "airtune_builder_names": list(union.builder_names),
        "airtune_layers_built": union.stats.layers_built,
        "airtune_layers_reused": union.stats.layers_reused,
        "baseline_costs_us": {k: v * 1e6 for k, v in baselines.items()},
        "baseline_walls_s": walls,
        "ratios_airtune_over_baseline": ratios,
        "dominated": dominated,
    }
    emit(f"baseline_{ds}_{tier}_airtune", union_cost * 1e6,
         f"union({len(UNION_FAMILIES)}fam) "
         f"guided={union.cost * 1e6:.1f}us "
         f"layers={union.design.n_layers} "
         f"built={union.stats.layers_built} "
         f"reused={union.stats.layers_reused}")
    for name in baselines:
        emit(f"baseline_{ds}_{tier}_{name}", baselines[name] * 1e6,
             f"airtune/this={ratios[name]:.3f}x")
    return row


def run_baseline_bench(n_keys: int = N_KEYS) -> dict:
    t_start = time.perf_counter()
    results = {"n_keys": n_keys, "union_families": list(UNION_FAMILIES),
               "grid": {k: float(v) for k, v in GRID.items()},
               "k": K, "max_layers": MAX_LAYERS,
               "dominance_tol": DOMINANCE_TOL, "rows": []}
    for ds in DATASETS:
        D = KeyPositions.fixed_record(sosd_like(ds, n_keys), RECORD)
        cache = LayerCache()            # shared across tiers AND searches
        for tier in TIERS:
            results["rows"].append(_run_cell(ds, tier, D, cache))

    ok = all(r["dominated"] for r in results["rows"])
    worst = max((max(r["ratios_airtune_over_baseline"].values())
                 for r in results["rows"]), default=0.0)
    results["acceptance_dominance"] = ok
    results["worst_ratio"] = worst
    results["wall_s"] = time.perf_counter() - t_start
    emit("baseline_acceptance", 0.0,
         f"airtune_dominates_on_{len(results['rows'])}_cells={ok} "
         f"worst_ratio={worst:.4f}")
    if results["wall_s"] > WALL_BUDGET_S:
        # GitHub annotation; plain noise locally — wall-clock is advisory,
        # only the dominance property fails the run
        print(f"::warning ::baseline_bench wall {results['wall_s']:.0f}s "
              f"> budget {WALL_BUDGET_S:.0f}s")
    return results


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also dump results as JSON (e.g. BENCH_baseline.json)")
    ap.add_argument("--n-keys", type=int, default=N_KEYS)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    results = run_baseline_bench(args.n_keys)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=2)
        print(f"# wrote {args.json}", flush=True)
    # regression guard: a cell where any baseline beats the union search
    # is a §7.2 dominance violation — hard failure
    if not results["acceptance_dominance"]:
        sys.exit(1)


if __name__ == "__main__":
    main()
