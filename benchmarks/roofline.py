"""Roofline terms from the dry-run artifacts (markdown tables rendered
by benchmarks/report.py; see README "Layout").

Per (arch × shape × mesh) cell from dryrun_results.jsonl:

    compute    = HLO_dot_FLOPs_per_device / 197e12      [s]   (bf16 MXU)
    memory     = HBM_traffic_per_device   / 819e9       [s]
    collective = collective_bytes_per_device / (n_links·50e9) [s]

All three use the trip-count-corrected HLO analysis (launch/hlo_analysis) —
the partitioned module is per-device, so numbers are already per-chip.
MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE) per step; the ratio
MODEL_FLOPS/(HLO_FLOPs·chips) shows how much compiled compute is useful
(remat and redundancy push it below 1).

v5e constants: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI with 4
links usable per chip on a 2D torus (2 send + 2 recv per direction pair);
we charge collectives against 2 links (conservative bidirectional rings).
"""
from __future__ import annotations

import json

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_LINK_BW = 50e9
ICI_LINKS = 2.0

SHAPE_TOKENS = {
    "train_4k": 4096 * 256,
    "prefill_32k": 32768 * 32,
    "decode_32k": 128,
    "long_500k": 1,
}


def model_flops(cfg, shape_name: str) -> float:
    """6·N(active)·tokens (train counts fwd+bwd; serve 2·N·tokens)."""
    n_active = cfg.param_count(active_only=True)
    tokens = SHAPE_TOKENS[shape_name]
    mult = 6.0 if shape_name == "train_4k" else 2.0
    return mult * n_active * tokens


def roofline_terms(rec: dict) -> dict:
    compute = rec["dot_flops"] / PEAK_FLOPS
    memory = rec["hbm_traffic_bytes"] / HBM_BW
    collective = rec["collectives"]["total"] / (ICI_LINKS * ICI_LINK_BW)
    dominant = max(
        (("compute", compute), ("memory", memory),
         ("collective", collective)), key=lambda kv: kv[1])[0]
    total = max(compute, memory, collective)
    return {
        "compute_s": compute, "memory_s": memory, "collective_s": collective,
        "dominant": dominant,
        "bound_s": total,
        "compute_fraction": compute / total if total else 0.0,
    }


def load_results(path: str = "dryrun_results.jsonl") -> list:
    out = []
    seen = {}
    for line in open(path):
        r = json.loads(line)
        seen[(r["arch"], r["shape"], r["mesh"])] = r   # last wins
    return list(seen.values())


def table(path: str = "dryrun_results.jsonl", mesh: str = "16x16") -> list:
    from repro.configs import all_configs
    cfgs = {c.name: c for c in all_configs().values()}
    rows = []
    for r in load_results(path):
        if r["mesh"] != mesh:
            continue
        row = {"arch": r["arch"], "shape": r["shape"], "status": r["status"]}
        if r["status"] == "ok":
            t = roofline_terms(r)
            cfg = cfgs[r["arch"]]
            mf = model_flops(cfg, r["shape"])
            hlo_total = r["dot_flops"] * r["n_devices"]
            row.update(t)
            row["model_flops"] = mf
            row["useful_ratio"] = mf / hlo_total if hlo_total else 0.0
            row["mfu_bound"] = (mf / r["n_devices"] / PEAK_FLOPS) / t["bound_s"] \
                if t["bound_s"] else 0.0
        elif r["status"] == "skipped":
            row["reason"] = r.get("reason", "")
        rows.append(row)
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    return rows


def print_table(path: str = "dryrun_results.jsonl", mesh: str = "16x16"):
    rows = table(path, mesh)
    hdr = (f"{'arch':24s} {'shape':12s} {'comp_ms':>9s} {'mem_ms':>9s} "
           f"{'coll_ms':>9s} {'dominant':>10s} {'useful':>7s} {'MFU_bnd':>8s}")
    print(hdr)
    for r in rows:
        if r["status"] != "ok":
            print(f"{r['arch']:24s} {r['shape']:12s} "
                  f"{'[' + r['status'] + ']':>9s}")
            continue
        print(f"{r['arch']:24s} {r['shape']:12s} "
              f"{r['compute_s'] * 1e3:9.2f} {r['memory_s'] * 1e3:9.2f} "
              f"{r['collective_s'] * 1e3:9.2f} {r['dominant']:>10s} "
              f"{r['useful_ratio']:7.2f} {r['mfu_bound']:8.3f}")
    return rows


if __name__ == "__main__":
    import sys
    print_table(sys.argv[1] if len(sys.argv) > 1 else "dryrun_results.jsonl",
                sys.argv[2] if len(sys.argv) > 2 else "16x16")
