"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines (us_per_call = the headline
latency/time of the benchmark; derived = the claim it validates).

The paper's evaluation is lookup latency on real storage; this container
is CPU-only, so latencies are evaluated under the storage model L_SM
(Eq. 6) with the paper's profiled tier constants — the same objective the
paper optimizes — plus real wall-clock for build/tuning times and real
partial-read lookups against the local filesystem.
"""
from __future__ import annotations

import sys
import time

import numpy as np

sys.path.insert(0, "src")

from repro.core import (AffineProfile, KeyPositions, PROFILES, airtune,
                        expected_latency, IndexDesign, make_builders,
                        mean_read_volume)
from repro.core.baselines import (build_fixed_btree, data_calculator,
                                  homogeneous_airtune, tune_pgm, tune_rmi)
from repro.data.datasets import DATASETS, sosd_like

N_KEYS = 400_000         # container-scale stand-in for SOSD's 200–800M
RECORD = 16
TIERS = ("azure_nfs", "azure_ssd", "azure_hdd")


def _dataset(name: str, n=N_KEYS) -> KeyPositions:
    return KeyPositions.fixed_record(sosd_like(name, n), RECORD)


def emit(name, us, derived):
    print(f"{name},{us:.2f},{derived}")


# ---------------------------------------------------------------------------
# Figure 2 — need for I/O-aware optimization (§2.1 worked example)
# ---------------------------------------------------------------------------
def bench_fig2_example():
    ssd, cloud = PROFILES["ssd_ex"], PROFILES["cloud_ex"]
    KB = 1024.0
    lk = lambda prof, n, node, page: n * float(prof(node)) + float(prof(page))
    b200_ssd, b5000_ssd = lk(ssd, 3, 4 * KB, 4 * KB), lk(ssd, 2, 100 * KB, 4 * KB)
    b200_cld, b5000_cld = lk(cloud, 3, 4 * KB, 4 * KB), lk(cloud, 2, 100 * KB, 4 * KB)
    emit("fig2_B200_ssd", b200_ssd * 1e6, "paper=416us")
    emit("fig2_B5000_ssd", b5000_ssd * 1e6, "paper=504us")
    emit("fig2_B200_cloud", b200_cld * 1e6, "paper=400160us")
    emit("fig2_B5000_cloud", b5000_cld * 1e6, "paper=302040us")
    flip = (b200_ssd < b5000_ssd) and (b5000_cld < b200_cld)
    emit("fig2_ordering_flips", 0.0, f"flip={flip} (paper: yes)")


# ---------------------------------------------------------------------------
# Figure 9 — cold-state first-query latency across datasets × storage
# ---------------------------------------------------------------------------
def bench_fig9_cold_lookup():
    for ds in DATASETS:
        D = _dataset(ds)
        for tier in TIERS:
            prof = PROFILES[tier]
            t0 = time.perf_counter()
            ours = airtune(D, prof, k=5)
            tune_s = time.perf_counter() - t0
            rows = {
                "airindex": ours.cost,
                "btree": expected_latency(build_fixed_btree(D), prof),
                "rmi": tune_rmi(D, prof).cost,
                "pgm": tune_pgm(D, prof).cost,
                "datacalc": data_calculator(D, prof).cost,
            }
            base = rows["airindex"]
            sp = {k: v / base for k, v in rows.items() if k != "airindex"}
            emit(f"fig9_{ds}_{tier}", base * 1e6,
                 "speedup_vs[" + " ".join(f"{k}={v:.2f}x"
                                          for k, v in sp.items())
                 + f"] tune={tune_s:.1f}s")


# ---------------------------------------------------------------------------
# Figure 11 — AirTune vs manual (L, λ) configurations (fb dataset)
# ---------------------------------------------------------------------------
def bench_fig11_manual_sweep():
    from repro.core.builders import build_gband
    from repro.core.nodes import outline
    D = _dataset("fb")
    for tier in ("azure_nfs", "azure_ssd"):
        prof = PROFILES[tier]
        auto = airtune(D, prof, k=5).cost
        best_manual = np.inf
        for lam in [2.0**s for s in range(10, 21, 2)]:
            for L in (1, 2, 3):
                layers, cur = [], D
                for _ in range(L):
                    lay = build_gband(cur, lam)
                    nxt = outline(lay, cur)
                    if nxt.size_bytes >= cur.size_bytes:
                        break
                    layers.append(lay)
                    cur = nxt
                c = expected_latency(IndexDesign(tuple(layers), D), prof)
                best_manual = min(best_manual, c)
        emit(f"fig11_fb_{tier}", auto * 1e6,
             f"best_manual={best_manual * 1e6:.1f}us "
             f"auto<=manual={auto <= best_manual * 1.0001}")


# ---------------------------------------------------------------------------
# Figure 12 — speedup over well-tuned baseline families (books, NFS)
# ---------------------------------------------------------------------------
def bench_fig12_tuned_baselines():
    D = _dataset("books")
    prof = PROFILES["azure_nfs"]
    ours = airtune(D, prof, k=5).cost
    best = {
        # explicit p=255 keeps this trend line on the historical legacy
        # series (decoupled fanout); the page-coupled discipline is the
        # registered `btree` family benched in baseline_bench.py
        "btree_lam": min(expected_latency(build_fixed_btree(D, p=255, lam=lam),
                                          prof)
                         for lam in (1024.0, 4096.0, 16384.0, 65536.0)),
        "rmi": tune_rmi(D, prof).cost,
        "pgm": tune_pgm(D, prof).cost,
    }
    emit("fig12_books_nfs", ours * 1e6,
         " ".join(f"{k}={v / ours:.2f}x" for k, v in best.items())
         + " (paper: 2.7x/1.5x over tuned LMDB/RMI)")


# ---------------------------------------------------------------------------
# Figure 13 — adaptivity over the latency×bandwidth spectrum (fb)
# ---------------------------------------------------------------------------
def bench_fig13_spectrum():
    D = _dataset("fb", n=150_000)
    lats = [1e-6, 1e-4, 1e-2, 1.0]
    bws = [1e4, 1e6, 1e8, 1e10]
    grid = []
    for ell in lats:
        for bw in bws:
            res = airtune(D, AffineProfile(ell, bw), k=3)
            grid.append((ell, bw, res.design.n_layers,
                         mean_read_volume(res.design)))
    by_lat = {}
    for ell, bw, L, vol in grid:
        by_lat.setdefault(ell, []).append(L)
    avg_layers = {ell: float(np.mean(v)) for ell, v in by_lat.items()}
    monotone = all(avg_layers[a] >= avg_layers[b] - 0.75
                   for a, b in zip(lats, lats[1:]))
    emit("fig13_spectrum", 0.0,
         "avg_layers_by_latency=" + "/".join(
             f"{avg_layers[l]:.1f}" for l in lats)
         + f" higher_latency->shallower={monotone}")
    for ell, bw, L, vol in grid:
        print(f"fig13_cell,0.00,lat={ell:g}s bw={bw:g}B/s layers={L} "
              f"read_volume={vol:.0f}B")


# ---------------------------------------------------------------------------
# Figure 15 — build time & search overhead vs data size (gmm)
# ---------------------------------------------------------------------------
def bench_fig15_build_time():
    for n in (125_000, 250_000, 500_000, 1_000_000):
        D = _dataset("gmm", n=n)
        prof = PROFILES["azure_ssd"]
        t0 = time.perf_counter()
        res = airtune(D, prof, k=5)
        tune_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        build_fixed_btree(D)
        btree_s = time.perf_counter() - t0
        per_key_ns = tune_s / max(D.n, 1) * 1e9
        emit(f"fig15_n{n}", tune_s * 1e6,
             f"tune={tune_s:.2f}s btree_build={btree_s:.2f}s "
             f"search_overhead={per_key_ns:.0f}ns/key "
             f"(paper: ~9.6us/key 1-core) layers_built={res.stats.layers_built}")


# ---------------------------------------------------------------------------
# Figure 20 — top-k sweep (books, SSD)
# ---------------------------------------------------------------------------
def bench_fig20_topk():
    D = _dataset("books", n=200_000)
    prof = PROFILES["azure_ssd"]
    costs = []
    for k in (1, 2, 5, 10, 20):
        t0 = time.perf_counter()
        res = airtune(D, prof, k=k)
        dt = time.perf_counter() - t0
        costs.append(res.cost)
        emit(f"fig20_k{k}", res.cost * 1e6, f"build={dt:.2f}s")
    dec = all(a >= b - 1e-9 for a, b in zip(costs, costs[1:]))
    emit("fig20_monotone", 0.0, f"cost_monotone_nonincreasing={dec}")


# ---------------------------------------------------------------------------
# §2.2 — heterogeneous vs homogeneous layers
# ---------------------------------------------------------------------------
def bench_sec22_heterogeneous():
    D = _dataset("gmm", n=200_000)
    prof = PROFILES["azure_ssd"]
    full = airtune(D, prof, k=5).cost
    step_only = homogeneous_airtune(D, prof, "step", k=5).cost
    band_only = homogeneous_airtune(D, prof, "band", k=5).cost
    emit("sec22_heterogeneous", full * 1e6,
         f"step_only={step_only / full:.2f}x band_only={band_only / full:.2f}x"
         f" hetero_best={full <= min(step_only, band_only) * 1.0001}")


# ---------------------------------------------------------------------------
# Batched lookup throughput (TPU-native path, jitted on CPU)
# ---------------------------------------------------------------------------
def bench_lookup_throughput():
    import jax.numpy as jnp
    from repro.kernels.index_lookup import ops as ilk
    rng = np.random.default_rng(0)
    keys = np.unique(rng.integers(0, 2**30, 500_000).astype(np.uint64))
    D = KeyPositions.fixed_record(keys, RECORD)
    res = airtune(D, PROFILES["hbm"],
                  make_builders(lam_low=2**8, lam_high=2**16, base=2.0), k=3)
    layers = ilk.device_arrays_from_design(res.design)
    q = jnp.asarray(rng.choice(keys, 8192).astype(np.int32))
    lo, hi = ilk.traverse_index(layers, q, use_ref=True)   # jit warmup
    lo.block_until_ready()
    t0 = time.perf_counter()
    iters = 20
    for _ in range(iters):
        lo, hi = ilk.traverse_index(layers, q, use_ref=True)
    lo.block_until_ready()
    dt = (time.perf_counter() - t0) / iters
    emit("lookup_batch8192", dt * 1e6,
         f"{8192 / dt / 1e6:.1f}M lookups/s (jnp path, 1 CPU core); "
         f"design={res.design.describe()}")


# ---------------------------------------------------------------------------
# Serving engine (batched lookups + tiered block cache) — BENCH_serve.json
# ---------------------------------------------------------------------------
SERVE_JSON_PATH = None     # set by main() via --serve-json
TUNE_JSON_PATH = None      # set by main() via --tune-json
BASELINE_JSON_PATH = None  # set by main() via --baseline-json
FLEET_JSON_PATH = None     # set by main() via --fleet-json
CHAOS_JSON_PATH = None     # set by main() via --chaos-json
P99_JSON_PATH = None       # set by main() via --p99-json


def bench_serve():
    try:
        from benchmarks import serve_bench
    except ImportError:                # invoked as `python benchmarks/run.py`
        import serve_bench
    results = serve_bench.run_serve_bench()
    if SERVE_JSON_PATH:
        import json
        with open(SERVE_JSON_PATH, "w") as f:
            json.dump(results, f, indent=2)
        print(f"# wrote {SERVE_JSON_PATH}", flush=True)


# ---------------------------------------------------------------------------
# Sharded fleet vs monolith (repro.fleet) — BENCH_fleet.json
# ---------------------------------------------------------------------------
def bench_fleet():
    try:
        from benchmarks import serve_bench
    except ImportError:                # invoked as `python benchmarks/run.py`
        import serve_bench
    results = serve_bench.run_fleet_bench()
    serve_bench.emit_fleet(results)
    if FLEET_JSON_PATH:
        import json
        with open(FLEET_JSON_PATH, "w") as f:
            json.dump(results, f, indent=2)
        print(f"# wrote {FLEET_JSON_PATH}", flush=True)


# ---------------------------------------------------------------------------
# Fault-injection gate (retries, checksums, hot swap) — BENCH_chaos.json
# ---------------------------------------------------------------------------
def bench_chaos():
    try:
        from benchmarks import serve_bench
    except ImportError:                # invoked as `python benchmarks/run.py`
        import serve_bench
    results = serve_bench.run_chaos_bench()
    serve_bench.emit_chaos(results)
    if CHAOS_JSON_PATH:
        import json
        with open(CHAOS_JSON_PATH, "w") as f:
            json.dump(results, f, indent=2)
        print(f"# wrote {CHAOS_JSON_PATH}", flush=True)
    fatal = serve_bench.chaos_fatal_warnings(results)
    if fatal:
        for msg in fatal:
            print(f"::error::{msg}")
        raise SystemExit(1)


# ---------------------------------------------------------------------------
# Tail-latency tuning gate (mean vs p99 objective) — BENCH_p99.json
# ---------------------------------------------------------------------------
def bench_p99():
    try:
        from benchmarks import serve_bench
    except ImportError:                # invoked as `python benchmarks/run.py`
        import serve_bench
    results = serve_bench.run_p99_bench()
    serve_bench.emit_p99(results)
    if P99_JSON_PATH:
        import json
        with open(P99_JSON_PATH, "w") as f:
            json.dump(results, f, indent=2)
        print(f"# wrote {P99_JSON_PATH}", flush=True)
    fatal = serve_bench.p99_fatal_warnings(results)
    if fatal:
        for msg in fatal:
            print(f"::error::{msg}")
        raise SystemExit(1)


# ---------------------------------------------------------------------------
# Tuner speed per search strategy (repro.api facade) — BENCH_tune.json
# ---------------------------------------------------------------------------
def bench_tune():
    try:
        from benchmarks import tune_bench
    except ImportError:                # invoked as `python benchmarks/run.py`
        import tune_bench
    results = tune_bench.run_tune_bench()
    # compact per-strategy trend lines — the numbers to eyeball across PRs
    for strat, a in results.get("per_strategy", {}).items():
        print(f"# tune-trend {strat}: wall={a['wall_s']:.2f}s "
              f"(legacy {a['legacy_wall_s']:.2f}s) "
              f"built={a['layers_built']} reused={a['layers_reused']} "
              f"scored={a['scored']} sweeps={a['sweeps']} "
              f"work_reduction={a['work_reduction']:.1f}x", flush=True)
    sb = results.get("scoring_backends", {})
    fmt = lambda v: f"{v:.0f}us" if isinstance(v, (int, float)) else "n/a"
    print(f"# tune-trend scoring: numpy={fmt(sb.get('numpy_us'))} "
          f"jnp={fmt(sb.get('jnp_us'))} "
          f"pallas_interpret={fmt(sb.get('pallas_interpret_us'))}", flush=True)
    if TUNE_JSON_PATH:
        import json
        with open(TUNE_JSON_PATH, "w") as f:
            json.dump(results, f, indent=2)
        print(f"# wrote {TUNE_JSON_PATH}", flush=True)


# ---------------------------------------------------------------------------
# Baseline families head-to-head (§7.2 dominance) — BENCH_baseline.json
# ---------------------------------------------------------------------------
def bench_baseline():
    try:
        from benchmarks import baseline_bench
    except ImportError:                # invoked as `python benchmarks/run.py`
        import baseline_bench
    results = baseline_bench.run_baseline_bench()
    # compact per-cell trend lines — AirTune's margin over the best baseline
    for row in results.get("rows", []):
        best = min(row["baseline_costs_us"].values())
        print(f"# baseline-trend {row['dataset']}/{row['tier']}: "
              f"airtune={row['airtune_cost_us']:.1f}us "
              f"best_baseline={best:.1f}us "
              f"margin={best / max(row['airtune_cost_us'], 1e-12):.2f}x "
              f"reused={row['airtune_layers_reused']}", flush=True)
    if BASELINE_JSON_PATH:
        import json
        with open(BASELINE_JSON_PATH, "w") as f:
            json.dump(results, f, indent=2)
        print(f"# wrote {BASELINE_JSON_PATH}", flush=True)


# ---------------------------------------------------------------------------
# Roofline table from the dry-run
# ---------------------------------------------------------------------------
def bench_roofline():
    import os
    path = "dryrun_results.jsonl"
    if not os.path.exists(path):
        emit("roofline", 0.0, "dryrun_results.jsonl missing — run dryrun")
        return
    from benchmarks import roofline
    rows = roofline.table(path, "16x16")
    for r in rows:
        if r["status"] != "ok":
            emit(f"roofline_{r['arch']}_{r['shape']}", 0.0, r["status"])
            continue
        emit(f"roofline_{r['arch']}_{r['shape']}", r["bound_s"] * 1e6,
             f"dominant={r['dominant']} useful={r['useful_ratio']:.2f} "
             f"mfu_bound={r['mfu_bound']:.3f}")


BENCHES = [
    bench_fig2_example,
    bench_fig9_cold_lookup,
    bench_fig11_manual_sweep,
    bench_fig12_tuned_baselines,
    bench_fig13_spectrum,
    bench_fig15_build_time,
    bench_fig20_topk,
    bench_sec22_heterogeneous,
    bench_lookup_throughput,
    bench_serve,
    bench_fleet,
    bench_chaos,
    bench_p99,
    bench_tune,
    bench_baseline,
    bench_roofline,
]


def _take_json_flag(argv: list, flag: str, default_path: str):
    """Parse ``--flag[=PATH]`` / ``--flag PATH`` out of argv (in place)."""
    for i, arg in enumerate(argv):
        if arg == flag or arg.startswith(flag + "="):
            if "=" in arg:
                path = arg.split("=", 1)[1]
                del argv[i]
            elif i + 1 < len(argv) and argv[i + 1].endswith(".json") \
                    and not argv[i + 1].startswith("-"):
                path = argv[i + 1]                 # space-separated PATH
                del argv[i:i + 2]
            else:
                path = default_path
                del argv[i]
            return path
    return None


def main() -> None:
    global SERVE_JSON_PATH, TUNE_JSON_PATH, BASELINE_JSON_PATH, \
        FLEET_JSON_PATH, CHAOS_JSON_PATH, P99_JSON_PATH
    argv = list(sys.argv[1:])
    # emit BENCH_*.json (perf trajectories)
    SERVE_JSON_PATH = _take_json_flag(argv, "--serve-json", "BENCH_serve.json")
    TUNE_JSON_PATH = _take_json_flag(argv, "--tune-json", "BENCH_tune.json")
    BASELINE_JSON_PATH = _take_json_flag(argv, "--baseline-json",
                                         "BENCH_baseline.json")
    FLEET_JSON_PATH = _take_json_flag(argv, "--fleet-json",
                                      "BENCH_fleet.json")
    CHAOS_JSON_PATH = _take_json_flag(argv, "--chaos-json",
                                      "BENCH_chaos.json")
    P99_JSON_PATH = _take_json_flag(argv, "--p99-json", "BENCH_p99.json")
    only = argv[0] if argv else None
    print("name,us_per_call,derived")
    for bench in BENCHES:
        if only and only not in bench.__name__:
            continue
        t0 = time.perf_counter()
        bench()
        print(f"# {bench.__name__} took {time.perf_counter() - t0:.1f}s",
              flush=True)


if __name__ == "__main__":
    main()
