"""Generate the dry-run and roofline markdown report tables."""
from __future__ import annotations

import sys

sys.path.insert(0, "src")
sys.path.insert(0, ".")

from benchmarks import roofline


def dryrun_table(path: str) -> str:
    rows = roofline.load_results(path)
    rows.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))
    out = ["| arch | shape | mesh | status | mem/dev (args+temp) GB | "
           "dot FLOPs/dev | coll GB/dev | compile s |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["status"] == "ok":
            mem = ((r["memory"]["argument_bytes"] or 0)
                   + (r["memory"]["temp_bytes"] or 0)) / 1e9
            out.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | "
                f"{mem:.1f} | {r['dot_flops']:.2e} | "
                f"{r['collectives']['total'] / 1e9:.1f} | "
                f"{r['compile_s']:.0f} |")
        else:
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                       f"{r['status']} |  |  |  |  |")
    return "\n".join(out)


def roofline_table_md(path: str, mesh: str = "16x16") -> str:
    rows = roofline.table(path, mesh)
    out = ["| arch | shape | compute ms | memory ms | collective ms | "
           "dominant | useful ratio | MFU bound |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                       f"*{r['status']}* |  |  |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s'] * 1e3:.1f} | "
            f"{r['memory_s'] * 1e3:.1f} | {r['collective_s'] * 1e3:.1f} | "
            f"**{r['dominant']}** | {r['useful_ratio']:.2f} | "
            f"{r['mfu_bound']:.3f} |")
    return "\n".join(out)


if __name__ == "__main__":
    path = sys.argv[1] if len(sys.argv) > 1 else "dryrun_results.jsonl"
    which = sys.argv[2] if len(sys.argv) > 2 else "both"
    if which in ("dryrun", "both"):
        print(dryrun_table(path))
        print()
    if which in ("roofline", "both"):
        print(roofline_table_md(path))
