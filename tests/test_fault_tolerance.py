"""Fault-tolerant storage I/O: the backend seam, deterministic fault
injection, RetryPolicy retries/backoff/deadlines, degraded run splitting,
page CRC32 verification, typed failures, live hot-swap, prefetch error
propagation, and fleet shard degradation.

The load-bearing invariant everywhere: results under any *recoverable*
fault schedule are bit-identical to the fault-free run, and the faults
leave the observability surface honest (retried/stalled samples never fit
the measured tier profile)."""
import errno
import threading
import time

import numpy as np
import pytest

from repro.api import RetryPolicy, ServeSpec
from repro.core import IndexDesign, KeyPositions, write_index
from repro.core.builders import build_gband, build_gstep
from repro.core.nodes import outline
from repro.core.serialize import layer_page_crcs, page_crc, read_meta
from repro.fleet import ShardUnavailableError
from repro.serve import (CorruptPageError, DeadlineExceededError,
                         FaultInjectingBackend, FileBackend, IndexService,
                         ReadError, StorageError, pread_full)
from repro.serve.index_service import (demo_serving_design,
                                       measured_backing_profile)

from conftest import make_keys

P = 1024
_KEYS = make_keys("books", 60_000, seed=9)
_D = KeyPositions.fixed_record(_KEYS, 16)
_RETRY = RetryPolicy(max_attempts=4, backoff_s=1e-5, max_backoff_s=1e-4)
_SPEC = ServeSpec(cache_bytes=(64 << 10,), retry=_RETRY)


@pytest.fixture(scope="module")
def served(tmp_path_factory):
    path = str(tmp_path_factory.mktemp("ft") / "index.air")
    write_index(path, demo_serving_design(_D), page_bytes=P)
    rng = np.random.default_rng(1)
    qs = rng.choice(_KEYS, 700)
    with IndexService(path, profile=None, spec=_SPEC) as svc:
        want = svc.lookup(qs)
    return path, qs, want


def _faulty(path, **kw):
    return FaultInjectingBackend(FileBackend(path), **kw)


# ---------------------------------------------------------------------------
# backend seam basics
# ---------------------------------------------------------------------------
def test_pread_full_loops_torn_reads_to_the_full_window(tmp_path):
    # pread may legally return fewer bytes than asked; pread_full must
    # keep reading until the window fills (or true EOF)
    p = tmp_path / "blob"
    p.write_bytes(bytes(range(200)) * 10)
    import os
    fd = os.open(str(p), os.O_RDONLY)
    try:
        assert pread_full(fd, 2000, 0) == p.read_bytes()
        assert pread_full(fd, 5000, 1500) == p.read_bytes()[1500:]  # EOF-short
        assert pread_full(fd, 10, 5000) == b""
    finally:
        os.close(fd)


def test_fault_schedule_is_deterministic_and_heals_per_attempt(served):
    path, _, _ = served
    kw = dict(seed=3, eio_rate=0.5, eio_attempts=2, page_bytes=P)
    a, b = _faulty(path, **kw), _faulty(path, **kw)
    offs = [(P * k, 3 * P) for k in range(12)]
    for be in (a, b):
        for off, n in offs:
            for _ in range(3):          # two injected failures, then heals
                try:
                    be.pread(n, off)
                except OSError as e:
                    assert e.errno == errno.EIO
    assert a.fault_log == b.fault_log and a.fault_log  # replayable schedule
    # attempt-bounded faults healed: the third read of any window succeeds
    assert all(att < 2 for (_, _, _, att) in a.fault_log)


def test_only_over_bytes_and_only_from_offset_gate_faults(served):
    path, _, _ = served
    be = _faulty(path, seed=1, eio_rate=1.0, only_over_bytes=P,
                 only_from_offset=4 * P, page_bytes=P)
    assert be.pread(P, 0)                 # small read: passes
    assert be.pread(8 * P, 0)             # big but before the offset gate
    with pytest.raises(OSError):
        be.pread(8 * P, 4 * P)            # big AND past the gate: faults


# ---------------------------------------------------------------------------
# RetryPolicy surface
# ---------------------------------------------------------------------------
def test_retry_policy_round_trips_and_validates():
    rp = RetryPolicy(max_attempts=5, backoff_s=0.002, backoff_mult=3.0,
                     max_backoff_s=0.05, pread_deadline_s=0.5,
                     batch_deadline_s=2.0)
    assert RetryPolicy.from_json(rp.to_json()) == rp
    assert RetryPolicy.from_dict(rp.to_dict()) == rp
    # backoff: exponential, capped
    assert rp.backoff(0) == 0.002
    assert rp.backoff(1) == 0.006
    assert rp.backoff(10) == 0.05
    with pytest.raises(ValueError):
        RetryPolicy(max_attempts=0).validate()
    with pytest.raises(ValueError):
        RetryPolicy(backoff_mult=0.5).validate()
    with pytest.raises(ValueError):
        RetryPolicy(pread_deadline_s=0.0).validate()
    with pytest.raises(ValueError):
        RetryPolicy.from_dict({"max_attempts": 2, "bogus": 1})


def test_serve_spec_carries_retry_policy_through_json():
    spec = ServeSpec(retry=RetryPolicy(max_attempts=7), verify_checksums=False)
    back = ServeSpec.from_json(spec.to_json())
    assert back == spec
    assert isinstance(back.retry, RetryPolicy)
    assert back.retry.max_attempts == 7 and back.verify_checksums is False


# ---------------------------------------------------------------------------
# recovery identity: faults in, correct bytes out
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("kw", [
    dict(eio_rate=0.4, eio_attempts=2),
    dict(short_rate=0.5, short_attempts=2),
    dict(stall_rate=0.4, stall_seconds=5e-4, stall_attempts=1),
    dict(corrupt_rate=1.0, corrupt_attempts=1, only_over_bytes=P),
    dict(fail_first=3),
    dict(eio_rate=0.3, eio_attempts=1, short_rate=0.3, short_attempts=1,
         corrupt_rate=0.5, corrupt_attempts=1, only_over_bytes=P),
], ids=["eio", "short", "stall", "corrupt", "flaky-start", "combined"])
def test_recoverable_schedules_serve_bit_identical(served, kw):
    path, qs, want = served
    with IndexService(path, profile=None, spec=_SPEC,
                      backend_factory=lambda p: _faulty(
                          p, seed=11, page_bytes=P, **kw)) as svc:
        got = svc.lookup(qs)
        s = svc.stats
    assert np.array_equal(want, got)
    if "eio_rate" in kw or "short_rate" in kw or kw.get("fail_first"):
        assert s.io_retries > 0
    if kw.get("corrupt_rate") == 1.0:
        assert s.corrupt_pages > 0
    # every repaired/retried serving read is tainted, never clean
    if s.corrupt_pages:
        assert any(r[3] for r in s.read_samples)


def test_persistent_eio_surfaces_typed_read_error(served):
    path, qs, _ = served
    with pytest.raises(ReadError) as ei:
        with IndexService(path, profile=None, spec=_SPEC,
                          backend_factory=lambda p: _faulty(
                              p, seed=2, eio_rate=0.5,
                              eio_attempts=None)) as svc:
            svc.lookup(qs)
    assert ei.value.attempts == _RETRY.max_attempts
    assert isinstance(ei.value, StorageError)


def test_persistent_corruption_surfaces_corrupt_page_error(served):
    path, qs, _ = served
    meta_end = None
    with IndexService(path, profile=None, spec=_SPEC) as svc:
        meta_end = min(lm.offset for lm in svc.meta.layers)
    with pytest.raises(CorruptPageError) as ei:
        with IndexService(path, profile=None, spec=_SPEC,
                          backend_factory=lambda p: _faulty(
                              p, seed=2, corrupt_rate=1.0,
                              corrupt_attempts=10**9, page_bytes=P,
                              only_from_offset=meta_end)) as svc:
            svc.lookup(qs)
    assert ei.value.page_id is not None


def test_batch_deadline_surfaces_deadline_exceeded(served):
    path, qs, _ = served
    spec = _SPEC.replace(retry=_RETRY.replace(batch_deadline_s=1e-9))
    with IndexService(path, profile=None, spec=spec) as svc:
        with pytest.raises(DeadlineExceededError):
            svc.lookup(qs)          # cold cache: must pread, deadline gone
        assert svc.stats.io_timeouts > 0


def test_stalls_count_timeouts_taint_samples_but_still_serve(served):
    path, qs, want = served
    spec = _SPEC.replace(retry=_RETRY.replace(pread_deadline_s=1e-4))
    with IndexService(path, profile=None, spec=spec,
                      backend_factory=lambda p: _faulty(
                          p, seed=5, stall_rate=0.6, stall_seconds=5e-3,
                          stall_attempts=10**9, page_bytes=P)) as svc:
        got = svc.lookup(qs)
        s = svc.stats
    assert np.array_equal(want, got)   # late bytes beat no bytes
    assert s.io_timeouts > 0
    assert any(r[3] for r in s.read_samples)


def test_degraded_split_rescues_runs_failing_only_when_coalesced(served):
    # faults ONLY on multi-page reads: the run-level pread exhausts its
    # budget, the engine splits to page granularity, pages come through
    path, qs, want = served
    with IndexService(path, profile=None, spec=_SPEC,
                      backend_factory=lambda p: _faulty(
                          p, seed=4, eio_rate=1.0, eio_attempts=None,
                          only_over_bytes=P, page_bytes=P)) as svc:
        got = svc.lookup(qs)
        s = svc.stats
    assert np.array_equal(want, got)
    assert s.degraded_runs > 0


# ---------------------------------------------------------------------------
# page checksums
# ---------------------------------------------------------------------------
def test_written_files_carry_per_page_crcs_that_match_bytes(served):
    path, _, _ = served
    import os
    fd = os.open(path, os.O_RDONLY)
    try:
        meta = read_meta(fd)
        assert meta.page_bytes == P
        for lm in meta.layers:
            blob = pread_full(fd, lm.size, lm.offset)
            assert lm.page_crcs == layer_page_crcs(blob, P)
            # and the on-disk page form (hole-padded) hashes identically
            for k, crc in enumerate(lm.page_crcs):
                disk = pread_full(fd, P, lm.offset + k * P)
                assert page_crc(disk, P) == crc
    finally:
        os.close(fd)


def test_unchecksummed_file_opens_verify_skipped(served, tmp_path):
    path, qs, want = served
    old = str(tmp_path / "old.air")
    write_index(old, demo_serving_design(_D), page_bytes=P, checksums=False)
    import os
    fd = os.open(old, os.O_RDONLY)
    try:
        assert all(lm.page_crcs is None for lm in read_meta(fd).layers)
    finally:
        os.close(fd)
    with IndexService(old, profile=None, spec=_SPEC) as svc:
        assert svc._st.page_crcs is None
        assert np.array_equal(svc.lookup(qs), want)


def test_verify_checksums_off_and_page_size_override_skip_verify(served):
    path, qs, want = served
    with IndexService(path, profile=None,
                      spec=_SPEC.replace(verify_checksums=False)) as svc:
        assert svc._st.page_crcs is None
        assert np.array_equal(svc.lookup(qs), want)
    # repaging the file (spec page_bytes != writer page_bytes) re-tiles
    # pages, so the writer's CRCs no longer apply: verify must skip, and
    # results must still be exact
    with IndexService(path, profile=None,
                      spec=_SPEC.replace(page_bytes=512)) as svc:
        assert svc._st.page_crcs is None
        assert np.array_equal(svc.lookup(qs), want)


def test_corrupt_page_repair_is_invisible_to_cache_contents(served):
    path, qs, want = served
    with IndexService(path, profile=None, spec=_SPEC) as clean:
        clean.lookup(qs)
        clean_pages = {pid: data for t in clean.cache.tiers
                       for pid, data in t.items()}
    with IndexService(path, profile=None, spec=_SPEC,
                      backend_factory=lambda p: _faulty(
                          p, seed=13, corrupt_rate=1.0, corrupt_attempts=1,
                          only_over_bytes=P, page_bytes=P)) as svc:
        got = svc.lookup(qs)
        assert svc.stats.corrupt_pages > 0
        faulted_pages = {pid: data for t in svc.cache.tiers
                         for pid, data in t.items()}
    assert np.array_equal(want, got)
    assert faulted_pages == clean_pages   # repaired bytes, not torn ones


# ---------------------------------------------------------------------------
# hot swap
# ---------------------------------------------------------------------------
def _alt_design():
    # a structurally different stack (different branching) over the same
    # data: lookups stay correct but windows differ from the demo design
    l1 = build_gstep(_D, 8, 2**9)
    o1 = outline(l1, _D)
    l2 = build_gband(o1, 2**8)
    l3 = build_gstep(outline(l2, o1), 8, 2**6)
    return IndexDesign(layers=(l1, l2, l3), data=_D)


def test_swap_replaces_epoch_and_keeps_results_exact(served, tmp_path):
    path, qs, want = served
    alt = str(tmp_path / "alt.air")
    write_index(alt, _alt_design(), page_bytes=P)
    with IndexService(alt, profile=None, spec=_SPEC) as svc:
        want_alt = svc.lookup(qs)
    assert not np.array_equal(want, want_alt)   # distinguishable designs

    with IndexService(path, profile=None, spec=_SPEC) as svc:
        assert np.array_equal(svc.lookup(qs), want)
        old_queries = svc.stats.queries
        svc.swap(alt)
        assert svc.path == alt
        assert np.array_equal(svc.lookup(qs), want_alt)
        # fresh epoch stats (observed_profile stays honest for the new
        # design), only the swap counter carries forward
        assert svc.stats.swaps == 1
        assert svc.stats.queries == len(qs) < old_queries + len(qs)
        svc.swap(path)
        assert svc.stats.swaps == 2
        assert np.array_equal(svc.lookup(qs), want)


def test_swap_persists_old_epoch_stats(served, tmp_path):
    path, qs, want = served
    import shutil
    a = str(tmp_path / "a.air")
    shutil.copy(path, a)
    from repro.serve.index_service import load_serve_stats
    with IndexService(a, profile=None,
                      spec=_SPEC.replace(persist_stats=True)) as svc:
        svc.lookup(qs)
        n = svc.stats.queries
        svc.swap(a)                      # same file, new epoch
        persisted = load_serve_stats(a)
        assert persisted is not None and persisted.queries == n


def test_swap_under_live_traffic_never_mixes_epochs(served, tmp_path):
    path, qs, want = served
    alt = str(tmp_path / "alt_live.air")
    write_index(alt, _alt_design(), page_bytes=P)
    rng = np.random.default_rng(3)
    batches = [rng.choice(_KEYS, 120) for _ in range(8)]
    spec = _SPEC.replace(pipeline_depth=2)
    with IndexService(path, profile=None, spec=spec) as svc:
        want_a = [svc.lookup(b) for b in batches]
    with IndexService(alt, profile=None, spec=spec) as svc:
        want_b = [svc.lookup(b) for b in batches]

    results, errors, stop = [], [], threading.Event()
    svc = IndexService(path, profile=None, spec=spec)

    def hammer():
        try:
            while not stop.is_set():
                results.append(svc.lookup_batches(batches))
        except Exception as e:          # pragma: no cover - fails the test
            errors.append(e)

    t = threading.Thread(target=hammer)
    t.start()
    try:
        for k in range(6):              # swap back and forth under load
            svc.swap(alt if k % 2 == 0 else path)
            time.sleep(0.01)
    finally:
        stop.set()
        t.join()
        svc.close()
    assert not errors
    assert results
    for run in results:
        for i, got in enumerate(run):
            ok_a = np.array_equal(got, want_a[i])
            ok_b = np.array_equal(got, want_b[i])
            # every batch is served wholly by one epoch: old or new,
            # never a row-mix of the two
            assert ok_a or ok_b


def test_lookup_after_close_raises_cleanly(served):
    path, qs, _ = served
    svc = IndexService(path, profile=None, spec=_SPEC)
    svc.close()
    assert svc.fd is None
    assert svc.stats is not None        # final epoch stays inspectable
    with pytest.raises(RuntimeError):
        svc.lookup(qs)
    with pytest.raises(RuntimeError):
        svc.swap(path)


# ---------------------------------------------------------------------------
# prefetch error propagation (satellite: a dead stage-1 worker must not
# silently degrade or hang the pipeline)
# ---------------------------------------------------------------------------
class _PrefetchOnlyFaults(FileBackend):
    """Healthy on the serving thread, EIO inside the prefetch worker."""

    def pread(self, nbytes, offset):
        if threading.current_thread().name.startswith("airindex-prefetch"):
            raise OSError(errno.EIO, "injected prefetch-only EIO")
        return super().pread(nbytes, offset)


def test_prefetch_worker_failure_surfaces_at_batch_boundary(tmp_path):
    # deterministic construction: a dense bottom layer (hundreds of
    # pages), a cache big enough that nothing evicts, batch 0 pre-warmed
    # (the serving thread fetches nothing), and the later batches in a
    # cold disjoint key region — the prefetch worker is the *only* thread
    # with pages to fetch, so it faults on every run, not just when it
    # wins a race against stage 2
    l1 = build_gstep(_D, 8, 2**6)
    o1 = outline(l1, _D)
    l2 = build_gband(o1, 2**9)
    l3 = build_gstep(outline(l2, o1), 8, 2**7)
    path = str(tmp_path / "dense.air")
    write_index(path, IndexDesign(layers=(l1, l2, l3), data=_D),
                page_bytes=P)
    warm = _KEYS[0:20000:40].copy()
    cold = [_KEYS[30000 + 5000 * j: 30000 + 5000 * j + 100].copy()
            for j in range(4)]
    spec = _SPEC.replace(cache_bytes=(4 << 20,), pipeline_depth=2,
                         prefetch_layers=2)
    with IndexService(path, profile=None, spec=spec,
                      backend_factory=_PrefetchOnlyFaults) as svc:
        svc.lookup(warm)
        with pytest.raises(ReadError):
            svc.lookup_batches([warm] + cold)
        # the pipeline recovers once drained: plain lookups still serve
        # (on the serving thread, where the backend is healthy)
        assert svc.lookup(cold[0]).shape == (100, 2)


# ---------------------------------------------------------------------------
# honesty of the observability surface
# ---------------------------------------------------------------------------
def test_measured_profile_excludes_tainted_samples(served):
    path, qs, want = served
    with IndexService(path, profile=None, spec=_SPEC) as svc:
        meta_end = min(lm.offset for lm in svc.meta.layers)
    with IndexService(path, profile=None, spec=_SPEC,
                      backend_factory=lambda p: _faulty(
                          p, seed=17, eio_rate=0.5, eio_attempts=2,
                          only_from_offset=meta_end, page_bytes=P)) as svc:
        assert np.array_equal(svc.lookup(qs), want)
        stats = svc.stats
    assert any(r[3] for r in stats.read_samples)
    import dataclasses
    clean_only = dataclasses.replace(
        stats, read_samples=[r for r in stats.read_samples if not r[3]])
    assert measured_backing_profile(stats, min_samples=2) == \
        measured_backing_profile(clean_only, min_samples=2)


def test_read_samples_round_trip_with_legacy_widths():
    from repro.serve import ServeStats
    s = ServeStats()
    s.record_read(100, 1e-4)
    s.record_read(200, 2e-4, overlapped=True)
    s.record_read(300, 3e-4, tainted=True)
    back = ServeStats.from_snapshot(s.snapshot())
    assert back == s
    legacy = s.snapshot()
    legacy["read_samples"] = [[100, 1e-4], [200, 2e-4, True]]
    old = ServeStats.from_snapshot(legacy)
    assert old.read_samples == [(100, 1e-4, False, False),
                                (200, 2e-4, True, False)]


# ---------------------------------------------------------------------------
# fleet shard degradation
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def fleet_parts(tmp_path_factory):
    # AirTune at this scale picks fully-resident 1-layer shard designs —
    # nothing would ever pread and the failure-isolation tests would pass
    # vacuously.  Build shard files from the 3-layer demo design instead
    # and drive FleetService directly, so every shard lookup walks disk.
    from repro.fleet.fleet import _partition
    from repro.fleet.service import FleetService
    from repro.fleet.spec import ShardMap

    d = tmp_path_factory.mktemp("ftfleet")
    keys = make_keys("gmm", 20_000, seed=6)
    D = KeyPositions.fixed_record(keys, 16)
    shard_map = ShardMap.even_keys(D.keys, 3)
    parts, bases = _partition(D, shard_map)
    paths = []
    for i, part in enumerate(parts):
        p = str(d / f"shard_{i}.air")
        write_index(p, demo_serving_design(part), page_bytes=P)
        paths.append(p)

    def serve(backend_factories=None):
        return FleetService(shard_map, paths, bases, profile=None,
                            specs=[_SPEC] * 3,
                            backend_factories=backend_factories)
    return serve, D


class _DiesAfterOpen(FileBackend):
    """Healthy while the service opens (meta + resident loads), then every
    pread raises persistently — a disk that died under a live shard."""

    armed = False

    def pread(self, nbytes, offset):
        if _DiesAfterOpen.armed:
            raise OSError(errno.EIO, "injected post-open EIO")
        return super().pread(nbytes, offset)


def _sick_shard_factories(svc_paths, sick: int):
    _DiesAfterOpen.armed = False

    def make(path):
        if path == svc_paths[sick]:
            return _DiesAfterOpen(path)
        return FileBackend(path)
    return make


def test_fleet_isolates_failing_shard_and_reports_health(fleet_parts):
    serve, D = fleet_parts
    rng = np.random.default_rng(2)
    qs = rng.choice(D.keys, 400)
    with serve() as svc:
        want = svc.lookup(qs)
        paths = svc.paths
    sick = 1
    with serve(
            backend_factories=_sick_shard_factories(paths, sick)) as svc:
        _DiesAfterOpen.armed = True      # the disk dies under live traffic
        # default contract: fail stop, typed
        with pytest.raises(ShardUnavailableError) as ei:
            svc.lookup(qs)
        assert ei.value.shard == sick
        assert svc.healthy == [True, False, True]
        # degraded contract: healthy shards bit-identical + explicit mask
        out, avail = svc.lookup(qs, partial_results=True)
        sick_keys = svc.shard_map.route(qs) == sick
        assert np.array_equal(avail, ~sick_keys)
        assert np.array_equal(out[avail], want[avail])
        assert (out[~avail] == -1).all()
        # batched flavor
        outs, avails = svc.lookup_batches([qs[:150], qs[150:]],
                                          partial_results=True)
        assert np.array_equal(np.concatenate(avails), ~sick_keys)
        assert np.array_equal(np.concatenate(outs)[~sick_keys],
                              want[~sick_keys])
        # health is in the summary, and the summary never raises
        summary = svc.stats_summary()
        assert summary["unhealthy_shards"] == 1
        assert summary["shards"][sick]["healthy"] is False
        assert summary["shards"][sick]["error"]
        # operator repaired the shard (here: nothing to repair - the
        # schedule was the fault): back in rotation
        svc.mark_healthy(sick)
        assert svc.stats_summary()["unhealthy_shards"] == 0


class _CorruptsAfterOpen(FileBackend):
    """Healthy through open, then every pread reports persistent page
    corruption — the typed cause the availability report must preserve."""

    armed = False

    def pread(self, nbytes, offset):
        raw = super().pread(nbytes, offset)
        if _CorruptsAfterOpen.armed:
            raise CorruptPageError("injected persistent corruption",
                                   path=self.path,
                                   page_id=int(offset) // P)
        return raw


def test_partial_results_preserve_corrupt_page_cause(fleet_parts):
    # regression: a broad `except Exception` anywhere on the fleet path
    # used to be able to flatten CorruptPageError into a generic failure;
    # the typed class name must survive into errors[] and stats_summary
    serve, D = fleet_parts
    rng = np.random.default_rng(3)
    qs = rng.choice(D.keys, 400)
    with serve() as svc:
        want = svc.lookup(qs)
        paths = svc.paths
    sick = 2
    _CorruptsAfterOpen.armed = False

    def make(path):
        if path == paths[sick]:
            return _CorruptsAfterOpen(path)
        return FileBackend(path)

    with serve(backend_factories=make) as svc:
        _CorruptsAfterOpen.armed = True
        out, avail = svc.lookup(qs, partial_results=True)
        sick_keys = svc.shard_map.route(qs) == sick
        assert np.array_equal(avail, ~sick_keys)
        assert np.array_equal(out[avail], want[avail])
        # the typed cause survives, by name, in both reporting surfaces
        assert svc.healthy == [True, True, False]
        assert "CorruptPageError" in svc.errors[sick]
        row = svc.stats_summary()["shards"][sick]
        assert row["healthy"] is False
        assert "CorruptPageError" in row["error"]
    _CorruptsAfterOpen.armed = False


def test_fleet_stats_summary_survives_closed_shard_service(fleet_parts):
    serve, D = fleet_parts
    with serve() as svc:
        svc.lookup(np.asarray(D.keys[:64]))
        svc.services[0].close()          # simulate a torn-down shard
        summary = svc.stats_summary()    # must not raise
        assert len(summary["shards"]) == svc.n_shards
