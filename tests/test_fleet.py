"""Sharded fleet: ShardMap routing, FleetSpec round-trips, the budget
allocator's water-filling properties, the Fleet lifecycle (tune → save →
open → serve), scatter-gather bit-identity, and robustness of persisted
stats loading (the fleet startup path reads N of them)."""
import json
import os
import warnings

import numpy as np
import pytest

from repro.api import Index, ServeSpec, TuneSpec, detect_drift_from_file
from repro.core import KeyPositions, PROFILES
from repro.fleet import (CachePlan, Fleet, FleetSpec, ShardMap,
                         allocate_cache_budget, demand_from_design,
                         demand_from_meta, split_cache_tiers)
from repro.fleet.budget import ShardDemand
from repro.serve import IndexService, cacheable_working_set
from repro.serve.index_service import (load_serve_stats, load_stats_history,
                                       stats_path)

from conftest import make_keys

SPEC = TuneSpec(lam_low=2**8, lam_high=2**14, lam_base=4.0, k=3,
                max_layers=4, page_bytes=1024)
FSPEC = FleetSpec(n_shards=4, tune=SPEC,
                  serve=ServeSpec(persist_stats=True))


@pytest.fixture(scope="module")
def data():
    keys = make_keys("gmm", 40_000, seed=5)
    return KeyPositions.fixed_record(keys, 16)


@pytest.fixture(scope="module")
def saved_fleet(data, tmp_path_factory):
    d = str(tmp_path_factory.mktemp("fleet") / "f")
    fleet = Fleet.tune(data, "azure_ssd", FSPEC).build()
    fleet.save(d)
    return d, fleet


# ---------------------------------------------------------------------------
# ShardMap
# ---------------------------------------------------------------------------
def test_shard_map_routes_every_key_to_its_range(data):
    sm = ShardMap.even_keys(data.keys, 4)
    sids = sm.route(data.keys)
    assert sm.n_shards == 4
    # bounds are the first key of each shard: routing must agree with the
    # slice boundaries even_keys cut
    sl = sm.slice_bounds(data.keys)
    for s, (a, b) in enumerate(sl):
        assert (sids[a:b] == s).all()
        assert b - a > 0


def test_shard_map_sub_batches_partition_exactly(data):
    sm = ShardMap.even_keys(data.keys, 3)
    rng = np.random.default_rng(0)
    q = rng.choice(data.keys, 257)
    seen = np.zeros(len(q), dtype=bool)
    for sid, pos in sm.sub_batches(q):
        assert not seen[pos].any()
        seen[pos] = True
        assert (sm.route(q[pos]) == sid).all()
    assert seen.all()


def test_shard_map_requires_sorted_distinct_bounds():
    with pytest.raises(ValueError):
        ShardMap(bounds=(10, 10))
    with pytest.raises(ValueError):
        ShardMap(bounds=(20, 10))


def test_shard_map_round_trips():
    sm = ShardMap(bounds=(100, 2**40, 2**63))
    assert ShardMap.from_dict(sm.to_dict()) == sm


# ---------------------------------------------------------------------------
# FleetSpec
# ---------------------------------------------------------------------------
def test_fleet_spec_round_trips_nested_specs():
    spec = FleetSpec(n_shards=8, tune=SPEC,
                     serve=ServeSpec(cache_bytes=(4096,), persist_stats=True),
                     cache_budget_bytes=1 << 20, budget_quantum=8192)
    again = FleetSpec.from_json(spec.to_json())
    assert again == spec
    assert again.tune == SPEC
    assert again.quantum == 8192


def test_fleet_spec_rejects_unknown_fields():
    with pytest.raises(ValueError):
        FleetSpec.from_dict({"n_shards": 2, "cache_budget": 1})


def test_fleet_spec_quantum_falls_back_to_page_bytes():
    assert FleetSpec(tune=SPEC).quantum == SPEC.page_bytes


# ---------------------------------------------------------------------------
# budget allocator
# ---------------------------------------------------------------------------
def _demand(shard, traffic, ws, saving=1e-4):
    return ShardDemand(shard=shard, traffic=traffic, working_set=ws,
                       saving=saving)


def test_water_filling_funds_hot_shards_first():
    demands = [_demand(0, 100.0, 8192), _demand(1, 10.0, 8192),
               _demand(2, 1.0, 8192)]
    plan = allocate_cache_budget(demands, 12288, quantum=4096)
    assert plan.for_shard(0) == 8192          # hot: full working set
    assert plan.for_shard(1) == 4096          # warm: the remainder
    assert plan.for_shard(2) == 0             # cold: priced out
    assert plan.allocated_bytes <= 12288


def test_water_filling_never_over_allocates_a_working_set():
    plan = allocate_cache_budget([_demand(0, 5.0, 5000)], 1 << 20,
                                 quantum=4096)
    # saturation: ceil(5000/4096) pages, not the whole budget
    assert plan.for_shard(0) == 8192
    assert plan.unallocated_bytes == (1 << 20) - 8192


def test_zero_working_set_earns_nothing():
    plan = allocate_cache_budget([_demand(0, 100.0, 0)], 1 << 20,
                                 quantum=4096)
    assert plan.for_shard(0) == 0


def test_duplicate_shard_rejected():
    with pytest.raises(ValueError):
        allocate_cache_budget([_demand(0, 1.0, 1), _demand(0, 2.0, 1)],
                              4096, quantum=4096)


def test_predicted_gain_monotone_in_budget():
    demands = [_demand(0, 9.0, 50_000), _demand(1, 3.0, 50_000)]
    gains = [allocate_cache_budget(demands, b, quantum=4096).predicted_gain
             for b in (0, 16 << 10, 64 << 10, 256 << 10)]
    assert all(a <= b + 1e-12 for a, b in zip(gains, gains[1:]))


def test_split_cache_tiers_preserves_total_and_quantum():
    tiers = split_cache_tiers(24576, (64 << 10, 512 << 10), quantum=4096)
    assert sum(tiers) == 24576
    assert all(t % 4096 == 0 for t in tiers)
    assert split_cache_tiers(8192, (), quantum=4096) == (8192,)


def test_demand_from_meta_uses_exact_file_layer_sizes(data, saved_fleet):
    _, fleet = saved_fleet
    idx = fleet.shards[0]
    d = demand_from_meta(0, idx.file_meta, PROFILES["azure_ssd"],
                         cache=PROFILES["host_dram"])
    assert d.working_set == cacheable_working_set(idx.file_meta, 1)
    assert d.saving >= 0.0


def test_demand_from_design_matches_working_set(data):
    idx = Index.tune(data, "azure_ssd", SPEC).build()
    d = demand_from_design(0, idx.result.design, PROFILES["azure_ssd"],
                           cache=PROFILES["host_dram"])
    layers = idx.result.design.layers
    non_resident = layers[:len(layers) - 1]
    assert d.working_set == sum(lay.size_bytes for lay in non_resident)
    assert d.saving >= 0.0


# ---------------------------------------------------------------------------
# Fleet lifecycle + scatter-gather identity
# ---------------------------------------------------------------------------
def test_fleet_lookup_covers_every_key(data, saved_fleet):
    _, fleet = saved_fleet
    rng = np.random.default_rng(1)
    q = rng.choice(data.keys, 500)
    got = fleet.lookup(q)
    order = np.searchsorted(data.keys, q)
    # Alg. 1 returns the final search window (global offsets after the
    # shard base is added back): it must contain each record's true range
    assert (got[:, 0] <= data.lo[order]).all()
    assert (got[:, 1] >= data.hi[order]).all()
    assert (got[:, 1] > got[:, 0]).all()


def test_fleet_open_restores_manifest(data, saved_fleet):
    d, fleet = saved_fleet
    again = Fleet.open(d, data=data)
    assert again.spec == fleet.spec
    assert again.shard_map == fleet.shard_map
    assert again.bases == fleet.bases
    assert [i.path for i in again.shards] == [i.path for i in fleet.shards]
    again.close()


def test_fleet_open_rejects_mismatched_data(saved_fleet):
    d, _ = saved_fleet
    other = KeyPositions.fixed_record(make_keys("uniform", 10_000, seed=9),
                                      16)
    with pytest.raises(ValueError):
        Fleet.open(d, data=other)


def test_scatter_gather_bit_identical_to_sequential(data, saved_fleet):
    d, fleet = saved_fleet
    rng = np.random.default_rng(2)
    q = rng.choice(data.keys, 700)
    # reference: each shard served alone, one at a time, plus its base
    want = np.empty((len(q), 2), dtype=np.int64)
    for sid, pos in fleet.shard_map.sub_batches(q):
        with IndexService(fleet.shards[sid].path,
                          profile="azure_ssd") as ref:
            want[pos] = ref.lookup(q[pos]) + fleet.bases[sid]
    with fleet.serve(persist_stats=False) as svc:
        got = svc.lookup(q)
    assert np.array_equal(got, want)


def test_lookup_batches_identical_to_lookup(data, saved_fleet):
    _, fleet = saved_fleet
    rng = np.random.default_rng(3)
    batches = [rng.choice(data.keys, 128) for _ in range(6)]
    with fleet.serve(persist_stats=False,
                     pipeline_depth=2, prefetch_layers=2) as svc:
        want = [svc.lookup(b) for b in batches]
        got = svc.lookup_batches(batches)
    assert all(np.array_equal(w, g) for w, g in zip(want, got))


def test_fleet_serve_splits_budget_and_reports_plan(data, saved_fleet):
    _, fleet = saved_fleet
    with fleet.serve(total_cache_bytes=64 << 10,
                     persist_stats=False) as svc:
        svc.lookup(data.keys[:256])
        summary = svc.stats_summary()
    assert summary["plan"] is not None
    assert summary["plan"]["total_bytes"] == 64 << 10
    assert summary["queries"] == 256
    assert len(summary["shards"]) == fleet.n_shards


def test_fleet_retune_budgeted_smoke(data, saved_fleet):
    d, _ = saved_fleet
    fleet = Fleet.open(d, data=data)
    retuned, plan = fleet.retune_budgeted(data=data,
                                          total_cache_bytes=128 << 10)
    assert isinstance(plan, CachePlan)
    assert retuned.spec.cache_budget_bytes == 128 << 10
    assert retuned.n_shards == fleet.n_shards
    # every shard has a design again (unsaved fleet, ready to build/save)
    retuned.build()
    rng = np.random.default_rng(4)
    q = rng.choice(data.keys, 200)
    assert np.array_equal(retuned.lookup(q), fleet.lookup(q))
    fleet.close()


def test_fleet_retune_budgeted_requires_budget(data, saved_fleet):
    d, _ = saved_fleet
    fleet = Fleet.open(d, data=data)
    with pytest.raises(ValueError):
        fleet.retune_budgeted(data=data)
    fleet.close()


# ---------------------------------------------------------------------------
# cacheable_working_set
# ---------------------------------------------------------------------------
def test_cacheable_working_set_counts_non_resident_layers(data, tmp_path):
    idx = Index.tune(data, "azure_nfs", SPEC).build()
    path = str(tmp_path / "ws.air")
    idx.save(path)
    with IndexService(path, profile="azure_nfs") as svc:
        meta = svc.meta
    L = len(meta.layers)
    assert cacheable_working_set(meta, resident_layers=L) == 0
    total = sum(lm.size for lm in meta.layers)
    # resident_layers=0 clamps to 1: the engine always pins the root
    assert cacheable_working_set(meta, resident_layers=0) \
        == cacheable_working_set(meta, resident_layers=1) \
        == total - meta.layers[-1].size


# ---------------------------------------------------------------------------
# persisted-stats robustness (the fleet startup path)
# ---------------------------------------------------------------------------
def _serve_some(path, n=600):
    rng = np.random.default_rng(0)
    with IndexService(path, profile="azure_ssd",
                      spec=ServeSpec(persist_stats=True)) as svc:
        svc.lookup(rng.choice(np.arange(1, n, dtype=np.uint64), 256))


@pytest.fixture()
def stats_file(data, tmp_path):
    idx = Index.tune(data, "azure_ssd", SPEC).build()
    path = str(tmp_path / "s.air")
    idx.save(path)
    rng = np.random.default_rng(0)
    with IndexService(path, profile="azure_ssd",
                      spec=ServeSpec(persist_stats=True)) as svc:
        svc.lookup(rng.choice(data.keys, 256))
    assert os.path.exists(stats_path(path))
    return path


def test_truncated_stats_file_warns_not_raises(stats_file):
    with open(stats_path(stats_file), "r+") as f:
        raw = f.read()
        f.seek(0)
        f.truncate()
        f.write(raw[:len(raw) // 2])      # mid-JSON truncation
    with pytest.warns(RuntimeWarning):
        assert load_stats_history(stats_file) == []
    with pytest.warns(RuntimeWarning):
        assert load_serve_stats(stats_file) is None
    with pytest.warns(RuntimeWarning):
        report = detect_drift_from_file(stats_file)
    assert report is not None
    assert report.action == "observe"
    assert report.confidence == 0.0


def test_wrong_top_level_type_warns_not_raises(stats_file):
    with open(stats_path(stats_file), "w") as f:
        json.dump(["not", "a", "dict"], f)
    with pytest.warns(RuntimeWarning):
        assert load_stats_history(stats_file) == []


def test_undecodable_snapshot_skipped_newer_first(stats_file):
    history = load_stats_history(stats_file)
    history.append({"stats": {"queries": "corrupt"}, "profile": None})
    with open(stats_path(stats_file), "w") as f:
        json.dump({"snapshots": history}, f)
    # newest snapshot is garbage: load_serve_stats falls back to the older
    # good one instead of raising
    with pytest.warns(RuntimeWarning):
        stats = load_serve_stats(stats_file)
    assert stats is not None and stats.queries > 0


def test_missing_stats_file_is_silent(tmp_path, data):
    idx = Index.tune(data, "azure_ssd", SPEC).build()
    path = str(tmp_path / "nostats.air")
    idx.save(path)
    with warnings.catch_warnings():
        warnings.simplefilter("error")    # cold start must not warn
        assert load_stats_history(path) == []
        assert load_serve_stats(path) is None
        assert detect_drift_from_file(path) is None
