"""Serving engine: parity with both lookup paths, cache behavior, read
coalescing, tiered LRU mechanics, CachedProfile, paged serialization."""
import numpy as np
import pytest

from repro.core import (CachedProfile, IndexDesign, KeyPositions, PROFILES,
                        airtune, build_gstep, coalesce_ranges, lookup_batch,
                        make_builders, outline, page_span, write_index)
from repro.api import ServeSpec
from repro.core.serialize import lookup_serialized
from repro.serve.index_service import (IndexService, TieredBlockCache,
                                       demo_serving_design)

from conftest import make_keys

# step <- band <- step root: exercises the disk path AND the band
# inter-key window-miss galloping
_band_stack = demo_serving_design


@pytest.fixture(scope="module")
def served(tmp_path_factory):
    keys = make_keys("books", 120_000, seed=3)
    D = KeyPositions.fixed_record(keys, 16)
    design = _band_stack(D)
    path = str(tmp_path_factory.mktemp("svc") / "index.air")
    write_index(path, design, page_bytes=1024)
    rng = np.random.default_rng(0)
    qs = rng.choice(D.keys, 600)
    return D, design, path, qs


# ---------------------------------------------------------------------------
# parity: engine == file walk == in-memory batch, and all are valid
# ---------------------------------------------------------------------------
def test_engine_matches_file_and_memory(served):
    D, design, path, qs = served
    want_file = lookup_serialized(path, None, qs)
    mem = lookup_batch(design, qs)
    with IndexService(path, profile="azure_ssd",
                      spec=ServeSpec(cache_bytes=(64 << 10,
                                                  512 << 10))) as svc:
        got = svc.lookup(qs)
        assert np.array_equal(got, want_file)
        assert np.array_equal(got[:, 0], mem.lo)
        assert np.array_equal(got[:, 1], mem.hi)
        idx = np.searchsorted(D.keys, qs)
        assert np.all((got[:, 0] <= D.lo[idx]) & (got[:, 1] >= D.hi[idx])), \
            "engine violates Eq. (1)"
        # the band stack forces inter-key window misses; galloping must
        # have kicked in (and still produced exact parity above)
        assert svc.stats.retries > 0


def test_engine_matches_on_airtuned_design(tmp_path):
    keys = make_keys("gmm", 30_000, seed=11)
    D = KeyPositions.fixed_record(keys, 16)
    res = airtune(D, PROFILES["azure_ssd"],
                  make_builders(lam_low=2**8, lam_high=2**16, base=4.0), k=3)
    path = str(tmp_path / "index.air")
    write_index(path, res.design, page_bytes=1024)
    qs = np.random.default_rng(1).choice(D.keys, 400)
    with IndexService(path, profile="azure_ssd") as svc:
        got = svc.lookup(qs)
    assert np.array_equal(got, lookup_serialized(path, None, qs))
    mem = lookup_batch(res.design, qs)
    assert np.array_equal(got[:, 0], mem.lo)
    assert np.array_equal(got[:, 1], mem.hi)


def test_engine_serves_unpaged_legacy_files(served):
    D, design, path_unused, qs = served
    import tempfile, os
    path = os.path.join(tempfile.mkdtemp(), "legacy.air")
    write_index(path, design)                      # page_bytes=0 layout
    with IndexService(path, profile="azure_ssd") as svc:
        assert svc.meta.page_bytes == 0 and svc.page_bytes > 0
        got = svc.lookup(qs)
    assert np.array_equal(got, lookup_serialized(path, None, qs))


# ---------------------------------------------------------------------------
# cache: a repeated batch reads strictly fewer bytes than the cold batch
# ---------------------------------------------------------------------------
def test_warm_batch_reads_strictly_fewer_bytes(served):
    D, design, path, qs = served
    with IndexService(path, profile="azure_nfs",
                      spec=ServeSpec(cache_bytes=(64 << 10,
                                                  512 << 10))) as svc:
        svc.lookup(qs)
        cold = svc.stats.snapshot()
        assert cold["bytes_fetched"] > 0 and cold["preads"] > 0
        got2 = svc.lookup(qs)
        warm_bytes = svc.stats.bytes_fetched - cold["bytes_fetched"]
        warm_modeled = svc.stats.modeled_seconds - cold["modeled_seconds"]
        assert warm_bytes < cold["bytes_fetched"]
        assert warm_modeled < cold["modeled_seconds"]
        assert svc.stats.hit_rate > 0
        assert np.array_equal(got2, lookup_serialized(path, None, qs))


def test_tiny_cache_still_correct(served):
    D, design, path, qs = served
    with IndexService(path, profile=None,
                      spec=ServeSpec(cache_bytes=(0,))) as svc:
        got = svc.lookup(qs)
    assert np.array_equal(got, lookup_serialized(path, None, qs))


# ---------------------------------------------------------------------------
# read coalescing
# ---------------------------------------------------------------------------
def test_coalesce_ranges_merges_overlaps():
    s, e = coalesce_ranges([0, 8, 30], [10, 20, 40])
    assert s.tolist() == [0, 30] and e.tolist() == [20, 40]


def test_coalesce_ranges_gap_and_order():
    s, e = coalesce_ranges([30, 0, 12], [40, 10, 20], gap=2)
    assert s.tolist() == [0, 30] and e.tolist() == [20, 40]
    s, e = coalesce_ranges([30, 0, 12], [40, 10, 20], gap=0)
    assert s.tolist() == [0, 12, 30] and e.tolist() == [10, 20, 40]


def test_coalesce_ranges_contained_and_empty():
    s, e = coalesce_ranges([0, 2], [100, 4])
    assert s.tolist() == [0] and e.tolist() == [100]
    s, e = coalesce_ranges([], [])
    assert len(s) == 0 and len(e) == 0


def test_batch_coalesces_to_few_preads(served):
    D, design, path, qs = served
    with IndexService(path, profile=None,
                      spec=ServeSpec(cache_bytes=(4 << 20,))) as svc:
        svc.lookup(qs)
        # 600 queries x 2 disk layers, but contiguous pages merge into runs
        assert svc.stats.preads < svc.stats.ranges_requested / 10


# ---------------------------------------------------------------------------
# tiered LRU block cache mechanics
# ---------------------------------------------------------------------------
def test_tiered_cache_promote_demote_evict():
    c = TieredBlockCache((2 * 64, 2 * 64), page_bytes=64)   # 2 pages per tier
    for pid in (1, 2, 3, 4):
        c.put(pid, bytes(64))
    # tier0 holds {3,4}; {1,2} demoted to tier1
    assert 3 in c.tiers[0] and 4 in c.tiers[0]
    assert 1 in c.tiers[1] and 2 in c.tiers[1]
    assert c.get(1) is not None           # tier-1 hit promotes to tier 0...
    assert 1 in c.tiers[0]
    assert c.hits == [0, 1]
    c.put(5, bytes(64))                   # ...and 5 displaces the tier-0 LRU
    assert len(c.tiers[0]) == 2 and len(c.tiers[1]) == 2
    assert c.get(2) is None               # 2 fell off the last tier
    assert c.misses == 1


def test_tiered_cache_zero_capacity_tier():
    c = TieredBlockCache((0,), page_bytes=64)
    c.put(1, bytes(64))
    assert c.get(1) is None               # nothing sticks, nothing crashes


def test_tiered_cache_subpage_tier_demotes_through():
    # middle tier smaller than one page (cap_pages == 0): the demotion
    # cascade must pass straight through it and terminate
    c = TieredBlockCache((2 * 64, 32, 2 * 64), page_bytes=64)
    assert c.cap_pages == [2, 0, 2]
    for pid in range(5):
        c.put(pid, bytes(64))
    assert len(c.tiers[1]) == 0           # nothing sticks in the 0-cap tier
    # exclusive cascade == one global LRU: {4,3} hot, {2,1} demoted, 0 gone
    assert sorted(c.tiers[0]) == [3, 4] and sorted(c.tiers[2]) == [1, 2]
    assert c.get(0) is None and c.misses == 1
    assert c.get(1) is not None           # promoted through the 0-cap tier
    assert 1 in c.tiers[0] and c.hits == [0, 0, 1]


def _reference_segments(order: list, caps: list) -> list:
    """Global-LRU reference: the exclusive cascade is a segmented LRU, so
    tier i must hold slice [Σcaps[:i], Σcaps[:i+1]) of the recency order."""
    segs, at = [], 0
    for cap in caps:
        segs.append(order[at:at + cap])
        at += cap
    return segs


@pytest.mark.parametrize("caps_bytes", [(256, 512), (256, 32, 512),
                                        (64, 0, 64, 128), (0, 256)])
def test_tiered_cache_matches_global_lru_model(caps_bytes):
    """Property test: after any op sequence, tier contents equal the
    recency segments of one global LRU of capacity Σ cap_pages, pages
    live in at most one tier, and every get is consistently a hit/miss."""
    P = 64
    c = TieredBlockCache(caps_bytes, page_bytes=P)
    total = sum(c.cap_pages)
    rng = np.random.default_rng(hash(caps_bytes) & 0xFFFF)
    order: list = []            # reference recency order, hottest first
    gets = hits = 0
    for _ in range(2000):
        pid = int(rng.integers(0, 24))    # small id space: force collisions
        if rng.random() < 0.5:
            c.put(pid, bytes(P))
            if pid in order:
                order.remove(pid)
            order.insert(0, pid)
            del order[total:]
        else:
            gets += 1
            got = c.get(pid)
            assert (got is not None) == (pid in order)
            if got is not None:
                hits += 1
                order.remove(pid)
                order.insert(0, pid)
                del order[total:]
        # invariants: segment equality, exclusivity, capacity, accounting
        segs = _reference_segments(order, c.cap_pages)
        for tier, seg, cap in zip(c.tiers, segs, c.cap_pages):
            assert len(tier) <= cap
            # OrderedDict order: oldest first; segment is hottest-first
            assert list(tier) == seg[::-1]
        resident = [pid for t in c.tiers for pid in t]
        assert len(resident) == len(set(resident)), "page in two tiers"
        assert sum(c.hits) == hits and c.misses == gets - hits


# ---------------------------------------------------------------------------
# engine construction / lifecycle bugfixes
# ---------------------------------------------------------------------------
def test_explicit_page_bytes_overrides_paged_meta(served):
    """An explicit ``page_bytes=`` kwarg must win over the file's recorded
    paged layout (it used to be silently ignored whenever the meta
    recorded one)."""
    D, design, path, qs = served
    with IndexService(path, profile=None,
                      spec=ServeSpec(page_bytes=512,
                                     cache_bytes=(1 << 20,))) as svc:
        assert svc.meta.page_bytes == 1024          # file IS paged...
        assert svc.page_bytes == 512                # ...but the caller wins
        assert svc.cache.page_bytes == 512          # cache pages accordingly
        got = svc.lookup(qs)
        # every cached page is a 512-byte unit (the file tail may be short)
        sizes = {len(v) for t in svc.cache.tiers for v in t.values()}
        assert sizes and all(s <= 512 for s in sizes) and 512 in sizes
    assert np.array_equal(got, lookup_serialized(path, None, qs))
    # meta fallback unchanged: no kwarg → the file's layout
    with IndexService(path, profile=None) as svc:
        assert svc.page_bytes == 1024


def test_close_is_idempotent_and_del_closes(served):
    import os
    D, design, path, qs = served
    svc = IndexService(path, profile=None)
    svc.lookup(qs[:16])
    svc.close()
    svc.close()                                     # double close: no error
    assert svc.fd is None
    svc = IndexService(path, profile=None)
    fd = svc.fd
    os.fstat(fd)                                    # open while referenced
    del svc                                         # caller forgot close():
    import gc
    gc.collect()
    with pytest.raises(OSError):                    # ...the finalizer closed
        os.fstat(fd)


def test_gallop_step_never_zero():
    from repro.core.serialize import RECORD_BYTES, gallop_step
    # zero-width window (degenerate clamp) still extends by ≥ one record
    assert gallop_step("step", 100, 100) == RECORD_BYTES["step"]
    assert gallop_step("band", 0, 0) == RECORD_BYTES["band"]
    # sub-record windows round up to one record as well
    assert gallop_step("band", 0, 8) == RECORD_BYTES["band"]
    # normal windows keep the doubling rule
    assert gallop_step("step", 0, 64) == 64
    assert gallop_step("band", 40, 200) == 160


# ---------------------------------------------------------------------------
# CachedProfile
# ---------------------------------------------------------------------------
def test_cached_profile_between_tiers_and_monotone():
    backing = PROFILES["azure_nfs"]
    cache = PROFILES["host_dram"]
    deltas = np.array([64.0, 4096.0, 1 << 20])
    for h in (0.0, 0.5, 0.95, 1.0):
        p = CachedProfile(backing=backing, cache=cache, hit_rate=h)
        t = p(deltas)
        assert np.all(np.diff(t) >= 0), "T(Δ) must stay monotone"
        assert np.all(t <= backing(deltas) + 1e-15)
        assert np.all(t >= cache(deltas) - 1e-15)
    hot = CachedProfile(backing=backing, cache=cache, hit_rate=0.99)
    cold = CachedProfile(backing=backing, cache=cache, hit_rate=0.01)
    assert float(hot(4096)) < float(cold(4096))


def test_observed_cached_profile_retunes(served):
    D, design, path, qs = served
    with IndexService(path, profile="azure_nfs",
                      spec=ServeSpec(cache_bytes=(1 << 20,))) as svc:
        svc.lookup(qs)
        svc.lookup(qs)
        eff = svc.cached_profile()
    assert 0.0 < eff.hit_rate <= 1.0
    assert float(eff(4096)) < float(PROFILES["azure_nfs"](4096))


# ---------------------------------------------------------------------------
# paged serialization
# ---------------------------------------------------------------------------
def test_paged_layout_aligns_layers(served):
    D, design, path, qs = served
    import os
    from repro.core.serialize import read_meta
    fd = os.open(path, os.O_RDONLY)
    try:
        meta = read_meta(fd)
    finally:
        os.close(fd)
    assert meta.page_bytes == 1024
    for lm in meta.layers:
        assert lm.offset % meta.page_bytes == 0
        p0, p1 = page_span(lm.offset, lm.size, meta.page_bytes)
        assert p0 * meta.page_bytes == lm.offset
        assert (p1 - p0) == -(-lm.size // meta.page_bytes)


# ---------------------------------------------------------------------------
# device (Pallas kernel) routing for resident layers
# ---------------------------------------------------------------------------
def test_device_resident_descend_matches_numpy(tmp_path):
    rng = np.random.default_rng(0)
    keys = np.unique(rng.integers(1, 2**30, 40_000).astype(np.uint64))
    D = KeyPositions.fixed_record(keys, 16)
    l1 = build_gstep(D, 8, 2**9)
    l2 = build_gstep(outline(l1, D), 8, 2**6)
    design = IndexDesign(layers=(l1, l2), data=D)
    path = str(tmp_path / "dev.air")
    write_index(path, design, page_bytes=1024)
    qs = rng.choice(D.keys, 256)
    want = lookup_serialized(path, None, qs)
    with IndexService(path, spec=ServeSpec(backend="pallas",
                                           resident_layers=2)) as svc:
        assert svc.device_active
        got = svc.lookup(qs)
        assert svc.stats.device_batches > 0
    assert np.array_equal(got, want)


# ---------------------------------------------------------------------------
# two-stage pipeline: prefetch must never change windows, only timing
# ---------------------------------------------------------------------------
def test_pipelined_batches_identical_to_sequential(served):
    D, design, path, qs = served
    rng = np.random.default_rng(7)
    batches = [rng.choice(D.keys, n) for n in (300, 1, 257, 64, 300, 128)]
    # small tiers force evictions between batches — the prefetch stage's
    # peek/drop-out paths actually execute under this pressure
    base = ServeSpec(cache_bytes=(16 << 10, 64 << 10))
    with IndexService(path, profile="azure_ssd", spec=base) as svc:
        want = [svc.lookup(b) for b in batches]
    with IndexService(path, profile="azure_ssd",
                      spec=base.replace(pipeline_depth=2,
                                        prefetch_layers=2)) as svc:
        got = svc.lookup_batches(batches)
        assert svc.stats.pipelined_batches == len(batches)
        roof = svc.stats.roofline()
        assert roof["io_seconds"] > 0 and roof["io_fraction"] is not None
    assert len(got) == len(want)
    for w, g in zip(want, got):
        assert np.array_equal(w, g)


def test_prefetch_stage_warms_cache_and_tags_overlapped(served):
    D, design, path, qs = served
    with IndexService(path, profile="azure_ssd",
                      spec=ServeSpec(cache_bytes=(256 << 10,),
                                     pipeline_depth=1,
                                     prefetch_layers=2)) as svc:
        staged = svc._prefetch_task(qs[:200])     # cold cache: must pread
        assert staged > 0
        assert svc.stats.overlapped_preads > 0
        assert svc.stats.overlapped_pread_seconds > 0
        assert svc.stats.prefetch_seconds > 0
        assert any(len(r) > 2 and r[2] for r in svc.stats.read_samples)
        # the prefetch probe must not have skewed hit/miss accounting
        assert svc.stats.pages_hit == 0 and svc.cache.misses == 0
        before = svc.stats.preads
        got = svc.lookup(qs[:200])                # serves mostly from cache
        assert svc.stats.pages_hit > 0
        # first-window pages were staged; only gallop extensions may read
        assert svc.stats.preads - before <= before
    assert np.array_equal(got, lookup_serialized(path, None, qs[:200]))


def test_lookup_batches_depth_zero_is_plain_sequential(served):
    D, design, path, qs = served
    batches = [qs[:100], qs[100:350], qs[350:]]
    spec = ServeSpec(cache_bytes=(64 << 10,))
    with IndexService(path, profile=None, spec=spec) as svc:
        want = [svc.lookup(b) for b in batches]
    with IndexService(path, profile=None, spec=spec) as svc:
        got = svc.lookup_batches(batches)
        assert svc.stats.pipelined_batches == 0
        assert svc._executor is None          # stage 1 never spun up
    for w, g in zip(want, got):
        assert np.array_equal(w, g)


def test_measured_profile_excludes_overlapped_samples():
    from repro.serve.index_service import ServeStats, measured_backing_profile
    s = ServeStats()
    for i in range(12):     # blocking samples: a plausible ~1ms/4KiB tier
        s.record_read(4096 * (1 + i % 3), 1e-3 * (1 + i % 3))
    for _ in range(30):     # overlapped: latency hidden by the pipeline
        s.record_read(4096, 1e-6, overlapped=True)
    prof = measured_backing_profile(s)
    assert prof is not None
    # the queue-hidden samples must not drag the fitted tier toward zero
    assert float(prof(4096)) >= 0.5e-3
    # but when ONLY overlapped samples exist, fall back rather than refuse
    s2 = ServeStats()
    for i in range(12):
        s2.record_read(4096 * (1 + i % 3), 1e-3, overlapped=True)
    assert measured_backing_profile(s2) is not None
