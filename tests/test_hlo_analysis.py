"""Trip-count-aware HLO analysis: validated against known-FLOPs fixtures."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analysis import analyze, top_dots


def _scan_matmul(n, size=128, nested=0):
    def f(x, w):
        def body(c, _):
            if nested:
                def inner(ci, __):
                    return jnp.tanh(ci @ w), None
                c, _ = jax.lax.scan(inner, c, None, length=nested)
                return c, None
            return jnp.tanh(c @ w), None
        out, _ = jax.lax.scan(body, x, None, length=n)
        return out
    x = jax.ShapeDtypeStruct((size, size), jnp.float32)
    w = jax.ShapeDtypeStruct((size, size), jnp.float32)
    return jax.jit(f).lower(x, w).compile().as_text()


@pytest.mark.parametrize("n", [1, 4, 16])
def test_scan_flops_exact(n):
    a = analyze(_scan_matmul(n))
    assert a["dot_flops"] == 2 * 128**3 * n


def test_nested_scan_flops_exact():
    a = analyze(_scan_matmul(4, nested=3))
    assert a["dot_flops"] == 2 * 128**3 * 12


def test_xla_cost_analysis_undercounts_loops():
    """The reason this module exists: XLA counts while bodies once."""
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        return jax.lax.scan(body, x, None, length=16)[0]
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    c = jax.jit(f).lower(x, w).compile()
    ca = c.cost_analysis()
    xla_flops = ca.get("flops") if isinstance(ca, dict) else ca[0]["flops"]
    assert xla_flops < 2 * 128**3 * 2          # ≈ single iteration
    assert analyze(c.as_text())["dot_flops"] == 2 * 128**3 * 16


def test_collective_bytes_counted():
    from jax.sharding import Mesh, PartitionSpec as P, NamedSharding
    devs = np.array(jax.devices()[:1]).reshape(1)
    mesh = Mesh(devs, ("x",))

    def f(a):
        return jax.lax.with_sharding_constraint(
            a.sum(0, keepdims=True), NamedSharding(mesh, P()))

    # single-device: no collectives expected — the counter must return 0,
    # not crash (the multi-device path is exercised by the dry-run sweep)
    with mesh:
        c = jax.jit(f).lower(jax.ShapeDtypeStruct((8, 8), jnp.float32)).compile()
    a = analyze(c.as_text())
    assert a["collective_bytes"]["total"] >= 0


def test_top_dots_ordering():
    dots = top_dots(_scan_matmul(8), 5)
    assert dots and dots[0]["flops"] == 2 * 128**3 * 8
    assert all(a["flops"] >= b["flops"] for a, b in zip(dots, dots[1:]))


def test_dus_traffic_counts_update_slice_only():
    def f(cache, upd):
        return jax.lax.dynamic_update_slice(cache, upd, (0, 0))
    cache = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
    upd = jax.ShapeDtypeStruct((1, 1024), jnp.float32)
    c = jax.jit(f, donate_argnums=(0,)).lower(cache, upd).compile()
    a = analyze(c.as_text())
    # 2× update bytes (read + write), NOT the 4 MB target buffer
    assert a["dus_traffic_bytes"] <= 4 * 2 * 1024 * 4
