"""ServeSpec: JSON round-trip, validation, on-disk meta recording, the
legacy-kwarg deprecation shims, and the Index.observe wrappers."""
import json
import warnings

import numpy as np
import pytest

from repro.api import Index, ServeSpec, TuneSpec
from repro.api.drift import DriftReport
from repro.core import KeyPositions
from repro.serve.index_service import IndexService, demo_serving_design

from conftest import make_keys


@pytest.fixture(scope="module")
def saved(tmp_path_factory):
    keys = make_keys("gmm", 40_000, seed=9)
    D = KeyPositions.fixed_record(keys, 16)
    idx = Index.from_design(demo_serving_design(D),
                            spec=TuneSpec(page_bytes=1024,
                                          cache_bytes=(128 << 10,)),
                            profile="azure_ssd")
    path = str(tmp_path_factory.mktemp("sspec") / "index.air")
    idx.save(path)
    return D, idx, path


# ---------------------------------------------------------------------------
# value-object mechanics (symmetric with TuneSpec)
# ---------------------------------------------------------------------------
def test_serve_spec_json_roundtrip():
    spec = ServeSpec(cache_bytes=(64 << 10, 1 << 20), cache_profile=None,
                     page_bytes=512, resident_layers=2, backend="pallas",
                     interpret=True, coalesce_gap=64, persist_stats=True,
                     pipeline_depth=3, prefetch_layers=2)
    assert ServeSpec.from_json(spec.to_json()) == spec
    assert json.loads(spec.to_json())["cache_bytes"] == [64 << 10, 1 << 20]
    assert spec.replace(backend="jnp").backend == "jnp"
    assert spec.backend == "pallas"               # frozen: replace copies


def test_serve_spec_validate_rejects_bad_knobs():
    with pytest.raises(ValueError, match="unknown backend"):
        ServeSpec(backend="cuda").validate()
    with pytest.raises(ValueError, match="unknown cache_profile"):
        ServeSpec(cache_profile="l5_cache").validate()
    with pytest.raises(ValueError, match="negative sizes"):
        ServeSpec(page_bytes=-1).validate()
    with pytest.raises(ValueError, match="bad knobs"):
        ServeSpec(prefetch_layers=0).validate()
    with pytest.raises(ValueError, match="bad knobs"):
        ServeSpec(pipeline_depth=-1).validate()
    with pytest.raises(ValueError, match="unknown ServeSpec fields"):
        ServeSpec.from_dict({"use_device": True})
    ServeSpec().validate()                        # defaults are valid


# ---------------------------------------------------------------------------
# recorded into the meta, restored on open, honored by serve()
# ---------------------------------------------------------------------------
def test_serve_spec_recorded_and_restored(saved, tmp_path):
    D, idx, _ = saved
    want = ServeSpec(cache_bytes=(32 << 10,), resident_layers=2,
                     coalesce_gap=128, pipeline_depth=2)
    path = str(tmp_path / "withserve.air")
    idx.save(path, serve_spec=want)
    re = Index.open(path)
    assert re.serve_spec == want
    assert (re.file_meta.tune or {}).get("serve") == want.to_dict()
    with re.serve(profile=None) as svc:           # recorded spec drives it
        assert svc.spec == want
        assert svc.cache.cap_pages[0] == (32 << 10) // svc.page_bytes
        assert len(svc._prefix) == 2
    # field overrides replace on top of the recorded spec
    with re.serve(profile=None, resident_layers=1) as svc:
        assert svc.spec.resident_layers == 1
        assert svc.spec.coalesce_gap == 128       # rest kept
    # engine alone also restores it from the meta
    with IndexService(path, profile=None) as svc:
        assert svc.spec == want


def test_serve_rejects_unknown_override(saved):
    D, idx, path = saved
    with pytest.raises(TypeError, match="unexpected keyword"):
        Index.open(path).serve(cache_mb=64)


def test_serve_spec_property_none_without_recording(saved):
    D, idx, path = saved
    assert Index.open(path).serve_spec is None


# ---------------------------------------------------------------------------
# legacy kwargs: warn-once shims outside, hard error inside repro
# ---------------------------------------------------------------------------
def test_legacy_kwargs_fold_into_spec_and_warn_once(saved):
    D, idx, path = saved
    from repro.core.deprecation import _WARNED
    for msg in [m for m in _WARNED
                if m.startswith("repro.serve.IndexService")]:
        _WARNED.discard(msg)
    with pytest.warns(DeprecationWarning,
                      match=r"repro\.serve\.IndexService\(use_device="):
        with IndexService(path, profile=None, use_device=True,
                          resident_layers=2) as svc:
            assert svc.spec.backend == "pallas"
            assert svc.spec.resident_layers == 2
    with warnings.catch_warnings():
        warnings.simplefilter("error")            # second use: deduplicated
        with IndexService(path, profile=None, use_device=False) as svc:
            assert svc.spec.backend == "numpy"


def test_legacy_kwargs_hard_error_inside_repro(saved):
    D, idx, path = saved
    src = ("from repro.serve.index_service import IndexService\n"
           "IndexService(path, profile=None, cache_bytes=(1024,))\n")
    with pytest.raises(AssertionError,
                       match="deprecated API used from within repro"):
        exec(src, {"__name__": "repro._testshim", "path": path})


def test_legacy_unknown_kwarg_is_type_error(saved):
    D, idx, path = saved
    with pytest.raises(TypeError, match="unexpected keyword"):
        IndexService(path, profile=None, cache_mb=64)


# ---------------------------------------------------------------------------
# Index.observe / observe_offline (the facade's drift entry points)
# ---------------------------------------------------------------------------
def test_observe_wrappers(saved, tmp_path):
    D, idx, _ = saved
    path = str(tmp_path / "obs.air")
    idx.save(path, serve_spec=ServeSpec(persist_stats=True))
    re = Index.open(path)
    assert re.observe_offline() is None           # nothing persisted yet
    rng = np.random.default_rng(4)
    with re.serve() as svc:
        for _ in range(4):
            svc.lookup(rng.choice(D.keys, 200))
        rep = re.observe(svc, min_queries=256)
        assert isinstance(rep, DriftReport)
    # close() persisted the snapshot (persist_stats spec field)
    rep2 = re.observe_offline(min_queries=256)
    assert isinstance(rep2, DriftReport)
    assert rep2.observed_seconds == pytest.approx(rep.observed_seconds)
    # observe() with no service falls back to the offline snapshot
    rep3 = re.observe(min_queries=256)
    assert isinstance(rep3, DriftReport)
