"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests and benches must
see the real single-device CPU; only launch/dryrun.py forces 512 devices."""
import numpy as np
import pytest

from repro.core import KeyPositions


def make_keys(kind: str, n: int, seed: int = 0) -> np.ndarray:
    """Synthetic key distributions mirroring the paper's datasets."""
    rng = np.random.default_rng(seed)
    if kind == "uniform":            # uden64-like
        keys = rng.integers(1, 2**50, n, dtype=np.uint64)
    elif kind == "gmm":              # paper's gmm
        c = rng.uniform(2**30, 2**44, 64)
        keys = np.abs(np.concatenate(
            [rng.normal(ci, 2**26, n // 64 + 1) for ci in c]))[:n]
        keys = keys.astype(np.uint64) + 1
    elif kind == "books":            # heavy-tailed cumulative counts
        gaps = rng.zipf(1.3, n).astype(np.uint64)
        keys = np.cumsum(gaps)
    elif kind == "fb":               # piecewise near-linear with jumps
        base = np.sort(rng.integers(1, 2**34, n).astype(np.uint64))
        jumps = (rng.random(n) < 1e-4) * rng.integers(2**38, 2**40, n)
        keys = base + np.cumsum(jumps.astype(np.uint64))
    else:
        raise ValueError(kind)
    return np.unique(np.sort(keys))


@pytest.fixture(scope="session")
def gmm_small():
    keys = make_keys("gmm", 50_000)
    return KeyPositions.fixed_record(keys, 16)


@pytest.fixture(scope="session")
def uniform_small():
    keys = make_keys("uniform", 50_000)
    return KeyPositions.fixed_record(keys, 16)
