"""Pallas kernel sweeps vs pure-jnp oracles (interpret mode on CPU)."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels.index_lookup import ops as ilk_ops
from repro.kernels.index_lookup import ref as ilk_ref
from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.flash_attention import ref as fa_ref
from repro.kernels.decode_attention import ops as da_ops
from repro.kernels.decode_attention import ref as da_ref

RNG = np.random.default_rng(42)


# ---------------------------------------------------------------------------
# index lookup
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("P,Q", [(64, 32), (1000, 777), (4096, 1024),
                                 (20_000, 513)])  # last: two-level path
def test_step_lookup_matches_ref(P, Q):
    keys = np.sort(RNG.choice(2**26, P, replace=False)).astype(np.int32)
    pos = np.sort(RNG.choice(2**28, P + 1, replace=False)).astype(np.int32)
    q = RNG.integers(0, 2**26, Q).astype(np.int32)
    lo1, hi1 = ilk_ops.lookup_step_layer(jnp.asarray(q), jnp.asarray(keys),
                                         jnp.asarray(pos))
    lo2, hi2 = ilk_ref.step_lookup_ref(jnp.asarray(q), jnp.asarray(keys),
                                       jnp.asarray(pos[:-1]),
                                       jnp.asarray(pos[1:]))
    np.testing.assert_array_equal(np.asarray(lo1), np.asarray(lo2))
    np.testing.assert_array_equal(np.asarray(hi1), np.asarray(hi2))


@pytest.mark.parametrize("N,Q", [(10, 64), (300, 300), (4096, 512)])
def test_band_lookup_matches_ref(N, Q):
    nk = np.sort(RNG.choice(2**24, N, replace=False)).astype(np.int32)
    x1 = nk.astype(np.float32)
    y1 = np.sort(RNG.uniform(0, 2**22, N)).astype(np.float32)
    m = RNG.uniform(0, 10, N).astype(np.float32)
    d = RNG.uniform(1, 100, N).astype(np.float32)
    q = RNG.integers(0, 2**24, Q).astype(np.int32)
    args = [jnp.asarray(a) for a in (q, nk, x1, y1, m, d)]
    lo1, hi1 = ilk_ops.lookup_band_layer(*args)
    lo2, hi2 = ilk_ref.band_lookup_ref(*args)
    # kernel and oracle may differ by a few ULP of the f32 mid (XLA FMA
    # contraction differs between the fused kernel and the reference);
    # real indexes absorb this in the δ slack (device_arrays_from_design)
    assert np.max(np.abs(np.asarray(lo1) - np.asarray(lo2))) <= 4
    assert np.max(np.abs(np.asarray(hi1) - np.asarray(hi2))) <= 4


def test_traverse_matches_design():
    """Kernel traversal of a real tuned index covers the true ranges.

    Uses int32-range keys — the kernel's regime (serving-scale page tables
    and sample indexes); SOSD-scale uint64 keys take the numpy path.
    """
    from repro.core import KeyPositions, PROFILES, airtune, make_builders
    rng = np.random.default_rng(5)
    c = rng.uniform(2**20, 2**30, 32)
    keys = np.unique(np.abs(np.concatenate(
        [rng.normal(ci, 2**16, 2000) for ci in c])).astype(np.uint64) + 1)
    assert keys.max() < 2**31
    D = KeyPositions.fixed_record(keys, 16)
    res = airtune(D, PROFILES["azure_ssd"],
                  make_builders(lam_low=2**10, lam_high=2**16, base=4.0), k=3)
    layers = ilk_ops.device_arrays_from_design(res.design)
    qs = RNG.choice(keys, 512).astype(np.int64)
    lo, hi = ilk_ops.traverse_index(layers, jnp.asarray(qs, jnp.int32))
    i = np.searchsorted(D.keys, qs.astype(np.uint64))
    assert np.all(np.asarray(lo) <= D.lo[i])
    assert np.all(np.asarray(hi) >= D.hi[i])


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
ATTN_CASES = [
    dict(B=2, Hq=4, Hkv=4, Sq=128, Skv=128, D=64),
    dict(B=1, Hq=8, Hkv=2, Sq=128, Skv=128, D=64),
    dict(B=2, Hq=4, Hkv=2, Sq=96, Skv=96, D=64),
    dict(B=1, Hq=4, Hkv=4, Sq=128, Skv=128, D=64, window=32),
    dict(B=1, Hq=4, Hkv=4, Sq=128, Skv=128, D=64, softcap=30.0),
    dict(B=1, Hq=4, Hkv=2, Sq=64, Skv=192, D=64),
    dict(B=1, Hq=4, Hkv=4, Sq=100, Skv=228, D=32, window=50),
    dict(B=1, Hq=2, Hkv=1, Sq=128, Skv=128, D=128, window=64, softcap=50.0),
]


@pytest.mark.parametrize("case", ATTN_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(case, dtype):
    c = dict(case)
    B, Hq, Hkv, Sq, Skv, D = (c.pop(k) for k in ("B", "Hq", "Hkv", "Sq",
                                                 "Skv", "D"))
    q = jnp.asarray(RNG.normal(size=(B, Hq, Sq, D)), dtype)
    k = jnp.asarray(RNG.normal(size=(B, Hkv, Skv, D)), dtype)
    v = jnp.asarray(RNG.normal(size=(B, Hkv, Skv, D)), dtype)
    o1 = fa_ops.flash_attention(q, k, v, block_q=64, block_k=64, **c)
    o2 = fa_ref.attention_ref(q, k, v, **c)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    assert float(jnp.max(jnp.abs(o1.astype(jnp.float32) - o2))) < tol


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("B,Hq,Hkv,S,D,partial", [
    (2, 4, 4, 256, 64, False), (2, 8, 2, 256, 64, False),
    (3, 8, 4, 192, 32, True), (1, 16, 8, 128, 128, True),
])
def test_decode_attention_matches_ref(B, Hq, Hkv, S, D, partial):
    q = jnp.asarray(RNG.normal(size=(B, Hq, D)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(B, Hkv, S, D)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(B, Hkv, S, D)), jnp.float32)
    L = jnp.asarray(RNG.integers(1, S + 1, B), jnp.int32) if partial else None
    o1, m1, l1 = da_ops.decode_attention(q, k, v, L, block_k=64)
    o2, m2, l2 = da_ref.decode_attention_ref(q, k, v, L)
    assert float(jnp.max(jnp.abs(o1 - o2))) < 3e-5
    assert float(jnp.max(jnp.abs(m1 - m2))) < 1e-5


def test_decode_shard_combination_equals_full():
    B, Hq, Hkv, S, D = 2, 8, 2, 256, 64
    q = jnp.asarray(RNG.normal(size=(B, Hq, D)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(B, Hkv, S, D)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(B, Hkv, S, D)), jnp.float32)
    full, _, _ = da_ref.decode_attention_ref(q, k, v)
    parts = [da_ops.decode_attention(q, k[:, :, i * 64:(i + 1) * 64],
                                     v[:, :, i * 64:(i + 1) * 64], block_k=64)
             for i in range(4)]
    O, _, _ = da_ops.combine_partials(
        jnp.stack([p[0] for p in parts]), jnp.stack([p[1] for p in parts]),
        jnp.stack([p[2] for p in parts]))
    assert float(jnp.max(jnp.abs(O - full))) < 3e-5
