"""Property test: the two-stage pipeline is invisible in results — for any
batch schedule, pipeline depth, prefetch depth, and cache size, pipelined
``lookup_batches`` returns windows identical to unpipelined serving."""
import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="optional test dep (pip install -e .[test])")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.api import ServeSpec                           # noqa: E402
from repro.core import KeyPositions, write_index          # noqa: E402
from repro.serve.index_service import (IndexService,      # noqa: E402
                                       demo_serving_design)

from conftest import make_keys                            # noqa: E402

_KEYS = make_keys("books", 80_000, seed=21)
_D = KeyPositions.fixed_record(_KEYS, 16)


@pytest.fixture(scope="module")
def served_path(tmp_path_factory):
    path = str(tmp_path_factory.mktemp("pipe") / "index.air")
    write_index(path, demo_serving_design(_D), page_bytes=1024)
    return path


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**16),
       n_batches=st.integers(2, 6),
       depth=st.integers(1, 3),
       prefetch=st.integers(1, 2),
       cache_kib=st.sampled_from([2, 8, 32, 128]))
def test_pipelined_identical_under_cache_pressure(served_path, seed,
                                                  n_batches, depth,
                                                  prefetch, cache_kib):
    rng = np.random.default_rng(seed)
    batches = [rng.choice(_KEYS, int(rng.integers(1, 400)))
               for _ in range(n_batches)]
    base = ServeSpec(cache_bytes=(cache_kib << 10,))
    with IndexService(served_path, profile=None, spec=base) as svc:
        want = [svc.lookup(b) for b in batches]
    with IndexService(served_path, profile=None,
                      spec=base.replace(pipeline_depth=depth,
                                        prefetch_layers=prefetch)) as svc:
        got = svc.lookup_batches(batches)
        assert svc.stats.pipelined_batches == n_batches
    for w, g in zip(want, got):
        assert np.array_equal(w, g)
