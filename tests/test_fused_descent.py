"""Fused multi-layer descent: bit-identity of the numpy backend with the
per-layer walk, device-backend step-exactness / band containment, ragged
batches, the Pallas → jnp → numpy fallback chain, and packing guards —
across layer-family mixes (gstep/gband/eband/rmi_leaf) and prefix depths."""
import numpy as np
import pytest

from repro.api import ServeSpec
from repro.core import IndexDesign, KeyPositions, write_index
from repro.core.baselines import build_rmi_leaf
from repro.core.builders import build_eband, build_gband, build_gstep
from repro.core.descent import descend_band_layer, descend_step_layer
from repro.kernels import fused_descent as fd
from repro.core.nodes import outline
from repro.serve.index_service import IndexService

from conftest import make_keys

# bottom-up family stacks, λ shrinking upward (demo_serving_design's
# shape); every registered serving family appears in some prefix
MIXES = {
    "gstep3": ("gstep", "gstep", "gstep"),
    "step-band-step": ("gstep", "gband", "gstep"),
    "band-eband-step": ("gband", "eband", "gstep"),
    "rmi-step-step": ("rmi_leaf", "gstep", "gstep"),
}

_BUILD = {
    "gstep": lambda D, lam: build_gstep(D, 8, lam),
    "gband": build_gband,
    "eband": build_eband,
    "rmi_leaf": lambda D, lam: build_rmi_leaf(
        D, max(int(len(D.keys) // lam), 1)),
}


def _design(D, kinds):
    layers, cur = [], D
    for kind, lam in zip(kinds, (2**10, 2**9, 2**7)):
        lay = _BUILD[kind](cur, lam)
        layers.append(lay)
        cur = outline(lay, cur)
    return IndexDesign(layers=tuple(layers), data=D)


@pytest.fixture(scope="module")
def stacks(tmp_path_factory):
    """{mix name: top-down parsed resident prefix (all 3 layers)} plus
    in-domain queries — parsed through the real IndexService path.
    Keys stay below 2**30 so the device backends are eligible (int32
    packing guard, same bound as the previous use_device gating)."""
    rng0 = np.random.default_rng(11)
    keys = np.unique(rng0.integers(1, 2**30, 60_000).astype(np.uint64))
    D = KeyPositions.fixed_record(keys, 16)
    rng = np.random.default_rng(5)
    qs = rng.choice(D.keys, 600)
    root = tmp_path_factory.mktemp("fused")
    out = {}
    for name, kinds in MIXES.items():
        path = str(root / f"{name}.air")
        write_index(path, _design(D, kinds), page_bytes=1024)
        with IndexService(path, profile=None,
                          spec=ServeSpec(resident_layers=3)) as svc:
            out[name] = svc._prefix
    return out, qs


def _per_layer_walk(prefix, q):
    """The pre-fusion reference: one descend_* call per layer."""
    lo = np.empty((len(prefix), len(q)), dtype=np.float64)
    hi = np.empty_like(lo)
    for r, lay in enumerate(prefix):
        if lay["kind"] == "step":
            l_, h_ = descend_step_layer(lay["keys"], lay["pos_lo"],
                                        lay["pos_hi"], q)
        else:
            l_, h_ = descend_band_layer(lay["x1"], lay["x1"], lay["y1"],
                                        lay["m"], lay["delta"], q)
        lo[r], hi[r] = l_, h_
    return lo, hi


# ---------------------------------------------------------------------------
# numpy backend == per-layer walk, bit for bit
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", sorted(MIXES))
@pytest.mark.parametrize("depth", [0, 1, 2, 3])
def test_numpy_backend_bit_identical_to_per_layer(stacks, name, depth):
    prefixes, qs = stacks
    layers = prefixes[name][:depth]
    for n in (1, 7, 256, 600):
        q = qs[:n]
        want_lo, want_hi = _per_layer_walk(layers, q)
        lo, hi, used = fd.fused_descent_with_backend(layers, q,
                                                     backend="numpy")
        assert used == "numpy"
        assert lo.shape == (depth, n) and hi.shape == (depth, n)
        np.testing.assert_array_equal(lo, want_lo)
        np.testing.assert_array_equal(hi, want_hi)


def test_empty_prefix_all_backends(stacks):
    _, qs = stacks
    for backend in ("numpy", "jnp", "pallas"):
        lo, hi, used = fd.fused_descent_with_backend([], qs, backend=backend)
        assert used == "numpy"          # nothing to pack → numpy serves
        assert lo.shape == (0, len(qs))


# ---------------------------------------------------------------------------
# device backends: step rows exact, band rows valid-but-wider, pallas≈jnp
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", sorted(MIXES))
def test_device_backends_step_exact_band_contained(stacks, name):
    prefixes, qs = stacks
    for depth in (1, 2, 3):
        layers = prefixes[name][:depth]
        rlo, rhi = fd.fused_descent(layers, qs, backend="numpy")
        plo, phi, pu = fd.fused_descent_with_backend(layers, qs,
                                                     backend="pallas")
        jlo, jhi, ju = fd.fused_descent_with_backend(layers, qs,
                                                     backend="jnp")
        assert pu == "pallas" and ju == "jnp"
        packed = fd.pack_prefix(layers)
        for r, lay in enumerate(layers):
            if packed["kinds"][r] == 0:          # step: exact on both
                np.testing.assert_array_equal(plo[r], rlo[r])
                np.testing.assert_array_equal(phi[r], rhi[r])
                np.testing.assert_array_equal(jlo[r], rlo[r])
                np.testing.assert_array_equal(jhi[r], rhi[r])
            else:                                # band: contained + bounded
                assert np.all(plo[r] <= rlo[r]) and np.all(phi[r] >= rhi[r])
                assert np.all(jlo[r] <= rlo[r]) and np.all(jhi[r] >= rhi[r])
                bound = 2.0 * float(np.max(fd.band_f32_slack(
                    lay["y1"], lay["m"], lay["x1"]))) + 4.0
                assert np.max((phi[r] - plo[r]) - (rhi[r] - rlo[r])) <= bound
        # pallas vs jnp differ only by f32 FMA contraction on band mids
        assert np.max(np.abs(plo - jlo)) <= 4
        assert np.max(np.abs(phi - jhi)) <= 4


def test_ragged_batches_match_full_batch(stacks):
    prefixes, qs = stacks
    layers = prefixes["step-band-step"]
    flo, fhi = fd.fused_descent(layers, qs, backend="pallas")
    off = 0
    for n in (1, 7, 255, 256, 81):
        blo, bhi = fd.fused_descent(layers, qs[off:off + n],
                                    backend="pallas")
        np.testing.assert_array_equal(blo, flo[:, off:off + n])
        np.testing.assert_array_equal(bhi, fhi[:, off:off + n])
        off += n


# ---------------------------------------------------------------------------
# fallback chain (candidate_score idiom): pallas → jnp → numpy
# ---------------------------------------------------------------------------
def test_fallback_chain_degrades_to_jnp_then_numpy(stacks, monkeypatch):
    prefixes, qs = stacks
    layers = prefixes["gstep3"]
    want_lo, want_hi = fd.fused_descent(layers, qs, backend="numpy")

    import repro.kernels.fused_descent.kernel as kernel
    import repro.kernels.fused_descent.ref as ref

    def boom(*a, **k):
        raise RuntimeError("backend down")

    monkeypatch.setattr(kernel, "fused_descent_pallas", boom)
    lo, hi, used = fd.fused_descent_with_backend(layers, qs,
                                                 backend="pallas")
    assert used == "jnp"
    np.testing.assert_array_equal(lo, want_lo)   # all-step: jnp is exact

    monkeypatch.setattr(ref, "fused_descent_jnp", boom)
    lo, hi, used = fd.fused_descent_with_backend(layers, qs,
                                                 backend="pallas")
    assert used == "numpy"
    np.testing.assert_array_equal(lo, want_lo)
    np.testing.assert_array_equal(hi, want_hi)


# ---------------------------------------------------------------------------
# packing guards: ineligible prefixes must decline, not break
# ---------------------------------------------------------------------------
def test_pack_prefix_guards():
    assert fd.pack_prefix([]) is None
    over = {"kind": "step",
            "keys": np.array([0, 2**31 - 1], dtype=np.uint64),
            "pos_lo": np.array([0, 8], dtype=np.int64),
            "pos_hi": np.array([8, 16], dtype=np.int64)}
    assert fd.pack_prefix([over]) is None
    n = fd.MAX_VMEM_ENTRIES + 1
    wide = {"kind": "step", "keys": np.arange(n, dtype=np.uint64),
            "pos_lo": np.arange(n, dtype=np.int64),
            "pos_hi": np.arange(1, n + 1, dtype=np.int64)}
    assert fd.pack_prefix([wide]) is None


# ---------------------------------------------------------------------------
# engine integration: fused windows feed the disk walk correctly
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("depth", [1, 2, 3])
def test_engine_numpy_backend_parity_across_depths(stacks, tmp_path, depth):
    from repro.core.serialize import lookup_serialized
    keys = make_keys("fb", 50_000, seed=13)
    D = KeyPositions.fixed_record(keys, 16)
    path = str(tmp_path / "mix.air")
    write_index(path, _design(D, MIXES["step-band-step"]), page_bytes=1024)
    rng = np.random.default_rng(2)
    qs = rng.choice(D.keys, 400)
    want = lookup_serialized(path, None, qs)
    with IndexService(path, profile=None,
                      spec=ServeSpec(resident_layers=depth)) as svc:
        got = svc.lookup(qs)
    assert np.array_equal(got, want)


def test_engine_device_backend_valid_and_attributed(stacks, tmp_path):
    rng0 = np.random.default_rng(13)
    keys = np.unique(rng0.integers(1, 2**30, 50_000).astype(np.uint64))
    D = KeyPositions.fixed_record(keys, 16)
    path = str(tmp_path / "dev.air")
    write_index(path, _design(D, MIXES["band-eband-step"]), page_bytes=1024)
    rng = np.random.default_rng(3)
    qs = rng.choice(D.keys, 300)
    with IndexService(path, profile=None,
                      spec=ServeSpec(resident_layers=3)) as ref_svc:
        want = ref_svc.lookup(qs)
    with IndexService(path, profile=None,
                      spec=ServeSpec(resident_layers=3,
                                     backend="pallas")) as svc:
        assert svc.device_active
        got = svc.lookup(qs)
        assert svc.stats.device_batches == 1
        assert svc.stats.descent_seconds > 0
    # device band widening may only widen the final data window
    assert np.all(got[:, 0] <= want[:, 0]) and np.all(got[:, 1] >= want[:, 1])
    idx = np.searchsorted(D.keys, qs)
    assert np.all((got[:, 0] <= D.lo[idx]) & (got[:, 1] >= D.hi[idx]))
