"""Substrate tests: data store, checkpoint manifest, fault tolerance,
gradient compression, KV-cache page tables, sharding rules."""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.data.store import ShardedTokenStore, write_token_store
from repro.serve.kvcache import PagedKVCache
from repro.train.checkpoint import restore_checkpoint, save_checkpoint
from repro.train.compression import compress_decompress, compressed_psum
from repro.train.fault_tolerance import (FTConfig, TrainingSupervisor,
                                         elastic_mesh_shape, rescale_batch)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def token_store(tmp_path_factory):
    rng = np.random.default_rng(0)
    samples = [rng.integers(0, 1000, rng.integers(20, 300)).astype(np.int32)
               for _ in range(500)]
    path = str(tmp_path_factory.mktemp("store"))
    write_token_store(path, samples)
    store = ShardedTokenStore(path, profile="azure_ssd")
    yield store, samples
    store.close()


def test_store_random_access_exact(token_store):
    store, samples = token_store
    rng = np.random.default_rng(1)
    for i in rng.integers(0, len(samples), 50):
        got = store.get(int(i))
        np.testing.assert_array_equal(got, samples[int(i)])


def test_store_partial_reads(token_store):
    store, samples = token_store
    before = store.index.bytes_read
    for i in range(30):
        store.get(i)
    # reads should be range-sized, not whole-file-sized
    total = sum(len(s) * 4 for s in samples)
    assert store.index.bytes_read - before < total


def test_store_batch_iterator_replayable(token_store):
    store, _ = token_store
    a = [next(store.batch_iterator(4, 64, seed=7, start_step=i))
         for i in range(3)]
    b = list(__import__("itertools").islice(
        store.batch_iterator(4, 64, seed=7), 3))
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x["tokens"], y["tokens"])


# ---------------------------------------------------------------------------
# checkpoint with AirIndex manifest
# ---------------------------------------------------------------------------
def test_checkpoint_roundtrip(tmp_path):
    rng = np.random.default_rng(0)
    tree = {"a": rng.normal(size=(100, 64)).astype(np.float32),
            "b": {"w": rng.normal(size=(257,)).astype(np.float32),
                  "s": np.int32(7)}}
    save_checkpoint(str(tmp_path), tree, profile="azure_ssd", step=3)
    like = jax.tree.map(lambda x: np.zeros_like(x), tree)
    out, stats = restore_checkpoint(str(tmp_path), like, step=3)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a).reshape(-1),
                                      np.asarray(b).reshape(-1))
    assert stats["slices_read"] >= 3


def test_checkpoint_partial_restore(tmp_path):
    rng = np.random.default_rng(0)
    tree = {"big": rng.normal(size=(3 << 20,)).astype(np.float32),  # 12 MB
            "small": rng.normal(size=(64,)).astype(np.float32)}
    save_checkpoint(str(tmp_path), tree, profile="azure_ssd", step=0)
    like = jax.tree.map(np.zeros_like, tree)
    out, stats = restore_checkpoint(str(tmp_path), like, step=0,
                                    leaf_filter=lambda n: n == "small")
    assert out["big"] is None
    np.testing.assert_array_equal(out["small"], tree["small"])
    # partial restore reads ≪ blob size
    assert stats["bytes_read"] < 2 << 20


def test_checkpoint_detects_corruption(tmp_path):
    tree = {"w": np.arange(4096, dtype=np.float32)}
    save_checkpoint(str(tmp_path), tree, profile="azure_ssd", step=0)
    blob = os.path.join(str(tmp_path), "ckpt-0.blob")
    with open(blob, "r+b") as f:
        f.seek(100)
        f.write(b"\xff\xff")
    with pytest.raises(AssertionError, match="corrupt"):
        restore_checkpoint(str(tmp_path), jax.tree.map(np.zeros_like, tree),
                           step=0)


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------
def test_supervisor_restarts_from_checkpoint(tmp_path):
    saved = {}

    def save_fn(state, step):
        saved[step] = dict(state)
        open(os.path.join(str(tmp_path), f"ckpt-{step}.json"), "w").write("{}")

    def restore_fn(step):
        return dict(saved[step])

    sup = TrainingSupervisor(str(tmp_path), ["h0", "h1", "h2", "h3"],
                             FTConfig(checkpoint_every=5), save_fn, restore_fn)
    state = {"x": 0}
    killed = {"done": False}

    def step_fn(st, step):
        if step == 12 and not killed["done"]:
            sup.monitor.kill("h2")       # inject a failure mid-run
            killed["done"] = True
        return {"x": st["x"] + 1}

    state, steps, log = sup.run(state, step_fn, n_steps=20)
    events = [e["event"] for e in log]
    assert "failure" in events and "restart" in events
    assert steps == 20
    assert len(sup.monitor.hosts) == 3         # h2 removed
    # the run replayed steps 10–12 after restoring from the step-10 ckpt
    assert state["x"] >= 20 - 10


def test_elastic_mesh_and_batch_rescale():
    assert elastic_mesh_shape(16, 16, 16) == (16, 16)
    assert elastic_mesh_shape(15, 16, 16) == (8, 16)   # power-of-two shrink
    assert rescale_batch(256, 16, 8) == 32
    with pytest.raises(AssertionError):
        rescale_batch(250, 16, 16)


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------
def test_int8_quantization_error_bounded():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(1000,)), jnp.float32)
    y = compress_decompress(x)
    scale = float(jnp.max(jnp.abs(x)))
    assert float(jnp.max(jnp.abs(x - y))) <= scale / 127.0 + 1e-6


def test_compressed_psum_error_feedback():
    """Error feedback: mean of compressed reductions over repeated steps
    converges to the true mean (the residual is carried, not lost)."""
    from jax.sharding import Mesh, PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    devs = np.array(jax.devices()[:1]).reshape(1)
    mesh = Mesh(devs, ("pod",))
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.normal(size=(64,)), jnp.float32)

    @jax.jit
    def step(err):
        f = shard_map(lambda e: compressed_psum({"g": g_true}, {"g": e},
                                                "pod"),
                      mesh=mesh, in_specs=P(), out_specs=P(),
                      check_rep=False)
        return f(err)

    err = jnp.zeros((64,), jnp.float32)
    acc = jnp.zeros_like(g_true)
    n = 30
    for _ in range(n):
        mean, errs = step(err)
        err = errs["g"]
        acc = acc + mean["g"]
    # accumulated compressed means ≈ n · true grad (error feedback works)
    rel = float(jnp.linalg.norm(acc / n - g_true) / jnp.linalg.norm(g_true))
    assert rel < 0.02, rel


# ---------------------------------------------------------------------------
# paged KV cache + tuned page table
# ---------------------------------------------------------------------------
def test_paged_kvcache_pool():
    pool = PagedKVCache(n_pages=8, page_tokens=16)
    pool.add_sequence(0)
    pool.append_tokens(0, 40)         # 3 pages
    assert len(pool.tables[0]) == 3
    pool.add_sequence(1)
    pool.append_tokens(1, 80)         # 5 pages
    with pytest.raises(MemoryError):
        pool.append_tokens(1, 16)     # pool exhausted
    pool.release(0)
    pool.append_tokens(1, 16)         # freed pages reused
    assert len(pool.free) == 2


def test_page_table_tuning_beats_flat():
    rng = np.random.default_rng(0)
    pool = PagedKVCache(n_pages=65536)
    for s in range(128):
        pool.add_sequence(s)
        pool.append_tokens(s, int(rng.integers(256, 4096)))
    stats = pool.modeled_lookup_cost("host_dram")
    assert stats["tuned_us"] <= stats["flat_us"] * 1.0001


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------
def test_param_shardings_cover_all_archs():
    from jax.sharding import Mesh
    from repro.configs import ARCHS, get_config
    from repro.dist.sharding import param_shardings
    from repro.models import api
    devs = np.array(jax.devices() * 1)[:1].reshape(1, 1)
    mesh = Mesh(devs, ("data", "model"))
    for arch in ARCHS:
        cfg = get_config(arch)
        specs = api.param_specs(cfg)
        sh = param_shardings(cfg, specs, mesh)
        # every leaf got a sharding and every spec is valid for its shape
        for s, spec in zip(jax.tree.leaves(specs), jax.tree.leaves(sh)):
            assert spec is not None
