"""Sweep-engine certification: bit-identity with the legacy per-builder
loop, multi-λ build equality, batched-scoring exactness, cache reuse, and
the device scoring backends."""
import numpy as np
import pytest

from repro.core import (AffineUniformProfile, CachedProfile,
                        KeyPositions, MeasuredProfile, PROFILES, airtune,
                        batched_mean_read_costs, beam_search, brute_force,
                        expected_latency, make_builders)
from repro.core.builders import (build_eband, build_eband_multi,
                                 build_gband, build_gband_multi, build_gstep,
                                 build_gstep_multi)
from repro.core.registry import BUILDER_FAMILIES, register_builder
from repro.core.sweep import LayerCache
from repro.core.storage import affine_coefficients

from conftest import make_keys

BUILDERS = make_builders(lam_low=2**10, lam_high=2**16, base=4.0)
STRATEGIES = {
    "airtune": (airtune, dict(k=3, max_layers=4)),
    "beam": (beam_search, dict(k=3, max_layers=4)),
    "brute_force": (brute_force, dict(max_layers=3)),
}


def _data(kind="gmm", n=5_000, seed=3):
    return KeyPositions.fixed_record(make_keys(kind, n, seed), 16)


def _layers_equal(a, b) -> bool:
    if len(a) != len(b):
        return False
    for la, lb in zip(a, b):
        if la.kind != lb.kind:
            return False
        if la.kind == "step":
            fields = ("piece_keys", "piece_pos", "node_piece_off")
        else:
            fields = ("node_keys", "x1", "y1", "m", "delta")
            if la.clamp_lo != lb.clamp_lo or la.clamp_hi != lb.clamp_hi:
                return False
        if not all(np.array_equal(getattr(la, f), getattr(lb, f))
                   for f in fields):
            return False
    return True


# ---------------------------------------------------------------------------
# acceptance: sweep ≡ legacy loop, bit for bit, on every strategy
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("kind", ["gmm", "books"])
@pytest.mark.parametrize("pname", ["azure_ssd", "azure_nfs"])
@pytest.mark.parametrize("sname", list(STRATEGIES))
def test_sweep_bit_identical_to_legacy_loop(kind, pname, sname):
    D = _data(kind)
    strat, kw = STRATEGIES[sname]
    a = strat(D, PROFILES[pname], BUILDERS, sweep=True, **kw)
    b = strat(D, PROFILES[pname], BUILDERS, sweep=False, **kw)
    assert a.cost == b.cost                       # bitwise, not approx
    assert a.builder_names == b.builder_names
    assert _layers_equal(a.design.layers, b.design.layers)


def test_sweep_stats_counters():
    D = _data("gmm", n=20_000)
    res = brute_force(D, PROFILES["azure_ssd"], BUILDERS, max_layers=4)
    s = res.stats
    assert s.sweeps > 0 and s.sweep_seconds > 0.0
    assert s.layers_reused > 0                      # λ-dedup + vertex memo
    leg = brute_force(D, PROFILES["azure_ssd"], BUILDERS, max_layers=4,
                      sweep=False)
    # the sweep never does MORE work than the loop it replaces
    assert s.layers_built <= leg.stats.layers_built
    assert s.candidates_scored <= leg.stats.candidates_scored


# ---------------------------------------------------------------------------
# shared LayerCache: cross-tier / cross-strategy reuse, results unchanged
# ---------------------------------------------------------------------------
def test_shared_layer_cache_reuse_is_bit_identical():
    D = _data("gmm", n=10_000)
    cache = LayerCache()
    warm, cold = {}, {}
    # brute force first: its exhaustive expansion warms the cache for the
    # guided strategies (the tune-bench certification runs this order)
    for pname in ("azure_ssd", "azure_nfs"):
        for sname in ("brute_force", "airtune", "beam"):
            strat, kw = STRATEGIES[sname]
            warm[pname, sname] = strat(D, PROFILES[pname], BUILDERS,
                                       layer_cache=cache, **kw)
            cold[pname, sname] = strat(D, PROFILES[pname], BUILDERS, **kw)
    assert len(cache) > 0
    total_reused = 0
    for key, w in warm.items():
        c = cold[key]
        assert w.cost == c.cost and w.builder_names == c.builder_names
        assert _layers_equal(w.design.layers, c.design.layers)
        total_reused += w.stats.layers_reused
    # later runs must ride the earlier runs' builds: the guided searches
    # only cold-build vertices deeper than brute force's expansion bound
    later_warm = sum(warm[k].stats.layers_built for k in warm
                     if k[1] != "brute_force")
    later_cold = sum(cold[k].stats.layers_built for k in cold
                     if k[1] != "brute_force")
    assert later_warm < later_cold / 3
    assert total_reused > sum(c.stats.layers_reused for c in cold.values())


# ---------------------------------------------------------------------------
# batched scoring: bit-identity of the numpy evaluator, per profile kind
# ---------------------------------------------------------------------------
PROFILES_UNDER_TEST = [
    PROFILES["azure_ssd"],
    AffineUniformProfile(1e-4, 3e-4, 1e8, 4e8),
    MeasuredProfile(deltas=(256.0, 4096.0, 65536.0, 1 << 20),
                    seconds=(1e-4, 2e-4, 9e-4, 4e-3)),
    CachedProfile(backing=PROFILES["azure_nfs"], hit_rate=0.7),
]


@pytest.mark.parametrize("profile", PROFILES_UNDER_TEST,
                         ids=lambda p: p.name)
def test_batched_mean_read_costs_bit_identical(profile):
    rng = np.random.default_rng(0)
    W = rng.uniform(1.0, 1e6, size=(7, 1023))
    weights = rng.uniform(0.5, 4.0, size=1023)
    got = batched_mean_read_costs(W, weights, profile)
    for c in range(W.shape[0]):
        scalar = float(np.average(profile(W[c]), weights=weights))
        assert got[c] == scalar        # bitwise: same reduction order


# ---------------------------------------------------------------------------
# multi-λ builders: each element ≡ the single-λ build; saturated λ dedup
# ---------------------------------------------------------------------------
LAMS = [2.0**s for s in range(8, 21, 2)]


@pytest.mark.parametrize("kind", ["gmm", "fb"])
def test_multi_lam_builds_match_single(kind):
    D = _data(kind, n=4_000)
    multi = {
        "gstep": (build_gstep_multi(D, LAMS, 16),
                  [build_gstep(D, 16, l) for l in LAMS]),
        "gband": (build_gband_multi(D, LAMS, 16),
                  [build_gband(D, l) for l in LAMS]),
        "eband": (build_eband_multi(D, LAMS, 16),
                  [build_eband(D, l) for l in LAMS]),
    }
    for fam, (got, want) in multi.items():
        assert len(got) == len(LAMS)
        for g, w in zip(got, want):
            assert _layers_equal([g], [w]), fam
    # the grid saturates on this small extent: identical partitions must
    # share one layer object (that sharing is what layers_reused counts)
    gs = multi["gstep"][0]
    assert len({id(x) for x in gs}) < len(gs)


# ---------------------------------------------------------------------------
# baseline families (btree / rmi_leaf / pgm): sweep certification
# ---------------------------------------------------------------------------
BASELINE_BUILDERS = make_builders(lam_low=2**10, lam_high=2**16, base=4.0,
                                  kinds=("gstep", "btree", "rmi_leaf", "pgm"))


@pytest.mark.parametrize("pname", ["azure_ssd", "azure_nfs"])
@pytest.mark.parametrize("sname", list(STRATEGIES))
def test_baseline_families_sweep_bit_identical(pname, sname):
    """The registered baseline families certify sweep=True ≡ sweep=False
    on every strategy × tier (btree/pgm ride multi-λ adapters; rmi_leaf
    rides the per-λ fallback with canonical-λ dedup)."""
    D = _data("gmm")
    strat, kw = STRATEGIES[sname]
    a = strat(D, PROFILES[pname], BASELINE_BUILDERS, sweep=True, **kw)
    b = strat(D, PROFILES[pname], BASELINE_BUILDERS, sweep=False, **kw)
    assert a.cost == b.cost                       # bitwise, not approx
    assert a.builder_names == b.builder_names
    assert _layers_equal(a.design.layers, b.design.layers)


def test_per_lam_fallback_family_hits_layer_cache():
    """rmi_leaf has no multi-λ entry: the per-λ fallback must still dedup
    builds (canonical λ → model count) and ride a shared LayerCache —
    TuneStats.layers_reused counts both effects."""
    D = _data("gmm", n=5_000)
    builders = make_builders(lam_low=2**8, lam_high=2**20, base=2.0,
                             kinds=("rmi_leaf",))
    cache = LayerCache()
    r1 = airtune(D, PROFILES["azure_ssd"], builders, k=3, layer_cache=cache)
    # the grid extends past the collection extent, so several λs clamp to
    # the same model count: canonical-λ dedup shows up as reuse already
    # on the first (cold-cache) run
    assert r1.stats.layers_reused > 0
    assert len(cache) > 0
    r2 = airtune(D, PROFILES["azure_ssd"], builders, k=3, layer_cache=cache)
    # a second identical tune rebuilds nothing: every fallback build is a
    # LayerCache hit, and the shared entries' score memos carry over too
    assert r2.stats.layers_built == 0
    assert r2.stats.layers_reused > 0
    assert r2.stats.candidates_scored == 0
    assert r2.cost == r1.cost
    assert r2.builder_names == r1.builder_names


def test_third_party_single_lam_family_falls_back():
    """A family registered without a multi-λ entry must still sweep —
    per-λ fallback builds, bit-identical to the legacy loop."""
    def build_wide_step(D, lam, p):
        return build_gstep(D, max(int(p) * 2, 1), lam)

    register_builder("widestep2", build_wide_step)
    try:
        D = _data("gmm", n=4_000)
        fams = ("gstep", "widestep2")
        builders = make_builders(lam_low=2**10, lam_high=2**14, base=4.0,
                                 kinds=fams)
        a = airtune(D, PROFILES["azure_ssd"], builders, k=3, sweep=True)
        b = airtune(D, PROFILES["azure_ssd"], builders, k=3, sweep=False)
        assert a.cost == b.cost and a.builder_names == b.builder_names
        assert _layers_equal(a.design.layers, b.design.layers)
    finally:
        BUILDER_FAMILIES.unregister("widestep2")


def test_unhashable_profile_is_pinned_not_id_keyed():
    """Unhashable profiles (e.g. MeasuredProfile built with list fields)
    must be pinned by the shared cache so a garbage-collected profile's
    id() can never alias another profile's memoized costs."""
    cache = LayerCache()
    D = _data("gmm", n=4_000)

    def unhashable_profile(scale):
        # list fields defeat the frozen-dataclass hash → TypeError on hash()
        return MeasuredProfile(deltas=[256.0, 4096.0, 1 << 20],
                               seconds=[scale * 1e-4, scale * 2e-4,
                                        scale * 4e-3])

    p1 = unhashable_profile(1.0)
    with pytest.raises(TypeError):
        hash(p1)
    r1 = airtune(D, p1, BUILDERS, k=3, layer_cache=cache)
    assert p1 in cache._pinned_profiles
    del p1                                   # id() may now be recycled...
    p2 = unhashable_profile(50.0)            # ...by a very different tier
    r2 = airtune(D, p2, BUILDERS, k=3, layer_cache=cache)
    fresh = airtune(D, p2, BUILDERS, k=3)    # no shared cache: ground truth
    assert r2.cost == fresh.cost and r2.builder_names == fresh.builder_names
    assert r1.cost != r2.cost


# ---------------------------------------------------------------------------
# device scoring backends (ranking fast path)
# ---------------------------------------------------------------------------
def test_affine_coefficients():
    ssd = PROFILES["azure_ssd"]
    ell, inv_bw = affine_coefficients(ssd)
    assert ell == ssd.latency and inv_bw == 1.0 / ssd.bandwidth
    cached = CachedProfile(backing=ssd, hit_rate=0.5)
    co = affine_coefficients(cached)
    assert co is not None
    np.testing.assert_allclose(cached(1e6), co[0] + 1e6 * co[1], rtol=1e-12)
    au = AffineUniformProfile(1e-4, 3e-4, 1e8, 4e8)
    ell, inv_bw = affine_coefficients(au)
    np.testing.assert_allclose(au(1e5), ell + 1e5 * inv_bw, rtol=1e-12)
    assert affine_coefficients(MeasuredProfile((1.0, 2.0), (1e-6, 2e-6))) \
        is None


def test_candidate_score_backends_agree():
    jax = pytest.importorskip("jax")     # noqa: F841 — device backends
    from repro.kernels.candidate_score import (affine_candidate_scores,
                                               candidate_scores)
    rng = np.random.default_rng(1)
    W = rng.uniform(16.0, 1e5, size=(5, 700))
    weights = rng.uniform(0.5, 3.0, size=700)
    prof = PROFILES["azure_ssd"]
    ell, inv_bw = affine_coefficients(prof)
    ref = affine_candidate_scores(W, weights, ell, inv_bw, backend="numpy")
    for backend in ("jnp", "pallas"):
        got = affine_candidate_scores(W, weights, ell, inv_bw,
                                      backend=backend)
        np.testing.assert_allclose(got, ref, rtol=3e-5)
    # dispatcher: affine tier takes the device path, measured tier the
    # numpy path; both must agree with the exact evaluator to f32 rank res
    exact = batched_mean_read_costs(W, weights, prof)
    np.testing.assert_allclose(
        candidate_scores(W, weights, prof, backend="pallas"), exact,
        rtol=3e-5)
    measured = PROFILES_UNDER_TEST[2]
    got = candidate_scores(W, weights, measured, backend="pallas")
    assert np.array_equal(got, batched_mean_read_costs(W, weights, measured))


def test_device_backend_tune_matches_numpy_cost():
    """jnp ranking may reorder float ties, but returned costs are always
    exact Eq. (6) values and should match the numpy-path optimum here."""
    pytest.importorskip("jax")
    D = _data("gmm", n=5_000)
    prof = PROFILES["azure_ssd"]
    a = airtune(D, prof, BUILDERS, k=3, score_backend="jnp")
    b = airtune(D, prof, BUILDERS, k=3)
    assert a.cost == pytest.approx(expected_latency(a.design, prof), rel=1e-9)
    assert a.cost == pytest.approx(b.cost, rel=1e-6)
