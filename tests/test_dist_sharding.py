"""Direct unit tests for ``repro.dist.sharding``: param / batch /
decode-state sharding rules and the residual-stream constraint.

Runs in a subprocess with 8 forced host devices so the main test session
keeps its single-device view (conftest contract).
"""
import json
import os
import subprocess
import sys

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from repro.dist.sharding import (batch_sharding, constrain_residual,
                                 decode_state_shardings, param_shardings,
                                 replicated, set_activation_mesh)


class Spec:
    def __init__(self, shape):
        self.shape = tuple(shape)


mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
out = {}

# -- param_shardings: tensor-parallel on the largest trailing divisible dim
params = {
    "emb": Spec((128, 64)),          # trailing dim 64 % 4 == 0 -> model
    "blocks": Spec((6, 128, 64)),    # leading dim 6 is the layer stack
    "scalar": Spec(()),              # nothing shardable
    "odd": Spec((7, 9)),             # nothing divides 4 -> replicated
}
ps = param_shardings(None, params, mesh)
out["param_specs"] = {k: str(s.spec) for k, s in ps.items()}

# ZeRO-1: moments additionally sharded over the data axis
zs = param_shardings(None, params, mesh, zero=True)
out["zero_specs"] = {k: str(s.spec) for k, s in zs.items()}

# -- batch_sharding: leading dim over data axes, indivisible -> replicated
bs = batch_sharding(mesh, {"x": Spec((4, 16)), "odd": Spec((3, 16)),
                           "empty": Spec(())})
out["batch_specs"] = {k: str(s.spec) for k, s in bs.items()}

# -- decode_state_shardings: (L, B, H, ...) -> batch axis 1, heads axis 2
ds = decode_state_shardings(None, {"kv": Spec((6, 4, 8, 64)),
                                   "odd_b": Spec((6, 3, 8, 64)),
                                   "vec": Spec((6,))}, mesh)
out["decode_specs"] = {k: str(s.spec) for k, s in ds.items()}

out["replicated"] = str(replicated(mesh).spec)

# -- constrain_residual: no-op without a mesh, sharded with one
x = jnp.zeros((4, 16))
y = constrain_residual(x)
out["residual_no_mesh_identity"] = bool(y is x)
set_activation_mesh(mesh)
with mesh:
    z = jax.jit(constrain_residual)(x)
    out["residual_sharded"] = str(z.sharding.spec)
    odd = jnp.zeros((3, 16))
    out["residual_odd_identity"] = bool(constrain_residual(odd) is odd)
set_activation_mesh(None)
out["residual_cleared_identity"] = bool(constrain_residual(x) is x)

print("RESULT " + json.dumps(out))
"""


def _run():
    out = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                         text=True, env={"PYTHONPATH": "src",
                                         "PATH": "/usr/bin:/bin",
                                         "JAX_PLATFORMS": "cpu"},
                         cwd=REPO_ROOT, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    line = [ln for ln in out.stdout.splitlines()
            if ln.startswith("RESULT")][0]
    return json.loads(line.split(" ", 1)[1])


def test_sharding_rules_on_a_2x4_mesh():
    got = _run()
    # params: model axis on the largest trailing divisible dim; the layer
    # stack dim of scanned block params is never sharded
    assert got["param_specs"] == {
        "emb": "PartitionSpec(None, 'model')",
        "blocks": "PartitionSpec(None, None, 'model')",
        "scalar": "PartitionSpec()",
        "odd": "PartitionSpec(None, None)",
    }
    # ZeRO-1 adds a data-axis dim where one divides (emb: 128 % 2 == 0)
    assert got["zero_specs"]["emb"] == "PartitionSpec('data', 'model')"
    assert got["zero_specs"]["blocks"] \
        == "PartitionSpec('data', None, 'model')"
    # batches: leading dim over data, indivisible leaves replicated
    # (specs are padded to full rank, so trailing dims show as None)
    assert got["batch_specs"] == {
        "x": "PartitionSpec('data', None)",
        "odd": "PartitionSpec(None, None)",
        "empty": "PartitionSpec()",
    }
    # decode state: (L, B, H, hd) -> batch on 'data', heads on 'model'
    assert got["decode_specs"]["kv"] \
        == "PartitionSpec(None, 'data', 'model', None)"
    assert got["decode_specs"]["odd_b"] \
        == "PartitionSpec(None, None, 'model', None)"
    assert got["decode_specs"]["vec"] == "PartitionSpec(None,)"
    assert got["replicated"] == "PartitionSpec()"


def test_constrain_residual_mesh_lifecycle():
    got = _run()
    assert got["residual_no_mesh_identity"] is True
    assert got["residual_sharded"] == "PartitionSpec('data',)"
    assert got["residual_odd_identity"] is True
    assert got["residual_cleared_identity"] is True
