"""shard_map flash-decode over a sequence-sharded cache == full attention.

Runs in a subprocess with 8 forced host devices so the main test session
keeps its single-device view (conftest contract).
"""
import json
import os
import subprocess
import sys

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.serve.attention import flash_decode_sharded
from repro.kernels.decode_attention import ref

mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
rng = np.random.default_rng(0)
B, Hq, Hkv, S, hd = 2, 8, 2, 256, 64
q = jnp.asarray(rng.normal(size=(B, Hq, hd)), jnp.float32)
k = jnp.asarray(rng.normal(size=(B, Hkv, S, hd)), jnp.float32)
v = jnp.asarray(rng.normal(size=(B, Hkv, S, hd)), jnp.float32)
errs = []
with mesh:
    fd = jax.jit(flash_decode_sharded(mesh, "model"))
    for L in (S, S - 17, 64, 1):
        lengths = jnp.full((B,), L, jnp.int32)
        got = fd(q, k, v, lengths)
        want, _, _ = ref.decode_attention_ref(q, k, v, lengths)
        errs.append(float(jnp.max(jnp.abs(got - want))))
print("ERRS", json.dumps(errs)) if False else None
import json as j
print("RESULT " + j.dumps(errs))
"""


def test_flash_decode_sharded_matches_ref():
    out = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                         text=True, env={"PYTHONPATH": "src",
                                          "PATH": "/usr/bin:/bin",
                                          "JAX_PLATFORMS": "cpu"},
                         cwd=REPO_ROOT, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT")][0]
    errs = json.loads(line.split(" ", 1)[1])
    assert max(errs) < 3e-5, errs
