"""Tail-latency objective certification: mean-objective bit-identity on
every strategy × tier, zero-variance reduction to the mean search,
fit_affine degenerate-input hardening, the tainted-reservoir regression
(faulty preads can never reach any fitted profile), DistributionalProfile
fit/JSON round-trips, and the TuneSpec.objective facade plumbing."""
import json

import numpy as np
import pytest

from repro.api import Index, TuneSpec, register_strategy
from repro.api.drift import drift_from_stats
from repro.core import (DistributionalProfile, KeyPositions,
                        MeasuredProfile, ObjectiveProfile, PROFILES, airtune,
                        beam_search, brute_force, expected_latency,
                        make_builders, mean_excess_per_lookup,
                        normalize_objective, objective_latency,
                        objective_profile, profile_from_dict, profile_to_dict,
                        quantile_latency)
from repro.core.registry import SEARCH_STRATEGIES
from repro.serve.index_service import (MIN_FIT_SAMPLES, ServeStats,
                                       distributional_backing_profile,
                                       measured_backing_profile,
                                       observed_profile_from_stats,
                                       untainted_read_samples)

from conftest import make_keys

BUILDERS = make_builders(lam_low=2**10, lam_high=2**16, base=4.0)
STRATEGIES = {
    "airtune": (airtune, dict(k=3, max_layers=4)),
    "beam": (beam_search, dict(k=3, max_layers=4)),
    "brute_force": (brute_force, dict(max_layers=3)),
}
P99 = {"p": 0.99, "weight": 0.5}


def _data(kind="gmm", n=5_000, seed=3):
    return KeyPositions.fixed_record(make_keys(kind, n, seed), 16)


def _layers_equal(a, b) -> bool:
    if len(a) != len(b):
        return False
    for la, lb in zip(a, b):
        if la.kind != lb.kind:
            return False
        if la.kind == "step":
            fields = ("piece_keys", "piece_pos", "node_piece_off")
        else:
            fields = ("node_keys", "x1", "y1", "m", "delta")
            if la.clamp_lo != lb.clamp_lo or la.clamp_hi != lb.clamp_hi:
                return False
        if not all(np.array_equal(getattr(la, f), getattr(lb, f))
                   for f in fields):
            return False
    return True


def _stall_profile():
    """A distributional tier where wide reads carry a heavy stall tail."""
    return DistributionalProfile(
        deltas=(4096.0, 65536.0, 1 << 20),
        means=(1e-4, 3e-4, 2e-3),
        excess=(5e-5, 1e-4, 4e-3),
        qs=(0.5, 0.99), qvalues=((9e-5, 1.2e-4), (2e-4, 2e-3), (1e-3, 3e-2)),
        name="stall-tier")


# ---------------------------------------------------------------------------
# satellite 4a: objective="mean" is bit-identical to the pre-objective
# search, on every strategy × tier
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("pname", ["azure_ssd", "azure_nfs"])
@pytest.mark.parametrize("sname", list(STRATEGIES))
def test_mean_objective_bit_identical(pname, sname):
    D = _data()
    strat, kw = STRATEGIES[sname]
    a = strat(D, PROFILES[pname], BUILDERS, objective="mean", **kw)
    b = strat(D, PROFILES[pname], BUILDERS, **kw)
    assert a.cost == b.cost                       # bitwise, not approx
    assert a.builder_names == b.builder_names
    assert _layers_equal(a.design.layers, b.design.layers)
    assert a.objective == "mean" and b.objective == "mean"
    # weight == 0 *is* the mean objective — same bitwise guarantee
    c = strat(D, PROFILES[pname], BUILDERS,
              objective={"p": 0.9, "weight": 0.0}, **kw)
    assert c.cost == b.cost and c.objective == "mean"
    assert _layers_equal(c.design.layers, b.design.layers)


# ---------------------------------------------------------------------------
# satellite 4b: a deterministic tier has no tail mass, so the quantile
# objective reduces to the mean search — same argmin, cost ×(1 + w)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("sname", list(STRATEGIES))
def test_quantile_objective_zero_variance_reduces_to_mean(sname):
    D = _data()
    strat, kw = STRATEGIES[sname]
    mean = strat(D, PROFILES["azure_ssd"], BUILDERS, **kw)
    tail = strat(D, PROFILES["azure_ssd"], BUILDERS, objective=P99, **kw)
    assert tail.builder_names == mean.builder_names
    assert _layers_equal(tail.design.layers, mean.design.layers)
    assert tail.cost == pytest.approx(
        (1.0 + P99["weight"]) * mean.cost, rel=1e-12)
    assert tail.objective == {"p": 0.99, "weight": 0.5}


def test_quantile_objective_prefers_tail_safe_design():
    """On a stall-heavy tier the p99 objective must value the tail mass:
    the objective cost strictly exceeds (1+w)·mean cost whenever the
    chosen design still touches stall-prone read sizes."""
    prof = _stall_profile()
    D = _data(n=8_000)
    mean = airtune(D, prof, BUILDERS, k=3, max_layers=4)
    tail = airtune(D, prof, BUILDERS, k=3, max_layers=4, objective=P99)
    w, p = P99["weight"], P99["p"]
    # the tail search minimized the wrapped curve, and its reported cost
    # is exactly that curve's Eq. 6 value on the returned design
    wrapped = objective_profile(prof, P99)
    assert tail.cost == pytest.approx(
        expected_latency(tail.design, wrapped), rel=1e-9)
    # identity: E[T] + w·(E[T] + me/(1−p)) evaluated via the latency API
    direct = (expected_latency(tail.design, prof)
              + w * quantile_latency(tail.design, prof, p))
    assert tail.cost == pytest.approx(direct, rel=1e-9)
    # and the tail-tuned design is no worse than the mean-tuned one
    # under its own objective (equality allowed: argmins may coincide)
    assert direct <= (expected_latency(mean.design, prof)
                      + w * quantile_latency(mean.design, prof, p)) + 1e-15


# ---------------------------------------------------------------------------
# objective/latency API identities
# ---------------------------------------------------------------------------
def test_latency_api_identities():
    prof = _stall_profile()
    D = _data(n=4_000)
    res = airtune(D, prof, BUILDERS, k=3)
    d = res.design
    me = mean_excess_per_lookup(d, prof)
    assert me > 0.0
    assert quantile_latency(d, prof, 0.99) == pytest.approx(
        expected_latency(d, prof) + me / (1.0 - 0.99), rel=1e-12)
    assert objective_latency(d, prof, "mean") == expected_latency(d, prof)
    # deterministic tier: me ≡ 0 → quantile == mean, objective == (1+w)·mean
    ssd = PROFILES["azure_ssd"]
    assert mean_excess_per_lookup(d, ssd) == 0.0
    assert quantile_latency(d, ssd, 0.99) == expected_latency(d, ssd)
    assert objective_latency(d, ssd, P99) == pytest.approx(
        1.5 * expected_latency(d, ssd), rel=1e-12)
    with pytest.raises(ValueError, match="quantile"):
        quantile_latency(d, prof, 1.0)


def test_normalize_objective_validation():
    assert normalize_objective(None) is None
    assert normalize_objective("mean") is None
    assert normalize_objective({"p": 0.9, "weight": 0.0}) is None
    assert normalize_objective({"p": 0.99}) == (0.99, 1.0)   # weight default
    assert normalize_objective({"p": 0.5, "weight": 2.5}) == (0.5, 2.5)
    for bad in ("p99", {"p": 1.0}, {"p": 0.0}, {"p": 0.9, "weight": -1.0},
                {"p": 0.9, "quantile": 0.5}, {"weight": 1.0}, 0.99,
                {"p": "hot"}):
        with pytest.raises(ValueError):
            normalize_objective(bad)
    # mean objective returns the *same object* — the bit-identity lever
    ssd = PROFILES["azure_ssd"]
    assert objective_profile(ssd, "mean") is ssd
    assert objective_profile(ssd, None) is ssd
    wrapped = objective_profile(ssd, P99)
    assert isinstance(wrapped, ObjectiveProfile)
    np.testing.assert_allclose(wrapped(4096.0), 1.5 * ssd(4096.0), rtol=1e-12)


# ---------------------------------------------------------------------------
# satellite 2: fit_affine degenerate measurements degrade, never poison
# ---------------------------------------------------------------------------
def test_fit_affine_single_size_degrades_to_constant():
    m = MeasuredProfile(deltas=(4096.0, 4096.0, 4096.0),
                        seconds=(1e-4, 3e-4, 2e-4), name="one-size")
    with pytest.warns(RuntimeWarning, match="degenerate"):
        fit = m.fit_affine()
    assert fit.latency == pytest.approx(2e-4)
    assert np.isfinite(fit.bandwidth) and fit.bandwidth > 0
    # the degraded profile predicts positive, finite times everywhere
    t = fit(np.array([1.0, 4096.0, 1e9]))
    assert np.all(np.isfinite(t)) and np.all(t > 0)
    assert t[0] == pytest.approx(t[2])        # constant: no slope leaked


def test_fit_affine_constant_seconds_degrades_to_constant():
    m = MeasuredProfile(deltas=(256.0, 4096.0, 65536.0),
                        seconds=(5e-4, 5e-4, 5e-4), name="flat")
    with pytest.warns(RuntimeWarning, match="degenerate"):
        fit = m.fit_affine()
    assert fit.latency == pytest.approx(5e-4)
    assert np.all(fit(np.array([1.0, 1e8])) > 0)
    # a decreasing (negative-slope) measurement clamps the same way
    dec = MeasuredProfile(deltas=(256.0, 65536.0), seconds=(2e-3, 1e-3))
    with pytest.warns(RuntimeWarning, match="slope"):
        fit = dec.fit_affine()
    assert fit.latency == pytest.approx(1.5e-3)
    # ... while a healthy measurement still fits cleanly, no warning
    import warnings as _w
    with _w.catch_warnings():
        _w.simplefilter("error")
        ok = MeasuredProfile(deltas=(256.0, 65536.0, 1 << 20),
                             seconds=(1e-4, 4e-4, 5e-3)).fit_affine()
    assert ok.latency > 0 and ok.bandwidth > 0
    # constant fallback round-trips through strict JSON (finite bandwidth)
    json.dumps(profile_to_dict(fit))


# ---------------------------------------------------------------------------
# satellite 1: tainted preads can never reach any fitted profile
# ---------------------------------------------------------------------------
def _stats_with(reads, queries=0):
    st = ServeStats(queries=queries, modeled_seconds=1.0,
                    walk_modeled_seconds=1.0)
    for nbytes, secs, overlapped, tainted in reads:
        st.record_read(nbytes, secs, overlapped=overlapped, tainted=tainted)
    return st


def test_mostly_tainted_reservoir_fits_nothing():
    # plenty of samples over 2 sizes, but almost all tainted: both fit
    # paths must refuse (None), never model the faults as the tier
    reads = [(4096, 50.0, False, True) for _ in range(3 * MIN_FIT_SAMPLES)]
    reads += [(65536, 60.0, False, True) for _ in range(3 * MIN_FIT_SAMPLES)]
    reads += [(4096, 1e-4, False, False)] * (MIN_FIT_SAMPLES - 1)
    st = _stats_with(reads)
    assert len(untainted_read_samples(st)) == MIN_FIT_SAMPLES - 1
    assert measured_backing_profile(st) is None
    assert distributional_backing_profile(st) is None
    # observed_profile keeps the modeled backing tier instead
    ssd = PROFILES["azure_ssd"]
    prof = observed_profile_from_stats(st, ssd, distributional=True)
    assert prof.backing is ssd


def test_mostly_tainted_window_drifts_to_zero_confidence_observe():
    reads = [(4096, 50.0, False, True) for _ in range(4 * MIN_FIT_SAMPLES)]
    st = _stats_with(reads, queries=10_000)     # enough queries to be sure
    rep = drift_from_stats(st, 1e-4)
    assert rep.confidence == 0.0
    assert rep.action == "observe"
    # the same window with clean samples is fully confident
    clean = [(4096, 1e-4, False, False) for _ in range(4 * MIN_FIT_SAMPLES)]
    rep2 = drift_from_stats(_stats_with(clean, queries=10_000), 1e-4)
    assert rep2.confidence == 1.0 and rep2.action != "observe"


def test_tainted_samples_never_bias_a_fit():
    # enough clean samples to fit: absurd tainted outliers must leave the
    # fitted values completely untouched, on both fit paths
    clean = ([(4096, 1e-4, False, False)] * (2 * MIN_FIT_SAMPLES)
             + [(65536, 4e-4, False, False)] * (2 * MIN_FIT_SAMPLES))
    tainted = [(4096, 100.0, False, True), (65536, 100.0, False, True)] * 8
    a = measured_backing_profile(_stats_with(clean))
    b = measured_backing_profile(_stats_with(clean + tainted))
    assert a == b
    da = distributional_backing_profile(_stats_with(clean))
    db = distributional_backing_profile(_stats_with(clean + tainted))
    assert da == db
    assert max(db.means) < 1.0          # the 100 s faults left no trace
    assert float(db.quantile_time(65536.0, 0.99)) < 1.0


def test_overlapped_filter_relaxes_but_tainted_never_does():
    # a fully-pipelined window: every clean sample is overlapped.  The
    # fallback must use them — but still never the tainted ones.
    reads = ([(4096, 1e-4, True, False)] * MIN_FIT_SAMPLES
             + [(65536, 4e-4, True, False)] * MIN_FIT_SAMPLES
             + [(4096, 100.0, True, True)] * (4 * MIN_FIT_SAMPLES))
    st = _stats_with(reads)
    m = measured_backing_profile(st)
    assert m is not None
    assert max(m.seconds) < 1.0
    d = distributional_backing_profile(st)
    assert d is not None and max(d.means) < 1.0


# ---------------------------------------------------------------------------
# DistributionalProfile: fit semantics and JSON round-trips
# ---------------------------------------------------------------------------
def test_distributional_fit_mean_excess_and_quantiles():
    samples = []
    for i in range(400):
        samples.append((4096.0, 1e-4))                   # deterministic size
        stall = 5e-3 if i % 10 == 0 else 0.0             # exact 10% stall tail
        samples.append((65536.0, 4e-4 + stall))
    prof = DistributionalProfile.fit(samples, min_samples=32)
    assert prof is not None
    assert float(prof.mean_excess(4096.0)) == 0.0
    mu = 4e-4 + 0.10 * 5e-3
    assert float(prof.read_time(65536.0)) == pytest.approx(mu, rel=1e-9)
    # E[(T−μ)₊] = P(stall)·(stall − E[stall]) for the two-point mixture
    assert float(prof.mean_excess(65536.0)) == pytest.approx(
        0.10 * (5e-3 - 0.10 * 5e-3), rel=1e-9)
    assert float(prof.quantile_time(65536.0, 0.5)) == pytest.approx(4e-4)
    assert float(prof.quantile_time(65536.0, 0.99)) > 4e-3
    # scarcity contracts: too few samples / too few distinct sizes → None
    assert DistributionalProfile.fit(samples[:10], min_samples=32) is None
    assert DistributionalProfile.fit([(4096.0, 1e-4)] * 64,
                                     min_samples=32) is None


def test_distributional_and_objective_profiles_json_roundtrip():
    prof = _stall_profile()
    d = profile_to_dict(prof)
    json.dumps(d)                                  # strict-JSON safe
    assert profile_from_dict(d) == prof
    wrapped = objective_profile(prof, P99)
    d2 = profile_to_dict(wrapped)
    json.dumps(d2)
    back = profile_from_dict(d2)
    assert isinstance(back, ObjectiveProfile)
    assert back.p == wrapped.p and back.weight == wrapped.weight
    assert back.base == prof
    probe = np.array([1024.0, 65536.0, 1 << 22], dtype=np.float64)
    np.testing.assert_array_equal(back(probe), wrapped(probe))
    # the wrapped curve is the documented surrogate, exactly
    np.testing.assert_allclose(
        wrapped(probe),
        1.5 * prof.read_time(probe) + (0.5 / 0.01) * prof.mean_excess(probe),
        rtol=1e-12)


def test_observed_profile_prefers_distributional_fit():
    clean = ([(4096, 1e-4, False, False)] * 32
             + [(65536, 4e-4, False, False)] * 32)
    st = _stats_with(clean)
    prof = observed_profile_from_stats(st, PROFILES["azure_ssd"],
                                       distributional=True)
    assert isinstance(prof.backing, DistributionalProfile)
    # default (mean-only) path is unchanged: measured fit
    prof2 = observed_profile_from_stats(st, PROFILES["azure_ssd"])
    assert isinstance(prof2.backing, MeasuredProfile)


# ---------------------------------------------------------------------------
# facade: TuneSpec.objective validation, meta recording, strategy gating
# ---------------------------------------------------------------------------
def test_tunespec_objective_validate_and_roundtrip():
    spec = TuneSpec(objective=P99)
    spec.validate()
    assert TuneSpec.from_json(spec.to_json()) == spec
    assert TuneSpec().objective == "mean"          # default, old metas too
    with pytest.raises(ValueError, match="objective"):
        TuneSpec(objective="p99").validate()
    with pytest.raises(ValueError, match="objective"):
        TuneSpec(objective={"p": 2.0}).validate()


def test_objective_recorded_in_meta_and_reopened(tmp_path):
    D = _data(n=4_000)
    spec = TuneSpec(lam_high=2.0**14, lam_base=4.0, k=2, max_layers=3,
                    page_bytes=1024, objective=P99)
    path = str(tmp_path / "p99.air")
    idx = Index.tune(D, "azure_ssd", spec).build()
    assert idx.result.objective == {"p": 0.99, "weight": 0.5}
    idx.save(path)
    re = Index.open(path)
    assert re.file_meta.tune["objective"] == {"p": 0.99, "weight": 0.5}
    assert re.spec.objective == {"p": 0.99, "weight": 0.5}
    # mean-objective indexes record "mean" (and old metas omitting the
    # key parse as "mean" via the TuneSpec default)
    path2 = str(tmp_path / "mean.air")
    Index.tune(D, "azure_ssd", spec.replace(objective="mean")).save(path2)
    assert Index.open(path2).file_meta.tune["objective"] == "mean"


def test_objective_unaware_strategy_is_refused_not_silent():
    # no **kwargs and no `objective` parameter: the facade must detect
    # that the strategy cannot honor a quantile objective
    def legacy_strategy(D, profile, builders, *, k=4, max_layers=6):
        return airtune(D, profile, builders, k=k, max_layers=max_layers)

    register_strategy("legacy_noobj")(legacy_strategy)
    try:
        D = _data(n=2_000)
        spec = TuneSpec(strategy="legacy_noobj", k=2, max_layers=3,
                        objective=P99)
        with pytest.raises(ValueError, match="objective-aware"):
            Index.tune(D, "azure_ssd", spec).build()
        # the mean objective still works through it (no gate to trip)
        mean_spec = spec.replace(objective="mean")
        idx = Index.tune(D, "azure_ssd", mean_spec).build()
        assert np.isfinite(idx.result.cost) and idx.result.cost > 0
    finally:
        SEARCH_STRATEGIES.unregister("legacy_noobj")


def test_retune_carries_objective():
    D = _data(n=4_000)
    spec = TuneSpec(lam_high=2.0**14, lam_base=4.0, k=2, max_layers=3,
                    objective=P99)
    idx = Index.tune(D, _stall_profile(), spec).build()
    re = idx.retune(PROFILES["azure_nfs"], warm_start=True)
    assert re.spec.objective == {"p": 0.99, "weight": 0.5}
    assert re.result.objective == {"p": 0.99, "weight": 0.5}
