"""Greedy-partition seam + §5.4 partitioned-build/merge coverage.

Separate from test_core_builders.py so these run even without the
optional hypothesis dependency (that file is importorskip-gated)."""
import numpy as np
import pytest

from repro.core import (build_partitioned, greedy_partition, merge_layers,
                        outline)
from repro.core.builders import LayerBuilder


def test_greedy_partition_seam_at_default_switch():
    """Cross the real ``switch = 8192`` boundary once: >8192 groups force
    the frontier-doubling path; the ``walk[:-1] + orbit`` seam must match
    the pure scalar walk."""
    n = 20_000
    rng = np.random.default_rng(5)
    widths = rng.integers(8, 64, n)
    lo = np.concatenate([[0], np.cumsum(widths[:-1])]).astype(np.int64)
    hi = (lo + widths).astype(np.int64)
    lam = 48.0                                   # ~1-2 items per group
    got = greedy_partition(lo, hi, lam)          # default switch: both paths
    ref = greedy_partition(lo, hi, lam, switch=n + 1)   # pure scalar walk
    assert len(got) > 8192
    assert np.array_equal(got, ref)


def test_greedy_partition_switch_invariant_randomized():
    """Boundaries are invariant to where the crossover lands, for random
    widths/λ straddling small switch values (frontier-doubling seeded at
    arbitrary walk prefixes)."""
    rng = np.random.default_rng(11)
    for trial in range(25):
        n = int(rng.integers(2, 600))
        widths = rng.integers(1, 40, n)
        lo = np.concatenate([[0], np.cumsum(widths[:-1])]).astype(np.int64)
        hi = (lo + widths).astype(np.int64)
        lam = float(rng.integers(1, 2000))
        ref = greedy_partition(lo, hi, lam, switch=n + 1)
        seq, s = [0], 0                          # sequential definition
        for i in range(1, n):
            if hi[i] - lo[s] > lam:
                seq.append(i)
                s = i
        assert np.array_equal(ref, np.asarray(seq, dtype=np.int64))
        for switch in (0, 1, int(rng.integers(0, 64))):
            got = greedy_partition(lo, hi, lam, switch=switch)
            assert np.array_equal(got, ref), (trial, switch)


@pytest.mark.parametrize("builder,kind", [
    (LayerBuilder("gstep", 4096, 16), "step"),
    (LayerBuilder("gband", 4096), "band"),
    (LayerBuilder("eband", 4096), "band"),
])
def test_merge_layers_lookup_validity_and_size_accounting(gmm_small, builder,
                                                          kind):
    """§5.4 partitioned building: per-partition layers merged into one must
    (a) stay a valid index layer — Eq. (1) containment for every pair,
    (b) account serialized bytes exactly as the sum of the parts (the
    paper's 1M-pair partitioning merges without padding or overlap)."""
    P = 7_000
    parts = [builder(gmm_small.slice(s, min(s + P, gmm_small.n)))
             for s in range(0, gmm_small.n, P)]
    assert len(parts) > 1
    merged = merge_layers(parts)
    assert merged.kind == kind
    # (a) merged lookups are valid at every original pair
    merged.validate_against(gmm_small)
    # (b) size accounting: bytes and node counts concatenate exactly
    assert merged.size_bytes == sum(q.size_bytes for q in parts)
    assert merged.n_nodes == sum(q.n_nodes for q in parts)
    np.testing.assert_array_equal(
        merged.node_sizes(), np.concatenate([q.node_sizes() for q in parts]))
    # build_partitioned is exactly build-per-partition + merge
    via_api = build_partitioned(builder, gmm_small, partition_pairs=P)
    assert via_api.size_bytes == merged.size_bytes
    # the merged layer outlines into a collection the next layer can use
    out = outline(merged, gmm_small)
    out.validate()
    assert out.size_bytes == merged.size_bytes
    assert out.total_weight == pytest.approx(gmm_small.total_weight)
