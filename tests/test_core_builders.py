"""Unit + property tests: layer builders and the Eq.(1) validity invariant."""
import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="optional test dep (pip install -e .[test])")
from hypothesis import given, settings, strategies as st

from repro.core import (KeyPositions, build_eband, build_gband, build_gstep,
                        build_partitioned, greedy_partition, make_builders,
                        outline)
from repro.core.builders import LayerBuilder

from conftest import make_keys


# ---------------------------------------------------------------------------
# greedy_partition: exactness against the sequential definition
# ---------------------------------------------------------------------------
@given(st.data())
@settings(max_examples=60, deadline=None)
def test_greedy_partition_matches_sequential(data):
    n = data.draw(st.integers(2, 500))
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    widths = rng.integers(1, 40, n)
    lo = np.concatenate([[0], np.cumsum(widths[:-1])]).astype(np.int64)
    hi = (lo + widths).astype(np.int64)
    lam = float(data.draw(st.integers(1, 2000)))
    got = greedy_partition(lo, hi, lam)
    ref, s = [0], 0
    for i in range(1, n):
        if hi[i] - lo[s] > lam:
            ref.append(i)
            s = i
    assert np.array_equal(got, np.asarray(ref, dtype=np.int64))


@given(st.data())
@settings(max_examples=60, deadline=None)
def test_greedy_partition_seam_switch_invariant(data):
    """The scalar-walk fast path and the frontier-doubling path must meet
    seamlessly: boundaries are invariant to where the ``switch``
    crossover lands, including the ``walk[:-1] + orbit`` seam (λ and
    group counts drawn to straddle the crossover)."""
    n = data.draw(st.integers(2, 600))
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    widths = rng.integers(1, 40, n)
    lo = np.concatenate([[0], np.cumsum(widths[:-1])]).astype(np.int64)
    hi = (lo + widths).astype(np.int64)
    lam = float(data.draw(st.integers(1, 2000)))
    # switch beyond any possible group count: pure scalar walk (reference)
    ref = greedy_partition(lo, hi, lam, switch=n + 1)
    # sequential definition, independent of both vectorized paths
    seq, s = [0], 0
    for i in range(1, n):
        if hi[i] - lo[s] > lam:
            seq.append(i)
            s = i
    assert np.array_equal(ref, np.asarray(seq, dtype=np.int64))
    for switch in (0, 1, data.draw(st.integers(0, 64))):
        got = greedy_partition(lo, hi, lam, switch=switch)
        assert np.array_equal(got, ref), switch


def test_greedy_partition_group_extent_bound():
    keys = make_keys("gmm", 20_000)
    D = KeyPositions.fixed_record(keys, 16)
    lam = 512.0
    starts = greedy_partition(D.lo, D.hi, lam)
    ends = np.append(starts[1:], D.n)
    extent = D.hi[ends - 1] - D.lo[starts]
    # every greedy group (except forced single-item groups) is within λ
    multi = (ends - starts) > 1
    assert np.all(extent[multi] <= lam)


# ---------------------------------------------------------------------------
# builder validity: Eq. (1) must hold on every dataset shape
# ---------------------------------------------------------------------------
BUILDERS = [
    ("gstep", lambda D: build_gstep(D, p=16, lam=1024)),
    ("gstep-small", lambda D: build_gstep(D, p=4, lam=64)),
    ("eband", lambda D: build_eband(D, lam=1024)),
    ("gband", lambda D: build_gband(D, lam=1024)),
]


@pytest.mark.parametrize("kind", ["uniform", "gmm", "books", "fb"])
@pytest.mark.parametrize("bname,build", BUILDERS)
def test_builder_validity(kind, bname, build):
    keys = make_keys(kind, 5_000, seed=7)
    D = KeyPositions.fixed_record(keys, 16)
    layer = build(D)
    layer.validate_against(D)          # asserts ŷ(x) ⊇ y for all pairs


@given(st.data())
@settings(max_examples=40, deadline=None)
def test_builder_validity_property(data):
    """Adversarial: arbitrary sorted keys, arbitrary record sizes."""
    n = data.draw(st.integers(2, 300))
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    keys = np.unique(rng.integers(0, 2**48, n).astype(np.uint64))
    widths = rng.integers(1, 1000, len(keys))
    offs = np.concatenate([[0], np.cumsum(widths)]).astype(np.int64)
    D = KeyPositions.from_offsets(keys, offs)
    lam = float(data.draw(st.sampled_from([64, 256, 4096, 1 << 20])))
    kind = data.draw(st.sampled_from(["gstep", "gband", "eband"]))
    layer = LayerBuilder(kind=kind, lam=lam, p=8)(D)
    layer.validate_against(D)


def test_gband_width_bound():
    """GBand: every multi-pair node's width 2δ ≤ λ (+fit slack)."""
    keys = make_keys("uniform", 20_000)
    D = KeyPositions.fixed_record(keys, 16)
    lam = 2048.0
    layer = build_gband(D, lam)
    # nodes that cover >1 pair obey the bound by the greedy feasibility test
    node_of = np.searchsorted(layer.node_keys, D.keys, side="right") - 1
    counts = np.bincount(np.maximum(node_of, 0), minlength=layer.n_nodes)
    multi = counts > 1
    assert np.all(2 * layer.delta[multi] <= lam + 8.0)


def test_outline_weights_conserved(gmm_small):
    layer = build_gstep(gmm_small, p=16, lam=4096)
    out = outline(layer, gmm_small)
    assert out.total_weight == pytest.approx(gmm_small.total_weight)
    assert out.size_bytes == layer.size_bytes
    out.validate()


def test_partitioned_build_equals_merged_validity(gmm_small):
    for b in (LayerBuilder("gstep", 2048, 16), LayerBuilder("eband", 2048),
              LayerBuilder("gband", 2048)):
        layer = build_partitioned(b, gmm_small, partition_pairs=7_000)
        layer.validate_against(gmm_small)


def test_make_builders_grid_matches_eq8():
    F = make_builders(lam_low=2**8, lam_high=2**20, base=2.0, p=16)
    # 13 λ values × 3 kinds (Eq. 8 example: 39 builders)
    assert len(F) == 39
    lams = sorted({f.lam for f in F})
    assert lams[0] == 2**8 and lams[-1] == 2**20
