"""Latency model, storage profiles, paper §2.1 worked-example arithmetic."""
import numpy as np
import pytest

from repro.core import (AffineProfile, AffineUniformProfile, KeyPositions,
                        MeasuredProfile, PROFILES, IndexDesign,
                        expected_latency, latency_breakdown, lookup_batch)
from repro.core.nodes import StepLayer
from repro.core.keyset import POS_DTYPE


def test_affine_profile():
    p = AffineProfile(100e-6, 1e9)
    assert p(4096) == pytest.approx(100e-6 + 4096 / 1e9)
    deltas = np.array([1.0, 10.0, 100.0])
    assert np.all(np.diff(p(deltas)) > 0)


def test_affine_uniform_profile_reduces_to_affine():
    p = AffineUniformProfile(1e-3, 1e-3, 1e8, 1e8)
    q = AffineProfile(1e-3, 1e8)
    assert p(12345.0) == pytest.approx(q(12345.0), rel=1e-6)


def test_affine_uniform_closed_form():
    # paper §3.2: T = (ℓ0+ℓ1)/2 + Δ(lnB1−lnB0)/(B1−B0)
    p = AffineUniformProfile(1e-3, 3e-3, 1e8, 4e8)
    expected = 2e-3 + 1e6 * (np.log(4e8) - np.log(1e8)) / 3e8
    assert p(1e6) == pytest.approx(expected, rel=1e-9)


def test_measured_profile_monotone_and_fit():
    mp = MeasuredProfile(deltas=(256, 4096, 65536, 1 << 20),
                         seconds=(1e-4, 1.2e-4, 3e-4, 1.3e-3))
    d = np.array([100, 1000, 10000, 1 << 21])
    assert np.all(np.diff(mp(d)) >= 0)
    aff = mp.fit_affine()
    assert aff.latency > 0 and aff.bandwidth > 0


def _example_btree(n_keys, fanout, node_bytes, page_bytes):
    """Construct the §2.1 B-tree shapes: uniform pieces of `page` width."""
    keys = np.arange(1, n_keys + 1, dtype=np.uint64) * 1000
    D = KeyPositions.fixed_record(keys, page_bytes // (n_keys // n_keys))
    return D


def test_paper_2_1_example_numbers():
    """§2.1: B200 vs B5000 on SSD(100µs,1GB/s) vs Cloud(100ms,100MB/s).

    The paper computes per-lookup times from the formula
    latency + size/bandwidth per fetch: B200 = 3 nodes + 1 page;
    B5000 = 2 nodes + 1 page.  Validate L_SM reproduces its numbers.
    """
    ssd = PROFILES["ssd_ex"]
    cloud = PROFILES["cloud_ex"]
    KB = 1024.0

    def lookup_time(profile, n_nodes, node_bytes, page_bytes):
        return n_nodes * float(profile(node_bytes)) + float(profile(page_bytes))

    b200_ssd = lookup_time(ssd, 3, 4 * KB, 4 * KB)
    b5000_ssd = lookup_time(ssd, 2, 100 * KB, 4 * KB)
    # paper: 416 µs vs 504 µs (21% slower)
    assert b200_ssd == pytest.approx(416e-6, rel=0.02)
    assert b5000_ssd == pytest.approx(504e-6, rel=0.02)
    assert b5000_ssd > b200_ssd

    b200_cloud = lookup_time(cloud, 3, 4 * KB, 4 * KB)
    b5000_cloud = lookup_time(cloud, 2, 100 * KB, 4 * KB)
    # paper: 400.16 ms vs 302.04 ms (B200 32% slower)
    assert b200_cloud == pytest.approx(400.16e-3, rel=0.02)
    assert b5000_cloud == pytest.approx(302.04e-3, rel=0.02)
    assert b200_cloud > b5000_cloud


def test_expected_latency_composition():
    """L_SM = T(s_root) + Σ E[T(Δ_l)] — check against a hand-built 2-layer."""
    keys = np.arange(0, 1024, dtype=np.uint64)
    D = KeyPositions.fixed_record(keys, 16)
    # layer 1: 64 pieces of 16 keys → width 256 B; 4 nodes of 16 pieces
    pk = keys[::16]
    pp = np.arange(65, dtype=POS_DTYPE) * 256
    l1 = StepLayer(piece_keys=pk, piece_pos=pp,
                   node_piece_off=np.arange(0, 65, 16, dtype=np.int64))
    # layer 2: 4 pieces (one per node below, 16*16=256 B each), 1 node
    pk2 = pk[::16]
    pp2 = np.arange(5, dtype=POS_DTYPE) * (16 * 16)
    l2 = StepLayer(piece_keys=pk2, piece_pos=pp2,
                   node_piece_off=np.array([0, 4], dtype=np.int64))
    design = IndexDesign(layers=(l1, l2), data=D)
    prof = AffineProfile(1e-4, 1e8)
    got = expected_latency(design, prof)
    want = float(prof(4 * 16)) + float(prof(256)) + float(prof(256))
    assert got == pytest.approx(want, rel=1e-12)
    bd = latency_breakdown(design, prof)
    assert bd["total"] == pytest.approx(got, rel=1e-12)
    assert len(bd["layers"]) == 2

    res = lookup_batch(design, keys[17:18], prof)
    assert res.lo[0] == 16 * 16 and res.hi[0] == 2 * 16 * 16  # covers key 17
