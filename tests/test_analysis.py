"""airlint: per-rule seeded violations, suppression hygiene, JSON schema
stability, CLI exit codes — and the fatal gate that the repo's own tree
is clean under every shipped rule."""
import json
import os
import textwrap

import pytest

from repro.analysis import ALL_RULES, run_checks
from repro.analysis.__main__ import JSON_SCHEMA_VERSION, main
from repro.analysis.core import collect_allows
from repro.analysis.rules import rules_by_name
from repro.analysis.rules import spec_roundtrip as spec_roundtrip_mod
from repro.analysis.rules.kernel_fallback import KernelFallbackShapeRule
from repro.analysis.rules.spec_roundtrip import roundtrip_problems

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def write(path, src):
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(textwrap.dedent(src))
    return str(path)


def check(paths, rule_names):
    findings, _ = run_checks([str(p) for p in paths],
                             rules_by_name(rule_names))
    return findings


# ---------------------------------------------------------------------------
# the gate itself: the repo's own tree is clean (this test is the fatal
# contract CI's airlint step re-checks; a violation anywhere in src/
# without a justified allow fails here first)
# ---------------------------------------------------------------------------
def test_repo_tree_is_clean_under_all_rules():
    findings, n = run_checks([os.path.join(REPO, "src"),
                              os.path.join(REPO, "benchmarks"),
                              os.path.join(REPO, "examples")], ALL_RULES)
    assert n > 50                       # the scan actually saw the repo
    assert findings == [], "\n" + "\n".join(f.format() for f in findings)


# ---------------------------------------------------------------------------
# AIR001 pread-seam
# ---------------------------------------------------------------------------
def test_pread_seam_flags_raw_pread_and_open(tmp_path):
    p = write(tmp_path / "reader.py", """\
        import os

        def f(fd, path):
            raw = os.pread(fd, 4, 0)
            fd2 = os.open(path, os.O_RDONLY)
            return raw, fd2
        """)
    fs = check([p], ["pread-seam"])
    assert [(f.code, f.line) for f in fs] == [("AIR001", 4), ("AIR001", 5)]
    assert all(f.path == p for f in fs)


def test_pread_seam_exempts_the_seam_module(tmp_path):
    p = write(tmp_path / "repro" / "serve" / "backend.py", """\
        import os

        def pread_full(fd):
            return os.pread(fd, 4, 0)
        """)
    assert check([p], ["pread-seam"]) == []


# ---------------------------------------------------------------------------
# AIR002 lock-discipline
# ---------------------------------------------------------------------------
def test_lock_discipline_stats_cache_and_pread(tmp_path):
    p = write(tmp_path / "engine.py", """\
        class Svc:
            def f(self, st):
                st.stats.hits += 1
                st.stats.record_read(1)
                st.cache.get(1)
                with self._mu:
                    st.stats.hits += 1
                    st.storage.pread(4, 0)
        """)
    fs = check([p], ["lock-discipline"])
    got = {(f.line, f.message.split("'")[1]) for f in fs}
    assert got == {(3, ".stats.hits"), (4, ".stats.record_read(...)"),
                   (5, ".cache.get(...)"), (8, ".pread(...)")}


def test_lock_discipline_keeps_state_through_except_blocks(tmp_path):
    # a `with self._mu:` inside an except handler must count as locked
    p = write(tmp_path / "engine.py", """\
        class Svc:
            def f(self, st):
                try:
                    st.storage.pread(4, 0)
                except OSError:
                    with self._mu:
                        st.stats.degraded_runs += 1
        """)
    assert check([p], ["lock-discipline"]) == []


def test_lock_discipline_skips_modules_without_the_idiom(tmp_path):
    p = write(tmp_path / "other.py", """\
        def f(st):
            st.stats.hits += 1
        """)
    assert check([p], ["lock-discipline"]) == []


# ---------------------------------------------------------------------------
# AIR003 typed-error-flow
# ---------------------------------------------------------------------------
def test_typed_error_flow_flags_broad_except_in_serve(tmp_path):
    p = write(tmp_path / "serve" / "svc.py", """\
        def f():
            try:
                return 1
            except Exception:
                return None
        """)
    fs = check([p], ["typed-error-flow"])
    assert [(f.code, f.line) for f in fs] == [("AIR003", 4)]


def test_typed_error_flow_accepts_shield_and_reraise(tmp_path):
    p = write(tmp_path / "fleet" / "svc.py", """\
        def f():
            try:
                return 1
            except StorageError:
                return 2
            except Exception:
                return None

        def g():
            try:
                return 1
            except Exception:
                raise
        """)
    assert check([p], ["typed-error-flow"]) == []


def test_typed_error_flow_ignores_out_of_scope_paths(tmp_path):
    p = write(tmp_path / "core" / "x.py", """\
        def f():
            try:
                return 1
            except Exception:
                return None
        """)
    assert check([p], ["typed-error-flow"]) == []


# ---------------------------------------------------------------------------
# AIR004 spec-roundtrip
# ---------------------------------------------------------------------------
_BROKEN_SPEC_SRC = """\
import dataclasses
import json


@dataclasses.dataclass(frozen=True)
class BrokenSpec:
    a: int = 1
    b: int = 2

    def to_dict(self):
        return {"a": self.a}

    @classmethod
    def from_dict(cls, d):
        return cls(**d)

    def to_json(self):
        return json.dumps(self.to_dict())

    @classmethod
    def from_json(cls, s):
        return cls.from_dict(json.loads(s))
"""


def test_roundtrip_problems_catch_dropped_field(tmp_path, monkeypatch):
    p = write(tmp_path / "broken_spec.py", _BROKEN_SPEC_SRC)
    monkeypatch.syspath_prepend(str(tmp_path))
    import broken_spec
    probs = roundtrip_problems(broken_spec.BrokenSpec, lambda c: c())
    assert any("field 'b' missing from to_dict()" in m for m in probs)


def test_spec_roundtrip_rule_anchors_at_class_def(tmp_path, monkeypatch):
    # distinct module name: broken_spec is already in sys.modules from the
    # test above, and a cached module would anchor at the wrong file
    p = write(tmp_path / "broken_spec_anchor.py", _BROKEN_SPEC_SRC)
    monkeypatch.syspath_prepend(str(tmp_path))
    monkeypatch.setattr(
        spec_roundtrip_mod, "SPEC_TARGETS",
        [("broken_spec_anchor", "BrokenSpec", lambda c: c())])
    rule = spec_roundtrip_mod.SpecRoundtripRule()
    files = [p, os.path.join(REPO, "src/repro/api/spec.py")]  # gate opens
    fs = list(rule.check_project(files))
    assert fs, "broken spec produced no findings"
    # class BrokenSpec: sits on line 6 of the fixture source
    assert all((f.path, f.line, f.code) == (p, 6, "AIR004") for f in fs)
    assert any("field 'b' missing" in f.message for f in fs)


def test_real_specs_round_trip_clean():
    for mod, cls_name, build in spec_roundtrip_mod.SPEC_TARGETS:
        import importlib
        cls = getattr(importlib.import_module(mod), cls_name)
        assert roundtrip_problems(cls, build) == [], (mod, cls_name)


# ---------------------------------------------------------------------------
# AIR005 shim-discipline
# ---------------------------------------------------------------------------
def test_shim_discipline_flags_imports_calls_and_legacy_kwargs(tmp_path):
    p = write(tmp_path / "caller.py", """\
        from repro.core.serialize import load_index

        def g(path, data):
            return load_index(path, data)

        def h(path, IndexService):
            return IndexService(path, cache_bytes=(1,), use_device=True)
        """)
    fs = check([p], ["shim-discipline"])
    assert [(f.code, f.line) for f in fs] == [
        ("AIR005", 1), ("AIR005", 4), ("AIR005", 7)]
    assert "cache_bytes, use_device" in fs[2].message


def test_shim_discipline_exempts_init_reexports(tmp_path):
    p = write(tmp_path / "pkg" / "__init__.py", """\
        from repro.core.serialize import load_index
        """)
    assert check([p], ["shim-discipline"]) == []


# ---------------------------------------------------------------------------
# AIR006 kernel-fallback-shape
# ---------------------------------------------------------------------------
def test_kernel_fallback_shape_seeded_violations(tmp_path):
    init = write(tmp_path / "repro" / "kernels" / "badkern" / "__init__.py",
                 "VERSION = 1\n")
    ops = write(tmp_path / "repro" / "kernels" / "badkern" / "ops.py", """\
        import jax

        def run(x, backend="pallas"):
            if backend == "pallas":
                return jax.numpy.asarray(x)
            return x
        """)
    fs = list(KernelFallbackShapeRule().check_project([init, ops]))
    msgs = [f.message for f in fs]
    assert any("missing ref.py" in m for m in msgs)
    assert any("does not re-export from .ops" in m for m in msgs)
    assert any("'jnp', 'numpy'" in m for m in msgs)
    jax_f = [f for f in fs if "module top level" in f.message]
    assert [(f.path, f.line) for f in jax_f] == [(ops, 1)]


def test_kernel_fallback_shape_accepts_repo_kernels():
    findings, _ = run_checks([os.path.join(REPO, "src/repro/kernels")],
                             [KernelFallbackShapeRule()])
    assert findings == []


# ---------------------------------------------------------------------------
# AIR000 allow hygiene + suppression semantics
# ---------------------------------------------------------------------------
def test_justified_allow_suppresses(tmp_path):
    p = write(tmp_path / "reader.py", """\
        import os

        def f(fd):
            return os.pread(fd, 4, 0)  # airlint: allow[pread-seam] -- probe
        """)
    assert check([p], ["pread-seam"]) == []


def test_standalone_allow_covers_next_code_line(tmp_path):
    p = write(tmp_path / "reader.py", """\
        import os

        def f(fd):
            # airlint: allow[pread-seam] -- offline path, justified over
            # two comment lines that both belong to this suppression
            return os.pread(fd, 4, 0)
        """)
    assert check([p], ["pread-seam"]) == []


def test_allow_without_reason_is_a_finding_and_never_suppresses(tmp_path):
    p = write(tmp_path / "reader.py", """\
        import os

        def f(fd):
            return os.pread(fd, 4, 0)  # airlint: allow[pread-seam]
        """)
    fs = check([p], ["pread-seam"])
    assert [(f.code, f.line) for f in fs] == [("AIR000", 4), ("AIR001", 4)]
    assert "without a justification" in fs[0].message


def test_allow_for_a_different_rule_does_not_suppress(tmp_path):
    p = write(tmp_path / "reader.py", """\
        import os

        def f(fd):
            return os.pread(fd, 4, 0)  # airlint: allow[lock-discipline] -- x
        """)
    fs = check([p], ["pread-seam"])
    assert [f.code for f in fs] == ["AIR001"]


def test_collect_allows_grammar():
    allows = collect_allows([
        "x = 1  # airlint: allow[pread-seam] -- reason here",
        "# airlint: allow[lock-discipline] -- standalone",
        "y = 2",
        "# airlint: allow[shim-discipline]",
    ])
    assert [(a.rule, a.line, a.comment_line, bool(a.reason))
            for a in allows] == [
        ("pread-seam", 1, 1, True),
        ("lock-discipline", 3, 2, True),
        ("shim-discipline", 5, 4, False),
    ]


# ---------------------------------------------------------------------------
# AIR999 parse failure is a finding, not a crash
# ---------------------------------------------------------------------------
def test_syntax_error_yields_air999(tmp_path):
    p = write(tmp_path / "broken.py", "def f(:\n")
    findings, n = run_checks([p], rules_by_name(["pread-seam"]))
    assert n == 1
    assert [f.code for f in findings] == ["AIR999"]


# ---------------------------------------------------------------------------
# CLI: exit codes + --json schema stability
# ---------------------------------------------------------------------------
def test_cli_exit_codes_and_json_schema(tmp_path, capsys):
    bad = write(tmp_path / "bad.py", """\
        import os

        def f(fd):
            return os.pread(fd, 4, 0)
        """)
    clean = write(tmp_path / "clean.py", "x = 1\n")
    report = tmp_path / "airlint.json"

    assert main([clean]) == 0
    assert main(["--rules", "no-such-rule", clean]) == 2
    assert main([bad, "--rules", "pread-seam",
                 "--json", str(report)]) == 1
    out = capsys.readouterr().out
    assert f"{bad}:4:" in out and "AIR001" in out

    blob = json.loads(report.read_text())
    assert set(blob) == {"version", "paths", "rules", "files_scanned",
                         "findings"}
    assert blob["version"] == JSON_SCHEMA_VERSION == 1
    assert blob["files_scanned"] == 1
    assert blob["paths"] == [bad]
    assert blob["rules"] == [{"name": "pread-seam", "code": "AIR001",
                              "description": rules_by_name(
                                  ["pread-seam"])[0].description}]
    (f,) = blob["findings"]
    assert set(f) == {"rule", "code", "path", "line", "col", "message"}
    assert (f["rule"], f["code"], f["path"], f["line"]) == \
        ("pread-seam", "AIR001", bad, 4)


def test_cli_list_rules(capsys):
    assert main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    for code in ("AIR001", "AIR002", "AIR003", "AIR004", "AIR005",
                 "AIR006"):
        assert code in out


def test_rules_by_name_rejects_unknown():
    with pytest.raises(KeyError, match="available:"):
        rules_by_name(["nope"])
